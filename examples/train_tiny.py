"""Train a reduced assistant backbone for a few hundred steps on CPU,
exercising the full training substrate: deterministic data pipeline,
AdamW + cosine schedule, grad accumulation, checkpoint/restore with a
simulated preemption mid-run.

Run:  PYTHONPATH=src python examples/train_tiny.py [--steps 200]
"""
import argparse
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.configs import registry
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models.config import reduced
from repro.training.train_loop import TrainSettings, init_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--preempt-at", type=int, default=None,
                    help="simulate preemption+resume at this step")
    args = ap.parse_args()
    preempt_at = args.preempt_at or args.steps // 2

    cfg = reduced(registry.get_config("artic-assistant"),
                  mrope_sections=None, dtype="float32",
                  param_dtype="float32", vocab=512)
    settings = TrainSettings(peak_lr=1e-3, warmup_steps=20,
                             total_steps=args.steps, grad_accum=2)
    pipe = TokenPipeline(DataConfig(vocab=cfg.vocab, batch=8, seq=64),
                         process_index=0, process_count=1)
    step_fn = jax.jit(make_train_step(cfg, settings))
    ckdir = tempfile.mkdtemp(prefix="artic_ckpt_")
    mgr = CheckpointManager(ckdir, keep=2)

    state = init_state(jax.random.PRNGKey(0), cfg, settings)
    n_params = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"model {cfg.name}: {n_params / 1e6:.2f}M params, "
          f"{args.steps} steps, ckpt dir {ckdir}")

    t0, losses = time.time(), []
    for i in range(preempt_at):
        state, metrics = step_fn(state, jax.tree.map(jnp.asarray,
                                                     pipe.batch_at(i)))
        losses.append(float(metrics["loss"]))
        if i % 20 == 0:
            print(f"step {i:4d} loss {losses[-1]:.3f} "
                  f"lr {float(metrics['lr']):.2e}")
    mgr.save(preempt_at, state, extra=pipe.cursor(preempt_at))
    print(f"--- simulated preemption at step {preempt_at}: "
          "checkpoint saved, process 'restarts' ---")

    restored, extra = mgr.restore(jax.eval_shape(lambda: state))
    state = restored
    for i in range(extra["data_step"], args.steps):
        state, metrics = step_fn(state, jax.tree.map(jnp.asarray,
                                                     pipe.batch_at(i)))
        losses.append(float(metrics["loss"]))
        if i % 20 == 0:
            print(f"step {i:4d} loss {losses[-1]:.3f}")
    dt = time.time() - t0
    print(f"done: loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({args.steps} steps in {dt:.0f}s, "
          f"{args.steps * 8 * 64 / dt:.0f} tok/s)")
    assert losses[-1] < losses[0], "loss must decrease"


if __name__ == "__main__":
    main()

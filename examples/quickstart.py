"""Quickstart: the Artic loop in 60 seconds (CPU).

Renders a synthetic retail scene, streams it over a fluctuating 5G uplink
under (a) WebRTC and (b) Artic, and prints the QoE comparison — the
paper's Figure 13 in miniature, declared through the scenario API:
a workload is a `ScenarioSpec`, `grid()` expands axes of it, and
`run_scenarios` compiles the specs into fleet cohorts and runs them.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.api import ScenarioSpec, build_session, grid, run_scenarios


def main():
    base = ScenarioSpec(scene="retail", code_period_frames=40,
                        trace="fluctuating",
                        trace_kwargs=dict(switches_per_min=6),
                        duration=40.0,
                        qa="periodic", qa_kwargs=dict(count=8,
                                                      answer_window=3.4))
    specs = grid(base, system=["webrtc", "artic"])

    # peek at what one spec materializes into
    s = build_session(specs[0])
    print(f"scene: {s.scene.category}, {len(s.scene.objects)} objects "
          f"(glyph cells {[o.cell for o in s.scene.objects]} px)")
    print(f"trace: {s.trace.name}, mean {np.mean(s.trace.bw) / 1e6:.2f} "
          "Mbps\n")

    result = run_scenarios(specs)   # both systems, one fleet cohort
    for spec, m in zip(result.specs, result.metrics):
        name = "WebRTC (GCC)" if spec.system == "webrtc" else "Artic"
        print(f"{name:14s} accuracy={m.accuracy:.2f}  "
              f"avg latency={m.avg_latency_ms:6.0f} ms  "
              f"p95={m.p95_latency_ms:6.0f} ms  "
              f"uplink={m.bandwidth_used / 1e6:.2f} Mbps  "
              f"drops={m.dropped_frames}")


if __name__ == "__main__":
    main()

"""Quickstart: the Artic loop in 60 seconds (CPU).

Renders a synthetic retail scene, streams it over a fluctuating 5G uplink
under (a) WebRTC and (b) Artic, and prints the QoE comparison — the
paper's Figure 13 in miniature.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.session import QASample, SessionConfig, run_session
from repro.net.traces import fluctuating_trace
from repro.video.scenes import make_scene


def main():
    scene = make_scene("retail", moving=False, seed=0,
                       code_period_frames=40)
    trace = fluctuating_trace(duration=40.0, switches_per_min=6, seed=0)
    qa = [QASample(t_ask=4.5 + 4.0 * i, obj_idx=i % len(scene.objects),
                   answer_window=3.4) for i in range(8)]

    print(f"scene: {scene.category}, {len(scene.objects)} objects "
          f"(glyph cells {[o.cell for o in scene.objects]} px)")
    print(f"trace: {trace.name}, mean {np.mean(trace.bw) / 1e6:.2f} Mbps\n")

    for name, flags in (("WebRTC (GCC)", dict(use_recap=False, use_zeco=False)),
                        ("Artic", dict(use_recap=True, use_zeco=True))):
        m = run_session(scene, qa, trace,
                        SessionConfig(duration=40.0, cc_kind="gcc", **flags))
        print(f"{name:14s} accuracy={m.accuracy:.2f}  "
              f"avg latency={m.avg_latency_ms:6.0f} ms  "
              f"p95={m.p95_latency_ms:6.0f} ms  "
              f"uplink={m.bandwidth_used / 1e6:.2f} Mbps  "
              f"drops={m.dropped_frames}")


if __name__ == "__main__":
    main()

"""Fig. 13-style four-system comparison on one harsh mobility trace.

    WebRTC | +ReCapABR | +ZeCoStream | Artic   x   {GCC, BBR}

Run:  PYTHONPATH=src python examples/artic_vs_webrtc.py
"""
import numpy as np

from repro.core.session import QASample, SessionConfig, run_session
from repro.net.traces import mobility_trace
from repro.video.scenes import make_scene

SYSTEMS = {
    "WebRTC": dict(use_recap=False, use_zeco=False),
    "WebRTC+ReCapABR": dict(use_recap=True, use_zeco=False),
    "WebRTC+ZeCoStream": dict(use_recap=False, use_zeco=True),
    "Artic": dict(use_recap=True, use_zeco=True),
}


def main():
    duration = 60.0
    scene = make_scene("street", moving=True, seed=1, code_period_frames=40)
    trace = mobility_trace("driving", duration, seed=1)
    qa = [QASample(t_ask=4.5 + 4.0 * i, obj_idx=i % len(scene.objects),
                   answer_window=3.4)
          for i in range(int(duration / 4) - 2)]

    print(f"{'system':20s} {'acc':>6s} {'avg ms':>8s} {'p95 ms':>8s} "
          f"{'Mbps':>6s} {'drops':>6s}")
    for cc in ("gcc", "bbr"):
        print(f"--- {cc.upper()} ---")
        for name, flags in SYSTEMS.items():
            m = run_session(scene, qa, trace, SessionConfig(
                duration=duration, cc_kind=cc, **flags))
            print(f"{name:20s} {m.accuracy:6.2f} {m.avg_latency_ms:8.0f} "
                  f"{m.p95_latency_ms:8.0f} {m.bandwidth_used / 1e6:6.2f} "
                  f"{m.dropped_frames:6d}")


if __name__ == "__main__":
    main()

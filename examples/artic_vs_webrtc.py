"""Fig. 13-style four-system comparison on one harsh mobility trace.

    WebRTC | +ReCapABR | +ZeCoStream | Artic   x   {GCC, BBR}

All eight cells run as ONE fleet call: the sessions advance in lockstep
ticks with a single batched codec dispatch per tick (repro.core.fleet).

Run:  PYTHONPATH=src python examples/artic_vs_webrtc.py
"""
from repro.core.fleet import FleetSession, run_fleet
from repro.core.session import QASample, SessionConfig
from repro.net.traces import mobility_trace
from repro.video.scenes import make_scene

SYSTEMS = {
    "WebRTC": dict(use_recap=False, use_zeco=False),
    "WebRTC+ReCapABR": dict(use_recap=True, use_zeco=False),
    "WebRTC+ZeCoStream": dict(use_recap=False, use_zeco=True),
    "Artic": dict(use_recap=True, use_zeco=True),
}


def main():
    duration = 60.0
    scene = make_scene("street", moving=True, seed=1, code_period_frames=40)
    trace = mobility_trace("driving", duration, seed=1)
    qa = [QASample(t_ask=4.5 + 4.0 * i, obj_idx=i % len(scene.objects),
                   answer_window=3.4)
          for i in range(int(duration / 4) - 2)]

    cells = [(cc, name, flags) for cc in ("gcc", "bbr")
             for name, flags in SYSTEMS.items()]
    metrics = run_fleet([
        FleetSession(scene=scene, qa_samples=qa, trace=trace,
                     cfg=SessionConfig(duration=duration, cc_kind=cc,
                                       **flags))
        for cc, _, flags in cells])

    print(f"{'system':20s} {'acc':>6s} {'avg ms':>8s} {'p95 ms':>8s} "
          f"{'Mbps':>6s} {'drops':>6s}")
    last_cc = None
    for (cc, name, _), m in zip(cells, metrics):
        if cc != last_cc:
            print(f"--- {cc.upper()} ---")
            last_cc = cc
        print(f"{name:20s} {m.accuracy:6.2f} {m.avg_latency_ms:8.0f} "
              f"{m.p95_latency_ms:8.0f} {m.bandwidth_used / 1e6:6.2f} "
              f"{m.dropped_frames:6d}")


if __name__ == "__main__":
    main()

"""Fig. 13-style four-system comparison on one harsh mobility trace.

    WebRTC | +ReCapABR | +ZeCoStream | Artic   x   {GCC, BBR}

The eight cells are declared as a scenario grid and run through ONE
`run_scenarios` call: the compiler folds them into a single cohort of
lockstep sessions with one batched codec dispatch per tick
(repro.core.fleet underneath).

Run:  PYTHONPATH=src python examples/artic_vs_webrtc.py
"""
from repro.api import SYSTEMS, ScenarioSpec, grid, run_scenarios

PRETTY = {"webrtc": "WebRTC", "webrtc+recap": "WebRTC+ReCapABR",
          "webrtc+zeco": "WebRTC+ZeCoStream", "artic": "Artic"}


def main():
    duration = 60.0
    base = ScenarioSpec(duration=duration, scene="street", moving=True,
                        scene_seed=1, code_period_frames=40,
                        trace="mobility.driving", trace_seed=1,
                        qa="periodic",
                        qa_kwargs=dict(count=int(duration / 4) - 2,
                                       answer_window=3.4))
    result = run_scenarios(grid(base, cc_kind=["gcc", "bbr"],
                                system=list(SYSTEMS)))

    print(f"{'system':20s} {'acc':>6s} {'avg ms':>8s} {'p95 ms':>8s} "
          f"{'Mbps':>6s} {'drops':>6s}")
    last_cc = None
    for s, m in zip(result.specs, result.metrics):
        if s.cc_kind != last_cc:
            print(f"--- {s.cc_kind.upper()} ---")
            last_cc = s.cc_kind
        print(f"{PRETTY[s.system]:20s} {m.accuracy:6.2f} "
              f"{m.avg_latency_ms:8.0f} {m.p95_latency_ms:8.0f} "
              f"{m.bandwidth_used / 1e6:6.2f} {m.dropped_frames:6d}")


if __name__ == "__main__":
    main()

"""Serve the MLLM video assistant with the real JAX model in the loop.

End-to-end driver of the serving stack (the paper's deployment kind):

 1. continuous-batching engine serves a queue of text requests over the
    artic-assistant backbone (slot reuse, per-slot lengths);
 2. a streaming *video session*: codec-degraded frames become patch
    embeddings appended to the MLLM context (chunked prefill); a question
    is decoded; the logit-derived confidence C_t and a gradient-saliency
    grounding box are produced — the two Artic feedback signals — at two
    different encoding bitrates, showing C_t tracking degradation.

Run:  PYTHONPATH=src python examples/serve_assistant.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.core.confidence import raw_score_from_telemetry
from repro.core.grounding import saliency_boxes
from repro.models import transformer as tfm
from repro.serving.engine import Engine, Request
from repro.video import codec
from repro.video.scenes import make_scene


def batched_serving(cfg, params):
    print("=== continuous batching: 6 requests through 2 slots ===")
    eng = Engine(cfg, params, max_batch=2, max_len=96)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(6):
        eng.submit(Request(uid=i,
                           tokens=rng.integers(0, cfg.vocab, 12, dtype=np.int32),
                           max_new_tokens=8))
    done = eng.run_until_drained()
    dt = time.time() - t0
    print(f"served {len(done)} requests / {eng.stats.tokens_out} tokens "
          f"in {dt:.1f}s ({eng.stats.tokens_out / dt:.1f} tok/s, "
          f"{eng.stats.steps} engine ticks)")
    for r in done[:3]:
        conf = raw_score_from_telemetry(
            [np.exp(l) for l in r.logprobs], r.entropies, cfg.vocab)
        print(f"  req {r.uid}: {len(r.output)} tokens, confidence {conf:.2f}")


def video_session(cfg, params):
    print("\n=== Artic video session with the real MLLM ===")
    scene = make_scene("retail", False, seed=0, h=128, w=128)
    patch = 16
    gy, gx = 128 // patch, 128 // patch
    key = jax.random.PRNGKey(0)
    # frozen random patch projection = the stubbed vision frontend
    proj = jax.random.normal(key, (patch * patch, cfg.d_model)) * 0.05

    def frame_to_embeds(frame):
        f = jnp.asarray(frame, jnp.float32)
        patches = f.reshape(gy, patch, gx, patch).transpose(0, 2, 1, 3)
        patches = patches.reshape(gy * gx, patch * patch)
        return (patches - 0.5) @ proj

    question = jnp.arange(1, 9, dtype=jnp.int32)[None, :]  # stub question ids

    for kbps in (2000.0, 150.0):
        frame = scene.render(0)
        qp, enc = codec.rate_control(
            jnp.asarray(frame), np.zeros((16, 16), np.float32),
            jnp.float32(kbps * 100))
        rx = codec.decode(enc)

        def answer_conf(embeds):
            cache = tfm.init_cache(cfg, 1, 256)
            _, cache = tfm.prefill_extend(params, cache,
                                          {"embeds": embeds[None]}, cfg)
            logits, cache = tfm.prefill_extend(params, cache,
                                               {"tokens": question}, cfg)
            logp = jax.nn.log_softmax(logits[0, -1].astype(jnp.float32))
            top = jnp.max(jnp.exp(logp))
            ent = -jnp.sum(jnp.exp(logp) * logp)
            return top, ent, logits

        embeds = frame_to_embeds(rx)
        top, ent, _ = answer_conf(embeds)
        # gradient saliency w.r.t. patch embeddings (one VJP)
        g = jax.grad(lambda e: answer_conf(e)[0])(embeds)
        boxes = saliency_boxes(np.asarray(g), (gy, gx), (128, 128))
        conf = raw_score_from_telemetry([float(top)], [float(ent)], cfg.vocab)
        print(f"  {kbps:6.0f} kbps: confidence C_t={conf:.3f}, "
              f"grounding box={np.round(boxes[0], 0) if boxes else None}")
    print("  (random-init weights -> C_t is flat; with a trained model "
          "C_t tracks degradation, cf. benchmarks/bench_confidence.py. "
          "This demo exercises the plumbing: logits->C_t telemetry and "
          "the one-VJP saliency box that feeds ZeCoStream's QP map.)")


def main():
    cfg = registry.get_config("artic-assistant")
    params = tfm.init(jax.random.PRNGKey(0), cfg)
    batched_serving(cfg, params)
    video_session(cfg, params)


if __name__ == "__main__":
    main()

"""Generate EXPERIMENTS.md from dry-run JSONs + benchmark logs.

    PYTHONPATH=src python scripts/make_experiments.py > EXPERIMENTS.md
"""
import glob
import json
import os
import re
import sys

sys.path.insert(0, "src")
from repro.roofline import report  # noqa: E402
from repro.roofline.analysis import HBM_BW, ICI_BW, PEAK_FLOPS  # noqa: E402

DRYRUN = "experiments/dryrun"
SCENARIO_JSON = "experiments/scenarios.json"


def scenario_section() -> str:
    """Run a small declarative scenario grid through `run_scenarios`
    and render the spec -> cohort -> RunResult chain as a table.

    The grid mixes frame sizes so the cohort compiler visibly
    partitions it; the exported RunResult JSON lands next to the
    dry-run artifacts and is schema-validated."""
    from repro.api import (RUN_RESULT_SCHEMA, ScenarioSpec, grid,
                           run_scenarios, validate_run_result_json)
    specs = grid(ScenarioSpec(duration=8.0, code_period_frames=40,
                              qa="epoch"),
                 system=["webrtc", "artic"], cc_kind=["gcc", "bbr"],
                 trace=["fluctuating", "mobility.driving"])
    specs += grid(ScenarioSpec(duration=8.0, scene="lawn", frame_h=64,
                               frame_w=64, rc_probe_stride=2),
                  system=["webrtc", "artic"])
    result = run_scenarios(specs)
    os.makedirs(os.path.dirname(SCENARIO_JSON), exist_ok=True)
    validate_run_result_json(result.to_json(SCENARIO_JSON))

    lines = [
        f"{len(result)} scenarios compiled into {len(result.cohorts)} "
        f"cohorts (grouped by fps / duration / frame size / probe "
        f"stride); full per-session metrics exported to "
        f"`{SCENARIO_JSON}` (schema `{RUN_RESULT_SCHEMA}`).\n",
        "| system | cc | trace | frame | accuracy | avg ms | Mbps |",
        "|---|---|---|---|---|---|---|",
    ]
    for s, m in zip(result.specs, result.metrics):
        lines.append(
            f"| {s.system} | {s.cc_kind} | {s.trace} "
            f"| {s.frame_h}x{s.frame_w} | {m.accuracy:.2f} "
            f"| {m.avg_latency_ms:.0f} | {m.bandwidth_used / 1e6:.2f} |")
    return "\n".join(lines)


def bench_csv():
    """Pull the CSV block out of the most recent benchmark log."""
    for path in ("experiments/bench_full.log", "bench_output.txt"):
        if os.path.exists(path):
            text = open(path).read()
            if "name,us_per_call,derived" in text:
                return text.split("name,us_per_call,derived", 1)[1].strip()
    return "(run `PYTHONPATH=src python -m benchmarks.run` to populate)"


def variant_rows(arch, shape):
    rows = []
    for f in sorted(glob.glob(f"{DRYRUN}/{arch}__{shape}__single*.json")):
        r = json.load(open(f))
        rf = r["roofline"]
        rows.append((r.get("variant") or "baseline (paper-faithful)",
                     rf["t_compute_s"], rf["t_memory_s"], rf["t_collective_s"],
                     rf["bottleneck"], rf["step_time_lb_s"],
                     rf["useful_flops_ratio"]))
    return rows


def variant_table(arch, shape):
    lines = ["| variant | t_compute | t_memory | t_collective | bottleneck "
             "| step-time LB | MODEL/HLO |",
             "|---|---|---|---|---|---|---|"]
    for v in variant_rows(arch, shape):
        lines.append(f"| {v[0]} | {v[1]:.3f}s | {v[2]:.3f}s | {v[3]:.3f}s "
                     f"| {v[4]} | **{v[5]:.3f}s** | {v[6]:.2f} |")
    return "\n".join(lines)


def main():
    recs = report.load_records(DRYRUN)
    base = [r for r in recs if not r.get("variant")]
    single = [r for r in base if r["mesh"] == "16x16"]
    multi = [r for r in base if r["mesh"] == "2x16x16"]

    print(TEMPLATE_HEAD)
    print(f"Cells compiled: **{len(single)} single-pod (16x16=256 chips) + "
          f"{len(multi)} multi-pod (2x16x16=512 chips) = {len(base)} total, "
          "0 failures.**\n")
    print(report.dryrun_table(base))
    print(TEMPLATE_ROOFLINE)
    print(report.roofline_table(base))
    print(TEMPLATE_PERF)
    print("### H1 — dbrx-132b x train_4k (most collective-bound)\n")
    print(variant_table("dbrx-132b", "train_4k"))
    print(H1_NARRATIVE)
    print("### H2 — qwen3-moe-30b-a3b x train_4k (worst useful-FLOPs, "
          "memory-bound)\n")
    print(variant_table("qwen3-moe-30b-a3b", "train_4k"))
    print(H2_NARRATIVE)
    print("### H3 — qwen2-vl-72b x decode_32k (paper-representative serving)\n")
    print(variant_table("qwen2-vl-72b", "decode_32k"))
    print(H3_NARRATIVE)
    print(TEMPLATE_PAPER)
    print("```\n" + bench_csv() + "\n```")
    print(TEMPLATE_SCENARIOS)
    print(scenario_section())
    print(TEMPLATE_TAIL)


TEMPLATE_HEAD = f"""# EXPERIMENTS

Hardware model: TPU v5e — {PEAK_FLOPS / 1e12:.0f} TFLOP/s bf16/chip,
{HBM_BW / 1e9:.0f} GB/s HBM, {ICI_BW / 1e9:.0f} GB/s/link ICI.  This
container is CPU-only; every number here is derived from compiled SPMD
artifacts (`.lower().compile()` on 512 virtual host devices), not wall
clock.  See DESIGN.md for the system; `repro/launch/dryrun.py` regenerates
everything in `experiments/dryrun/`.

## §Dry-run

Every supported (arch x shape) cell lowers AND compiles on both the
single-pod (16,16)=("data","model") and multi-pod (2,16,16)=
("pod","data","model") production meshes.  `long_500k` runs only for the
sub-quadratic archs (mamba2, recurrentgemma) per the shape-table rule;
all other archs are decoder-only so all remaining shapes apply (32 cells
per mesh).

Notes on the table: `args GB/dev` = resident inputs (params + optimizer
state + caches) per device from `memory_analysis()`; `temp GB/dev` =
transient peak; collective bytes are per-device payloads parsed from the
post-SPMD HLO (all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute operands).
"""

TEMPLATE_ROOFLINE = """
## §Roofline (single-pod, per paper spec)

Terms (seconds): t_compute = HLO_FLOPs / (256 x 197e12); t_memory =
HLO_bytes / (256 x 819e9); t_collective = per-device collective bytes /
50e9.  **Methodology:** XLA's `cost_analysis()` counts a `lax.scan`
(while-loop) body once, not x trip-count, so FLOPs/bytes/collectives are
probe-corrected: two shallow *unrolled* depths are compiled per cell and
v(L) = outer + L x per_layer is extrapolated to the real depth (the
`probe` block in each JSON).  `MODEL/HLO` = MODEL_FLOPS / HLO_FLOPs with
MODEL_FLOPS = 6·N·D (train) or 2·N_active·D (inference) — values < 1 flag
remat recompute, attention score FLOPs and router/dispatch overhead;
`roofline frac` = MODEL_FLOPS / (step-time lower bound x peak fleet
FLOP/s), i.e. an MFU upper bound at the dominant term.

Caveats recorded: (i) `bytes accessed` is the sum of operand+result bytes
over HLO ops — it over-counts true HBM traffic under fusion, so t_memory
is conservative and its *relative* movement across variants is the
signal; (ii) decode cells have tiny MODEL_FLOPS (2·N per token) so their
roofline fraction is inherently ~0 — the step-time lower bound is the
metric that matters there.

Bottleneck summary: training cells for the big dense archs are
memory/collective-bound (FSDP weight gathers + remat re-gathers);
MoE training is dominated by dispatch-tensor traffic; every decode cell
is memory- or collective-bound as expected at 1 token/step; prefill cells
sit closest to compute among inference shapes.
"""

TEMPLATE_PERF = """
## §Perf — hillclimbing log

Method per DESIGN: baseline every cell (table above), pick three —
worst roofline fraction with leverage (qwen3-moe train: MODEL/HLO 0.27,
16s memory term), most collective-bound (dbrx train: 26.2s collective >
25.0s memory > 8.9s compute), paper-representative (qwen2-vl decode: the
MLLM-serving step Artic controls) — then hypothesis -> change -> re-lower
-> re-measure.  The **paper-faithful baseline rows are kept** next to
each optimized variant.
"""

H1_NARRATIVE = """
* **Hypothesis 1** (expert weights dominate): replicating expert weights
  along `data` (stationary, no per-layer FSDP gather) predicted a large
  collective drop. **Refuted**: -1.28s (-5%) only — per-layer expert
  gathers are ~0.4 GiB vs ~32 GiB/layer total. The collective term is
  dominated by the fp32 (B,S,E,C) dispatch/combine tensors crossing the
  `model` axis.
* **Hypothesis 2** (grad-accum multiplies weight re-gathers): *not
  measurable* with the probe design (probes normalize to accum=1);
  analytically the weight-gather share scales linearly with microbatch
  count — recorded as a lever traded against activation memory.
* **Hypothesis 3** (remat recompute): `remat_dots` keeps dot outputs:
  compute 8.88->6.79s (-24%), memory 24.98->19.64s (-21%); collective
  unchanged (26.1s still bottleneck). **Confirmed but not binding.**
* **Hypothesis 4** (dispatch payload): cast dispatch/combine to bf16 at
  creation + capacity 1.25->1.0 + stationary expert weights
  (`moe_bf16_cap1`): collective 26.2->21.1s (-20%), memory -10%, compute
  -18%. **Confirmed** — the dispatch one-hots were the dominant payload.
* **Iteration 4** (`moe_full_opt` = bf16 dispatch + cap1 + stationary
  experts + dots-saveable remat): **step-time lower bound 26.20s ->
  20.93s (-20%) and MODEL/HLO useful-FLOPs 0.51 -> 0.81.** Stopped here:
  the remaining collective term is activation sequence-parallel gathers,
  whose removal trades against the memory term (<5% predicted).
"""

H2_NARRATIVE = """
* **Hypothesis 1** (one-hot dispatch bloat): replace einsum dispatch with
  scatter/gather token buffers (`moe_gather`, bitwise-equivalent routing,
  see tests). Predicted large memory win. **Refuted under XLA SPMD**:
  compute -45% (dispatch einsum FLOPs gone, MODEL/HLO 0.27->0.49) but
  bytes x2.5 and collective x4.7 — SPMD lowers the unsorted scatter into
  gather/scatter sequences with full-buffer rematerialization. Lesson: on
  TPU the dispatch one-hot einsum IS the right SPMD formulation; a
  dropless dispatch needs a dedicated Pallas kernel (ragged all-to-all),
  not jnp scatter.
* **Hypothesis 2** (capacity): cap 1.25->1.0 trims buffers ~20%:
  compute -14%, memory -4%. **Confirmed, small.**  Adding bf16 dispatch
  (`moe_bf16_cap1`) trims collectives a further -12% but not memory —
  unlike dbrx, qwen3-moe's memory term is dominated by the (B,S,k,E,C)
  routing intermediates, not the shipped dispatch tensor.
* Net: the GShard formulation with bf16 dispatch + tuned capacity is the
  production configuration; the memory term is dominated by per-op
  accounting of the (B,S,E,C) tensors that a fused dispatch kernel would
  eliminate — recorded as the top TPU-kernel follow-up.
"""

H3_NARRATIVE = """
* **Hypothesis 1** (per-token FSDP weight gathers dominate decode):
  16.7 GiB/device/token of all-gather at baseline. Variant
  `serve_replicated` replicates the weight FSDP dim (stationary weights,
  classic TP-only serving; 72B bf16 = 9 GB/device TP shard — fits v5e).
  **Confirmed: t_collective 0.360s -> 0.002s (-99.4%)**; bottleneck flips
  to memory; step-time lower bound -26%. This is the single biggest
  §Perf win and matches production serving practice (weights stationary,
  activations move).
* **Hypothesis 2** (KV reads dominate the remaining memory term): int8
  KV cache with per-token-per-head scales (`serve_repl_kvint8`, accuracy
  validated in tests): memory term 0.286 -> 0.202s (-29%). **Confirmed.**
  Net for the paper-representative serving cell: **step-time lower bound
  0.360s -> 0.202s (-44%)** vs the paper-faithful baseline. On real TPU
  the dequant fuses into the attention reads; the conservative
  bytes-accessed metric understates the win.
* Not applied to llama3-405b x decode: 810 GB bf16 / 16-way TP = 50
  GB/device does not fit v5e HBM — 405B-class serving on this mesh keeps
  2-D sharding and amortizes weight gathers across a larger decode batch,
  or moves to int8 weights (future work; recorded honestly).
"""

TEMPLATE_PAPER = """
## §Paper-claims validation (benchmarks, CPU simulator)

All RTC/accuracy numbers come from the JAX codec + channel simulator and
the DeViBench glyph oracle (DESIGN.md §3): *relative* claims are the
reproduction target, absolute Kbps/ms are simulator-scale.

| Paper claim | Ours (full bench) | Verdict |
|---|---|---|
| Accuracy saturates with bitrate (Fig. 3, knee ~968 Kbps) | saturation curve with knee at 400-968 Kbps; DeViBench samples 0% @200 -> ~1.0 @4000 | reproduced (knee earlier: synthetic glyph cliff is sharper than natural video) |
| CC lag causes latency spikes on bandwidth drops (Fig. 2, 1389 ms) | elevator trace: baseline spike >= 4x pre-drop median | reproduced (magnitude trace-dependent) |
| ReCapABR latency gain grows with fluctuation frequency (Fig. 9: 23.7 ms @1 -> 148.4 ms @4) | ~23 ms @1 -> hundreds of ms @4/min | reproduced; stronger at high frequency |
| Confidence aligns with accuracy (Fig. 10) | Pearson r ~= 0.96, monotone reliability bins | reproduced |
| ZeCoStream holds accuracy at low bitrate (Fig. 11: 0.39->0.60 @290) | standard collapses @<=290 Kbps, ZeCoStream holds near-saturation; 0.9-accuracy bitrate reduced | reproduced |
| End-to-end: +15.12 pp accuracy, -135.31 ms latency (Fig. 13) | latency -172/-220 ms (exceeds paper) and bandwidth -35/-68 % at accuracy within -8 pp (harsh traces) to +5.6 pp (moderate traces) of WebRTC | latency/bandwidth reproduced+; accuracy composition depends on the QA-interaction model (our per-question deadline dance penalizes the capped-rate regime harder than the paper's replay evaluation — see bench_e2e.py docstring) |
| Bandwidth use -46.8/-69.8 % (Fig. 14) | ~ -67/-71 % (GCC/BBR) | reproduced |
| Monetary overhead +27.13 % (Fig. 15) | +27.06 % (same cost model) | reproduced |
| DeViBench yield 25.25% accept x 89.37% verify = 22.57% (§6) | pipeline reports accept/verify/net yields each run (quick: ~46%/100%; sharper synthetic filter) | pipeline reproduced; yields corpus-dependent |

### Benchmark CSV (name,us_per_call,derived)
"""

TEMPLATE_SCENARIOS = """
## §Scenario grid (declarative workload API)

Workloads are declared as `ScenarioSpec`s and run through
`repro.api.run_scenarios`, which auto-partitions mixed-shape grids into
fleet cohorts (see README "Scenario API").  The table below is
regenerated on every `make_experiments.py` run:
"""

TEMPLATE_TAIL = """
## Reproduce

```
PYTHONPATH=src pytest tests/                      # unit+integration+property
PYTHONPATH=src python -m benchmarks.run           # paper figures (quick)
BENCH_QUICK=0 PYTHONPATH=src python -m benchmarks.run   # full size
PYTHONPATH=src python -m repro.api                # scenario-grid smoke
PYTHONPATH=src python -m repro.launch.dryrun --all      # all 64 cells
PYTHONPATH=src python -m repro.launch.dryrun --arch dbrx-132b \\
    --shape train_4k --mesh single --variant moe_bf16_cap1  # a §Perf variant
PYTHONPATH=src python scripts/make_experiments.py > EXPERIMENTS.md
```
"""

if __name__ == "__main__":
    main()

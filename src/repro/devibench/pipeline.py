"""DeViBench — Degraded Video Understanding Benchmark (paper §6).

Automated QA-sample construction with the paper's 5-step pipeline:

 1. video collection        -> seeded synthetic scenes, 6*2 categories
 2. video preprocessing     -> encode @200 Kbps and @4000 Kbps (codec sim)
 3. QA generation           -> generator proposes free-response questions
                               (read the glyph code / count objects / read
                               a corner attribute)
 4. QA filtering            -> accept iff correct@high AND wrong@low
                               bitrate (the degradation-sensitivity test);
                               a judge checks answers semantically (here:
                               exact code match -- free-response ints)
 5. cross verification      -> an independent verifier (different detector
                               operating point) must reproduce the answer
                               on the high-bitrate video

Outputs a Benchmark with test/validation splits; the validation split
drives Platt calibration of the confidence head and the tau/gamma/mu
hyperparameters (§6.2), mirroring the paper's use exactly.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.confidence import PlattCalibrator
from repro.video import codec
from repro.video.scenes import (GLYPH_BITS, Scene, all_categories,
                                decode_glyph, make_scene)

LOW_KBPS = 200.0
HIGH_KBPS = 4000.0


@dataclasses.dataclass
class QARecord:
    scene_id: int
    category: str
    moving: bool
    kind: str            # read_code | corner_attr | count_objects
    t_frame: int
    obj_idx: int
    answer: int
    # pipeline bookkeeping
    correct_high: bool = False
    correct_low: bool = False
    accepted: bool = False
    verified: bool = False
    # detector margin at high bitrate (confidence-calibration feature)
    margin_high: float = 0.0
    margin_low: float = 0.0
    temporal: str = "intra"  # intra | inter (needs multiple frames)


@dataclasses.dataclass
class Benchmark:
    scenes: List[Scene]
    validation: List[QARecord]
    test: List[QARecord]
    stats: Dict

    def scene(self, rec: QARecord) -> Scene:
        return self.scenes[rec.scene_id]


def _encode_at(frame: np.ndarray, kbps: float, fps: float = 10.0
               ) -> np.ndarray:
    target_bits = np.float32(kbps * 1e3 / fps)
    qp_shape = np.zeros((frame.shape[0] // 8, frame.shape[1] // 8), np.float32)
    _, enc = codec.rate_control(frame, qp_shape, target_bits)
    return np.asarray(codec.decode(enc))


def _answer(scene: Scene, rec: QARecord, frame: np.ndarray,
            margin_floor: float = 0.35) -> Tuple[int, float]:
    """Detector-as-MLLM answering on a (possibly degraded) frame."""
    obj = scene.objects[rec.obj_idx]
    y0, x0, y1, x1 = obj.bbox(rec.t_frame)
    y0 = int(np.clip(y0, 0, scene.h - obj.size))
    x0 = int(np.clip(x0, 0, scene.w - obj.size))
    patch = frame[y0:y0 + obj.size, x0:x0 + obj.size]
    # DeViBench clips are static-content (code epoch 0): truth == obj.code
    code, margin = decode_glyph(patch, obj.cell)
    if margin < margin_floor:
        return -1, margin  # "can't read" — refuses rather than hallucinates
    if rec.kind == "read_code":
        return code, margin
    if rec.kind == "corner_attr":
        return code & 1, margin
    raise ValueError(rec.kind)


def generate(n_scenes_per_cat: int = 2, questions_per_obj: int = 2,
             seed: int = 0, fps: float = 10.0, frame_hw=(256, 256),
             n_frames: int = 60) -> Benchmark:
    """Run the full 5-step pipeline; see module docstring."""
    t_start = time.time()
    rng = np.random.default_rng(seed)
    scenes: List[Scene] = []
    records: List[QARecord] = []

    # -- 1. collection + 3. generation ---------------------------------
    sid = 0
    for cat, moving in all_categories():
        for k in range(n_scenes_per_cat):
            sc = make_scene(cat, moving, seed=seed * 977 + sid,
                            h=frame_hw[0], w=frame_hw[1], n_frames=n_frames)
            scenes.append(sc)
            for oi in range(len(sc.objects)):
                for _ in range(questions_per_obj):
                    t_frame = int(rng.integers(0, n_frames))
                    kind = rng.choice(
                        ["read_code", "read_code", "read_code", "corner_attr"])
                    truth = (sc.objects[oi].code if kind == "read_code"
                             else sc.objects[oi].code & 1)
                    records.append(QARecord(
                        scene_id=sid, category=cat, moving=moving,
                        kind=str(kind), t_frame=t_frame, obj_idx=oi,
                        answer=truth,
                        temporal="inter" if moving and rng.random() < 0.15
                        else "intra"))
            sid += 1

    # -- 2. preprocessing + 4. filtering --------------------------------
    # cache encoded frames per (scene, t_frame, kbps)
    cache: Dict[Tuple[int, int, float], np.ndarray] = {}

    def degraded(sid_, t_, kbps):
        key = (sid_, t_, kbps)
        if key not in cache:
            cache[key] = _encode_at(scenes[sid_].render(t_), kbps, fps)
        return cache[key]

    for rec in records:
        hi = degraded(rec.scene_id, rec.t_frame, HIGH_KBPS)
        lo = degraded(rec.scene_id, rec.t_frame, LOW_KBPS)
        sc = scenes[rec.scene_id]
        ans_hi, m_hi = _answer(sc, rec, hi)
        ans_lo, m_lo = _answer(sc, rec, lo)
        rec.margin_high, rec.margin_low = m_hi, m_lo
        rec.correct_high = ans_hi == rec.answer
        rec.correct_low = ans_lo == rec.answer
        rec.accepted = rec.correct_high and not rec.correct_low

    accepted = [r for r in records if r.accepted]

    # -- 5. cross verification (independent operating point) ------------
    for rec in accepted:
        hi = degraded(rec.scene_id, rec.t_frame, HIGH_KBPS)
        ans_v, _ = _answer(scenes[rec.scene_id], rec, hi, margin_floor=0.25)
        rec.verified = ans_v == rec.answer
    verified = [r for r in accepted if r.verified]

    # -- splits + summary ------------------------------------------------
    rng.shuffle(verified)
    n_val = max(min(len(verified) // 5, 100), 1)
    validation, test = verified[:n_val], verified[n_val:]

    stats = {
        "n_generated": len(records),
        "n_accepted": len(accepted),
        "n_verified": len(verified),
        "accept_rate": len(accepted) / max(len(records), 1),
        "verify_rate": len(verified) / max(len(accepted), 1),
        "net_yield": len(verified) / max(len(records), 1),
        "n_validation": len(validation),
        "n_test": len(test),
        "categories": sorted({r.category for r in verified}),
        "by_kind": {k: sum(r.kind == k for r in verified)
                    for k in ("read_code", "corner_attr")},
        "by_temporal": {k: sum(r.temporal == k for r in verified)
                        for k in ("intra", "inter")},
        "total_duration_s": len(scenes) * n_frames / fps,
        "build_time_s": time.time() - t_start,
    }
    return Benchmark(scenes=scenes, validation=validation, test=test,
                     stats=stats)


# --------------------------------------------------------------------------
# Evaluation + calibration helpers
# --------------------------------------------------------------------------
def accuracy_at_bitrate(bench: Benchmark, kbps: float, fps: float = 10.0,
                        qp_shape_fn=None, split: str = "test") -> float:
    """Fraction of QA answered correctly at a given uniform (or shaped)
    encoding bitrate — the Fig. 3 / Fig. 11 measurement."""
    recs = bench.test if split == "test" else bench.validation
    ok = []
    for rec in recs:
        sc = bench.scene(rec)
        frame = sc.render(rec.t_frame)
        if qp_shape_fn is None:
            qp_shape = np.zeros((sc.h // 8, sc.w // 8), np.float32)
        else:
            qp_shape = qp_shape_fn(sc, rec)
        _, enc = codec.rate_control(frame, qp_shape,
                                    np.float32(kbps * 1e3 / fps))
        rx = np.asarray(codec.decode(enc))
        ans, _ = _answer(sc, rec, rx)
        ok.append(ans == rec.answer)
    return float(np.mean(ok)) if ok else 0.0


def fit_confidence_calibrator(bench: Benchmark) -> PlattCalibrator:
    """Platt scaling of detector margin -> P(correct) on the val split."""
    scores, correct = [], []
    for rec in bench.validation:
        scores += [rec.margin_high, rec.margin_low]
        correct += [rec.correct_high, rec.correct_low]
    # augment with mid-bitrate points for a smoother fit
    for rec in bench.validation[:20]:
        sc = bench.scene(rec)
        frame = sc.render(rec.t_frame)
        for kbps in (400.0, 900.0, 1700.0):
            _, enc = codec.rate_control(
                frame, np.zeros((sc.h // 8, sc.w // 8), np.float32),
                np.float32(kbps * 1e2))
            rx = np.asarray(codec.decode(enc))
            ans, m = _answer(sc, rec, rx)
            scores.append(m)
            correct.append(ans == rec.answer)
    return PlattCalibrator().fit(np.asarray(scores), np.asarray(correct))

"""DeViBench — Degraded Video Understanding Benchmark (paper §6).

Automated QA-sample construction with the paper's 5-step pipeline:

 1. video collection        -> seeded synthetic scenes, 6*2 categories
 2. video preprocessing     -> encode @200 Kbps and @4000 Kbps (codec sim)
 3. QA generation           -> generator proposes free-response questions
                               (read the glyph code / count objects / read
                               a corner attribute)
 4. QA filtering            -> accept iff correct@high AND wrong@low
                               bitrate (the degradation-sensitivity test);
                               a judge checks answers semantically (here:
                               exact code match -- free-response ints)
 5. cross verification      -> an independent verifier (different detector
                               operating point) must reproduce the answer
                               on the high-bitrate video

Outputs a Benchmark with test/validation splits; the validation split
drives Platt calibration of the confidence head and the tau/gamma/mu
hyperparameters (§6.2), mirroring the paper's use exactly.

Execution engines: steps 2/4/5 (the codec + answering work) run either
through the vectorized grid engine (`repro.devibench.engine`, the
default — all records encoded and answered in batched dispatches) or
through the original per-record serial loop (`engine="serial"`), which
is kept bit-identical as the pinned parity oracle
(tests/test_devibench_engine.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.confidence import PlattCalibrator
from repro.devibench.engine import (DegradationSpec, GridResult,
                                    bitrate_ladder, evaluate_records)
from repro.video import codec
from repro.video.scenes import (GLYPH_BITS, Scene, all_categories,
                                decode_glyph, make_scene)

LOW_KBPS = 200.0
HIGH_KBPS = 4000.0
# step-5 cross verification re-reads the high-bitrate decode at a more
# permissive detector operating point
VERIFY_MARGIN_FLOOR = 0.25


@dataclasses.dataclass
class QARecord:
    scene_id: int
    category: str
    moving: bool
    kind: str            # read_code | corner_attr | count_objects
    t_frame: int
    obj_idx: int
    answer: int
    # pipeline bookkeeping
    correct_high: bool = False
    correct_low: bool = False
    accepted: bool = False
    verified: bool = False
    # detector margin at high bitrate (confidence-calibration feature)
    margin_high: float = 0.0
    margin_low: float = 0.0
    temporal: str = "intra"  # intra | inter (needs multiple frames)


@dataclasses.dataclass
class Benchmark:
    scenes: List[Scene]
    validation: List[QARecord]
    test: List[QARecord]
    stats: Dict

    def scene(self, rec: QARecord) -> Scene:
        return self.scenes[rec.scene_id]

    def split(self, name: str) -> List[QARecord]:
        if name == "test":
            return self.test
        if name == "validation":
            return self.validation
        if name == "all":
            return self.test + self.validation
        raise ValueError(f"unknown split {name!r}; "
                         "one of ('test', 'validation', 'all')")


def _encode_at(frame: np.ndarray, kbps: float, fps: float = 10.0
               ) -> np.ndarray:
    target_bits = np.float32(kbps * 1e3 / fps)
    qp_shape = np.zeros((frame.shape[0] // 8, frame.shape[1] // 8), np.float32)
    _, enc = codec.rate_control(frame, qp_shape, target_bits)
    return np.asarray(codec.decode(enc))


def _answer(scene: Scene, rec: QARecord, frame: np.ndarray,
            margin_floor: float = 0.35) -> Tuple[int, float]:
    """Detector-as-MLLM answering on a (possibly degraded) frame."""
    obj = scene.objects[rec.obj_idx]
    y0, x0, y1, x1 = obj.bbox(rec.t_frame)
    y0 = int(np.clip(y0, 0, scene.h - obj.size))
    x0 = int(np.clip(x0, 0, scene.w - obj.size))
    patch = frame[y0:y0 + obj.size, x0:x0 + obj.size]
    # DeViBench clips are static-content (code epoch 0): truth == obj.code
    code, margin = decode_glyph(patch, obj.cell)
    if margin < margin_floor:
        return -1, margin  # "can't read" — refuses rather than hallucinates
    if rec.kind == "read_code":
        return code, margin
    if rec.kind == "corner_attr":
        return code & 1, margin
    raise ValueError(rec.kind)


# --------------------------------------------------------------------------
# Step 1 + 3: scene collection and QA generation (shared by both engines
# so the rng stream — and therefore the Benchmark — is engine-invariant)
# --------------------------------------------------------------------------
def _propose(rng: np.random.Generator, n_scenes_per_cat: int,
             questions_per_obj: int, seed: int, frame_hw, n_frames: int
             ) -> Tuple[List[Scene], List[QARecord]]:
    scenes: List[Scene] = []
    records: List[QARecord] = []
    sid = 0
    for cat, moving in all_categories():
        for k in range(n_scenes_per_cat):
            sc = make_scene(cat, moving, seed=seed * 977 + sid,
                            h=frame_hw[0], w=frame_hw[1], n_frames=n_frames)
            scenes.append(sc)
            for oi in range(len(sc.objects)):
                for _ in range(questions_per_obj):
                    t_frame = int(rng.integers(0, n_frames))
                    kind = rng.choice(
                        ["read_code", "read_code", "read_code", "corner_attr"])
                    truth = (sc.objects[oi].code if kind == "read_code"
                             else sc.objects[oi].code & 1)
                    records.append(QARecord(
                        scene_id=sid, category=cat, moving=moving,
                        kind=str(kind), t_frame=t_frame, obj_idx=oi,
                        answer=truth,
                        temporal="inter" if moving and rng.random() < 0.15
                        else "intra"))
            sid += 1
    return scenes, records


# --------------------------------------------------------------------------
# Steps 2 + 4 + 5: degrade, filter, cross-verify — two engines
# --------------------------------------------------------------------------
def _degraded_frame(scenes: Sequence[Scene],
                    cache: Dict[Tuple[int, int, float], np.ndarray],
                    sid: int, t: int, kbps: float, fps: float
                    ) -> np.ndarray:
    """Cached encode of scene `sid`'s frame `t` at `kbps`.  Module-level
    with every key as an explicit argument — no closure over loop
    variables, so two records of the same scene can never alias each
    other's degradations (regression-tested)."""
    key = (sid, t, kbps)
    if key not in cache:
        cache[key] = _encode_at(scenes[sid].render(t), kbps, fps)
    return cache[key]


def _screen_serial(scenes: List[Scene], records: List[QARecord],
                   fps: float) -> None:
    """The original per-record loop: one device dispatch per (record,
    bitrate).  Pinned as the parity oracle for the vectorized engine."""
    cache: Dict[Tuple[int, int, float], np.ndarray] = {}
    for rec in records:
        hi = _degraded_frame(scenes, cache, rec.scene_id, rec.t_frame,
                             HIGH_KBPS, fps)
        lo = _degraded_frame(scenes, cache, rec.scene_id, rec.t_frame,
                             LOW_KBPS, fps)
        sc = scenes[rec.scene_id]
        ans_hi, m_hi = _answer(sc, rec, hi)
        ans_lo, m_lo = _answer(sc, rec, lo)
        rec.margin_high, rec.margin_low = m_hi, m_lo
        rec.correct_high = ans_hi == rec.answer
        rec.correct_low = ans_lo == rec.answer
        rec.accepted = rec.correct_high and not rec.correct_low

    for rec in records:
        if not rec.accepted:
            continue
        hi = _degraded_frame(scenes, cache, rec.scene_id, rec.t_frame,
                             HIGH_KBPS, fps)
        ans_v, _ = _answer(scenes[rec.scene_id], rec, hi,
                           margin_floor=VERIFY_MARGIN_FLOOR)
        rec.verified = ans_v == rec.answer


def _screen_vectorized(scenes: List[Scene], records: List[QARecord],
                       fps: float) -> None:
    """Steps 2+4+5 as one stacked (record x {high, low}) grid: two
    batched codec dispatches and one batched answering pass for the
    whole benchmark.  Step 5's independent operating point is a pure
    re-threshold of the high-bitrate column (the decode is
    deterministic, exactly what the serial loop's cache recomputes)."""
    res = evaluate_records(scenes, records,
                           bitrate_ladder([HIGH_KBPS, LOW_KBPS]), fps=fps)
    ans_v = res.reanswer(0, margin_floor=VERIFY_MARGIN_FLOOR)
    ok_hi, ok_lo = res.correct[:, 0], res.correct[:, 1]
    for i, rec in enumerate(records):
        rec.margin_high = float(res.margins[i, 0])
        rec.margin_low = float(res.margins[i, 1])
        rec.correct_high = bool(ok_hi[i])
        rec.correct_low = bool(ok_lo[i])
        rec.accepted = rec.correct_high and not rec.correct_low
        rec.verified = rec.accepted and bool(ans_v[i] == rec.answer)


def generate(n_scenes_per_cat: int = 2, questions_per_obj: int = 2,
             seed: int = 0, fps: float = 10.0, frame_hw=(256, 256),
             n_frames: int = 60, engine: str = "vectorized") -> Benchmark:
    """Run the full 5-step pipeline; see module docstring.

    `engine="vectorized"` (default) batches all codec + answering work;
    `engine="serial"` runs the original per-record loop.  Both produce
    bit-identical Benchmarks (the rng stream is consumed only by the
    shared propose/shuffle steps)."""
    t_start = time.time()
    rng = np.random.default_rng(seed)
    scenes, records = _propose(rng, n_scenes_per_cat, questions_per_obj,
                               seed, frame_hw, n_frames)

    if engine == "serial":
        _screen_serial(scenes, records, fps)
    elif engine == "vectorized":
        _screen_vectorized(scenes, records, fps)
    else:
        raise ValueError(f"unknown engine {engine!r}; "
                         "one of ('vectorized', 'serial')")

    accepted = [r for r in records if r.accepted]
    verified = [r for r in accepted if r.verified]

    # -- splits + summary ------------------------------------------------
    rng.shuffle(verified)
    n_val = max(min(len(verified) // 5, 100), 1)
    validation, test = verified[:n_val], verified[n_val:]

    stats = {
        "n_generated": len(records),
        "n_accepted": len(accepted),
        "n_verified": len(verified),
        "accept_rate": len(accepted) / max(len(records), 1),
        "verify_rate": len(verified) / max(len(accepted), 1),
        "net_yield": len(verified) / max(len(records), 1),
        "n_validation": len(validation),
        "n_test": len(test),
        "categories": sorted({r.category for r in verified}),
        "by_kind": {k: sum(r.kind == k for r in verified)
                    for k in ("read_code", "corner_attr")},
        "by_temporal": {k: sum(r.temporal == k for r in verified)
                        for k in ("intra", "inter")},
        "total_duration_s": len(scenes) * n_frames / fps,
        "build_time_s": time.time() - t_start,
        "engine": engine,
    }
    return Benchmark(scenes=scenes, validation=validation, test=test,
                     stats=stats)


# --------------------------------------------------------------------------
# Evaluation + calibration helpers
# --------------------------------------------------------------------------
def accuracy_at_bitrate(bench: Benchmark, kbps: float, fps: float = 10.0,
                        qp_shape_fn=None, split: str = "test") -> float:
    """Fraction of QA answered correctly at a given uniform (or shaped)
    encoding bitrate — the Fig. 3 / Fig. 11 measurement.

    Per-record serial loop; pinned as the parity oracle for
    `accuracy_grid` (which batches the whole ladder)."""
    recs = bench.test if split == "test" else bench.validation
    ok = []
    for rec in recs:
        sc = bench.scene(rec)
        frame = sc.render(rec.t_frame)
        if qp_shape_fn is None:
            qp_shape = np.zeros((sc.h // 8, sc.w // 8), np.float32)
        else:
            qp_shape = qp_shape_fn(sc, rec)
        _, enc = codec.rate_control(frame, qp_shape,
                                    np.float32(kbps * 1e3 / fps))
        rx = np.asarray(codec.decode(enc))
        ans, _ = _answer(sc, rec, rx)
        ok.append(ans == rec.answer)
    return float(np.mean(ok)) if ok else 0.0


def evaluate(bench: Benchmark, degradations: Sequence[DegradationSpec],
             split: str = "test", fps: float = 10.0,
             margin_floor: float = 0.35, backend: str = "jnp"
             ) -> GridResult:
    """Vectorized (record x degradation) grid over a benchmark split."""
    return evaluate_records(bench.scenes, bench.split(split), degradations,
                            fps=fps, margin_floor=margin_floor,
                            backend=backend)


def accuracy_grid(bench: Benchmark, kbps_ladder: Sequence[float],
                  split: str = "test", fps: float = 10.0,
                  engine: str = "vectorized", backend: str = "jnp"
                  ) -> np.ndarray:
    """Accuracy across a bitrate ladder as one stacked grid (the whole
    Fig. 3 curve in a handful of batched dispatches).  Bit-identical to
    mapping `accuracy_at_bitrate` over the ladder."""
    if engine == "serial":
        return np.asarray([accuracy_at_bitrate(bench, float(k), fps,
                                               split=split)
                           for k in kbps_ladder])
    return evaluate(bench, bitrate_ladder(kbps_ladder), split=split,
                    fps=fps, backend=backend).accuracy()


def fit_confidence_calibrator(bench, engine: str = "vectorized"
                              ) -> PlattCalibrator:
    """Platt scaling of detector margin -> P(correct).

    Accepts a `Benchmark` (fit on the validation split + a mid-bitrate
    augmentation grid) or any object with `stacked_margins()` returning
    (scores, correct) stacked arrays — e.g. the scenario layer's
    DeViBench RunResult — in which case the fit consumes the arrays
    directly with no per-record work at all."""
    if hasattr(bench, "stacked_margins"):
        scores, correct = bench.stacked_margins()
        return PlattCalibrator().fit(np.asarray(scores),
                                     np.asarray(correct))
    if isinstance(bench, GridResult):
        return PlattCalibrator().fit(bench.margins.ravel(),
                                     bench.correct.ravel())
    if engine == "serial":
        return _fit_calibrator_serial(bench)

    val = bench.validation
    # the high/low margins were already measured during generate()
    scores = np.asarray([[r.margin_high, r.margin_low] for r in val],
                        np.float64).ravel()
    correct = np.asarray([[r.correct_high, r.correct_low] for r in val],
                         bool).ravel()
    # augment with mid-bitrate points for a smoother fit — one stacked
    # (record x 3-bitrate) grid instead of a per-record loop.  fps is
    # pinned to 10 to match the serial oracle's kbps*1e2 target.
    res = evaluate_records(bench.scenes, val[:20],
                           bitrate_ladder([400.0, 900.0, 1700.0]),
                           fps=10.0)
    scores = np.concatenate([scores, res.margins.ravel()])
    correct = np.concatenate([correct, res.correct.ravel()])
    return PlattCalibrator().fit(scores, correct)


def _fit_calibrator_serial(bench: Benchmark) -> PlattCalibrator:
    """The original per-record loop; parity oracle for the vectorized
    `fit_confidence_calibrator`."""
    scores, correct = [], []
    for rec in bench.validation:
        scores += [rec.margin_high, rec.margin_low]
        correct += [rec.correct_high, rec.correct_low]
    for rec in bench.validation[:20]:
        sc = bench.scene(rec)
        frame = sc.render(rec.t_frame)
        for kbps in (400.0, 900.0, 1700.0):
            _, enc = codec.rate_control(
                frame, np.zeros((sc.h // 8, sc.w // 8), np.float32),
                np.float32(kbps * 1e2))
            rx = np.asarray(codec.decode(enc))
            ans, m = _answer(sc, rec, rx)
            scores.append(m)
            correct.append(ans == rec.answer)
    return PlattCalibrator().fit(np.asarray(scores), np.asarray(correct))

"""Vectorized DeViBench evaluation engine (paper §6, Fig. 3/11).

The legacy pipeline evaluates one QA record at a time: render -> jitted
single-frame `codec.rate_control` -> `codec.decode` -> per-patch NumPy
glyph decode; every record x bitrate point is its own device dispatch.
This module rebuilds that as one stacked (scene x record x degradation)
grid:

    DegradationSpec     one degradation cell as pure data.  Four kinds,
                        each mapped onto an existing batched codec
                        primitive:
                          bitrate    uniform-QP rate control at a target
                                     bitrate cap (`rate_control_batch`)
                          requant    encode at `kbps`, then lose a
                                     `loss` fraction of the bits in
                                     flight and re-quantize the cached
                                     coefficients toward the delivered
                                     budget (`decode_delivered_batch` —
                                     the fleet's partial-drop path)
                          drop       streaming stall: the freshest
                                     delivered frame is `stall_frames`
                                     old, encoded at `kbps`; the
                                     question still targets the object's
                                     *current* position
                          downscale  block-mean downscale by `scale`,
                                     encode at `kbps`, nearest upscale
                                     back (resolution degradation)
                        plus "none" (pristine render, no codec).
    evaluate_records()  dedupes the (scene, frame-time) set per
                        degradation, encodes every unique frame of the
                        whole grid in one batched dispatch per frame
                        geometry, gathers all QA patches with one
                        fancy-index per glyph cell size, and thresholds
                        answers as (R, D) array ops.
    GridResult          stacked outputs — codes / margins / answers /
                        correct as (R, D) arrays + accuracy helpers —
                        exactly the arrays `fit_confidence_calibrator`
                        and the ReCap-ABR tau/gamma fit consume.

Parity: the batched dispatches are vmaps of the exact single-frame
jitted functions and `decode_glyph_batch` mirrors the scalar glyph
reader's arithmetic, so a bitrate-kind grid is bit-identical to the
serial `accuracy_at_bitrate` loop (tests/test_devibench_engine.py).
Batch sizes are padded to powers of two so repeated grids of nearby
sizes share compiled executables; vmapped rows are independent, so
padding never perturbs real rows.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Sequence, Tuple

import numpy as np

from repro.video import codec
from repro.video.scenes import (GLYPH_GRID, Scene, decode_glyph_batch)

DEGRADATION_KINDS = ("none", "bitrate", "requant", "drop", "downscale")

#: default reference bitrate for the non-bitrate degradation kinds —
#: the DeViBench high-quality operating point (pipeline.HIGH_KBPS).
REFERENCE_KBPS = 4000.0


@dataclasses.dataclass(frozen=True)
class DegradationSpec:
    """One degradation cell of the evaluation grid, as pure data.

    Frozen/hashable/JSON-round-trippable so it can ride on
    `ScenarioSpec` (the scenario layer's degradation dimension) and in
    the DeViBench RunResult export."""
    kind: str = "bitrate"
    kbps: float = REFERENCE_KBPS  # encode target (all codec kinds)
    loss: float = 0.0             # requant: fraction of bits dropped
    stall_frames: int = 0         # drop: age of the freshest frame
    scale: int = 1                # downscale: integer factor

    def __post_init__(self):
        if self.kind not in DEGRADATION_KINDS:
            raise ValueError(f"unknown degradation kind {self.kind!r}; "
                             f"one of {DEGRADATION_KINDS}")
        if not 0.0 <= self.loss < 1.0:
            raise ValueError(f"loss must be in [0, 1): {self.loss}")
        if self.stall_frames < 0:
            raise ValueError(f"stall_frames must be >= 0: {self.stall_frames}")
        if self.scale < 1 or int(self.scale) != self.scale:
            raise ValueError(f"scale must be a positive int: {self.scale}")
        if self.kbps <= 0:
            raise ValueError(f"kbps must be positive: {self.kbps}")

    @property
    def label(self) -> str:
        if self.kind == "none":
            return "pristine"
        if self.kind == "bitrate":
            return f"bitrate@{self.kbps:g}"
        if self.kind == "requant":
            return f"requant@{self.kbps:g}-{100 * self.loss:g}%"
        if self.kind == "drop":
            return f"drop@{self.kbps:g}+{self.stall_frames}f"
        return f"down{self.scale}x@{self.kbps:g}"

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "DegradationSpec":
        return cls(**d)


def bitrate_ladder(kbps_list: Sequence[float]) -> List[DegradationSpec]:
    """The Fig. 3 / Fig. 11 sweep: one bitrate-cap cell per ladder rung."""
    return [DegradationSpec(kind="bitrate", kbps=float(k)) for k in kbps_list]


def default_degradations(kbps: float = REFERENCE_KBPS
                         ) -> List[DegradationSpec]:
    """A 6-cell grid covering every degradation axis once: pristine,
    saturated + starved bitrate caps, mid-flight partial loss, a
    streaming stall, and a resolution downscale."""
    return [
        DegradationSpec(kind="none"),
        DegradationSpec(kind="bitrate", kbps=kbps),
        DegradationSpec(kind="bitrate", kbps=200.0),
        DegradationSpec(kind="requant", kbps=kbps, loss=0.5),
        DegradationSpec(kind="drop", kbps=kbps, stall_frames=5),
        DegradationSpec(kind="downscale", kbps=kbps, scale=2),
    ]


# --------------------------------------------------------------------------
# Grid result: the stacked arrays downstream fitting consumes
# --------------------------------------------------------------------------
@dataclasses.dataclass
class GridResult:
    """Stacked (record x degradation) evaluation output.

    `margins` are the detector's raw confidence signal (the calibrator's
    input feature); `answers` hold -1 where the detector refused
    (margin below the floor)."""
    degradations: List[DegradationSpec]
    truth: np.ndarray        # (R,) int64 ground-truth answers
    is_corner: np.ndarray    # (R,) bool — corner_attr vs read_code
    codes: np.ndarray        # (R, D) int64 raw glyph codes
    margins: np.ndarray      # (R, D) float64 detector margins
    answers: np.ndarray      # (R, D) int64, -1 = refused
    correct: np.ndarray      # (R, D) bool
    scene_id: np.ndarray     # (R,) int64
    t_frame: np.ndarray      # (R,) int64
    cell: np.ndarray         # (R,) int64 glyph cell sizes
    margin_floor: float = 0.35

    @property
    def n_records(self) -> int:
        return len(self.truth)

    def accuracy(self) -> np.ndarray:
        """(D,) fraction correct per degradation cell."""
        return self.correct.mean(axis=0)

    def refuse_rate(self) -> np.ndarray:
        """(D,) fraction of refused ('can't read') answers per cell."""
        return (self.answers == -1).mean(axis=0)

    def reanswer(self, d_idx: int, margin_floor: float) -> np.ndarray:
        """Re-threshold one degradation column at a different margin
        floor — the step-5 'independent operating point' verifier, as a
        pure array op (the decode is deterministic, so re-answering the
        same frame only moves the refusal threshold)."""
        base = np.where(self.is_corner, self.codes[:, d_idx] & 1,
                        self.codes[:, d_idx])
        return np.where(self.margins[:, d_idx] < margin_floor, -1, base)

    def saturation_curve(self) -> Tuple[np.ndarray, np.ndarray]:
        """(kbps, accuracy) over the bitrate-kind cells, sorted by kbps
        — the Fig. 3 curve ReCap-ABR's saturation point is read from."""
        idx = [i for i, d in enumerate(self.degradations)
               if d.kind == "bitrate"]
        if not idx:
            raise ValueError("no bitrate-kind degradations in this grid")
        kbps = np.asarray([self.degradations[i].kbps for i in idx])
        acc = self.accuracy()[idx]
        order = np.argsort(kbps, kind="stable")
        return kbps[order], acc[order]


# --------------------------------------------------------------------------
# The engine
# --------------------------------------------------------------------------
def _pad_rows(n: int) -> int:
    """Round a batch size up to the next multiple of 16 so repeated
    grids of nearby sizes share compiled executables (vmapped rows are
    independent — padding never perturbs real rows)."""
    return max(16, -(-n // 16) * 16)


def _answer_kind_arrays(scenes: Sequence[Scene], records
                        ) -> Tuple[np.ndarray, ...]:
    """Per-record metadata arrays (pure bookkeeping, no device work)."""
    sid = np.asarray([r.scene_id for r in records], np.int64)
    t = np.asarray([r.t_frame for r in records], np.int64)
    truth = np.asarray([r.answer for r in records], np.int64)
    for r in records:
        if r.kind not in ("read_code", "corner_attr"):
            raise ValueError(f"unsupported QA kind {r.kind!r}")
    is_corner = np.asarray([r.kind == "corner_attr" for r in records], bool)
    cell = np.empty(len(records), np.int64)
    y0 = np.empty(len(records), np.int64)
    x0 = np.empty(len(records), np.int64)
    for i, r in enumerate(records):
        sc = scenes[r.scene_id]
        obj = sc.objects[r.obj_idx]
        by0, bx0, _, _ = obj.bbox(r.t_frame)
        cell[i] = obj.cell
        y0[i] = int(np.clip(by0, 0, sc.h - obj.size))
        x0[i] = int(np.clip(bx0, 0, sc.w - obj.size))
    return sid, t, truth, is_corner, cell, y0, x0


def evaluate_records(scenes: Sequence[Scene], records,
                     degradations: Sequence[DegradationSpec], *,
                     fps: float = 10.0, margin_floor: float = 0.35,
                     backend: str = "jnp") -> GridResult:
    """Evaluate every (record, degradation) pair of the stacked grid.

    All codec work runs through the fleet's batched primitives — one
    `rate_control_batch` dispatch per frame geometry (plus one
    receive-side dispatch), not one per record.  `backend="kernel"`
    reconstructs through the fused Pallas qp_codec kernel instead of the
    jnp decode (interpret mode off-TPU); it supports every kind except
    `requant`, whose coefficient cache lives on the jnp path.
    """
    if backend not in ("jnp", "kernel"):
        raise ValueError(f"unknown backend {backend!r}")
    records = list(records)
    degradations = list(degradations)
    R, D = len(records), len(degradations)
    if R == 0 or D == 0:
        raise ValueError("evaluate_records needs >=1 record and degradation")
    H, W = scenes[0].h, scenes[0].w
    if any(sc.h != H or sc.w != W for sc in scenes):
        raise ValueError("all scenes in one grid must share frame size")
    sid, t, truth, is_corner, cell, y0, x0 = _answer_kind_arrays(
        scenes, records)

    # -- encode plan: unique (scene, frame-time) rows per degradation --
    frame_row = np.empty((R, D), np.int64)
    row_sid: List[int] = []
    row_t: List[int] = []
    row_kbps: List[float] = []
    row_loss: List[float] = []
    pristine_rows: List[int] = []
    buckets: Dict[int, List[int]] = {}   # scale -> global row indices
    for j, d in enumerate(degradations):
        te = np.maximum(t - d.stall_frames, 0) if d.kind == "drop" else t
        uniq, inv = np.unique(np.stack([sid, te], axis=1), axis=0,
                              return_inverse=True)
        offset = len(row_sid)
        frame_row[:, j] = offset + inv
        rows = range(offset, offset + len(uniq))
        row_sid.extend(int(s) for s in uniq[:, 0])
        row_t.extend(int(tt) for tt in uniq[:, 1])
        row_kbps.extend([d.kbps] * len(uniq))
        row_loss.extend([d.loss if d.kind == "requant" else 0.0] * len(uniq))
        if d.kind == "none":
            pristine_rows.extend(rows)
        else:
            scale = d.scale if d.kind == "downscale" else 1
            if scale > 1 and ((H // scale) % codec.BLOCK
                              or (W // scale) % codec.BLOCK
                              or H % scale or W % scale):
                raise ValueError(
                    f"downscale {scale}x of {H}x{W} breaks 8px blocking")
            buckets.setdefault(scale, []).extend(rows)

    render_memo: Dict[Tuple[int, int], np.ndarray] = {}

    def rendered(row: int) -> np.ndarray:
        key = (row_sid[row], row_t[row])
        if key not in render_memo:
            render_memo[key] = scenes[key[0]].render(key[1])
        return render_memo[key]

    decoded = np.empty((len(row_sid), H, W), np.float32)
    for row in pristine_rows:
        decoded[row] = rendered(row)

    # -- batched encode + receive, one dispatch per geometry -----------
    # Unique frames are deduped ACROSS degradations within a geometry
    # bucket, so a frame evaluated under six degradation cells is
    # rendered + DCT'd once and only re-quantized per cell.
    for scale, rows in sorted(buckets.items()):
        slot: Dict[Tuple[int, int], int] = {}
        frame_idx = np.empty(len(rows), np.int64)
        uniq_frames: List[np.ndarray] = []
        for i, r in enumerate(rows):
            key = (row_sid[r], row_t[r])
            if key not in slot:
                slot[key] = len(uniq_frames)
                uniq_frames.append(rendered(r))
            frame_idx[i] = slot[key]
        frames = np.stack(uniq_frames).astype(np.float32)
        if scale > 1:
            frames = frames.reshape(-1, H // scale, scale, W // scale,
                                    scale).mean(axis=(2, 4),
                                                dtype=np.float32)
        F = len(frames)
        FP = max(8, -(-F // 8) * 8)  # pad the static frame dim too
        if FP > F:
            frames = np.concatenate(
                [frames, np.repeat(frames[-1:], FP - F, axis=0)])
        targets = np.asarray([np.float32(row_kbps[r] * 1e3 / fps)
                              for r in rows], np.float32)
        loss = np.asarray([row_loss[r] for r in rows], np.float32)
        nby = frames.shape[1] // codec.BLOCK
        nbx = frames.shape[2] // codec.BLOCK
        dec = np.empty((len(rows),) + frames.shape[1:], np.float32)

        def run_rows(sel: np.ndarray, requant: bool) -> None:
            M = int(sel.sum())
            if M == 0:
                return
            P = _pad_rows(M)
            idx = np.concatenate([frame_idx[sel],
                                  np.zeros(P - M, np.int64)])
            tb = np.concatenate([targets[sel],
                                 np.full(P - M, targets[sel][-1],
                                         np.float32)])
            qp0 = np.zeros((P, nby, nbx), np.float32)
            if backend == "kernel" and not requant:
                from repro.kernels.qp_codec.ops import \
                    rate_controlled_codec_frames
                out, _ = rate_controlled_codec_frames(
                    frames[idx], qp0, tb)
            elif requant:
                ls = np.concatenate([loss[sel],
                                     np.zeros(P - M, np.float32)])
                _, enc = codec.rate_control_batch(frames[idx], qp0, tb)
                delivered = (np.asarray(enc.bits)
                             * (1.0 - ls)).astype(np.float32)
                out = codec.decode_delivered_batch(enc, qp0, delivered,
                                                   ls > 0)
            else:
                out, _ = codec.grid_rate_control_decode(frames, idx,
                                                        qp0, tb)
            dec[sel] = np.asarray(out)[:M]

        needs = loss > 0
        if backend == "kernel" and needs.any():
            raise ValueError("backend='kernel' does not support requant "
                             "degradations (the coefficient cache lives "
                             "on the jnp path)")
        run_rows(~needs, requant=False)
        run_rows(needs, requant=True)
        out_rows = dec
        if scale > 1:
            out_rows = np.repeat(np.repeat(dec, scale, axis=1),
                                 scale, axis=2)
        decoded[rows] = out_rows

    # -- batched answering: one gather + glyph decode per cell size ----
    codes = np.zeros((R, D), np.int64)
    margins = np.zeros((R, D), np.float64)
    for c in np.unique(cell):
        m = cell == c
        S = GLYPH_GRID * int(c)
        rows = frame_row[m]                                 # (Rc, D)
        yy = y0[m][:, None, None, None] + np.arange(S)[None, None, :, None]
        xx = x0[m][:, None, None, None] + np.arange(S)[None, None, None, :]
        patches = decoded[rows[:, :, None, None], yy, xx]   # (Rc, D, S, S)
        code_c, margin_c = decode_glyph_batch(
            patches.reshape(-1, S, S), int(c))
        codes[m] = code_c.reshape(-1, D)
        margins[m] = margin_c.reshape(-1, D)

    base = np.where(is_corner[:, None], codes & 1, codes)
    answers = np.where(margins < margin_floor, -1, base)
    correct = answers == truth[:, None]
    return GridResult(degradations=degradations, truth=truth,
                      is_corner=is_corner, codes=codes, margins=margins,
                      answers=answers, correct=correct, scene_id=sid,
                      t_frame=t, cell=cell, margin_floor=margin_floor)

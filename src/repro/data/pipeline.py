"""Deterministic sharded data pipeline with exact-resume cursors.

The training corpus here is synthetic (seeded token streams / Artic video
QA episodes) but the pipeline has the production shape: per-host sharding
by process index, stateless random access by (epoch, step) so a restart
at step N reproduces byte-identical batches, and a background prefetch
thread that keeps `prefetch` batches ready while the accelerator runs.
Straggler note: because batches are stateless-indexed, the launcher can
re-assign a slow host's shard range without coordination (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    batch: int           # per-host batch
    seq: int
    seed: int = 0
    num_codebooks: int = 1
    kind: str = "lm"     # lm | vlm | audio


class TokenPipeline:
    """Stateless-indexed synthetic LM stream: batch(step) is a pure function
    of (seed, process_index, step)."""

    def __init__(self, cfg: DataConfig,
                 process_index: Optional[int] = None,
                 process_count: Optional[int] = None):
        self.cfg = cfg
        self.pidx = jax.process_index() if process_index is None else process_index
        self.pcnt = jax.process_count() if process_count is None else process_count

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        root = np.random.SeedSequence(
            [c.seed, self.pidx, step])
        rng = np.random.default_rng(root)
        if c.kind == "audio" or c.num_codebooks > 1:
            toks = rng.integers(0, c.vocab,
                                (c.batch, c.num_codebooks, c.seq + 1),
                                dtype=np.int32)
            return {"tokens": toks[:, :, :-1], "labels": toks[:, :, 1:]}
        if c.kind == "vlm":
            # frontend stub: embeddings + aligned labels
            emb = rng.standard_normal((c.batch, c.seq, c.vocab // 16),
                                      dtype=np.float32) * 0.02
            lab = rng.integers(0, c.vocab, (c.batch, c.seq), dtype=np.int32)
            pos = np.broadcast_to(np.arange(c.seq, dtype=np.int32),
                                  (3, c.batch, c.seq)).copy()
            return {"embeds": emb, "labels": lab, "mrope_positions": pos}
        toks = rng.integers(0, c.vocab, (c.batch, c.seq + 1), dtype=np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def iterate(self, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1

    def cursor(self, step: int) -> Dict[str, int]:
        """Serializable resume cursor (stored in checkpoint manifest)."""
        return {"data_step": int(step), "seed": self.cfg.seed,
                "process_index": self.pidx, "process_count": self.pcnt}


class Prefetcher:
    """Background-thread prefetch of `depth` ready batches."""

    def __init__(self, it: Iterator, depth: int = 2,
                 put_fn: Optional[Callable[[Any], Any]] = None):
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self.put_fn = put_fn or (lambda x: x)

        def work():
            for item in it:
                if self._stop.is_set():
                    return
                self.q.put(self.put_fn(item))

        self.t = threading.Thread(target=work, daemon=True)
        self.t.start()

    def __iter__(self):
        return self

    def __next__(self):
        return self.q.get()

    def stop(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass

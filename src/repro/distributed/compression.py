"""int8 error-feedback gradient compression for the cross-pod reduction.

At 1000+ node scale the `pod` axis rides the DCN, whose bandwidth is
~10-25x below ICI; compressing the data-parallel gradient contribution 4x
(fp32->int8 with per-tensor scale) before the reduction and carrying the
quantization residual forward (error feedback, 1-bit-Adam style) keeps
convergence intact — see tests/test_compression.py for the convergence
property test.

Usage: wrap the grads inside the train step *before* the optimizer.  The
all-reduce itself is emitted by pjit from the sharding of the batch axis;
quantizing the tensor going into that reduction shrinks the collective's
payload (we quantize, mean-reduce in int-space via psum of int32, then
dequantize).  When running under plain jit (tests/CPU) the same code path
degenerates to quantize->dequantize, exposing exactly the numerical error
the scheme would add at scale.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class CompressionState(NamedTuple):
    error: Any  # residual pytree, same structure as grads


def init_state(grads_shape: Any) -> CompressionState:
    return CompressionState(
        error=jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                           grads_shape))


def _quant(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_grads(grads: Any, state: Optional[CompressionState]
                   ) -> Tuple[Any, CompressionState, dict]:
    """fp grads -> int8-roundtripped grads with error feedback."""
    if state is None:
        state = init_state(grads)

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = _quant(g32)
        deq = q.astype(jnp.float32) * scale
        return deq.astype(g.dtype), g32 - deq

    out = jax.tree.map(one, grads, state.error)
    new_g = jax.tree.map(lambda t: t[0], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_e = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    # compression telemetry: relative error this step
    num = sum(jnp.sum(jnp.square(e)) for e in jax.tree.leaves(new_e))
    den = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(grads))
    rel = jnp.sqrt(num / jnp.maximum(den, 1e-20))
    return new_g, CompressionState(error=new_e), {"compress_rel_err": rel}

"""Logical-axis sharding: names -> mesh axes with divisibility fallback.

The model code annotates every parameter dimension and key activations with
*logical* names ("embed", "heads", "batch", ...).  This module maps them to
physical mesh axes per a rules table, *dropping* any assignment that does
not divide the dimension (e.g. 8 KV heads on a 16-way `model` axis fall
back to replication — recorded so the dry-run can report it).

Rules are swappable via `rules_context` which is how §Perf hillclimbing
tries alternative sharding layouts without touching model code.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]


def is_axes_leaf(t) -> bool:
    """Leaf of an axes tree: a plain tuple of logical names / None.

    NamedTuples (TrainState, optimizer states) are containers, not leaves."""
    return (isinstance(t, tuple) and not hasattr(t, "_fields")
            and all(e is None or isinstance(e, str) for e in t))

# Logical axis -> preferred mesh axes (first existing+dividing one wins for
# each entry; tuple entries mean "shard over the product of these axes").
DEFAULT_RULES: Dict[str, Tuple[MeshAxes, ...]] = {
    # --- weights ---
    "vocab": ("model",),
    "embed": (("pod", "data"), "data"),     # FSDP dim
    "mlp": ("model",),
    "expert_mlp": (None,),
    "expert_embed": (("pod", "data"), "data"),  # FSDP dim of expert weights
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": (None,),
    "experts": ("model",),
    "conv": (None,),
    "layers": (None,),
    # --- activations ---
    "batch": (("pod", "data"), "data"),
    "act_seq": (None,),
    "act_embed": (None,),
    "act_heads": ("model",),
}

_local = threading.local()


def current_rules() -> Dict[str, Tuple[MeshAxes, ...]]:
    return getattr(_local, "rules", DEFAULT_RULES)


@contextlib.contextmanager
def rules_context(rules: Dict[str, Tuple[MeshAxes, ...]]):
    old = getattr(_local, "rules", None)
    _local.rules = rules
    try:
        yield
    finally:
        if old is None:
            del _local.rules
        else:
            _local.rules = old


def _mesh_axis_sizes(mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.axis_shape if hasattr(mesh, "axis_shape")
                    else mesh.devices.shape))


def _axes_size(candidate: MeshAxes, sizes: Dict[str, int]) -> Optional[int]:
    """Product of mesh-axis sizes, or None if any axis is missing."""
    if candidate is None:
        return 1
    names = (candidate,) if isinstance(candidate, str) else candidate
    total = 1
    for n in names:
        if n not in sizes:
            return None
        total *= sizes[n]
    return total


def resolve_axis(logical: Optional[str], dim: Optional[int],
                 sizes: Dict[str, int],
                 rules: Optional[Dict] = None) -> MeshAxes:
    """Pick the first rule candidate whose mesh axes exist and divide dim."""
    if logical is None:
        return None
    rules = rules or current_rules()
    for candidate in rules.get(logical, (None,)):
        n = _axes_size(candidate, sizes)
        if n is None:
            continue
        if n == 1:
            return None
        if dim is None or dim % n == 0:
            return candidate
    return None


def logical_to_pspec(axes: Sequence[Optional[str]],
                     shape: Optional[Sequence[int]],
                     sizes: Dict[str, int],
                     rules: Optional[Dict] = None) -> P:
    used: set = set()
    out = []
    for i, name in enumerate(axes):
        dim = None if shape is None else shape[i]
        resolved = resolve_axis(name, dim, sizes, rules)
        # a mesh axis may appear at most once in a PartitionSpec
        flat = ((resolved,) if isinstance(resolved, str)
                else (resolved or ()))
        if any(a in used for a in flat):
            resolved = None
        else:
            used.update(flat)
        out.append(resolved)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def make_shardings(axes_tree: Any, shapes_tree: Any, mesh: Mesh,
                   rules: Optional[Dict] = None):
    """axes_tree leaves: tuples of logical names; shapes_tree: matching
    ShapeDtypeStructs (or arrays).  Returns NamedSharding tree."""
    sizes = _mesh_axis_sizes(mesh)

    def one(axes, shaped):
        shape = tuple(shaped.shape)
        return NamedSharding(mesh, logical_to_pspec(axes, shape, sizes, rules))

    return jax.tree.map(one, axes_tree, shapes_tree, is_leaf=is_axes_leaf)


def pspec_tree(axes_tree: Any, shapes_tree: Any, mesh: Mesh,
               rules: Optional[Dict] = None):
    sizes = _mesh_axis_sizes(mesh)
    return jax.tree.map(
        lambda a, s: logical_to_pspec(a, tuple(s.shape), sizes, rules),
        axes_tree, shapes_tree, is_leaf=is_axes_leaf)


def sharding_report(axes_tree: Any, shapes_tree: Any, mesh: Mesh,
                    rules: Optional[Dict] = None):
    """List of (path, shape, pspec, bytes_per_device) for the dry-run log."""
    sizes = _mesh_axis_sizes(mesh)
    flat, _ = jax.tree_util.tree_flatten_with_path(shapes_tree)
    specs = jax.tree.leaves(
        pspec_tree(axes_tree, shapes_tree, mesh, rules),
        is_leaf=lambda x: isinstance(x, P))
    rows = []
    for (path, s), spec in zip(flat, specs):
        shard_elems = int(np.prod(s.shape)) if s.shape else 1
        denom = 1
        for entry in spec:
            denom *= _axes_size(entry, sizes) or 1
        rows.append((jax.tree_util.keystr(path), tuple(s.shape), spec,
                     shard_elems // max(denom, 1) * s.dtype.itemsize))
    return rows


# --------------------------------------------------------------------------
# Session-axis partitioning (the fleet engine's data parallelism)
# --------------------------------------------------------------------------
def shard_map_compat(f, mesh: Mesh, in_specs, out_specs,
                     check_rep: bool = True):
    """Version-compat shard_map: `jax.shard_map` (new) falling back to
    `jax.experimental.shard_map.shard_map` (every JAX we support).

    `check_rep=False` disables the static replication checker, which has
    no rule for `while` — required by any body containing a
    `lax.while_loop` (e.g. the rollout's packet-drain loops)."""
    kw = {} if check_rep else {"check_rep": False}
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, **kw)
        except TypeError:  # newer JAX renamed check_rep -> check_vma
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs,
                                 **({"check_vma": False} if kw else {}))
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     **kw)


def session_partition(mesh: Mesh, logical: str = "batch",
                      rules: Optional[Dict] = None
                      ) -> Tuple[MeshAxes, int]:
    """Mesh axes + way-count for the fleet's session ("data") axis.

    Picks the first rule candidate for `logical` whose mesh axes all
    exist, IGNORING divisibility: unlike `resolve_axis`, a session count
    that does not divide the axis size is not replicated — the fleet
    engine pads it up to the next multiple with masked dead sessions
    (`pad_sessions`) so the partition always applies.  Returns
    (None, 1) when no multi-way candidate exists (single-device mesh),
    which callers treat as "run unsharded".

    Everything the rollout scan stacks along the session dimension rides
    this partition unchanged — including the on-device server phase's
    stats outputs (glyph margins/codes, card boxes/counts), which is why
    shard-local bodies must size per-session buffers from the local
    shard (`x.shape[0]`), never the global N.  The megakernel rollout is
    the one exception: Pallas grids don't compose with shard_map here,
    so `Fleet` rejects megakernel+mesh up front rather than letting a
    partition silently fall back."""
    sizes = _mesh_axis_sizes(mesh)
    for candidate in (rules or current_rules()).get(logical, (None,)):
        n = _axes_size(candidate, sizes)
        if n is None or n == 1:
            continue
        return candidate, n
    return None, 1


def pad_sessions(n: int, ways: int) -> int:
    """Smallest multiple of `ways` >= n: the padded session count whose
    tail rows are masked dead sessions (results sliced off)."""
    if n <= 0 or ways <= 0:
        raise ValueError(f"need positive n/ways, got {n}/{ways}")
    return -(-n // ways) * ways


# --------------------------------------------------------------------------
# In-model activation constraints
# --------------------------------------------------------------------------
def _active_mesh_sizes() -> Optional[Dict[str, int]]:
    try:
        m = jax.sharding.get_abstract_mesh()
    except Exception:
        return None
    if m is None or not getattr(m, "axis_names", ()):
        return None
    return dict(zip(m.axis_names, m.axis_sizes))


def constrain(x, logical_axes: Sequence[Optional[str]]):
    """with_sharding_constraint by logical names; no-op without a mesh."""
    sizes = _active_mesh_sizes()
    if not sizes:
        return x
    spec = logical_to_pspec(logical_axes, tuple(x.shape), sizes)
    if all(e is None for e in spec):
        return x
    return jax.lax.with_sharding_constraint(x, spec)

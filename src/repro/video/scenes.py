"""Synthetic video scenes with machine-readable glyph codes.

Offline stand-in for DeViBench's video corpus (DESIGN.md §3): each scene
renders a smooth background plus moving objects carrying binary glyph
codes.  A glyph is an ng x ng grid of bright/dark cells encoding
`ng*ng - 4` payload bits (4 corner anchors).  Cell size controls
information density: small cells = high-frequency detail = degradation-
sensitive (the paper's "text on the product" regime); large cells survive
heavy compression (the "lawn and sky" regime).

Everything is seeded and pure-numpy so benchmark videos are reproducible.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Sequence, Tuple

import numpy as np

GLYPH_GRID = 4           # 4x4 cells
GLYPH_BITS = GLYPH_GRID * GLYPH_GRID - 4  # 12 payload bits

SCENE_CATEGORIES = [
    # (name, n_objects, glyph_cell_px, texture_amp) x {static, moving}
    # cell <= 3 px puts glyph energy in the top DCT bands: the first thing
    # low-bitrate quantization destroys (paper: text-rich = 81.9% of
    # degradation-sensitive samples); cell >= 8 survives heavy compression
    # (the "lawn and sky" insensitive regime).
    ("street", 3, 4, 0.25),
    ("retail", 4, 3, 0.15),
    ("office", 2, 3, 0.10),
    ("lawn", 1, 12, 0.05),
    ("document", 5, 2, 0.05),
    ("sports", 3, 8, 0.30),
]


@functools.lru_cache(maxsize=4096)
def glyph_pattern(code: int, cell: int) -> np.ndarray:
    """Render a GLYPH_GRID^2-cell glyph; corners are anchors (1,0,0,1).

    Cached per (code, cell): scenes re-stamp the same glyph every frame
    within a code epoch, and callers never mutate the returned array."""
    bits = [(code >> i) & 1 for i in range(GLYPH_BITS)]
    grid = np.zeros((GLYPH_GRID, GLYPH_GRID), np.float32)
    anchors = {(0, 0): 1, (0, GLYPH_GRID - 1): 0,
               (GLYPH_GRID - 1, 0): 0, (GLYPH_GRID - 1, GLYPH_GRID - 1): 1}
    bi = 0
    for r in range(GLYPH_GRID):
        for c in range(GLYPH_GRID):
            if (r, c) in anchors:
                grid[r, c] = anchors[(r, c)]
            else:
                grid[r, c] = bits[bi]
                bi += 1
    out = np.kron(grid, np.ones((cell, cell), np.float32))
    out.setflags(write=False)  # shared via the lru_cache
    return out


# payload-cell flat indices and their bit weights (corners are anchors)
_PAYLOAD_IDX = np.asarray(
    [r * GLYPH_GRID + c for r in range(GLYPH_GRID) for c in range(GLYPH_GRID)
     if (r, c) not in ((0, 0), (0, GLYPH_GRID - 1), (GLYPH_GRID - 1, 0),
                       (GLYPH_GRID - 1, GLYPH_GRID - 1))], np.int64)
_PAYLOAD_WEIGHTS = (1 << np.arange(GLYPH_BITS, dtype=np.int64))


def decode_glyph_batch(patches: np.ndarray, cell: int
                       ) -> Tuple[np.ndarray, np.ndarray]:
    """Vectorized `decode_glyph` over (B, S, S) stacked patches of one
    cell size -> (codes (B,) int64, margins (B,) float64).

    Per-record arithmetic is bit-identical to the scalar function: the
    cell means reduce the same contiguous elements in the same order,
    the threshold/denominator scalars stay float32 exactly as in the
    scalar path, and the final margin product is carried out in float64
    to mirror the scalar path's python-float multiply
    (tests/test_devibench_engine.py asserts exact equality)."""
    size = GLYPH_GRID * cell
    p = np.ascontiguousarray(patches[:, :size, :size])
    cells = p.reshape(-1, GLYPH_GRID, cell, GLYPH_GRID, cell).mean(axis=(2, 4))
    lo = cells.min(axis=(1, 2))
    hi = cells.max(axis=(1, 2))
    thresh = 0.5 * (lo + hi)
    denom = np.maximum(hi - lo, 1e-6)
    margin = np.clip(np.abs(cells - thresh[:, None, None])
                     / (0.5 * denom)[:, None, None], 0, 1).mean(axis=(1, 2))
    contrast = np.clip((hi - lo) / 0.5, 0, 1)
    margin = margin.astype(np.float64) * contrast.astype(np.float64)
    hard = cells.reshape(len(cells), -1)[:, _PAYLOAD_IDX] > thresh[:, None]
    codes = (hard * _PAYLOAD_WEIGHTS).sum(axis=1)
    return codes, margin


def decode_glyph(patch: np.ndarray, cell: int) -> Tuple[int, float]:
    """Threshold cell means -> (code, margin in [0,1]).

    The margin (mean distance of cell means from the 0.5 threshold) is the
    detector's native confidence signal — blurred glyphs pull means toward
    0.5, shrinking the margin before bits actually flip."""
    size = GLYPH_GRID * cell
    p = patch[:size, :size]
    cells = p.reshape(GLYPH_GRID, cell, GLYPH_GRID, cell).mean(axis=(1, 3))
    lo, hi = cells.min(), cells.max()
    thresh = 0.5 * (lo + hi)
    denom = max(hi - lo, 1e-6)
    margin = float(np.clip(np.abs(cells - thresh) / (0.5 * denom), 0, 1).mean())
    # low-contrast patches are unreadable regardless of threshold geometry
    margin *= float(np.clip((hi - lo) / 0.5, 0, 1))
    hard = (cells.reshape(-1)[_PAYLOAD_IDX] > thresh)
    code = int((_PAYLOAD_WEIGHTS * hard).sum())
    return code, margin


@dataclasses.dataclass
class SceneObject:
    code: int
    cell: int                      # glyph cell size in px
    pos0: Tuple[float, float]      # (y, x) top-left at t=0
    vel: Tuple[float, float]       # px/frame

    @property
    def size(self) -> int:
        return GLYPH_GRID * self.cell

    def code_at(self, epoch: int) -> int:
        """Scene content changes over time (price tags update, products
        rotate): each code epoch re-randomizes the glyph.  §4.1: 'newly
        appeared content requires immediate high quality' — stale visual
        memory cannot answer questions about the current epoch."""
        if epoch <= 0:
            return self.code
        return (self.code * 2654435761 + epoch * 0x9E3779B1) % (1 << GLYPH_BITS)

    def pos(self, t: int) -> Tuple[int, int]:
        return (int(round(self.pos0[0] + self.vel[0] * t)),
                int(round(self.pos0[1] + self.vel[1] * t)))

    def bbox(self, t: int) -> Tuple[int, int, int, int]:
        """(y0, x0, y1, x1) at frame t."""
        y, x = self.pos(t)
        return (y, x, y + self.size, x + self.size)


@dataclasses.dataclass
class Scene:
    h: int
    w: int
    n_frames: int
    objects: List[SceneObject]
    category: str
    moving: bool
    texture_amp: float
    seed: int
    # frames per code epoch; None = static content (DeViBench clips)
    code_period_frames: Optional[int] = None

    def epoch(self, frame_idx: int) -> int:
        if self.code_period_frames is None:
            return 0
        return int(frame_idx) // self.code_period_frames

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # smooth low-frequency background + mid-frequency texture
        yy, xx = np.mgrid[0:self.h, 0:self.w].astype(np.float32)
        self._bg = (0.45
                    + 0.18 * np.sin(2 * np.pi * xx / self.w + rng.uniform(0, 6))
                    + 0.14 * np.cos(2 * np.pi * yy / self.h + rng.uniform(0, 6)))
        tex = rng.standard_normal((self.h // 8, self.w // 8)).astype(np.float32)
        tex = np.kron(tex, np.ones((8, 8), np.float32))
        self._bg = np.clip(self._bg + self.texture_amp * 0.15 * tex, 0.05, 0.95)
        self._render_key = None
        self._render_cache = None

    def render(self, t: int) -> np.ndarray:
        # frame content is fully determined by (code epoch, object
        # positions): static scenes re-render identical frames every tick
        # (until the epoch rolls), so memoize the last one.  Callers
        # treat rendered frames as read-only.
        epoch = self.epoch(t)
        key = (epoch, tuple(obj.pos(t) for obj in self.objects))
        if key == self._render_key:
            return self._render_cache
        frame = self._bg.copy()
        for obj in self.objects:
            y, x = obj.pos(t)
            g = glyph_pattern(obj.code_at(epoch), obj.cell)
            s = obj.size
            y = int(np.clip(y, 0, self.h - s))
            x = int(np.clip(x, 0, self.w - s))
            # white card behind the glyph (like a product label)
            pad = max(obj.cell // 2, 2)
            y0, x0 = max(y - pad, 0), max(x - pad, 0)
            y1, x1 = min(y + s + pad, self.h), min(x + s + pad, self.w)
            frame[y0:y1, x0:x1] = 0.9
            frame[y:y + s, x:x + s] = 0.15 + 0.7 * g
        frame.setflags(write=False)  # shared via the cache from here on
        self._render_key, self._render_cache = key, frame
        return frame


def make_scene(category: str, moving: bool, seed: int,
               h: int = 256, w: int = 256, n_frames: int = 300,
               code_period_frames: Optional[int] = None) -> Scene:
    spec = {name: (n, cell, amp) for name, n, cell, amp in SCENE_CATEGORIES}
    n_obj, base_cell, amp = spec[category]
    rng = np.random.default_rng(seed)
    objs = []
    for _ in range(n_obj):
        # per-object cell jitter spreads the degradation breakpoint across
        # the bitrate ladder (graded accuracy curves, cf. paper Fig. 11)
        cell = int(base_cell + rng.integers(0, 3)) if base_cell < 8 else base_cell
        size = GLYPH_GRID * cell
        pos0 = (rng.uniform(8, h - size - 8), rng.uniform(8, w - size - 8))
        if moving:
            speed = rng.uniform(0.5, 2.0)
            ang = rng.uniform(0, 2 * np.pi)
            # bounce-free: aim roughly toward frame center
            cy, cx = h / 2 - pos0[0], w / 2 - pos0[1]
            norm = np.hypot(cy, cx) + 1e-6
            vel = (0.7 * speed * cy / norm + 0.3 * speed * np.sin(ang),
                   0.7 * speed * cx / norm + 0.3 * speed * np.cos(ang))
        else:
            vel = (0.0, 0.0)
        objs.append(SceneObject(code=int(rng.integers(0, 1 << GLYPH_BITS)),
                                cell=cell, pos0=pos0, vel=vel))
    return Scene(h=h, w=w, n_frames=n_frames, objects=objs,
                 category=category, moving=moving, texture_amp=amp, seed=seed,
                 code_period_frames=code_period_frames)


def all_categories() -> List[Tuple[str, bool]]:
    """The 6*2 scene-category grid of the paper (Table 2)."""
    return [(name, moving) for name, _, _, _ in SCENE_CATEGORIES
            for moving in (False, True)]

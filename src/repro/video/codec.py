"""Block-DCT video codec simulator with per-patch QP (the ZeCoStream
control surface).

This is the JAX stand-in for x265/Kvazaar (DESIGN.md §3): 8x8 DCT-II via
two MXU matmuls, HEVC-style quantization step `Qstep = 2^((QP-4)/6)`, a
coefficient-magnitude entropy-proxy rate model, and inverse transform.
The per-block transform+quant pipeline is also implemented as a Pallas
TPU kernel (repro/kernels/qp_codec) — this module is the jnp oracle and
the CPU execution path.

Frames are (H, W) grayscale in [0, 1]; H, W multiples of 8.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

BLOCK = 8
QP_MIN, QP_MAX = 20, 51
# bits-per-coefficient entropy-proxy calibration: puts a 256x256@10fps
# synthetic scene on the paper's operating curve — QP20 ~ 1.7 Mbps
# (saturated, cf. the 968 Kbps knee), QP51 ~ 0.1 Mbps (broken detail at
# the 200 Kbps DeViBench low-bitrate point).
RATE_COEF = 14.0
RATE_OVERHEAD_PER_BLOCK = 10.0  # header bits


@functools.lru_cache()
def dct_matrix(n: int = BLOCK) -> np.ndarray:
    """Orthonormal DCT-II matrix."""
    k = np.arange(n)[:, None]
    i = np.arange(n)[None, :]
    m = np.cos(np.pi * (2 * i + 1) * k / (2 * n)) * np.sqrt(2.0 / n)
    m[0] /= np.sqrt(2.0)
    return m.astype(np.float32)


def qstep(qp):
    """HEVC quantization step size."""
    return 2.0 ** ((qp - 4.0) / 6.0)


class EncodedFrame(NamedTuple):
    coeffs: jnp.ndarray   # quantized DCT coefficients (nby, nbx, 8, 8) int32
    qp_blocks: jnp.ndarray  # per-block QP used (nby, nbx) float32
    bits: jnp.ndarray     # scalar estimated size in bits
    bits_blocks: jnp.ndarray  # per-block bits (nby, nbx)


def _to_blocks(frame: jnp.ndarray) -> jnp.ndarray:
    H, W = frame.shape
    nby, nbx = H // BLOCK, W // BLOCK
    return frame.reshape(nby, BLOCK, nbx, BLOCK).transpose(0, 2, 1, 3)


def _from_blocks(blocks: jnp.ndarray) -> jnp.ndarray:
    nby, nbx = blocks.shape[:2]
    return blocks.transpose(0, 2, 1, 3).reshape(nby * BLOCK, nbx * BLOCK)


def block_qp_from_patch_qp(qp_patches: jnp.ndarray, frame_hw: Tuple[int, int],
                           patch: int) -> jnp.ndarray:
    """Upsample a (H//patch, W//patch) QP map to per-8x8-block QP."""
    H, W = frame_hw
    rep = patch // BLOCK
    qp = jnp.repeat(jnp.repeat(qp_patches, rep, axis=0), rep, axis=1)
    return qp[: H // BLOCK, : W // BLOCK]


@jax.jit
def encode(frame: jnp.ndarray, qp_blocks: jnp.ndarray) -> EncodedFrame:
    """Transform + quantize with per-block QP; returns coefficients + rate."""
    D = jnp.asarray(dct_matrix())
    blocks = _to_blocks(frame.astype(jnp.float32) - 0.5)
    coef = jnp.einsum("ij,yxjk,lk->yxil", D, blocks, D)
    qs = qstep(qp_blocks)[..., None, None] * (1.0 / 64.0)
    q = jnp.round(coef / qs).astype(jnp.int32)
    # rate proxy: ~log2(1+|q|) bits per coefficient + per-block overhead
    bits_blocks = (RATE_COEF * jnp.sum(jnp.log2(1.0 + jnp.abs(q)), axis=(-1, -2))
                   + RATE_OVERHEAD_PER_BLOCK)
    return EncodedFrame(coeffs=q, qp_blocks=qp_blocks,
                        bits=jnp.sum(bits_blocks), bits_blocks=bits_blocks)


@jax.jit
def decode(enc: EncodedFrame) -> jnp.ndarray:
    D = jnp.asarray(dct_matrix())
    qs = qstep(enc.qp_blocks)[..., None, None] * (1.0 / 64.0)
    coef = enc.coeffs.astype(jnp.float32) * qs
    blocks = jnp.einsum("ji,yxjk,kl->yxil", D, coef, D)
    return jnp.clip(_from_blocks(blocks) + 0.5, 0.0, 1.0)


def roundtrip(frame: jnp.ndarray, qp_blocks: jnp.ndarray
              ) -> Tuple[jnp.ndarray, EncodedFrame]:
    enc = encode(frame, qp_blocks)
    return decode(enc), enc


def psnr(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    mse = jnp.mean(jnp.square(a - b))
    return 10.0 * jnp.log10(1.0 / jnp.maximum(mse, 1e-10))


# --------------------------------------------------------------------------
# Rate control: hit a bits target by shifting the QP surface
# --------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("iters",))
def rate_control(frame: jnp.ndarray, qp_shape: jnp.ndarray,
                 target_bits: jnp.ndarray, iters: int = 8
                 ) -> Tuple[jnp.ndarray, EncodedFrame]:
    """Find offset o s.t. encode(frame, clip(qp_shape + o)) meets target_bits.

    `qp_shape` is the *relative* QP surface (uniform zeros for standard
    encoding; the Eq.4 map for ZeCoStream).  Bisection over the offset —
    rate is monotone in QP.  Returns (qp_blocks, EncodedFrame).
    """
    lo = jnp.float32(QP_MIN) - jnp.max(qp_shape)
    hi = jnp.float32(QP_MAX) - jnp.min(qp_shape)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        qp = jnp.clip(qp_shape + mid, QP_MIN, QP_MAX)
        bits = encode(frame, qp).bits
        # too many bits -> raise QP (raise lo)
        lo = jnp.where(bits > target_bits, mid, lo)
        hi = jnp.where(bits > target_bits, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    qp = jnp.clip(qp_shape + 0.5 * (lo + hi), QP_MIN, QP_MAX)
    enc = encode(frame, qp)
    return qp, enc

"""Block-DCT video codec simulator with per-patch QP (the ZeCoStream
control surface).

This is the JAX stand-in for x265/Kvazaar (DESIGN.md §3): 8x8 DCT-II via
two MXU matmuls, HEVC-style quantization step `Qstep = 2^((QP-4)/6)`, a
coefficient-magnitude entropy-proxy rate model, and inverse transform.
The per-block transform+quant pipeline is also implemented as a Pallas
TPU kernel (repro/kernels/qp_codec) — this module is the jnp oracle and
the CPU execution path.

Frames are (H, W) grayscale in [0, 1]; H, W multiples of 8.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

BLOCK = 8
QP_MIN, QP_MAX = 20, 51
# bits-per-coefficient entropy-proxy calibration: puts a 256x256@10fps
# synthetic scene on the paper's operating curve — QP20 ~ 1.7 Mbps
# (saturated, cf. the 968 Kbps knee), QP51 ~ 0.1 Mbps (broken detail at
# the 200 Kbps DeViBench low-bitrate point).
RATE_COEF = 14.0
RATE_OVERHEAD_PER_BLOCK = 10.0  # header bits


@functools.lru_cache()
def dct_matrix(n: int = BLOCK) -> np.ndarray:
    """Orthonormal DCT-II matrix."""
    k = np.arange(n)[:, None]
    i = np.arange(n)[None, :]
    m = np.cos(np.pi * (2 * i + 1) * k / (2 * n)) * np.sqrt(2.0 / n)
    m[0] /= np.sqrt(2.0)
    return m.astype(np.float32)


def qstep(qp):
    """HEVC quantization step size."""
    return 2.0 ** ((qp - 4.0) / 6.0)


def tree_sum(x: jnp.ndarray, ndims: int) -> jnp.ndarray:
    """Fixed-order pairwise-tree sum over the trailing `ndims` axes.

    Written as an explicit log2-depth chain of elementwise adds (after an
    exact zero pad to the next power of two) instead of an XLA reduce.
    Reduce accumulation order is a backend/fusion decision: the same
    `jnp.sum` can round differently when the surrounding graph changes —
    e.g. the rate model inlined into the rollout's `lax.scan` body vs the
    standalone fleet executable.  An explicit add DAG has exactly one
    evaluation order under any fusion, which is what pins `bits` to the
    same float across the serial, fleet-eager and fleet-rollout paths.
    All summands here are finite and non-negative, so the zero pad is
    exact.
    """
    lead = x.shape[:x.ndim - ndims]
    flat = x.reshape(lead + (-1,))
    n = flat.shape[-1]
    p = 1 << max(n - 1, 0).bit_length()
    if p != n:
        flat = jnp.pad(flat, [(0, 0)] * len(lead) + [(0, p - n)])
    while flat.shape[-1] > 1:
        flat = flat[..., ::2] + flat[..., 1::2]
    return flat[..., 0]


class EncodedFrame(NamedTuple):
    coeffs: jnp.ndarray   # quantized DCT coefficients (nby, nbx, 8, 8) int32
    qp_blocks: jnp.ndarray  # per-block QP used (nby, nbx) float32
    bits: jnp.ndarray     # scalar estimated size in bits
    bits_blocks: jnp.ndarray  # per-block bits (nby, nbx)


def _to_blocks(frame: jnp.ndarray) -> jnp.ndarray:
    H, W = frame.shape
    nby, nbx = H // BLOCK, W // BLOCK
    return frame.reshape(nby, BLOCK, nbx, BLOCK).transpose(0, 2, 1, 3)


def _from_blocks(blocks: jnp.ndarray) -> jnp.ndarray:
    nby, nbx = blocks.shape[:2]
    return blocks.transpose(0, 2, 1, 3).reshape(nby * BLOCK, nbx * BLOCK)


def _dct_blocks(frame: jnp.ndarray) -> jnp.ndarray:
    """Blockwise DCT-II of a (H, W) frame -> (nby, nbx, 8, 8).

    D @ block @ D^T computed as two flat-batched (B*8, 8) x (8, 8)
    matmuls (the Pallas kernel's MXU-friendly formulation) — measurably
    faster than the nested einsum on CPU as well."""
    D = jnp.asarray(dct_matrix())
    nby, nbx = frame.shape[0] // BLOCK, frame.shape[1] // BLOCK
    x = _to_blocks(frame.astype(jnp.float32) - 0.5).reshape(-1, 8, 8)
    t = jax.lax.dot_general(x, D, (((2,), (1,)), ((), ())))       # x @ D^T
    coef = jax.lax.dot_general(
        t.transpose(0, 2, 1), D, (((2,), (1,)), ((), ()))).transpose(0, 2, 1)
    return coef.reshape(nby, nbx, 8, 8)


def _idct_blocks(coef: jnp.ndarray) -> jnp.ndarray:
    """Inverse of `_dct_blocks`: (nby, nbx, 8, 8) -> (H, W) in [0, 1]."""
    D = jnp.asarray(dct_matrix())
    nby, nbx = coef.shape[:2]
    c = coef.reshape(-1, 8, 8)
    t = jax.lax.dot_general(c, D, (((2,), (0,)), ((), ())))       # c @ D
    rec = jax.lax.dot_general(
        t.transpose(0, 2, 1), D, (((2,), (0,)), ((), ()))).transpose(0, 2, 1)
    return jnp.clip(_from_blocks(rec.reshape(nby, nbx, 8, 8)) + 0.5,
                    0.0, 1.0)


@jax.jit
def encode(frame: jnp.ndarray, qp_blocks: jnp.ndarray) -> EncodedFrame:
    """Transform + quantize with per-block QP; returns coefficients + rate."""
    coef = _dct_blocks(frame)
    qs = qstep(qp_blocks)[..., None, None] * (1.0 / 64.0)
    q = jnp.round(coef / qs).astype(jnp.int32)
    # rate proxy: ~log2(1+|q|) bits per coefficient + per-block overhead.
    # The int32->float32 cast is explicit so the arithmetic stays float32
    # even when traced under enable_x64 (the rollout scan), where the
    # weak-scalar promotion of `1.0 + int32` would otherwise yield f64.
    bits_blocks = (RATE_COEF * tree_sum(
        jnp.log2(jnp.float32(1.0) + jnp.abs(q).astype(jnp.float32)), 2)
        + RATE_OVERHEAD_PER_BLOCK)
    return EncodedFrame(coeffs=q, qp_blocks=qp_blocks,
                        bits=tree_sum(bits_blocks, 2),
                        bits_blocks=bits_blocks)


@jax.jit
def decode(enc: EncodedFrame) -> jnp.ndarray:
    qs = qstep(enc.qp_blocks)[..., None, None] * (1.0 / 64.0)
    return _idct_blocks(enc.coeffs.astype(jnp.float32) * qs)


def roundtrip(frame: jnp.ndarray, qp_blocks: jnp.ndarray
              ) -> Tuple[jnp.ndarray, EncodedFrame]:
    enc = encode(frame, qp_blocks)
    return decode(enc), enc


def psnr(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    mse = jnp.mean(jnp.square(a - b))
    return 10.0 * jnp.log10(1.0 / jnp.maximum(mse, 1e-10))


# --------------------------------------------------------------------------
# Rate control: hit a bits target by shifting the QP surface
# --------------------------------------------------------------------------
def _rate_model(coef: jnp.ndarray, qp: jnp.ndarray) -> jnp.ndarray:
    """Per-block bits of quantizing DCT coefficients at per-block QP —
    the same formula `encode` uses, factored out so bisection probes can
    run it on cached/subsampled coefficients without re-transforming."""
    qs = qstep(qp)[..., None, None] * (1.0 / 64.0)
    q = jnp.round(coef / qs)
    return (RATE_COEF * tree_sum(jnp.log2(1.0 + jnp.abs(q)), 2)
            + RATE_OVERHEAD_PER_BLOCK)


def _probe(coef: jnp.ndarray, qp_shape: jnp.ndarray, probe_stride: int):
    """Strided block subset + scale factor for estimated whole-frame bits.

    probe_stride=1 is exact; stride s probes 1/s^2 of the blocks during
    bisection (the final encode is always exact) — a fleet-scale knob
    that cuts the dominant cost of rate control ~s^2-fold at the price
    of a few percent of rate-targeting error."""
    if probe_stride <= 1:
        return coef, qp_shape, jnp.float32(1.0)
    coef_p = coef[::probe_stride, ::probe_stride]
    shape_p = qp_shape[::probe_stride, ::probe_stride]
    scale = (coef.shape[0] * coef.shape[1]) / (
        coef_p.shape[0] * coef_p.shape[1])
    return coef_p, shape_p, jnp.float32(scale)


@functools.partial(jax.jit, static_argnames=("iters", "probe_stride"))
def rate_control(frame: jnp.ndarray, qp_shape: jnp.ndarray,
                 target_bits: jnp.ndarray, iters: int = 8,
                 probe_stride: int = 1
                 ) -> Tuple[jnp.ndarray, EncodedFrame]:
    """Find offset o s.t. encode(frame, clip(qp_shape + o)) meets target_bits.

    `qp_shape` is the *relative* QP surface (uniform zeros for standard
    encoding; the Eq.4 map for ZeCoStream).  Bisection over the offset —
    rate is monotone in QP.  The DCT runs once; each iteration only
    re-quantizes (optionally a strided block probe, see `_probe`).
    Returns (qp_blocks, EncodedFrame).
    """
    coef = _dct_blocks(frame)
    coef_p, shape_p, scale = _probe(coef, qp_shape, probe_stride)
    lo = jnp.float32(QP_MIN) - jnp.max(qp_shape)
    hi = jnp.float32(QP_MAX) - jnp.min(qp_shape)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        qp = jnp.clip(shape_p + mid, QP_MIN, QP_MAX)
        bits = tree_sum(_rate_model(coef_p, qp), 2) * scale
        # too many bits -> raise QP (raise lo)
        lo = jnp.where(bits > target_bits, mid, lo)
        hi = jnp.where(bits > target_bits, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    qp = jnp.clip(qp_shape + 0.5 * (lo + hi), QP_MIN, QP_MAX)
    enc = encode(frame, qp)
    return qp, enc


def _requantize_core(coeffs: jnp.ndarray, qp_blocks: jnp.ndarray,
                     qp_shape: jnp.ndarray, target_bits: jnp.ndarray,
                     iters: int = 8, probe_stride: int = 1) -> EncodedFrame:
    """Re-quantize already-computed coefficients toward a new bits target.

    Used when the channel partially drops a frame: instead of rerunning
    the full DCT + 8-iteration bisection on the source frame, dequantize
    the cached coefficients once and run the shared coefficient-domain
    bisection (`_rc_core_from_coef` — no transform).  `qp_shape` is the
    same relative surface rate_control searched over, so the result
    lives in the same QP family as a from-scratch encode at the
    delivered rate.
    """
    qs0 = qstep(qp_blocks)[..., None, None] * (1.0 / 64.0)
    coef = coeffs.astype(jnp.float32) * qs0  # dequantized approximation
    return _rc_core_from_coef(coef, qp_shape, target_bits, iters,
                              probe_stride)


@functools.partial(jax.jit, static_argnames=("iters", "probe_stride"))
def requantize(coeffs: jnp.ndarray, qp_blocks: jnp.ndarray,
               qp_shape: jnp.ndarray, target_bits: jnp.ndarray,
               iters: int = 8, probe_stride: int = 1) -> EncodedFrame:
    return _requantize_core(coeffs, qp_blocks, qp_shape, target_bits,
                            iters, probe_stride)


def _rc_core_from_coef(coef: jnp.ndarray, qp_shape: jnp.ndarray,
                       target_bits: jnp.ndarray, iters: int = 8,
                       probe_stride: int = 1) -> EncodedFrame:
    """`rate_control`'s bisection + final quantize, starting from
    already-computed DCT coefficients.

    Mirrors `rate_control` op for op — the final quantize applies the
    same `encode` arithmetic to `coef` instead of re-transforming the
    frame, which is exact because the DCT is deterministic (the grid
    path below DCTs each unique frame once and shares the coefficients
    across every degradation cell that reuses the frame)."""
    coef_p, shape_p, scale = _probe(coef, qp_shape, probe_stride)
    lo = jnp.float32(QP_MIN) - jnp.max(qp_shape)
    hi = jnp.float32(QP_MAX) - jnp.min(qp_shape)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        qp = jnp.clip(shape_p + mid, QP_MIN, QP_MAX)
        bits = tree_sum(_rate_model(coef_p, qp), 2) * scale
        lo = jnp.where(bits > target_bits, mid, lo)
        hi = jnp.where(bits > target_bits, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    qp = jnp.clip(qp_shape + 0.5 * (lo + hi), QP_MIN, QP_MAX)
    qs = qstep(qp)[..., None, None] * (1.0 / 64.0)
    q = jnp.round(coef / qs).astype(jnp.int32)
    # explicit float32 cast: x64-trace-robust, see `encode`
    bb = (RATE_COEF * tree_sum(
        jnp.log2(jnp.float32(1.0) + jnp.abs(q).astype(jnp.float32)), 2)
        + RATE_OVERHEAD_PER_BLOCK)
    return EncodedFrame(coeffs=q, qp_blocks=qp, bits=tree_sum(bb, 2),
                        bits_blocks=bb)


# --------------------------------------------------------------------------
# Batched entry points: the fleet engine's single-dispatch-per-tick path.
# All are vmaps of the single-frame functions above, so per-sample results
# are identical to the serial path (verified by tests/test_fleet.py).
# --------------------------------------------------------------------------
@jax.jit
def encode_batch(frames: jnp.ndarray, qp_blocks: jnp.ndarray) -> EncodedFrame:
    """frames (N, H, W), qp_blocks (N, H//8, W//8) -> batched EncodedFrame."""
    return jax.vmap(encode)(frames, qp_blocks)


@jax.jit
def decode_batch(enc: EncodedFrame) -> jnp.ndarray:
    """Batched inverse of encode_batch -> (N, H, W) reconstructions."""
    return jax.vmap(decode)(enc)


@functools.partial(jax.jit, static_argnames=("iters", "probe_stride"))
def rate_control_batch(frames: jnp.ndarray, qp_shapes: jnp.ndarray,
                       target_bits: jnp.ndarray, iters: int = 8,
                       probe_stride: int = 1
                       ) -> Tuple[jnp.ndarray, EncodedFrame]:
    """Vmapped per-session bisection: frames (N, H, W), qp_shapes
    (N, H//8, W//8), target_bits (N,) -> (qp (N, ...), EncodedFrame batch).

    One device dispatch encodes a whole fleet tick; each session bisects
    its own QP offset against its own target."""
    return jax.vmap(
        lambda f, q, t: rate_control(f, q, t, iters, probe_stride))(
            frames, qp_shapes, target_bits)


@functools.partial(jax.jit, static_argnames=("iters", "probe_stride"))
def grid_rate_control_decode(frames: jnp.ndarray, frame_idx: jnp.ndarray,
                             qp_shapes: jnp.ndarray,
                             target_bits: jnp.ndarray, iters: int = 8,
                             probe_stride: int = 1
                             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """DeViBench grid fast path: encode+decode M = len(frame_idx) grid
    rows over F <= M unique frames in ONE dispatch.

    frames (F, H, W) are DCT'd once; each grid row gathers its frame's
    coefficients (`frame_idx` (M,)) and runs the per-row QP bisection,
    final quantize and inverse transform on them — a (frame x
    degradation) grid shares the transform across every degradation
    cell that reuses a frame, and nothing round-trips to the host
    between stages.  Returns (reconstructions (M, H, W), bits (M,));
    per-row results are bit-identical to serial `rate_control` +
    `decode` (tests/test_devibench_engine.py)."""
    coef = jax.vmap(_dct_blocks)(frames)[frame_idx]

    def one(c, qs_, tb):
        enc = _rc_core_from_coef(c, qs_, tb, iters, probe_stride)
        return decode(enc), enc.bits

    return jax.vmap(one)(coef, qp_shapes, target_bits)


@functools.partial(jax.jit, static_argnames=("iters", "probe_stride"))
def decode_delivered_batch(enc: EncodedFrame, qp_shapes: jnp.ndarray,
                           delivered_bits: jnp.ndarray,
                           needs_requant: jnp.ndarray, iters: int = 8,
                           probe_stride: int = 1) -> jnp.ndarray:
    """Receiver-side finalize for a fleet tick, one dispatch for N frames.

    Sessions whose frame survived intact decode the original coefficients;
    sessions with a partial packet drop re-quantize toward the delivered
    bits first (same cheap path as the serial `requantize`).  The whole
    re-quantize bisection is gated behind a `lax.cond` on whether ANY
    session needs it — most ticks drop nothing, and the where-select
    below returns `enc` verbatim then, so skipping the branch is
    bit-exact while saving the dominant cost of this dispatch."""
    def _requant(_):
        enc2 = jax.vmap(
            lambda c, qb, qs, tb: _requantize_core(c, qb, qs, tb, iters,
                                                   probe_stride))(
                enc.coeffs, enc.qp_blocks, qp_shapes, delivered_bits)
        m4 = needs_requant[:, None, None, None, None]
        m2 = needs_requant[:, None, None]
        return EncodedFrame(
            coeffs=jnp.where(m4, enc2.coeffs, enc.coeffs),
            qp_blocks=jnp.where(m2, enc2.qp_blocks, enc.qp_blocks),
            bits=jnp.where(needs_requant, enc2.bits, enc.bits),
            bits_blocks=jnp.where(m2, enc2.bits_blocks, enc.bits_blocks))

    sel = jax.lax.cond(jnp.any(needs_requant), _requant,
                       lambda _: enc, None)
    return jax.vmap(decode)(sel)

"""Fault-tolerant checkpointing with mesh-elastic restore.

Layout (one directory per step, committed atomically by manifest rename):

    <dir>/step_000120/
        arrays.npz          # flattened pytree leaves (key = tree path)
        MANIFEST.json       # step, tree paths, dtypes, data cursor, meta
    <dir>/LATEST            # text file: committed step number

Guarantees:
  * a checkpoint is visible only after its MANIFEST is fully written and
    LATEST is atomically replaced (rename) — a preempted save never leaves
    a half-readable checkpoint;
  * restore is **elastic**: arrays are restored host-side and re-placed
    with whatever shardings the *current* mesh prescribes, so a run saved
    on (16,16) restarts unchanged on (2,16,16) or on one CPU device;
  * `keep` bounds disk usage; `register_preemption_handler` flushes a
    checkpoint on SIGTERM (the standard TPU preemption signal).

On multi-host deployments each process would write its addressable shards
(`arrays.<proc>.npz`); the single-host path below is the degenerate case
and the manifest format already carries the process count.
"""
from __future__ import annotations

import json
import os
import shutil
import signal
import tempfile
import threading
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(path): np.asarray(jax.device_get(leaf))
            for path, leaf in flat}


def _tree_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [jax.tree_util.keystr(p) for p, _ in flat], treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._lock = threading.Lock()

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def save(self, step: int, state: Any, extra: Optional[Dict] = None):
        """Blocking save; atomic commit via LATEST rename."""
        with self._lock:
            arrays = _flatten(state)
            paths, _ = _tree_paths(state)
            final = self._step_dir(step)
            tmp = tempfile.mkdtemp(dir=self.dir, prefix=".tmp_save_")
            try:
                np.savez(os.path.join(tmp, "arrays.npz"),
                         **{k: v for k, v in arrays.items()})
                manifest = {
                    "step": int(step),
                    "paths": paths,
                    "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
                    "shapes": {k: list(v.shape) for k, v in arrays.items()},
                    "process_count": jax.process_count(),
                    "extra": extra or {},
                }
                with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
                    json.dump(manifest, f)
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)
            except BaseException:
                shutil.rmtree(tmp, ignore_errors=True)
                raise
            # atomic LATEST pointer
            latest_tmp = os.path.join(self.dir, ".LATEST.tmp")
            with open(latest_tmp, "w") as f:
                f.write(str(step))
            os.replace(latest_tmp, os.path.join(self.dir, "LATEST"))
            self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------------
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                if os.path.exists(os.path.join(self.dir, name, "MANIFEST.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        p = os.path.join(self.dir, "LATEST")
        if not os.path.exists(p):
            return None
        with open(p) as f:
            step = int(f.read().strip())
        return step if step in self.all_steps() else (
            self.all_steps()[-1] if self.all_steps() else None)

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Optional[Any] = None) -> Tuple[Any, Dict]:
        """Restore into the structure of `like`.

        `shardings`: optional matching pytree of NamedSharding for elastic
        re-placement on the current mesh; None keeps arrays on default
        device.  Returns (state, manifest_extra)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = self._step_dir(step)
        with open(os.path.join(d, "MANIFEST.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "arrays.npz"))
        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for path, leaf in flat:
            key = jax.tree_util.keystr(path)
            arr = data[key]
            assert tuple(arr.shape) == tuple(leaf.shape), (
                f"{key}: ckpt {arr.shape} vs model {leaf.shape}")
            leaves.append(arr)
        if shardings is not None:
            sh_leaves = jax.tree.leaves(
                shardings, is_leaf=lambda s: isinstance(s, jax.sharding.Sharding))
            leaves = [jax.device_put(a, s) for a, s in zip(leaves, sh_leaves)]
        else:
            leaves = [jnp.asarray(a) for a in leaves]
        state = jax.tree_util.tree_unflatten(treedef, leaves)
        return state, manifest.get("extra", {})


def register_preemption_handler(save_fn: Callable[[], None]):
    """Invoke `save_fn` then exit(0) on SIGTERM (TPU preemption notice)."""

    def handler(signum, frame):
        save_fn()
        os._exit(0)

    signal.signal(signal.SIGTERM, handler)
    return handler

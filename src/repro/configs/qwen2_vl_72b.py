"""qwen2-vl-72b [vlm] — M-RoPE, dynamic resolution. [arXiv:2409.12191; hf]

Backbone only; the vision frontend is a stub — input_specs() provides
precomputed patch embeddings plus (t, h, w) M-RoPE position ids.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=29568, vocab=152064,
    rope_theta=1e6, mrope_sections=(16, 24, 24), remat_policy="full",
).validate()

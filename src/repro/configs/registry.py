"""Architecture registry: ``--arch <id>`` -> ModelConfig."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

ARCHS: Dict[str, str] = {
    "qwen3-0.6b": "qwen3_0_6b",
    "llama3-405b": "llama3_405b",
    "mistral-large-123b": "mistral_large_123b",
    "deepseek-7b": "deepseek_7b",
    "dbrx-132b": "dbrx_132b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "mamba2-780m": "mamba2_780m",
    "musicgen-medium": "musicgen_medium",
    "recurrentgemma-9b": "recurrentgemma_9b",
    # the paper's own assistant model (small VLM used by examples/)
    "artic-assistant": "artic_assistant",
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; choices: {sorted(ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{ARCHS[name]}")
    return mod.CONFIG


def list_archs(include_extra: bool = False) -> List[str]:
    names = [n for n in ARCHS if n != "artic-assistant"]
    return names + (["artic-assistant"] if include_extra else [])

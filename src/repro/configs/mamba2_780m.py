"""mamba2-780m [ssm] — SSD (state-space duality). [arXiv:2405.21060]

Attention-free: n_heads here is the SSD head count d_inner/headdim = 48.
Sub-quadratic => runs the long_500k shape.
"""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-780m", family="ssm",
    n_layers=48, d_model=1536, n_heads=48, n_kv_heads=48, head_dim=64,
    d_ff=0, vocab=50280,
    ssm=SSMConfig(d_state=128, expand=2, headdim=64, ngroups=1, chunk=256),
).validate()

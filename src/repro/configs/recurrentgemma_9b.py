"""recurrentgemma-9b [hybrid] — RG-LRU + local attention 1:2. [arXiv:2402.19427]

38 layers = 12 x (rec, rec, attn) groups + 2 tail rec layers.  MQA (kv=1),
2048-token local window. Sub-quadratic => runs the long_500k shape.
"""
from repro.models.config import ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, head_dim=256,
    d_ff=12288, vocab=256000,
    local_window=2048, rope_theta=1e4,
    rglru=RGLRUConfig(lru_width=4096, pattern=("rec", "rec", "attn")),
).validate()

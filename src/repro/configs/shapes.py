"""Assigned input-shape sets and ShapeDtypeStruct input specs per cell.

Every (arch x shape) cell is defined here; `input_specs` builds the exact
pytree of jax.ShapeDtypeStruct stand-ins the dry-run lowers against (no
device allocation).  Modality frontends are stubs per the assignment:
the VLM cell feeds precomputed patch embeddings + M-RoPE ids, the audio
cell feeds EnCodec codebook token streams.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer as tfm
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq: int
    batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

# archs whose attention is O(S^2) with a full-seq KV skip long_500k
SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def supported(cfg: ModelConfig, shape_name: str) -> Tuple[bool, str]:
    s = SHAPES[shape_name]
    if s.name == "long_500k" and cfg.family not in SUBQUADRATIC_FAMILIES:
        return False, ("pure full-attention arch: 512k dense-attention KV "
                       "decode skipped per shape-table rule (DESIGN.md §6)")
    return True, ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def _token_batch(cfg: ModelConfig, batch: int, seq: int, labels: bool):
    if cfg.mrope_sections is not None:
        # vision-frontend stub: precomputed patch embeddings + (t,h,w) ids
        b: Dict[str, Any] = {
            "embeds": _sds((batch, seq, cfg.d_model), cfg.dtype),
            "mrope_positions": _sds((3, batch, seq), jnp.int32),
        }
        if labels:
            b["labels"] = _sds((batch, seq), jnp.int32)
        return b
    if cfg.num_codebooks > 1:
        b = {"tokens": _sds((batch, cfg.num_codebooks, seq), jnp.int32)}
        if labels:
            b["labels"] = _sds((batch, cfg.num_codebooks, seq), jnp.int32)
        return b
    b = {"tokens": _sds((batch, seq), jnp.int32)}
    if labels:
        b["labels"] = _sds((batch, seq), jnp.int32)
    return b


def cache_specs(cfg: ModelConfig, batch: int, max_len: int):
    """ShapeDtypeStruct pytree for the decode cache (via eval_shape)."""
    return jax.eval_shape(lambda: tfm.init_cache(cfg, batch, max_len))


def input_specs(cfg: ModelConfig, shape_name: str) -> Dict[str, Any]:
    """Returns {"batch": ...} for train/prefill and additionally
    {"cache": ...} for decode shapes."""
    s = SHAPES[shape_name]
    ok, why = supported(cfg, shape_name)
    if not ok:
        raise ValueError(f"{cfg.name} x {shape_name}: {why}")
    if s.kind == "train":
        return {"batch": _token_batch(cfg, s.batch, s.seq, labels=True)}
    if s.kind == "prefill":
        return {"batch": _token_batch(cfg, s.batch, s.seq, labels=False)}
    # decode: one new token against a cache of length seq. Sub-quadratic
    # archs keep O(1)/O(window) state; attention archs a full KV cache.
    cache_len = s.seq if cfg.family in ("dense", "moe") else s.seq
    batch = _token_batch(cfg, s.batch, 1, labels=False)
    if "embeds" in batch:
        # decode continues with text tokens (response generation)
        batch = {"tokens": _sds((s.batch, 1), jnp.int32),
                 "mrope_positions": _sds((3, s.batch, 1), jnp.int32)}
    return {"batch": batch, "cache": cache_specs(cfg, s.batch, cache_len)}

"""dbrx-132b [moe] — 16 experts top-4, fine-grained. [hf:databricks/dbrx-base]"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=10752, vocab=100352,
    rope_theta=5e5, remat_policy="full",
    moe=MoEConfig(num_experts=16, top_k=4, d_ff_expert=10752),
).validate()

"""musicgen-medium [audio] — decoder-only over EnCodec tokens. [arXiv:2306.05284]

Backbone only; the EnCodec frontend is a stub — inputs are 4 parallel
codebook token streams (delay-pattern interleaving lives in repro.data).
24 heads do not divide the 16-way `model` axis: attention projections fall
back to replication (mlp stays TP) — see DESIGN.md §4.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="dense",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, head_dim=64,
    d_ff=6144, vocab=2048, num_codebooks=4,
    rope_theta=1e4,
).validate()

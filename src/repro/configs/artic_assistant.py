"""The paper's own MLLM video assistant backbone (examples/ scale).

A small qwen2-vl-style decoder that ingests video-patch embeddings from
the Artic codec pipeline plus text tokens, and produces responses,
confidence feedback and grounding boxes.  This is the model the runnable
examples train/serve on CPU; the production archs swap in via --arch.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="artic-assistant", family="dense",
    n_layers=4, d_model=256, n_heads=8, n_kv_heads=4, head_dim=32,
    d_ff=1024, vocab=4096,
    qk_norm=True, rope_theta=1e5, mrope_sections=(4, 6, 6),
    dtype="float32", param_dtype="float32",
).validate()

"""Jitted wrapper: model layout (B, 1, Hq, d) + cache (B, S, Hk, d)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_decode.flash_decode import flash_decode_bhd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def flash_decode(q, k_cache, v_cache, lengths, *, bk: int = 1024,
                 interpret=None):
    """q (B, 1, Hq, d); caches (B, Smax, Hk, d); lengths () or (B,).

    Returns (B, 1, Hq, d)."""
    if interpret is None:
        interpret = not _on_tpu()
    B, _, Hq, d = q.shape
    _, Sk, Hk, _ = k_cache.shape
    qr = q[:, 0].transpose(0, 1, 2).reshape(B * Hq, d)
    kr = k_cache.transpose(0, 2, 1, 3).reshape(B * Hk, Sk, d)
    vr = v_cache.transpose(0, 2, 1, 3).reshape(B * Hk, Sk, d)
    lengths = jnp.asarray(lengths, jnp.int32)
    if lengths.ndim == 0:
        lengths = jnp.broadcast_to(lengths, (B,))
    len_rows = jnp.repeat(lengths, Hq)
    out = flash_decode_bhd(qr, kr, vr, len_rows, bk=bk, interpret=interpret)
    return out.reshape(B, Hq, 1, d).transpose(0, 2, 1, 3)

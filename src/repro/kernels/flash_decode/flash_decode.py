"""Flash-decoding Pallas TPU kernel (split-KV decode, FlashDecoding-style).

decode_32k shapes are latency-bound on a single long KV stream per query:
one token attends to 32k cached keys.  Splitting the KV axis across the
grid turns the sequential softmax into `n_chunks` independent partial
reductions (each emitting (m, l, acc)) merged by a tiny logsumexp epilogue
in the wrapper — on real hardware the chunks pipeline HBM reads back to
back, which is exactly the roofline-optimal behaviour for a memory-bound
op (arithmetic intensity ~ 1 FLOP/byte).

Valid-length masking: per-row `lengths` live in a (B,) input consumed via
a scalar index map (bh -> b = bh // Hq).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _fd_kernel(len_ref, q_ref, k_ref, v_ref, m_ref, l_ref, acc_ref, *,
               bk: int, seq_kv: int):
    ci = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)        # (1, d) single query row
    k = k_ref[0].astype(jnp.float32)        # (bk, d)
    v = v_ref[0].astype(jnp.float32)        # (bk, d)
    scale = q.shape[-1] ** -0.5
    s = jax.lax.dot_general(q * scale, k, (((1,), (1,)), ((), ())))  # (1, bk)
    cols = ci * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
    valid = (cols < len_ref[0]) & (cols < seq_kv)
    s = jnp.where(valid, s, NEG_INF)
    m = jnp.max(s, axis=-1)                          # (1,)
    p = jnp.exp(s - m[:, None])
    p = jnp.where(valid, p, 0.0)
    l = jnp.sum(p, axis=-1)
    acc = jax.lax.dot(p.astype(v.dtype), v)          # (1, d)
    m_ref[0, 0] = m[0]
    l_ref[0, 0] = l[0]
    acc_ref[0, 0] = acc[0].astype(acc_ref.dtype)


def flash_decode_bhd(q, k, v, lengths, *, bk: int = 1024,
                     interpret: bool = False):
    """q (BH, d); k/v (BHk, Sk, d); lengths (BH,) -> out (BH, d)."""
    BH, d = q.shape
    BHk, Sk, _ = k.shape
    G = BH // BHk
    bk = min(bk, Sk)
    pk = (-Sk) % bk
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0)))
    nc = k.shape[1] // bk

    kernel = functools.partial(_fd_kernel, bk=bk, seq_kv=Sk)
    m, l, acc = pl.pallas_call(
        kernel,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1,), lambda bh, ci: (bh,)),
            pl.BlockSpec((1, 1, d), lambda bh, ci: (bh, 0, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, ci: (bh // G, ci, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, ci: (bh // G, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda bh, ci: (bh, ci)),
            pl.BlockSpec((1, 1), lambda bh, ci: (bh, ci)),
            pl.BlockSpec((1, 1, d), lambda bh, ci: (bh, ci, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, nc), jnp.float32),
            jax.ShapeDtypeStruct((BH, nc), jnp.float32),
            jax.ShapeDtypeStruct((BH, nc, d), jnp.float32),
        ],
        interpret=interpret,
    )(lengths.astype(jnp.int32), q[:, None, :], k, v)

    # merge partials: softmax over chunk maxima
    m_star = jnp.max(m, axis=1, keepdims=True)            # (BH, 1)
    w = jnp.exp(m - m_star)                               # (BH, nc)
    denom = jnp.sum(l * w, axis=1)                        # (BH,)
    num = jnp.einsum("bc,bcd->bd", l * w, acc / jnp.maximum(l, 1e-30)[..., None])
    return (num / jnp.maximum(denom, 1e-30)[:, None]).astype(q.dtype)

"""Pure-jnp oracle for flash decode."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_ref(q, k, v, lengths):
    """q (BH, d); k/v (BHk, Sk, d); lengths (BH,) -> (BH, d)."""
    BH, d = q.shape
    BHk, Sk, _ = k.shape
    G = BH // BHk
    k = jnp.repeat(k, G, axis=0)
    v = jnp.repeat(v, G, axis=0)
    s = jnp.einsum("bd,bkd->bk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (d ** -0.5)
    valid = jnp.arange(Sk)[None, :] < lengths[:, None]
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bk,bkd->bd", p.astype(v.dtype), v).astype(q.dtype)

"""Fused flash-attention Pallas TPU kernel (prefill path).

Tiling: grid = (batch*q_heads, Sq/bq, Sk/bk) with the KV axis innermost so
the (m, l, acc) online-softmax state lives in VMEM scratch across KV
iterations; one (bq, d) output tile is written on the last KV step.  GQA
is handled in the BlockSpec index maps (q head -> kv head = h // G), so
K/V tiles are fetched once per group from HBM.

VMEM working set per program:
    q (bq, d) + k (bk, d) + v (bk, d) + acc (bq, d) + p (bq, bk)
with defaults bq=256, bk=512, d=128 fp32: ~1.2 MB « 16 MB VMEM, leaving
room for double-buffered HBM->VMEM pipelining of the K/V streams.
bq/bk are multiples of 128 to keep the MXU systolic array full.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale: float, bq: int, bk: int, seq_q: int, seq_kv: int,
               causal: bool, window, q_offset: int, n_kv_blocks: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale              # (bq, d)
    k = k_ref[0].astype(jnp.float32)                      # (bk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)

    rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + q_offset
    cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = cols < seq_kv
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols > rows - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1)
    v = v_ref[0].astype(jnp.float32)
    acc_scr[...] = (acc_scr[...] * alpha[:, None]
                    + jax.lax.dot(p.astype(v.dtype), v).astype(jnp.float32))
    m_scr[...] = m_new

    @pl.when(ki == n_kv_blocks - 1)
    def _done():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True, window=None,
                         q_offset: int = 0, bq: int = 256, bk: int = 512,
                         interpret: bool = False):
    """q (BH, Sq, d); k/v (BHk, Sk, d) with BH = BHk * G. Returns (BH,Sq,d).

    Rows of q map to rows of k/v by bh -> bh_kv = (bh // (Hq*?)) handled by
    the caller: here we require BH % BHk == 0 and head-major grouping, i.e.
    q row r uses kv row r // G.
    """
    BH, Sq, d = q.shape
    BHk, Sk, _ = k.shape
    assert BH % BHk == 0
    G = BH // BHk
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    pq = (-Sq) % bq
    pk = (-Sk) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0)))
    nq = q.shape[1] // bq
    nk = k.shape[1] // bk
    scale = d ** -0.5

    kernel = functools.partial(
        _fa_kernel, scale=scale, bq=bq, bk=bk, seq_q=Sq, seq_kv=Sk,
        causal=causal, window=window, q_offset=q_offset, n_kv_blocks=nk)

    out = pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, qi, ki: (bh // G, ki, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, qi, ki: (bh // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :Sq]

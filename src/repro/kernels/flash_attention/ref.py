"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True, window=None,
                  q_offset: int = 0):
    """q (BH, Sq, d); k/v (BHk, Sk, d), BH = BHk*G, q row r -> kv row r//G."""
    BH, Sq, d = q.shape
    BHk, Sk, _ = k.shape
    G = BH // BHk
    k = jnp.repeat(k, G, axis=0)
    v = jnp.repeat(v, G, axis=0)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (d ** -0.5)
    rows = jnp.arange(Sq)[:, None] + q_offset
    cols = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols > rows - window
    s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p.astype(v.dtype), v).astype(q.dtype)

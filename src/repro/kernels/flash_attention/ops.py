"""Jitted public wrapper: model layout (B, S, H, d) -> kernel layout."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_bhsd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_offset",
                                             "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    q_offset: int = 0, bq: int = 256, bk: int = 512,
                    interpret=None):
    """q (B, Sq, Hq, d); k/v (B, Sk, Hk, d) -> (B, Sq, Hq, d).

    interpret=None auto-selects: compiled on TPU, interpret elsewhere.
    """
    if interpret is None:
        interpret = not _on_tpu()
    B, Sq, Hq, d = q.shape
    _, Sk, Hk, _ = k.shape
    G = Hq // Hk
    # head-major grouping: q row b*Hq + h maps to kv row (b*Hq + h)//G
    qr = q.transpose(0, 2, 1, 3).reshape(B * Hq, Sq, d)
    kr = k.transpose(0, 2, 1, 3).reshape(B * Hk, Sk, d)
    vr = v.transpose(0, 2, 1, 3).reshape(B * Hk, Sk, d)
    out = flash_attention_bhsd(qr, kr, vr, causal=causal, window=window,
                               q_offset=q_offset, bq=bq, bk=bk,
                               interpret=interpret)
    return out.reshape(B, Hq, Sq, d).transpose(0, 2, 1, 3)

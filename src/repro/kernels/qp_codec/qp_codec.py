"""ZeCoStream QP-codec Pallas TPU kernel.

The paper's client-side hot loop: per-8x8-block DCT-II -> per-block-QP
quantize -> rate proxy -> dequant -> inverse DCT, fused into a single
VMEM pass (the jnp path in repro.video.codec materializes each stage in
HBM).  The 8x8 DCTs are batched into (bs*8, 8) x (8, 8) matmuls so the
MXU does the transform; one grid step processes `bs` blocks.

VMEM per program @ bs=512: 512*64*4B*4 buffers ~ 0.5 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.video.codec import RATE_COEF, RATE_OVERHEAD_PER_BLOCK, dct_matrix


def _codec_kernel(d_ref, x_ref, qp_ref, rec_ref, bits_ref, *, bs: int):
    D = d_ref[...]                                 # (8, 8) DCT basis
    x = x_ref[...].astype(jnp.float32) - 0.5       # (bs, 8, 8)
    # DCT: D @ x @ D^T as two batched matmuls
    t = jax.lax.dot_general(x, D, (((2,), (1,)), ((), ())))   # x @ D^T
    coef = jax.lax.dot_general(
        t.transpose(0, 2, 1), D, (((2,), (1,)), ((), ()))).transpose(0, 2, 1)
    qs = (jnp.exp2((qp_ref[...] - 4.0) / 6.0) / 64.0)[:, None, None]
    q = jnp.round(coef / qs)
    bits_ref[...] = (RATE_COEF * jnp.sum(jnp.log2(1.0 + jnp.abs(q)),
                                         axis=(-1, -2))
                     + RATE_OVERHEAD_PER_BLOCK)
    deq = q * qs
    # inverse DCT: D^T @ deq @ D
    t2 = jax.lax.dot_general(deq, D, (((2,), (0,)), ((), ())))  # deq @ D
    rec = jax.lax.dot_general(
        t2.transpose(0, 2, 1), D, (((2,), (0,)), ((), ()))).transpose(0, 2, 1)
    rec_ref[...] = jnp.clip(rec + 0.5, 0.0, 1.0).astype(rec_ref.dtype)


def qp_codec_blocks(blocks: jnp.ndarray, qp: jnp.ndarray, *, bs: int = 512,
                    interpret: bool = False):
    """blocks (N, 8, 8) float in [0,1]; qp (N,) -> (rec (N,8,8), bits (N,)).

    Fused encode+decode round-trip (what the client simulator needs: the
    reconstruction drives what the MLLM sees, the bits drive rate control).
    """
    N = blocks.shape[0]
    bs = min(bs, N)
    pad = (-N) % bs
    if pad:
        blocks = jnp.pad(blocks, ((0, pad), (0, 0), (0, 0)))
        qp = jnp.pad(qp, ((0, pad),), constant_values=51.0)
    n = blocks.shape[0] // bs

    rec, bits = pl.pallas_call(
        functools.partial(_codec_kernel, bs=bs),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((8, 8), lambda i: (0, 0)),
            pl.BlockSpec((bs, 8, 8), lambda i: (i, 0, 0)),
            pl.BlockSpec((bs,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bs, 8, 8), lambda i: (i, 0, 0)),
            pl.BlockSpec((bs,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(blocks.shape, jnp.float32),
            jax.ShapeDtypeStruct((blocks.shape[0],), jnp.float32),
        ],
        interpret=interpret,
    )(jnp.asarray(dct_matrix()), blocks.astype(jnp.float32),
      qp.astype(jnp.float32))
    return rec[:N], bits[:N]

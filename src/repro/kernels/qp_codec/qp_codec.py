"""ZeCoStream QP-codec Pallas TPU kernels.

The paper's client-side hot loop: per-8x8-block DCT-II -> per-block-QP
quantize -> rate proxy -> dequant -> inverse DCT, fused into a single
VMEM pass (the jnp path in repro.video.codec materializes each stage in
HBM).  The 8x8 DCTs are batched into (bs*8, 8) x (8, 8) matmuls so the
MXU does the transform; one grid step processes `bs` blocks.

VMEM per program @ bs=512: 512*64*4B*4 buffers ~ 0.5 MB.

Two kernel variants:

* `qp_codec_blocks` — takes a precomputed per-block QP map (the original
  kernel).
* `_zeco_rc_kernel` (via `repro.kernels.qp_codec.ops.zeco_codec_frames`)
  — the FUSED context-aware path: takes the ZeCoStream box arrays
  directly and runs importance (Eq. 3) -> QP surface (Eq. 4, zero-mean)
  -> rate-control bisection -> DCT -> quantize -> rate -> reconstruction
  for one frame per grid step, entirely in VMEM.  The (H//8, W//8) QP
  surface never exists in HBM — it is built, searched over and consumed
  on-chip.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.video.codec import (QP_MAX, QP_MIN, RATE_COEF,
                               RATE_OVERHEAD_PER_BLOCK, dct_matrix)


def _codec_kernel(d_ref, x_ref, qp_ref, rec_ref, bits_ref, *, bs: int):
    D = d_ref[...]                                 # (8, 8) DCT basis
    x = x_ref[...].astype(jnp.float32) - 0.5       # (bs, 8, 8)
    # DCT: D @ x @ D^T as two batched matmuls
    t = jax.lax.dot_general(x, D, (((2,), (1,)), ((), ())))   # x @ D^T
    coef = jax.lax.dot_general(
        t.transpose(0, 2, 1), D, (((2,), (1,)), ((), ()))).transpose(0, 2, 1)
    qs = (jnp.exp2((qp_ref[...] - 4.0) / 6.0) / 64.0)[:, None, None]
    q = jnp.round(coef / qs)
    bits_ref[...] = (RATE_COEF * jnp.sum(jnp.log2(1.0 + jnp.abs(q)),
                                         axis=(-1, -2))
                     + RATE_OVERHEAD_PER_BLOCK)
    deq = q * qs
    # inverse DCT: D^T @ deq @ D
    t2 = jax.lax.dot_general(deq, D, (((2,), (0,)), ((), ())))  # deq @ D
    rec = jax.lax.dot_general(
        t2.transpose(0, 2, 1), D, (((2,), (0,)), ((), ()))).transpose(0, 2, 1)
    rec_ref[...] = jnp.clip(rec + 0.5, 0.0, 1.0).astype(rec_ref.dtype)


def qp_codec_blocks(blocks: jnp.ndarray, qp: jnp.ndarray, *, bs: int = 512,
                    interpret: bool = False):
    """blocks (N, 8, 8) float in [0,1]; qp (N,) -> (rec (N,8,8), bits (N,)).

    Fused encode+decode round-trip (what the client simulator needs: the
    reconstruction drives what the MLLM sees, the bits drive rate control).
    """
    N = blocks.shape[0]
    bs = min(bs, N)
    pad = (-N) % bs
    if pad:
        blocks = jnp.pad(blocks, ((0, pad), (0, 0), (0, 0)))
        qp = jnp.pad(qp, ((0, pad),), constant_values=51.0)
    n = blocks.shape[0] // bs

    rec, bits = pl.pallas_call(
        functools.partial(_codec_kernel, bs=bs),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((8, 8), lambda i: (0, 0)),
            pl.BlockSpec((bs, 8, 8), lambda i: (i, 0, 0)),
            pl.BlockSpec((bs,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bs, 8, 8), lambda i: (i, 0, 0)),
            pl.BlockSpec((bs,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(blocks.shape, jnp.float32),
            jax.ShapeDtypeStruct((blocks.shape[0],), jnp.float32),
        ],
        interpret=interpret,
    )(jnp.asarray(dct_matrix()), blocks.astype(jnp.float32),
      qp.astype(jnp.float32))
    return rec[:N], bits[:N]


# --------------------------------------------------------------------------
# Fused importance -> QP -> rate-controlled encode (box arrays in)
# --------------------------------------------------------------------------
def _zeco_rc_kernel(d_ref, x_ref, box_ref, meta_ref, rec_ref, bits_ref, *,
                    gy: int, gx: int, patch: int, mu_diag: float,
                    q_min: float, q_max: float, iters: int, nblk: int):
    """One grid step = one frame: boxes -> Eq. 3/4 surface -> bisected QP
    offset -> quantized blocks, with every intermediate in VMEM.

    meta_ref row: (box_count, engaged, target_bits) as float32."""
    D = d_ref[...]                                  # (8, 8) DCT basis
    x = x_ref[0].astype(jnp.float32) - 0.5          # (nblk, 8, 8)
    t = jax.lax.dot_general(x, D, (((2,), (1,)), ((), ())))   # x @ D^T
    coef = jax.lax.dot_general(
        t.transpose(0, 2, 1), D, (((2,), (1,)), ((), ()))).transpose(0, 2, 1)

    # Eq. 3 on the patch grid, masked over the padded box axis
    b = box_ref[0]                                  # (B, 4)
    count, engaged, target = meta_ref[0, 0], meta_ref[0, 1], meta_ref[0, 2]
    cy = (jax.lax.broadcasted_iota(jnp.float32, (gy, gx), 0) + 0.5) * patch
    cx = (jax.lax.broadcasted_iota(jnp.float32, (gy, gx), 1) + 0.5) * patch
    dy = jnp.maximum(jnp.maximum(b[:, 0, None, None] - cy,
                                 cy - b[:, 2, None, None]), 0.0)
    dx = jnp.maximum(jnp.maximum(b[:, 1, None, None] - cx,
                                 cx - b[:, 3, None, None]), 0.0)
    d = jnp.sqrt(dy * dy + dx * dx)
    valid = jax.lax.broadcasted_iota(jnp.float32, d.shape, 0) < count
    d_min = jnp.min(jnp.where(valid, d, jnp.inf), axis=0)
    rho = jnp.maximum(0.0, 1.0 - d_min / mu_diag)

    # Eq. 4 -> per-block zero-mean relative surface (uniform 0 when
    # disengaged, so the bisection degenerates to standard rate control)
    qp = q_min + (q_max - q_min) * jnp.square(1.0 - rho)
    rep = patch // 8
    qpb = jnp.repeat(jnp.repeat(qp, rep, axis=0), rep, axis=1).reshape(-1)
    shape = (qpb - jnp.mean(qpb)) * engaged         # (nblk,)

    # the offset search clips at the codec's global QP range (exactly as
    # codec.rate_control does) — q_min/q_max only parameterize Eq. 4
    def rate_at(mid):
        qpx = jnp.clip(shape + mid, QP_MIN, QP_MAX)
        qs = jnp.exp2((qpx - 4.0) / 6.0) * (1.0 / 64.0)
        q = jnp.round(coef / qs[:, None, None])
        return (RATE_COEF * jnp.sum(jnp.log2(1.0 + jnp.abs(q)))
                + nblk * RATE_OVERHEAD_PER_BLOCK)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        over = rate_at(mid) > target
        return jnp.where(over, mid, lo), jnp.where(over, hi, mid)

    lo0 = QP_MIN - jnp.max(shape)
    hi0 = QP_MAX - jnp.min(shape)
    lo, hi = jax.lax.fori_loop(0, iters, body, (lo0, hi0))

    qp_f = jnp.clip(shape + 0.5 * (lo + hi), QP_MIN, QP_MAX)
    qs = jnp.exp2((qp_f - 4.0) / 6.0) * (1.0 / 64.0)
    q = jnp.round(coef / qs[:, None, None])
    bits_ref[0, :] = (RATE_COEF * jnp.sum(jnp.log2(1.0 + jnp.abs(q)),
                                          axis=(-1, -2))
                      + RATE_OVERHEAD_PER_BLOCK)
    deq = q * qs[:, None, None]
    t2 = jax.lax.dot_general(deq, D, (((2,), (0,)), ((), ())))  # deq @ D
    rec = jax.lax.dot_general(
        t2.transpose(0, 2, 1), D, (((2,), (0,)), ((), ()))).transpose(0, 2, 1)
    rec_ref[0] = jnp.clip(rec + 0.5, 0.0, 1.0).astype(rec_ref.dtype)


def zeco_rc_blocks(blocks: jnp.ndarray, boxes: jnp.ndarray,
                   meta: jnp.ndarray, *, frame_hw, patch: int = 64,
                   mu: float = 0.5, q_min: float = float(QP_MIN),
                   q_max: float = float(QP_MAX), iters: int = 8,
                   interpret: bool = False):
    """Fused variant entry on the block-list layout.

    blocks (N, nblk, 8, 8); boxes (N, B, 4); meta (N, 3) float32 rows of
    (box_count, engaged, target_bits) -> (rec (N, nblk, 8, 8),
    bits (N, nblk))."""
    H, W = frame_hw
    if H % patch or W % patch or patch % 8:
        raise ValueError("fused kernel needs patch | H, W and 8 | patch")
    N, nblk = blocks.shape[:2]
    gy, gx = H // patch, W // patch
    kern = functools.partial(
        _zeco_rc_kernel, gy=gy, gx=gx, patch=patch,
        mu_diag=float(mu * np.hypot(H, W)), q_min=float(q_min),
        q_max=float(q_max), iters=iters, nblk=nblk)
    B = boxes.shape[1]
    return pl.pallas_call(
        kern,
        grid=(N,),
        in_specs=[
            pl.BlockSpec((8, 8), lambda i: (0, 0)),
            pl.BlockSpec((1, nblk, 8, 8), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, B, 4), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 3), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, nblk, 8, 8), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, nblk), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, nblk, 8, 8), jnp.float32),
            jax.ShapeDtypeStruct((N, nblk), jnp.float32),
        ],
        interpret=interpret,
    )(jnp.asarray(dct_matrix()), blocks.astype(jnp.float32),
      boxes.astype(jnp.float32), meta.astype(jnp.float32))


# --------------------------------------------------------------------------
# Whole-tick client megakernel: surface -> bisection -> quantize -> rate,
# emitting the rollout scan's codec products (no reconstruction — the
# scan's shared decode path consumes the coefficients downstream)
# --------------------------------------------------------------------------
def _tick_rc_kernel(d_ref, x_ref, box_ref, meta_ref, cy_ref, cx_ref,
                    up_ref, coef_ref, qp_ref, bits_ref, surf_ref, *,
                    mu_diag: float, q_min: float, q_max: float,
                    iters: int, nblk: int, nbx: int, probe_stride: int,
                    probe_scale: float):
    """One grid step = one frame of the rollout's per-tick client
    compute: Eq. 3 importance (partial-patch centers come in as
    `cy/cx`), Eq. 4 QP surface, one-hot upsample to the block grid
    (`up_ref`, handles non-divisible H/W), strided-probe bisection rate
    control, and the final quantize + per-block packetized rate — every
    intermediate in VMEM.  Outputs the scan's codec products: int32
    coefficients, final per-block QP, per-block bits and the zero-mean
    relative surface (the partial-drop requantize input)."""
    D = d_ref[...]
    x = x_ref[0].astype(jnp.float32) - 0.5          # (nblk, 8, 8)
    t = jax.lax.dot_general(x, D, (((2,), (1,)), ((), ())))   # x @ D^T
    coef = jax.lax.dot_general(
        t.transpose(0, 2, 1), D, (((2,), (1,)), ((), ()))).transpose(0, 2, 1)

    b = box_ref[0]                                  # (B, 4)
    count, engaged, target = meta_ref[0, 0], meta_ref[0, 1], meta_ref[0, 2]
    cy, cx = cy_ref[...], cx_ref[...]               # (gy, gx) centers
    dy = jnp.maximum(jnp.maximum(b[:, 0, None, None] - cy,
                                 cy - b[:, 2, None, None]), 0.0)
    dx = jnp.maximum(jnp.maximum(b[:, 1, None, None] - cx,
                                 cx - b[:, 3, None, None]), 0.0)
    d = jnp.sqrt(dy * dy + dx * dx)
    valid = jax.lax.broadcasted_iota(jnp.float32, d.shape, 0) < count
    d_min = jnp.min(jnp.where(valid, d, jnp.inf), axis=0)
    rho = jnp.maximum(0.0, 1.0 - d_min / mu_diag)
    qp = q_min + (q_max - q_min) * jnp.square(1.0 - rho)

    # patch -> block upsample as a one-hot matmul (the gather-free MXU
    # formulation of zecostream._block_to_patch_idx)
    qpb = jax.lax.dot_general(qp.reshape(1, -1), up_ref[...],
                              (((1,), (0,)), ((), ()))).reshape(-1)
    shape = (qpb - jnp.mean(qpb)) * engaged         # (nblk,)
    surf_ref[0, :] = shape

    # strided block probe (codec._probe): bisection iterations rate only
    # the (by % s == 0) & (bx % s == 0) blocks, scaled back up
    if probe_stride > 1:
        bi = jax.lax.broadcasted_iota(jnp.int32, (nblk,), 0)
        pmask = (((bi // nbx) % probe_stride == 0)
                 & ((bi % nbx) % probe_stride == 0))

    def rate_at(mid):
        qpx = jnp.clip(shape + mid, QP_MIN, QP_MAX)
        qs = jnp.exp2((qpx - 4.0) / 6.0) * (1.0 / 64.0)
        q = jnp.round(coef / qs[:, None, None])
        bb = (RATE_COEF * jnp.sum(jnp.log2(1.0 + jnp.abs(q)),
                                  axis=(-1, -2))
              + RATE_OVERHEAD_PER_BLOCK)
        if probe_stride > 1:
            return jnp.sum(jnp.where(pmask, bb, 0.0)) * probe_scale
        return jnp.sum(bb)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        over = rate_at(mid) > target
        return jnp.where(over, mid, lo), jnp.where(over, hi, mid)

    lo0 = QP_MIN - jnp.max(shape)
    hi0 = QP_MAX - jnp.min(shape)
    lo, hi = jax.lax.fori_loop(0, iters, body, (lo0, hi0))

    qp_f = jnp.clip(shape + 0.5 * (lo + hi), QP_MIN, QP_MAX)
    qs = jnp.exp2((qp_f - 4.0) / 6.0) * (1.0 / 64.0)
    q = jnp.round(coef / qs[:, None, None])
    coef_ref[0] = q.astype(jnp.int32)
    qp_ref[0, :] = qp_f
    bits_ref[0, :] = (RATE_COEF * jnp.sum(jnp.log2(1.0 + jnp.abs(q)),
                                          axis=(-1, -2))
                      + RATE_OVERHEAD_PER_BLOCK)


def tick_rc_blocks(blocks: jnp.ndarray, boxes: jnp.ndarray,
                   meta: jnp.ndarray, centers, upsample, *, nbx: int,
                   mu_diag: float, q_min: float, q_max: float,
                   iters: int = 8, probe_stride: int = 1,
                   probe_scale: float = 1.0, interpret: bool = False):
    """Tick-megakernel entry on the block-list layout.

    blocks (N, nblk, 8, 8); boxes (N, B, 4); meta (N, 3) float32 rows of
    (box_count, engaged, target_bits); centers = (cy, cx) patch-center
    grids (gy, gx); upsample (gy*gx, nblk) one-hot float32; nbx = blocks
    per frame row -> (coeffs int32 (N, nblk, 8, 8), qp (N, nblk),
    bits (N, nblk), surf (N, nblk))."""
    N, nblk = blocks.shape[:2]
    cy, cx = centers
    gy, gx = cy.shape
    gp = gy * gx
    kern = functools.partial(
        _tick_rc_kernel, mu_diag=float(mu_diag), q_min=float(q_min),
        q_max=float(q_max), iters=iters, nblk=nblk, nbx=int(nbx),
        probe_stride=int(probe_stride), probe_scale=float(probe_scale))
    B = boxes.shape[1]
    return pl.pallas_call(
        kern,
        grid=(N,),
        in_specs=[
            pl.BlockSpec((8, 8), lambda i: (0, 0)),
            pl.BlockSpec((1, nblk, 8, 8), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, B, 4), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 3), lambda i: (i, 0)),
            pl.BlockSpec((gy, gx), lambda i: (0, 0)),
            pl.BlockSpec((gy, gx), lambda i: (0, 0)),
            pl.BlockSpec((gp, nblk), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, nblk, 8, 8), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, nblk), lambda i: (i, 0)),
            pl.BlockSpec((1, nblk), lambda i: (i, 0)),
            pl.BlockSpec((1, nblk), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, nblk, 8, 8), jnp.int32),
            jax.ShapeDtypeStruct((N, nblk), jnp.float32),
            jax.ShapeDtypeStruct((N, nblk), jnp.float32),
            jax.ShapeDtypeStruct((N, nblk), jnp.float32),
        ],
        interpret=interpret,
    )(jnp.asarray(dct_matrix()), blocks.astype(jnp.float32),
      boxes.astype(jnp.float32), meta.astype(jnp.float32),
      jnp.asarray(cy, jnp.float32), jnp.asarray(cx, jnp.float32),
      jnp.asarray(upsample, jnp.float32))

"""Pure-jnp oracle for the QP-codec kernel (delegates to repro.video.codec
math on a block list layout)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.video.codec import (RATE_COEF, RATE_OVERHEAD_PER_BLOCK,
                               dct_matrix, qstep)


def qp_codec_ref(blocks: jnp.ndarray, qp: jnp.ndarray):
    """blocks (N, 8, 8) in [0,1]; qp (N,) -> (rec (N,8,8), bits (N,))."""
    D = jnp.asarray(dct_matrix())
    x = blocks.astype(jnp.float32) - 0.5
    coef = jnp.einsum("ij,njk,lk->nil", D, x, D)
    qs = (qstep(qp) / 64.0)[:, None, None]
    q = jnp.round(coef / qs)
    bits = (RATE_COEF * jnp.sum(jnp.log2(1.0 + jnp.abs(q)), axis=(-1, -2))
            + RATE_OVERHEAD_PER_BLOCK)
    deq = q * qs
    rec = jnp.einsum("ji,njk,kl->nil", D, deq, D)
    return jnp.clip(rec + 0.5, 0.0, 1.0), bits

"""Pure-jnp oracles for the QP-codec kernels (delegate to
repro.video.codec math on a block list layout)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.video.codec import (QP_MAX, QP_MIN, RATE_COEF,
                               RATE_OVERHEAD_PER_BLOCK, dct_matrix, qstep)


def qp_codec_ref(blocks: jnp.ndarray, qp: jnp.ndarray):
    """blocks (N, 8, 8) in [0,1]; qp (N,) -> (rec (N,8,8), bits (N,))."""
    D = jnp.asarray(dct_matrix())
    x = blocks.astype(jnp.float32) - 0.5
    coef = jnp.einsum("ij,njk,lk->nil", D, x, D)
    qs = (qstep(qp) / 64.0)[:, None, None]
    q = jnp.round(coef / qs)
    bits = (RATE_COEF * jnp.sum(jnp.log2(1.0 + jnp.abs(q)), axis=(-1, -2))
            + RATE_OVERHEAD_PER_BLOCK)
    deq = q * qs
    rec = jnp.einsum("ji,njk,kl->nil", D, deq, D)
    return jnp.clip(rec + 0.5, 0.0, 1.0), bits


def _zeco_rc_ref_one(frame, boxes, count, engaged, target, *, patch, mu,
                     q_min, q_max, iters):
    """jnp oracle mirroring `_zeco_rc_kernel` for ONE frame."""
    H, W = frame.shape
    nby, nbx = H // 8, W // 8
    blocks = frame.reshape(nby, 8, nbx, 8).transpose(0, 2, 1, 3)
    blocks = blocks.reshape(-1, 8, 8)
    nblk = blocks.shape[0]
    D = jnp.asarray(dct_matrix())
    coef = jnp.einsum("ij,njk,lk->nil", D, blocks - 0.5, D)

    gy, gx = H // patch, W // patch
    cy = (jnp.arange(gy, dtype=jnp.float32)[:, None] + 0.5) * patch
    cx = (jnp.arange(gx, dtype=jnp.float32)[None, :] + 0.5) * patch
    dy = jnp.maximum(jnp.maximum(boxes[:, 0, None, None] - cy,
                                 cy - boxes[:, 2, None, None]), 0.0)
    dx = jnp.maximum(jnp.maximum(boxes[:, 1, None, None] - cx,
                                 cx - boxes[:, 3, None, None]), 0.0)
    d = jnp.sqrt(dy * dy + dx * dx)
    valid = jnp.arange(boxes.shape[0])[:, None, None] < count
    d_min = jnp.min(jnp.where(valid, d, jnp.inf), axis=0)
    rho = jnp.maximum(0.0, 1.0 - d_min / jnp.float32(mu * np.hypot(H, W)))
    qp = q_min + (q_max - q_min) * jnp.square(1.0 - rho)
    rep = patch // 8
    qpb = jnp.repeat(jnp.repeat(qp, rep, 0), rep, 1).reshape(-1)
    shape = (qpb - jnp.mean(qpb)) * engaged

    def rate_at(mid):
        qpx = jnp.clip(shape + mid, QP_MIN, QP_MAX)
        qs = (qstep(qpx) / 64.0)[:, None, None]
        q = jnp.round(coef / qs)
        return (RATE_COEF * jnp.sum(jnp.log2(1.0 + jnp.abs(q)))
                + nblk * RATE_OVERHEAD_PER_BLOCK)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        over = rate_at(mid) > target
        return jnp.where(over, mid, lo), jnp.where(over, hi, mid)

    lo, hi = jax.lax.fori_loop(0, iters, body,
                               (QP_MIN - jnp.max(shape),
                                QP_MAX - jnp.min(shape)))
    qp_f = jnp.clip(shape + 0.5 * (lo + hi), QP_MIN, QP_MAX)
    qs = (qstep(qp_f) / 64.0)[:, None, None]
    q = jnp.round(coef / qs)
    bits = (RATE_COEF * jnp.sum(jnp.log2(1.0 + jnp.abs(q)), axis=(-1, -2))
            + RATE_OVERHEAD_PER_BLOCK)
    rec = jnp.clip(jnp.einsum("ji,njk,kl->nil", D, q * qs, D) + 0.5,
                   0.0, 1.0)
    rec = rec.reshape(nby, nbx, 8, 8).transpose(0, 2, 1, 3)
    return rec.reshape(H, W), jnp.sum(bits)


@functools.partial(jax.jit, static_argnames=(
    "nbx", "mu_diag", "q_min", "q_max", "iters", "probe_stride",
    "probe_scale"))
def _tick_rc_ref_one(blocks, boxes, count, engaged, target, cy, cx, up, *,
                     nbx, mu_diag, q_min, q_max, iters, probe_stride,
                     probe_scale):
    """jnp oracle mirroring `_tick_rc_kernel` op-for-op for ONE frame's
    block list (same dot_general forms, iota masks and reduction
    shapes).  Jitted so XLA applies the same fusion/FMA contractions it
    applies to the interpret-mode kernel trace — eager op-by-op
    execution drifts by ~2 ulp in the surface; under jit the
    interpret-mode kernel output is bitwise identical."""
    D = jnp.asarray(dct_matrix())
    nblk = blocks.shape[0]
    x = blocks.astype(jnp.float32) - 0.5
    t = jax.lax.dot_general(x, D, (((2,), (1,)), ((), ())))
    coef = jax.lax.dot_general(
        t.transpose(0, 2, 1), D, (((2,), (1,)), ((), ()))).transpose(0, 2, 1)

    dy = jnp.maximum(jnp.maximum(boxes[:, 0, None, None] - cy,
                                 cy - boxes[:, 2, None, None]), 0.0)
    dx = jnp.maximum(jnp.maximum(boxes[:, 1, None, None] - cx,
                                 cx - boxes[:, 3, None, None]), 0.0)
    d = jnp.sqrt(dy * dy + dx * dx)
    valid = jax.lax.broadcasted_iota(jnp.float32, d.shape, 0) < count
    d_min = jnp.min(jnp.where(valid, d, jnp.inf), axis=0)
    rho = jnp.maximum(0.0, 1.0 - d_min / mu_diag)
    qp = q_min + (q_max - q_min) * jnp.square(1.0 - rho)

    qpb = jax.lax.dot_general(qp.reshape(1, -1), up,
                              (((1,), (0,)), ((), ()))).reshape(-1)
    shape = (qpb - jnp.mean(qpb)) * engaged

    if probe_stride > 1:
        bi = jax.lax.broadcasted_iota(jnp.int32, (nblk,), 0)
        pmask = (((bi // nbx) % probe_stride == 0)
                 & ((bi % nbx) % probe_stride == 0))

    def rate_at(mid):
        qpx = jnp.clip(shape + mid, QP_MIN, QP_MAX)
        qs = jnp.exp2((qpx - 4.0) / 6.0) * (1.0 / 64.0)
        q = jnp.round(coef / qs[:, None, None])
        bb = (RATE_COEF * jnp.sum(jnp.log2(1.0 + jnp.abs(q)),
                                  axis=(-1, -2))
              + RATE_OVERHEAD_PER_BLOCK)
        if probe_stride > 1:
            return jnp.sum(jnp.where(pmask, bb, 0.0)) * probe_scale
        return jnp.sum(bb)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        over = rate_at(mid) > target
        return jnp.where(over, mid, lo), jnp.where(over, hi, mid)

    lo, hi = jax.lax.fori_loop(0, iters, body,
                               (QP_MIN - jnp.max(shape),
                                QP_MAX - jnp.min(shape)))
    qp_f = jnp.clip(shape + 0.5 * (lo + hi), QP_MIN, QP_MAX)
    qs = jnp.exp2((qp_f - 4.0) / 6.0) * (1.0 / 64.0)
    q = jnp.round(coef / qs[:, None, None])
    bits = (RATE_COEF * jnp.sum(jnp.log2(1.0 + jnp.abs(q)), axis=(-1, -2))
            + RATE_OVERHEAD_PER_BLOCK)
    return q.astype(jnp.int32), qp_f, bits, shape


def tick_codec_ref(frames, boxes, counts, engaged, target_bits, *,
                   frame_hw, patch: int = 64, mu: float = 0.5,
                   q_min: float = float(QP_MIN),
                   q_max: float = float(QP_MAX), iters: int = 8,
                   probe_stride: int = 1):
    """Oracle for `ops.tick_codec_frames`: same frame-level signature and
    (surfaces, EncodedFrame) products, built from the mirrored
    per-frame oracle above."""
    from repro.kernels.qp_codec.ops import _tick_geometry
    from repro.video import codec
    N, H, W = frames.shape
    nby, nbx = H // 8, W // 8
    cy, cx, up, _, scale = _tick_geometry(tuple(frame_hw), int(patch),
                                          int(probe_stride))
    cy_j, cx_j = jnp.asarray(cy), jnp.asarray(cx)
    up_j = jnp.asarray(up)
    outs = []
    for i in range(N):
        blocks = jnp.asarray(frames[i], jnp.float32)
        blocks = blocks.reshape(nby, 8, nbx, 8).transpose(0, 2, 1, 3)
        outs.append(_tick_rc_ref_one(
            blocks.reshape(-1, 8, 8), jnp.asarray(boxes[i], jnp.float32),
            jnp.float32(counts[i]), jnp.float32(engaged[i]),
            jnp.float32(target_bits[i]), cy_j, cx_j, up_j, nbx=nbx,
            mu_diag=float(mu * np.hypot(H, W)), q_min=float(q_min),
            q_max=float(q_max), iters=iters,
            probe_stride=int(probe_stride), probe_scale=float(scale)))
    coeffs = jnp.stack([o[0] for o in outs]).reshape(N, nby, nbx, 8, 8)
    qp = jnp.stack([o[1] for o in outs]).reshape(N, nby, nbx)
    bitsb = jnp.stack([o[2] for o in outs]).reshape(N, nby, nbx)
    surf = jnp.stack([o[3] for o in outs]).reshape(N, nby, nbx)
    enc = codec.EncodedFrame(coeffs=coeffs, qp_blocks=qp,
                             bits=codec.tree_sum(bitsb, 2),
                             bits_blocks=bitsb)
    return surf, enc


def zeco_codec_ref(frames, boxes, counts, engaged, target_bits, *,
                   patch: int = 64, mu: float = 0.5,
                   q_min: float = float(QP_MIN),
                   q_max: float = float(QP_MAX), iters: int = 8):
    """Oracle for `ops.zeco_codec_frames`: (N, H, W) frames + box arrays
    -> (rec (N, H, W), bits (N,))."""
    outs = [_zeco_rc_ref_one(
        jnp.asarray(frames[i], jnp.float32),
        jnp.asarray(boxes[i], jnp.float32), jnp.float32(counts[i]),
        jnp.float32(engaged[i]), jnp.float32(target_bits[i]),
        patch=patch, mu=mu, q_min=q_min, q_max=q_max, iters=iters)
        for i in range(frames.shape[0])]
    return (jnp.stack([o[0] for o in outs]),
            jnp.stack([o[1] for o in outs]))

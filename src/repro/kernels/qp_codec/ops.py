"""Jitted wrapper: frame layout (H, W) + per-block QP map (H//8, W//8)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.qp_codec.qp_codec import qp_codec_blocks


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("bs", "interpret"))
def qp_codec_frame(frame: jnp.ndarray, qp_blocks: jnp.ndarray, *,
                   bs: int = 512, interpret=None):
    """Fused encode+decode: frame (H, W), qp (H//8, W//8) ->
    (reconstruction (H, W), total_bits scalar)."""
    if interpret is None:
        interpret = not _on_tpu()
    H, W = frame.shape
    nby, nbx = H // 8, W // 8
    blocks = frame.reshape(nby, 8, nbx, 8).transpose(0, 2, 1, 3)
    blocks = blocks.reshape(nby * nbx, 8, 8)
    rec, bits = qp_codec_blocks(blocks, qp_blocks.reshape(-1),
                                bs=bs, interpret=interpret)
    rec = rec.reshape(nby, nbx, 8, 8).transpose(0, 2, 1, 3).reshape(H, W)
    return rec, jnp.sum(bits)


@functools.partial(jax.jit, static_argnames=("bs", "interpret"))
def qp_codec_frames(frames: jnp.ndarray, qp_blocks: jnp.ndarray, *,
                    bs: int = 512, interpret=None):
    """Fleet-batched fused encode+decode: frames (N, H, W), qp
    (N, H//8, W//8) -> (reconstructions (N, H, W), per-frame bits (N,)).

    All N frames' blocks are flattened into ONE kernel launch, so a whole
    fleet tick's codec work is a single device dispatch."""
    if interpret is None:
        interpret = not _on_tpu()
    N, H, W = frames.shape
    nby, nbx = H // 8, W // 8
    blocks = frames.reshape(N, nby, 8, nbx, 8).transpose(0, 1, 3, 2, 4)
    blocks = blocks.reshape(N * nby * nbx, 8, 8)
    rec, bits = qp_codec_blocks(blocks, qp_blocks.reshape(-1),
                                bs=bs, interpret=interpret)
    rec = rec.reshape(N, nby, nbx, 8, 8).transpose(0, 1, 3, 2, 4)
    rec = rec.reshape(N, H, W)
    return rec, bits.reshape(N, nby * nbx).sum(axis=1)

"""Jitted wrappers: frame layout (H, W) + per-block QP map (H//8, W//8),
plus the fused box-array entry `zeco_codec_frames`."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.qp_codec.qp_codec import (qp_codec_blocks, tick_rc_blocks,
                                             zeco_rc_blocks)
from repro.video import codec
from repro.video.codec import QP_MAX, QP_MIN


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("bs", "interpret"))
def qp_codec_frame(frame: jnp.ndarray, qp_blocks: jnp.ndarray, *,
                   bs: int = 512, interpret=None):
    """Fused encode+decode: frame (H, W), qp (H//8, W//8) ->
    (reconstruction (H, W), total_bits scalar)."""
    if interpret is None:
        interpret = not _on_tpu()
    H, W = frame.shape
    nby, nbx = H // 8, W // 8
    blocks = frame.reshape(nby, 8, nbx, 8).transpose(0, 2, 1, 3)
    blocks = blocks.reshape(nby * nbx, 8, 8)
    rec, bits = qp_codec_blocks(blocks, qp_blocks.reshape(-1),
                                bs=bs, interpret=interpret)
    rec = rec.reshape(nby, nbx, 8, 8).transpose(0, 2, 1, 3).reshape(H, W)
    return rec, jnp.sum(bits)


@functools.partial(jax.jit, static_argnames=("bs", "interpret"))
def qp_codec_frames(frames: jnp.ndarray, qp_blocks: jnp.ndarray, *,
                    bs: int = 512, interpret=None):
    """Fleet-batched fused encode+decode: frames (N, H, W), qp
    (N, H//8, W//8) -> (reconstructions (N, H, W), per-frame bits (N,)).

    All N frames' blocks are flattened into ONE kernel launch, so a whole
    fleet tick's codec work is a single device dispatch."""
    if interpret is None:
        interpret = not _on_tpu()
    N, H, W = frames.shape
    nby, nbx = H // 8, W // 8
    blocks = frames.reshape(N, nby, 8, nbx, 8).transpose(0, 1, 3, 2, 4)
    blocks = blocks.reshape(N * nby * nbx, 8, 8)
    rec, bits = qp_codec_blocks(blocks, qp_blocks.reshape(-1),
                                bs=bs, interpret=interpret)
    rec = rec.reshape(N, nby, nbx, 8, 8).transpose(0, 1, 3, 2, 4)
    rec = rec.reshape(N, H, W)
    return rec, bits.reshape(N, nby * nbx).sum(axis=1)


@functools.partial(jax.jit, static_argnames=("iters", "probe_stride", "bs",
                                             "interpret"))
def rate_controlled_codec_frames(frames: jnp.ndarray,
                                 qp_shapes: jnp.ndarray,
                                 target_bits: jnp.ndarray, *,
                                 iters: int = 8, probe_stride: int = 1,
                                 bs: int = 512, interpret=None):
    """Rate-controlled fused encode+decode for a DeViBench grid batch:
    the jnp bisection solves each row's QP offset against its own bits
    target, then ONE fused Pallas launch reconstructs every frame at the
    solved surfaces.

    frames (N, H, W), qp_shapes (N, H//8, W//8), target_bits (N,) ->
    (reconstructions (N, H, W), per-frame bits (N,)).  This is the
    DeViBench engine's `backend="kernel"` encode path (interpret mode
    off-TPU); it matches the jnp path to kernel tolerance, not bitwise
    (tests/test_devibench_engine.py)."""
    qp, _ = codec.rate_control_batch(frames, qp_shapes, target_bits,
                                     iters=iters,
                                     probe_stride=probe_stride)
    return qp_codec_frames(frames, qp, bs=bs, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("patch", "mu", "q_min",
                                             "q_max", "iters", "interpret"))
def zeco_codec_frames(frames: jnp.ndarray, boxes: jnp.ndarray,
                      counts: jnp.ndarray, engaged: jnp.ndarray,
                      target_bits: jnp.ndarray, *, patch: int = 64,
                      mu: float = 0.5, q_min: float = float(QP_MIN),
                      q_max: float = float(QP_MAX), iters: int = 8,
                      interpret=None):
    """Fleet-batched FUSED context-aware encode: the kernel takes the
    ZeCoStream box arrays directly and runs importance (Eq. 3) -> QP
    surface (Eq. 4) -> rate-control bisection -> DCT -> quantize -> rate
    -> reconstruction in one VMEM pass per frame — the QP surface never
    materializes in HBM.

    frames (N, H, W), boxes (N, B, 4), counts (N,), engaged (N,),
    target_bits (N,) -> (reconstructions (N, H, W), per-frame bits (N,)).
    Disengaged (or box-less) rows degenerate to uniform-QP rate control.

    This is the TPU encode path (validated in interpret mode by
    tests/test_kernels.py and timed in benchmarks/bench_kernels.py; the
    on-chip-surface claim is untested on real TPU hardware).  The fleet
    engine's `fused_plan` mode uses the jnp-level
    `zecostream.rate_control_batch_fused` instead — it yields the cached
    coefficients the partial-drop requantize path needs and supports
    `probe_stride`, which this kernel's exact bisection does not.
    """
    if interpret is None:
        interpret = not _on_tpu()
    N, H, W = frames.shape
    nby, nbx = H // 8, W // 8
    blocks = frames.reshape(N, nby, 8, nbx, 8).transpose(0, 1, 3, 2, 4)
    blocks = blocks.reshape(N, nby * nbx, 8, 8)
    meta = jnp.stack([counts.astype(jnp.float32),
                      engaged.astype(jnp.float32),
                      target_bits.astype(jnp.float32)], axis=1)
    rec, bits = zeco_rc_blocks(blocks, boxes, meta, frame_hw=(H, W),
                               patch=patch, mu=mu, q_min=q_min,
                               q_max=q_max, iters=iters,
                               interpret=interpret)
    rec = rec.reshape(N, nby, nbx, 8, 8).transpose(0, 1, 3, 2, 4)
    return rec.reshape(N, H, W), bits.sum(axis=1)


@functools.lru_cache(maxsize=64)
def _tick_geometry(frame_hw, patch: int, probe_stride: int):
    """Static per-(frame geometry) kernel inputs: partial-patch center
    grids, the (gy*gx, nblk) one-hot patch->block upsample matrix, the
    blocks-per-row count and the probe rescale factor of codec._probe."""
    from repro.core.zecostream import (_block_to_patch_idx, _patch_centers,
                                       _patch_grid)
    H, W = frame_hw
    nby, nbx = H // 8, W // 8
    nblk = nby * nbx
    gy, gx = _patch_grid(frame_hw, patch)
    yy, xx = _patch_centers(frame_hw, patch)
    cy = np.ascontiguousarray(yy, np.float32)
    cx = np.ascontiguousarray(xx, np.float32)
    iy, ix = _block_to_patch_idx(frame_hw, patch)
    pidx = (iy[:, None] * gx + ix[None, :]).reshape(-1)
    up = np.zeros((gy * gx, nblk), np.float32)
    up[pidx, np.arange(nblk)] = 1.0
    s = max(int(probe_stride), 1)
    scale = nblk / (-(-nby // s) * -(-nbx // s))
    return cy, cx, up, nbx, float(scale)


@functools.partial(jax.jit, static_argnames=(
    "frame_hw", "patch", "mu", "q_min", "q_max", "iters", "probe_stride",
    "interpret"))
def tick_codec_frames(frames: jnp.ndarray, boxes: jnp.ndarray,
                      counts: jnp.ndarray, engaged: jnp.ndarray,
                      target_bits: jnp.ndarray, *, frame_hw,
                      patch: int = 64, mu: float = 0.5,
                      q_min: float = float(QP_MIN),
                      q_max: float = float(QP_MAX), iters: int = 8,
                      probe_stride: int = 1, interpret=None):
    """Tick megakernel: the rollout scan's whole per-tick client phase —
    box arrays -> importance (Eq. 3) -> QP surface (Eq. 4) -> DCT ->
    strided-probe bisection rate control -> quantize -> packetized rate —
    fused into one VMEM pass per frame over all N sessions.

    Unlike `zeco_codec_frames` it emits the CODEC PRODUCTS the scan
    carries forward instead of a reconstruction: (surfaces (N, nby, nbx)
    zero-mean relative QP, EncodedFrame(coeffs int32, qp_blocks, bits,
    bits_blocks)) — the scan's shared `decode_delivered_batch` does the
    (possibly partial-delivery requantized) reconstruction downstream.
    Handles non-divisible H/W via partial-patch centers + a one-hot
    upsample matmul, and supports `probe_stride` (an in-kernel iota mask
    replaces codec._probe's strided slice).  This is the
    `Fleet(..., megakernel=True)` encode path: kernel-vs-ref parity is
    bitwise in interpret mode (tests/test_kernels.py); vs the eager jnp
    fleet it is a documented fast-math tolerance tier, NOT bit-exact."""
    if interpret is None:
        interpret = not _on_tpu()
    N, H, W = frames.shape
    nby, nbx = H // 8, W // 8
    blocks = frames.reshape(N, nby, 8, nbx, 8).transpose(0, 1, 3, 2, 4)
    blocks = blocks.reshape(N, nby * nbx, 8, 8)
    meta = jnp.stack([counts.astype(jnp.float32),
                      engaged.astype(jnp.float32),
                      target_bits.astype(jnp.float32)], axis=1)
    cy, cx, up, _, scale = _tick_geometry(tuple(frame_hw), int(patch),
                                          int(probe_stride))
    coeffs, qp, bitsb, surf = tick_rc_blocks(
        blocks, boxes, meta, (cy, cx), up, nbx=nbx,
        mu_diag=float(mu * np.hypot(H, W)), q_min=float(q_min),
        q_max=float(q_max), iters=iters, probe_stride=int(probe_stride),
        probe_scale=scale, interpret=interpret)
    surf = surf.reshape(N, nby, nbx)
    bitsb = bitsb.reshape(N, nby, nbx)
    enc = codec.EncodedFrame(
        coeffs=coeffs.reshape(N, nby, nbx, 8, 8),
        qp_blocks=qp.reshape(N, nby, nbx),
        bits=codec.tree_sum(bitsb, 2),
        bits_blocks=bitsb)
    return surf, enc

"""Jitted wrappers: frame layout (H, W) + per-block QP map (H//8, W//8),
plus the fused box-array entry `zeco_codec_frames`."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.qp_codec.qp_codec import qp_codec_blocks, zeco_rc_blocks
from repro.video import codec
from repro.video.codec import QP_MAX, QP_MIN


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("bs", "interpret"))
def qp_codec_frame(frame: jnp.ndarray, qp_blocks: jnp.ndarray, *,
                   bs: int = 512, interpret=None):
    """Fused encode+decode: frame (H, W), qp (H//8, W//8) ->
    (reconstruction (H, W), total_bits scalar)."""
    if interpret is None:
        interpret = not _on_tpu()
    H, W = frame.shape
    nby, nbx = H // 8, W // 8
    blocks = frame.reshape(nby, 8, nbx, 8).transpose(0, 2, 1, 3)
    blocks = blocks.reshape(nby * nbx, 8, 8)
    rec, bits = qp_codec_blocks(blocks, qp_blocks.reshape(-1),
                                bs=bs, interpret=interpret)
    rec = rec.reshape(nby, nbx, 8, 8).transpose(0, 2, 1, 3).reshape(H, W)
    return rec, jnp.sum(bits)


@functools.partial(jax.jit, static_argnames=("bs", "interpret"))
def qp_codec_frames(frames: jnp.ndarray, qp_blocks: jnp.ndarray, *,
                    bs: int = 512, interpret=None):
    """Fleet-batched fused encode+decode: frames (N, H, W), qp
    (N, H//8, W//8) -> (reconstructions (N, H, W), per-frame bits (N,)).

    All N frames' blocks are flattened into ONE kernel launch, so a whole
    fleet tick's codec work is a single device dispatch."""
    if interpret is None:
        interpret = not _on_tpu()
    N, H, W = frames.shape
    nby, nbx = H // 8, W // 8
    blocks = frames.reshape(N, nby, 8, nbx, 8).transpose(0, 1, 3, 2, 4)
    blocks = blocks.reshape(N * nby * nbx, 8, 8)
    rec, bits = qp_codec_blocks(blocks, qp_blocks.reshape(-1),
                                bs=bs, interpret=interpret)
    rec = rec.reshape(N, nby, nbx, 8, 8).transpose(0, 1, 3, 2, 4)
    rec = rec.reshape(N, H, W)
    return rec, bits.reshape(N, nby * nbx).sum(axis=1)


@functools.partial(jax.jit, static_argnames=("iters", "probe_stride", "bs",
                                             "interpret"))
def rate_controlled_codec_frames(frames: jnp.ndarray,
                                 qp_shapes: jnp.ndarray,
                                 target_bits: jnp.ndarray, *,
                                 iters: int = 8, probe_stride: int = 1,
                                 bs: int = 512, interpret=None):
    """Rate-controlled fused encode+decode for a DeViBench grid batch:
    the jnp bisection solves each row's QP offset against its own bits
    target, then ONE fused Pallas launch reconstructs every frame at the
    solved surfaces.

    frames (N, H, W), qp_shapes (N, H//8, W//8), target_bits (N,) ->
    (reconstructions (N, H, W), per-frame bits (N,)).  This is the
    DeViBench engine's `backend="kernel"` encode path (interpret mode
    off-TPU); it matches the jnp path to kernel tolerance, not bitwise
    (tests/test_devibench_engine.py)."""
    qp, _ = codec.rate_control_batch(frames, qp_shapes, target_bits,
                                     iters=iters,
                                     probe_stride=probe_stride)
    return qp_codec_frames(frames, qp, bs=bs, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("patch", "mu", "q_min",
                                             "q_max", "iters", "interpret"))
def zeco_codec_frames(frames: jnp.ndarray, boxes: jnp.ndarray,
                      counts: jnp.ndarray, engaged: jnp.ndarray,
                      target_bits: jnp.ndarray, *, patch: int = 64,
                      mu: float = 0.5, q_min: float = float(QP_MIN),
                      q_max: float = float(QP_MAX), iters: int = 8,
                      interpret=None):
    """Fleet-batched FUSED context-aware encode: the kernel takes the
    ZeCoStream box arrays directly and runs importance (Eq. 3) -> QP
    surface (Eq. 4) -> rate-control bisection -> DCT -> quantize -> rate
    -> reconstruction in one VMEM pass per frame — the QP surface never
    materializes in HBM.

    frames (N, H, W), boxes (N, B, 4), counts (N,), engaged (N,),
    target_bits (N,) -> (reconstructions (N, H, W), per-frame bits (N,)).
    Disengaged (or box-less) rows degenerate to uniform-QP rate control.

    This is the TPU encode path (validated in interpret mode by
    tests/test_kernels.py and timed in benchmarks/bench_kernels.py; the
    on-chip-surface claim is untested on real TPU hardware).  The fleet
    engine's `fused_plan` mode uses the jnp-level
    `zecostream.rate_control_batch_fused` instead — it yields the cached
    coefficients the partial-drop requantize path needs and supports
    `probe_stride`, which this kernel's exact bisection does not.
    """
    if interpret is None:
        interpret = not _on_tpu()
    N, H, W = frames.shape
    nby, nbx = H // 8, W // 8
    blocks = frames.reshape(N, nby, 8, nbx, 8).transpose(0, 1, 3, 2, 4)
    blocks = blocks.reshape(N, nby * nbx, 8, 8)
    meta = jnp.stack([counts.astype(jnp.float32),
                      engaged.astype(jnp.float32),
                      target_bits.astype(jnp.float32)], axis=1)
    rec, bits = zeco_rc_blocks(blocks, boxes, meta, frame_hw=(H, W),
                               patch=patch, mu=mu, q_min=q_min,
                               q_max=q_max, iters=iters,
                               interpret=interpret)
    rec = rec.reshape(N, nby, nbx, 8, 8).transpose(0, 1, 3, 2, 4)
    return rec.reshape(N, H, W), bits.sum(axis=1)

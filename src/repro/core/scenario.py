"""Declarative scenario API: workload specs -> auto-partitioned cohorts.

Every entry point used to hand-assemble `FleetSession` lists and was
bound by `Fleet.__init__`'s homogeneity rule (same fps / duration /
frame size / rc_probe_stride across members).  This module moves that
restriction out of the user-facing API and into an internal
partitioning step:

    ScenarioSpec         one session's workload as pure data (system
                         variant, CC, trace family + seed, scene
                         category, fps/duration/frame size, ABR/ZeCo
                         knobs, QA policy) — frozen, hashable,
                         JSON-serializable.
    preset()/grid()      a registry of named base specs plus a
                         cartesian-product expander over spec fields.
    compile_cohorts()    groups specs into cohorts of fleet-compatible
                         sessions (same fps, duration, frame size,
                         probe stride, trace dt).
    run_scenarios()      materializes each spec into a FleetSession,
                         runs every cohort as one `Fleet`, and
                         reassembles a `RunResult` (per-session metrics
                         as stacked arrays + spec tags, JSON/CSV
                         export, aggregation helpers) in input order.

A mixed-shape grid (several frame sizes x several fps) therefore runs
in a single `run_scenarios` call, and each cohort reproduces a direct
`Fleet` over the same sessions bit for bit (tests/test_scenario.py).
`Fleet`/`FleetSession` remain the lower layer for code that needs
manual control; `repro.api` is the thin public facade.
"""
from __future__ import annotations

import csv
import dataclasses
import io
import itertools
import json
from typing import (Any, Callable, Dict, Iterable, List, Optional, Sequence,
                    Tuple, Union)

import numpy as np

from repro.core.fleet import Fleet, FleetSession
from repro.core.session import QASample, SessionConfig, SessionMetrics
from repro.devibench.engine import (DEGRADATION_KINDS, DegradationSpec,
                                    GridResult)
from repro.net import traces as trace_lib
from repro.video.scenes import Scene, make_scene

# --------------------------------------------------------------------------
# Frozen-kwargs plumbing: spec extension fields are tuples of (key, value)
# pairs so ScenarioSpec stays hashable; dicts/lists are accepted at
# construction and frozen automatically.
# --------------------------------------------------------------------------
FrozenKwargs = Tuple[Tuple[str, Any], ...]
_KWARGS_FIELDS = ("trace_kwargs", "scene_kwargs", "qa_kwargs",
                  "session_kwargs", "degradation_kwargs", "engine_kwargs",
                  "churn_kwargs")


def _freeze(value, top: bool = True) -> Any:
    if isinstance(value, dict):
        if not top:
            # _thaw cannot tell a frozen dict from a tuple of pairs, so
            # nesting would come back corrupted — fail loudly instead
            raise ValueError("nested dicts in *_kwargs are not supported; "
                             "flatten the value or add a spec field")
        return tuple((k, _freeze(v, top=False)) for k, v in value.items())
    if isinstance(value, (list, tuple)):
        return tuple(_freeze(v, top=False) for v in value)
    return value


def _thaw(kwargs: FrozenKwargs) -> Dict[str, Any]:
    return {k: (list(v) if isinstance(v, tuple) else v) for k, v in kwargs}


# --------------------------------------------------------------------------
# System variants (paper §7 baselines)
# --------------------------------------------------------------------------
SYSTEMS: Dict[str, Dict[str, bool]] = {
    "webrtc": dict(use_recap=False, use_zeco=False),
    "webrtc+recap": dict(use_recap=True, use_zeco=False),
    "webrtc+zeco": dict(use_recap=False, use_zeco=True),
    "artic": dict(use_recap=True, use_zeco=True),
}


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """One session's workload as pure data.

    Everything the old call sites passed positionally into
    `make_scene` / trace factories / `SessionConfig` lives here as a
    named, comparable field; `with_(**overrides)` derives variants and
    `grid()` expands axes of them.  Extension knobs that are not worth
    first-class fields ride in the `*_kwargs` tuples (frozen dicts)."""
    # system variant + congestion control
    system: str = "artic"             # key into SYSTEMS
    cc_kind: str = "gcc"              # gcc | bbr
    # scene (content)
    scene: str = "retail"             # category, see video.scenes
    moving: bool = False
    scene_seed: int = 0
    frame_h: int = 256
    frame_w: int = 256
    code_period_frames: Optional[int] = None
    scene_kwargs: FrozenKwargs = ()   # extra make_scene kwargs (n_frames…)
    # trace (network)
    trace: str = "fluctuating"        # key into TRACE_FAMILIES
    trace_seed: int = 0
    trace_kwargs: FrozenKwargs = ()   # family kwargs (mbps, levels_kbps…)
    # timing
    fps: float = 10.0
    duration: float = 40.0
    # ABR / ZeCoStream knobs
    tau: float = 0.8
    gamma: float = 2.0
    rc_probe_stride: int = 1
    seed: int = 0                     # SessionConfig seed
    session_kwargs: FrozenKwargs = () # extra SessionConfig kwargs
    # conversational QA policy
    qa: str = "none"                  # key into QA_POLICIES
    qa_kwargs: FrozenKwargs = ()
    # server peer: "oracle" (bit-exact glyph lookup, the default) or
    # "engine" (the continuous-batching MLLM engine via serving.bridge)
    server: str = "oracle"
    engine_kwargs: FrozenKwargs = ()  # EngineServerBridge knobs
    # DeViBench degradation dimension (run_devibench workloads; must
    # stay "none" on the RTC fleet path)
    degradation: str = "none"         # key into engine.DEGRADATION_KINDS
    degradation_kwargs: FrozenKwargs = ()  # kbps / loss / stall_frames…
    # workload shape: "fixed" runs the spec as one session to
    # completion; "churn" treats it as the base population of an
    # open-loop arrival/departure process (repro.core.churn)
    workload: str = "fixed"
    churn_kwargs: FrozenKwargs = ()   # ChurnConfig knobs (rate, slots…)
    # free-form label carried through to RunResult tags
    tag: str = ""

    def __post_init__(self):
        if self.system not in SYSTEMS:
            raise ValueError(f"unknown system {self.system!r}; "
                             f"one of {sorted(SYSTEMS)}")
        if self.degradation not in DEGRADATION_KINDS:
            raise ValueError(f"unknown degradation {self.degradation!r}; "
                             f"one of {DEGRADATION_KINDS}")
        if self.server not in ("oracle", "engine"):
            raise ValueError(f"unknown server {self.server!r}; "
                             "one of ('oracle', 'engine')")
        if self.workload not in ("fixed", "churn"):
            raise ValueError(f"unknown workload {self.workload!r}; "
                             "one of ('fixed', 'churn')")
        if self.churn_kwargs and self.workload != "churn":
            raise ValueError("churn_kwargs requires workload='churn'")
        for f in _KWARGS_FIELDS:
            # accept dicts (or pair lists) and freeze them for hashing
            object.__setattr__(self, f, _freeze(dict(getattr(self, f))))

    # -- derivation ----------------------------------------------------
    def with_(self, **overrides) -> "ScenarioSpec":
        """Functional update; dict values for `*_kwargs` are frozen."""
        return dataclasses.replace(self, **overrides)

    # -- views ---------------------------------------------------------
    @property
    def flags(self) -> Dict[str, bool]:
        return dict(SYSTEMS[self.system])

    @property
    def frame_hw(self) -> Tuple[int, int]:
        return (self.frame_h, self.frame_w)

    def degradation_spec(self) -> DegradationSpec:
        """The spec's degradation dimension as an engine DegradationSpec
        (kind 'none' is the pristine reference cell)."""
        return DegradationSpec(kind=self.degradation,
                               **_thaw(self.degradation_kwargs))

    def session_config(self) -> SessionConfig:
        return SessionConfig(fps=self.fps, duration=self.duration,
                             cc_kind=self.cc_kind, tau=self.tau,
                             gamma=self.gamma,
                             rc_probe_stride=self.rc_probe_stride,
                             seed=self.seed, **self.flags,
                             **_thaw(self.session_kwargs))

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        for f in _KWARGS_FIELDS:
            d[f] = _thaw(d[f])
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ScenarioSpec":
        return cls(**d)


# --------------------------------------------------------------------------
# Trace families, QA policies, presets — three small registries
# --------------------------------------------------------------------------
def _mobility(kind: str):
    def make(duration: float, seed: int, **kw) -> trace_lib.Trace:
        return trace_lib.mobility_trace(kind, duration, seed=seed, **kw)
    return make


TRACE_FAMILIES: Dict[str, Callable[..., trace_lib.Trace]] = {
    "static": lambda duration, seed, **kw:
        trace_lib.static_trace(duration, seed=seed, **kw),
    "fluctuating": lambda duration, seed, **kw:
        trace_lib.fluctuating_trace(duration, seed=seed, **kw),
    "mobility.walking": _mobility("walking"),
    "mobility.driving": _mobility("driving"),
    "elevator": lambda duration, seed, **kw:
        trace_lib.elevator_trace(duration, seed=seed, **kw),
}


def _qa_none(scene: Scene, spec: ScenarioSpec) -> List[QASample]:
    return []


def _qa_epoch(scene: Scene, spec: ScenarioSpec) -> List[QASample]:
    """One question shortly after each content epoch begins — the user
    asks about what just appeared (§4.1 'newly appeared content'),
    giving every system the same runway within the epoch."""
    period = scene.code_period_frames / spec.fps
    out, i = [], 0
    t = period + 0.5
    while t < spec.duration * 0.95:
        out.append(QASample(t_ask=float(t),
                            obj_idx=i % len(scene.objects),
                            answer_window=min(4.0, period - 0.6)))
        i += 1
        t += period
    return out


def _qa_periodic(scene: Scene, spec: ScenarioSpec, *, start: float = 4.5,
                 period: float = 4.0, answer_window: float = 3.4,
                 count: Optional[int] = None) -> List[QASample]:
    """Fixed-cadence questions cycling over the scene's objects."""
    if count is None:
        count = int(spec.duration / period) - 2
    return [QASample(t_ask=start + period * i,
                     obj_idx=i % len(scene.objects),
                     answer_window=answer_window)
            for i in range(count)]


def _qa_devibench(scene: Scene, spec: ScenarioSpec, **kw) -> List[QASample]:
    raise ValueError(
        "qa='devibench' specs evaluate offline QA grids, not live fleet "
        "sessions — run them through run_devibench() / "
        "run_scenarios(..., workload='devibench')")


QA_POLICIES: Dict[str, Callable[..., List[QASample]]] = {
    "none": _qa_none,
    "epoch": _qa_epoch,
    "periodic": _qa_periodic,
    "devibench": _qa_devibench,
}

# Named base specs.  These replace the trace/scene/QA setup helpers that
# were copy-pasted across benchmarks/bench_*.py.
PRESETS: Dict[str, ScenarioSpec] = {}


def register_preset(name: str, spec: ScenarioSpec,
                    overwrite: bool = False) -> ScenarioSpec:
    if name in PRESETS and not overwrite:
        raise ValueError(f"preset {name!r} already registered")
    PRESETS[name] = spec
    return spec


def preset(name: str) -> ScenarioSpec:
    try:
        return PRESETS[name]
    except KeyError:
        raise KeyError(f"unknown preset {name!r}; "
                       f"one of {sorted(PRESETS)}") from None


register_preset("artic", ScenarioSpec())
register_preset("webrtc", ScenarioSpec(system="webrtc"))
# Fig. 13 cell: epoch-locked QA on a 4 s code period (bench_e2e)
register_preset("fig13", ScenarioSpec(code_period_frames=40, qa="epoch"))
# thumbnail-tier fleet member for throughput benchmarks (bench_fleet)
register_preset("fleet-thumb", ScenarioSpec(
    scene="lawn", frame_h=64, frame_w=64, code_period_frames=40,
    trace="fluctuating",
    trace_kwargs=dict(switches_per_min=6, levels_kbps=[1710, 1130, 710]),
    rc_probe_stride=2))
# starved uplink so ZeCoStream engages (bench_zecostream)
register_preset("zeco-starved", ScenarioSpec(
    system="webrtc+zeco", code_period_frames=40,
    trace="static", trace_kwargs=dict(mbps=0.35)))
# tiny DeViBench cell: a quick-build benchmark (12 scenes, 20 frames)
# evaluated at the high-bitrate reference; expand the degradation axis
# with grid("devibench", degradation=[...], degradation_kwargs=[...])
register_preset("devibench", ScenarioSpec(
    qa="devibench",
    qa_kwargs=dict(n_scenes_per_cat=1, questions_per_obj=2, n_frames=20),
    degradation="bitrate", degradation_kwargs=dict(kbps=4000.0)))


# --------------------------------------------------------------------------
# Grid expansion
# --------------------------------------------------------------------------
def grid(base: Union[ScenarioSpec, str, None] = None,
         **axes) -> List[ScenarioSpec]:
    """Cartesian product over spec fields.

    >>> grid("fig13", system=["webrtc", "artic"], cc_kind=["gcc", "bbr"])

    Each axis value may be a list/tuple (expanded) or a scalar (applied
    to every point).  The first axis varies slowest, so the output order
    matches nested for-loops in the given keyword order."""
    if isinstance(base, str):
        base = preset(base)
    base = base or ScenarioSpec()
    keys = list(axes)
    lists = [v if isinstance(v, (list, tuple, range)) else [v]
             for v in axes.values()]
    return [base.with_(**dict(zip(keys, combo)))
            for combo in itertools.product(*lists)]


# --------------------------------------------------------------------------
# Materialization: spec -> FleetSession
# --------------------------------------------------------------------------
def build_session(spec: ScenarioSpec, calibrator=None) -> FleetSession:
    """Materialize one spec into the lower-layer `FleetSession`."""
    scene = make_scene(spec.scene, spec.moving, seed=spec.scene_seed,
                       h=spec.frame_h, w=spec.frame_w,
                       code_period_frames=spec.code_period_frames,
                       **_thaw(spec.scene_kwargs))
    try:
        trace_factory = TRACE_FAMILIES[spec.trace]
    except KeyError:
        raise KeyError(f"unknown trace family {spec.trace!r}; "
                       f"one of {sorted(TRACE_FAMILIES)}") from None
    trace = trace_factory(spec.duration, spec.trace_seed,
                          **_thaw(spec.trace_kwargs))
    try:
        qa_policy = QA_POLICIES[spec.qa]
    except KeyError:
        raise KeyError(f"unknown QA policy {spec.qa!r}; "
                       f"one of {sorted(QA_POLICIES)}") from None
    qa = qa_policy(scene, spec, **_thaw(spec.qa_kwargs))
    return FleetSession(scene=scene, qa_samples=qa, trace=trace,
                        cfg=spec.session_config(), calibrator=calibrator)


# --------------------------------------------------------------------------
# Cohort compilation: the homogeneity rule, internalized
# --------------------------------------------------------------------------
def cohort_key(spec: ScenarioSpec) -> Tuple:
    """Fleet-compatibility key: sessions sharing it may run as one
    `Fleet` (same frame clock, frame size, probe stride and trace time
    step — everything `Fleet.__init__`/`TraceBank.stack` require)."""
    trace_dt = dict(spec.trace_kwargs).get("dt",
                                           trace_lib.DEFAULT_TRACE_DT)
    return (spec.fps, spec.duration, spec.frame_h, spec.frame_w,
            spec.rc_probe_stride, trace_dt, spec.server,
            spec.engine_kwargs)


@dataclasses.dataclass(frozen=True)
class Cohort:
    """A fleet-compatible group of scenario indices (into the input
    spec list), in input order."""
    key: Tuple
    indices: Tuple[int, ...]

    def to_dict(self) -> Dict[str, Any]:
        fps, duration, h, w, stride, dt, server, engine_kwargs = self.key
        return {"fps": fps, "duration": duration, "frame_h": h,
                "frame_w": w, "rc_probe_stride": stride, "trace_dt": dt,
                "server": server, "engine_kwargs": _thaw(engine_kwargs),
                "sessions": list(self.indices)}


def compile_cohorts(specs: Sequence[ScenarioSpec]) -> List[Cohort]:
    """Partition specs into cohorts, ordered by first occurrence."""
    groups: Dict[Tuple, List[int]] = {}
    for i, s in enumerate(specs):
        groups.setdefault(cohort_key(s), []).append(i)
    return [Cohort(key=k, indices=tuple(idx)) for k, idx in groups.items()]


def build_fleet(specs: Sequence[ScenarioSpec], calibrator=None,
                **fleet_kwargs) -> Fleet:
    """Materialize a single-cohort spec list into one `Fleet`.

    For callers (benchmarks) that need the Fleet object itself — e.g. to
    time `.run()` apart from construction.  Raises if the specs span
    more than one cohort; use `run_scenarios` for mixed grids."""
    cohorts = compile_cohorts(specs)
    if len(cohorts) != 1:
        raise ValueError(
            f"specs span {len(cohorts)} cohorts "
            f"{[c.key for c in cohorts]}; build_fleet needs exactly one "
            "(run_scenarios handles mixed grids)")
    return Fleet([build_session(s, calibrator) for s in specs],
                 **fleet_kwargs)


# --------------------------------------------------------------------------
# RunResult: stacked metrics + tags, export, aggregation
# --------------------------------------------------------------------------
RUN_RESULT_SCHEMA = "artic.scenario.run_result/v1"

# scalar per-session metrics stacked into (N,) arrays
SCALAR_METRICS = ("accuracy", "avg_latency_ms", "p95_latency_ms",
                  "avg_bitrate", "bandwidth_used", "n_qa",
                  "dropped_frames", "zeco_engaged_frames")

# server-peer telemetry columns: populated under server="engine" (NaN
# under the default oracle — an oracle row has no engine telemetry, and
# NaN keeps it distinguishable from a real zero-latency measurement).
# Kept out of SCALAR_METRICS so the committed golden files — exported
# before the serving bridge existed — stay schema-valid; exports carry
# both sets.
SERVING_METRICS = ("ttft_p50_ms", "ttft_p95_ms", "ttft_p99_ms",
                   "queue_p50_ms", "queue_p95_ms", "queue_p99_ms",
                   # context-overflow counters: sink+recent evictions vs
                   # legacy rollovers (0/0/0 on oracle rows).  Also
                   # outside SCALAR_METRICS, so pre-eviction goldens and
                   # validate_run_result_json stay untouched.
                   "server_evictions", "server_evicted_tokens",
                   "server_rollovers")


@dataclasses.dataclass
class RunResult:
    """Structured output of `run_scenarios`, in input order.

    `metrics[i]` is the full `SessionMetrics` of `specs[i]`; the scalar
    fields are also stacked into (N,) arrays (`values`, `arrays`) keyed
    by the spec's fields as tags for selection and aggregation."""
    specs: List[ScenarioSpec]
    metrics: List[SessionMetrics]
    cohorts: List[Cohort]
    phase_times: Optional[List[Dict[str, float]]] = None  # per cohort

    def __len__(self) -> int:
        return len(self.specs)

    # -- stacked arrays ------------------------------------------------
    def values(self, field: str) -> np.ndarray:
        return np.asarray([getattr(m, field) for m in self.metrics])

    def arrays(self) -> Dict[str, np.ndarray]:
        return {f: self.values(f) for f in SCALAR_METRICS}

    # -- tag-based selection / aggregation -----------------------------
    def select(self, **where) -> "RunResult":
        """Subset by spec-field equality, e.g. select(system='artic').

        `phase_times` is not carried over: it is keyed to the original
        run's cohorts, which a subset no longer describes."""
        keep = [i for i, s in enumerate(self.specs)
                if all(getattr(s, k) == v for k, v in where.items())]
        sub_specs = [self.specs[i] for i in keep]
        return RunResult(specs=sub_specs,
                         metrics=[self.metrics[i] for i in keep],
                         cohorts=compile_cohorts(sub_specs))

    def aggregate(self, by: Sequence[str],
                  fields: Sequence[str] = ("accuracy", "avg_latency_ms"),
                  reduce=np.mean) -> Dict[Tuple, Dict[str, float]]:
        """Group sessions by spec fields, reduce each metric per group.

        Returns {group-key-tuple: {field: reduced value}}, groups in
        first-occurrence order."""
        out: Dict[Tuple, Dict[str, List[float]]] = {}
        for s, m in zip(self.specs, self.metrics):
            key = tuple(getattr(s, k) for k in by)
            acc = out.setdefault(key, {f: [] for f in fields})
            for f in fields:
                acc[f].append(getattr(m, f))
        return {k: {f: float(reduce(v[f])) for f in fields}
                for k, v in out.items()}

    # -- export --------------------------------------------------------
    def to_json(self, path: Optional[str] = None,
                include_series: bool = False) -> Dict[str, Any]:
        """Schema-stable dict (optionally written to `path`).

        `include_series=True` adds the per-frame latency/rate/confidence
        series; the default keeps the export compact."""
        scenarios = []
        cohort_of = {i: ci for ci, c in enumerate(self.cohorts)
                     for i in c.indices}
        for i, (s, m) in enumerate(zip(self.specs, self.metrics)):
            rec = {"spec": s.to_dict(),
                   "cohort": cohort_of[i],
                   "metrics": {f: float(getattr(m, f))
                               for f in SCALAR_METRICS + SERVING_METRICS}}
            rec["metrics"]["qa_results"] = [bool(b) for b in m.qa_results]
            if include_series:
                rec["series"] = {
                    "latencies": [float(v) for v in m.latencies],
                    "rates": [float(v) for v in m.rates],
                    "confidences": [float(v) for v in m.confidences]}
            scenarios.append(rec)
        doc = {"schema": RUN_RESULT_SCHEMA,
               "n_scenarios": len(self.specs),
               "scenarios": scenarios,
               "cohorts": [c.to_dict() for c in self.cohorts]}
        if path is not None:
            with open(path, "w") as f:
                json.dump(doc, f, indent=1, default=_json_default)
        return doc

    def to_csv(self, path: Optional[str] = None) -> str:
        """One row per scenario: spec fields + scalar metrics."""
        spec_fields = [f.name for f in dataclasses.fields(ScenarioSpec)
                       if f.name not in _KWARGS_FIELDS]
        buf = io.StringIO()
        w = csv.writer(buf)
        w.writerow(spec_fields + list(SCALAR_METRICS + SERVING_METRICS))
        for s, m in zip(self.specs, self.metrics):
            w.writerow([getattr(s, f) for f in spec_fields]
                       + [getattr(m, f)
                          for f in SCALAR_METRICS + SERVING_METRICS])
        text = buf.getvalue()
        if path is not None:
            with open(path, "w") as f:
                f.write(text)
        return text


def _json_default(o):
    if isinstance(o, (np.integer,)):
        return int(o)
    if isinstance(o, (np.floating,)):
        return float(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    raise TypeError(f"not JSON-serializable: {type(o)}")


def validate_run_result_json(doc: Dict[str, Any]) -> None:
    """Raise ValueError unless `doc` matches RUN_RESULT_SCHEMA.

    Checked by the CI smoke job; keep in sync with `to_json`."""
    def need(cond, msg):
        if not cond:
            raise ValueError(f"run_result schema violation: {msg}")

    need(doc.get("schema") == RUN_RESULT_SCHEMA,
         f"schema tag {doc.get('schema')!r} != {RUN_RESULT_SCHEMA!r}")
    scen = doc.get("scenarios")
    need(isinstance(scen, list) and len(scen) == doc.get("n_scenarios"),
         "scenarios list missing or length != n_scenarios")
    cohorts = doc.get("cohorts")
    need(isinstance(cohorts, list) and cohorts, "cohorts missing")
    seen = []
    for c in cohorts:
        for k in ("fps", "duration", "frame_h", "frame_w",
                  "rc_probe_stride", "trace_dt", "sessions"):
            need(k in c, f"cohort missing key {k!r}")
        seen.extend(c["sessions"])
    need(sorted(seen) == list(range(len(scen))),
         "cohorts do not partition the scenario indices")
    for i, rec in enumerate(scen):
        need(isinstance(rec.get("spec"), dict), f"scenario {i}: no spec")
        ScenarioSpec.from_dict(rec["spec"])  # round-trips
        need(rec.get("cohort") in range(len(cohorts)),
             f"scenario {i}: bad cohort index")
        need(i in cohorts[rec["cohort"]]["sessions"],
             f"scenario {i}: not listed in its cohort")
        m = rec.get("metrics")
        need(isinstance(m, dict), f"scenario {i}: no metrics")
        for f in SCALAR_METRICS:
            need(isinstance(m.get(f), (int, float)),
                 f"scenario {i}: metric {f!r} missing or non-numeric")
        need(isinstance(m.get("qa_results"), list),
             f"scenario {i}: qa_results missing")


# --------------------------------------------------------------------------
# DeViBench workloads: offline degradation grids through the same spec API
# --------------------------------------------------------------------------
DEVIBENCH_RESULT_SCHEMA = "artic.devibench.run_result/v1"

# scalar per-scenario metrics stacked into (N,) arrays
DEVIBENCH_SCALAR_METRICS = ("accuracy", "n_records", "refuse_rate",
                            "margin_mean")


def devibench_key(spec: ScenarioSpec) -> Tuple:
    """Benchmark-compatibility key: specs sharing it evaluate against
    one `devibench.generate` build (same corpus seed, frame geometry,
    frame rate and generation knobs) and differ only along the
    degradation axis of one stacked grid."""
    return (spec.seed, spec.frame_h, spec.frame_w, spec.fps,
            spec.qa_kwargs)


@dataclasses.dataclass(frozen=True)
class DeViBenchCohort:
    """Scenario indices (into the input spec list) sharing one
    benchmark build, in input order."""
    key: Tuple
    indices: Tuple[int, ...]
    n_records: int

    def to_dict(self) -> Dict[str, Any]:
        seed, h, w, fps, qa_kwargs = self.key
        return {"seed": seed, "frame_h": h, "frame_w": w, "fps": fps,
                "generate_kwargs": _thaw(qa_kwargs),
                "n_records": self.n_records,
                "sessions": list(self.indices)}


@dataclasses.dataclass
class DeViBenchRunResult:
    """Structured output of `run_devibench`, in input order.

    Scenario `i` evaluated as column `columns[i][1]` of the stacked
    `GridResult` of cohort `columns[i][0]` — the per-record margins /
    correctness stay available as arrays, which is what
    `fit_confidence_calibrator` and `fit_recap` consume (no per-record
    Python loop anywhere downstream of the grid)."""
    specs: List[ScenarioSpec]
    cohorts: List[DeViBenchCohort]
    grids: List[GridResult]            # one stacked grid per cohort
    columns: List[Tuple[int, int]]     # spec i -> (cohort, grid column)
    split: str = "test"

    def __len__(self) -> int:
        return len(self.specs)

    # -- stacked arrays ------------------------------------------------
    def record_margins(self, i: int) -> np.ndarray:
        ci, col = self.columns[i]
        return self.grids[ci].margins[:, col]

    def record_correct(self, i: int) -> np.ndarray:
        ci, col = self.columns[i]
        return self.grids[ci].correct[:, col]

    def values(self, field: str) -> np.ndarray:
        if field == "accuracy":
            return np.asarray([self.record_correct(i).mean()
                               for i in range(len(self))])
        if field == "n_records":
            return np.asarray([self.cohorts[self.columns[i][0]].n_records
                               for i in range(len(self))])
        if field == "refuse_rate":
            return np.asarray(
                [self.grids[ci].refuse_rate()[col]
                 for ci, col in self.columns])
        if field == "margin_mean":
            return np.asarray([self.record_margins(i).mean()
                               for i in range(len(self))])
        raise KeyError(f"unknown metric {field!r}; "
                       f"one of {DEVIBENCH_SCALAR_METRICS}")

    def arrays(self) -> Dict[str, np.ndarray]:
        return {f: self.values(f) for f in DEVIBENCH_SCALAR_METRICS}

    def stacked_margins(self) -> Tuple[np.ndarray, np.ndarray]:
        """(scores, correct) concatenated over every scenario column —
        the calibrator's training arrays, spec-major order."""
        scores = np.concatenate([self.record_margins(i)
                                 for i in range(len(self))])
        correct = np.concatenate([self.record_correct(i)
                                  for i in range(len(self))])
        return scores, correct

    # -- tag-based selection / aggregation -----------------------------
    def _subset(self, keep: List[int]) -> "DeViBenchRunResult":
        sub_specs = [self.specs[i] for i in keep]
        cohort_map: Dict[int, int] = {}
        cohorts: List[DeViBenchCohort] = []
        columns: List[Tuple[int, int]] = []
        grids: List[GridResult] = []
        by_cohort: Dict[int, List[int]] = {}
        for new_i, i in enumerate(keep):
            ci, col = self.columns[i]
            if ci not in cohort_map:
                cohort_map[ci] = len(cohorts)
                cohorts.append(dataclasses.replace(self.cohorts[ci],
                                                   indices=()))
                grids.append(self.grids[ci])
            by_cohort.setdefault(cohort_map[ci], []).append(new_i)
            columns.append((cohort_map[ci], col))
        cohorts = [dataclasses.replace(c, indices=tuple(by_cohort[ci]))
                   for ci, c in enumerate(cohorts)]
        return DeViBenchRunResult(specs=sub_specs, cohorts=cohorts,
                                  grids=grids, columns=columns,
                                  split=self.split)

    def select(self, **where) -> "DeViBenchRunResult":
        """Subset by spec-field equality, e.g. select(degradation='drop')."""
        keep = [i for i, s in enumerate(self.specs)
                if all(getattr(s, k) == v for k, v in where.items())]
        return self._subset(keep)

    def aggregate(self, by: Sequence[str],
                  fields: Sequence[str] = ("accuracy",),
                  reduce=np.mean) -> Dict[Tuple, Dict[str, float]]:
        """Group scenarios by spec fields, reduce each metric per group
        (first-occurrence group order, mirroring `RunResult.aggregate`)."""
        vals = {f: self.values(f) for f in fields}
        out: Dict[Tuple, Dict[str, List[float]]] = {}
        for i, s in enumerate(self.specs):
            key = tuple(getattr(s, k) for k in by)
            acc = out.setdefault(key, {f: [] for f in fields})
            for f in fields:
                acc[f].append(vals[f][i])
        return {k: {f: float(reduce(v[f])) for f in fields}
                for k, v in out.items()}

    # -- the benchmark -> saturation point -> ABR cap loop -------------
    def saturation_curve(self, **where) -> Tuple[np.ndarray, np.ndarray]:
        """(kbps, accuracy) over the bitrate-kind scenarios (optionally
        filtered by spec fields), sorted by bitrate — Fig. 3."""
        sub = self.select(degradation="bitrate", **where)
        if not len(sub):
            raise ValueError("no degradation='bitrate' scenarios to "
                             "build a saturation curve from")
        kbps = np.asarray([s.degradation_spec().kbps for s in sub.specs])
        acc = sub.values("accuracy")
        order = np.argsort(kbps, kind="stable")
        return kbps[order], acc[order]

    def fit_calibrator(self):
        """Platt calibrator fit on the stacked margin/correct arrays."""
        from repro.core.confidence import PlattCalibrator
        return PlattCalibrator().fit(*self.stacked_margins())

    def fit_recap(self, *, calibrator=None, min_rate: float = 150e3,
                  frac: float = 0.95, **where) -> Dict[str, float]:
        """Close the paper's loop: saturation curve -> knee -> (tau,
        gamma, bitrate cap) for ReCap-ABR, all from the stacked arrays."""
        from repro.core.recap_abr import fit_recap_params
        sub = self.select(degradation="bitrate", **where)
        if not len(sub):
            raise ValueError("no degradation='bitrate' scenarios to "
                             "fit ReCap-ABR from")
        # one stable order for all three curves, so tied-kbps rungs
        # (e.g. the same ladder over two cohorts) stay paired
        kbps = np.asarray([s.degradation_spec().kbps for s in sub.specs])
        order = np.argsort(kbps, kind="stable")
        acc = sub.values("accuracy")[order]
        cal = calibrator if calibrator is not None else self.fit_calibrator()
        conf = np.asarray([cal.batch(sub.record_margins(int(i))).mean()
                           for i in order])
        return fit_recap_params(kbps[order], conf, accuracy=acc,
                                min_rate=min_rate, frac=frac)

    # -- export --------------------------------------------------------
    def to_json(self, path: Optional[str] = None) -> Dict[str, Any]:
        """Schema-stable dict (optionally written to `path`)."""
        scenarios = []
        vals = self.arrays()
        for i, s in enumerate(self.specs):
            ci, col = self.columns[i]
            d = s.degradation_spec()
            scenarios.append(
                {"spec": s.to_dict(), "cohort": ci,
                 "degradation": {**d.to_dict(), "label": d.label},
                 "metrics": {f: float(vals[f][i])
                             for f in DEVIBENCH_SCALAR_METRICS}})
        doc = {"schema": DEVIBENCH_RESULT_SCHEMA,
               "split": self.split,
               "n_scenarios": len(self.specs),
               "scenarios": scenarios,
               "cohorts": [c.to_dict() for c in self.cohorts]}
        if path is not None:
            with open(path, "w") as f:
                json.dump(doc, f, indent=1, default=_json_default)
        return doc

    def to_csv(self, path: Optional[str] = None) -> str:
        """One row per scenario: spec fields + degradation + metrics."""
        spec_fields = [f.name for f in dataclasses.fields(ScenarioSpec)
                       if f.name not in _KWARGS_FIELDS]
        degr_fields = ["degradation_label", "kbps", "loss",
                       "stall_frames", "scale"]
        buf = io.StringIO()
        w = csv.writer(buf)
        w.writerow(spec_fields + degr_fields
                   + list(DEVIBENCH_SCALAR_METRICS))
        vals = self.arrays()
        for i, s in enumerate(self.specs):
            d = s.degradation_spec()
            w.writerow([getattr(s, f) for f in spec_fields]
                       + [d.label, d.kbps, d.loss, d.stall_frames,
                          d.scale]
                       + [vals[f][i] for f in DEVIBENCH_SCALAR_METRICS])
        text = buf.getvalue()
        if path is not None:
            with open(path, "w") as f:
                f.write(text)
        return text


def validate_devibench_json(doc: Dict[str, Any]) -> None:
    """Raise ValueError unless `doc` matches DEVIBENCH_RESULT_SCHEMA.

    Checked by the CI devibench-smoke job; keep in sync with
    `DeViBenchRunResult.to_json`."""
    def need(cond, msg):
        if not cond:
            raise ValueError(f"devibench run_result schema violation: {msg}")

    need(doc.get("schema") == DEVIBENCH_RESULT_SCHEMA,
         f"schema tag {doc.get('schema')!r} != {DEVIBENCH_RESULT_SCHEMA!r}")
    need(doc.get("split") in ("test", "validation", "all"),
         f"bad split {doc.get('split')!r}")
    scen = doc.get("scenarios")
    need(isinstance(scen, list) and len(scen) == doc.get("n_scenarios"),
         "scenarios list missing or length != n_scenarios")
    cohorts = doc.get("cohorts")
    need(isinstance(cohorts, list) and cohorts, "cohorts missing")
    seen = []
    for c in cohorts:
        for k in ("seed", "frame_h", "frame_w", "fps", "generate_kwargs",
                  "n_records", "sessions"):
            need(k in c, f"cohort missing key {k!r}")
        seen.extend(c["sessions"])
    need(sorted(seen) == list(range(len(scen))),
         "cohorts do not partition the scenario indices")
    for i, rec in enumerate(scen):
        need(isinstance(rec.get("spec"), dict), f"scenario {i}: no spec")
        spec = ScenarioSpec.from_dict(rec["spec"])  # round-trips
        need(spec.qa == "devibench",
             f"scenario {i}: qa policy is not 'devibench'")
        need(rec.get("cohort") in range(len(cohorts)),
             f"scenario {i}: bad cohort index")
        need(i in cohorts[rec["cohort"]]["sessions"],
             f"scenario {i}: not listed in its cohort")
        d = rec.get("degradation")
        need(isinstance(d, dict) and "label" in d,
             f"scenario {i}: degradation block missing")
        DegradationSpec.from_dict(
            {k: v for k, v in d.items() if k != "label"})  # round-trips
        m = rec.get("metrics")
        need(isinstance(m, dict), f"scenario {i}: no metrics")
        for f in DEVIBENCH_SCALAR_METRICS:
            need(isinstance(m.get(f), (int, float)),
                 f"scenario {i}: metric {f!r} missing or non-numeric")
        need(0.0 <= m["accuracy"] <= 1.0,
             f"scenario {i}: accuracy out of [0, 1]")


def run_devibench(specs: Union[ScenarioSpec, str,
                               Iterable[Union[ScenarioSpec, str]]],
                  *, split: str = "test", margin_floor: float = 0.35,
                  backend: str = "jnp") -> DeViBenchRunResult:
    """Evaluate DeViBench degradation scenarios as stacked grids.

    Each spec names one degradation cell (`degradation` +
    `degradation_kwargs`) over a benchmark whose construction knobs ride
    in `qa_kwargs` (`n_scenes_per_cat`, `questions_per_obj`,
    `n_frames`).  Specs sharing `devibench_key` evaluate as ONE
    (record x degradation) grid through the vectorized engine — the
    benchmark is built once and every unique frame is encoded in
    batched dispatches."""
    from repro.devibench import pipeline as dvb

    if isinstance(specs, (ScenarioSpec, str)):
        specs = [specs]
    specs = [preset(s) if isinstance(s, str) else s for s in specs]
    if not specs:
        raise ValueError("run_devibench needs at least one spec")
    for i, s in enumerate(specs):
        if s.qa != "devibench":
            raise ValueError(
                f"spec {i} has qa={s.qa!r}; DeViBench scenarios must set "
                "qa='devibench' (generation knobs ride in qa_kwargs)")

    groups: Dict[Tuple, List[int]] = {}
    for i, s in enumerate(specs):
        groups.setdefault(devibench_key(s), []).append(i)

    cohorts: List[DeViBenchCohort] = []
    grids: List[GridResult] = []
    columns: List[Optional[Tuple[int, int]]] = [None] * len(specs)
    for key, indices in groups.items():
        first = specs[indices[0]]
        bench = dvb.generate(seed=first.seed, fps=first.fps,
                             frame_hw=first.frame_hw,
                             **_thaw(first.qa_kwargs))
        # dedupe identical degradation cells into shared grid columns
        degr: List[DegradationSpec] = []
        col_of: Dict[DegradationSpec, int] = {}
        for i in indices:
            d = specs[i].degradation_spec()
            if d not in col_of:
                col_of[d] = len(degr)
                degr.append(d)
        grid_res = dvb.evaluate(bench, degr, split=split, fps=first.fps,
                                margin_floor=margin_floor,
                                backend=backend)
        ci = len(cohorts)
        cohorts.append(DeViBenchCohort(key=key, indices=tuple(indices),
                                       n_records=grid_res.n_records))
        grids.append(grid_res)
        for i in indices:
            columns[i] = (ci, col_of[specs[i].degradation_spec()])
    return DeViBenchRunResult(specs=specs, cohorts=cohorts, grids=grids,
                              columns=columns, split=split)


# --------------------------------------------------------------------------
# The entry point
# --------------------------------------------------------------------------
def run_scenarios(specs: Union[ScenarioSpec, str,
                               Iterable[Union[ScenarioSpec, str]]],
                  *, calibrator=None, fused_plan: bool = False,
                  profile: bool = False, workload: str = "rtc",
                  split: str = "test", mesh=None
                  ) -> Union[RunResult, DeViBenchRunResult]:
    """Compile specs into cohorts, run each cohort as one `Fleet`, and
    return per-session metrics in input order.

    Accepts a single spec, a preset name, or any iterable mixing the
    two.  Sessions sharing a cohort advance in lockstep ticks with
    batched codec dispatches; the partitioning is an internal detail —
    a grid mixing frame sizes and frame rates is one call.

    `mesh=...` (e.g. `repro.launch.mesh.make_fleet_mesh()`) runs every
    cohort device-sharded over the mesh's `data` axis: each cohort's
    session batch is padded to the axis size with masked dead sessions
    and its tick dispatches shard_map over the devices.  Results are
    bit-identical to the unsharded run, in the same input order
    (tests/test_sharded_fleet.py).

    `workload="devibench"` routes the specs through `run_devibench`
    instead: offline degradation grids emitting a `DeViBenchRunResult`
    (`split` selects the benchmark split; `calibrator`/`fused_plan`/
    `profile`/`mesh` are fleet-only knobs)."""
    if workload == "devibench":
        return run_devibench(specs, split=split)
    if workload != "rtc":
        raise ValueError(f"unknown workload {workload!r}; "
                         "one of ('rtc', 'devibench')")
    if isinstance(specs, (ScenarioSpec, str)):
        specs = [specs]
    specs = [preset(s) if isinstance(s, str) else s for s in specs]
    if not specs:
        raise ValueError("run_scenarios needs at least one spec")
    churny = [s.workload == "churn" for s in specs]
    if any(churny):
        if not all(churny):
            raise ValueError(
                "churn and fixed workload specs cannot mix in one run; "
                "split them into separate run_scenarios calls")
        if mesh is not None:
            raise NotImplementedError(
                "workload='churn' does not compose with mesh sharding yet")
        from repro.core.churn import ChurnRunResult, run_churn
        return ChurnRunResult([run_churn(s, calibrator=calibrator,
                                         fused_plan=fused_plan)
                               for s in specs])
    for i, s in enumerate(specs):
        if s.degradation != "none":
            raise ValueError(
                f"spec {i} carries degradation={s.degradation!r}, which "
                "the RTC fleet path would silently ignore — run it with "
                "workload='devibench' (or run_devibench)")
    cohorts = compile_cohorts(specs)
    metrics: List[Optional[SessionMetrics]] = [None] * len(specs)
    phase_times: List[Dict[str, float]] = []
    for cohort in cohorts:
        # server mode and engine knobs are part of cohort_key, so every
        # member of a cohort agrees on them
        spec0 = specs[cohort.indices[0]]
        fleet = Fleet([build_session(specs[i], calibrator)
                       for i in cohort.indices],
                      fused_plan=fused_plan, profile=profile, mesh=mesh,
                      server=spec0.server,
                      engine_cfg=_thaw(spec0.engine_kwargs))
        for i, m in zip(cohort.indices, fleet.run()):
            metrics[i] = m
        if profile:
            phase_times.append(dict(fleet.phase_times))
    return RunResult(specs=specs, metrics=metrics, cohorts=cohorts,
                     phase_times=phase_times if profile else None)

"""ZeCoStream — Zero-overhead Context-aware Streaming (paper §5).

Eq. (3): per-patch contextual importance from the MLLM-fed-back boxes,
    rho_ij = max(0, 1 - d_ij / (mu * sqrt(W^2 + H^2)))
with d_ij the distance from the patch center to the nearest box (0 inside)
and mu = 0.5.

Eq. (4): non-linear QP map,
    Q_ij = Qmin + (Qmax - Qmin) * (1 - rho_ij)^2

Grounding-then-prediction (§5.2): feedback boxes are >= 1.2-1.5 s stale;
the server ships a short horizon of *predicted* boxes and the client picks
the one matching the current timestamp.

Trigger policy (§3): ZeCoStream engages only when the bitrate is below the
critical level where accuracy is at risk; otherwise uniform encoding
protects the background for visual memory.

Array box format
----------------
Feedback boxes travel as fixed-capacity stacked arrays rather than Python
lists, so a whole fleet's context state is a handful of ndarrays:

* one feedback packet (``TimedBoxes``) is ``times (K,) float64`` +
  ``boxes (K, B, 4) float32`` + ``counts (K,) int32``, where row k holds
  ``counts[k]`` valid boxes ``(y0, x0, y1, x1)`` in pixels and the
  remaining ``B - counts[k]`` rows are zero padding;
* ``ZeCoStreamBank`` stacks N sessions' latest packets into
  ``(N, K, B, 4)`` boxes + ``(N, K)`` counts + ``(N, K)`` times, with
  per-session trigger/hysteresis/engaged state as ``(N,)`` arrays.  K and
  B are capacities that grow (power-of-two) if a packet exceeds them —
  padding never changes results because distances of masked boxes are
  +inf under the Eq. 3 min.

Eqs. 3-4 for all N sessions run as ONE jitted mask-over-boxes kernel
(``surfaces_from_boxes``): no Python loop over boxes or sessions.  The
legacy per-session ``ZeCoStream`` object routes through the same kernel
at N=1, so bank and per-session execution are bit-identical (pinned by
tests/test_zecostream_bank.py); ``importance_map`` / ``qp_map`` /
``reference_surface`` remain the pure-NumPy semantic reference.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.video import codec
from repro.video.codec import QP_MAX, QP_MIN

Box = Tuple[float, float, float, float]  # (y0, x0, y1, x1) pixels

FEEDBACK_STEPS = 6  # prediction-horizon timestamps per feedback packet


@functools.lru_cache(maxsize=64)
def zero_surface(nby: int, nbx: int) -> np.ndarray:
    """Cached all-zeros relative QP surface (the disengaged path would
    otherwise allocate one per session per tick)."""
    out = np.zeros((nby, nbx), np.float32)
    out.setflags(write=False)  # shared via the lru_cache
    return out


@functools.lru_cache(maxsize=64)
def _patch_grid(frame_hw: Tuple[int, int], patch: int) -> Tuple[int, int]:
    """Ceil-division patch-grid shape: partial trailing patches get their
    own row/column instead of being silently dropped."""
    H, W = frame_hw
    return -(-H // patch), -(-W // patch)


@functools.lru_cache(maxsize=64)
def _patch_centers(frame_hw: Tuple[int, int], patch: int):
    """Cached (yy, xx) patch-center grids (rebuilt identically per call
    otherwise — the fleet engine evaluates Eq. 3 every session, every
    tick).  A partial trailing patch is centered on the strip it actually
    covers; full patches keep the exact (i + 0.5) * patch centers."""
    H, W = frame_hw
    gy, gx = _patch_grid(frame_hw, patch)

    def centers(g, size):
        lo = np.arange(g) * patch
        hi = np.minimum(lo + patch, size)
        return 0.5 * (lo + hi)

    yy, xx = np.meshgrid(centers(gy, H), centers(gx, W), indexing="ij")
    yy.setflags(write=False)  # shared via the lru_cache
    xx.setflags(write=False)
    return yy, xx


@functools.lru_cache(maxsize=64)
def _block_to_patch_idx(frame_hw: Tuple[int, int], patch: int):
    """Static gather indices upsampling a patch grid to the full 8x8-block
    grid.  `qp[iy][:, ix]` covers every block — including trailing blocks
    of a partial patch, which the old repeat-then-clip upsample dropped."""
    H, W = frame_hw
    iy = (8 * np.arange(H // 8)) // patch
    ix = (8 * np.arange(W // 8)) // patch
    iy.setflags(write=False)
    ix.setflags(write=False)
    return iy, ix


def importance_map(boxes: Sequence[Box], frame_hw: Tuple[int, int],
                   patch: int = 64, mu: float = 0.5) -> np.ndarray:
    """Eq. 3 over the patch grid (NumPy reference implementation).

    Empty boxes -> all-zeros (uniform low).  The grid uses ceil division,
    so frames whose H or W is not a patch multiple get a trailing partial
    row/column instead of losing coverage."""
    H, W = frame_hw
    gy, gx = _patch_grid(frame_hw, patch)
    yy, xx = _patch_centers((H, W), patch)
    if not len(boxes):
        return np.zeros((gy, gx), np.float32)
    diag = float(np.hypot(H, W))
    d_min = np.full((gy, gx), np.inf, np.float32)
    for (y0, x0, y1, x1) in boxes:
        # distance from point to axis-aligned box boundary (0 inside)
        dy = np.maximum(np.maximum(y0 - yy, yy - y1), 0.0)
        dx = np.maximum(np.maximum(x0 - xx, xx - x1), 0.0)
        d = np.hypot(dy, dx)
        d_min = np.minimum(d_min, d)
    rho = np.maximum(0.0, 1.0 - d_min / (mu * diag))
    return rho.astype(np.float32)


def qp_map(rho: np.ndarray, q_min: float = QP_MIN, q_max: float = QP_MAX
           ) -> np.ndarray:
    """Eq. 4: quadratic importance -> QP."""
    return (q_min + (q_max - q_min) * np.square(1.0 - rho)).astype(np.float32)


def reference_surface(boxes: Sequence[Box], frame_hw: Tuple[int, int],
                      patch: int = 64, mu: float = 0.5,
                      q_min: float = QP_MIN, q_max: float = QP_MAX
                      ) -> np.ndarray:
    """NumPy reference for the full engaged-path surface: Eq. 3 -> Eq. 4
    -> block upsample -> zero-mean shift.  `surfaces_from_boxes` is the
    batched jitted equivalent (pinned to this by test_zecostream_bank)."""
    H, W = frame_hw
    qp = qp_map(importance_map(boxes, frame_hw, patch, mu), q_min, q_max)
    iy, ix = _block_to_patch_idx(frame_hw, patch)
    qp_blocks = qp[iy][:, ix]
    return (qp_blocks - qp_blocks.mean()).astype(np.float32)


# --------------------------------------------------------------------------
# Array-native feedback packets
# --------------------------------------------------------------------------
def boxes_to_array(boxes: Sequence[Box], capacity: Optional[int] = None
                   ) -> Tuple[np.ndarray, int]:
    """Pack a list of boxes into a padded (capacity, 4) float32 array."""
    n = len(boxes)
    cap = n if capacity is None else capacity
    out = np.zeros((cap, 4), np.float32)
    if n:
        out[:n] = np.asarray(boxes, np.float32)[:cap]
    return out, min(n, cap)


@dataclasses.dataclass
class TimedBoxes:
    """A grounding-then-prediction feedback packet: boxes at future times.

    ``boxes`` is stored as a stacked ``(K, B, 4)`` float32 array with a
    ``counts (K,)`` mask (see the module docstring); the constructor also
    accepts the legacy list-of-lists form and packs it."""

    times: np.ndarray                              # (K,) timestamps (s)
    boxes: Union[np.ndarray, List[List[Box]]]      # (K, B, 4) after init
    counts: Optional[np.ndarray] = None            # (K,) valid boxes/step

    def __post_init__(self):
        self.times = np.asarray(self.times, np.float64)
        if isinstance(self.boxes, (list, tuple)):
            rows = self.boxes
            cap = max((len(r) for r in rows), default=0)
            arr = np.zeros((len(rows), cap, 4), np.float32)
            cnt = np.zeros(len(rows), np.int32)
            for k, r in enumerate(rows):
                cnt[k] = len(r)
                if r:
                    arr[k, :len(r)] = np.asarray(r, np.float32)
            self.boxes, self.counts = arr, cnt
        else:
            self.boxes = np.asarray(self.boxes, np.float32)
            if self.counts is None:
                # a padded array without its mask would silently promote
                # zero-padding rows to real boxes at the frame origin
                raise ValueError(
                    "TimedBoxes built from a (K, B, 4) array needs the "
                    "counts mask (use the list-of-lists form otherwise)")
            self.counts = np.asarray(self.counts, np.int32)

    def at_arrays(self, t: float) -> Tuple[np.ndarray, int]:
        """Client-side matching of the current timestamp (§5.2): the
        padded box array + valid count at the nearest packet time."""
        if len(self.times) == 0:
            return np.zeros((0, 4), np.float32), 0
        i = int(np.argmin(np.abs(self.times - t)))
        return self.boxes[i], int(self.counts[i])

    def at(self, t: float) -> List[Box]:
        """Legacy list-of-tuples view of `at_arrays`."""
        arr, count = self.at_arrays(t)
        return [tuple(float(v) for v in arr[j]) for j in range(count)]


# --------------------------------------------------------------------------
# Batched Eq. 3-4: one jitted mask-over-boxes kernel for all N sessions
# --------------------------------------------------------------------------
def _surface_one(boxes: jnp.ndarray, count: jnp.ndarray,
                 engaged: jnp.ndarray, *, frame_hw: Tuple[int, int],
                 patch: int, mu: float, q_min: float, q_max: float
                 ) -> jnp.ndarray:
    """Zero-mean relative QP surface for ONE session from padded boxes.

    boxes (B, 4) float32 with `count` valid rows; masked rows sit at +inf
    distance so padding never affects the Eq. 3 min.  Returns the
    (H//8, W//8) float32 surface, all-zeros when `engaged` is false."""
    H, W = frame_hw
    yy, xx = _patch_centers(frame_hw, patch)
    yy = jnp.asarray(yy, jnp.float32)
    xx = jnp.asarray(xx, jnp.float32)
    dy = jnp.maximum(jnp.maximum(boxes[:, 0, None, None] - yy,
                                 yy - boxes[:, 2, None, None]), 0.0)
    dx = jnp.maximum(jnp.maximum(boxes[:, 1, None, None] - xx,
                                 xx - boxes[:, 3, None, None]), 0.0)
    d = jnp.sqrt(dy * dy + dx * dx)
    valid = jnp.arange(boxes.shape[0])[:, None, None] < count
    d_min = jnp.min(jnp.where(valid, d, jnp.inf), axis=0)
    rho = jnp.maximum(0.0, 1.0 - d_min / jnp.float32(mu * np.hypot(H, W)))
    qp = q_min + (q_max - q_min) * jnp.square(1.0 - rho)
    iy, ix = _block_to_patch_idx(frame_hw, patch)
    qp_blocks = qp[jnp.asarray(iy)][:, jnp.asarray(ix)]
    # fixed-order sum (see codec.tree_sum): the zero-mean shift feeds the
    # quantizer, so its rounding must not depend on the fusion context
    surf = qp_blocks - codec.tree_sum(qp_blocks, 2) / qp_blocks.size
    return jnp.where(engaged, surf, 0.0).astype(jnp.float32)


def _surfaces(boxes, counts, engaged, *, frame_hw, patch, mu, q_min, q_max):
    one = functools.partial(_surface_one, frame_hw=frame_hw, patch=patch,
                            mu=mu, q_min=q_min, q_max=q_max)
    return jax.vmap(one)(boxes, counts, engaged)


@functools.partial(jax.jit, static_argnames=("frame_hw", "patch", "mu",
                                             "q_min", "q_max"))
def surfaces_from_boxes(boxes: jnp.ndarray, counts: jnp.ndarray,
                        engaged: jnp.ndarray, *,
                        frame_hw: Tuple[int, int], patch: int = 64,
                        mu: float = 0.5, q_min: float = float(QP_MIN),
                        q_max: float = float(QP_MAX)) -> jnp.ndarray:
    """Eqs. 3-4 for a whole fleet tick in one dispatch.

    boxes (N, B, 4), counts (N,), engaged (N,) -> (N, H//8, W//8) zero-mean
    relative QP surfaces (zeros for disengaged rows)."""
    return _surfaces(boxes, counts, engaged, frame_hw=frame_hw, patch=patch,
                     mu=mu, q_min=q_min, q_max=q_max)


@functools.partial(jax.jit, static_argnames=("frame_hw", "patch", "mu",
                                             "q_min", "q_max", "iters",
                                             "probe_stride"))
def rate_control_batch_fused(frames: jnp.ndarray, boxes: jnp.ndarray,
                             counts: jnp.ndarray, engaged: jnp.ndarray,
                             target_bits: jnp.ndarray, *,
                             frame_hw: Tuple[int, int], patch: int = 64,
                             mu: float = 0.5, q_min: float = float(QP_MIN),
                             q_max: float = float(QP_MAX), iters: int = 8,
                             probe_stride: int = 1):
    """Fused importance -> QP -> rate-controlled encode for a fleet tick.

    The Eq. 3-4 surfaces are computed in-graph from the box arrays and fed
    straight into `codec.rate_control_batch`, so the fused plan+encode is
    ONE device dispatch and the QP surface never makes a host round-trip
    (XLA keeps it an internal buffer of the computation).  Returns
    (surfaces, qp_blocks, EncodedFrame batch); the surfaces come back as a
    device array only for the partial-drop requantize path."""
    surf = _surfaces(boxes, counts, engaged, frame_hw=frame_hw, patch=patch,
                     mu=mu, q_min=q_min, q_max=q_max)
    qp, enc = codec.rate_control_batch(frames, surf, target_bits,
                                       iters=iters,
                                       probe_stride=probe_stride)
    return surf, qp, enc


# --------------------------------------------------------------------------
# Per-session legacy object (reference semantics; shares the jitted kernel)
# --------------------------------------------------------------------------
@dataclasses.dataclass
class ZeCoStream:
    patch: int = 64
    mu: float = 0.5
    q_min: float = QP_MIN
    q_max: float = QP_MAX
    # trigger: engage below this bitrate (validation-tuned; §3 "critical
    # level where the MLLM struggles")
    trigger_bps: float = 1.2e6
    # and disengage with hysteresis to avoid flapping
    release_bps: float = 1.6e6

    def __post_init__(self):
        self.active = False
        self.last_feedback: Optional[TimedBoxes] = None

    def on_feedback(self, fb: TimedBoxes):
        self.last_feedback = fb

    def engage_decision(self, rate_bps: float,
                        confidence: Optional[float] = None,
                        tau: float = 0.8) -> bool:
        """Paper §3 trigger with hysteresis, as a PURE decision: engage
        only when the MLLM struggles to answer AND bandwidth does not
        permit a higher bitrate.  Does not touch `self.active` — the
        decision is applied exactly once per tick (in `qp_shape`), so
        probing it cannot flap the hysteresis state twice in a tick."""
        struggling = confidence is None or confidence < tau
        thresh = self.release_bps if self.active else self.trigger_bps
        return rate_bps < thresh and struggling

    def should_engage(self, rate_bps: float,
                      confidence: Optional[float] = None,
                      tau: float = 0.8) -> bool:
        """Decision + application (back-compat wrapper around
        `engage_decision`)."""
        self.active = self.engage_decision(rate_bps, confidence, tau)
        return self.active

    def qp_shape(self, t: float, frame_hw: Tuple[int, int],
                 rate_bps: float, confidence: Optional[float] = None,
                 tau: float = 0.8) -> Tuple[np.ndarray, bool]:
        """Relative QP surface for the encoder's rate control.

        Returns (qp_surface (H//8, W//8), engaged).  When disengaged the
        surface is uniform zeros (context-agnostic encoding); when engaged
        it is the Eq. 3/4 map shifted to zero-mean so rate control's global
        offset search composes with it."""
        H, W = frame_hw
        nby, nbx = H // 8, W // 8
        decision = self.engage_decision(rate_bps, confidence, tau)
        self.active = decision  # single application site per tick
        if not decision or self.last_feedback is None:
            return zero_surface(nby, nbx), False
        boxes, count = self.last_feedback.at_arrays(t)
        if count == 0:
            return zero_surface(nby, nbx), False
        surf = surfaces_from_boxes(
            boxes[None], np.asarray([count], np.int32),
            np.asarray([True]), frame_hw=(H, W), patch=self.patch,
            mu=self.mu, q_min=float(self.q_min), q_max=float(self.q_max))
        return np.asarray(surf)[0], True


# --------------------------------------------------------------------------
# Fleet-wide bank: N sessions' context state as arrays
# --------------------------------------------------------------------------
def _grow(cap: int, need: int) -> int:
    while cap < need:
        cap = max(2 * cap, 1)
    return cap


class ZeCoStreamBank:
    """Vectorized ZeCoStream for N sessions (see the module docstring for
    the array layout).  Per tick, `plan` runs the trigger/hysteresis
    update, timestamp matching and Eqs. 3-4 for every session with array
    ops + ONE jitted kernel dispatch — the serial `ZeCoStream` object's
    state machine, element-wise over (N,) arrays."""

    def __init__(self, n: int, frame_hw: Tuple[int, int], *,
                 patch: int = 64, mu: float = 0.5,
                 q_min: float = QP_MIN, q_max: float = QP_MAX,
                 trigger_bps: float = 1.2e6, release_bps: float = 1.6e6,
                 tau=0.8, enabled=None, box_capacity: int = 8,
                 time_capacity: int = FEEDBACK_STEPS):
        self.n = n
        self.frame_hw = (int(frame_hw[0]), int(frame_hw[1]))
        self.patch, self.mu = patch, mu
        self.q_min, self.q_max = float(q_min), float(q_max)
        self.trigger_bps = np.broadcast_to(
            np.asarray(trigger_bps, np.float64), (n,)).copy()
        self.release_bps = np.broadcast_to(
            np.asarray(release_bps, np.float64), (n,)).copy()
        self.tau = np.broadcast_to(np.asarray(tau, np.float64), (n,)).copy()
        self.enabled = (np.ones(n, bool) if enabled is None
                        else np.asarray(enabled, bool).copy())
        # hysteresis + feedback state, all (N,)-leading arrays
        self.active = np.zeros(n, bool)
        self.has_fb = np.zeros(n, bool)
        self.engaged_total = np.zeros(n, np.int64)
        self._alloc(time_capacity, max(1, box_capacity))

    def _alloc(self, kcap: int, bcap: int):
        self.fb_times = np.full((self.n, kcap), np.inf)
        self.fb_boxes = np.zeros((self.n, kcap, bcap, 4), np.float32)
        self.fb_counts = np.zeros((self.n, kcap), np.int32)
        self.fb_len = np.zeros(self.n, np.int32)

    def _ensure_capacity(self, k: int, b: int):
        kcap, bcap = self.fb_times.shape[1], self.fb_boxes.shape[2]
        if k <= kcap and b <= bcap:
            return
        old = (self.fb_times, self.fb_boxes, self.fb_counts, self.fb_len)
        self._alloc(_grow(kcap, k), _grow(bcap, b))
        self.fb_times[:, :kcap] = old[0]
        self.fb_boxes[:, :kcap, :bcap] = old[1]
        self.fb_counts[:, :kcap] = old[2]
        self.fb_len = old[3]

    def reset_row(self, row: int, tau: Optional[float] = None,
                  enabled: Optional[bool] = None) -> None:
        """Restart row's trigger/hysteresis/feedback state (churn slot
        revival); `tau`/`enabled` re-key the row to the new tenant's
        session config.  Snapshot `engaged_total[row]` (the departing
        tenant's metric) BEFORE calling this."""
        self.active[row] = False
        self.has_fb[row] = False
        self.engaged_total[row] = 0
        self.fb_times[row] = np.inf
        self.fb_boxes[row] = 0.0
        self.fb_counts[row] = 0
        self.fb_len[row] = 0
        if tau is not None:
            self.tau[row] = float(tau)
        if enabled is not None:
            self.enabled[row] = bool(enabled)

    # -- feedback ingestion --------------------------------------------
    def on_feedback(self, row: int, fb: TimedBoxes):
        """Store one session's latest feedback packet into the bank."""
        k, b = fb.boxes.shape[0], fb.boxes.shape[1]
        self._ensure_capacity(k, b)
        self.fb_times[row] = np.inf
        self.fb_times[row, :k] = fb.times
        self.fb_boxes[row] = 0.0
        self.fb_boxes[row, :k, :b] = fb.boxes
        self.fb_counts[row] = 0
        self.fb_counts[row, :k] = fb.counts
        self.fb_len[row] = k
        self.has_fb[row] = True

    # -- per-tick planning ---------------------------------------------
    def decide_engage(self, rate_bps: np.ndarray, confidence: np.ndarray
                      ) -> np.ndarray:
        """PURE vectorized trigger/hysteresis decision (§3): the array
        form of `ZeCoStream.engage_decision`.  Application happens once
        per tick in `plan_arrays`."""
        struggling = np.asarray(confidence) < self.tau
        thresh = np.where(self.active, self.release_bps, self.trigger_bps)
        return self.enabled & struggling & (np.asarray(rate_bps) < thresh)

    def _select(self, t: float) -> Tuple[np.ndarray, np.ndarray]:
        """Nearest-timestamp boxes for every session: (N, B, 4), (N,)."""
        i = np.argmin(np.abs(self.fb_times - t), axis=1)
        rows = np.arange(self.n)
        counts = np.where(self.fb_len > 0, self.fb_counts[rows, i], 0)
        return self.fb_boxes[rows, i], counts.astype(np.int32)

    def plan_arrays(self, t: float, rate_bps: np.ndarray,
                    confidence: np.ndarray
                    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Apply the engage decision (once) and match timestamps; returns
        (boxes (N, B, 4), counts (N,), engaged (N,)) ready for either
        `surfaces_from_boxes` or the fused codec path."""
        decision = self.decide_engage(rate_bps, confidence)
        self.active = decision  # single application site per tick
        boxes, counts = self._select(t)
        engaged = decision & self.has_fb & (counts > 0)
        self.engaged_total += engaged
        return boxes, counts, engaged

    def surface_dispatch(self):
        """The bank's default Eq. 3-4 kernel as a (boxes, counts,
        engaged) -> surfaces callable — the signature `plan` accepts as
        its `dispatch` override, so a sharded fleet can substitute a
        shard_map-wrapped equivalent without touching the plan logic."""
        return functools.partial(
            surfaces_from_boxes, frame_hw=self.frame_hw, patch=self.patch,
            mu=self.mu, q_min=self.q_min, q_max=self.q_max)

    def plan(self, t: float, rate_bps: np.ndarray, confidence: np.ndarray,
             dispatch=None) -> Tuple[np.ndarray, np.ndarray]:
        """One fleet-wide plan dispatch: (N, H//8, W//8) relative QP
        surfaces + the (N,) engaged mask for this tick.  `dispatch`
        replaces the surface kernel call (same signature as
        `surface_dispatch()`); the trigger/selection logic and the
        disengaged-tick skip are identical either way, so a custom
        dispatch stays bit-compatible with the default.  A custom
        dispatch's output is returned AS IS (the sharded fleet keeps the
        surfaces device-resident for the encode dispatch instead of
        paying a host round trip); the default path materializes to a
        host array as before."""
        boxes, counts, engaged = self.plan_arrays(t, rate_bps, confidence)
        nby, nbx = self.frame_hw[0] // 8, self.frame_hw[1] // 8
        if not engaged.any():
            # common fully-disengaged tick: skip the device dispatch
            return (np.broadcast_to(zero_surface(nby, nbx),
                                    (self.n, nby, nbx)), engaged)
        if dispatch is not None:
            return dispatch(boxes, counts, engaged), engaged
        return np.asarray(self.surface_dispatch()(boxes, counts, engaged)
                          ), engaged

"""ZeCoStream — Zero-overhead Context-aware Streaming (paper §5).

Eq. (3): per-patch contextual importance from the MLLM-fed-back boxes,
    rho_ij = max(0, 1 - d_ij / (mu * sqrt(W^2 + H^2)))
with d_ij the distance from the patch center to the nearest box (0 inside)
and mu = 0.5.

Eq. (4): non-linear QP map,
    Q_ij = Qmin + (Qmax - Qmin) * (1 - rho_ij)^2

Grounding-then-prediction (§5.2): feedback boxes are >= 1.2-1.5 s stale;
the server ships a short horizon of *predicted* boxes and the client picks
the one matching the current timestamp.

Trigger policy (§3): ZeCoStream engages only when the bitrate is below the
critical level where accuracy is at risk; otherwise uniform encoding
protects the background for visual memory.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.video.codec import QP_MAX, QP_MIN

Box = Tuple[float, float, float, float]  # (y0, x0, y1, x1) pixels


@functools.lru_cache(maxsize=64)
def zero_surface(nby: int, nbx: int) -> np.ndarray:
    """Cached all-zeros relative QP surface (the disengaged path would
    otherwise allocate one per session per tick)."""
    out = np.zeros((nby, nbx), np.float32)
    out.setflags(write=False)  # shared via the lru_cache
    return out


@functools.lru_cache(maxsize=64)
def _patch_centers(frame_hw: Tuple[int, int], patch: int):
    """Cached (yy, xx) patch-center grids (rebuilt identically per call
    otherwise — the fleet engine evaluates Eq. 3 every session, every
    tick)."""
    H, W = frame_hw
    gy, gx = H // patch, W // patch
    cy = (np.arange(gy) + 0.5) * patch
    cx = (np.arange(gx) + 0.5) * patch
    yy, xx = np.meshgrid(cy, cx, indexing="ij")
    yy.setflags(write=False)  # shared via the lru_cache
    xx.setflags(write=False)
    return yy, xx


def importance_map(boxes: Sequence[Box], frame_hw: Tuple[int, int],
                   patch: int = 64, mu: float = 0.5) -> np.ndarray:
    """Eq. 3 over the patch grid. Empty boxes -> all-zeros (uniform low)."""
    H, W = frame_hw
    gy, gx = H // patch, W // patch
    yy, xx = _patch_centers((H, W), patch)
    if not boxes:
        return np.zeros((gy, gx), np.float32)
    diag = float(np.hypot(H, W))
    d_min = np.full((gy, gx), np.inf, np.float32)
    for (y0, x0, y1, x1) in boxes:
        # distance from point to axis-aligned box boundary (0 inside)
        dy = np.maximum(np.maximum(y0 - yy, yy - y1), 0.0)
        dx = np.maximum(np.maximum(x0 - xx, xx - x1), 0.0)
        d = np.hypot(dy, dx)
        d_min = np.minimum(d_min, d)
    rho = np.maximum(0.0, 1.0 - d_min / (mu * diag))
    return rho.astype(np.float32)


def qp_map(rho: np.ndarray, q_min: float = QP_MIN, q_max: float = QP_MAX
           ) -> np.ndarray:
    """Eq. 4: quadratic importance -> QP."""
    return (q_min + (q_max - q_min) * np.square(1.0 - rho)).astype(np.float32)


@dataclasses.dataclass
class TimedBoxes:
    """A grounding-then-prediction feedback packet: boxes at future times."""

    times: np.ndarray          # (K,) absolute timestamps (s)
    boxes: List[List[Box]]     # K lists of boxes

    def at(self, t: float) -> List[Box]:
        """Client-side matching of the current timestamp (§5.2)."""
        if len(self.times) == 0:
            return []
        i = int(np.argmin(np.abs(self.times - t)))
        return self.boxes[i]


@dataclasses.dataclass
class ZeCoStream:
    patch: int = 64
    mu: float = 0.5
    q_min: float = QP_MIN
    q_max: float = QP_MAX
    # trigger: engage below this bitrate (validation-tuned; §3 "critical
    # level where the MLLM struggles")
    trigger_bps: float = 1.2e6
    # and disengage with hysteresis to avoid flapping
    release_bps: float = 1.6e6

    def __post_init__(self):
        self.active = False
        self.last_feedback: Optional[TimedBoxes] = None

    def on_feedback(self, fb: TimedBoxes):
        self.last_feedback = fb

    def should_engage(self, rate_bps: float,
                      confidence: Optional[float] = None,
                      tau: float = 0.8) -> bool:
        """Paper §3: trigger only when the MLLM struggles to answer AND
        bandwidth does not permit a higher bitrate; otherwise uniform
        encoding protects background visual memory."""
        struggling = confidence is None or confidence < tau
        if self.active:
            self.active = rate_bps < self.release_bps and struggling
        else:
            self.active = rate_bps < self.trigger_bps and struggling
        return self.active

    def qp_shape(self, t: float, frame_hw: Tuple[int, int],
                 rate_bps: float, confidence: Optional[float] = None,
                 tau: float = 0.8) -> Tuple[np.ndarray, bool]:
        """Relative QP surface for the encoder's rate control.

        Returns (qp_surface (H//8, W//8), engaged).  When disengaged the
        surface is uniform zeros (context-agnostic encoding); when engaged
        it is the Eq. 3/4 map shifted to zero-mean so rate control's global
        offset search composes with it."""
        H, W = frame_hw
        nby, nbx = H // 8, W // 8
        if (not self.should_engage(rate_bps, confidence, tau)
                or self.last_feedback is None):
            return zero_surface(nby, nbx), False
        boxes = self.last_feedback.at(t)
        if not boxes:
            return zero_surface(nby, nbx), False
        rho = importance_map(boxes, frame_hw, self.patch, self.mu)
        qp = qp_map(rho, self.q_min, self.q_max)
        # expand patch grid -> 8x8 block grid
        rep = self.patch // 8
        qp_blocks = np.repeat(np.repeat(qp, rep, axis=0), rep, axis=1)
        qp_blocks = qp_blocks[:nby, :nbx]
        return (qp_blocks - qp_blocks.mean()).astype(np.float32), True

"""End-to-end Artic RTC session: client <-> channel <-> MLLM server loop.

Wire-up per frame (paper Fig. 4):

    trace bw ──► Channel ──► frame latency / drops
       ▲            ▲
       │            │ encoded frame (rate-controlled, QP surface)
    CC (GCC/BBR) ReCapABR ◄── confidence C_t (delayed feedback)
       │            │
       └── B_hat ───┘      ZeCoStream QP ◄── TimedBoxes (delayed feedback)

The server consumes *decoded degraded frames* (as the real MLLM would),
answers QA samples, and emits {confidence, predicted boxes} feedback that
reaches the client after uplink-latency + inference + downlink delay —
measured on Doubao at 1.20-1.52 s total (§5.2), which our defaults match.

System variants (paper §7 baselines) come from two switches:
    use_recap=False, use_zeco=False  -> WebRTC (GCC or BBR)
    use_recap=True,  use_zeco=False  -> WebRTC + ReCapABR
    use_recap=False, use_zeco=True   -> WebRTC + ZeCoStream
    use_recap=True,  use_zeco=True   -> Artic
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.confidence import ConfidenceHead, PlattCalibrator
from repro.core.grounding import TrajectoryPredictor, detect_cards
from repro.core.recap_abr import CCOnlyABR, ReCapABR
from repro.core.zecostream import TimedBoxes, ZeCoStream
from repro.net.cc import make_cc
from repro.net.channel import Channel
from repro.net.traces import Trace
from repro.video import codec
from repro.video.scenes import Scene, decode_glyph


@dataclasses.dataclass(frozen=True)
class QASample:
    t_ask: float
    obj_idx: int
    kind: str = "read_code"   # read_code | count_objects
    # degradation-sensitivity labels filled by the DeViBench pipeline
    sensitive: bool = True
    # conversational answer window: the assistant may use frames that
    # arrive until t_ask + answer_window before committing its response
    answer_window: float = 4.0


@dataclasses.dataclass
class SessionConfig:
    fps: float = 10.0
    duration: float = 60.0
    use_recap: bool = True
    use_zeco: bool = True
    cc_kind: str = "gcc"
    tau: float = 0.8
    gamma: float = 2.0
    inference_delay: float = 0.25   # MLLM processing per feedback round
    downlink_delay: float = 0.05    # feedback packet delay (tiny payload)
    feedback_period: float = 0.5    # server feedback cadence (s)
    readable_margin: float = 0.35   # detector margin for a confident read
    seed: int = 0


class OracleServer:
    """Benchmark-scale MLLM stand-in: glyph detector + visual memory.

    Mirrors the §4.1 accuracy factors: information density (glyph cell
    size), memory of seen content (best-decode cache), and confidence that
    tracks actual readability (Fig. 10)."""

    def __init__(self, scene: Scene, cfg: SessionConfig,
                 calibrator: Optional[PlattCalibrator] = None):
        self.scene = scene
        self.cfg = cfg
        self.conf_head = ConfidenceHead(mode="oracle",
                                        calibrator=calibrator)
        self.predictor = TrajectoryPredictor()
        # visual memory keyed by (obj_idx, code_epoch): stale epochs cannot
        # answer questions about current content (§4.1 seen-vs-unseen)
        self.memory: Dict[Tuple[int, int], Tuple[float, int]] = {}
        self.last_margins: List[float] = [0.0]
        self.frames_seen = 0
        # the open conversational question (drives grounding, §5.1: the
        # MLLM grounds regions important to the *current* context)
        self.active_question: Optional[QASample] = None

    # -- ingestion ------------------------------------------------------
    def ingest(self, t_capture: float, frame: np.ndarray):
        """Process one received (already decoded, degraded) frame."""
        self.frames_seen += 1
        frame_idx = int(round(t_capture * self.cfg.fps))
        epoch = self.scene.epoch(frame_idx)
        margins = []
        for idx, obj in enumerate(self.scene.objects):
            y0, x0, y1, x1 = obj.bbox(frame_idx)
            y0 = int(np.clip(y0, 0, self.scene.h - obj.size))
            x0 = int(np.clip(x0, 0, self.scene.w - obj.size))
            patch = frame[y0:y0 + obj.size, x0:x0 + obj.size]
            code, margin = decode_glyph(patch, obj.cell)
            margins.append(margin)
            best = self.memory.get((idx, epoch), (0.0, -1))
            if margin > best[0]:
                self.memory[(idx, epoch)] = (margin, code)
        self.last_margins = margins or [0.0]
        # grounding runs on the degraded frame itself (zero client cost)
        self.predictor.observe(t_capture, detect_cards(frame))

    # -- feedback -------------------------------------------------------
    def feedback(self, t_now: float) -> Tuple[float, TimedBoxes]:
        """Confidence + grounding-then-prediction boxes.

        With an open question, confidence reflects readability of the
        *queried* region and grounding narrows to the track covering it
        (question-conditioned context, Fig. 5); otherwise scene-level."""
        fb = self.predictor.feedback(t_now, horizon=1.5)
        q = self.active_question
        if q is not None and q.kind == "read_code":
            frame_idx = int(round(t_now * self.cfg.fps))
            epoch = self.scene.epoch(frame_idx)
            margin, _ = self.memory.get((q.obj_idx, epoch), (0.0, -1))
            conf = self.conf_head.from_margin(margin)
            # narrow grounding to the track nearest the queried object
            # (modern MLLMs ground conversational references accurately)
            oy, ox, oy1, ox1 = self.scene.objects[q.obj_idx].bbox(frame_idx)
            ocy, ocx = 0.5 * (oy + oy1), 0.5 * (ox + ox1)
            best = None
            for tr in self.predictor.tracks:
                (y0, x0, y1, x1) = tr.history[-1][1]
                d = np.hypot(0.5 * (y0 + y1) - ocy, 0.5 * (x0 + x1) - ocx)
                if best is None or d < best[0]:
                    best = (d, tr)
            if best is not None:
                times = fb.times
                boxes = [[best[1].predict(float(tt))] for tt in times]
                fb = TimedBoxes(times=times, boxes=boxes)
            return conf, fb
        conf = self.conf_head.from_margin(float(np.mean(self.last_margins)))
        return conf, fb

    # -- QA -------------------------------------------------------------
    def answer(self, qa: QASample) -> bool:
        """True iff the server answers correctly (memory-aided within the
        current code epoch — delayed/corrupted frames mean the server never
        saw the current content clearly and answers wrong)."""
        frame_idx = int(round(qa.t_ask * self.cfg.fps))
        epoch = self.scene.epoch(frame_idx)
        truth = self.scene.objects[qa.obj_idx].code_at(epoch)
        if qa.kind == "count_objects":
            # coarse question: count tracked cards (degradation-insensitive)
            n = len(self.predictor.tracks)
            return n == len(self.scene.objects)
        margin, code = self.memory.get((qa.obj_idx, epoch), (0.0, -1))
        if margin < self.cfg.readable_margin:
            return False  # never seen this epoch clearly
        return code == truth


@dataclasses.dataclass
class SessionMetrics:
    latencies: List[float]
    accuracy: float
    n_qa: int
    avg_bitrate: float       # bits/s offered by the encoder
    bandwidth_used: float    # bits/s actually sent
    confidences: List[float]
    rates: List[float]
    zeco_engaged_frames: int
    qa_results: List[bool]
    dropped_frames: int = 0

    @property
    def avg_latency_ms(self) -> float:
        lat = [l for l in self.latencies if np.isfinite(l)]
        return 1e3 * float(np.mean(lat)) if lat else float("inf")

    @property
    def p95_latency_ms(self) -> float:
        lat = [l for l in self.latencies if np.isfinite(l)]
        return 1e3 * float(np.percentile(lat, 95)) if lat else float("inf")

    def frac_below(self, ms: float) -> float:
        lat = np.asarray(self.latencies) * 1e3
        return float(np.mean(lat < ms)) if len(lat) else 0.0


def run_session(scene: Scene, qa_samples: List[QASample], trace: Trace,
                cfg: SessionConfig,
                calibrator: Optional[PlattCalibrator] = None
                ) -> SessionMetrics:
    channel = Channel(trace)
    cc = make_cc(cfg.cc_kind)
    abr = (ReCapABR(tau=cfg.tau, gamma=cfg.gamma) if cfg.use_recap
           else CCOnlyABR())
    zeco = ZeCoStream()
    server = OracleServer(scene, cfg, calibrator)

    frame_hw = (scene.h, scene.w)
    n_frames = int(cfg.duration * cfg.fps)
    dt = 1.0 / cfg.fps

    # event queues: (time, payload)
    arrivals: List[Tuple[float, float, np.ndarray]] = []  # (t_arr, t_cap, frame)
    feedbacks: List[Tuple[float, float, TimedBoxes]] = []  # (t_recv, conf, boxes)
    next_feedback_t = 0.0

    confidence = 0.5  # client's current belief (before first feedback)
    boxes_fb: Optional[TimedBoxes] = None
    latencies, confs, rates = [], [], []
    zeco_engaged = 0
    bits_total = 0.0

    qa_sorted = sorted(qa_samples, key=lambda q: q.t_ask)
    qa_i, qa_results = 0, []

    for i in range(n_frames):
        t = i * dt

        # 1. deliver pending server->client feedback
        while feedbacks and feedbacks[0][0] <= t:
            _, confidence, boxes_fb = feedbacks.pop(0)
            if boxes_fb is not None:
                zeco.on_feedback(boxes_fb)

        # 2. CC estimate from channel acks
        b_hat = cc.estimate(channel.ack_stats())

        # 3. ReCapABR (Eq. 1-2) or CC-follow
        rate = abr.update(confidence, b_hat)
        rates.append(rate)

        # 4. encode: ZeCoStream QP surface when engaged, else uniform
        frame = scene.render(i)
        if cfg.use_zeco:
            qp_shape, engaged = zeco.qp_shape(t, frame_hw, rate,
                                              confidence, cfg.tau)
            zeco_engaged += int(engaged)
        else:
            qp_shape = np.zeros((scene.h // 8, scene.w // 8), np.float32)
        target_bits = rate * dt
        qp_blocks, enc = codec.rate_control(
            frame, np.asarray(qp_shape), np.float32(target_bits))
        bits_total += float(enc.bits)

        # 5. ship over the uplink
        rep = channel.send_frame(t, float(enc.bits))
        latencies.append(rep.latency)
        if np.isfinite(rep.latency):
            # receiver decodes the (possibly partially dropped) frame
            if rep.dropped and rep.bits_delivered < rep.bits_sent:
                # re-encode at the delivered rate to emulate partial loss
                qp2, enc2 = codec.rate_control(
                    frame, np.asarray(qp_shape),
                    np.float32(max(rep.bits_delivered, 1e3)))
                rx = codec.decode(enc2)
            else:
                rx = codec.decode(enc)
            arrivals.append((t + rep.latency, t, np.asarray(rx)))
            arrivals.sort(key=lambda e: e[0])

        # 6. server ingests frames that have arrived by now
        while arrivals and arrivals[0][0] <= t:
            t_arr, t_cap, rx = arrivals.pop(0)
            server.ingest(t_cap, rx)

        # 7. server emits feedback at its cadence
        if t >= next_feedback_t and server.frames_seen:
            conf, fb = server.feedback(t)
            t_recv = t + cfg.inference_delay + cfg.downlink_delay
            feedbacks.append((t_recv, conf, fb))
            feedbacks.sort(key=lambda e: e[0])
            next_feedback_t = t + cfg.feedback_period

        # 8. conversational QA: a question opens at t_ask (the server
        # grounds the queried region from then on) and the response is
        # committed at t_ask + answer_window
        if (server.active_question is None and qa_i < len(qa_sorted)
                and qa_sorted[qa_i].t_ask <= t):
            server.active_question = qa_sorted[qa_i]
            qa_i += 1
        q = server.active_question
        if q is not None and t >= q.t_ask + q.answer_window:
            qa_results.append(server.answer(q))
            server.active_question = None
        confs.append(confidence)

    # flush: commit any open question and ask the rest at session end
    if server.active_question is not None:
        qa_results.append(server.answer(server.active_question))
        server.active_question = None
    while qa_i < len(qa_sorted):
        qa_results.append(server.answer(qa_sorted[qa_i]))
        qa_i += 1

    return SessionMetrics(
        latencies=latencies,
        accuracy=float(np.mean(qa_results)) if qa_results else 1.0,
        n_qa=len(qa_results),
        avg_bitrate=bits_total / cfg.duration,
        bandwidth_used=sum(r.bits_sent for r in channel.reports) / cfg.duration,
        confidences=confs,
        rates=rates,
        zeco_engaged_frames=zeco_engaged,
        qa_results=qa_results,
        dropped_frames=sum(r.dropped for r in channel.reports),
    )

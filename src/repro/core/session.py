"""End-to-end Artic RTC session: client <-> channel <-> MLLM server loop.

Wire-up per frame (paper Fig. 4):

    trace bw ──► Channel ──► frame latency / drops
       ▲            ▲
       │            │ encoded frame (rate-controlled, QP surface)
    CC (GCC/BBR) ReCapABR ◄── confidence C_t (delayed feedback)
       │            │
       └── B_hat ───┘      ZeCoStream QP ◄── TimedBoxes (delayed feedback)

The server consumes *decoded degraded frames* (as the real MLLM would),
answers QA samples, and emits {confidence, predicted boxes} feedback that
reaches the client after uplink-latency + inference + downlink delay —
measured on Doubao at 1.20-1.52 s total (§5.2), which our defaults match.

System variants (paper §7 baselines) come from two switches:
    use_recap=False, use_zeco=False  -> WebRTC (GCC or BBR)
    use_recap=True,  use_zeco=False  -> WebRTC + ReCapABR
    use_recap=False, use_zeco=True   -> WebRTC + ZeCoStream
    use_recap=True,  use_zeco=True   -> Artic
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.confidence import ConfidenceHead, PlattCalibrator
from repro.core.grounding import TrajectoryPredictor, detect_cards
from repro.core.ingest import glyph_stats_batch
from repro.core.recap_abr import CCOnlyABR, ReCapABR
from repro.core.zecostream import TimedBoxes, ZeCoStreamBank
from repro.net.cc import make_cc
from repro.net.channel import Channel
from repro.net.traces import Trace
from repro.video import codec
from repro.video.scenes import Scene


@dataclasses.dataclass(frozen=True)
class QASample:
    t_ask: float
    obj_idx: int
    kind: str = "read_code"   # read_code | count_objects
    # degradation-sensitivity labels filled by the DeViBench pipeline
    sensitive: bool = True
    # conversational answer window: the assistant may use frames that
    # arrive until t_ask + answer_window before committing its response
    answer_window: float = 4.0


@dataclasses.dataclass
class SessionConfig:
    fps: float = 10.0
    duration: float = 60.0
    use_recap: bool = True
    use_zeco: bool = True
    cc_kind: str = "gcc"
    tau: float = 0.8
    gamma: float = 2.0
    inference_delay: float = 0.25   # MLLM processing per feedback round
    downlink_delay: float = 0.05    # feedback packet delay (tiny payload)
    feedback_period: float = 0.5    # server feedback cadence (s)
    readable_margin: float = 0.35   # detector margin for a confident read
    # rate-control bisection probe stride: 1 = exact (default); s probes
    # 1/s^2 of the blocks per iteration (final encode stays exact) — a
    # fleet-scale throughput knob, applied identically in serial and
    # fleet execution so the two paths stay bit-identical to each other
    rc_probe_stride: int = 1
    seed: int = 0


class OracleServer:
    """Benchmark-scale MLLM stand-in: glyph detector + visual memory.

    Mirrors the §4.1 accuracy factors: information density (glyph cell
    size), memory of seen content (best-decode cache), and confidence that
    tracks actual readability (Fig. 10)."""

    def __init__(self, scene: Scene, cfg: SessionConfig,
                 calibrator: Optional[PlattCalibrator] = None):
        self.scene = scene
        self.cfg = cfg
        self.conf_head = ConfidenceHead(mode="oracle",
                                        calibrator=calibrator)
        self.predictor = TrajectoryPredictor()
        # visual memory keyed by (obj_idx, code_epoch): stale epochs cannot
        # answer questions about current content (§4.1 seen-vs-unseen)
        self.memory: Dict[Tuple[int, int], Tuple[float, int]] = {}
        self.last_margins: List[float] = [0.0]
        self.frames_seen = 0
        # the open conversational question (drives grounding, §5.1: the
        # MLLM grounds regions important to the *current* context)
        self.active_question: Optional[QASample] = None

    # -- ingestion ------------------------------------------------------
    def ingest(self, t_capture: float, frame: np.ndarray):
        """Process one received (already decoded, degraded) frame.

        The glyph decode/margin math runs through the batched jnp
        kernel (`ingest.glyph_stats_batch`, B=1 per object here) — the
        same kernel the fleet engine and the on-device rollout use, so
        every execution mode's server sees identical readings."""
        self.frames_seen += 1
        frame_idx = int(round(t_capture * self.cfg.fps))
        epoch = self.scene.epoch(frame_idx)
        margins = []
        for idx, obj in enumerate(self.scene.objects):
            y0, x0, y1, x1 = obj.bbox(frame_idx)
            y0 = int(np.clip(y0, 0, self.scene.h - obj.size))
            x0 = int(np.clip(x0, 0, self.scene.w - obj.size))
            patch = frame[y0:y0 + obj.size, x0:x0 + obj.size]
            codes, margs = glyph_stats_batch(patch[None], obj.cell)
            code, margin = int(codes[0]), float(margs[0])
            margins.append(margin)
            best = self.memory.get((idx, epoch), (0.0, -1))
            if margin > best[0]:
                self.memory[(idx, epoch)] = (margin, code)
        self.last_margins = margins or [0.0]
        # grounding runs on the degraded frame itself (zero client cost)
        self.predictor.observe(t_capture, detect_cards(frame))

    # -- feedback -------------------------------------------------------
    def feedback(self, t_now: float) -> Tuple[float, TimedBoxes]:
        """Confidence + grounding-then-prediction boxes.

        With an open question, confidence reflects readability of the
        *queried* region and grounding narrows to the track covering it
        (question-conditioned context, Fig. 5); otherwise scene-level."""
        fb = self.predictor.feedback(t_now, horizon=1.5)
        q = self.active_question
        if q is not None and q.kind == "read_code":
            frame_idx = int(round(t_now * self.cfg.fps))
            epoch = self.scene.epoch(frame_idx)
            margin, _ = self.memory.get((q.obj_idx, epoch), (0.0, -1))
            conf = self.conf_head.from_margin(margin)
            # narrow grounding to the track nearest the queried object
            # (modern MLLMs ground conversational references accurately)
            oy, ox, oy1, ox1 = self.scene.objects[q.obj_idx].bbox(frame_idx)
            ocy, ocx = 0.5 * (oy + oy1), 0.5 * (ox + ox1)
            best = None
            for tr in self.predictor.tracks:
                (y0, x0, y1, x1) = tr.history[-1][1]
                d = np.hypot(0.5 * (y0 + y1) - ocy, 0.5 * (x0 + x1) - ocx)
                if best is None or d < best[0]:
                    best = (d, tr)
            if best is not None:
                times = fb.times
                fb = TimedBoxes(
                    times=times,
                    boxes=best[1].predict_times(times)[:, None, :]
                    .astype(np.float32),
                    counts=np.ones(len(times), np.int32))
            return conf, fb
        conf = self.conf_head.from_margin(float(np.mean(self.last_margins)))
        return conf, fb

    # -- QA -------------------------------------------------------------
    def answer(self, qa: QASample) -> bool:
        """True iff the server answers correctly (memory-aided within the
        current code epoch — delayed/corrupted frames mean the server never
        saw the current content clearly and answers wrong)."""
        frame_idx = int(round(qa.t_ask * self.cfg.fps))
        epoch = self.scene.epoch(frame_idx)
        truth = self.scene.objects[qa.obj_idx].code_at(epoch)
        if qa.kind == "count_objects":
            # coarse question: count tracked cards (degradation-insensitive)
            n = len(self.predictor.tracks)
            return n == len(self.scene.objects)
        margin, code = self.memory.get((qa.obj_idx, epoch), (0.0, -1))
        if margin < self.cfg.readable_margin:
            return False  # never seen this epoch clearly
        return code == truth


@dataclasses.dataclass
class SessionMetrics:
    latencies: List[float]
    accuracy: float
    n_qa: int
    avg_bitrate: float       # bits/s offered by the encoder
    bandwidth_used: float    # bits/s actually sent
    confidences: List[float]
    rates: List[float]
    zeco_engaged_frames: int
    qa_results: List[bool]
    dropped_frames: int = 0
    # serving telemetry — populated only when the fleet runs with
    # server="engine" (per answered query / per extend+query op); empty
    # lists under the default oracle server, so oracle metrics are
    # byte-identical to pre-engine runs.
    server_ttfts: List[float] = dataclasses.field(default_factory=list)
    server_queue_delays: List[float] = dataclasses.field(default_factory=list)
    server_confidences: List[float] = dataclasses.field(default_factory=list)
    # context-overflow handling counters (engine server only): sink+recent
    # evictions keep the session warm; rollovers are the legacy full
    # context drop (eviction=False)
    server_evictions: int = 0
    server_evicted_tokens: int = 0
    server_rollovers: int = 0

    @property
    def avg_latency_ms(self) -> float:
        lat = [l for l in self.latencies if np.isfinite(l)]
        return 1e3 * float(np.mean(lat)) if lat else float("inf")

    @property
    def p95_latency_ms(self) -> float:
        lat = [l for l in self.latencies if np.isfinite(l)]
        return 1e3 * float(np.percentile(lat, 95)) if lat else float("inf")

    def frac_below(self, ms: float) -> float:
        lat = np.asarray(self.latencies) * 1e3
        return float(np.mean(lat < ms)) if len(lat) else 0.0

    def _latency_pct(self, p: float) -> float:
        lat = [l for l in self.latencies if np.isfinite(l)]
        return 1e3 * float(np.percentile(lat, p)) if lat else float("inf")

    @property
    def p50_latency_ms(self) -> float:
        return self._latency_pct(50)

    @property
    def p99_latency_ms(self) -> float:
        return self._latency_pct(99)

    # serving percentiles export NaN when empty (oracle rows have no
    # engine telemetry; NaN keeps them distinguishable from a real
    # zero-latency measurement in the CSV/JSON exports)
    def _serving_pct(self, vals: List[float], p: float) -> float:
        return 1e3 * float(np.percentile(vals, p)) if vals else float("nan")

    @property
    def ttft_p50_ms(self) -> float:
        return self._serving_pct(self.server_ttfts, 50)

    @property
    def ttft_p95_ms(self) -> float:
        return self._serving_pct(self.server_ttfts, 95)

    @property
    def ttft_p99_ms(self) -> float:
        return self._serving_pct(self.server_ttfts, 99)

    @property
    def queue_p50_ms(self) -> float:
        return self._serving_pct(self.server_queue_delays, 50)

    @property
    def queue_p95_ms(self) -> float:
        return self._serving_pct(self.server_queue_delays, 95)

    @property
    def queue_p99_ms(self) -> float:
        return self._serving_pct(self.server_queue_delays, 99)


# ==========================================================================
# State-machine session engine
#
# The per-frame loop is decomposed into explicit dataclass states plus
# phase functions, so the same transition logic drives both the serial
# `run_session` wrapper below and the vectorized fleet engine
# (repro.core.fleet), which interleaves a batched codec dispatch between
# the client and receiver phases:
#
#   client_encode_plan(state, t, ack)   # feedback -> CC -> ABR -> QP plan
#       |        (codec.rate_control / rate_control_batch)
#   client_record_send(state, rep)      # uplink accounting
#       |        (codec.decode / decode_delivered_batch)
#   push_arrival(state, t, latency, rx) # uplink in-flight event queue
#   server_tick(state, t)               # ingest -> feedback -> QA
#
# Event queues (uplink arrivals, downlink feedback) are heapq min-heaps
# keyed on (time, seq): O(log n) per push, with seq preserving the
# insertion order of simultaneous events (what the old stable sort did).
# ==========================================================================
@dataclasses.dataclass
class EncodePlan:
    """What the client wants encoded this tick."""
    frame: np.ndarray        # (H, W) rendered source frame
    qp_shape: np.ndarray     # (H//8, W//8) relative QP surface
    target_bits: float       # rate budget for this frame


@dataclasses.dataclass
class ClientState:
    """Uplink-side state: CC / ABR / ZeCoStream plus the downlink
    feedback queue and the client-side metric accumulators.

    `zeco` is a ZeCoStreamBank row: serial sessions own a bank of size 1;
    the fleet engine points every member at one shared N-row bank (with
    `zeco_row` selecting the member's row), so context state always lives
    in arrays."""
    cc: object
    abr: object
    zeco: ZeCoStreamBank
    zeco_row: int = 0
    confidence: float = 0.5   # belief before the first feedback arrives
    # min-heap of (t_recv, seq, confidence, TimedBoxes) in-flight feedback
    feedbacks: List[Tuple[float, int, float, Optional[TimedBoxes]]] = \
        dataclasses.field(default_factory=list)
    rates: List[float] = dataclasses.field(default_factory=list)
    confs: List[float] = dataclasses.field(default_factory=list)
    latencies: List[float] = dataclasses.field(default_factory=list)
    bits_total: float = 0.0

    @property
    def zeco_engaged(self) -> int:
        return int(self.zeco.engaged_total[self.zeco_row])


@dataclasses.dataclass
class ServerState:
    """MLLM-side state: visual memory / tracks / the open question, plus
    the uplink in-flight queue and QA bookkeeping."""
    server: OracleServer
    # min-heap of (t_arrival, seq, t_capture, frame) in-flight frames
    arrivals: List[Tuple[float, int, float, np.ndarray]] = \
        dataclasses.field(default_factory=list)
    next_feedback_t: float = 0.0
    qa_sorted: List[QASample] = dataclasses.field(default_factory=list)
    qa_i: int = 0
    qa_results: List[bool] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class SessionState:
    """Everything one client<->server session evolves over time."""
    scene: Scene
    cfg: SessionConfig
    client: ClientState
    server: ServerState
    channel: Optional[Channel] = None   # owned by ChannelBank in fleet mode
    seq: itertools.count = dataclasses.field(default_factory=itertools.count)

    @property
    def frame_hw(self) -> Tuple[int, int]:
        return (self.scene.h, self.scene.w)


def make_session_state(scene: Scene, qa_samples: List[QASample],
                       cfg: SessionConfig,
                       calibrator: Optional[PlattCalibrator] = None,
                       channel: Optional[Channel] = None) -> SessionState:
    client = ClientState(
        cc=make_cc(cfg.cc_kind),
        abr=(ReCapABR(tau=cfg.tau, gamma=cfg.gamma) if cfg.use_recap
             else CCOnlyABR()),
        zeco=ZeCoStreamBank(1, (scene.h, scene.w), tau=cfg.tau,
                            enabled=[cfg.use_zeco]))
    server = ServerState(
        server=OracleServer(scene, cfg, calibrator),
        qa_sorted=sorted(qa_samples, key=lambda q: q.t_ask))
    return SessionState(scene=scene, cfg=cfg, client=client, server=server,
                        channel=channel)


def deliver_feedback(state: SessionState, t: float) -> None:
    """1. deliver pending server->client feedback."""
    c = state.client
    while c.feedbacks and c.feedbacks[0][0] <= t:
        _, _, c.confidence, boxes_fb = heapq.heappop(c.feedbacks)
        if boxes_fb is not None:
            c.zeco.on_feedback(c.zeco_row, boxes_fb)


def build_plan(state: SessionState, t: float, rate: float) -> EncodePlan:
    """4. render + ZeCoStream QP surface for an already-chosen bitrate.

    The QP surface comes from the session's ZeCoStreamBank at N=1 — the
    exact dispatch the fleet engine runs for all N rows at once (so the
    serial and fleet plan phases share one code path)."""
    cfg, c = state.cfg, state.client
    c.rates.append(rate)
    i = int(round(t * cfg.fps))
    frame = state.scene.render(i)
    surfaces, _ = c.zeco.plan(t, np.asarray([rate]),
                              np.asarray([c.confidence]))
    return EncodePlan(frame=frame, qp_shape=surfaces[0],
                      target_bits=rate * (1.0 / cfg.fps))


def client_encode_plan(state: SessionState, t: float, ack: Dict
                       ) -> EncodePlan:
    """Client phase: deliver due feedback, run CC + ABR, shape QPs.

    (The fleet engine runs the same three sub-phases with CC and ABR
    advanced by the vectorized banks in net.cc / core.recap_abr.)"""
    deliver_feedback(state, t)
    c = state.client
    # 2. CC estimate from channel acks
    b_hat = c.cc.estimate(ack)
    # 3. ReCapABR (Eq. 1-2) or CC-follow
    rate = c.abr.update(c.confidence, b_hat)
    return build_plan(state, t, rate)


def client_record_send(state: SessionState, enc_bits: float,
                       latency: float) -> None:
    """Uplink accounting after the encoded frame is handed to the channel."""
    state.client.bits_total += enc_bits
    state.client.latencies.append(latency)


def push_arrival(state: SessionState, t: float, latency: float,
                 rx: np.ndarray) -> None:
    """Queue a decoded frame for server ingestion at its arrival time."""
    heapq.heappush(state.server.arrivals,
                   (t + latency, next(state.seq), t, rx))


def pop_due_arrivals(state: SessionState, t: float
                     ) -> List[Tuple[float, np.ndarray]]:
    """Drain (t_capture, frame) pairs that have arrived by t, in arrival
    order.  A queued frame may be a zero-arg callable: the fleet engine
    defers device->host materialization of the decoded batch until first
    ingestion."""
    due = []
    sv = state.server
    while sv.arrivals and sv.arrivals[0][0] <= t:
        _, _, t_cap, rx = heapq.heappop(sv.arrivals)
        due.append((t_cap, rx() if callable(rx) else rx))
    return due


def peek_commit(state: SessionState, t: float) -> Optional[QASample]:
    """Non-mutating mirror of `server_emit`'s QA open/commit logic:
    the QASample that `server_emit(state, t)` would commit this tick, or
    None.  The fleet's engine server mode uses this to submit all
    committing questions into the engine BEFORE one batched decode drain,
    then hands the results back through `server_emit(..., answer_fn=...)`."""
    sv = state.server
    q = sv.server.active_question
    if (q is None and sv.qa_i < len(sv.qa_sorted)
            and sv.qa_sorted[sv.qa_i].t_ask <= t):
        q = sv.qa_sorted[sv.qa_i]
    if q is not None and t >= q.t_ask + q.answer_window:
        return q
    return None


def server_emit(state: SessionState, t: float,
                answer_fn: Optional[Callable[[QASample], bool]] = None
                ) -> None:
    """Post-ingestion server phase: emit feedback, progress QA.

    `answer_fn` overrides how a committing question is answered (the
    engine server path); None keeps the oracle's lookup answer."""
    cfg, sv, c = state.cfg, state.server, state.client
    # 7. server emits feedback at its cadence
    if t >= sv.next_feedback_t and sv.server.frames_seen:
        conf, fb = sv.server.feedback(t)
        t_recv = t + cfg.inference_delay + cfg.downlink_delay
        heapq.heappush(c.feedbacks, (t_recv, next(state.seq), conf, fb))
        sv.next_feedback_t = t + cfg.feedback_period
    # 8. conversational QA: a question opens at t_ask (the server grounds
    # the queried region from then on) and the response is committed at
    # t_ask + answer_window
    if (sv.server.active_question is None and sv.qa_i < len(sv.qa_sorted)
            and sv.qa_sorted[sv.qa_i].t_ask <= t):
        sv.server.active_question = sv.qa_sorted[sv.qa_i]
        sv.qa_i += 1
    q = sv.server.active_question
    if q is not None and t >= q.t_ask + q.answer_window:
        answer = (answer_fn(q) if answer_fn is not None
                  else sv.server.answer(q))
        sv.qa_results.append(answer)
        sv.server.active_question = None
    c.confs.append(c.confidence)


def server_tick(state: SessionState, t: float) -> None:
    """Server phase: ingest arrived frames, emit feedback, progress QA.
    (The fleet engine runs the same two sub-phases, with ingestion
    batched across all sessions of a tick.)"""
    # 6. server ingests frames that have arrived by now
    for t_cap, rx in pop_due_arrivals(state, t):
        state.server.server.ingest(t_cap, rx)
    server_emit(state, t)


def step(state: SessionState, t: float) -> SessionState:
    """One frame tick of the serial state machine.

    All evolving session state lives in (and is returned through) `state`;
    the fleet engine runs the same phases with the codec and channel calls
    batched across sessions."""
    plan = client_encode_plan(state, t, state.channel.ack_stats())
    _, enc = codec.rate_control(plan.frame, plan.qp_shape,
                                np.float32(plan.target_bits),
                                probe_stride=state.cfg.rc_probe_stride)
    bits = float(enc.bits)
    # 5. ship over the uplink
    rep = state.channel.send_frame(t, bits)
    client_record_send(state, bits, rep.latency)
    if np.isfinite(rep.latency):
        # receiver decodes the (possibly partially dropped) frame
        if rep.dropped and rep.bits_delivered < rep.bits_sent:
            # partial loss: re-quantize the cached coefficients toward the
            # delivered budget (no second DCT + full bisection)
            enc2 = codec.requantize(
                enc.coeffs, enc.qp_blocks, plan.qp_shape,
                np.float32(max(rep.bits_delivered, 1e3)),
                probe_stride=state.cfg.rc_probe_stride)
            rx = codec.decode(enc2)
        else:
            rx = codec.decode(enc)
        push_arrival(state, t, rep.latency, np.asarray(rx))
    server_tick(state, t)
    return state


def finalize(state: SessionState, reports,
             answer_fn: Optional[Callable[[QASample], bool]] = None,
             server_telemetry: Optional[Dict[str, List[float]]] = None,
             span: Optional[float] = None) -> SessionMetrics:
    """Flush open QA and assemble SessionMetrics from the final state.

    `answer_fn` replaces the oracle answer for the end-of-run flush (the
    engine server path); `server_telemetry` carries the bridge's
    per-session ttft/queue/confidence lists into the metrics; `span`
    overrides the bitrate-normalization window (churn sessions live
    shorter than `cfg.duration`)."""
    cfg, sv, c = state.cfg, state.server, state.client
    _answer = answer_fn if answer_fn is not None else sv.server.answer
    # flush: commit any open question and ask the rest at session end
    if sv.server.active_question is not None:
        sv.qa_results.append(_answer(sv.server.active_question))
        sv.server.active_question = None
    while sv.qa_i < len(sv.qa_sorted):
        sv.qa_results.append(_answer(sv.qa_sorted[sv.qa_i]))
        sv.qa_i += 1
    dur = cfg.duration if span is None else max(span, 1.0 / cfg.fps)
    return SessionMetrics(
        **(server_telemetry or {}),
        latencies=c.latencies,
        accuracy=(float(np.mean(sv.qa_results)) if sv.qa_results else 1.0),
        n_qa=len(sv.qa_results),
        avg_bitrate=c.bits_total / dur,
        bandwidth_used=sum(r.bits_sent for r in reports) / dur,
        confidences=c.confs,
        rates=c.rates,
        zeco_engaged_frames=c.zeco_engaged,
        qa_results=sv.qa_results,
        dropped_frames=sum(r.dropped for r in reports),
    )


def run_session(scene: Scene, qa_samples: List[QASample], trace: Trace,
                cfg: SessionConfig,
                calibrator: Optional[PlattCalibrator] = None
                ) -> SessionMetrics:
    """Serial compatibility wrapper: one session through the state machine."""
    state = make_session_state(scene, qa_samples, cfg, calibrator,
                               channel=Channel(trace))
    n_frames = int(cfg.duration * cfg.fps)
    dt = 1.0 / cfg.fps
    for i in range(n_frames):
        step(state, i * dt)
    return finalize(state, state.channel.reports)

"""Batched glyph-ingestion kernel: the server-side decode-glyph/margin
math as jitted jnp ops.

`OracleServer.ingest` (serial) and the fleet's `_ingest_batched` used to
carry two NumPy copies of the threshold-cell-means arithmetic; both now
funnel their patches through `glyph_stats_batch`, one compiled kernel
per glyph geometry (static `cell`).  The on-device rollout
(repro.core.rollout) inlines the same arithmetic into its scan body via
`glyph_stats_core`, so the ported kernel is what every execution mode's
server sees.

Determinism contract (the fleet/rollout parity requirement): every
reduction is either exactly order-independent (min / max / the 12-term
integer code sum) or written as a fixed sequence of elementwise adds
(the cell means and the 16-cell margin mean), so per-record results are
bit-identical at any batch size and under any XLA fusion — B=1 serial
calls equal rows of a B=G fleet batch.  Scalar arithmetic stays float32
exactly as in `scenes.decode_glyph`, with the final margin product
promoted to float64 (the serial path's python-float multiply).
`scenes.decode_glyph` itself is untouched — the DeViBench degradation
grid keeps its pure-NumPy reference path.

x64 handling: the float64 promotion needs an `enable_x64()` scope, but
only while TRACING — a compiled executable keeps its dtypes regardless
of the ambient config.  `glyph_stats_batch` therefore AOT-compiles one
executable per (cell, padded batch) under the context and caches it;
steady-state calls invoke the cached executable directly and never
re-enter the context manager.  (Skipping the context around a plain
`jax.jit` call would NOT work: `jax_enable_x64` is part of the jit
cache key, so the call would silently retrace with the promotion
demoted to float32.)
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro.video.scenes import _PAYLOAD_IDX, _PAYLOAD_WEIGHTS, GLYPH_GRID


def glyph_stats_core(patches: jnp.ndarray, cell: int
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(B, S, S) float32 patches of one glyph geometry (S = 4*cell) ->
    (codes (B,) int64, margins (B,) float64).

    Pure traceable jnp — no jit, no x64 context: the caller decides the
    staging (the AOT cache below, or inlining into the rollout's
    enable_x64-traced scan body)."""
    g = GLYPH_GRID
    p = patches[:, :g * cell, :g * cell].reshape(-1, g, cell, g, cell)
    # cell means: fixed-order elementwise adds over the cell x cell
    # sub-pixels (unrolled — cell <= 12), then one float32 divide
    acc = jnp.zeros((p.shape[0], g, g), jnp.float32)
    for j in range(cell * cell):
        acc = acc + p[:, :, j // cell, :, j % cell]
    cells = acc / jnp.float32(cell * cell)
    lo = jnp.min(cells, axis=(1, 2))
    hi = jnp.max(cells, axis=(1, 2))
    thresh = 0.5 * (lo + hi)
    denom = jnp.maximum(hi - lo, 1e-6)
    mc = jnp.clip(jnp.abs(cells - thresh[:, None, None])
                  / (0.5 * denom)[:, None, None], 0.0, 1.0)
    flat = mc.reshape(-1, g * g)
    macc = jnp.zeros_like(lo)
    for j in range(g * g):
        macc = macc + flat[:, j]
    margin = macc / jnp.float32(g * g)
    contrast = jnp.clip((hi - lo) / 0.5, 0.0, 1.0)
    margin64 = margin.astype(jnp.float64) * contrast.astype(jnp.float64)
    hard = cells.reshape(-1, g * g)[:, _PAYLOAD_IDX] > thresh[:, None]
    codes = jnp.sum(hard * jnp.asarray(_PAYLOAD_WEIGHTS), axis=1)
    return codes, margin64


# back-compat jitted alias (tests and callers that manage x64 themselves)
_glyph_stats = jax.jit(glyph_stats_core, static_argnames=("cell",))

# AOT-compiled executables keyed by (cell, padded patch shape); a
# compiled executable is config-independent, so steady-state calls skip
# enable_x64 entirely.
_COMPILED: Dict[Tuple[int, Tuple[int, ...]], "jax.stages.Compiled"] = {}


def _compiled_glyph_stats(cell: int, shape: Tuple[int, ...]):
    key = (cell, shape)
    fn = _COMPILED.get(key)
    if fn is None:
        with enable_x64():
            fn = (jax.jit(functools.partial(glyph_stats_core, cell=cell))
                  .lower(jax.ShapeDtypeStruct(shape, jnp.float32))
                  .compile())
        _COMPILED[key] = fn
    return fn


def glyph_stats_batch(patches: np.ndarray, cell: int
                      ) -> Tuple[np.ndarray, np.ndarray]:
    """Host entry: stack of same-geometry patches -> (codes int64,
    margins float64) NumPy arrays.  Compiled once per (cell, padded
    batch) under enable_x64 so the margin promotion and the weight sum
    really run in 64-bit; steady-state calls hit the `_COMPILED` cache
    and never re-enter the context manager (see the module docstring).
    The batch is padded to the next power of two so the executable
    count stays logarithmic in the tick's ingestion load — per-record
    results are batch-size-invariant, so the zero pad rows are simply
    discarded."""
    patches = np.asarray(patches, np.float32)
    b = patches.shape[0]
    bp = 1 << max(b - 1, 0).bit_length()
    if bp != b:
        patches = np.concatenate(
            [patches, np.zeros((bp - b,) + patches.shape[1:], np.float32)])
    fn = _compiled_glyph_stats(int(cell), patches.shape)
    codes, margins = fn(patches)
    return np.asarray(codes)[:b], np.asarray(margins)[:b]

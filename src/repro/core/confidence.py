"""Response-confidence feedback C_t (paper §4.2).

The paper prompts the MLLM to self-report a confidence score via
in-context learning.  Our serving stack owns the model, so the default
("logit") mode derives C_t from telemetry the sampler already produces —
mean top-1 probability and normalized entropy of the answer span — at
zero extra FLOPs (a beyond-paper engineering win, DESIGN.md §3).  The
"oracle" mode consumes the DeViBench glyph-detector margin.  Both go
through a Platt calibration fit on the DeViBench validation split, which
is what the paper's §6.2 validation set is for.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple

import numpy as np


def raw_score_from_telemetry(top1_probs: Sequence[float],
                             entropies: Sequence[float],
                             vocab: int) -> float:
    """Uncalibrated confidence in [0,1] from answer-span sampler telemetry."""
    if len(top1_probs) == 0:
        return 0.0
    p = float(np.mean(top1_probs))
    h = float(np.mean(entropies)) / max(math.log(vocab), 1e-6)
    return float(np.clip(0.5 * (p + (1.0 - h)), 0.0, 1.0))


@dataclasses.dataclass
class PlattCalibrator:
    """sigmoid(a * score + b) fit by Newton-damped logistic regression."""

    a: float = 6.0
    b: float = -3.0

    def fit(self, scores: np.ndarray, correct: np.ndarray,
            iters: int = 200, lr: float = 0.5) -> "PlattCalibrator":
        s = np.asarray(scores, np.float64)
        y = np.asarray(correct, np.float64)
        a, b = self.a, self.b
        for _ in range(iters):
            z = a * s + b
            p = 1.0 / (1.0 + np.exp(-z))
            ga = np.mean((p - y) * s)
            gb = np.mean(p - y)
            a -= lr * ga * 8.0
            b -= lr * gb * 2.0
        self.a, self.b = float(a), float(b)
        return self

    def __call__(self, score: float) -> float:
        return float(1.0 / (1.0 + np.exp(-(self.a * score + self.b))))

    def batch(self, scores: np.ndarray) -> np.ndarray:
        """Vectorized calibration: scores (...,) -> P(correct) (...,)
        in [0, 1] — the DeViBench grid / reliability-curve path, one
        array op instead of a per-score loop."""
        scores = np.asarray(scores, np.float64)
        return 1.0 / (1.0 + np.exp(-(self.a * scores + self.b)))


@dataclasses.dataclass
class ConfidenceHead:
    mode: str = "oracle"           # oracle | logit
    calibrator: Optional[PlattCalibrator] = None

    def __post_init__(self):
        if self.calibrator is None:
            self.calibrator = PlattCalibrator()

    def from_margin(self, margin: float) -> float:
        return self.calibrator(margin)

    def from_telemetry(self, top1_probs, entropies, vocab: int) -> float:
        return self.calibrator(
            raw_score_from_telemetry(top1_probs, entropies, vocab))

"""ReCapABR — Response-Capability-aware Adaptive Bitrate (paper §4).

Implements Eq. (1)-(2) exactly:

    delta_t = (tau - C_t) / tau                       # normalized gap
    w_t     = delta_t * |delta_t|^(gamma-1)           # Eq. 1
    R_{t+1} = min(B_t, R_t + w_t * (B_t - R_t))       # Eq. 2

tau=0.8 and gamma=2 are the validation-set-tuned defaults (§6.2).  When
C_t > tau the weight goes negative and the bitrate voluntarily backs off
below the CC estimate — the "maximum margin" headroom that absorbs
bandwidth drops (Fig. 9).  When congestion pushes B_t below R_t the min()
caps immediately.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class ReCapABR:
    tau: float = 0.8
    gamma: float = 2.0
    min_rate: float = 150e3     # never starve the encoder entirely
    init_rate: float = 1e6

    def __post_init__(self):
        self.rate = self.init_rate
        self.last_confidence = None

    def weight(self, confidence: float) -> float:
        """Eq. 1."""
        delta = (self.tau - confidence) / self.tau
        return delta * abs(delta) ** (self.gamma - 1.0)

    def update(self, confidence: float, bw_estimate: float) -> float:
        """Eq. 2: next-step bitrate from confidence + CC estimate."""
        self.last_confidence = confidence
        w = self.weight(confidence)
        r = min(bw_estimate, self.rate + w * (bw_estimate - self.rate))
        self.rate = max(r, self.min_rate)
        return self.rate


@dataclasses.dataclass
class CCOnlyABR:
    """The WebRTC baseline: bitrate blindly follows the CC estimate."""

    init_rate: float = 1e6
    min_rate: float = 150e3

    def __post_init__(self):
        self.rate = self.init_rate
        self.last_confidence = None

    def update(self, confidence: float, bw_estimate: float) -> float:
        del confidence
        self.rate = max(bw_estimate, self.min_rate)
        return self.rate


# --------------------------------------------------------------------------
# Vectorized banks: Eq. 1-2 elementwise over (M,) session arrays, for the
# fleet engine.  Same arithmetic as the scalar classes above (per-session
# tau; gamma=2 keeps |delta|^(gamma-1) an exact no-op power).
# --------------------------------------------------------------------------
class ReCapABRBank:
    def __init__(self, taus, gammas, min_rate: float = 150e3,
                 init_rate: float = 1e6):
        self.tau = np.asarray(taus, np.float64)
        self.gamma = np.asarray(gammas, np.float64)
        self.min_rate = min_rate
        self.rate = np.full(len(self.tau), init_rate)

    def update(self, confidence: np.ndarray, bw_estimate: np.ndarray
               ) -> np.ndarray:
        delta = (self.tau - confidence) / self.tau
        w = delta * np.abs(delta) ** (self.gamma - 1.0)
        r = np.minimum(bw_estimate,
                       self.rate + w * (bw_estimate - self.rate))
        self.rate = np.maximum(r, self.min_rate)
        return self.rate


class CCOnlyABRBank:
    def __init__(self, m: int, min_rate: float = 150e3,
                 init_rate: float = 1e6):
        self.min_rate = min_rate
        self.rate = np.full(m, init_rate)

    def update(self, confidence: np.ndarray, bw_estimate: np.ndarray
               ) -> np.ndarray:
        del confidence
        self.rate = np.maximum(bw_estimate, self.min_rate)
        return self.rate

"""ReCapABR — Response-Capability-aware Adaptive Bitrate (paper §4).

Implements Eq. (1)-(2) exactly:

    delta_t = (tau - C_t) / tau                       # normalized gap
    w_t     = delta_t * |delta_t|^(gamma-1)           # Eq. 1
    R_{t+1} = min(B_t, R_t + w_t * (B_t - R_t))       # Eq. 2

tau=0.8 and gamma=2 are the validation-set-tuned defaults (§6.2).  When
C_t > tau the weight goes negative and the bitrate voluntarily backs off
below the CC estimate — the "maximum margin" headroom that absorbs
bandwidth drops (Fig. 9).  When congestion pushes B_t below R_t the min()
caps immediately.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class ReCapABR:
    tau: float = 0.8
    gamma: float = 2.0
    min_rate: float = 150e3     # never starve the encoder entirely
    init_rate: float = 1e6

    def __post_init__(self):
        self.rate = self.init_rate
        self.last_confidence = None

    def weight(self, confidence: float) -> float:
        """Eq. 1."""
        delta = (self.tau - confidence) / self.tau
        return delta * abs(delta) ** (self.gamma - 1.0)

    def update(self, confidence: float, bw_estimate: float) -> float:
        """Eq. 2: next-step bitrate from confidence + CC estimate."""
        self.last_confidence = confidence
        w = self.weight(confidence)
        r = min(bw_estimate, self.rate + w * (bw_estimate - self.rate))
        self.rate = max(r, self.min_rate)
        return self.rate


@dataclasses.dataclass
class CCOnlyABR:
    """The WebRTC baseline: bitrate blindly follows the CC estimate."""

    init_rate: float = 1e6
    min_rate: float = 150e3

    def __post_init__(self):
        self.rate = self.init_rate
        self.last_confidence = None

    def update(self, confidence: float, bw_estimate: float) -> float:
        del confidence
        self.rate = max(bw_estimate, self.min_rate)
        return self.rate


# --------------------------------------------------------------------------
# Vectorized banks: Eq. 1-2 elementwise over (M,) session arrays, for the
# fleet engine.  Same arithmetic as the scalar classes above (per-session
# tau; gamma=2 keeps |delta|^(gamma-1) an exact no-op power).
# --------------------------------------------------------------------------
class ReCapABRBank:
    def __init__(self, taus, gammas, min_rate: float = 150e3,
                 init_rate: float = 1e6):
        self.tau = np.asarray(taus, np.float64)
        self.gamma = np.asarray(gammas, np.float64)
        self.min_rate = min_rate
        self.init_rate = init_rate
        self.rate = np.full(len(self.tau), init_rate)

    def reset_lane(self, i: int) -> None:
        """Restart lane i from the cold-start rate (churn slot revival)."""
        self.rate[i] = self.init_rate

    def update(self, confidence: np.ndarray, bw_estimate: np.ndarray
               ) -> np.ndarray:
        delta = (self.tau - confidence) / self.tau
        w = delta * np.abs(delta) ** (self.gamma - 1.0)
        r = np.minimum(bw_estimate,
                       self.rate + w * (bw_estimate - self.rate))
        self.rate = np.maximum(r, self.min_rate)
        return self.rate


class CCOnlyABRBank:
    def __init__(self, m: int, min_rate: float = 150e3,
                 init_rate: float = 1e6):
        self.min_rate = min_rate
        self.init_rate = init_rate
        self.rate = np.full(m, init_rate)

    def reset_lane(self, i: int) -> None:
        """Restart lane i from the cold-start rate (churn slot revival)."""
        self.rate[i] = self.init_rate

    def update(self, confidence: np.ndarray, bw_estimate: np.ndarray
               ) -> np.ndarray:
        del confidence
        self.rate = np.maximum(bw_estimate, self.min_rate)
        return self.rate


# --------------------------------------------------------------------------
# Fitting tau / gamma / the bitrate cap from DeViBench saturation curves
# (§6.2: the validation split tunes the hyperparameters).  Pure array ops
# over the stacked (bitrate, accuracy/confidence) curves the vectorized
# DeViBench engine emits — the benchmark -> saturation point -> ABR cap
# loop of the paper's pipeline.
# --------------------------------------------------------------------------
def saturation_point(kbps, acc, frac: float = 0.95) -> float:
    """Smallest bitrate whose accuracy reaches `frac` of the curve's
    maximum — the Fig. 3 knee (the paper's 968 Kbps)."""
    kbps = np.asarray(kbps, np.float64)
    acc = np.asarray(acc, np.float64)
    if kbps.shape != acc.shape or kbps.ndim != 1 or len(kbps) == 0:
        raise ValueError("saturation_point needs matching 1-D curves")
    order = np.argsort(kbps)
    kbps, acc = kbps[order], acc[order]
    ok = acc >= frac * acc.max()
    return float(kbps[int(np.argmax(ok))])


def fit_recap_params(kbps, confidence, accuracy=None, *,
                     min_rate: float = 150e3, frac: float = 0.95,
                     gammas=None, horizon: int = 48,
                     settle_tol: float = 0.05):
    """Fit (tau, gamma, cap) from a DeViBench saturation curve.

    `kbps`/`accuracy` locate the saturation knee; `confidence` is the
    calibrated mean confidence at each ladder rung (so tau — the Eq. 1
    target — is the confidence the system sees right at the knee, and
    driving confidence back to tau drives bitrate to the knee).  gamma
    is picked by simulating the Eq. 1-2 recursion against a bandwidth
    ceiling at the knee for every candidate at once (vectorized over
    the gamma axis) and keeping the fastest settle into the +-
    `settle_tol` band; ties prefer the paper's gamma=2.  The returned
    cap is never below `min_rate`."""
    kbps = np.asarray(kbps, np.float64)
    confidence = np.asarray(confidence, np.float64)
    if accuracy is None:
        accuracy = confidence
    accuracy = np.asarray(accuracy, np.float64)
    knee = saturation_point(kbps, accuracy, frac)
    order = np.argsort(kbps)
    tau = float(np.clip(np.interp(knee, kbps[order], confidence[order]),
                        0.5, 0.95))
    cap_bps = max(knee * 1e3, min_rate)

    if gammas is None:
        gammas = np.linspace(1.0, 4.0, 13)
    gammas = np.asarray(gammas, np.float64)
    rate = np.full(len(gammas), min_rate)
    bw = cap_bps
    settle = np.full(len(gammas), horizon, np.int64)
    for step in range(horizon):
        conf = np.interp(rate / 1e3, kbps[order], confidence[order])
        delta = (tau - conf) / tau
        w = delta * np.abs(delta) ** (gammas - 1.0)
        rate = np.maximum(np.minimum(bw, rate + w * (bw - rate)), min_rate)
        # settle = first step of the final uninterrupted in-band run
        inside = np.abs(rate - cap_bps) <= settle_tol * cap_bps
        settle = np.where(
            inside, np.where(settle == horizon, step + 1, settle), horizon)
    # fastest settle wins; among ties prefer gamma closest to 2 (§6.2)
    best = settle == settle.min()
    gamma = float(gammas[best][np.argmin(np.abs(gammas[best] - 2.0))])
    return {"tau": tau, "gamma": gamma, "cap_bps": float(cap_bps),
            "knee_kbps": float(knee),
            "settle_steps": int(settle.min())}

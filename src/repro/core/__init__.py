# The paper's primary contribution — the Artic system: ReCapABR +
# ZeCoStream + the trace-driven session engines.
#
# repro.core.session — one client<->MLLM session as an explicit state
#   machine (ClientState / ServerState, heapq event queues, step()).
# repro.core.fleet — N sessions in lockstep ticks with one batched
#   codec dispatch + one vectorized channel advance per tick.
# repro.core.scenario — declarative ScenarioSpec workloads compiled into
#   auto-partitioned fleet cohorts (run via repro.api.run_scenarios).

"""Open-loop session churn: arrivals and departures over a slotted fleet.

Every fixed-N scenario runs its sessions to completion; production
traffic is an *arrival process*.  This module adds the open-loop
workload layer the ROADMAP names: seeded Poisson or diurnal
(sinusoidally-modulated, thinned) arrivals, seeded session-lifetime
distributions, and an admission queue that places arriving sessions into
free fleet slots by reusing the masked-dead-session machinery — a
departed session's slot goes dead (`Fleet.deactivate`), an arrival
revives it with fresh scene/trace/CC state (`Fleet.activate`).  Under
`server="engine"` the revival opens a fresh engine session in
queue-or-wait mode, so a full engine delays admission (stamped into
telemetry) instead of crashing.

Entry points:

    ScenarioSpec(workload="churn", churn_kwargs=dict(rate=..., slots=...))
        routed here by `run_scenarios` -> `ChurnRunResult`.
    run_churn(spec)      one open-loop run -> `ChurnResult` with
                         per-session records and steady-state metrics
                         (sustained sessions/sec, p50/p95/p99 latency
                         and TTFT, admission delay, queue depth).

Determinism contract: arrivals/lifetimes come from seeded NumPy
generators, admission is FIFO into the lowest free slot at tick
boundaries, and every per-lane bank state is reset at revival — two runs
of the same spec are digest-identical (`ChurnResult.digest`), and a
slot's successive tenants never observe each other
(tests/test_churn.py).

Every arrival derives from the base spec with per-arrival seed offsets
(scene/trace/session seeds shift by the arrival index); the structural
knobs — fps, duration, frame size, probe stride, cc_kind, system — stay
fixed, because CC/ABR bank *membership* inside the fleet is fixed at
construction (only per-lane state resets).
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import json
from typing import Any, Dict, List, Optional

import numpy as np

ARRIVAL_KINDS = ("poisson", "diurnal")
LIFETIME_KINDS = ("exponential", "fixed", "uniform")

CHURN_RESULT_SCHEMA = "artic.churn.run_result/v1"


@dataclasses.dataclass(frozen=True)
class ChurnConfig:
    """Arrival/lifetime/slot knobs of one open-loop run (the thawed
    `ScenarioSpec.churn_kwargs`)."""
    arrival: str = "poisson"       # ARRIVAL_KINDS
    rate: float = 1.0              # mean arrivals per second
    lifetime: str = "exponential"  # LIFETIME_KINDS
    mean_lifetime: float = 4.0     # seconds
    min_lifetime: float = 1.0      # floor: shorter than a feedback round
    #   a session measures nothing
    slots: int = 4                 # concurrent fleet slots
    seed: int = 0
    # diurnal shape: rate(t) = rate * (1 + depth * sin(2*pi*t / period))
    period: float = 20.0
    depth: float = 0.8
    max_arrivals: int = 512        # hard cap (runaway-rate backstop)

    def __post_init__(self):
        if self.arrival not in ARRIVAL_KINDS:
            raise ValueError(f"unknown arrival process {self.arrival!r}; "
                             f"one of {ARRIVAL_KINDS}")
        if self.lifetime not in LIFETIME_KINDS:
            raise ValueError(f"unknown lifetime kind {self.lifetime!r}; "
                             f"one of {LIFETIME_KINDS}")
        if self.rate <= 0:
            raise ValueError(f"arrival rate must be positive, got {self.rate}")
        if self.slots < 1:
            raise ValueError(f"slots must be >= 1, got {self.slots}")
        if not (0.0 < self.min_lifetime <= self.mean_lifetime):
            raise ValueError("need 0 < min_lifetime <= mean_lifetime; got "
                             f"{self.min_lifetime} / {self.mean_lifetime}")
        if not (0.0 <= self.depth <= 1.0):
            raise ValueError(f"diurnal depth must be in [0, 1], "
                             f"got {self.depth}")
        if self.period <= 0:
            raise ValueError(f"diurnal period must be positive, "
                             f"got {self.period}")
        if self.max_arrivals < 1:
            raise ValueError("max_arrivals must be >= 1")

    @classmethod
    def from_spec(cls, spec) -> "ChurnConfig":
        from repro.core.scenario import _thaw
        return cls(**_thaw(spec.churn_kwargs))


def arrival_times(cfg: ChurnConfig, duration: float) -> np.ndarray:
    """Seeded arrival timestamps in [0, duration), sorted ascending.

    Poisson: homogeneous exponential gaps at `cfg.rate`.  Diurnal:
    non-homogeneous Poisson with intensity
    rate * (1 + depth * sin(2*pi*t / period)) via thinning against the
    peak rate — both deterministic functions of `cfg.seed`."""
    rng = np.random.default_rng(cfg.seed)
    out: List[float] = []
    if cfg.arrival == "poisson":
        t = rng.exponential(1.0 / cfg.rate)
        while t < duration and len(out) < cfg.max_arrivals:
            out.append(t)
            t += rng.exponential(1.0 / cfg.rate)
        return np.asarray(out)
    peak = cfg.rate * (1.0 + cfg.depth)
    t = 0.0
    while len(out) < cfg.max_arrivals:
        t += rng.exponential(1.0 / peak)
        if t >= duration:
            break
        lam = cfg.rate * (1.0 + cfg.depth * np.sin(2 * np.pi * t
                                                   / cfg.period))
        if rng.random() * peak <= lam:
            out.append(t)
    return np.asarray(out)


def sample_lifetimes(cfg: ChurnConfig, n: int) -> np.ndarray:
    """Seeded session lifetimes (seconds), floored at `min_lifetime`.
    A separate stream from the arrivals (seed + 1), so changing the
    arrival count does not reshuffle lifetimes."""
    rng = np.random.default_rng(cfg.seed + 1)
    if cfg.lifetime == "exponential":
        life = rng.exponential(cfg.mean_lifetime, n)
    elif cfg.lifetime == "fixed":
        life = np.full(n, cfg.mean_lifetime)
    else:  # uniform, symmetric about the mean
        life = rng.uniform(cfg.min_lifetime,
                           2.0 * cfg.mean_lifetime - cfg.min_lifetime, n)
    return np.maximum(life, cfg.min_lifetime)


# --------------------------------------------------------------------------
# Results
# --------------------------------------------------------------------------
@dataclasses.dataclass
class ChurnSessionRecord:
    """One served session's lifecycle + its finalized SessionMetrics."""
    index: int          # arrival index
    slot: int
    arrival: float      # offered time
    admitted: float     # tick the session got a slot
    lifetime: float     # sampled lifetime (seconds)
    departed: float = float("nan")   # actual close time (clipped to run end)
    metrics: Any = None              # SessionMetrics once departed

    @property
    def admission_delay(self) -> float:
        return self.admitted - self.arrival


def _pct(vals: List[float], p: float) -> float:
    return 1e3 * float(np.percentile(vals, p)) if vals else float("nan")


@dataclasses.dataclass
class ChurnResult:
    """One open-loop run: per-session records + steady-state metrics."""
    spec: Any                       # the churn ScenarioSpec
    config: ChurnConfig
    records: List[ChurnSessionRecord]   # served sessions, arrival order
    offered: int                    # arrivals generated
    unserved: int                   # still queued when the run ended
    queue_depth: List[int]          # admission-queue depth per tick
    duration: float

    # -- steady-state aggregates ---------------------------------------
    @property
    def served(self) -> int:
        return len(self.records)

    @property
    def sessions_per_sec(self) -> float:
        return self.served / self.duration

    def _latencies(self) -> List[float]:
        return [l for r in self.records for l in r.metrics.latencies
                if np.isfinite(l)]

    def _ttfts(self) -> List[float]:
        return [v for r in self.records for v in r.metrics.server_ttfts]

    def _admissions(self) -> List[float]:
        return [r.admission_delay for r in self.records]

    def summary(self) -> Dict[str, float]:
        lat, ttft, adm = self._latencies(), self._ttfts(), self._admissions()
        depth = np.asarray(self.queue_depth) if self.queue_depth else \
            np.zeros(1)
        return {
            "offered_sessions": float(self.offered),
            "served_sessions": float(self.served),
            "unserved_sessions": float(self.unserved),
            "offered_per_sec": self.offered / self.duration,
            "sessions_per_sec": self.sessions_per_sec,
            "latency_p50_ms": _pct(lat, 50),
            "latency_p95_ms": _pct(lat, 95),
            "latency_p99_ms": _pct(lat, 99),
            "ttft_p50_ms": _pct(ttft, 50),
            "ttft_p95_ms": _pct(ttft, 95),
            "ttft_p99_ms": _pct(ttft, 99),
            "admission_p50_ms": _pct(adm, 50),
            "admission_p95_ms": _pct(adm, 95),
            "admission_p99_ms": _pct(adm, 99),
            "queue_depth_peak": float(depth.max()),
            "queue_depth_mean": float(depth.mean()),
            "accuracy_mean": (float(np.mean([r.metrics.accuracy
                                             for r in self.records]))
                              if self.records else float("nan")),
        }

    def digest(self) -> str:
        """Content digest over every served session's full telemetry —
        two runs of the same spec must match."""
        payload = [[r.index, r.slot,
                    float(r.arrival).hex(), float(r.admitted).hex(),
                    float(r.departed).hex(),
                    [float(v).hex() for v in r.metrics.latencies],
                    [bool(b) for b in r.metrics.qa_results],
                    [float(v).hex() for v in r.metrics.server_ttfts],
                    [float(v).hex() for v in r.metrics.server_queue_delays],
                    [int(r.metrics.server_evictions),
                     int(r.metrics.server_evicted_tokens),
                     int(r.metrics.server_rollovers)]]
                   for r in self.records]
        payload.append([int(d) for d in self.queue_depth])
        return hashlib.sha256(
            json.dumps(payload).encode()).hexdigest()

    def to_dict(self) -> Dict[str, Any]:
        return {"spec": self.spec.to_dict(),
                "config": dataclasses.asdict(self.config),
                "offered": self.offered,
                "served": self.served,
                "unserved": self.unserved,
                "duration": self.duration,
                "queue_depth": [int(d) for d in self.queue_depth],
                "summary": self.summary(),
                "digest": self.digest(),
                "sessions": [{"index": r.index, "slot": r.slot,
                              "arrival": r.arrival,
                              "admitted": r.admitted,
                              "departed": r.departed,
                              "lifetime": r.lifetime,
                              "accuracy": float(r.metrics.accuracy),
                              "n_qa": int(r.metrics.n_qa)}
                             for r in self.records]}


@dataclasses.dataclass
class ChurnRunResult:
    """`run_scenarios` output for workload='churn' specs (one
    ChurnResult per spec, input order)."""
    results: List[ChurnResult]

    def __len__(self) -> int:
        return len(self.results)

    def summaries(self) -> List[Dict[str, float]]:
        return [r.summary() for r in self.results]

    def digest(self) -> str:
        return hashlib.sha256(
            "".join(r.digest() for r in self.results).encode()).hexdigest()

    def to_json(self, path: Optional[str] = None) -> Dict[str, Any]:
        doc = {"schema": CHURN_RESULT_SCHEMA,
               "n_scenarios": len(self.results),
               "scenarios": [r.to_dict() for r in self.results]}
        if path is not None:
            with open(path, "w") as f:
                json.dump(doc, f, indent=1)
        return doc


def validate_churn_result_json(doc: Dict[str, Any]) -> None:
    """Raise ValueError unless `doc` matches CHURN_RESULT_SCHEMA."""
    def need(cond, msg):
        if not cond:
            raise ValueError(f"churn result schema violation: {msg}")

    need(doc.get("schema") == CHURN_RESULT_SCHEMA,
         f"schema tag {doc.get('schema')!r} != {CHURN_RESULT_SCHEMA!r}")
    scen = doc.get("scenarios")
    need(isinstance(scen, list) and len(scen) == doc.get("n_scenarios"),
         "scenarios list missing or length != n_scenarios")
    for i, rec in enumerate(scen):
        for key in ("spec", "config", "offered", "served", "unserved",
                    "summary", "digest", "sessions"):
            need(key in rec, f"scenario {i}: missing key {key!r}")
        need(rec["served"] == len(rec["sessions"]),
             f"scenario {i}: served != len(sessions)")
        for key in ("sessions_per_sec", "latency_p50_ms", "latency_p95_ms",
                    "latency_p99_ms", "ttft_p99_ms", "admission_p95_ms",
                    "queue_depth_peak"):
            need(isinstance(rec["summary"].get(key), (int, float)),
                 f"scenario {i}: summary {key!r} missing or non-numeric")


# --------------------------------------------------------------------------
# The open-loop driver
# --------------------------------------------------------------------------
def _arrival_member(spec, idx: int, calibrator, t_admit: float,
                    t_depart: float):
    """Materialize arrival `idx` as a FleetSession: per-arrival seed
    offsets over the base spec, with its QA restricted to the window the
    session is actually live for (global-time QA policies generate over
    the whole run)."""
    from repro.core.scenario import build_session

    variant = spec.with_(workload="fixed", churn_kwargs=(),
                         seed=spec.seed + idx,
                         scene_seed=spec.scene_seed + idx,
                         trace_seed=spec.trace_seed + idx,
                         tag=f"{spec.tag or 'churn'}-a{idx}")
    member = build_session(variant, calibrator)
    qa = [q for q in member.qa_samples
          if t_admit <= q.t_ask and q.t_ask + q.answer_window <= t_depart]
    return dataclasses.replace(member, qa_samples=qa)


def run_churn(spec, *, calibrator=None, fused_plan: bool = False
              ) -> ChurnResult:
    """Run one open-loop churn scenario to completion.

    Per tick, in order: departures free their slots, new arrivals join
    the FIFO admission queue, queued arrivals admit into free slots
    (lowest slot first), then the fleet ticks.  Sessions still live at
    the run end are closed at `spec.duration`; arrivals still queued
    count as `unserved`."""
    from repro.core.fleet import Fleet
    from repro.core.scenario import _thaw, build_session

    if spec.workload != "churn":
        raise ValueError("run_churn needs a workload='churn' spec")
    cfg = ChurnConfig.from_spec(spec)
    duration = float(spec.duration)
    n_frames = int(duration * spec.fps)
    dt = 1.0 / spec.fps
    arrivals = arrival_times(cfg, duration)
    lifetimes = sample_lifetimes(cfg, len(arrivals))

    # the fleet starts as `slots` placeholder members (no QA) that are
    # closed before tick 0 — every slot begins dead, every real session
    # enters through the same activate() admission path
    placeholder = build_session(
        spec.with_(workload="fixed", churn_kwargs=(), qa="none",
                   qa_kwargs=(), tag="placeholder"), calibrator)
    fleet = Fleet([placeholder] * cfg.slots, server=spec.server,
                  engine_cfg=_thaw(spec.engine_kwargs),
                  fused_plan=fused_plan)
    for k in range(cfg.slots):
        fleet.deactivate(k, 0.0)

    records: List[Optional[ChurnSessionRecord]] = [None] * len(arrivals)
    active: Dict[int, int] = {}       # slot -> arrival index
    depart_at: Dict[int, float] = {}
    queue: "collections.deque[int]" = collections.deque()
    depth: List[int] = []
    ai = 0
    for i in range(n_frames):
        t = i * dt
        for k in sorted(active):
            if depart_at[k] <= t:
                idx = active.pop(k)
                del depart_at[k]
                m = fleet.deactivate(k, t)
                records[idx].departed = t
                records[idx].metrics = m
        while ai < len(arrivals) and arrivals[ai] <= t:
            queue.append(ai)
            ai += 1
        for k in range(cfg.slots):
            if not queue:
                break
            if fleet.alive[k]:
                continue
            idx = queue.popleft()
            t_dep = min(t + float(lifetimes[idx]), duration)
            member = _arrival_member(spec, idx, calibrator, t, t_dep)
            fleet.activate(k, member, t)
            active[k] = idx
            depart_at[k] = t_dep
            records[idx] = ChurnSessionRecord(
                index=idx, slot=k, arrival=float(arrivals[idx]),
                admitted=t, lifetime=float(lifetimes[idx]))
        depth.append(len(queue))
        fleet.tick(t)
    for k in sorted(active):
        idx = active.pop(k)
        m = fleet.deactivate(k, duration)
        records[idx].departed = duration
        records[idx].metrics = m

    served = [r for r in records if r is not None and r.metrics is not None]
    return ChurnResult(spec=spec, config=cfg, records=served,
                       offered=len(arrivals),
                       unserved=len(arrivals) - len(served),
                       queue_depth=depth, duration=duration)

"""Grounding-then-prediction (paper §5.1-5.2).

Three grounding sources, all producing TimedBoxes feedback packets:

* ``SaliencyGrounder`` — TPU-idiomatic MLLM grounding: gradient of the
  answer-span confidence w.r.t. the vision-patch embeddings; the per-patch
  gradient-norm map thresholded into a box.  Works for *any* backbone
  including attention-free SSMs (DESIGN.md §6) at the cost of one VJP.
* ``server_grounding`` — detector-based grounding on the received
  (degraded) frames: finds glyph-card regions by local contrast. This is
  what the benchmark-scale OracleServer uses; like the paper's scheme it
  runs server-side only (zero client overhead).
* constant-velocity **prediction**: every grounder keeps a short history
  per tracked region and extrapolates boxes over `horizon` seconds so the
  client can compensate the 1.2-1.5 s feedback latency (§5.2).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.zecostream import Box, TimedBoxes


def _center(b: Box) -> Tuple[float, float]:
    return (0.5 * (b[0] + b[2]), 0.5 * (b[1] + b[3]))


@dataclasses.dataclass
class TrackedRegion:
    history: List[Tuple[float, Box]] = dataclasses.field(default_factory=list)

    def observe(self, t: float, box: Box, keep: int = 8):
        self.history.append((t, box))
        self.history = self.history[-keep:]

    def velocity(self) -> Tuple[float, float]:
        if len(self.history) < 2:
            return (0.0, 0.0)
        (t0, b0), (t1, b1) = self.history[0], self.history[-1]
        dt = max(t1 - t0, 1e-6)
        c0, c1 = _center(b0), _center(b1)
        return ((c1[0] - c0[0]) / dt, (c1[1] - c0[1]) / dt)

    def predict(self, t: float) -> Box:
        t1, b1 = self.history[-1]
        vy, vx = self.velocity()
        d = t - t1
        return (b1[0] + vy * d, b1[1] + vx * d, b1[2] + vy * d, b1[3] + vx * d)

    def predict_times(self, times: np.ndarray) -> np.ndarray:
        """Vectorized `predict` over a (K,) time array -> (K, 4) boxes."""
        t1, b1 = self.history[-1]
        vy, vx = self.velocity()
        d = np.asarray(times, np.float64) - t1
        shift = np.asarray([vy, vx, vy, vx])
        return np.asarray(b1, np.float64)[None, :] + d[:, None] * shift


class TrajectoryPredictor:
    """Matches observations to tracks (nearest center) and emits TimedBoxes."""

    def __init__(self, match_dist: float = 48.0):
        self.tracks: List[TrackedRegion] = []
        self.match_dist = match_dist

    def observe(self, t: float, boxes: Sequence[Box]):
        for b in boxes:
            c = _center(b)
            best, best_d = None, self.match_dist
            for tr in self.tracks:
                tc = _center(tr.history[-1][1])
                d = float(np.hypot(c[0] - tc[0], c[1] - tc[1]))
                if d < best_d:
                    best, best_d = tr, d
            if best is None:
                best = TrackedRegion()
                self.tracks.append(best)
            best.observe(t, b)
        # expire stale tracks
        self.tracks = [tr for tr in self.tracks
                       if t - tr.history[-1][0] < 3.0]

    def feedback(self, t: float, horizon: float = 1.5, steps: int = 6
                 ) -> TimedBoxes:
        """Predicted boxes for `steps` future timestamps covering horizon,
        emitted directly in the stacked (K, B, 4) array format (one
        constant-velocity extrapolation op across every track)."""
        times = t + np.linspace(0.0, horizon, steps)
        n = len(self.tracks)
        if n == 0:
            return TimedBoxes(times=times,
                              boxes=np.zeros((steps, 0, 4), np.float32),
                              counts=np.zeros(steps, np.int32))
        last_t = np.asarray([tr.history[-1][0] for tr in self.tracks])
        last_b = np.asarray([tr.history[-1][1] for tr in self.tracks])
        vel = np.asarray([tr.velocity() for tr in self.tracks])  # (B, 2)
        shift = vel[:, [0, 1, 0, 1]]                             # (B, 4)
        d = times[:, None] - last_t[None, :]                     # (K, B)
        boxes = last_b[None, :, :] + d[:, :, None] * shift[None, :, :]
        return TimedBoxes(times=times, boxes=boxes.astype(np.float32),
                          counts=np.full(steps, n, np.int32))


# --------------------------------------------------------------------------
# Detector-based server grounding (benchmark scale)
# --------------------------------------------------------------------------
def split_runs(idx: np.ndarray, min_gap: int = 4):
    """Split a sorted index array into (start, end) runs at gaps."""
    brk = np.flatnonzero(np.diff(idx) > min_gap)
    starts = np.concatenate(([0], brk + 1))
    ends = np.concatenate((brk, [len(idx) - 1]))
    return [(int(idx[s]), int(idx[e])) for s, e in zip(starts, ends)]


def _boxes_from_mask(mask: np.ndarray, row_runs, min_size: int
                     ) -> List[Box]:
    """Greedy connected-ish split: cluster columns by projection gaps
    within each (r0, r1) row run."""
    boxes: List[Box] = []
    for r0, r1 in row_runs:
        sub = mask[r0:r1 + 1]
        cidx = np.where(sub.any(axis=0))[0]
        if len(cidx) == 0:
            continue
        for c0, c1 in split_runs(cidx):
            if (r1 - r0) >= min_size and (c1 - c0) >= min_size:
                boxes.append((float(r0), float(c0), float(r1), float(c1)))
    return boxes


def detect_cards(frame: np.ndarray, min_size: int = 8,
                 bright: float = 0.75) -> List[Box]:
    """Find bright card regions (the glyph carriers) by row/col projection.

    Runs on the *received degraded* frame — grounding quality itself
    degrades with bitrate, as in the real system."""
    mask = frame > bright
    if mask.sum() < min_size * min_size:
        return []
    rows = np.where(mask.any(axis=1))[0]
    cols = np.where(mask.any(axis=0))[0]
    if len(rows) == 0 or len(cols) == 0:
        return []
    return _boxes_from_mask(mask, split_runs(rows), min_size)


def _merge_runs(starts: np.ndarray, ends: np.ndarray, min_gap: int = 4):
    """Merge mask-transition runs separated by gaps <= min_gap — the
    same clustering `split_runs` applies to a nonzero-index array."""
    runs = [(int(starts[0]), int(ends[0]))]
    for s, e in zip(starts[1:], ends[1:]):
        if s - runs[-1][1] > min_gap:
            runs.append((int(s), int(e)))
        else:
            runs[-1] = (runs[-1][0], int(e))
    return runs


def detect_cards_batch(frames: np.ndarray, min_size: int = 8,
                       bright: float = 0.75) -> List[List[Box]]:
    """`detect_cards` over a stacked (M, H, W) batch.

    The full-frame thresholding, projections, and row-run transitions
    run as single array ops across the batch (the fleet engine's
    tick-batched ingestion); only the per-run column work stays per
    item.  Results are identical to mapping `detect_cards` over the
    frames (a nonempty row projection implies a nonempty column
    projection, so the serial path's separate cols check is subsumed)."""
    M, H, _ = frames.shape
    masks = frames > bright
    sums = masks.sum(axis=(1, 2))
    rows_any = np.zeros((M, H + 2), np.int8)
    rows_any[:, 1:-1] = masks.any(axis=2)
    d = np.diff(rows_any, axis=1)
    sm, sr = np.nonzero(d == 1)    # run starts, grouped by frame
    em, er = np.nonzero(d == -1)   # run ends (exclusive)
    bound_s = np.searchsorted(sm, np.arange(M + 1))
    out: List[List[Box]] = []
    for m in range(M):
        b0, b1 = bound_s[m], bound_s[m + 1]
        if b1 == b0 or sums[m] < min_size * min_size:
            out.append([])
            continue
        out.append(_boxes_from_mask(
            masks[m], _merge_runs(sr[b0:b1], er[b0:b1] - 1), min_size))
    return out


# --------------------------------------------------------------------------
# Gradient-saliency grounding for the real JAX MLLM
# --------------------------------------------------------------------------
def saliency_boxes(grad_embeds: np.ndarray, grid_hw: Tuple[int, int],
                   frame_hw: Tuple[int, int], frac: float = 0.5,
                   top_quantile: float = 0.9) -> List[Box]:
    """Per-patch gradient norms -> thresholded bounding box.

    grad_embeds: (n_patches, d) gradient of the confidence/answer score
    w.r.t. the vision-patch embeddings (one VJP)."""
    gy, gx = grid_hw
    H, W = frame_hw
    norms = np.linalg.norm(np.asarray(grad_embeds, np.float32), axis=-1)
    norms = norms[: gy * gx].reshape(gy, gx)
    thresh = max(float(np.quantile(norms, top_quantile)) * frac, 1e-12)
    mask = norms >= thresh
    if not mask.any():
        return []
    ys, xs = np.where(mask)
    py, px = H / gy, W / gx
    return [(float(ys.min() * py), float(xs.min() * px),
             float((ys.max() + 1) * py), float((xs.max() + 1) * px))]

"""Grounding-then-prediction (paper §5.1-5.2).

Three grounding sources, all producing TimedBoxes feedback packets:

* ``SaliencyGrounder`` — TPU-idiomatic MLLM grounding: gradient of the
  answer-span confidence w.r.t. the vision-patch embeddings; the per-patch
  gradient-norm map thresholded into a box.  Works for *any* backbone
  including attention-free SSMs (DESIGN.md §6) at the cost of one VJP.
* ``server_grounding`` — detector-based grounding on the received
  (degraded) frames: finds glyph-card regions by local contrast. This is
  what the benchmark-scale OracleServer uses; like the paper's scheme it
  runs server-side only (zero client overhead).
* constant-velocity **prediction**: every grounder keeps a short history
  per tracked region and extrapolates boxes over `horizon` seconds so the
  client can compensate the 1.2-1.5 s feedback latency (§5.2).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.zecostream import Box, TimedBoxes


def _center(b: Box) -> Tuple[float, float]:
    return (0.5 * (b[0] + b[2]), 0.5 * (b[1] + b[3]))


@dataclasses.dataclass
class TrackedRegion:
    history: List[Tuple[float, Box]] = dataclasses.field(default_factory=list)

    def observe(self, t: float, box: Box, keep: int = 8):
        self.history.append((t, box))
        self.history = self.history[-keep:]

    def velocity(self) -> Tuple[float, float]:
        if len(self.history) < 2:
            return (0.0, 0.0)
        (t0, b0), (t1, b1) = self.history[0], self.history[-1]
        dt = max(t1 - t0, 1e-6)
        c0, c1 = _center(b0), _center(b1)
        return ((c1[0] - c0[0]) / dt, (c1[1] - c0[1]) / dt)

    def predict(self, t: float) -> Box:
        t1, b1 = self.history[-1]
        vy, vx = self.velocity()
        d = t - t1
        return (b1[0] + vy * d, b1[1] + vx * d, b1[2] + vy * d, b1[3] + vx * d)

    def predict_times(self, times: np.ndarray) -> np.ndarray:
        """Vectorized `predict` over a (K,) time array -> (K, 4) boxes."""
        t1, b1 = self.history[-1]
        vy, vx = self.velocity()
        d = np.asarray(times, np.float64) - t1
        shift = np.asarray([vy, vx, vy, vx])
        return np.asarray(b1, np.float64)[None, :] + d[:, None] * shift


@functools.lru_cache(maxsize=16)
def _horizon_offsets(horizon: float, steps: int) -> np.ndarray:
    """`np.linspace(0, horizon, steps)`, cached: every feedback emission
    uses the same grid, and linspace itself costs ~20us per call — a
    measurable slice of the per-tick server phase at fleet scale."""
    out = np.linspace(0.0, horizon, steps)
    out.setflags(write=False)
    return out


class TrajectoryPredictor:
    """Matches observations to tracks (nearest center) and emits TimedBoxes."""

    def __init__(self, match_dist: float = 48.0):
        self.tracks: List[TrackedRegion] = []
        self.match_dist = match_dist

    def observe(self, t: float, boxes: Sequence[Box]):
        for b in boxes:
            c = _center(b)
            best, best_d = None, self.match_dist
            for tr in self.tracks:
                tc = _center(tr.history[-1][1])
                d = math.hypot(c[0] - tc[0], c[1] - tc[1])
                if d < best_d:
                    best, best_d = tr, d
            if best is None:
                best = TrackedRegion()
                self.tracks.append(best)
            best.observe(t, b)
        # expire stale tracks
        self.tracks = [tr for tr in self.tracks
                       if t - tr.history[-1][0] < 3.0]

    def feedback(self, t: float, horizon: float = 1.5, steps: int = 6
                 ) -> TimedBoxes:
        """Predicted boxes for `steps` future timestamps covering horizon,
        emitted directly in the stacked (K, B, 4) array format (one
        constant-velocity extrapolation op across every track)."""
        times = t + _horizon_offsets(horizon, steps)
        n = len(self.tracks)
        if n == 0:
            return TimedBoxes(times=times,
                              boxes=np.zeros((steps, 0, 4), np.float32),
                              counts=np.zeros(steps, np.int32))
        last_t = np.asarray([tr.history[-1][0] for tr in self.tracks])
        last_b = np.asarray([tr.history[-1][1] for tr in self.tracks])
        vel = np.asarray([tr.velocity() for tr in self.tracks])  # (B, 2)
        shift = vel[:, [0, 1, 0, 1]]                             # (B, 4)
        d = times[:, None] - last_t[None, :]                     # (K, B)
        boxes = last_b[None, :, :] + d[:, :, None] * shift[None, :, :]
        return TimedBoxes(times=times, boxes=boxes.astype(np.float32),
                          counts=np.full(steps, n, np.int32))


# --------------------------------------------------------------------------
# Detector-based server grounding (benchmark scale)
# --------------------------------------------------------------------------
def split_runs(idx: np.ndarray, min_gap: int = 4):
    """Split a sorted index array into (start, end) runs at gaps."""
    brk = np.flatnonzero(np.diff(idx) > min_gap)
    starts = np.concatenate(([0], brk + 1))
    ends = np.concatenate((brk, [len(idx) - 1]))
    return [(int(idx[s]), int(idx[e])) for s, e in zip(starts, ends)]


def _boxes_from_mask(mask: np.ndarray, row_runs, min_size: int
                     ) -> List[Box]:
    """Greedy connected-ish split: cluster columns by projection gaps
    within each (r0, r1) row run."""
    boxes: List[Box] = []
    for r0, r1 in row_runs:
        sub = mask[r0:r1 + 1]
        cidx = np.where(sub.any(axis=0))[0]
        if len(cidx) == 0:
            continue
        for c0, c1 in split_runs(cidx):
            if (r1 - r0) >= min_size and (c1 - c0) >= min_size:
                boxes.append((float(r0), float(c0), float(r1), float(c1)))
    return boxes


def detect_cards(frame: np.ndarray, min_size: int = 8,
                 bright: float = 0.75) -> List[Box]:
    """Find bright card regions (the glyph carriers) by row/col projection.

    Runs on the *received degraded* frame — grounding quality itself
    degrades with bitrate, as in the real system."""
    mask = frame > bright
    if mask.sum() < min_size * min_size:
        return []
    rows = np.where(mask.any(axis=1))[0]
    cols = np.where(mask.any(axis=0))[0]
    if len(rows) == 0 or len(cols) == 0:
        return []
    return _boxes_from_mask(mask, split_runs(rows), min_size)


def _merge_runs(starts: np.ndarray, ends: np.ndarray, min_gap: int = 4):
    """Merge mask-transition runs separated by gaps <= min_gap — the
    same clustering `split_runs` applies to a nonzero-index array."""
    runs = [(int(starts[0]), int(ends[0]))]
    for s, e in zip(starts[1:], ends[1:]):
        if s - runs[-1][1] > min_gap:
            runs.append((int(s), int(e)))
        else:
            runs[-1] = (runs[-1][0], int(e))
    return runs


def detect_cards_batch(frames: np.ndarray, min_size: int = 8,
                       bright: float = 0.75) -> List[List[Box]]:
    """`detect_cards` over a stacked (M, H, W) batch.

    The full-frame thresholding, projections, and row-run transitions
    run as single array ops across the batch (the fleet engine's
    tick-batched ingestion); only the per-run column work stays per
    item.  Results are identical to mapping `detect_cards` over the
    frames (a nonempty row projection implies a nonempty column
    projection, so the serial path's separate cols check is subsumed)."""
    M, H, _ = frames.shape
    masks = frames > bright
    sums = masks.sum(axis=(1, 2))
    rows_any = np.zeros((M, H + 2), np.int8)
    rows_any[:, 1:-1] = masks.any(axis=2)
    d = np.diff(rows_any, axis=1)
    sm, sr = np.nonzero(d == 1)    # run starts, grouped by frame
    em, er = np.nonzero(d == -1)   # run ends (exclusive)
    bound_s = np.searchsorted(sm, np.arange(M + 1))
    out: List[List[Box]] = []
    for m in range(M):
        b0, b1 = bound_s[m], bound_s[m + 1]
        if b1 == b0 or sums[m] < min_size * min_size:
            out.append([])
            continue
        out.append(_boxes_from_mask(
            masks[m], _merge_runs(sr[b0:b1], er[b0:b1] - 1), min_size))
    return out


# --------------------------------------------------------------------------
# Traceable detect_cards (the on-device rollout's server grounding)
# --------------------------------------------------------------------------
# The rollout scan (repro.core.rollout) computes card boxes in-graph from
# the decoded frames, so the per-window device->host frame transfer and
# the host-side numpy detector disappear from the replay.  The port must
# be BIT-EXACT vs `detect_cards` on the same frame: box coordinates are
# integer-valued (exact in float32) and the comparisons are integer
# arithmetic, so exactness reduces to producing the same runs in the
# same order.
#
# Fixed capacities (a traced program cannot return ragged lists):
# * runs along an axis of length L are separated by > min_gap absent
#   positions, so at most `run_capacity(L)` runs exist — the nonzero
#   extraction pads to that bound;
# * candidate boxes are compacted (order-preserving) into `box_cap`
#   rows with a count + overflow flag; the host raises on overflow
#   instead of silently truncating.

def run_capacity(length: int, min_gap: int = 4) -> int:
    """Upper bound on the number of projection runs along an axis of
    `length` pixels: consecutive runs' starts are >= min_gap + 1 apart."""
    return (length + min_gap) // (min_gap + 1)


def _runs_last(present, cap: int, min_gap: int = 4):
    """Bridged runs of True along the LAST axis of a bool array.

    A position starts a run iff it is present and none of the previous
    `min_gap` positions are (mirrors `split_runs`: a break needs a gap
    > min_gap between consecutive present indices); ends symmetrically.
    Returns (starts, ends) int32 arrays of shape (..., cap), ascending,
    padded with L — padded slots produce zero-span (invalid) runs."""
    import jax.numpy as jnp

    L = present.shape[-1]
    pad = [(0, 0)] * (present.ndim - 1) + [(min_gap, min_gap)]
    pp = jnp.pad(present, pad)
    prev_any = jnp.zeros_like(present)
    next_any = jnp.zeros_like(present)
    for s in range(1, min_gap + 1):
        prev_any = prev_any | pp[..., min_gap - s: min_gap - s + L]
        next_any = next_any | pp[..., min_gap + s: min_gap + s + L]
    ar = jnp.arange(L, dtype=jnp.int32)
    fill = jnp.int32(L)
    s_idx = jnp.sort(jnp.where(present & ~prev_any, ar, fill),
                     axis=-1)[..., :cap]
    e_idx = jnp.sort(jnp.where(present & ~next_any, ar, fill),
                     axis=-1)[..., :cap]
    return s_idx, e_idx


def detect_cards_core(frame, *, min_size: int = 8, bright: float = 0.75,
                      box_cap: int = 16, min_gap: int = 4):
    """Traceable `detect_cards` for ONE (H, W) frame.

    Returns (boxes (box_cap, 4) float32, count int32, overflow bool);
    rows [0, count) equal `detect_cards(frame)` in order (row-run-major,
    then column runs ascending).  `overflow` flags more than box_cap
    valid boxes — the caller must treat the result as unusable then."""
    import jax.numpy as jnp

    H, W = frame.shape
    r_cap = run_capacity(H, min_gap)
    c_cap = run_capacity(W, min_gap)
    mask = frame > bright
    enough = jnp.sum(mask.astype(jnp.int32)) >= min_size * min_size
    r0s, r1s = _runs_last(mask.any(axis=1), r_cap, min_gap)      # (r_cap,)
    rows = jnp.arange(H, dtype=jnp.int32)
    in_run = ((rows[None, :] >= r0s[:, None])
              & (rows[None, :] <= r1s[:, None]))                 # (r_cap, H)
    # any(mask[r0:r1+1, w]) as an f32 GEMM: 0/1 products sum to integer
    # counts <= H (exact in float32), so `> 0` is exactly the boolean
    # any() — the dot hits the tuned GEMM path on CPU where the
    # (r_cap, H, W) broadcast-and-reduce lowers to a slow scalar loop
    # (this runs per scan tick in the on-device rollout's server phase)
    col_present = jnp.dot(in_run.astype(jnp.float32),
                          mask.astype(jnp.float32)) > 0          # (r_cap, W)
    c0s, c1s = _runs_last(col_present, c_cap, min_gap)   # (r_cap, c_cap)
    row_ok = (r1s - r0s) >= min_size                             # (r_cap,)
    col_ok = (c0s < W) & ((c1s - c0s) >= min_size)
    valid = (enough & row_ok[:, None] & col_ok).reshape(-1)
    cand = jnp.stack(
        [jnp.broadcast_to(r0s[:, None], (r_cap, c_cap)), c0s,
         jnp.broadcast_to(r1s[:, None], (r_cap, c_cap)), c1s],
        axis=-1).astype(jnp.float32).reshape(-1, 4)
    rank = jnp.cumsum(valid.astype(jnp.int32)) - 1
    count = jnp.sum(valid.astype(jnp.int32))
    slot = jnp.where(valid, rank, box_cap)  # rank >= cap also drops
    # compaction as a one-hot f32 matmul instead of a scatter (XLA CPU
    # scatters lower to a serial loop, ~5x slower here); slot values are
    # unique, so each boxes row sums exactly one cand row, and the
    # integer-valued coordinates are exact in float32
    onehot = (slot[None, :]
              == jnp.arange(box_cap, dtype=jnp.int32)[:, None]
              ).astype(jnp.float32)                      # (box_cap, rc*cc)
    boxes = jnp.dot(onehot, cand)
    return boxes, jnp.minimum(count, box_cap), count > box_cap


# --------------------------------------------------------------------------
# Gradient-saliency grounding for the real JAX MLLM
# --------------------------------------------------------------------------
def saliency_boxes(grad_embeds: np.ndarray, grid_hw: Tuple[int, int],
                   frame_hw: Tuple[int, int], frac: float = 0.5,
                   top_quantile: float = 0.9) -> List[Box]:
    """Per-patch gradient norms -> thresholded bounding box.

    grad_embeds: (n_patches, d) gradient of the confidence/answer score
    w.r.t. the vision-patch embeddings (one VJP)."""
    gy, gx = grid_hw
    H, W = frame_hw
    norms = np.linalg.norm(np.asarray(grad_embeds, np.float32), axis=-1)
    norms = norms[: gy * gx].reshape(gy, gx)
    thresh = max(float(np.quantile(norms, top_quantile)) * frac, 1e-12)
    mask = norms >= thresh
    if not mask.any():
        return []
    ys, xs = np.where(mask)
    py, px = H / gy, W / gx
    return [(float(ys.min() * py), float(xs.min() * px),
             float((ys.max() + 1) * py), float((xs.max() + 1) * px))]

"""Fleet-scale session engine: N client<->MLLM sessions in one program.

The serial `repro.core.session.run_session` advances one session with a
per-frame Python loop in which every encode is its own device dispatch.
This module runs N independent sessions — heterogeneous scenes, traces,
CC algorithms and system variants (WebRTC / +ReCapABR / +ZeCoStream /
Artic) — in **lockstep ticks**, batching all device work so a whole
fleet tick costs two dispatches regardless of N.

Tick architecture
-----------------
Every session shares the frame clock (same fps/duration); each tick t:

1. **Client phase**: deliver due server->client feedback from each
   session's downlink min-heap (feedback boxes land in the shared
   `ZeCoStreamBank` as (N, K, B, 4) arrays), then run CC on the
   vectorized ack stats, ReCapABR (Eq. 1-2) and the ZeCoStream plan
   (Eq. 3-4) for the WHOLE fleet as (N,) array ops — the QP surfaces for
   all N sessions come from one jitted bank dispatch
   (`ZeCoStreamBank.plan`), the same dispatch the serial path runs at
   N=1 in `session.build_plan`.
2. **Batched encode** (one dispatch): the N rendered frames are stacked
   into a (N, H, W) batch and `codec.rate_control_batch` runs the
   vmapped QP-offset bisection with per-session targets and QP surfaces.
3. **Vectorized channel**: `net.channel.ChannelBank` advances all N
   drop-tail queues against stacked trace arrays with (N,) NumPy ops —
   shared tick timestamps mean the trace-step boundaries are scalar and
   only backlogs/budgets/latencies are per-session vectors.
4. **Batched receive** (one dispatch): `codec.decode_delivered_batch`
   decodes every delivered frame; sessions with a partial packet drop
   re-quantize the cached coefficients toward the delivered bits first.
5. **Server phase** (per session): arrived frames pop off the uplink
   min-heap into the OracleServer's visual memory, feedback packets are
   pushed onto the downlink heap with the inference+downlink delay, and
   conversational QA opens/commits questions.

Event queues
------------
In-flight frames (uplink) and feedback packets (downlink) live in
per-session `heapq` min-heaps keyed on (time, seq) — O(log n) per event,
with `seq` preserving insertion order for simultaneous events.  The same
heaps serve the serial wrapper, so fleet and serial execution order are
identical event for event.

Parity
------
Because steps 2 and 4 are vmaps of the exact single-frame jitted
functions and the ChannelBank mirrors `Channel` op for op, a fleet of N
sessions reproduces N serial `run_session` calls metric for metric
(tests/test_fleet.py asserts this at N=4).  The Pallas fused codec
kernel has a fleet-batched wrapper too
(`repro.kernels.qp_codec.ops.qp_codec_frames`) — one kernel launch for
all N frames — benchmarked in benchmarks/bench_fleet.py.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.confidence import PlattCalibrator
from repro.core.grounding import detect_cards_batch
from repro.core.ingest import glyph_stats_batch
from repro.core.recap_abr import CCOnlyABRBank, ReCapABRBank
from repro.core.session import (QASample, SessionConfig, SessionMetrics,
                                SessionState, client_record_send,
                                deliver_feedback, finalize,
                                make_session_state, peek_commit,
                                pop_due_arrivals, push_arrival, server_emit)
from repro.core.zecostream import (ZeCoStreamBank, rate_control_batch_fused,
                                   surfaces_from_boxes)
from repro.distributed.sharding import (pad_sessions, session_partition,
                                        shard_map_compat)
from repro.launch.mesh import use_mesh
from repro.net.cc import make_cc_bank
from repro.net.channel import ChannelBank
from repro.net.traces import Trace
from repro.video import codec
from repro.video.scenes import Scene

# bandwidth assigned to masked dead sessions (the rows padding the fleet
# up to the device count): any positive constant works — their results
# are computed and discarded — but a fixed value keeps padded runs
# deterministic across processes
DEAD_SESSION_RATE = 1e5


class _LazyFrames:
    """A decoded (N, H, W) batch left on device until first read.

    Arrival events queue a per-session getter; the single device->host
    transfer happens at the first server ingestion — by which point the
    asynchronously dispatched decode has long finished.  On that first
    read the batch is sliced into per-session row copies and both the
    device array and the host batch are released, so a congested
    channel with long-in-flight frames pins one (H, W) frame per
    arrival (as the serial path does), not whole (N, H, W) batches."""

    __slots__ = ("dev", "_keys", "_rows")

    def __init__(self, dev):
        self.dev = dev
        self._keys = []
        self._rows = None

    def _materialize(self) -> None:
        if self._rows is None:
            batch = np.asarray(self.dev)
            self._rows = {k: batch[k].copy() for k in self._keys}
            self.dev = None

    def getter(self, k: int):
        self._keys.append(k)

        def fetch() -> np.ndarray:
            self._materialize()
            return self._rows.pop(k)
        return fetch


def _ingest_batched(states: List[SessionState],
                    due: List[Tuple[int, float, np.ndarray]]) -> None:
    """Tick-batched server ingestion: what OracleServer.ingest does per
    frame, with the full-frame work (card detection) and the per-object
    glyph decoding (grouped by glyph geometry) run as stacked array ops
    across every frame ingested this tick.  Float-op ordering matches
    the serial path, so results are identical to per-session ingestion.
    """
    if not due:
        return
    frames = np.stack([f for _, _, f in due])
    boxes_all = detect_cards_batch(frames)

    # group every (frame, object) patch by glyph geometry
    groups = {}  # (size, cell) -> [patches], [(item, obj_idx)]
    metas = []
    for i, (k, t_cap, frame) in enumerate(due):
        srv = states[k].server.server
        frame_idx = int(round(t_cap * srv.cfg.fps))
        epoch = srv.scene.epoch(frame_idx)
        metas.append((srv, epoch))
        for oi, obj in enumerate(srv.scene.objects):
            y0, x0, y1, x1 = obj.bbox(frame_idx)
            # integer clamp == the serial path's np.clip on int coords
            y0 = min(max(y0, 0), srv.scene.h - obj.size)
            x0 = min(max(x0, 0), srv.scene.w - obj.size)
            patches, owners = groups.setdefault((obj.size, obj.cell),
                                                ([], []))
            patches.append(frame[y0:y0 + obj.size, x0:x0 + obj.size])
            owners.append((i, oi))

    # one jitted glyph-stats dispatch per geometry group — the same
    # batched jnp kernel the serial OracleServer.ingest runs at B=1
    # (per-record results are batch-size-invariant, so serial, fleet
    # and rollout ingestion read identical codes/margins)
    results = {}  # (item, obj_idx) -> (code, margin)
    for (size, cell), (patches, owners) in groups.items():
        codes, margin = glyph_stats_batch(np.stack(patches), cell)
        for g, owner in enumerate(owners):
            results[owner] = (int(codes[g]), float(margin[g]))

    # apply per-frame updates in arrival order (matches serial ingest)
    for i, (k, t_cap, _) in enumerate(due):
        srv, epoch = metas[i]
        srv.frames_seen += 1
        margins = []
        for oi in range(len(srv.scene.objects)):
            code, margin = results[(i, oi)]
            margins.append(margin)
            best = srv.memory.get((oi, epoch), (0.0, -1))
            if margin > best[0]:
                srv.memory[(oi, epoch)] = (margin, code)
        srv.last_margins = margins or [0.0]
        srv.predictor.observe(t_cap, boxes_all[i])


@dataclasses.dataclass
class FleetSession:
    """Spec for one fleet member; members may differ in everything but
    fps, duration and frame size."""
    scene: Scene
    qa_samples: List[QASample]
    trace: Trace
    cfg: SessionConfig
    calibrator: Optional[PlattCalibrator] = None


# --------------------------------------------------------------------------
# Device-sharded dispatches: the session axis laid out over a mesh
# --------------------------------------------------------------------------
class _ShardedDispatch:
    """The fleet tick's device dispatches, shard_mapped over the mesh's
    session ("data") axes.

    Every batched codec / plan entry point is a vmap of a per-session
    function with no cross-session communication, so splitting the
    padded session axis across devices runs the SAME per-row program on
    each shard — results are bit-identical to the single-device batch
    (pinned by tests/test_sharded_fleet.py).  `put` lays host arrays
    (or pytrees, e.g. an EncodedFrame batch) out with the matching
    NamedSharding; re-putting an already-sharded output is a no-op."""

    def __init__(self, mesh, axes, probe_stride: int,
                 frame_hw: Tuple[int, int], patch: int, mu: float,
                 q_min: float, q_max: float):
        spec = P(axes)
        self.sharding = NamedSharding(mesh, spec)

        def smap(fn):
            return jax.jit(shard_map_compat(fn, mesh, spec, spec))

        self.surfaces = smap(functools.partial(
            surfaces_from_boxes, frame_hw=frame_hw, patch=patch, mu=mu,
            q_min=q_min, q_max=q_max))
        self.rate_control = smap(functools.partial(
            codec.rate_control_batch, probe_stride=probe_stride))
        self.fused = smap(functools.partial(
            rate_control_batch_fused, frame_hw=frame_hw, patch=patch,
            mu=mu, q_min=q_min, q_max=q_max, probe_stride=probe_stride))
        self.decode_delivered = smap(functools.partial(
            codec.decode_delivered_batch, probe_stride=probe_stride))
        self.decode = smap(codec.decode_batch)

    def put(self, tree):
        return jax.device_put(tree, self.sharding)

    def plan_dispatch(self):
        """`ZeCoStreamBank.plan`-compatible surface dispatch that lays
        the box arrays out over the mesh first."""
        return lambda boxes, counts, engaged: self.surfaces(
            self.put(boxes), self.put(counts), self.put(engaged))


@functools.lru_cache(maxsize=32)
def _sharded_dispatch(mesh, axes, probe_stride, frame_hw, patch, mu,
                      q_min, q_max) -> _ShardedDispatch:
    """Cache per (mesh, statics): fleets come and go per cohort, but the
    jitted shard_map wrappers (and their compiled executables) must not."""
    return _ShardedDispatch(mesh, axes, probe_stride, frame_hw, patch,
                            mu, q_min, q_max)


class Fleet:
    """N lockstep sessions with batched codec + vectorized channel.

    `fused_plan=True` routes the plan+encode through
    `zecostream.rate_control_batch_fused`: the Eq. 3-4 surfaces are
    computed in-graph from the box arrays and flow straight into the
    rate-control bisection as one device dispatch (no host-side surface
    materialization).  `profile=True` accumulates wall-clock per tick
    phase in `self.phase_times` (seconds): `client` (feedback delivery +
    CC/ABR), `render` (scene rasterization), `plan` (the ZeCoStream bank
    dispatch; in fused mode only the host-side decision/selection — the
    surface kernel is billed to `encode` there, fused into its
    dispatch), `encode`, `channel`, `decode`, `server`.

    `mesh=...` shards the fleet over the session axis: every
    per-session array — ZeCoStream context rows, ChannelBank queues,
    CC/ABR lanes, frame/QP/codec batches — is laid out at a session
    count padded to a multiple of the mesh's `data` axes
    (`distributed.sharding.session_partition`; the pad rows are masked
    *dead sessions* whose results are discarded), and each tick's
    batched dispatches run shard_mapped over that axis under the mesh
    context.  Per-session results are bit-identical to the unsharded
    fleet (tests/test_sharded_fleet.py).  A mesh without a multi-way
    data axis degenerates to unsharded execution."""

    def __init__(self, sessions: Sequence[FleetSession], *,
                 fused_plan: bool = False, profile: bool = False,
                 mesh=None, megakernel: bool = False,
                 on_device_server: bool = False,
                 server: str = "oracle",
                 engine_cfg: Optional[Dict] = None):
        if not sessions:
            raise ValueError("fleet needs at least one session")
        if server not in ("oracle", "engine"):
            raise ValueError(f"server must be 'oracle' or 'engine', "
                             f"got {server!r}")
        # server="engine" routes the per-tick server phase through the
        # continuous-batching Engine (repro.serving.bridge): delivered
        # frames become patch embeddings via chunked prefill, committing
        # QA questions become one batched decode drain, and per-session
        # TTFT/queueing-delay/confidence telemetry lands in
        # SessionMetrics.  Oracle ingestion still runs (it drives the
        # feedback/ABR loop, keeping channel dynamics identical across
        # server modes); only the ANSWER comes from the engine.
        self.server_mode = server
        if server == "engine":
            if mesh is not None:
                raise NotImplementedError(
                    "server='engine' does not compose with mesh sharding "
                    "yet (session axis x engine batch; see ROADMAP)")
            if megakernel or on_device_server:
                raise NotImplementedError(
                    "server='engine' requires the eager host server "
                    "phase — drop megakernel/on_device_server")
        # rollout-mode switches (repro.core.rollout reads them; the eager
        # tick loop ignores both):
        # * megakernel=True routes the scan's per-tick encode through the
        #   fused Pallas tick kernel (kernels.qp_codec.ops.tick_codec_frames)
        #   — a fast-math tier, NOT covered by the bit-exactness contract;
        # * on_device_server=True computes the server-phase ingestion
        #   numerics (glyph stats + card boxes) in-graph at the send tick
        #   and drops the decoded-frame outfeed; the host replays only
        #   heap/metrics bookkeeping from the stats outputs (bit-exact).
        self.megakernel = bool(megakernel)
        self.on_device_server = bool(on_device_server)
        if self.megakernel and mesh is not None:
            raise NotImplementedError(
                "megakernel=True is single-device only: the Pallas tick "
                "kernel is not shard_map-wrapped yet — drop the mesh or "
                "the megakernel flag")
        self._last_rollout = None  # set by _run_rollout (bench introspection)
        self.specs = list(sessions)
        cfg0 = self.specs[0].cfg
        hw0 = (self.specs[0].scene.h, self.specs[0].scene.w)
        for s in self.specs:
            if (s.cfg.fps, s.cfg.duration) != (cfg0.fps, cfg0.duration):
                raise ValueError(
                    "fleet sessions must share fps and duration")
            if (s.scene.h, s.scene.w) != hw0:
                raise ValueError("fleet sessions must share frame size")
            if s.cfg.rc_probe_stride != cfg0.rc_probe_stride:
                raise ValueError(
                    "fleet sessions must share rc_probe_stride")
        self._probe_stride = cfg0.rc_probe_stride
        # last tick timestamp: arrivals past it can never be ingested,
        # so their getters are not queued (keeps _LazyFrames batches
        # from being pinned by events that will never fire)
        self._t_last = (int(cfg0.duration * cfg0.fps) - 1) * (1.0 / cfg0.fps)
        self.states: List[SessionState] = [
            make_session_state(s.scene, s.qa_samples, s.cfg, s.calibrator)
            for s in self.specs]
        self.n = len(self.specs)
        # session-axis partition: pad N to a multiple of the mesh's data
        # axes with masked dead sessions; ways == 1 (no mesh, or a mesh
        # with no multi-way data axis) keeps n_pad == n
        self.mesh = None
        self._axes = None
        ways = 1
        if mesh is not None:
            self._axes, ways = session_partition(mesh)
            if ways > 1:
                self.mesh = mesh
            else:
                self._axes = None
        self.n_pad = pad_sessions(self.n, ways)
        self.pad = self.n_pad - self.n
        # one shared ZeCoStreamBank: every member's context state is a row
        # (dead rows are disabled, so they never engage)
        self.zeco = ZeCoStreamBank(
            self.n_pad, hw0,
            tau=[s.cfg.tau for s in self.specs] + [0.8] * self.pad,
            enabled=[s.cfg.use_zeco for s in self.specs]
            + [False] * self.pad)
        for k, st in enumerate(self.states):
            # CC/ABR advance through the vectorized banks below; the
            # per-session objects would otherwise sit stale and mislead
            st.client.cc = None
            st.client.abr = None
            # retarget the N=1 bank from make_session_state at the shared
            # fleet bank so feedback delivery and metrics hit row k
            st.client.zeco = self.zeco
            st.client.zeco_row = k
        self.bank = ChannelBank([s.trace for s in self.specs],
                                pad_to=self.n_pad)
        # churn support: a LIVE row can go dead mid-run (session departed,
        # `deactivate`) and be revived with a fresh member (`activate`).
        # Dead live-rows are masked exactly like the pad rows — blank
        # frames, DEAD_SESSION_RATE, no metric accumulation — and every
        # lane of bank state is reset at revival, so tenants of the same
        # slot never observe each other (tests/test_churn.py pins this).
        self.alive = np.ones(self.n, bool)
        self._open_tick = [0] * self.n     # bank tick of each admission
        self._open_t = [0.0] * self.n      # admission timestamp
        self._blank = np.zeros(hw0, np.float32)
        self.bridge = None
        if server == "engine":
            # imported lazily: the bridge pulls in the model zoo, which
            # oracle-mode fleets never need
            from repro.serving.bridge import EngineServerBridge

            self.bridge = EngineServerBridge(self.n, **(engine_cfg or {}))
            for k, st in enumerate(self.states):
                self.bridge.open(k, st.scene, cfg0.fps)
        self._disp: Optional[_ShardedDispatch] = None
        if self.mesh is not None:
            self._disp = _sharded_dispatch(
                self.mesh, self._axes, self._probe_stride,
                self.zeco.frame_hw, self.zeco.patch, self.zeco.mu,
                self.zeco.q_min, self.zeco.q_max)
        self._fused = fused_plan
        self.phase_times: Optional[Dict[str, float]] = (
            dict(client=0.0, render=0.0, plan=0.0, encode=0.0,
                 channel=0.0, decode=0.0, server=0.0)
            if profile else None)
        # vectorized CC / ABR: sessions grouped by algorithm, each group
        # advanced by one bank call per tick (same math as the scalar
        # objects the serial path uses)
        self._cc_groups = []
        for kind in sorted({s.cfg.cc_kind for s in self.specs}):
            idx = np.asarray([k for k, s in enumerate(self.specs)
                              if s.cfg.cc_kind == kind])
            self._cc_groups.append((idx, make_cc_bank(kind, len(idx))))
        self._abr_groups = []
        recap = np.asarray([k for k, s in enumerate(self.specs)
                            if s.cfg.use_recap])
        if len(recap):
            self._abr_groups.append((recap, ReCapABRBank(
                [self.specs[k].cfg.tau for k in recap],
                [self.specs[k].cfg.gamma for k in recap])))
        follow = np.asarray([k for k, s in enumerate(self.specs)
                             if not s.cfg.use_recap])
        if len(follow):
            self._abr_groups.append((follow, CCOnlyABRBank(len(follow))))

    # ------------------------------------------------------------------
    def _mark(self, phase: str, t0: float, *sync) -> float:
        """Charge `now - t0` to `phase` (when profiling) and return now.

        JAX dispatches are asynchronous: without a sync, a phase's mark
        lands before its device work finishes and the time gets charged
        to whichever LATER phase first forces materialization (decode
        used to be billed to `server`, where ingestion reads the lazy
        batch).  Under `profile=True` every pytree in `sync` is
        block_until_ready'd before the timestamp, so phases are charged
        their own device time and the per-phase times sum to the total
        tick wall time (tests/test_fleet.py).  The non-profiling path
        never blocks — the async pipeline is the perf feature."""
        if self.phase_times is not None:
            for obj in sync:
                jax.block_until_ready(obj)
            now = time.perf_counter()
            self.phase_times[phase] += now - t0
            return now
        return time.perf_counter()

    def tick(self, t: float) -> None:
        """Advance every session by one frame interval.

        All per-session vectors run at `n_pad`; rows >= `n` are masked
        dead sessions (fixed rate, blank frames, ZeCoStream disabled)
        whose results are computed and discarded — elementwise lanes, so
        live-row values are unchanged by the padding."""
        # client phase: feedback delivery per session, then CC + ABR +
        # the ZeCoStream plan for the whole fleet as (N,) array ops — the
        # QP surfaces for every session come from ONE bank dispatch, with
        # no per-session Python loop
        t0 = time.perf_counter()
        alive = self.alive
        acks = self.bank.ack_stats_arrays()
        for k, st in enumerate(self.states):
            if alive[k]:
                deliver_feedback(st, t)
        conf = np.full(self.n_pad, 0.5)
        conf[:self.n] = [st.client.confidence for st in self.states]
        conf[:self.n][~alive] = 0.5
        b_hat = np.full(self.n_pad, DEAD_SESSION_RATE)
        for idx, cc_bank in self._cc_groups:
            b_hat[idx] = cc_bank.estimate(
                {key: val[idx] for key, val in acks.items()})
        rate = np.full(self.n_pad, DEAD_SESSION_RATE)
        for idx, abr_bank in self._abr_groups:
            rate[idx] = abr_bank.update(conf[idx], b_hat[idx])
        rate[:self.n][~alive] = DEAD_SESSION_RATE
        for k, st in enumerate(self.states):
            if alive[k]:
                st.client.rates.append(float(rate[k]))
        t0 = self._mark("client", t0)
        i = int(round(t * self.specs[0].cfg.fps))
        rendered = [st.scene.render(i) if alive[k] else self._blank
                    for k, st in enumerate(self.states)]
        if self.pad:
            rendered.extend([np.zeros_like(rendered[0])] * self.pad)
        frames = np.stack(rendered)
        t0 = self._mark("render", t0)
        targets = (rate * (1.0 / self.specs[0].cfg.fps)).astype(np.float32)
        d = self._disp

        if self._fused:
            # fused plan+encode: Eq. 3-4 surfaces are computed inside the
            # rate-control dispatch straight from the box arrays; they
            # come back only as a device array for the requantize path
            boxes, counts, engaged = self.zeco.plan_arrays(t, rate, conf)
            t0 = self._mark("plan", t0, boxes, counts, engaged)
            if d is not None:
                qp_shapes, _, enc = d.fused(
                    d.put(frames), d.put(boxes),
                    d.put(counts.astype(np.int32)), d.put(engaged),
                    d.put(targets))
            else:
                qp_shapes, _, enc = rate_control_batch_fused(
                    frames, boxes, counts.astype(np.int32), engaged,
                    targets, frame_hw=self.zeco.frame_hw,
                    patch=self.zeco.patch, mu=self.zeco.mu,
                    q_min=self.zeco.q_min, q_max=self.zeco.q_max,
                    probe_stride=self._probe_stride)
        else:
            qp_shapes, _ = self.zeco.plan(
                t, rate, conf,
                dispatch=None if d is None else d.plan_dispatch())
            t0 = self._mark("plan", t0, qp_shapes)
            # one dispatch: vmapped rate-controlled encode of the fleet
            if d is not None:
                _, enc = d.rate_control(d.put(frames), d.put(qp_shapes),
                                        d.put(targets))
            else:
                _, enc = codec.rate_control_batch(
                    frames, qp_shapes, targets,
                    probe_stride=self._probe_stride)
        bits = np.asarray(enc.bits, np.float64)
        t0 = self._mark("encode", t0, enc)

        # vectorized channel: N queues advance together
        rep = self.bank.send_frames(t, bits)
        for k, st in enumerate(self.states):
            if alive[k]:
                client_record_send(st, float(bits[k]), float(rep.latency[k]))
        t0 = self._mark("channel", t0)

        # one dispatch: decode what each uplink delivered (partial drops
        # re-quantize the cached coefficients toward the delivered bits).
        # The requantize pass only compiles in when some frame actually
        # needs it, and the decoded batch stays on device — frames are
        # first read by server ingestion one or more ticks later, so the
        # transfer is deferred (LazyFrames) and the decode compute
        # overlaps the per-session Python below.
        finite = np.isfinite(rep.latency)
        needs = finite & rep.dropped & (rep.bits_delivered < rep.bits_sent)
        if needs.any():
            delivered = np.maximum(rep.bits_delivered, 1e3).astype(np.float32)
            if d is not None:
                rx = _LazyFrames(d.decode_delivered(
                    d.put(enc), d.put(qp_shapes), d.put(delivered),
                    d.put(needs)))
            else:
                rx = _LazyFrames(codec.decode_delivered_batch(
                    enc, qp_shapes, delivered, needs,
                    probe_stride=self._probe_stride))
        else:
            rx = _LazyFrames(codec.decode_batch(enc) if d is None
                             else d.decode(d.put(enc)))

        for k, st in enumerate(self.states):
            # skip arrivals landing after the final tick: the serial path
            # queues (and never reads) them; queuing their getters here
            # would pin the tick's whole decoded batch until teardown
            if (alive[k] and finite[k]
                    and t + float(rep.latency[k]) <= self._t_last):
                push_arrival(st, t, float(rep.latency[k]), rx.getter(k))
        t0 = self._mark("decode", t0, rx.dev)

        # server phase: ingestion batched across all sessions, then the
        # per-session feedback/QA emission
        due = [(k, t_cap, frame)
               for k, st in enumerate(self.states) if alive[k]
               for t_cap, frame in pop_due_arrivals(st, t)]
        _ingest_batched(self.states, due)
        if self.bridge is None:
            for k, st in enumerate(self.states):
                if alive[k]:
                    server_emit(st, t)
        else:
            # engine server phase: this tick's delivered frames extend
            # each session's context (chunked prefill), then every
            # committing question is submitted before ONE batched decode
            # drain serves them all together
            frames_by_k: Dict[int, List[np.ndarray]] = {}
            for k, _, frame in due:
                frames_by_k.setdefault(k, []).append(frame)
            for k in sorted(frames_by_k):
                self.bridge.extend(k, np.stack(frames_by_k[k]), t)
            committing = [(k, peek_commit(st, t))
                          for k, st in enumerate(self.states) if alive[k]]
            for k, q in committing:
                if q is not None:
                    self.bridge.submit(k, q, t)
            answers = self.bridge.drain(t)
            for k, st in enumerate(self.states):
                if not alive[k]:
                    continue
                server_emit(st, t, answer_fn=(
                    (lambda q, _a=answers[k]: _a) if k in answers
                    else None))
        self._mark("server", t0)

    # -- churn slot lifecycle (repro.core.churn drives these) -----------
    def deactivate(self, k: int, t: float) -> SessionMetrics:
        """Close slot k mid-run (session departure): finalize its metrics
        over ITS OWN ticks/reports and mark the row dead.  The row keeps
        flowing through the tick's elementwise dispatches exactly like a
        pad row (blank frame, DEAD_SESSION_RATE, no metric accumulation)
        until `activate` revives it."""
        if not self.alive[k]:
            raise ValueError(f"slot {k} is already dead")
        st = self.states[k]
        reports = self.bank.reports_for(k, since=self._open_tick[k])
        span = t - self._open_t[k]
        if self.bridge is None:
            m = finalize(st, reports, span=span)
        else:
            m = finalize(st, reports, span=span,
                         answer_fn=lambda q: self.bridge.answer_now(k, q, t))
            for field, vals in self.bridge.metrics_kwargs(k).items():
                setattr(m, field, vals)
            self.bridge.close(k)
        # a dead row must not engage ZeCo while it idles between tenants
        self.zeco.enabled[k] = False
        self.zeco.active[k] = False
        self.alive[k] = False
        return m

    def activate(self, k: int, member: FleetSession, t: float) -> None:
        """Revive dead slot k with a fresh member (churn admission): new
        scene/QA/trace plus a cold restart of every per-lane bank state
        (channel history + backlog, CC, ABR, ZeCoStream) — and, under
        server="engine", a fresh engine session (queue-or-wait).

        The member must match the fleet's structural knobs: the cohort
        shape (fps/duration/frame size/probe stride) AND the slot's
        cc_kind / use_recap, because CC/ABR bank *membership* is fixed at
        construction — churn derives every arrival from one base spec, so
        this holds by construction there."""
        if self.alive[k]:
            raise ValueError(f"slot {k} is still live")
        cfg0, old = self.specs[0].cfg, self.specs[k].cfg
        if (member.cfg.fps, member.cfg.duration) != (cfg0.fps,
                                                     cfg0.duration):
            raise ValueError("revived member must share fleet fps/duration")
        if (member.scene.h, member.scene.w) != (self.specs[0].scene.h,
                                                self.specs[0].scene.w):
            raise ValueError("revived member must share fleet frame size")
        if member.cfg.rc_probe_stride != cfg0.rc_probe_stride:
            raise ValueError("revived member must share rc_probe_stride")
        if (member.cfg.cc_kind, member.cfg.use_recap) != (old.cc_kind,
                                                          old.use_recap):
            raise ValueError(
                "revived member must keep the slot's cc_kind/use_recap "
                "(CC/ABR bank membership is fixed at construction)")
        self.specs[k] = member
        st = make_session_state(member.scene, member.qa_samples,
                                member.cfg, member.calibrator)
        st.client.cc = None
        st.client.abr = None
        st.client.zeco = self.zeco
        st.client.zeco_row = k
        self.states[k] = st
        self.bank.reset_row(k, member.trace)
        for idx, bank in self._cc_groups + self._abr_groups:
            pos = np.nonzero(idx == k)[0]
            if len(pos):
                bank.reset_lane(int(pos[0]))
        self.zeco.reset_row(k, tau=member.cfg.tau,
                            enabled=member.cfg.use_zeco)
        if self.bridge is not None:
            self.bridge.open(k, member.scene, cfg0.fps, now=t, wait=True)
        self.alive[k] = True
        self._open_tick[k] = self.bank.n_ticks
        self._open_t[k] = t

    def run(self, rollout: Optional[int] = None) -> List[SessionMetrics]:
        """Run every session to completion.

        `rollout=K` compiles K-tick windows of the whole tick loop into
        one `lax.scan` dispatch each (repro.core.rollout) instead of the
        eager per-tick loop; metrics are bit-identical either way
        (tests/test_rollout.py).  K is clamped to the largest window the
        feedback-turnaround invariants allow (`rollout.max_window`)."""
        cfg0 = self.specs[0].cfg
        n_frames = int(cfg0.duration * cfg0.fps)
        dt = 1.0 / cfg0.fps
        # sharded fleets tick under the mesh context (use_mesh shim);
        # the shard_map dispatches also carry the mesh explicitly, so an
        # out-of-context tick() still shards correctly
        ctx = (use_mesh(self.mesh) if self.mesh is not None
               else contextlib.nullcontext())
        with ctx:
            if rollout is not None:
                if self.bridge is not None:
                    raise NotImplementedError(
                        "server='engine' does not compose with the "
                        "compiled rollout yet — run the eager tick loop")
                self._run_rollout(int(rollout), n_frames)
            else:
                for i in range(n_frames):
                    self.tick(i * dt)
        if self.bridge is None:
            return [finalize(st, self.bank.reports_for(k))
                    for k, st in enumerate(self.states)]
        # engine mode: the end-of-run QA flush also answers through the
        # engine (one query at a time — teardown, not the hot path), and
        # the bridge's per-session telemetry joins the metrics.  The
        # telemetry is attached AFTER finalize so flush-answered queries
        # are included.
        t_end = cfg0.duration
        out = []
        for k, st in enumerate(self.states):
            m = finalize(
                st, self.bank.reports_for(k),
                answer_fn=lambda q, _k=k: self.bridge.answer_now(
                    _k, q, t_end))
            for field, vals in self.bridge.metrics_kwargs(k).items():
                setattr(m, field, vals)
            out.append(m)
        return out

    def _run_rollout(self, window: int, n_frames: int) -> None:
        # imported lazily: rollout imports this module at load time
        from repro.core.rollout import FleetRollout

        ro = FleetRollout(self, window)
        self._last_rollout = ro  # benches read the phase timers off this
        i0 = 0
        while i0 < n_frames:
            w = min(ro.window, n_frames - i0)
            ro.run_window(i0, w)
            i0 += w
        ro.finish()


def run_fleet(sessions: Sequence[FleetSession],
              **kwargs) -> List[SessionMetrics]:
    """Run N sessions to completion; returns per-session SessionMetrics
    in input order.  kwargs forward to `Fleet` (fused_plan, profile,
    mesh)."""
    return Fleet(sessions, **kwargs).run()

"""Fleet-scale session engine: N client<->MLLM sessions in one program.

The serial `repro.core.session.run_session` advances one session with a
per-frame Python loop in which every encode is its own device dispatch.
This module runs N independent sessions — heterogeneous scenes, traces,
CC algorithms and system variants (WebRTC / +ReCapABR / +ZeCoStream /
Artic) — in **lockstep ticks**, batching all device work so a whole
fleet tick costs two dispatches regardless of N.

Tick architecture
-----------------
Every session shares the frame clock (same fps/duration); each tick t:

1. **Client phase** (per session, pure Python/NumPy): deliver due
   server->client feedback from the session's downlink min-heap, run CC
   on the vectorized ack stats, ReCapABR (Eq. 1-2), and the ZeCoStream
   QP surface (Eq. 3-4).  This is `session.client_encode_plan` — exactly
   the code the serial path runs.
2. **Batched encode** (one dispatch): the N rendered frames are stacked
   into a (N, H, W) batch and `codec.rate_control_batch` runs the
   vmapped QP-offset bisection with per-session targets and QP surfaces.
3. **Vectorized channel**: `net.channel.ChannelBank` advances all N
   drop-tail queues against stacked trace arrays with (N,) NumPy ops —
   shared tick timestamps mean the trace-step boundaries are scalar and
   only backlogs/budgets/latencies are per-session vectors.
4. **Batched receive** (one dispatch): `codec.decode_delivered_batch`
   decodes every delivered frame; sessions with a partial packet drop
   re-quantize the cached coefficients toward the delivered bits first.
5. **Server phase** (per session): arrived frames pop off the uplink
   min-heap into the OracleServer's visual memory, feedback packets are
   pushed onto the downlink heap with the inference+downlink delay, and
   conversational QA opens/commits questions.

Event queues
------------
In-flight frames (uplink) and feedback packets (downlink) live in
per-session `heapq` min-heaps keyed on (time, seq) — O(log n) per event,
with `seq` preserving insertion order for simultaneous events.  The same
heaps serve the serial wrapper, so fleet and serial execution order are
identical event for event.

Parity
------
Because steps 2 and 4 are vmaps of the exact single-frame jitted
functions and the ChannelBank mirrors `Channel` op for op, a fleet of N
sessions reproduces N serial `run_session` calls metric for metric
(tests/test_fleet.py asserts this at N=4).  The Pallas fused codec
kernel has a fleet-batched wrapper too
(`repro.kernels.qp_codec.ops.qp_codec_frames`) — one kernel launch for
all N frames — benchmarked in benchmarks/bench_fleet.py.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.confidence import PlattCalibrator
from repro.core.grounding import detect_cards_batch
from repro.core.recap_abr import CCOnlyABRBank, ReCapABRBank
from repro.core.session import (QASample, SessionConfig, SessionMetrics,
                                SessionState, build_plan,
                                client_record_send, deliver_feedback,
                                finalize, make_session_state,
                                pop_due_arrivals, push_arrival,
                                server_emit)
from repro.net.cc import make_cc_bank
from repro.net.channel import ChannelBank
from repro.net.traces import Trace
from repro.video import codec
from repro.video.scenes import (_PAYLOAD_IDX, _PAYLOAD_WEIGHTS, GLYPH_GRID,
                                Scene)


class _LazyFrames:
    """A decoded (N, H, W) batch left on device until first read.

    Arrival events queue a per-session getter; the single device->host
    transfer happens at the first server ingestion — by which point the
    asynchronously dispatched decode has long finished.  On that first
    read the batch is sliced into per-session row copies and both the
    device array and the host batch are released, so a congested
    channel with long-in-flight frames pins one (H, W) frame per
    arrival (as the serial path does), not whole (N, H, W) batches."""

    __slots__ = ("dev", "_keys", "_rows")

    def __init__(self, dev):
        self.dev = dev
        self._keys = []
        self._rows = None

    def _materialize(self) -> None:
        if self._rows is None:
            batch = np.asarray(self.dev)
            self._rows = {k: batch[k].copy() for k in self._keys}
            self.dev = None

    def getter(self, k: int):
        self._keys.append(k)

        def fetch() -> np.ndarray:
            self._materialize()
            return self._rows.pop(k)
        return fetch


def _ingest_batched(states: List[SessionState],
                    due: List[Tuple[int, float, np.ndarray]]) -> None:
    """Tick-batched server ingestion: what OracleServer.ingest does per
    frame, with the full-frame work (card detection) and the per-object
    glyph decoding (grouped by glyph geometry) run as stacked array ops
    across every frame ingested this tick.  Float-op ordering matches
    the serial path, so results are identical to per-session ingestion.
    """
    if not due:
        return
    frames = np.stack([f for _, _, f in due])
    boxes_all = detect_cards_batch(frames)

    # group every (frame, object) patch by glyph geometry
    groups = {}  # (size, cell) -> [patches], [(item, obj_idx)]
    metas = []
    for i, (k, t_cap, frame) in enumerate(due):
        srv = states[k].server.server
        frame_idx = int(round(t_cap * srv.cfg.fps))
        epoch = srv.scene.epoch(frame_idx)
        metas.append((srv, epoch))
        for oi, obj in enumerate(srv.scene.objects):
            y0, x0, y1, x1 = obj.bbox(frame_idx)
            # integer clamp == the serial path's np.clip on int coords
            y0 = min(max(y0, 0), srv.scene.h - obj.size)
            x0 = min(max(x0, 0), srv.scene.w - obj.size)
            patches, owners = groups.setdefault((obj.size, obj.cell),
                                                ([], []))
            patches.append(frame[y0:y0 + obj.size, x0:x0 + obj.size])
            owners.append((i, oi))

    # one vectorized decode_glyph per geometry group
    results = {}  # (item, obj_idx) -> (code, margin)
    for (size, cell), (patches, owners) in groups.items():
        p = np.stack(patches)[:, :GLYPH_GRID * cell, :GLYPH_GRID * cell]
        cells = p.reshape(len(patches), GLYPH_GRID, cell, GLYPH_GRID,
                          cell).mean(axis=(2, 4))
        lo = cells.min(axis=(1, 2))
        hi = cells.max(axis=(1, 2))
        thresh = 0.5 * (lo + hi)
        denom = np.maximum(hi - lo, 1e-6)
        margin = np.clip(
            np.abs(cells - thresh[:, None, None])
            / (0.5 * denom)[:, None, None], 0, 1).mean(axis=(1, 2))
        # matches serial float64 promotion: float(mean) * float(contrast)
        margin = (margin.astype(np.float64)
                  * np.clip((hi - lo) / 0.5, 0, 1).astype(np.float64))
        hard = cells.reshape(len(patches), -1)[:, _PAYLOAD_IDX] > \
            thresh[:, None]
        codes = (hard * _PAYLOAD_WEIGHTS).sum(axis=1)
        for g, owner in enumerate(owners):
            results[owner] = (int(codes[g]), float(margin[g]))

    # apply per-frame updates in arrival order (matches serial ingest)
    for i, (k, t_cap, _) in enumerate(due):
        srv, epoch = metas[i]
        srv.frames_seen += 1
        margins = []
        for oi in range(len(srv.scene.objects)):
            code, margin = results[(i, oi)]
            margins.append(margin)
            best = srv.memory.get((oi, epoch), (0.0, -1))
            if margin > best[0]:
                srv.memory[(oi, epoch)] = (margin, code)
        srv.last_margins = margins or [0.0]
        srv.predictor.observe(t_cap, boxes_all[i])


@dataclasses.dataclass
class FleetSession:
    """Spec for one fleet member; members may differ in everything but
    fps, duration and frame size."""
    scene: Scene
    qa_samples: List[QASample]
    trace: Trace
    cfg: SessionConfig
    calibrator: Optional[PlattCalibrator] = None


class Fleet:
    """N lockstep sessions with batched codec + vectorized channel."""

    def __init__(self, sessions: Sequence[FleetSession]):
        if not sessions:
            raise ValueError("fleet needs at least one session")
        self.specs = list(sessions)
        cfg0 = self.specs[0].cfg
        hw0 = (self.specs[0].scene.h, self.specs[0].scene.w)
        for s in self.specs:
            if (s.cfg.fps, s.cfg.duration) != (cfg0.fps, cfg0.duration):
                raise ValueError(
                    "fleet sessions must share fps and duration")
            if (s.scene.h, s.scene.w) != hw0:
                raise ValueError("fleet sessions must share frame size")
            if s.cfg.rc_probe_stride != cfg0.rc_probe_stride:
                raise ValueError(
                    "fleet sessions must share rc_probe_stride")
        self._probe_stride = cfg0.rc_probe_stride
        # last tick timestamp: arrivals past it can never be ingested,
        # so their getters are not queued (keeps _LazyFrames batches
        # from being pinned by events that will never fire)
        self._t_last = (int(cfg0.duration * cfg0.fps) - 1) * (1.0 / cfg0.fps)
        self.states: List[SessionState] = [
            make_session_state(s.scene, s.qa_samples, s.cfg, s.calibrator)
            for s in self.specs]
        for st in self.states:
            # CC/ABR advance through the vectorized banks below; the
            # per-session objects would otherwise sit stale and mislead
            st.client.cc = None
            st.client.abr = None
        self.bank = ChannelBank([s.trace for s in self.specs])
        self.n = len(self.specs)
        # vectorized CC / ABR: sessions grouped by algorithm, each group
        # advanced by one bank call per tick (same math as the scalar
        # objects the serial path uses)
        self._cc_groups = []
        for kind in sorted({s.cfg.cc_kind for s in self.specs}):
            idx = np.asarray([k for k, s in enumerate(self.specs)
                              if s.cfg.cc_kind == kind])
            self._cc_groups.append((idx, make_cc_bank(kind, len(idx))))
        self._abr_groups = []
        recap = np.asarray([k for k, s in enumerate(self.specs)
                            if s.cfg.use_recap])
        if len(recap):
            self._abr_groups.append((recap, ReCapABRBank(
                [self.specs[k].cfg.tau for k in recap],
                [self.specs[k].cfg.gamma for k in recap])))
        follow = np.asarray([k for k, s in enumerate(self.specs)
                             if not s.cfg.use_recap])
        if len(follow):
            self._abr_groups.append((follow, CCOnlyABRBank(len(follow))))

    # ------------------------------------------------------------------
    def tick(self, t: float) -> None:
        """Advance every session by one frame interval."""
        # client phase: feedback delivery per session, then CC + ABR for
        # the whole fleet as grouped (M,) array ops
        acks = self.bank.ack_stats_arrays()
        for st in self.states:
            deliver_feedback(st, t)
        conf = np.asarray([st.client.confidence for st in self.states])
        b_hat = np.empty(self.n)
        for idx, cc_bank in self._cc_groups:
            b_hat[idx] = cc_bank.estimate(
                {key: val[idx] for key, val in acks.items()})
        rate = np.empty(self.n)
        for idx, abr_bank in self._abr_groups:
            rate[idx] = abr_bank.update(conf[idx], b_hat[idx])
        plans = [build_plan(st, t, float(rate[k]))
                 for k, st in enumerate(self.states)]

        # one dispatch: vmapped rate-controlled encode of the whole fleet
        frames = np.stack([p.frame for p in plans])
        qp_shapes = np.stack([p.qp_shape for p in plans])
        targets = np.asarray([p.target_bits for p in plans], np.float32)
        _, enc = codec.rate_control_batch(frames, qp_shapes, targets,
                                          probe_stride=self._probe_stride)
        bits = np.asarray(enc.bits, np.float64)

        # vectorized channel: N queues advance together
        rep = self.bank.send_frames(t, bits)
        for k, st in enumerate(self.states):
            client_record_send(st, float(bits[k]), float(rep.latency[k]))

        # one dispatch: decode what each uplink delivered (partial drops
        # re-quantize the cached coefficients toward the delivered bits).
        # The requantize pass only compiles in when some frame actually
        # needs it, and the decoded batch stays on device — frames are
        # first read by server ingestion one or more ticks later, so the
        # transfer is deferred (LazyFrames) and the decode compute
        # overlaps the per-session Python below.
        finite = np.isfinite(rep.latency)
        needs = finite & rep.dropped & (rep.bits_delivered < rep.bits_sent)
        if needs.any():
            delivered = np.maximum(rep.bits_delivered, 1e3).astype(np.float32)
            rx = _LazyFrames(codec.decode_delivered_batch(
                enc, qp_shapes, delivered, needs,
                probe_stride=self._probe_stride))
        else:
            rx = _LazyFrames(codec.decode_batch(enc))

        for k, st in enumerate(self.states):
            # skip arrivals landing after the final tick: the serial path
            # queues (and never reads) them; queuing their getters here
            # would pin the tick's whole decoded batch until teardown
            if finite[k] and t + float(rep.latency[k]) <= self._t_last:
                push_arrival(st, t, float(rep.latency[k]), rx.getter(k))

        # server phase: ingestion batched across all sessions, then the
        # per-session feedback/QA emission
        due = [(k, t_cap, frame)
               for k, st in enumerate(self.states)
               for t_cap, frame in pop_due_arrivals(st, t)]
        _ingest_batched(self.states, due)
        for st in self.states:
            server_emit(st, t)

    def run(self) -> List[SessionMetrics]:
        cfg0 = self.specs[0].cfg
        n_frames = int(cfg0.duration * cfg0.fps)
        dt = 1.0 / cfg0.fps
        for i in range(n_frames):
            self.tick(i * dt)
        return [finalize(st, self.bank.reports_for(k))
                for k, st in enumerate(self.states)]


def run_fleet(sessions: Sequence[FleetSession]) -> List[SessionMetrics]:
    """Run N sessions to completion; returns per-session SessionMetrics
    in input order."""
    return Fleet(sessions).run()

"""Whole-tick on-device fleet rollout: `lax.scan` over jitted ticks.

The eager fleet engine (repro.core.fleet) pays one Python tick per frame
interval: host-side CC/ABR/trigger NumPy, two device dispatches, then
host channel math.  This module compiles a K-tick window of the WHOLE
per-tick loop into one jitted `lax.scan`: every per-session state the
eager tick mutates on the host — ChannelBank backlogs and the ack
history ring, GCC/BBR congestion-control lanes, the ReCap-ABR rate
recursion, ZeCoStream trigger/hysteresis/feedback context — lives as a
pytree of (N,)-leading device arrays in the scan carry, and the fused
plan+encode (`zecostream.rate_control_batch_fused`) plus the delivered-
bits decode run in-graph, so a K-tick window is ONE dispatch instead of
~2K dispatches + K rounds of host arithmetic.

Bit-exact parity with the eager tick loop is the design constraint, not
an afterthought; every reduction the window performs is either exactly
order-independent or routed through the same shared deterministic
kernels the eager path uses (`channel.masked_mean_latency`,
`ingest.glyph_stats_batch`).  Everything float-ordering-sensitive that
remains on the host (server ingestion, feedback emission, QA, the event
heaps) is *replayed* after each window from the scan outputs, in the
exact per-tick order the eager loop runs it.

Feedback turnaround and the depth-S carry slots
-----------------------------------------------
Server->client feedback closes the loop: an emission at tick t is
delivered at t + inference_delay + downlink_delay and changes the
client's confidence (hence ABR and the ZeCo trigger) from the delivery
tick on.  The window length is clamped to

    W_max = max(1, floor(turnaround / dt))

(`max_window`): an emission during a window can never be due within
that same window (turnaround > (W-1) * dt), so emissions stay
host-side in the replay.  The feedback PERIOD no longer clamps the
window — the in-carry delivery buffer is a depth-S slot ring, with

    S = ceil(W * dt / feedback_period)

slots per session (maximized over members): consecutive emissions are
>= feedback_period apart, so at most S pending packets can become due
inside any W-tick window.  Before each window the host pops the due
entries per session off the downlink heap into the slots in pop order
(ascending due time); in-graph, each tick applies every slot whose
`slot_t` has passed, in slot order — confidence and the ZeCo
feedback-context rows are overwritten sequentially, exactly like the
eager `session.deliver_feedback` loop (last due packet wins).  With
the default config (period 0.5 s, turnaround 0.3 s, dt 0.1 s) S == 1
and both window and carry layout are unchanged from the depth-1
scheme, so the default path's bit-exactness contract is untouched.

On-device server phase (`Fleet(..., on_device_server=True)`)
------------------------------------------------------------
By default the scan outfeeds the decoded (W, N, H, W) frame batch and
the host replays the full server phase — card detection, glyph
decoding, memory/predictor updates — from the frames.  In on-device
mode the scan instead computes the ingestion NUMERICS in-graph at the
send tick (they depend only on the decoded frame and its capture
index, not on the arrival tick): per-object glyph codes/margins
(`ingest.glyph_stats_core`, geometry-unrolled with static per-row
masks) and the contrast-based card boxes
(`grounding.detect_cards_core`, bit-exact port of `detect_cards`).
The ys carry those small stats arrays INSTEAD of the decoded frames,
so the dominant device->host transfer and the host-side detector /
glyph dispatches disappear; the host replay pushes lightweight stats
records through the same arrival heaps and applies them on pop
(`_apply_stats`), keeping feedback emission (Platt-calibrated
confidence — host-only by the 1-ulp `exp` divergence), QA and all
heap/metrics bookkeeping host-replayed and therefore bit-exact.

On-device composite render
--------------------------
Frame INPUT is symmetric to the stats outfeed: when every member's
scene is the procedural `Scene` renderer, the scan synthesizes frames
in-graph (`_render_frames`) from per-(session, object, epoch)
card+glyph composite patches built host-side with `Scene.render`'s own
numpy expressions and uploaded once as consts, plus the background
stack.  The host render loop and the (W, N, H, W) per-window frame
upload both vanish — the xs shrink to timestamps, clamped object
positions and code-epoch indices — while bit-exactness holds because
the in-graph stamp only selects the host renderer's float32 bits.
Fleets with any non-`Scene` member fall back to host-rendered frame
xs.

Sharding
--------
With a fleet mesh the window function runs under
`jit(shard_map(...))` with every (N,)-leading carry/xs/ys leaf split on
the session axis (same `session_partition` axes and dead-session
padding discipline as PR 5's eager sharded dispatches); trace arrays
are replicated, and the per-row program contains no cross-session
communication, so shard boundaries cannot perturb values.
"""
from __future__ import annotations

import functools
import heapq
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental import enable_x64
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.fleet import DEAD_SESSION_RATE, Fleet, _ingest_batched
from repro.core.recap_abr import ReCapABRBank
from repro.core.session import (client_record_send, pop_due_arrivals,
                                push_arrival, server_emit)
from repro.core.zecostream import rate_control_batch_fused
from repro.distributed.sharding import shard_map_compat
from repro.net.cc import BBRBank, GCCBank, RATE_MAX, RATE_MIN
from repro.net.channel import ACK_WINDOW, MTU_BITS, masked_mean_latency
from repro.video import codec
from repro.video.scenes import GLYPH_GRID, Scene, glyph_pattern


# Compiled window functions shared across FleetRollout instances, keyed
# on every static the trace bakes in (see _jit_key).  Without this each
# rollout run would re-jit — and thus recompile — the whole scanned
# window from scratch, which costs seconds and would make the rollout
# LOSE to the eager loop (whose per-phase jits are module-level and
# shared across Fleet instances).  The cached callable closes over the
# first instance with that signature; that is sound because everything
# the trace reads from `self` is part of the key — array-shaped inputs
# (carry/xs/consts) retrace within the wrapper as usual.
_WINDOW_FN_CACHE: Dict[tuple, object] = {}


def _no_fma(x):
    """Exact identity that pins `x` to its IEEE-rounded value.

    XLA CPU's backend may contract a multiply feeding an add/subtract
    into a single fused multiply-add, which rounds once where the eager
    host path (NumPy) rounds twice — a 1-ulp parity break (observed in
    the channel departure search: `rem - bw*(se - tt)` compiled to an
    FMA inside the scan body but not in the standalone executable).
    Routing the product through sign-bit ops — abs + copysign lower to
    integer bitmask ops — breaks the mul->add chain the contraction
    looks for; `lax.optimization_barrier` does NOT prevent it, and a
    plain bitcast round-trip is folded away by the HLO simplifier.
    Exact for every float including -0.0 (copysign restores the sign
    bit abs cleared).
    """
    return jnp.copysign(jnp.abs(x), x)


def max_window(specs, fps: float) -> int:
    """Largest window honouring the in-window-emission invariant (see
    the module docstring) across every member's turnaround.  The
    feedback period no longer bounds the window — the depth-S slot ring
    absorbs multiple due deliveries per window."""
    dt = 1.0 / fps
    w = 10 ** 9
    for s in specs:
        turnaround = s.cfg.inference_delay + s.cfg.downlink_delay
        w = min(w, int(turnaround / dt + 1e-9))
    return max(1, w)


def slot_depth(specs, fps: float, window: int) -> int:
    """Feedback-slot ring depth for a `window`-tick scan: consecutive
    emissions per session are >= feedback_period apart, so at most
    ceil(window * dt / period) can come due inside one window."""
    dt = 1.0 / fps
    return max(1, max(
        int(np.ceil(window * dt / s.cfg.feedback_period - 1e-9))
        for s in specs))


class FleetRollout:
    """Compiled K-tick windows over a `Fleet`'s session state.

    Drives a *fresh* fleet (no ticks run yet — the carry is initialized
    from the banks' start-of-run state, and BBR's ring/gain counters are
    derived from the tick index).  `Fleet.run(rollout=K)` is the public
    entry; this class owns the carry pytree, the jitted window function
    (optionally shard_mapped over the fleet's mesh) and the host-side
    replay that keeps servers/heaps/metrics identical to eager ticks.
    """

    def __init__(self, fleet: Fleet, window: Optional[int] = None):
        f = fleet
        self.fleet = f
        cfg0 = f.specs[0].cfg
        self.fps = cfg0.fps
        self.dt = 1.0 / cfg0.fps
        self._inv_fps = 1.0 / cfg0.fps
        w_max = max_window(f.specs, cfg0.fps)
        self.window = w_max if window is None else max(1, min(int(window),
                                                              w_max))
        self._slot_depth = slot_depth(f.specs, cfg0.fps, self.window)
        n = f.n_pad
        self.n = n
        if f.bank._send_times or f.bank.now != 0.0:
            raise ValueError("rollout must start from a fresh fleet "
                             "(no eager ticks before Fleet.run(rollout=K))")
        for s in f.specs:
            if s.cfg.use_recap and s.cfg.gamma != 2.0:
                raise NotImplementedError(
                    "rollout supports the paper's gamma=2 ReCap weight "
                    f"only (got gamma={s.cfg.gamma}); run eager ticks")

        # -- statics closed over by the step function -------------------
        self._dt_tr = float(f.bank.bank.dt)
        self._queue_packets = int(f.bank.queue_packets)
        # eager _drain covers at most ceil(dt_tick/dt_trace) trace steps
        # (+1 for a float-boundary guard step); unrolled with masking
        self._drain_steps = int(np.ceil(self.dt / self._dt_tr)) + 2
        self._tts_iters = int(300.0 / self._dt_tr)
        z = f.zeco
        self._frame_hw = z.frame_hw
        self._patch, self._mu = z.patch, z.mu
        self._q_min, self._q_max = z.q_min, z.q_max
        self._probe = f._probe_stride
        self._megakernel = bool(f.megakernel)
        self._on_device = bool(f.on_device_server)
        if self._megakernel and f.mesh is not None:
            raise NotImplementedError(
                "megakernel=True is single-device only (no shard_map "
                "lowering for the Pallas tick kernel); drop the mesh or "
                "the flag")
        # on-device server phase: static object geometry for the in-scan
        # glyph/card stats (positions are a precomputed xs input — the
        # constant-velocity trajectories are known host-side)
        self._card_cap = 16
        cells = sorted({obj.cell for s in f.specs
                        for obj in s.scene.objects})
        self._geo_cells = tuple(cells)
        self._o_max = max([len(s.scene.objects) for s in f.specs] + [1])
        # on-device composite render: frames are synthesized IN-GRAPH
        # from per-(session, object, epoch) composite patches stamped on
        # the background stack (see _render_frames), so neither the host
        # render loop nor the (W, N, H, W) frame upload happens at all.
        # Only the procedural `Scene` renderer is portable this way;
        # anything else falls back to host-rendered frame xs.
        self._device_render = all(
            type(s.scene) is Scene
            and (s.scene.h, s.scene.w) == self._frame_hw
            for s in f.specs)
        if self._device_render:
            self._rd_period = np.zeros(n, np.int64)
            for k, s in enumerate(f.specs):
                self._rd_period[k] = s.scene.code_period_frames or 0
        if self._on_device or self._device_render:
            o = self._o_max
            self._obj_pos0 = np.zeros((n, o, 2))
            self._obj_vel = np.zeros((n, o, 2))
            self._obj_hi = np.zeros((n, o, 2), np.int64)
            self._geo_masks = {c: np.zeros((n, o), bool)
                               for c in self._geo_cells}
            for k, s in enumerate(f.specs):
                for oi, obj in enumerate(s.scene.objects):
                    self._obj_pos0[k, oi] = obj.pos0
                    self._obj_vel[k, oi] = obj.vel
                    self._obj_hi[k, oi] = (s.scene.h - obj.size,
                                           s.scene.w - obj.size)
                    self._geo_masks[obj.cell][k, oi] = True
        # wall-clock attribution for the roofline/bench reports: device
        # dispatch+outfeed vs host replay, plus the ys transfer volume
        self.t_render = 0.0
        self.t_dispatch = 0.0
        self.t_replay = 0.0
        self._ys_nbytes = 0

        gcc = next((b for _, b in f._cc_groups if isinstance(b, GCCBank)),
                   None)
        bbr = next((b for _, b in f._cc_groups if isinstance(b, BBRBank)),
                   None)
        self._gcc_beta = gcc.beta if gcc else 0.85
        self._gcc_eta = gcc.eta if gcc else 1.05
        self._gcc_thresh = gcc.overuse_thresh if gcc else 0.010
        self._gcc_neghalf = -self._gcc_thresh / 2
        self._bbr_window = bbr.window if bbr else 10
        self._bbr_gain = np.asarray(BBRBank.GAIN_CYCLE, np.float64)
        if bbr is not None and (bbr._count != 1 or bbr._phase != 0):
            raise ValueError("rollout requires fresh BBR lanes")
        recap = next((b for _, b in f._abr_groups
                      if isinstance(b, ReCapABRBank)), None)
        self._abr_min = recap.min_rate if recap else 150e3

        self._kcap = z.fb_times.shape[1]
        self._bcap = z.fb_boxes.shape[2]
        self._consts_np = self._build_consts()
        self.carry = self._init_carry()
        self._windows_run = 0
        self._build_call()

    # ------------------------------------------------------------------
    def _build_consts(self) -> Dict[str, np.ndarray]:
        f, n = self.fleet, self.n
        live = np.zeros(n, bool)
        live[:f.n] = True
        is_gcc = np.zeros(n, bool)
        use_recap = np.zeros(n, bool)
        abr_tau = np.ones(n, np.float64)
        for k, s in enumerate(f.specs):
            is_gcc[k] = s.cfg.cc_kind == "gcc"
            use_recap[k] = s.cfg.use_recap
            if s.cfg.use_recap:
                abr_tau[k] = s.cfg.tau
        z = f.zeco
        out = {
            "tr_concat": np.asarray(f.bank.bank.concat, np.float64),
            "tr_off": np.asarray(f.bank.bank.offsets, np.int64),
            "tr_len": np.asarray(f.bank.bank.lengths, np.int64),
            # trace dt as a RUNTIME operand, not a compile-time literal:
            # XLA strength-reduces `x / const` into `x * (1/const)`,
            # whose rounding differs from the host's true division right
            # at trace-step boundaries (observed: 2.15/0.05 -> 42.99..
            # on host, 43.0 via reciprocal -> different trace index).
            # A runtime denominator keeps the real divide instruction.
            "tr_dt": np.float64(f.bank.bank.dt),
            "mtu": np.float64(MTU_BITS),
            "live": live, "is_gcc": is_gcc, "use_recap": use_recap,
            "abr_tau": abr_tau,
            "z_enabled": z.enabled.copy(),
            "z_trigger": z.trigger_bps.copy(),
            "z_release": z.release_bps.copy(),
            "z_tau": z.tau.copy(),
        }
        if self._on_device:
            # (n, O_max) bool masks selecting which (session, object)
            # rows carry each glyph geometry — per-session rows, so the
            # shard_map specs split them on the session axis like every
            # other (n,)-leading const
            for c, m in self._geo_masks.items():
                out[f"geo_{c}"] = m
        if self._device_render:
            out.update(self._render_consts())
        return out

    def _render_consts(self) -> Dict[str, np.ndarray]:
        """Background stack + pre-composed card+glyph patches for the
        in-graph render.  Each (session, object, epoch) composite is the
        uncropped (size + 2*pad)^2 region `Scene.render` would stamp —
        0.9 card border, `0.15 + 0.7 * g` glyph interior — built with
        the SAME numpy expressions on the same float32 buffers, so the
        bits the scan gathers out of it are the bits the host renderer
        would have written.  Epochs roll every `code_period_frames`, so
        a whole run needs at most epoch(n_frames - 1) + 1 composites:
        a few MB uploaded once, vs ~H*W*4 bytes per session-tick of
        frame xs."""
        f, n, o = self.fleet, self.n, self._o_max
        hh, ww = self._frame_hw
        cfg0 = f.specs[0].cfg
        n_frames = int(cfg0.duration * cfg0.fps)
        e_max, sc_max = 1, 1
        for s in f.specs:
            e_max = max(e_max, s.scene.epoch(max(n_frames - 1, 0)) + 1)
            for obj in s.scene.objects:
                sc_max = max(sc_max,
                             obj.size + 2 * max(obj.cell // 2, 2))
        bg = np.zeros((n, hh, ww), np.float32)
        comp = np.zeros((n, o, e_max, sc_max, sc_max), np.float32)
        size = np.zeros((n, o), np.int32)
        pad = np.zeros((n, o), np.int32)
        valid = np.zeros((n, o), bool)
        for k, s in enumerate(f.specs):
            bg[k] = s.scene._bg
            for oi, obj in enumerate(s.scene.objects):
                sz, pd = obj.size, max(obj.cell // 2, 2)
                size[k, oi], pad[k, oi] = sz, pd
                valid[k, oi] = True
                for e in range(e_max):
                    g = glyph_pattern(obj.code_at(e), obj.cell)
                    patch = comp[k, oi, e]
                    patch[:sz + 2 * pd, :sz + 2 * pd] = 0.9
                    patch[pd:pd + sz, pd:pd + sz] = 0.15 + 0.7 * g
        return {"rd_bg": bg, "rd_comp": comp, "rd_size": size,
                "rd_pad": pad, "rd_valid": valid}

    def _init_carry(self) -> Dict[str, np.ndarray]:
        f, n = self.fleet, self.n
        gcc_rate = np.full(n, 1e6)
        gcc_prev = np.full(n, np.nan)
        gcc_cap = np.full(n, 1e6)
        bbr_samples = np.full((n, self._bbr_window), -np.inf)
        bbr_samples[:, 0] = 1e6
        for idx, bank in f._cc_groups:
            if isinstance(bank, GCCBank):
                gcc_rate[idx] = bank.rate
                gcc_prev[idx] = bank._prev_delay
                gcc_cap[idx] = bank._capacity
            else:
                bbr_samples[idx] = bank._samples.T
        abr_rate = np.full(n, 1e6)
        for idx, bank in f._abr_groups:
            if isinstance(bank, ReCapABRBank):
                abr_rate[idx] = bank.rate
        conf = np.full(n, 0.5)
        conf[:f.n] = [st.client.confidence for st in f.states]
        z = f.zeco
        return {
            "ch_qb": f.bank._queue_bits.copy(),
            "ch_qpk": f.bank._queue_pkts.copy(),
            "ack_lat": np.full((n, ACK_WINDOW), np.inf),
            "ack_deliv": np.zeros((n, ACK_WINDOW), np.int64),
            "ack_drop": np.zeros((n, ACK_WINDOW), bool),
            "ack_qd": np.zeros((n, ACK_WINDOW), np.float64),
            "gcc_rate": gcc_rate, "gcc_prev": gcc_prev, "gcc_cap": gcc_cap,
            "bbr_samples": bbr_samples,
            "abr_rate": abr_rate,
            "conf": conf,
            "z_active": z.active.copy(),
            "z_hasfb": z.has_fb.copy(),
            "z_total": z.engaged_total.copy(),
            "z_times": z.fb_times.copy(),
            "z_boxes": z.fb_boxes.copy(),
            "z_counts": z.fb_counts.copy(),
            "z_len": z.fb_len.copy(),
            **self._empty_slots(),
        }

    def _empty_slots(self) -> Dict[str, np.ndarray]:
        n, S = self.n, self._slot_depth
        return {
            "slot_t": np.full((n, S), np.inf),
            "slot_conf": np.zeros((n, S), np.float64),
            "slot_has": np.zeros((n, S), bool),
            "slot_len": np.zeros((n, S), np.int32),
            "slot_times": np.full((n, S, self._kcap), np.inf),
            "slot_boxes": np.zeros((n, S, self._kcap, self._bcap, 4),
                                   np.float32),
            "slot_counts": np.zeros((n, S, self._kcap), np.int32),
        }

    # ------------------------------------------------------------------
    # In-graph tick
    # ------------------------------------------------------------------
    def _trace_at(self, tt, consts):
        # Trace.at: int(t / dt) truncation (t >= 0), modulo trace length
        # (runtime-operand denominator — see `tr_dt` in _build_consts)
        k = (tt / consts["tr_dt"]).astype(jnp.int64)
        return consts["tr_concat"][consts["tr_off"] + k % consts["tr_len"]]

    def _ack_stats(self, carry, i):
        """`ChannelBank.ack_stats_arrays` over the carry's ack ring:
        window = the last min(i, 20) sends, gathered oldest-first so the
        chronological order (hence the shared latency-mean kernel's add
        sequence) matches the eager history stack bit for bit."""
        w = ACK_WINDOW
        m = jnp.minimum(i, w)
        j = jnp.arange(w)
        e = i - m + j                       # global send index per slot
        valid = j < m
        slot = jnp.where(valid, e % w, 0)
        lat = jnp.where(valid[None, :], carry["ack_lat"][:, slot], jnp.inf)
        deliv = jnp.where(valid[None, :], carry["ack_deliv"][:, slot], 0)
        drop = jnp.where(valid[None, :], carry["ack_drop"][:, slot], False)
        qd = carry["ack_qd"][:, slot]
        mf = m.astype(jnp.float64)
        span = jnp.maximum(
            _no_fma((i - 1).astype(jnp.float64) * self.dt)
            - _no_fma((i - m).astype(jnp.float64) * self.dt), 1e-6)
        bits = jnp.sum(jnp.where(j < m - 1, deliv, 0), axis=1)
        finite = jnp.isfinite(lat)
        cnt = jnp.sum(finite, axis=1)
        avg = masked_mean_latency(lat, finite)
        min_lat = jnp.where(
            cnt > 0, jnp.min(jnp.where(finite, lat, jnp.inf), axis=1), 0.0)
        loss = jnp.sum(jnp.where(valid[None, :], drop, False),
                       axis=1).astype(jnp.float64) / mf
        app = jnp.sum(jnp.where(valid[None, :], qd < 0.02, False),
                      axis=1).astype(jnp.float64) / mf
        ok = m >= 2
        return {
            "delivery_rate": jnp.where(ok, bits.astype(jnp.float64) / span,
                                       0.0),
            "avg_latency": jnp.where(ok, avg, 0.05),
            "min_latency": jnp.where(ok, min_lat, 0.05),
            "loss": jnp.where(ok, loss, 0.0),
            "app_limited": jnp.where(ok, app, 1.0),
        }

    def _cc(self, carry, ack, i, consts):
        """GCCBank + BBRBank, both advanced elementwise for every row
        (each row reads only its own algorithm's lanes via `is_gcc`)."""
        delay = ack["avg_latency"] - ack["min_latency"]
        grad = jnp.where(jnp.isnan(carry["gcc_prev"]), 0.0,
                         delay - carry["gcc_prev"])
        decrease = ((grad > self._gcc_thresh) | (ack["loss"] > 0.1)
                    | (delay > 0.3))
        hold = ~decrease & (grad < self._gcc_neghalf)
        measured = jnp.maximum(ack["delivery_rate"], 1e4)
        app = ack["app_limited"] > 0.5
        cap = jnp.where(app, carry["gcc_cap"],
                        _no_fma(0.7 * carry["gcc_cap"])
                        + _no_fma(0.3 * measured))
        dec_rate = jnp.where(app,
                             jnp.minimum(carry["gcc_rate"], 1.2 * cap),
                             self._gcc_beta * measured)
        inc_cap = jnp.where(app, _no_fma(2.0 * cap) + 1e5,
                            _no_fma(1.5 * measured) + 1e5)
        inc_rate = jnp.minimum(carry["gcc_rate"] * self._gcc_eta, inc_cap)
        gcc_rate = jnp.clip(
            jnp.where(decrease, dec_rate,
                      jnp.where(hold, carry["gcc_rate"], inc_rate)),
            RATE_MIN, RATE_MAX)

        samples = carry["bbr_samples"]
        btlbw_prev = jnp.max(samples, axis=1)
        bmeas = jnp.maximum(ack["delivery_rate"], 1e4)
        bmeas = jnp.where(app, jnp.maximum(bmeas, btlbw_prev), bmeas)
        # the eager bank starts _count=1/_phase=0 and bumps both once per
        # tick, so at tick i the ring write lands at (1+i) % window and
        # the pacing gain is GAIN_CYCLE[i % len]
        samples = samples.at[:, (1 + i) % self._bbr_window].set(bmeas)
        btlbw = jnp.max(samples, axis=1)
        gain = jnp.asarray(self._bbr_gain)[i % len(self._bbr_gain)]
        gain = jnp.where(delay > 0.25, jnp.minimum(gain, 0.75), gain)
        bbr_rate = jnp.clip(btlbw * gain, RATE_MIN, RATE_MAX)

        b_hat = jnp.where(consts["is_gcc"], gcc_rate, bbr_rate)
        b_hat = jnp.where(consts["live"], b_hat, DEAD_SESSION_RATE)
        upd = {"gcc_rate": gcc_rate, "gcc_prev": delay, "gcc_cap": cap,
               "bbr_samples": samples}
        return b_hat, upd

    def _channel(self, carry, t, i, bits64, consts):
        """`ChannelBank._drain` + `send_frames` + `_time_to_send` as
        traced ops: bounded-unroll drain (masked), exact admission
        arithmetic, and a `while_loop` departure search."""
        dtr = consts["tr_dt"]
        qb = carry["ch_qb"]
        tt = jnp.maximum(i - 1, 0).astype(jnp.float64) * self.dt
        for _ in range(self._drain_steps):
            active = tt < t
            se = (jnp.floor(tt / dtr + 1e-9) + 1.0) * dtr
            se = jnp.where(se <= tt + 1e-12, tt + dtr, se)
            se = jnp.minimum(t, se)
            budget = _no_fma(self._trace_at(tt, consts) * (se - tt))
            qb = jnp.where(active, qb - jnp.minimum(budget, qb), qb)
            tt = jnp.where(active, se, tt)
        queue_pkts = jnp.ceil(qb / consts["mtu"]).astype(jnp.int64)

        bw_now = jnp.maximum(self._trace_at(t, consts), 1e3)
        queue_delay = qb / bw_now
        n_pkts = jnp.maximum(
            jnp.ceil(bits64 / consts["mtu"]).astype(jnp.int64), 1)
        free = jnp.maximum(self._queue_packets - queue_pkts, 0)
        admitted_pkts = jnp.minimum(n_pkts, free)
        admitted_bits = jnp.minimum(
            bits64, (admitted_pkts * MTU_BITS).astype(jnp.float64))
        dropped = admitted_pkts < n_pkts
        backlog = qb + admitted_bits

        def tts_cond(s):
            it, _, _, _, done = s
            return (it < self._tts_iters) & ~jnp.all(done)

        def tts_body(s):
            it, tt, rem, out, done = s
            bw = jnp.maximum(self._trace_at(tt, consts), 1e3)
            se = (jnp.floor(tt / dtr + 1e-9) + 1.0) * dtr
            se = jnp.where(se <= tt + 1e-12, tt + dtr, se)
            budget = _no_fma(bw * (se - tt))
            fin = ~done & (budget >= rem)
            out = jnp.where(fin, tt + rem / bw - t, out)
            done = done | fin
            rem = jnp.where(done, rem, rem - budget)
            return it + 1, se, rem, out, done

        it0 = jnp.zeros((), jnp.int64)
        _, tt_f, _, out, done = lax.while_loop(
            tts_cond, tts_body,
            (it0, t, backlog, jnp.zeros_like(backlog),
             jnp.zeros(backlog.shape, bool)))
        tts = jnp.where(done, out, tt_f - t)  # capped at 300 s
        latency = jnp.where(admitted_pkts > 0, tts, jnp.inf)
        upd = {"ch_qb": backlog, "ch_qpk": queue_pkts + admitted_pkts}
        return latency, admitted_bits, dropped, queue_delay, upd

    def _step(self, carry, x, consts):
        t = x["t"]
        i = x["idx"].astype(jnp.int64)
        ack = self._ack_stats(carry, i)

        # -- feedback delivery from the depth-S slot ring --------------
        # slots are filled in ascending due time, so applying them in
        # slot order reproduces the eager deliver_feedback pop order
        # (last due packet wins the conf/context overwrite)
        conf = carry["conf"]
        z_hasfb = carry["z_hasfb"]
        z_times, z_boxes = carry["z_times"], carry["z_boxes"]
        z_counts, z_len = carry["z_counts"], carry["z_len"]
        for s in range(self._slot_depth):
            due = carry["slot_t"][:, s] <= t
            conf = jnp.where(due, carry["slot_conf"][:, s], conf)
            ctx = due & carry["slot_has"][:, s]
            z_hasfb = z_hasfb | ctx
            z_times = jnp.where(ctx[:, None], carry["slot_times"][:, s],
                                z_times)
            z_boxes = jnp.where(ctx[:, None, None, None],
                                carry["slot_boxes"][:, s], z_boxes)
            z_counts = jnp.where(ctx[:, None], carry["slot_counts"][:, s],
                                 z_counts)
            z_len = jnp.where(ctx, carry["slot_len"][:, s], z_len)
        slot_t = jnp.where(carry["slot_t"] <= t, jnp.inf, carry["slot_t"])

        # -- CC + ABR --------------------------------------------------
        b_hat, cc_upd = self._cc(carry, ack, i, consts)
        tau = consts["abr_tau"]
        delta = (tau - conf) / tau
        w_eq1 = delta * jnp.abs(delta)          # gamma == 2 exact power
        recap = jnp.maximum(
            jnp.minimum(b_hat, carry["abr_rate"]
                        + _no_fma(w_eq1 * (b_hat - carry["abr_rate"]))),
            self._abr_min)
        cc_only = jnp.maximum(b_hat, self._abr_min)
        abr_rate = jnp.where(consts["use_recap"], recap, cc_only)
        rate = jnp.where(consts["live"], abr_rate, DEAD_SESSION_RATE)

        # -- ZeCoStream trigger / selection (plan_arrays) --------------
        struggling = conf < consts["z_tau"]
        thresh = jnp.where(carry["z_active"], consts["z_release"],
                           consts["z_trigger"])
        decision = consts["z_enabled"] & struggling & (rate < thresh)
        sel = jnp.argmin(jnp.abs(z_times - t), axis=1)
        rows = jnp.arange(z_times.shape[0])
        counts = jnp.where(z_len > 0, z_counts[rows, sel], 0)
        boxes = z_boxes[rows, sel]
        engaged = decision & z_hasfb & (counts > 0)
        z_total = carry["z_total"] + engaged

        # -- fused plan+encode ------------------------------------------
        # The barriers bracket the eager dispatch's jaxpr as a scheduling
        # unit.  They are belt-and-braces only: parity holds without them
        # (tree_sum's fixed-order reductions, _no_fma and the runtime
        # dt/MTU operands carry the bit-exactness contract), but they
        # keep cross-phase fusion from ever becoming a parity suspect.
        targets = (rate * self._inv_fps).astype(jnp.float32)
        if self._device_render:
            frames = self._render_frames(x["patch_pos"], x["epoch"],
                                         consts)
        else:
            frames = x["frames"]
        enc_in = lax.optimization_barrier(
            (frames, boxes, counts.astype(jnp.int32), engaged,
             targets))
        if self._megakernel:
            # fused Pallas tick kernel (fast-math tier, not bit-exact
            # vs eager): surface -> bisection -> quantize in one VMEM
            # pass per frame; interpret mode traces as jnp off-TPU
            from repro.kernels.qp_codec import ops as qp_ops
            surf, enc = qp_ops.tick_codec_frames(
                *enc_in, frame_hw=self._frame_hw, patch=self._patch,
                mu=self._mu, q_min=self._q_min, q_max=self._q_max,
                probe_stride=self._probe)
        else:
            surf, _, enc = rate_control_batch_fused(
                *enc_in, frame_hw=self._frame_hw, patch=self._patch,
                mu=self._mu, q_min=self._q_min, q_max=self._q_max,
                probe_stride=self._probe)
        surf, enc = lax.optimization_barrier((surf, enc))
        bits64 = enc.bits.astype(jnp.float64)

        # -- channel + ack-ring write ----------------------------------
        latency, admitted_bits, dropped, queue_delay, ch_upd = \
            self._channel(carry, t, i, bits64, consts)
        sent_i = bits64.astype(jnp.int64)
        deliv_i = admitted_bits.astype(jnp.int64)
        slot_w = i % ACK_WINDOW
        ack_upd = {
            "ack_lat": carry["ack_lat"].at[:, slot_w].set(latency),
            "ack_deliv": carry["ack_deliv"].at[:, slot_w].set(deliv_i),
            "ack_drop": carry["ack_drop"].at[:, slot_w].set(dropped),
            "ack_qd": carry["ack_qd"].at[:, slot_w].set(queue_delay),
        }

        # -- decode what the uplink delivered --------------------------
        delivered = jnp.maximum(deliv_i.astype(jnp.float64),
                                1e3).astype(jnp.float32)
        needs = jnp.isfinite(latency) & dropped & (deliv_i < sent_i)
        dec_in = lax.optimization_barrier((enc, surf, delivered, needs))
        decoded = codec.decode_delivered_batch(*dec_in,
                                               probe_stride=self._probe)
        decoded = lax.optimization_barrier(decoded)

        if self._on_device:
            stats = self._server_stats(decoded, x["patch_pos"], consts)

        new_carry = {
            **ch_upd, **ack_upd, **cc_upd,
            "abr_rate": abr_rate, "conf": conf,
            "z_active": decision, "z_hasfb": z_hasfb, "z_total": z_total,
            "z_times": z_times, "z_boxes": z_boxes, "z_counts": z_counts,
            "z_len": z_len,
            "slot_t": slot_t, "slot_conf": carry["slot_conf"],
            "slot_has": carry["slot_has"], "slot_len": carry["slot_len"],
            "slot_times": carry["slot_times"],
            "slot_boxes": carry["slot_boxes"],
            "slot_counts": carry["slot_counts"],
        }
        ys = {"rate": rate, "conf": conf, "bits": bits64,
              "latency": latency, "bits_sent": sent_i,
              "bits_delivered": deliv_i, "dropped": dropped,
              "queue_delay": queue_delay}
        if self._on_device:
            ys.update(stats)  # small stats arrays replace the frames
        else:
            ys["decoded"] = decoded
        return new_carry, ys

    def _render_frames(self, pos, epoch, consts):
        """`Scene.render`, in-graph: per object, one clipped card-rect
        mask + one clamped gather from that object's per-epoch composite
        patch, `jnp.where`-stamped onto the background in object order
        (later objects overwrite, like the host's sequential fills).

        Bit-exact by construction: the composites and backgrounds carry
        the host renderer's float32 bits (`_render_consts`), and the
        stamp only SELECTS them.  Wherever the mask is true, rows sit in
        [max(y-pad, 0), min(y+size+pad, H)), so the gather index
        `rows - (y - pad)` is already inside the composite — the clip
        only sanitizes indices at positions the mask discards.  Border
        cropping falls out the same way: a card clipped at the frame
        edge starts its mask at row 0, which gathers composite row
        `pad - y` — exactly the surviving part of the host's cropped
        `frame[y0:y1, x0:x1] = 0.9` fill (the glyph interior never
        crops; positions are pre-clamped to [0, H - size])."""
        hh, ww = self._frame_hw
        rows = lax.broadcasted_iota(jnp.int32, (hh, ww), 0)
        cols = lax.broadcasted_iota(jnp.int32, (hh, ww), 1)

        def one(bg, comp, p, e, size, pad, valid):
            frame = bg
            for oi in range(self._o_max):
                y, x = p[oi, 0], p[oi, 1]
                s, pd = size[oi], pad[oi]
                y0, x0 = y - pd, x - pd
                mask = ((rows >= jnp.maximum(y0, 0))
                        & (rows < jnp.minimum(y + s + pd, hh))
                        & (cols >= jnp.maximum(x0, 0))
                        & (cols < jnp.minimum(x + s + pd, ww))
                        & valid[oi])
                ri = jnp.clip(rows - y0, 0, comp.shape[-2] - 1)
                ci = jnp.clip(cols - x0, 0, comp.shape[-1] - 1)
                frame = jnp.where(mask, comp[oi, e, ri, ci], frame)
            return frame

        return jax.vmap(one)(consts["rd_bg"], consts["rd_comp"], pos,
                             epoch, consts["rd_size"], consts["rd_pad"],
                             consts["rd_valid"])

    def _server_stats(self, decoded, pos, consts):
        """The server phase's ingestion numerics, in-graph at the send
        tick: per-object glyph codes/margins and per-frame card boxes
        from the decoded frames.  Valid at the SEND tick because the
        eager path's per-arrival ingestion depends only on (decoded
        frame, capture frame index) — the arrival tick only orders the
        host-side memory/predictor bookkeeping, which `_apply_stats`
        replays from these outputs."""
        from repro.core.grounding import detect_cards_core
        from repro.core.ingest import glyph_stats_core

        # local batch size, not self.n: under shard_map this traces with
        # the per-device session slice
        n, o = decoded.shape[0], self._o_max
        margins = jnp.zeros((n, o), jnp.float64)
        codes = jnp.zeros((n, o), jnp.int64)
        for cell in self._geo_cells:
            size = GLYPH_GRID * cell
            patches = jax.vmap(lambda fr, ps: jax.vmap(
                lambda p: lax.dynamic_slice(fr, (p[0], p[1]),
                                            (size, size)))(ps))(
                decoded, pos)
            c_all, m_all = glyph_stats_core(
                patches.reshape(n * o, size, size), cell)
            mask = consts[f"geo_{cell}"]
            margins = jnp.where(mask, m_all.reshape(n, o), margins)
            codes = jnp.where(mask, c_all.reshape(n, o), codes)
        card = functools.partial(detect_cards_core,
                                 box_cap=self._card_cap)
        card_boxes, card_counts, card_over = jax.vmap(card)(decoded)
        return {"margins": margins, "codes": codes,
                "card_boxes": card_boxes, "card_counts": card_counts,
                "card_overflow": card_over}

    # ------------------------------------------------------------------
    def _window_fn(self, carry, xs, consts):
        def step(c, x):
            return self._step(c, x, consts)
        return lax.scan(step, carry, xs)

    def _jit_key(self) -> tuple:
        """Hashable signature of every static value `_step` and its
        helpers read off `self` during tracing, plus the mesh layout and
        which consts are per-session (they pick the shard_map in_specs).
        Two instances with equal keys trace to identical programs, so
        they may share one compiled window function."""
        f = self.fleet
        mesh_sig = None
        if f.mesh is not None:
            ax = f._axes
            mesh_sig = (tuple(f.mesh.axis_names), f.mesh.devices.shape,
                        tuple(d.id for d in f.mesh.devices.flat),
                        ax if (ax is None or isinstance(ax, str))
                        else tuple(ax))
        per_row = tuple(sorted(
            (k, v.shape[:1] == (self.n,))
            for k, v in self._consts_np.items()))
        return (self.n, self.dt, self.fps, self._drain_steps,
                self._tts_iters, self._queue_packets, self._frame_hw,
                self._patch, self._mu, self._q_min, self._q_max,
                self._probe, self._gcc_beta, self._gcc_eta,
                self._gcc_thresh, self._bbr_window,
                tuple(self._bbr_gain.tolist()), self._abr_min,
                self._slot_depth, self._megakernel, self._on_device,
                self._device_render, self._o_max, self._geo_cells,
                self._card_cap, mesh_sig, per_row)

    def _ys_names(self) -> Tuple[str, ...]:
        base = ("rate", "conf", "bits", "latency", "bits_sent",
                "bits_delivered", "dropped", "queue_delay")
        if self._on_device:
            return base + ("margins", "codes", "card_boxes",
                           "card_counts", "card_overflow")
        return base + ("decoded",)

    def _build_call(self):
        f = self.fleet
        key = self._jit_key()
        cached = _WINDOW_FN_CACHE.get(key)
        if cached is not None:
            self._call = cached
            with enable_x64():
                if f.mesh is not None:
                    self._consts = {
                        k: jax.device_put(
                            v, NamedSharding(f.mesh, self._consts_spec(k)))
                        for k, v in self._consts_np.items()}
                else:
                    self._consts = jax.device_put(self._consts_np)
            return
        if f.mesh is not None:
            ax = f._axes
            row = P(ax)
            carry_specs = {
                k: row for k in self.carry}
            xs_specs = {"t": P(None), "idx": P(None)}
            if not self._device_render:
                xs_specs["frames"] = P(None, ax)
            if self._on_device or self._device_render:
                xs_specs["patch_pos"] = P(None, ax)
            if self._device_render:
                xs_specs["epoch"] = P(None, ax)
            consts_specs = {k: self._consts_spec(k)
                            for k in self._consts_np}
            ys_specs = {k: P(None, ax) for k in self._ys_names()}
            # check_rep=False: the drain/time-to-send while_loops have no
            # replication rule; every operand is explicitly spec'd anyway.
            self._call = jax.jit(shard_map_compat(
                self._window_fn, f.mesh,
                (carry_specs, xs_specs, consts_specs),
                (carry_specs, ys_specs), check_rep=False))
            with enable_x64():
                self._consts = {
                    k: jax.device_put(v, NamedSharding(f.mesh,
                                                       consts_specs[k]))
                    for k, v in self._consts_np.items()}
        else:
            self._call = jax.jit(self._window_fn)
            with enable_x64():
                self._consts = jax.device_put(self._consts_np)
        _WINDOW_FN_CACHE[key] = self._call

    def _consts_spec(self, k: str) -> P:
        """PartitionSpec of one consts entry: per-session rows shard
        over the session axes, everything else replicates."""
        if self._consts_np[k].shape[:1] == (self.n,):
            return P(self.fleet._axes)
        return P()

    # ------------------------------------------------------------------
    # Host driver
    # ------------------------------------------------------------------
    def _grow_slots(self, kk: int, bb: int) -> None:
        """A feedback packet exceeded the fb-context capacities: grow
        power-of-two (the bank's `_ensure_capacity` policy), re-pad the
        carry's context arrays host-side and let jit retrace."""
        from repro.core.zecostream import _grow
        kcap = _grow(self._kcap, kk)
        bcap = _grow(self._bcap, bb)
        c = jax.device_get(self.carry)
        times = np.full((self.n, kcap), np.inf)
        times[:, :self._kcap] = c["z_times"]
        boxes = np.zeros((self.n, kcap, bcap, 4), np.float32)
        boxes[:, :self._kcap, :self._bcap] = c["z_boxes"]
        counts = np.zeros((self.n, kcap), np.int32)
        counts[:, :self._kcap] = c["z_counts"]
        c.update(z_times=times, z_boxes=boxes, z_counts=counts)
        self._kcap, self._bcap = kcap, bcap
        c.update({k: v for k, v in self._empty_slots().items()
                  if k in ("slot_times", "slot_boxes", "slot_counts")})
        self.carry = c

    def _fill_slots(self, t_end: float) -> Dict[str, np.ndarray]:
        """Pop the (provably <= slot_depth per session) feedback entries
        due by the window's last tick off the downlink heaps into the
        slot ring, in pop order (ascending due time)."""
        slots = self._empty_slots()
        for k, st in enumerate(self.fleet.states):
            fbs = []
            while (st.client.feedbacks
                   and st.client.feedbacks[0][0] <= t_end):
                fbs.append(heapq.heappop(st.client.feedbacks))
            if len(fbs) > self._slot_depth:
                raise RuntimeError(
                    "rollout window invariant violated: "
                    f"{len(fbs)} > {self._slot_depth} feedbacks due "
                    f"for session {k} by t={t_end} (window too long?)")
            for s, (t_recv, _, conf, fb) in enumerate(fbs):
                slots["slot_t"][k, s] = t_recv
                slots["slot_conf"][k, s] = conf
                if fb is not None:
                    kk, bb = fb.boxes.shape[0], fb.boxes.shape[1]
                    if kk > self._kcap or bb > self._bcap:
                        self._grow_slots(kk, bb)
                        slots = self._resize_slots(slots)
                    slots["slot_has"][k, s] = True
                    slots["slot_len"][k, s] = kk
                    slots["slot_times"][k, s, :kk] = fb.times
                    slots["slot_boxes"][k, s, :kk, :bb] = fb.boxes
                    slots["slot_counts"][k, s, :kk] = fb.counts
        return slots

    def _resize_slots(self, old: Dict[str, np.ndarray]
                      ) -> Dict[str, np.ndarray]:
        new = self._empty_slots()
        for k in ("slot_t", "slot_conf", "slot_has", "slot_len"):
            new[k] = old[k]
        kc, bc = old["slot_times"].shape[2], old["slot_boxes"].shape[3]
        new["slot_times"][:, :, :kc] = old["slot_times"]
        new["slot_boxes"][:, :, :kc, :bc] = old["slot_boxes"]
        new["slot_counts"][:, :, :kc] = old["slot_counts"]
        return new

    def run_window(self, i0: int, w: int) -> None:
        """Run ticks [i0, i0+w) as one compiled scan, then replay the
        host-side server phase per tick from the scan outputs."""
        f = self.fleet
        ts = [i * self.dt for i in range(i0, i0 + w)]
        slots = self._fill_slots(ts[-1])
        t0 = time.perf_counter()
        xs = {"t": np.asarray(ts, np.float64),
              "idx": np.arange(i0, i0 + w, dtype=np.int32)}
        if not self._device_render:
            frames = np.zeros((w, self.n) + self._frame_hw, np.float32)
            for j, t in enumerate(ts):
                fi = int(round(t * self.fps))
                for k, st in enumerate(f.states):
                    frames[j, k] = st.scene.render(fi)
            xs["frames"] = frames
        if self._on_device or self._device_render:
            xs["patch_pos"] = self._patch_positions(i0, w)
        if self._device_render:
            xs["epoch"] = self._epochs(ts)
        t0 = self._tick_timer("t_render", t0)
        carry = dict(self.carry)
        carry.update(slots)
        with enable_x64():
            self.carry, ys = self._call(carry, xs, self._consts)
        ys = jax.device_get(ys)
        self._ys_nbytes += sum(v.nbytes for v in ys.values())
        self._windows_run += 1
        t0 = self._tick_timer("t_dispatch", t0)
        if self._on_device:
            self._replay_on_device(ts, ys)
        else:
            self._replay(ts, ys)
        self._tick_timer("t_replay", t0)

    def _tick_timer(self, name: str, t0: float) -> float:
        now = time.perf_counter()
        setattr(self, name, getattr(self, name) + (now - t0))
        return now

    def _patch_positions(self, i0: int, w: int) -> np.ndarray:
        """Clamped top-left glyph-patch coordinates for every (tick,
        session, object) of the window, (w, n, O_max, 2) int32.  Matches
        the eager path's `obj.bbox(frame_idx)` + integer clamp exactly:
        np.round is round-half-even like python round, and the clip
        bounds are the same (h - size, w - size) integers."""
        fi = np.arange(i0, i0 + w, dtype=np.float64)
        pos = self._obj_pos0[None] + self._obj_vel[None] * fi[:, None,
                                                             None, None]
        return np.clip(np.round(pos), 0, self._obj_hi[None]
                       ).astype(np.int32)

    def _epochs(self, ts: List[float]) -> np.ndarray:
        """Per-(tick, session) code-epoch indices, (w, n) int32.  Frame
        index via the same `round(t * fps)` the host render loop uses;
        period 0 marks epoch-less scenes (and padded dead rows)."""
        fi = np.asarray([int(round(t * self.fps)) for t in ts], np.int64)
        per = self._rd_period
        return np.where(per > 0, fi[:, None] // np.maximum(per, 1),
                        0).astype(np.int32)

    def _replay(self, ts: List[float], ys: Dict[str, np.ndarray]) -> None:
        """The eager tick's host half, per window tick in order: channel
        history, client accounting, arrival events, batched ingestion,
        feedback emission + QA.  Identical call sequence to
        `Fleet.tick`, so heaps/metrics/server state match bit for bit."""
        f = self.fleet
        bank = f.bank
        rate_l, conf_l = ys["rate"].tolist(), ys["conf"].tolist()
        bits_l, lat_l = ys["bits"].tolist(), ys["latency"].tolist()
        deliver = (np.asarray(ts, np.float64)[:, None]
                   + ys["latency"]) <= f._t_last
        for j, t in enumerate(ts):
            lat = ys["latency"][j]
            bank.now = t
            bank._send_times.append(t)
            bank._latency.append(lat)
            bank._bits_sent.append(ys["bits_sent"][j])
            bank._bits_delivered.append(ys["bits_delivered"][j])
            bank._dropped.append(ys["dropped"][j])
            bank._queue_delay.append(ys["queue_delay"][j])
            decoded = ys["decoded"][j]
            rj, cj, bj, lj = rate_l[j], conf_l[j], bits_l[j], lat_l[j]
            for k, st in enumerate(f.states):
                st.client.rates.append(rj[k])
                st.client.confidence = cj[k]
                client_record_send(st, bj[k], lj[k])
                if deliver[j, k]:
                    push_arrival(st, t, lj[k], decoded[k].copy())
            due = [(k, t_cap, frame)
                   for k, st in enumerate(f.states)
                   for t_cap, frame in pop_due_arrivals(st, t)]
            _ingest_batched(f.states, due)
            for st in f.states:
                server_emit(st, t)

    def _replay_on_device(self, ts: List[float],
                          ys: Dict[str, np.ndarray]) -> None:
        """Host replay when the ingestion numerics ran in-graph: only
        heap/metrics bookkeeping remains.  Channel history appends stay
        tick-major (shared bank lists); the per-session work runs
        session-major — valid because every remaining update touches
        only its own session's state (heaps, client metrics, server
        memory — the seq counters are per-SessionState), so the
        cross-session interleaving of the eager loop is irrelevant."""
        f = self.fleet
        bank = f.bank
        for j, t in enumerate(ts):
            bank.now = t
            bank._send_times.append(t)
            bank._latency.append(ys["latency"][j])
            bank._bits_sent.append(ys["bits_sent"][j])
            bank._bits_delivered.append(ys["bits_delivered"][j])
            bank._dropped.append(ys["dropped"][j])
            bank._queue_delay.append(ys["queue_delay"][j])
        # Bulk-convert the per-(tick, session) scalars once per window:
        # ndarray.tolist() yields the same python floats float() would
        # (f32 -> double is exact), ~10x cheaper than 12k+ scalar
        # __getitem__/float() round-trips on a big fleet.
        lat = ys["latency"]
        lat_l, rate_l = lat.tolist(), ys["rate"].tolist()
        conf_l, bits_l = ys["conf"].tolist(), ys["bits"].tolist()
        margins, codes = ys["margins"], ys["codes"]
        cboxes, ccounts = ys["card_boxes"], ys["card_counts"]
        ccounts_l = ccounts.tolist()
        # delivered <=> finite latency AND lands inside the run: NaN/inf
        # latencies fail the <= comparison, so one vectorized mask
        # matches the eager per-element isfinite+deadline test exactly
        deliver = (np.asarray(ts, np.float64)[:, None] + lat) <= f._t_last
        bad = ys["card_overflow"] & deliver
        if bad.any():
            j, k = (int(v) for v in np.argwhere(bad)[0])
            raise RuntimeError(
                "detect_cards_core overflow (more than "
                f"{self._card_cap} boxes) for session {k} "
                f"at t={ts[j]}; raise the cap")
        for k, st in enumerate(f.states):
            rates = st.client.rates
            for j, t in enumerate(ts):
                rates.append(rate_l[j][k])
                st.client.confidence = conf_l[j][k]
                lk = lat_l[j][k]
                client_record_send(st, bits_l[j][k], lk)
                if deliver[j, k]:
                    push_arrival(st, t, lk,
                                 (margins[j, k], codes[j, k],
                                  cboxes[j, k], ccounts_l[j][k]))
                for t_cap, rec in pop_due_arrivals(st, t):
                    self._apply_stats(st, t_cap, rec)
                server_emit(st, t)

    @staticmethod
    def _apply_stats(st, t_cap: float, rec) -> None:
        """`_ingest_batched`'s apply phase from precomputed stats: the
        memory/predictor updates the eager path runs per arrival, fed by
        the in-graph glyph/card numerics instead of a decoded frame."""
        m_row, c_row, boxes_arr, n_boxes = rec
        srv = st.server.server
        frame_idx = int(round(t_cap * srv.cfg.fps))
        epoch = srv.scene.epoch(frame_idx)
        srv.frames_seen += 1
        m_list, c_list = m_row.tolist(), c_row.tolist()
        margins = []
        for oi in range(len(srv.scene.objects)):
            margin = m_list[oi]
            margins.append(margin)
            best = srv.memory.get((oi, epoch), (0.0, -1))
            if margin > best[0]:
                srv.memory[(oi, epoch)] = (margin, c_list[oi])
        srv.last_margins = margins or [0.0]
        srv.predictor.observe(
            t_cap, [tuple(r) for r in boxes_arr[:n_boxes].tolist()])

    def finish(self) -> None:
        """Sync the carry's resident state back into the fleet's banks
        so post-run inspection (zeco metrics, channel backlog) sees what
        eager ticks would have left behind."""
        c = jax.device_get(self.carry)
        f = self.fleet
        z = f.zeco
        z.active = np.asarray(c["z_active"], bool)
        z.has_fb = np.asarray(c["z_hasfb"], bool)
        z.engaged_total = np.asarray(c["z_total"], np.int64)
        z.fb_times = np.asarray(c["z_times"], np.float64)
        z.fb_boxes = np.asarray(c["z_boxes"], np.float32)
        z.fb_counts = np.asarray(c["z_counts"], np.int32)
        z.fb_len = np.asarray(c["z_len"], np.int32)
        f.bank._queue_bits = np.asarray(c["ch_qb"], np.float64)
        f.bank._queue_pkts = np.asarray(c["ch_qpk"], np.int64)

    # ------------------------------------------------------------------
    # Compiled-artifact access for the roofline report
    # ------------------------------------------------------------------
    def aot(self, w: Optional[int] = None) -> Tuple[object, object]:
        """Lower + compile the window function for a `w`-tick window
        without running it; returns (lowered, compiled) for
        `roofline.analysis.fleet_step_report`."""
        w = self.window if w is None else w
        xs = {"t": np.zeros(w, np.float64),
              "idx": np.arange(w, dtype=np.int32)}
        if not self._device_render:
            xs["frames"] = np.zeros((w, self.n) + self._frame_hw,
                                    np.float32)
        if self._on_device or self._device_render:
            xs["patch_pos"] = np.zeros((w, self.n, self._o_max, 2),
                                       np.int32)
        if self._device_render:
            xs["epoch"] = np.zeros((w, self.n), np.int32)
        carry = dict(self.carry)
        with enable_x64():
            lowered = self._call.lower(carry, xs, self._consts)
            compiled = lowered.compile()
        return lowered, compiled

"""Parameter initialization helpers and the logical-axis annotation scheme.

Params are plain nested dicts of jnp arrays (no flax).  Each module exposes
``init(key, cfg) -> params`` plus ``axes(cfg) -> tree`` where the axes tree
mirrors the params tree and holds a tuple of *logical* axis names per array
dimension.  ``repro.distributed.sharding`` maps logical names onto mesh axes
(with divisibility-aware fallback), giving MaxText-style 2-D FSDP x TP
sharding without a module framework.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

Params = Any  # nested dict pytree of arrays
AxesTree = Any  # same structure, leaves are tuples of Optional[str]


def dense_init(key, shape, dtype, in_axis: int = 0, scale: float = 1.0):
    """Truncated-normal fan-in initializer (matches common LM practice)."""
    fan_in = shape[in_axis]
    std = scale / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype, scale: float = 1.0):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def zeros_init(_key, shape, dtype):
    return jnp.zeros(shape, dtype)


def ones_init(_key, shape, dtype):
    return jnp.ones(shape, dtype)


def split_like(key, tree_keys: Sequence[str]):
    keys = jax.random.split(key, len(tree_keys))
    return dict(zip(tree_keys, keys))


def stack_init(block_init: Callable, n: int):
    """vmap a per-layer init over `n` layer keys -> stacked params."""

    def init(key, *args, **kw):
        keys = jax.random.split(key, n)
        return jax.vmap(lambda k: block_init(k, *args, **kw))(keys)

    return init


def stacked_axes(axes_tree: AxesTree) -> AxesTree:
    """Prepend the (unsharded) `layers` scan axis to every leaf."""
    from repro.distributed.sharding import is_axes_leaf
    return jax.tree.map(
        lambda t: ("layers",) + tuple(t),
        axes_tree,
        is_leaf=is_axes_leaf,
    )


def param_count(params: Params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


def param_bytes(params: Params) -> int:
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree.leaves(params))


def cast_tree(params: Params, dtype) -> Params:
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        params,
    )


def tree_shapes(params: Params):
    return jax.tree.map(lambda x: tuple(x.shape), params)


def assert_tree_matches(params: Params, axes: AxesTree):
    """Every array's rank must match its logical-axes tuple length."""

    def chk(path, x, a):
        assert len(a) == x.ndim, f"{path}: rank {x.ndim} vs axes {a}"

    flat_p = jax.tree_util.tree_flatten_with_path(params)[0]
    flat_a = jax.tree.leaves(axes, is_leaf=lambda t: isinstance(t, tuple))
    assert len(flat_p) == len(flat_a), (
        f"param/axes leaf count mismatch: {len(flat_p)} vs {len(flat_a)}")
    for (path, x), a in zip(flat_p, flat_a):
        chk(jax.tree_util.keystr(path), x, a)

"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) block.

Chunked SSD algorithm: within-chunk quadratic attention-like term +
inter-chunk recurrence on (H, P, N) states, both expressed as einsums so
the TPU MXU does all the work; the inter-chunk scan runs over S/chunk
steps only.  Decode is the O(1) selective-state update.

Layout follows the reference Mamba-2: a single input projection produces
[z, x, B, C, dt]; depthwise causal conv over (x, B, C); gated RMSNorm
before the output projection.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.config import ModelConfig
from repro.models.layers import rmsnorm


class SSMState(NamedTuple):
    conv: jnp.ndarray  # (B, conv_width-1, conv_dim) rolling conv inputs
    ssm: jnp.ndarray   # (B, H, P, N) recurrent state


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    nheads = d_inner // s.headdim
    conv_dim = d_inner + 2 * s.ngroups * s.d_state
    return d_inner, nheads, conv_dim


def ssd_init(key, cfg: ModelConfig):
    s = cfg.ssm
    d_inner, nheads, conv_dim = _dims(cfg)
    d_in_proj = 2 * d_inner + 2 * s.ngroups * s.d_state + nheads
    ks = common.split_like(
        key, ["in_proj", "conv", "dt_bias", "a_log", "d", "norm", "out_proj"])
    # dt bias: inverse-softplus of dt sampled log-uniform in [dt_min, dt_max]
    u = jax.random.uniform(ks["dt_bias"], (nheads,), jnp.float32)
    dt = jnp.exp(u * (math.log(s.dt_max) - math.log(s.dt_min)) + math.log(s.dt_min))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))
    a = jax.random.uniform(ks["a_log"], (nheads,), jnp.float32,
                           minval=s.a_init_range[0], maxval=s.a_init_range[1])
    return {
        "in_proj": common.dense_init(ks["in_proj"], (cfg.d_model, d_in_proj), cfg.p_dtype),
        "conv_w": common.dense_init(ks["conv"], (s.conv_width, conv_dim), cfg.p_dtype),
        "conv_b": jnp.zeros((conv_dim,), cfg.p_dtype),
        "dt_bias": dt_bias,
        "a_log": jnp.log(a),
        "d": jnp.ones((nheads,), jnp.float32),
        "norm": {"scale": jnp.ones((d_inner,), cfg.p_dtype)},
        "out_proj": common.dense_init(ks["out_proj"], (d_inner, cfg.d_model), cfg.p_dtype),
    }


def ssd_axes(_cfg):
    return {
        "in_proj": ("embed", "mlp"),
        "conv_w": ("conv", "mlp"),
        "conv_b": ("mlp",),
        "dt_bias": ("heads",),
        "a_log": ("heads",),
        "d": ("heads",),
        "norm": {"scale": ("mlp",)},
        "out_proj": ("mlp", "embed"),
    }


def _split_proj(zxbcdt, cfg: ModelConfig):
    s = cfg.ssm
    d_inner, nheads, _ = _dims(cfg)
    gn = s.ngroups * s.d_state
    z, x, B, C, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + gn, 2 * d_inner + 2 * gn],
        axis=-1)
    return z, x, B, C, dt


def _causal_conv(x, w, b, prev: Optional[jnp.ndarray] = None):
    """Depthwise causal conv. x (B,S,C), w (K,C) -> (B,S,C).

    `prev` (B,K-1,C) holds the tail of the previous segment (decode)."""
    K = w.shape[0]
    if prev is None:
        prev = jnp.zeros((x.shape[0], K - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([prev, x], axis=1)
    out = sum(
        xp[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(K))
    return out + b[None, None, :], xp[:, -(K - 1):, :]


def _segsum(x):
    """x (..., L) -> (..., L, L) lower-triangular pairwise cumulative sums."""
    L = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool))
    return jnp.where(mask, d, -jnp.inf)


def ssd_scan(x, dt, A, B, C, chunk: int,
             init_state: Optional[jnp.ndarray] = None):
    """Chunked SSD.

    x  (b, s, h, p)   inputs per head
    dt (b, s, h)      positive step sizes
    A  (h,)           negative decay rates
    B  (b, s, g, n)   input matrices (g groups broadcast over heads)
    C  (b, s, g, n)   output matrices
    Returns y (b, s, h, p) and final state (b, h, p, n).
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    L = chunk
    assert s % L == 0, f"seq {s} % chunk {L}"
    nc = s // L
    hpg = h // g

    xc = x.reshape(b, nc, L, h, p)
    dtc = dt.reshape(b, nc, L, h)
    Bc = B.reshape(b, nc, L, g, n)
    Cc = C.reshape(b, nc, L, g, n)

    dA = dtc * A[None, None, None, :]          # (b,c,l,h) negative
    dA = jnp.moveaxis(dA, -1, 1)               # (b,h,c,l)
    dA_cs = jnp.cumsum(dA, axis=-1)            # (b,h,c,l)

    # 1. intra-chunk (quadratic) term
    Ldec = jnp.exp(_segsum(dA))                # (b,h,c,l,l)
    # scores: C_i . B_j  with decay and dt weighting
    CB = jnp.einsum("bclgn,bcsgn->bcgls", Cc, Bc)          # (b,c,g,l,s)
    CB = jnp.repeat(CB, hpg, axis=2)                       # (b,c,h,l,s)
    att = CB * jnp.moveaxis(Ldec, 1, 2)                    # (b,c,h,l,s)
    att = att * jnp.moveaxis(dtc, -1, -2)[..., None, :]     # dt_j weighting (b,c,h,1?,s)
    y_diag = jnp.einsum("bchls,bcshp->bclhp", att.astype(x.dtype), xc)

    # 2. chunk-final states: sum_j exp(dA_cs[-1]-dA_cs[j]) dt_j B_j x_j
    decay_states = jnp.exp(dA_cs[..., -1:] - dA_cs)        # (b,h,c,l)
    wts = decay_states * jnp.moveaxis(dtc, -1, 1)          # (b,h,c,l)
    Brep = jnp.repeat(Bc, hpg, axis=3) if g != h else Bc   # (b,c,l,h,n)
    xw = (xc * jnp.moveaxis(wts, 1, -1)[..., None]).astype(x.dtype)
    states = jnp.einsum("bclhn,bclhp->bchpn", Brep.astype(x.dtype), xw)

    # 3. inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(dA_cs[..., -1])                   # (b,h,c)

    def step(carry, inp):
        st, dec = inp                                       # (b,h,p,n), (b,h)
        new = carry * dec[..., None, None] + st
        return new, carry                                   # emit state *entering* the chunk

    init = (init_state if init_state is not None
            else jnp.zeros((b, h, p, n), jnp.float32))
    states_c = jnp.moveaxis(states, 1, 0).astype(jnp.float32)  # (c,b,h,p,n)
    decays_c = jnp.moveaxis(chunk_decay, -1, 0)                # (c,b,h)
    final, prev_states = jax.lax.scan(step, init, (states_c, decays_c))
    prev_states = jnp.moveaxis(prev_states, 0, 1)              # (b,c,h,p,n)

    # 4. inter-chunk contribution: C_i exp(dA_cs[i]) S_prev
    state_decay = jnp.exp(dA_cs)                               # (b,h,c,l)
    Crep = jnp.repeat(Cc, hpg, axis=3) if g != h else Cc       # (b,c,l,h,n)
    y_off = jnp.einsum("bclhn,bchpn->bclhp",
                       Crep.astype(jnp.float32), prev_states)
    y_off = y_off * jnp.moveaxis(state_decay, 1, -1).reshape(b, nc, L, h)[..., None]
    y = (y_diag.astype(jnp.float32) + y_off).reshape(b, s, h, p)
    return y.astype(x.dtype), final


def ssd_apply(params, x, cfg: ModelConfig,
              state: Optional[SSMState] = None,
              return_state: bool = False):
    """Full Mamba-2 mixer. x (B,S,D) -> (B,S,D) [, SSMState]."""
    s = cfg.ssm
    d_inner, nheads, conv_dim = _dims(cfg)
    dt_ = cfg.act_dtype
    zxbcdt = jnp.einsum("bsd,de->bse", x, params["in_proj"].astype(dt_))
    z, xin, B, C, dt = _split_proj(zxbcdt, cfg)

    conv_in = jnp.concatenate([xin, B, C], axis=-1)
    prev = state.conv if state is not None else None
    conv_out, conv_tail = _causal_conv(
        conv_in, params["conv_w"].astype(dt_), params["conv_b"].astype(dt_), prev)
    conv_out = jax.nn.silu(conv_out)
    xin, B, C = jnp.split(
        conv_out, [d_inner, d_inner + s.ngroups * s.d_state], axis=-1)

    bsz, seq = x.shape[0], x.shape[1]
    xh = xin.reshape(bsz, seq, nheads, s.headdim)
    Bh = B.reshape(bsz, seq, s.ngroups, s.d_state)
    Ch = C.reshape(bsz, seq, s.ngroups, s.d_state)
    dt = jax.nn.softplus(
        dt.astype(jnp.float32) + params["dt_bias"][None, None, :])
    A = -jnp.exp(params["a_log"])

    init_ssm = state.ssm if state is not None else None
    chunk = min(s.chunk, seq)
    while seq % chunk:  # largest divisor of seq <= configured chunk
        chunk -= 1
    y, final = ssd_scan(xh, dt, A, Bh, Ch, chunk, init_ssm)
    y = y + xh * params["d"][None, None, :, None].astype(dt_)
    y = y.reshape(bsz, seq, d_inner)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(dt_))
    if return_state:
        return out, SSMState(conv=conv_tail, ssm=final)
    return out


def ssd_decode_step(params, x, state: SSMState, cfg: ModelConfig
                    ) -> Tuple[jnp.ndarray, SSMState]:
    """O(1) recurrent update. x (B,1,D) -> (B,1,D), new state."""
    s = cfg.ssm
    d_inner, nheads, _ = _dims(cfg)
    dt_ = cfg.act_dtype
    zxbcdt = jnp.einsum("bsd,de->bse", x, params["in_proj"].astype(dt_))
    z, xin, B, C, dt = _split_proj(zxbcdt, cfg)

    conv_in = jnp.concatenate([xin, B, C], axis=-1)
    conv_out, conv_tail = _causal_conv(
        conv_in, params["conv_w"].astype(dt_), params["conv_b"].astype(dt_),
        state.conv)
    conv_out = jax.nn.silu(conv_out)
    xin, B, C = jnp.split(
        conv_out, [d_inner, d_inner + s.ngroups * s.d_state], axis=-1)

    bsz = x.shape[0]
    xh = xin.reshape(bsz, nheads, s.headdim)                      # S=1 squeezed
    Bh = B.reshape(bsz, s.ngroups, s.d_state)
    Ch = C.reshape(bsz, s.ngroups, s.d_state)
    dt1 = jax.nn.softplus(
        dt.astype(jnp.float32)[:, 0, :] + params["dt_bias"][None, :])  # (B,H)
    A = -jnp.exp(params["a_log"])
    decay = jnp.exp(dt1 * A[None, :])                              # (B,H)

    hpg = nheads // s.ngroups
    Bfull = jnp.repeat(Bh, hpg, axis=1)                            # (B,H,N)
    Cfull = jnp.repeat(Ch, hpg, axis=1)
    dBx = jnp.einsum("bh,bhn,bhp->bhpn", dt1,
                     Bfull.astype(jnp.float32), xh.astype(jnp.float32))
    new_ssm = state.ssm * decay[..., None, None] + dBx
    y = jnp.einsum("bhpn,bhn->bhp", new_ssm, Cfull.astype(jnp.float32))
    y = y.astype(dt_) + xh * params["d"][None, :, None].astype(dt_)
    y = y.reshape(bsz, 1, d_inner)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(dt_))
    return out, SSMState(conv=conv_tail, ssm=new_ssm)


def ssd_init_state(cfg: ModelConfig, batch: int) -> SSMState:
    s = cfg.ssm
    d_inner, nheads, conv_dim = _dims(cfg)
    return SSMState(
        conv=jnp.zeros((batch, s.conv_width - 1, conv_dim), cfg.act_dtype),
        ssm=jnp.zeros((batch, nheads, s.headdim, s.d_state), jnp.float32),
    )

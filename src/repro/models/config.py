"""Model configuration dataclasses.

Every assigned architecture is expressed as a :class:`ModelConfig`; the
family field selects the block composition (dense / moe / ssm / hybrid).
Configs are immutable and hashable so they can be closed over by jitted
functions safely.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    """GShard-style token-choice MoE."""

    num_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_z_coef: float = 1e-3
    aux_loss_coef: float = 1e-2
    # number of always-on shared experts (DeepSeek-style); 0 for dbrx/qwen3
    num_shared: int = 0
    # dispatch implementation: "gshard" = one-hot dispatch/combine einsums
    # (canonical, SPMD-friendly); "gather" = scatter/gather token buffers
    # (no (B,S,E,C) tensors — the §Perf memory-bytes optimization)
    moe_impl: str = "gshard"
    # keep dispatch/combine one-hots in fp32 (exact) or cast to the
    # activation dtype at creation (halves the dominant MoE collective
    # payload — §Perf H1)
    dispatch_fp32: bool = True


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD, state-space duality) block parameters."""

    d_state: int = 128
    expand: int = 2
    headdim: int = 64
    ngroups: int = 1
    conv_width: int = 4
    chunk: int = 256
    dt_min: float = 0.001
    dt_max: float = 0.1
    a_init_range: Tuple[float, float] = (1.0, 16.0)


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    """Griffin / RecurrentGemma RG-LRU recurrent block parameters."""

    lru_width: Optional[int] = None  # defaults to d_model
    conv_width: int = 4
    c_constant: float = 8.0
    # layer pattern within a repeating group (recurrentgemma is 2 rec : 1 attn)
    pattern: Tuple[str, ...] = ("rec", "rec", "attn")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None  # defaults to d_model // n_heads

    # attention details
    qk_norm: bool = False
    rope_theta: float = 1e4
    local_window: Optional[int] = None  # sliding window (recurrentgemma)
    # M-RoPE (qwen2-vl): per-component rotary sections (t, h, w); the
    # sections are in units of rotary pairs and must sum to head_dim // 2.
    mrope_sections: Optional[Tuple[int, int, int]] = None

    # multi-codebook audio LM (musicgen): inputs/outputs are (B, K, S)
    num_codebooks: int = 1

    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None

    # numerics
    dtype: str = "bfloat16"  # activation / compute dtype
    param_dtype: str = "bfloat16"
    logits_dtype: str = "float32"

    # norm
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # attention execution strategy
    attn_block_q: int = 512
    attn_block_kv: int = 1024
    # beyond this sequence length the blocked (flash-style scan) attention
    # path is used so HLO never materializes an O(S^2) score tensor
    blocked_threshold: int = 8192
    attention_impl: str = "reference"  # reference | pallas

    # remat policy for the scanned layer stack: none | dots | full
    remat_policy: str = "dots"
    # unroll the layer scan (dry-run probes only: XLA cost_analysis counts a
    # while-loop body once, so roofline probes lower shallow unrolled copies)
    scan_unroll: bool = False

    # KV-cache storage: "model" (= activation dtype) or "int8"
    # (KIVI/KVQuant-style per-token-per-head scales; serving memory win)
    kv_cache_dtype: str = "model"

    # training
    z_loss_coef: float = 1e-4

    # ---- derived helpers -------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads if self.n_kv_heads else 1

    @property
    def act_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def p_dtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def attn_layer_indices(self) -> Tuple[int, ...]:
        """Indices of attention layers (hybrid family only)."""
        if self.family != "hybrid":
            return tuple(range(self.n_layers))
        pat = self.rglru.pattern
        return tuple(
            i for i in range(self.n_layers) if pat[i % len(pat)] == "attn"
        )

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def validate(self) -> "ModelConfig":
        assert self.n_heads % max(self.n_kv_heads, 1) == 0, "GQA grouping"
        if self.mrope_sections is not None:
            assert sum(self.mrope_sections) == self.head_dim_ // 2, (
                f"M-RoPE sections {self.mrope_sections} must sum to "
                f"head_dim/2 = {self.head_dim_ // 2}"
            )
        if self.family == "moe":
            assert self.moe is not None
        if self.family == "ssm":
            assert self.ssm is not None
        if self.family == "hybrid":
            assert self.rglru is not None and self.local_window is not None
        return self


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests.

    Keeps the family, block composition and every structural flag but
    shrinks widths/depths so a forward+backward runs in <1s on CPU.
    """
    kw = dict(
        name=cfg.name + "-smoke",
        n_layers=min(cfg.n_layers, 2 if cfg.family != "hybrid" else 3),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2),
        head_dim=32,
        d_ff=256,
        vocab=512,
        blocked_threshold=64,  # exercise the blocked path in smoke tests too
        attn_block_q=16,
        attn_block_kv=16,
    )
    if cfg.mrope_sections is not None:
        kw["mrope_sections"] = (4, 6, 6)  # sums to head_dim/2 = 16
    if cfg.local_window is not None:
        kw["local_window"] = 32
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe, num_experts=min(cfg.moe.num_experts, 8),
            top_k=min(cfg.moe.top_k, 2), d_ff_expert=128)
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(
            cfg.ssm, d_state=16, headdim=16, chunk=16)
    kw.update(overrides)
    return cfg.replace(**kw).validate()

"""Griffin / RecurrentGemma RG-LRU recurrent block (arXiv:2402.19427).

Block layout (the "recurrent block" of Griffin):

    x ─ linear ─ conv1d(4) ─ RG-LRU ─┐
                                      ⊙ ─ linear → out
    x ─ linear ─ GeLU ───────────────┘

RG-LRU recurrence (per channel):
    r_t = sigmoid(W_a x_t + b_a)
    i_t = sigmoid(W_x x_t + b_x)
    a_t = exp(-c · softplus(Λ) · r_t)
    h_t = a_t h_{t-1} + sqrt(1 - a_t²) · (i_t ⊙ x_t)

Train/prefill uses an associative scan over the sequence (log-depth on
TPU); decode is the O(1) update.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.config import ModelConfig


class RGLRUState(NamedTuple):
    conv: jnp.ndarray  # (B, conv_width-1, W)
    h: jnp.ndarray     # (B, W) recurrent state (fp32)


def _width(cfg: ModelConfig) -> int:
    return cfg.rglru.lru_width or cfg.d_model


def rglru_init(key, cfg: ModelConfig):
    W = _width(cfg)
    r = cfg.rglru
    ks = common.split_like(
        key, ["in_x", "in_gate", "conv", "wa", "wx", "lam", "out"])
    # Λ init so that a^c = exp(-c softplus Λ) gives decay in [0.9, 0.999]
    u = jax.random.uniform(ks["lam"], (W,), jnp.float32, 0.9, 0.999)
    # solve exp(-c * softplus(lam)) = u  ->  softplus(lam) = -log(u)/c
    sp = -jnp.log(u) / r.c_constant
    lam = jnp.log(jnp.expm1(sp))
    return {
        "in_x": common.dense_init(ks["in_x"], (cfg.d_model, W), cfg.p_dtype),
        "in_gate": common.dense_init(ks["in_gate"], (cfg.d_model, W), cfg.p_dtype),
        "conv_w": common.dense_init(ks["conv"], (r.conv_width, W), cfg.p_dtype),
        "conv_b": jnp.zeros((W,), cfg.p_dtype),
        "wa": common.dense_init(ks["wa"], (W, W), jnp.float32, scale=0.5),
        "ba": jnp.zeros((W,), jnp.float32),
        "wx": common.dense_init(ks["wx"], (W, W), jnp.float32, scale=0.5),
        "bx": jnp.zeros((W,), jnp.float32),
        "lam": lam,
        "out": common.dense_init(ks["out"], (W, cfg.d_model), cfg.p_dtype),
    }


def rglru_axes(_cfg):
    return {
        "in_x": ("embed", "mlp"),
        "in_gate": ("embed", "mlp"),
        "conv_w": ("conv", "mlp"),
        "conv_b": ("mlp",),
        "wa": ("mlp", None),
        "ba": (None,),
        "wx": ("mlp", None),
        "bx": (None,),
        "lam": (None,),
        "out": ("mlp", "embed"),
    }


def _causal_conv(x, w, b, prev: Optional[jnp.ndarray] = None):
    K = w.shape[0]
    if prev is None:
        prev = jnp.zeros((x.shape[0], K - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([prev, x], axis=1)
    out = sum(
        xp[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(K))
    return out + b[None, None, :], xp[:, -(K - 1):, :]


def _gates(params, x, c_constant):
    """x (B,S,W) fp32 -> (a, gated_in) both (B,S,W) fp32."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ params["wa"] + params["ba"])
    i = jax.nn.sigmoid(xf @ params["wx"] + params["bx"])
    log_a = -c_constant * jax.nn.softplus(params["lam"]) * r  # (B,S,W), <=0
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) with a = exp(log_a); use expm1 for stability
    beta = jnp.sqrt(-jnp.expm1(2.0 * log_a))
    return a, beta * (i * xf)


def rglru_scan(a, bx, init_h: Optional[jnp.ndarray] = None):
    """h_t = a_t h_{t-1} + bx_t via associative scan over S. (B,S,W) fp32."""
    if init_h is not None:
        # fold the initial state into the first input
        bx = bx.at[:, 0, :].add(a[:, 0, :] * init_h)

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    aa, hh = jax.lax.associative_scan(combine, (a, bx), axis=1)
    del aa
    return hh


def rglru_apply(params, x, cfg: ModelConfig,
                state: Optional[RGLRUState] = None,
                return_state: bool = False):
    """x (B,S,D) -> (B,S,D) [, RGLRUState]."""
    r = cfg.rglru
    dt = cfg.act_dtype
    gate = jax.nn.gelu(
        jnp.einsum("bsd,dw->bsw", x, params["in_gate"].astype(dt)))
    xr = jnp.einsum("bsd,dw->bsw", x, params["in_x"].astype(dt))
    prev = state.conv if state is not None else None
    xr, conv_tail = _causal_conv(
        xr, params["conv_w"].astype(dt), params["conv_b"].astype(dt), prev)
    a, bx = _gates(params, xr, r.c_constant)
    h0 = state.h if state is not None else None
    h = rglru_scan(a, bx, h0)
    y = (h.astype(dt) * gate)
    out = jnp.einsum("bsw,wd->bsd", y, params["out"].astype(dt))
    if return_state:
        return out, RGLRUState(conv=conv_tail, h=h[:, -1, :])
    return out


def rglru_decode_step(params, x, state: RGLRUState, cfg: ModelConfig
                      ) -> Tuple[jnp.ndarray, RGLRUState]:
    """x (B,1,D) -> (B,1,D), new state."""
    r = cfg.rglru
    dt = cfg.act_dtype
    gate = jax.nn.gelu(
        jnp.einsum("bsd,dw->bsw", x, params["in_gate"].astype(dt)))
    xr = jnp.einsum("bsd,dw->bsw", x, params["in_x"].astype(dt))
    xr, conv_tail = _causal_conv(
        xr, params["conv_w"].astype(dt), params["conv_b"].astype(dt), state.conv)
    a, bx = _gates(params, xr, r.c_constant)
    h = a[:, 0, :] * state.h + bx[:, 0, :]
    y = h[:, None, :].astype(dt) * gate
    out = jnp.einsum("bsw,wd->bsd", y, params["out"].astype(dt))
    return out, RGLRUState(conv=conv_tail, h=h)


def rglru_init_state(cfg: ModelConfig, batch: int) -> RGLRUState:
    W = _width(cfg)
    return RGLRUState(
        conv=jnp.zeros((batch, cfg.rglru.conv_width - 1, W), cfg.act_dtype),
        h=jnp.zeros((batch, W), jnp.float32),
    )

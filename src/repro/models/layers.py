"""Basic layers: RMSNorm, embeddings, output heads, cross-entropy loss."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.config import ModelConfig


# --------------------------------------------------------------------------
# RMSNorm
# --------------------------------------------------------------------------
def rmsnorm_init(key, dim, cfg: ModelConfig):
    del key
    return {"scale": jnp.ones((dim,), cfg.p_dtype)}


def rmsnorm_axes(_cfg):
    return {"scale": (None,)}


def rmsnorm(params, x, eps: float):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(dtype)


def rmsnorm_nop(x, eps: float):
    """Scale-free RMSNorm (used for per-head qk-norm without extra params
    when the config calls for it; qwen3 uses learned scales, see attention)."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dtype)


# --------------------------------------------------------------------------
# Token embedding + LM head
# --------------------------------------------------------------------------
def embedding_init(key, cfg: ModelConfig):
    if cfg.num_codebooks > 1:
        # one table per codebook (musicgen); summed at input
        return {
            "table": common.embed_init(
                key, (cfg.num_codebooks, cfg.vocab, cfg.d_model), cfg.p_dtype)
        }
    return {"table": common.embed_init(key, (cfg.vocab, cfg.d_model), cfg.p_dtype)}


def embedding_axes(cfg: ModelConfig):
    if cfg.num_codebooks > 1:
        return {"table": (None, "vocab", "embed")}
    return {"table": ("vocab", "embed")}


def embed(params, tokens, cfg: ModelConfig):
    """tokens: (B, S) int32 — or (B, K, S) for multi-codebook models."""
    table = params["table"].astype(cfg.act_dtype)
    if cfg.num_codebooks > 1:
        # (B, K, S) -> sum_k table[k, tok]
        def one(k):
            return jnp.take(table[k], tokens[:, k, :], axis=0)

        return sum(one(k) for k in range(cfg.num_codebooks))
    return jnp.take(table, tokens, axis=0)


def lm_head_init(key, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return {}
    if cfg.num_codebooks > 1:
        return {
            "w": common.dense_init(
                key, (cfg.num_codebooks, cfg.d_model, cfg.vocab), cfg.p_dtype, in_axis=1)
        }
    return {"w": common.dense_init(key, (cfg.d_model, cfg.vocab), cfg.p_dtype)}


def lm_head_axes(cfg: ModelConfig):
    if cfg.tie_embeddings:
        return {}
    if cfg.num_codebooks > 1:
        return {"w": (None, "embed", "vocab")}
    return {"w": ("embed", "vocab")}


def lm_head(params, embed_params, x, cfg: ModelConfig):
    """x: (B, S, D) -> logits (B, S, V) or (B, K, S, V) for codebooks."""
    if cfg.tie_embeddings:
        table = embed_params["table"].astype(cfg.act_dtype)
        if cfg.num_codebooks > 1:
            return jnp.einsum("bsd,kvd->bksv", x, table).astype(cfg.logits_dtype)
        return jnp.einsum("bsd,vd->bsv", x, table).astype(cfg.logits_dtype)
    w = params["w"].astype(cfg.act_dtype)
    if cfg.num_codebooks > 1:
        return jnp.einsum("bsd,kdv->bksv", x, w).astype(cfg.logits_dtype)
    return jnp.einsum("bsd,dv->bsv", x, w).astype(cfg.logits_dtype)


# --------------------------------------------------------------------------
# Loss
# --------------------------------------------------------------------------
def softmax_cross_entropy(logits, labels, z_loss_coef: float = 0.0):
    """Stable CE with optional z-loss (PaLM); logits (..., V), labels (...)."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = lse - gold
    if z_loss_coef:
        ce = ce + z_loss_coef * jnp.square(lse)
    return ce


def lm_loss(logits, labels, mask=None, z_loss_coef: float = 0.0):
    """Mean next-token CE.  logits (B,S,V) or (B,K,S,V); labels match."""
    ce = softmax_cross_entropy(logits, labels, z_loss_coef)
    if mask is None:
        return jnp.mean(ce)
    mask = mask.astype(jnp.float32)
    return jnp.sum(ce * mask) / jnp.maximum(jnp.sum(mask), 1.0)

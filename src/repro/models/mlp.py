"""SwiGLU feed-forward (llama/qwen/mistral style) and plain GeLU MLP."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.config import ModelConfig


def swiglu_init(key, cfg: ModelConfig, d_ff=None):
    d_ff = d_ff or cfg.d_ff
    ks = common.split_like(key, ["wi", "wg", "wo"])
    return {
        "wi": common.dense_init(ks["wi"], (cfg.d_model, d_ff), cfg.p_dtype),
        "wg": common.dense_init(ks["wg"], (cfg.d_model, d_ff), cfg.p_dtype),
        "wo": common.dense_init(ks["wo"], (d_ff, cfg.d_model), cfg.p_dtype),
    }


def swiglu_axes(_cfg):
    return {
        "wi": ("embed", "mlp"),
        "wg": ("embed", "mlp"),
        "wo": ("mlp", "embed"),
    }


def swiglu(params, x, cfg: ModelConfig):
    dt = cfg.act_dtype
    h = jnp.einsum("bsd,df->bsf", x, params["wi"].astype(dt))
    g = jnp.einsum("bsd,df->bsf", x, params["wg"].astype(dt))
    h = jax.nn.silu(g) * h
    return jnp.einsum("bsf,fd->bsd", h, params["wo"].astype(dt))


def gelu_mlp_init(key, cfg: ModelConfig, d_ff=None):
    d_ff = d_ff or cfg.d_ff
    ks = common.split_like(key, ["wi", "wo"])
    return {
        "wi": common.dense_init(ks["wi"], (cfg.d_model, d_ff), cfg.p_dtype),
        "wo": common.dense_init(ks["wo"], (d_ff, cfg.d_model), cfg.p_dtype),
    }


def gelu_mlp_axes(_cfg):
    return {"wi": ("embed", "mlp"), "wo": ("mlp", "embed")}


def gelu_mlp(params, x, cfg: ModelConfig):
    dt = cfg.act_dtype
    h = jnp.einsum("bsd,df->bsf", x, params["wi"].astype(dt))
    h = jax.nn.gelu(h)
    return jnp.einsum("bsf,fd->bsd", h, params["wo"].astype(dt))

from repro.models.config import ModelConfig, MoEConfig, RGLRUConfig, SSMConfig, reduced  # noqa: F401

"""GShard-style token-choice top-k MoE with capacity-factor routing.

Design for scale (EP over the `model` mesh axis):

* expert weights carry an `experts` logical axis -> sharded over `model`;
* tokens are dispatched with one-hot dispatch/combine einsums, so XLA's
  SPMD partitioner materializes the all-to-all from sharding propagation
  (the standard GShard lowering) rather than hand-written collectives;
* capacity-factor truncation keeps the dispatch tensor static-shaped,
  which is required for pjit;
* auxiliary load-balancing loss (Switch) + router z-loss are returned so
  the trainer can add them.

The router runs in fp32 — bf16 logits measurably degrade load balance.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.config import ModelConfig, MoEConfig


def moe_init(key, cfg: ModelConfig):
    m = cfg.moe
    ks = common.split_like(key, ["router", "wi", "wg", "wo", "shared"])
    E, D, F = m.num_experts, cfg.d_model, m.d_ff_expert
    p = {
        "router": common.dense_init(ks["router"], (D, E), jnp.float32),
        "wi": common.dense_init(ks["wi"], (E, D, F), cfg.p_dtype, in_axis=1),
        "wg": common.dense_init(ks["wg"], (E, D, F), cfg.p_dtype, in_axis=1),
        "wo": common.dense_init(ks["wo"], (E, F, D), cfg.p_dtype, in_axis=1),
    }
    if m.num_shared:
        from repro.models.mlp import swiglu_init
        p["shared"] = swiglu_init(ks["shared"], cfg, d_ff=F * m.num_shared)
    return p


def moe_axes(cfg: ModelConfig):
    a = {
        "router": ("embed", None),
        # expert weights get their own FSDP logical name so serving /
        # collective-bound hillclimbs can keep them expert-sharded but
        # replicated along `data` (stationary weights, no per-step gather)
        "wi": ("experts", "expert_embed", "expert_mlp"),
        "wg": ("experts", "expert_embed", "expert_mlp"),
        "wo": ("experts", "expert_mlp", "expert_embed"),
    }
    if cfg.moe.num_shared:
        from repro.models.mlp import swiglu_axes
        a["shared"] = swiglu_axes(cfg)
    return a


def _capacity(tokens_per_group: int, m: MoEConfig) -> int:
    cap = int(m.capacity_factor * tokens_per_group * m.top_k / m.num_experts)
    return max(cap, m.top_k)


def route(router_w, x, m: MoEConfig, out_dtype=jnp.float32):
    """x (B,S,D) -> top-k routing.

    Returns (dispatch (B,S,E,C) bool-ish, combine (B,S,E,C) float,
    aux_loss scalar, router_z scalar).
    """
    B, S, _ = x.shape
    C = _capacity(S, m)
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), router_w)
    probs = jax.nn.softmax(logits, axis=-1)

    gate_vals, gate_idx = jax.lax.top_k(probs, m.top_k)  # (B,S,k)
    # renormalize the selected gates (dbrx/mixtral convention)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # position of each (token, choice) in its expert's buffer
    onehot = jax.nn.one_hot(gate_idx, m.num_experts, dtype=jnp.float32)  # (B,S,k,E)
    # rank tokens per expert by arrival order (token-major, choice-minor)
    flat = onehot.reshape(B, S * m.top_k, m.num_experts)
    pos_in_expert = jnp.cumsum(flat, axis=1) - flat  # (B, S*k, E)
    pos_in_expert = pos_in_expert.reshape(B, S, m.top_k, m.num_experts)
    within_cap = pos_in_expert < C
    keep = onehot * within_cap  # (B,S,k,E)

    pos_clipped = jnp.minimum(pos_in_expert, C - 1).astype(jnp.int32)
    pos_onehot = jax.nn.one_hot(pos_clipped, C, dtype=jnp.float32)  # (B,S,k,E,C)
    dispatch = jnp.einsum("bske,bskec->bsec", keep, pos_onehot).astype(out_dtype)
    combine = jnp.einsum("bsk,bske,bskec->bsec", gate_vals, keep,
                         pos_onehot).astype(out_dtype)

    # Switch aux loss: E * sum_e f_e * p_e
    density = jnp.mean(onehot.sum(axis=2), axis=(0, 1))        # fraction routed per expert
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = m.num_experts * jnp.sum(density * mean_prob) * m.aux_loss_coef
    router_z = jnp.mean(
        jnp.square(jax.scipy.special.logsumexp(logits, axis=-1))) * m.router_z_coef
    return dispatch, combine, aux, router_z


def moe_apply(params, x, cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (output (B,S,D), aux_losses scalar)."""
    if cfg.moe.moe_impl == "gather":
        return moe_apply_gather(params, x, cfg)
    return moe_apply_gshard(params, x, cfg)


def moe_apply_gshard(params, x, cfg: ModelConfig
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    m = cfg.moe
    dt = cfg.act_dtype
    disp_dt = jnp.float32 if m.dispatch_fp32 else dt
    dispatch, combine, aux, router_z = route(params["router"], x, m, disp_dt)
    # dispatch tokens into per-expert buffers: (B, E, C, D)
    xe = jnp.einsum("bsec,bsd->becd", dispatch.astype(dt), x)
    h = jnp.einsum("becd,edf->becf", xe, params["wi"].astype(dt))
    g = jnp.einsum("becd,edf->becf", xe, params["wg"].astype(dt))
    h = jax.nn.silu(g) * h
    ye = jnp.einsum("becf,efd->becd", h, params["wo"].astype(dt))
    y = jnp.einsum("bsec,becd->bsd", combine.astype(dt), ye)
    if m.num_shared:
        from repro.models.mlp import swiglu
        y = y + swiglu(params["shared"], x, cfg)
    return y, aux + router_z


def moe_apply_gather(params, x, cfg: ModelConfig
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Scatter/gather dispatch: numerically identical routing to the
    GShard path (same arrival-order capacity drops) but the (B,S,E,C)
    dispatch/combine one-hots never materialize — tokens are scatter-added
    into (B, E*C, D) buffers and gathered back by slot index.

    Memory per layer drops from O(B S E C) to O(B S k) index tensors,
    which is the dominant §Perf memory-bytes win for 128-expert configs.
    """
    m = cfg.moe
    dt = cfg.act_dtype
    B, S, D = x.shape
    E, k = m.num_experts, m.top_k
    C = _capacity(S, m)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)           # (B,S,k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)  # (B,S,k,E)
    flat = onehot.reshape(B, S * k, E)
    pos_in_expert = jnp.cumsum(flat, axis=1) - flat          # (B,S*k,E)
    pos_k = jnp.sum(pos_in_expert * flat, axis=-1)           # (B,S*k)
    pos_k = pos_k.reshape(B, S, k).astype(jnp.int32)
    keep = (pos_k < C)                                       # (B,S,k)
    slot = gate_idx * C + jnp.minimum(pos_k, C - 1)          # (B,S,k)

    # scatter tokens into per-expert buffers (dropped tokens add zeros)
    xk = (x[:, :, None, :] * keep[..., None].astype(dt)).reshape(B, S * k, D)
    slot_flat = slot.reshape(B, S * k)
    xe = jnp.zeros((B, E * C, D), dt).at[
        jnp.arange(B)[:, None], slot_flat].add(xk)
    xe = xe.reshape(B, E, C, D)

    h = jnp.einsum("becd,edf->becf", xe, params["wi"].astype(dt))
    g = jnp.einsum("becd,edf->becf", xe, params["wg"].astype(dt))
    h = jax.nn.silu(g) * h
    ye = jnp.einsum("becf,efd->becd", h, params["wo"].astype(dt))

    # gather back + weighted combine over the k choices
    ye_flat = ye.reshape(B, E * C, D)
    out_k = jnp.take_along_axis(
        ye_flat, slot_flat[..., None], axis=1).reshape(B, S, k, D)
    w = (gate_vals * keep).astype(dt)
    y = jnp.einsum("bsk,bskd->bsd", w, out_k)

    density = jnp.mean(onehot.sum(axis=2), axis=(0, 1))
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(density * mean_prob) * m.aux_loss_coef
    router_z = jnp.mean(jnp.square(
        jax.scipy.special.logsumexp(logits, axis=-1))) * m.router_z_coef
    if m.num_shared:
        from repro.models.mlp import swiglu
        y = y + swiglu(params["shared"], x, cfg)
    return y, aux + router_z

"""Rotary position embeddings: standard RoPE and qwen2-vl-style M-RoPE.

M-RoPE (multimodal rotary, arXiv:2409.12191) splits the rotary frequency
bands into (temporal, height, width) sections; text tokens carry identical
(t, h, w) positions so M-RoPE degenerates to 1-D RoPE on text.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp


def rope_angles(positions, head_dim: int, theta: float):
    """positions: (..., S) int -> cos/sin of shape (..., S, head_dim//2)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, half)
    return jnp.cos(ang), jnp.sin(ang)


def mrope_angles(positions, head_dim: int, theta: float,
                 sections: Tuple[int, int, int]):
    """positions: (3, B, S) int (t, h, w) -> cos/sin (B, S, head_dim//2).

    Section s of the frequency bands takes its rotation angle from
    positions[s]; sections sum to head_dim//2.
    """
    half = head_dim // 2
    assert sum(sections) == half
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    # component index for every frequency band
    comp = jnp.repeat(
        jnp.arange(3), jnp.asarray(sections), total_repeat_length=half)
    pos = positions.astype(jnp.float32)  # (3, B, S)
    # select the right positional component per band: (B, S, half)
    pos_bs3 = jnp.moveaxis(pos, 0, -1)  # (B, S, 3)
    idx = jnp.broadcast_to(comp[None, None, :], pos.shape[1:] + (half,))
    pos_sel = jnp.take_along_axis(pos_bs3, idx, axis=-1)
    ang = pos_sel * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, S, H, D); cos/sin broadcastable to (B, S, 1, D//2).

    Uses the "rotate-half" convention (llama / qwen): the head dim is split
    into two halves forming the (real, imag) parts.
    """
    dtype = x.dtype
    half = x.shape[-1] // 2
    # cos/sin arrive as (B, S, half) -> add head axis
    c = cos[..., None, :].astype(jnp.float32)
    s = sin[..., None, :].astype(jnp.float32)
    x = x.astype(jnp.float32)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return out.astype(dtype)


def default_positions(batch: int, seq: int, offset=0):
    pos = jnp.arange(seq, dtype=jnp.int32)[None, :] + offset
    return jnp.broadcast_to(pos, (batch, seq))


def make_rope(cfg, positions, mrope_positions: Optional[jnp.ndarray] = None):
    """Returns (cos, sin) of shape (B, S, head_dim//2) for this config."""
    if cfg.mrope_sections is not None:
        if mrope_positions is None:
            # text-only fallback: all three components equal
            mrope_positions = jnp.broadcast_to(
                positions[None], (3,) + positions.shape)
        return mrope_angles(mrope_positions, cfg.head_dim_, cfg.rope_theta,
                            cfg.mrope_sections)
    return rope_angles(positions, cfg.head_dim_, cfg.rope_theta)

"""Decoder-only LM covering all four assigned families.

Families:
  dense   — [norm → GQA attn → +res] [norm → SwiGLU → +res]       (llama etc.)
  moe     — [norm → GQA attn → +res] [norm → MoE → +res]           (dbrx etc.)
  ssm     — [norm → Mamba-2 mixer → +res]                          (mamba2)
  hybrid  — Griffin groups (rec, rec, local-attn), MLP every layer (recurrentgemma)

The layer stack is `lax.scan`ned over stacked params (one compiled layer
body regardless of depth) with a configurable remat policy.  Three entry
points are exposed per model: ``forward`` (training, full causal),
``prefill`` (returns logits + decode cache) and ``decode_step``.

Caches are pytrees with a leading `layers` axis so decode also scans.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import common, layers, mlp, moe, rglru, rope, ssd
from repro.models.config import ModelConfig
from repro.distributed.sharding import constrain


# ==========================================================================
# Per-family layer blocks
# ==========================================================================
def dense_block_init(key, cfg: ModelConfig):
    ks = common.split_like(key, ["ln1", "attn", "ln2", "mlp"])
    return {
        "ln1": layers.rmsnorm_init(ks["ln1"], cfg.d_model, cfg),
        "attn": attn.attention_init(ks["attn"], cfg),
        "ln2": layers.rmsnorm_init(ks["ln2"], cfg.d_model, cfg),
        "mlp": mlp.swiglu_init(ks["mlp"], cfg),
    }


def dense_block_axes(cfg: ModelConfig):
    return {
        "ln1": layers.rmsnorm_axes(cfg),
        "attn": attn.attention_axes(cfg),
        "ln2": layers.rmsnorm_axes(cfg),
        "mlp": mlp.swiglu_axes(cfg),
    }


def moe_block_init(key, cfg: ModelConfig):
    ks = common.split_like(key, ["ln1", "attn", "ln2", "moe"])
    return {
        "ln1": layers.rmsnorm_init(ks["ln1"], cfg.d_model, cfg),
        "attn": attn.attention_init(ks["attn"], cfg),
        "ln2": layers.rmsnorm_init(ks["ln2"], cfg.d_model, cfg),
        "moe": moe.moe_init(ks["moe"], cfg),
    }


def moe_block_axes(cfg: ModelConfig):
    return {
        "ln1": layers.rmsnorm_axes(cfg),
        "attn": attn.attention_axes(cfg),
        "ln2": layers.rmsnorm_axes(cfg),
        "moe": moe.moe_axes(cfg),
    }


def ssm_block_init(key, cfg: ModelConfig):
    ks = common.split_like(key, ["ln", "mixer"])
    return {
        "ln": layers.rmsnorm_init(ks["ln"], cfg.d_model, cfg),
        "mixer": ssd.ssd_init(ks["mixer"], cfg),
    }


def ssm_block_axes(cfg: ModelConfig):
    return {"ln": layers.rmsnorm_axes(cfg), "mixer": ssd.ssd_axes(cfg)}


def griffin_layer_init(key, cfg: ModelConfig, kind: str):
    ks = common.split_like(key, ["ln1", "mix", "ln2", "mlp"])
    mix = (rglru.rglru_init(ks["mix"], cfg) if kind == "rec"
           else attn.attention_init(ks["mix"], cfg))
    return {
        "ln1": layers.rmsnorm_init(ks["ln1"], cfg.d_model, cfg),
        "mix": mix,
        "ln2": layers.rmsnorm_init(ks["ln2"], cfg.d_model, cfg),
        "mlp": mlp.swiglu_init(ks["mlp"], cfg),
    }


def griffin_layer_axes(cfg: ModelConfig, kind: str):
    return {
        "ln1": layers.rmsnorm_axes(cfg),
        "mix": rglru.rglru_axes(cfg) if kind == "rec" else attn.attention_axes(cfg),
        "ln2": layers.rmsnorm_axes(cfg),
        "mlp": mlp.swiglu_axes(cfg),
    }


def griffin_group_init(key, cfg: ModelConfig):
    """One repeating Griffin group following cfg.rglru.pattern."""
    pat = cfg.rglru.pattern
    ks = jax.random.split(key, len(pat))
    return {f"l{i}_{kind}": griffin_layer_init(ks[i], cfg, kind)
            for i, kind in enumerate(pat)}


def griffin_group_axes(cfg: ModelConfig):
    pat = cfg.rglru.pattern
    return {f"l{i}_{kind}": griffin_layer_axes(cfg, kind)
            for i, kind in enumerate(pat)}


# ==========================================================================
# Remat
# ==========================================================================
def _unroll(cfg: ModelConfig):
    """Unroll factor for layer scans (True = fully unrolled probes)."""
    return True if cfg.scan_unroll else 1


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots, prevent_cse=False)
    return jax.checkpoint(fn, prevent_cse=False)  # "full": save nothing


# ==========================================================================
# Model: init
# ==========================================================================
def init(key, cfg: ModelConfig):
    ks = common.split_like(key, ["embed", "layers", "final", "head"])
    p: Dict[str, Any] = {
        "embed": layers.embedding_init(ks["embed"], cfg),
        "final_norm": layers.rmsnorm_init(ks["final"], cfg.d_model, cfg),
        "lm_head": layers.lm_head_init(ks["head"], cfg),
    }
    if cfg.family == "dense":
        p["layers"] = common.stack_init(dense_block_init, cfg.n_layers)(ks["layers"], cfg)
    elif cfg.family == "moe":
        p["layers"] = common.stack_init(moe_block_init, cfg.n_layers)(ks["layers"], cfg)
    elif cfg.family == "ssm":
        p["layers"] = common.stack_init(ssm_block_init, cfg.n_layers)(ks["layers"], cfg)
    elif cfg.family == "hybrid":
        pat = cfg.rglru.pattern
        n_groups, n_tail = divmod(cfg.n_layers, len(pat))
        kg, kt = jax.random.split(ks["layers"])
        p["groups"] = common.stack_init(griffin_group_init, n_groups)(kg, cfg)
        if n_tail:
            p["tail"] = common.stack_init(
                lambda k, c: griffin_layer_init(k, c, "rec"), n_tail)(kt, cfg)
    else:
        raise ValueError(cfg.family)
    return p


def axes(cfg: ModelConfig):
    a: Dict[str, Any] = {
        "embed": layers.embedding_axes(cfg),
        "final_norm": layers.rmsnorm_axes(cfg),
        "lm_head": layers.lm_head_axes(cfg),
    }
    if cfg.family == "dense":
        a["layers"] = common.stacked_axes(dense_block_axes(cfg))
    elif cfg.family == "moe":
        a["layers"] = common.stacked_axes(moe_block_axes(cfg))
    elif cfg.family == "ssm":
        a["layers"] = common.stacked_axes(ssm_block_axes(cfg))
    elif cfg.family == "hybrid":
        pat = cfg.rglru.pattern
        n_groups, n_tail = divmod(cfg.n_layers, len(pat))
        a["groups"] = common.stacked_axes(griffin_group_axes(cfg))
        if n_tail:
            a["tail"] = common.stacked_axes(griffin_layer_axes(cfg, "rec"))
    return a


# ==========================================================================
# Forward (training / full causal)
# ==========================================================================
def _embed_inputs(params, batch, cfg: ModelConfig):
    """batch: {"tokens": (B,S) | (B,K,S)} or {"embeds": (B,S,D)} (+positions)."""
    if "embeds" in batch:
        x = batch["embeds"].astype(cfg.act_dtype)
        B, S = x.shape[0], x.shape[1]
    else:
        tokens = batch["tokens"]
        B = tokens.shape[0]
        S = tokens.shape[-1]
        x = layers.embed(params["embed"], tokens, cfg)
    positions = batch.get("positions")
    if positions is None:
        positions = rope.default_positions(B, S)
    return x, positions, batch.get("mrope_positions")


def forward(params, batch, cfg: ModelConfig):
    """Full causal forward -> (logits, aux_loss)."""
    x, positions, mpos = _embed_inputs(params, batch, cfg)
    x = constrain(x, ("batch", None, None))
    rope_cs = (rope.make_rope(cfg, positions, mpos)
               if cfg.family != "ssm" else None)

    if cfg.family in ("dense", "moe"):
        is_moe = cfg.family == "moe"

        def body(carry, layer_p):
            h, aux = carry
            y = layers.rmsnorm(layer_p["ln1"], h, cfg.norm_eps)
            q, k, v = attn.qkv_project(layer_p["attn"], y, cfg, rope_cs)
            o = attn.attend(q, k, v, cfg, window=cfg.local_window)
            h = h + attn.out_project(layer_p["attn"], o, cfg)
            y = layers.rmsnorm(layer_p["ln2"], h, cfg.norm_eps)
            if is_moe:
                f, aux_d = moe.moe_apply(layer_p["moe"], y, cfg)
                aux = aux + aux_d
            else:
                f = mlp.swiglu(layer_p["mlp"], y, cfg)
            h = constrain(h + f, ("batch", "act_seq", None))
            return (h, aux), None

        (x, aux), _ = jax.lax.scan(
            _remat(body, cfg.remat_policy), (x, jnp.float32(0.0)),
            params["layers"], unroll=_unroll(cfg))

    elif cfg.family == "ssm":

        def body(carry, layer_p):
            h, aux = carry
            y = layers.rmsnorm(layer_p["ln"], h, cfg.norm_eps)
            h = constrain(h + ssd.ssd_apply(layer_p["mixer"], y, cfg),
                          ("batch", "act_seq", None))
            return (h, aux), None

        (x, aux), _ = jax.lax.scan(
            _remat(body, cfg.remat_policy), (x, jnp.float32(0.0)),
            params["layers"], unroll=_unroll(cfg))

    elif cfg.family == "hybrid":
        pat = cfg.rglru.pattern

        def layer_apply(layer_p, h, kind):
            y = layers.rmsnorm(layer_p["ln1"], h, cfg.norm_eps)
            if kind == "rec":
                h = h + rglru.rglru_apply(layer_p["mix"], y, cfg)
            else:
                q, k, v = attn.qkv_project(layer_p["mix"], y, cfg, rope_cs)
                o = attn.attend(q, k, v, cfg, window=cfg.local_window)
                h = h + attn.out_project(layer_p["mix"], o, cfg)
            y = layers.rmsnorm(layer_p["ln2"], h, cfg.norm_eps)
            return constrain(h + mlp.swiglu(layer_p["mlp"], y, cfg),
                             ("batch", "act_seq", None))

        def group_body(carry, group_p):
            h, aux = carry
            for i, kind in enumerate(pat):
                h = layer_apply(group_p[f"l{i}_{kind}"], h, kind)
            return (h, aux), None

        (x, aux), _ = jax.lax.scan(
            _remat(group_body, cfg.remat_policy), (x, jnp.float32(0.0)),
            params["groups"], unroll=_unroll(cfg))
        if "tail" in params:
            def tail_body(carry, layer_p):
                h, aux = carry
                return (layer_apply(layer_p, h, "rec"), aux), None

            (x, aux), _ = jax.lax.scan(
                _remat(tail_body, cfg.remat_policy), (x, aux), params["tail"],
                unroll=_unroll(cfg))
    else:
        raise ValueError(cfg.family)

    x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = layers.lm_head(params["lm_head"], params["embed"], x, cfg)
    return logits, aux


def loss_fn(params, batch, cfg: ModelConfig):
    """batch needs "labels" (B,S) or (B,K,S); optional "loss_mask"."""
    logits, aux = forward(params, batch, cfg)
    ce = layers.lm_loss(logits, batch["labels"], batch.get("loss_mask"),
                        cfg.z_loss_coef)
    loss = ce + aux
    return loss, {"loss": loss, "ce": ce, "aux": aux}


# ==========================================================================
# Caches
# ==========================================================================
def _kv_quant(k):
    """k (..., hd) -> (int8, scale (...,)) per-token-per-head (KIVI-style)."""
    scale = jnp.max(jnp.abs(k.astype(jnp.float32)), axis=-1) / 127.0 + 1e-8
    q = jnp.clip(jnp.round(k.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _kv_dequant(q, scale, dtype):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)



def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Decode cache pytree; leading `layers`/`groups` axis scans with params."""
    hd, Hk = cfg.head_dim_, cfg.n_kv_heads
    dt = cfg.act_dtype

    def kv(n, length):
        if cfg.kv_cache_dtype == "int8":
            return {
                "k": jnp.zeros((n, batch, length, Hk, hd), jnp.int8),
                "v": jnp.zeros((n, batch, length, Hk, hd), jnp.int8),
                "k_scale": jnp.zeros((n, batch, length, Hk), jnp.float32),
                "v_scale": jnp.zeros((n, batch, length, Hk), jnp.float32),
            }
        return {
            "k": jnp.zeros((n, batch, length, Hk, hd), dt),
            "v": jnp.zeros((n, batch, length, Hk, hd), dt),
        }

    if cfg.family in ("dense", "moe"):
        c = kv(cfg.n_layers, max_len)
    elif cfg.family == "ssm":
        st = ssd.ssd_init_state(cfg, batch)
        c = {"conv": jnp.broadcast_to(st.conv, (cfg.n_layers,) + st.conv.shape),
             "ssm": jnp.broadcast_to(st.ssm, (cfg.n_layers,) + st.ssm.shape)}
    elif cfg.family == "hybrid":
        pat = cfg.rglru.pattern
        n_groups, n_tail = divmod(cfg.n_layers, len(pat))
        W = cfg.local_window
        group: Dict[str, Any] = {}
        for i, kind in enumerate(pat):
            if kind == "rec":
                st = rglru.rglru_init_state(cfg, batch)
                group[f"l{i}_conv"] = jnp.broadcast_to(
                    st.conv, (n_groups,) + st.conv.shape)
                group[f"l{i}_h"] = jnp.broadcast_to(
                    st.h, (n_groups,) + st.h.shape)
            else:
                group[f"l{i}_k"] = jnp.zeros((n_groups, batch, W, Hk, hd), dt)
                group[f"l{i}_v"] = jnp.zeros((n_groups, batch, W, Hk, hd), dt)
        c = {"groups": group}
        if n_tail:
            st = rglru.rglru_init_state(cfg, batch)
            c["tail"] = {
                "conv": jnp.broadcast_to(st.conv, (n_tail,) + st.conv.shape),
                "h": jnp.broadcast_to(st.h, (n_tail,) + st.h.shape),
            }
    else:
        raise ValueError(cfg.family)
    c["length"] = jnp.zeros((), jnp.int32)
    return c


def cache_axes(cfg: ModelConfig):
    """Logical axes for the cache pytree (for explicit dry-run shardings)."""
    kv_ax = ("layers", "batch", None, "kv_heads", "head_dim")
    if cfg.family in ("dense", "moe"):
        out = {"k": kv_ax, "v": kv_ax, "length": ()}
        if cfg.kv_cache_dtype == "int8":
            out["k_scale"] = ("layers", "batch", None, "kv_heads")
            out["v_scale"] = ("layers", "batch", None, "kv_heads")
        return out
    if cfg.family == "ssm":
        return {"conv": ("layers", "batch", None, "mlp"),
                "ssm": ("layers", "batch", "heads", None, None),
                "length": ()}
    pat = cfg.rglru.pattern
    n_groups, n_tail = divmod(cfg.n_layers, len(pat))
    group = {}
    for i, kind in enumerate(pat):
        if kind == "rec":
            group[f"l{i}_conv"] = ("layers", "batch", None, "mlp")
            group[f"l{i}_h"] = ("layers", "batch", "mlp")
        else:
            group[f"l{i}_k"] = kv_ax
            group[f"l{i}_v"] = kv_ax
    out = {"groups": group, "length": ()}
    if n_tail:
        out["tail"] = {"conv": ("layers", "batch", None, "mlp"),
                       "h": ("layers", "batch", "mlp")}
    return out


# ==========================================================================
# Prefill
# ==========================================================================
def prefill(params, batch, cfg: ModelConfig, max_len: Optional[int] = None):
    """Process a full prompt; returns (last-position logits, cache).

    max_len: cache capacity (>= prompt length); defaults to prompt length.
    """
    x, positions, mpos = _embed_inputs(params, batch, cfg)
    x = constrain(x, ("batch", None, None))
    B, S = x.shape[0], x.shape[1]
    max_len = max_len or S
    rope_cs = (rope.make_rope(cfg, positions, mpos)
               if cfg.family != "ssm" else None)

    def pad_kv(k):  # (B,S,Hk,hd) -> (B,max_len,Hk,hd)
        if max_len == S:
            return k
        return jnp.pad(k, ((0, 0), (0, max_len - S), (0, 0), (0, 0)))

    def pad_kv_scale(sc):  # (B,S,Hk) -> (B,max_len,Hk)
        if max_len == S:
            return sc
        return jnp.pad(sc, ((0, 0), (0, max_len - S), (0, 0)))

    if cfg.family in ("dense", "moe"):
        is_moe = cfg.family == "moe"

        def body(h, layer_p):
            y = layers.rmsnorm(layer_p["ln1"], h, cfg.norm_eps)
            q, k, v = attn.qkv_project(layer_p["attn"], y, cfg, rope_cs)
            o = attn.attend(q, k, v, cfg, window=cfg.local_window)
            h = h + attn.out_project(layer_p["attn"], o, cfg)
            y = layers.rmsnorm(layer_p["ln2"], h, cfg.norm_eps)
            f = (moe.moe_apply(layer_p["moe"], y, cfg)[0] if is_moe
                 else mlp.swiglu(layer_p["mlp"], y, cfg))
            h = constrain(h + f, ("batch", "act_seq", None))
            if cfg.kv_cache_dtype == "int8":
                k8, ks_ = _kv_quant(k)
                v8, vs_ = _kv_quant(v)
                return h, {"k": pad_kv(k8), "v": pad_kv(v8),
                           "k_scale": pad_kv_scale(ks_),
                           "v_scale": pad_kv_scale(vs_)}
            return h, {"k": pad_kv(k), "v": pad_kv(v)}

        x, cache = jax.lax.scan(body, x, params["layers"],
                                unroll=_unroll(cfg))

    elif cfg.family == "ssm":

        def body(h, layer_p):
            y = layers.rmsnorm(layer_p["ln"], h, cfg.norm_eps)
            out, st = ssd.ssd_apply(layer_p["mixer"], y, cfg, return_state=True)
            h = constrain(h + out, ("batch", "act_seq", None))
            return h, {"conv": st.conv, "ssm": st.ssm}

        x, cache = jax.lax.scan(body, x, params["layers"],
                                unroll=_unroll(cfg))

    elif cfg.family == "hybrid":
        pat = cfg.rglru.pattern
        W = cfg.local_window

        def ring_from_prefill(k):  # (B,S,Hk,hd) -> ring (B,W,Hk,hd)
            if S < W:
                pad = jnp.zeros((B, W - S, Hk_, hd_), k.dtype)
                return jnp.concatenate([k, pad], axis=1)
            return jnp.roll(k[:, -W:], shift=S % W, axis=1)

        Hk_, hd_ = cfg.n_kv_heads, cfg.head_dim_

        def layer_apply(layer_p, h, kind):
            y = layers.rmsnorm(layer_p["ln1"], h, cfg.norm_eps)
            if kind == "rec":
                out, st = rglru.rglru_apply(layer_p["mix"], y, cfg,
                                            return_state=True)
                h = h + out
                entry = {"conv": st.conv, "h": st.h}
            else:
                q, k, v = attn.qkv_project(layer_p["mix"], y, cfg, rope_cs)
                o = attn.attend(q, k, v, cfg, window=W)
                h = h + attn.out_project(layer_p["mix"], o, cfg)
                entry = {"k": ring_from_prefill(k), "v": ring_from_prefill(v)}
            y = layers.rmsnorm(layer_p["ln2"], h, cfg.norm_eps)
            h = constrain(h + mlp.swiglu(layer_p["mlp"], y, cfg),
                          ("batch", "act_seq", None))
            return h, entry

        def group_body(h, group_p):
            entries = {}
            for i, kind in enumerate(pat):
                h, e = layer_apply(group_p[f"l{i}_{kind}"], h, kind)
                for kk, vv in e.items():
                    entries[f"l{i}_{kk}"] = vv
            return h, entries

        x, groups_cache = jax.lax.scan(group_body, x, params["groups"],
                                       unroll=_unroll(cfg))
        cache = {"groups": groups_cache}
        if "tail" in params:
            def tail_body(h, layer_p):
                h, e = layer_apply(layer_p, h, "rec")
                return h, e

            x, tail_cache = jax.lax.scan(tail_body, x, params["tail"],
                                         unroll=_unroll(cfg))
            cache["tail"] = tail_cache
    else:
        raise ValueError(cfg.family)

    x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    last = x[:, -1:, :]
    logits = layers.lm_head(params["lm_head"], params["embed"], last, cfg)
    cache["length"] = jnp.asarray(S, jnp.int32)
    return logits, cache


# ==========================================================================
# Prefill extension (chunked prefill / streaming context growth)
# ==========================================================================
def prefill_extend(params, cache, batch, cfg: ModelConfig):
    """Append a chunk of S new positions to an existing cache.

    This is Sarathi-style chunked prefill and also how Artic video sessions
    grow: each encoded frame's patch embeddings extend the MLLM context.
    Requires a scalar cache["length"] (lock-step session batch).
    Returns (logits for the chunk (B,S,V), new cache).
    """
    x, _, mpos = _embed_inputs(params, batch, cfg)
    x = constrain(x, ("batch", None, None))
    B, S = x.shape[0], x.shape[1]
    start = cache["length"]
    positions = (jnp.arange(S, dtype=jnp.int32)[None, :] + start)
    positions = jnp.broadcast_to(positions, (B, S))
    rope_cs = (rope.make_rope(cfg, positions, mpos)
               if cfg.family != "ssm" else None)

    if cfg.family in ("dense", "moe"):
        is_moe = cfg.family == "moe"

        def body(h, inp):
            layer_p, kc, vc = inp
            y = layers.rmsnorm(layer_p["ln1"], h, cfg.norm_eps)
            q, k, v = attn.qkv_project(layer_p["attn"], y, cfg, rope_cs)
            kc = jax.lax.dynamic_update_slice_in_dim(kc, k, start, axis=1)
            vc = jax.lax.dynamic_update_slice_in_dim(vc, v, start, axis=1)
            # mask kj <= qi (absolute) covers both history and the chunk
            o = attn.full_attention(q, kc, vc, cfg, q_offset=start,
                                    window=cfg.local_window)
            h = h + attn.out_project(layer_p["attn"], o, cfg)
            y = layers.rmsnorm(layer_p["ln2"], h, cfg.norm_eps)
            f = (moe.moe_apply(layer_p["moe"], y, cfg)[0] if is_moe
                 else mlp.swiglu(layer_p["mlp"], y, cfg))
            return h + f, {"k": kc, "v": vc}

        x, new_kv = jax.lax.scan(body, x,
                                 (params["layers"], cache["k"], cache["v"]),
                                 unroll=_unroll(cfg))
        new_cache = {"k": new_kv["k"], "v": new_kv["v"]}

    elif cfg.family == "ssm":

        def body(h, inp):
            layer_p, conv, ssm_st = inp
            y = layers.rmsnorm(layer_p["ln"], h, cfg.norm_eps)
            out, st = ssd.ssd_apply(layer_p["mixer"], y, cfg,
                                    state=ssd.SSMState(conv=conv, ssm=ssm_st),
                                    return_state=True)
            return h + out, {"conv": st.conv, "ssm": st.ssm}

        x, new_c = jax.lax.scan(
            body, x, (params["layers"], cache["conv"], cache["ssm"]),
            unroll=_unroll(cfg))
        new_cache = {"conv": new_c["conv"], "ssm": new_c["ssm"]}

    else:
        raise NotImplementedError(
            f"prefill_extend for family {cfg.family!r}: hybrid sessions "
            "extend via repeated decode_step")

    x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = layers.lm_head(params["lm_head"], params["embed"], x, cfg)
    new_cache["length"] = start + S
    return logits, new_cache


# ==========================================================================
# Decode step
# ==========================================================================
def decode_step(params, cache, batch, cfg: ModelConfig):
    """One decode step. batch: {"tokens": (B,1) or (B,K,1), ...}.

    cache["length"] may be a scalar (lock-step batch: the dry-run shapes)
    or an (B,) vector (continuous batching: per-slot sequence lengths).
    Returns (logits (B,1,V) | (B,K,1,V), new cache).
    """
    x, _, mpos = _embed_inputs(params, batch, cfg)
    x = constrain(x, ("batch", None, None))
    B = x.shape[0]
    pos = cache["length"]
    vec = pos.ndim == 1  # per-slot lengths
    positions = (pos[:, None] if vec
                 else jnp.broadcast_to(pos[None, None], (B, 1))).astype(jnp.int32)

    def kv_update(kc, k, idx):
        """Insert k (B,1,Hk,hd) at per-batch or scalar position `idx`."""
        if vec:
            return kc.at[jnp.arange(B), idx].set(k[:, 0])
        return jax.lax.dynamic_update_slice_in_dim(kc, k, idx, axis=1)
    rope_cs = (rope.make_rope(cfg, positions, mpos)
               if cfg.family != "ssm" else None)

    if cfg.family in ("dense", "moe"):
        is_moe = cfg.family == "moe"
        max_len = cache["k"].shape[2]
        int8_kv = cfg.kv_cache_dtype == "int8"

        def scale_update(sc, s_new, idx):
            # s_new (B,1,Hk) into sc (B,Smax,Hk)
            if vec:
                return sc.at[jnp.arange(B), idx].set(s_new[:, 0])
            return jax.lax.dynamic_update_slice_in_dim(sc, s_new, idx, axis=1)

        def body(h, inp):
            if int8_kv:
                layer_p, kc, vc, ksc, vsc = inp
            else:
                layer_p, kc, vc = inp
            y = layers.rmsnorm(layer_p["ln1"], h, cfg.norm_eps)
            q, k, v = attn.qkv_project(layer_p["attn"], y, cfg, rope_cs)
            if int8_kv:
                k8, ks_ = _kv_quant(k)
                v8, vs_ = _kv_quant(v)
                kc = kv_update(kc, k8, pos)
                vc = kv_update(vc, v8, pos)
                ksc = scale_update(ksc, ks_, pos)
                vsc = scale_update(vsc, vs_, pos)
                kf = _kv_dequant(kc, ksc, q.dtype)
                vf = _kv_dequant(vc, vsc, q.dtype)
            else:
                kc = kv_update(kc, k, pos)
                vc = kv_update(vc, v, pos)
                kf, vf = kc, vc
            o = attn.decode_attention(q, kf, vf, pos + 1, cfg)
            h = h + attn.out_project(layer_p["attn"], o, cfg)
            y = layers.rmsnorm(layer_p["ln2"], h, cfg.norm_eps)
            f = (moe.moe_apply(layer_p["moe"], y, cfg)[0] if is_moe
                 else mlp.swiglu(layer_p["mlp"], y, cfg))
            if int8_kv:
                return h + f, {"k": kc, "v": vc, "k_scale": ksc, "v_scale": vsc}
            return h + f, {"k": kc, "v": vc}

        if int8_kv:
            x, new_kv = jax.lax.scan(
                body, x, (params["layers"], cache["k"], cache["v"],
                          cache["k_scale"], cache["v_scale"]),
                unroll=_unroll(cfg))
            new_cache = {"k": new_kv["k"], "v": new_kv["v"],
                         "k_scale": new_kv["k_scale"],
                         "v_scale": new_kv["v_scale"]}
        else:
            x, new_kv = jax.lax.scan(
                body, x, (params["layers"], cache["k"], cache["v"]),
                unroll=_unroll(cfg))
            new_cache = {"k": new_kv["k"], "v": new_kv["v"]}

    elif cfg.family == "ssm":

        def body(h, inp):
            layer_p, conv, ssm_st = inp
            y = layers.rmsnorm(layer_p["ln"], h, cfg.norm_eps)
            out, st = ssd.ssd_decode_step(
                layer_p["mixer"], y, ssd.SSMState(conv=conv, ssm=ssm_st), cfg)
            return h + out, {"conv": st.conv, "ssm": st.ssm}

        x, new_c = jax.lax.scan(
            body, x, (params["layers"], cache["conv"], cache["ssm"]),
            unroll=_unroll(cfg))
        new_cache = {"conv": new_c["conv"], "ssm": new_c["ssm"]}

    elif cfg.family == "hybrid":
        pat = cfg.rglru.pattern
        W = cfg.local_window
        slot = jnp.mod(pos, W)

        def layer_apply(layer_p, h, kind, entry):
            y = layers.rmsnorm(layer_p["ln1"], h, cfg.norm_eps)
            if kind == "rec":
                out, st = rglru.rglru_decode_step(
                    layer_p["mix"], y,
                    rglru.RGLRUState(conv=entry["conv"], h=entry["h"]), cfg)
                h = h + out
                new_entry = {"conv": st.conv, "h": st.h}
            else:
                q, k, v = attn.qkv_project(layer_p["mix"], y, cfg, rope_cs)
                kc = kv_update(entry["k"], k, slot)
                vc = kv_update(entry["v"], v, slot)
                o = attn.decode_attention(q, kc, vc, jnp.minimum(pos + 1, W), cfg)
                h = h + attn.out_project(layer_p["mix"], o, cfg)
                new_entry = {"k": kc, "v": vc}
            y = layers.rmsnorm(layer_p["ln2"], h, cfg.norm_eps)
            return h + mlp.swiglu(layer_p["mlp"], y, cfg), new_entry

        def group_body(h, inp):
            group_p, group_c = inp
            new_entries = {}
            for i, kind in enumerate(pat):
                keys = (("conv", "h") if kind == "rec" else ("k", "v"))
                entry = {kk: group_c[f"l{i}_{kk}"] for kk in keys}
                h, ne = layer_apply(group_p[f"l{i}_{kind}"], h, kind, entry)
                for kk, vv in ne.items():
                    new_entries[f"l{i}_{kk}"] = vv
            return h, new_entries

        x, new_groups = jax.lax.scan(
            group_body, x, (params["groups"], cache["groups"]),
            unroll=_unroll(cfg))
        new_cache = {"groups": new_groups}
        if "tail" in params:
            def tail_body(h, inp):
                layer_p, conv, hh = inp
                h, ne = layer_apply(layer_p, h, "rec",
                                    {"conv": conv, "h": hh})
                return h, ne

            x, new_tail = jax.lax.scan(
                tail_body, x,
                (params["tail"], cache["tail"]["conv"], cache["tail"]["h"]),
                unroll=_unroll(cfg))
            new_cache["tail"] = new_tail
    else:
        raise ValueError(cfg.family)

    x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = layers.lm_head(params["lm_head"], params["embed"], x, cfg)
    new_cache["length"] = pos + 1
    return logits, new_cache

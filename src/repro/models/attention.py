"""GQA attention: full, blocked (flash-style scan), local-window and decode.

Three execution paths share one set of projection weights:

* ``full``     — einsum attention materializing (S, S) scores. Used for
                 short sequences (training at 4k).
* ``blocked``  — lax.scan over KV blocks with online softmax. HLO memory
                 stays O(block) instead of O(S^2); this is the pure-JAX
                 flash-attention used by the multi-pod dry-run (Pallas
                 cannot lower for the CPU host platform).
* ``pallas``   — repro.kernels.flash_attention on real TPUs.

Decode reads a contiguous KV cache; see repro/serving/kv_cache.py for the
paged variant.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.config import ModelConfig
from repro.models.layers import rmsnorm
from repro.models.rope import apply_rope

NEG_INF = -1e30


# --------------------------------------------------------------------------
# Params
# --------------------------------------------------------------------------
def attention_init(key, cfg: ModelConfig):
    D, Hq, Hk, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    ks = common.split_like(key, ["wq", "wk", "wv", "wo", "qn", "kn"])
    p = {
        "wq": common.dense_init(ks["wq"], (D, Hq, hd), cfg.p_dtype),
        "wk": common.dense_init(ks["wk"], (D, Hk, hd), cfg.p_dtype),
        "wv": common.dense_init(ks["wv"], (D, Hk, hd), cfg.p_dtype),
        "wo": common.dense_init(ks["wo"], (Hq, hd, D), cfg.p_dtype, in_axis=2),
    }
    if cfg.qk_norm:
        p["q_norm"] = {"scale": jnp.ones((hd,), cfg.p_dtype)}
        p["k_norm"] = {"scale": jnp.ones((hd,), cfg.p_dtype)}
    return p


def attention_axes(cfg: ModelConfig):
    a = {
        "wq": ("embed", "heads", "head_dim"),
        "wk": ("embed", "kv_heads", "head_dim"),
        "wv": ("embed", "kv_heads", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
    }
    if cfg.qk_norm:
        a["q_norm"] = {"scale": (None,)}
        a["k_norm"] = {"scale": (None,)}
    return a


def qkv_project(params, x, cfg: ModelConfig, rope: Tuple[jnp.ndarray, jnp.ndarray]):
    """x (B,S,D) -> q (B,S,Hq,hd), k/v (B,S,Hk,hd), rope applied."""
    dt = cfg.act_dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dt))
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(params["k_norm"], k, cfg.norm_eps)
    cos, sin = rope
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    return q, k, v


def out_project(params, o, cfg: ModelConfig):
    return jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(cfg.act_dtype))


# --------------------------------------------------------------------------
# Full (materialized) attention
# --------------------------------------------------------------------------
def _causal_mask(sq: int, sk: int, q_offset: int, window: Optional[int]):
    """Additive mask (sq, sk): causal, optionally banded to `window`."""
    qi = jnp.arange(sq)[:, None] + q_offset
    kj = jnp.arange(sk)[None, :]
    ok = kj <= qi
    if window is not None:
        ok &= kj > qi - window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def full_attention(q, k, v, cfg: ModelConfig, q_offset: int = 0,
                   window: Optional[int] = None):
    """q (B,Sq,Hq,d), k/v (B,Sk,Hk,d) -> (B,Sq,Hq,d)."""
    B, Sq, Hq, d = q.shape
    Sk, Hk = k.shape[1], k.shape[2]
    G = Hq // Hk
    qg = q.reshape(B, Sq, Hk, G, d)
    scale = d ** -0.5
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32) * scale
    scores = scores + _causal_mask(Sq, Sk, q_offset, window)[None, None, None]
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return o.reshape(B, Sq, Hq, d)


# --------------------------------------------------------------------------
# Blocked streaming attention (pure-JAX flash): scan over KV blocks
# --------------------------------------------------------------------------
def blocked_attention(q, k, v, cfg: ModelConfig, q_offset: int = 0,
                      window: Optional[int] = None):
    """Online-softmax attention; never materializes (Sq, Sk) at once.

    Scans KV blocks; each step computes scores for one (Sq, block_kv) tile.
    Numerically identical to full_attention (same fp32 softmax).
    """
    B, Sq, Hq, d = q.shape
    Sk, Hk = k.shape[1], k.shape[2]
    G = Hq // Hk
    bk = min(cfg.attn_block_kv, Sk)
    if Sk % bk:  # pad KV to a multiple of the block
        pad = bk - Sk % bk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nkb = k.shape[1] // bk
    kb = k.reshape(B, nkb, bk, Hk, d).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nkb, bk, Hk, d).transpose(1, 0, 2, 3, 4)

    qg = q.reshape(B, Sq, Hk, G, d)
    scale = d ** -0.5
    qi = jnp.arange(Sq)[:, None] + q_offset  # absolute query positions

    def step(carry, inp):
        m, l, acc = carry  # running max (B,Hk,G,Sq), denom, weighted sum
        kblk, vblk, kstart = inp
        kj = kstart + jnp.arange(bk)[None, :]
        ok = (kj <= qi) & (kj < Sk)
        if window is not None:
            ok &= kj > qi - window
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kblk).astype(jnp.float32) * scale
        s = jnp.where(ok[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p.astype(q.dtype), vblk).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hk, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hk, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, Hk, G, Sq, d), jnp.float32)
    starts = jnp.arange(nkb) * bk
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kb, vb, starts),
                                  unroll=True if cfg.scan_unroll else 1)
    o = acc / jnp.maximum(l, 1e-30)[..., None]
    return o.transpose(0, 3, 1, 2, 4).reshape(B, Sq, Hq, d).astype(q.dtype)


def attend(q, k, v, cfg: ModelConfig, q_offset: int = 0,
           window: Optional[int] = None):
    """Dispatch on sequence length / configured implementation."""
    Sk = k.shape[1]
    if cfg.attention_impl == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops
        if q.shape[1] > 1:
            return fa_ops.flash_attention(
                q, k, v, causal=True, q_offset=q_offset, window=window)
    if Sk > cfg.blocked_threshold:
        return blocked_attention(q, k, v, cfg, q_offset, window)
    return full_attention(q, k, v, cfg, q_offset, window)


# --------------------------------------------------------------------------
# Decode (single new token against a KV cache)
# --------------------------------------------------------------------------
def decode_attention(q, k_cache, v_cache, length, cfg: ModelConfig,
                     window: Optional[int] = None):
    """q (B,1,Hq,d); caches (B,Smax,Hk,d); length: scalar or (B,) valid len.

    Positions >= length are masked. For local attention the cache is a ring
    buffer of size `window` and every live slot is valid.
    """
    B, _, Hq, d = q.shape
    Smax, Hk = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hk
    qg = q.reshape(B, Hk, G, d)
    scale = d ** -0.5
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache).astype(jnp.float32) * scale
    kj = jnp.arange(Smax)[None, :]
    valid = kj < jnp.reshape(jnp.asarray(length), (-1, 1))
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache)
    return o.reshape(B, 1, Hq, d)

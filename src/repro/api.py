"""Public facade for driving the Artic simulator.

    from repro.api import ScenarioSpec, grid, run_scenarios

    result = run_scenarios(grid("fig13",
                                system=["webrtc", "artic"],
                                cc_kind=["gcc", "bbr"],
                                trace_seed=[0, 1]))
    print(result.aggregate(by=("cc_kind", "system")))

Workload specs are pure data (`ScenarioSpec`); `run_scenarios` compiles
them into cohorts of fleet-compatible sessions, runs each cohort as one
vectorized `Fleet`, and returns a `RunResult` (stacked metrics + tags,
JSON/CSV export).  `repro.core.fleet` stays available as the lower
layer; nothing here hand-assembles `FleetSession` lists.

`python -m repro.api` runs a tiny grid end to end and validates the
exported JSON against the RunResult schema — the CI smoke job.
"""
from __future__ import annotations

from repro.core.scenario import (DEVIBENCH_RESULT_SCHEMA,
                                 DEVIBENCH_SCALAR_METRICS, PRESETS,
                                 QA_POLICIES, RUN_RESULT_SCHEMA,
                                 SCALAR_METRICS, SERVING_METRICS, SYSTEMS,
                                 TRACE_FAMILIES, Cohort, DeViBenchCohort,
                                 DeViBenchRunResult, RunResult,
                                 ScenarioSpec, build_fleet, build_session,
                                 cohort_key, compile_cohorts,
                                 devibench_key, grid, preset,
                                 register_preset, run_devibench,
                                 run_scenarios, validate_devibench_json,
                                 validate_run_result_json)
from repro.core.session import (QASample, SessionConfig, SessionMetrics,
                                run_session)
from repro.launch.mesh import make_fleet_mesh, use_mesh
from repro.devibench.engine import (DEGRADATION_KINDS, DegradationSpec,
                                    GridResult, bitrate_ladder,
                                    default_degradations)
from repro.devibench.pipeline import fit_confidence_calibrator

__all__ = [
    "ScenarioSpec", "RunResult", "Cohort", "run_scenarios", "grid",
    "preset", "register_preset", "PRESETS", "SYSTEMS", "TRACE_FAMILIES",
    "QA_POLICIES", "SCALAR_METRICS", "SERVING_METRICS",
    "RUN_RESULT_SCHEMA",
    "build_session", "build_fleet", "cohort_key", "compile_cohorts",
    "validate_run_result_json",
    "DegradationSpec", "DEGRADATION_KINDS", "GridResult",
    "bitrate_ladder", "default_degradations", "run_devibench",
    "DeViBenchRunResult", "DeViBenchCohort", "devibench_key",
    "DEVIBENCH_RESULT_SCHEMA", "DEVIBENCH_SCALAR_METRICS",
    "validate_devibench_json", "fit_confidence_calibrator",
    "QASample", "SessionConfig", "SessionMetrics", "run_session",
    "make_fleet_mesh", "use_mesh",
]


def smoke(out_path: str = "/tmp/artic_scenario_smoke.json",
          sharded: bool = False) -> RunResult:
    """Tiny end-to-end grid: 2 system variants x 2 trace families, short
    duration, mixed frame sizes (so cohort partitioning is exercised),
    exported to JSON and schema-validated.  `sharded=True` runs every
    cohort over a `make_fleet_mesh()` of all visible devices (the
    multi-device CI job forces 8 virtual CPU devices via XLA_FLAGS)."""
    import json

    specs = grid(ScenarioSpec(duration=3.0, scene="retail", qa="periodic",
                              qa_kwargs=dict(start=1.0, period=1.0,
                                             count=2,
                                             answer_window=1.0)),
                 system=["webrtc", "artic"],
                 trace=["fluctuating", "mobility.driving"])
    # a thumbnail member lands in its own cohort within the same call
    specs.append(specs[0].with_(frame_h=64, frame_w=64, scene="lawn"))
    mesh = make_fleet_mesh() if sharded else None
    if sharded:
        print(f"[smoke] sharding cohorts over "
              f"{mesh.devices.size} device(s)")
    result = run_scenarios(specs, mesh=mesh)
    doc = result.to_json(out_path)
    validate_run_result_json(doc)
    with open(out_path) as f:
        validate_run_result_json(json.load(f))  # survives the round trip
    print(f"[smoke] {len(result)} scenarios in {len(result.cohorts)} "
          f"cohorts -> {out_path} (schema {RUN_RESULT_SCHEMA} OK)")
    for key, agg in result.aggregate(by=("system", "trace")).items():
        print(f"[smoke]   {key}: acc={agg['accuracy']:.2f} "
              f"lat={agg['avg_latency_ms']:.0f}ms")
    return result


def rollout_smoke(window: int = 3) -> None:
    """Whole-tick rollout smoke: a tiny fleet run twice — eager per-tick
    loop vs `Fleet.run(rollout=K)` compiled scan windows — must produce
    identical metrics.  Interpret-mode friendly (pure jnp + lax.scan, no
    Pallas), so the CI job runs it on the CPU backend directly."""
    base = ScenarioSpec(duration=2.0, frame_h=64, frame_w=64,
                        scene="retail", qa="periodic",
                        qa_kwargs=dict(start=0.5, period=0.6, count=2,
                                       answer_window=0.5))
    specs = grid(base, system=["webrtc", "artic"],
                 trace=["fluctuating", "elevator"])
    eager = build_fleet(specs, fused_plan=True).run()
    got = build_fleet(specs, fused_plan=True).run(rollout=window)
    for k, (a, b) in enumerate(zip(eager, got)):
        same = (a.latencies == b.latencies and a.rates == b.rates
                and a.confidences == b.confidences
                and a.accuracy == b.accuracy
                and a.avg_bitrate == b.avg_bitrate
                and a.bandwidth_used == b.bandwidth_used
                and a.dropped_frames == b.dropped_frames
                and a.zeco_engaged_frames == b.zeco_engaged_frames)
        if not same:
            raise AssertionError(
                f"rollout metrics diverge from eager for session {k}")
    print(f"[rollout-smoke] {len(specs)} sessions, rollout={window}: "
          "metrics identical to the eager tick loop")


def devibench_smoke(out_path: str = "/tmp/artic_devibench_smoke.json"
                    ) -> DeViBenchRunResult:
    """Tiny DeViBench grid end to end: one quick benchmark build, a
    degradation axis covering every kind, evaluated as one stacked grid
    through `run_scenarios(workload='devibench')`, exported to JSON and
    schema-validated, then consumed by the calibrator + ReCap-ABR fit
    (the benchmark -> saturation point -> ABR cap loop)."""
    import json

    base = preset("devibench")
    specs = [base.with_(degradation="bitrate",
                        degradation_kwargs=dict(kbps=k))
             for k in (200.0, 700.0, 1700.0, 4000.0)]
    specs += [base.with_(degradation="requant",
                         degradation_kwargs=dict(kbps=4000.0, loss=0.5)),
              base.with_(degradation="drop",
                         degradation_kwargs=dict(kbps=4000.0,
                                                 stall_frames=5)),
              base.with_(degradation="downscale",
                         degradation_kwargs=dict(kbps=4000.0, scale=2))]
    result = run_scenarios(specs, workload="devibench")
    doc = result.to_json(out_path)
    validate_devibench_json(doc)
    with open(out_path) as f:
        validate_devibench_json(json.load(f))  # survives the round trip
    print(f"[devibench-smoke] {len(result)} scenarios in "
          f"{len(result.cohorts)} cohort(s) -> {out_path} "
          f"(schema {DEVIBENCH_RESULT_SCHEMA} OK)")
    kbps, acc = result.saturation_curve()
    print(f"[devibench-smoke]   saturation curve: "
          + ", ".join(f"{int(k)}kbps={a:.2f}" for k, a in zip(kbps, acc)))
    cal = fit_confidence_calibrator(result)
    fit = result.fit_recap(calibrator=cal)
    print(f"[devibench-smoke]   fit: tau={fit['tau']:.2f} "
          f"gamma={fit['gamma']:.1f} knee={fit['knee_kbps']:.0f}kbps "
          f"cap={fit['cap_bps'] / 1e3:.0f}kbps")
    return result


def serving_smoke(out_path: str = "/tmp/artic_serving_smoke.json"
                  ) -> RunResult:
    """Engine-server smoke: a tiny `Fleet(server="engine")` scenario run
    end to end on CPU — delivered frames stream into the
    continuous-batching engine as patch embeddings (chunked prefill),
    committing QA questions decode as one batch, and per-session
    TTFT/queueing-delay telemetry lands in the metrics.  Run TWICE and
    digest-compared: the reduced-config random-weight model plus the
    simulated engine clock make the whole path deterministic."""
    import hashlib
    import json

    base = ScenarioSpec(duration=3.0, frame_h=64, frame_w=64,
                        scene="retail", qa="periodic",
                        qa_kwargs=dict(start=1.0, period=1.0, count=2,
                                       answer_window=1.0),
                        server="engine",
                        engine_kwargs=dict(max_len=128, step_dt=0.004))
    specs = grid(base, system=["webrtc", "artic"],
                 trace=["fluctuating", "elevator"])

    def digest(result: RunResult) -> str:
        doc = [[m.server_ttfts, m.server_queue_delays,
                m.server_confidences, m.qa_results, m.latencies]
               for m in result.metrics]
        return hashlib.sha256(
            json.dumps(doc, default=float).encode()).hexdigest()

    result = run_scenarios(specs)
    again = run_scenarios(specs)
    d1, d2 = digest(result), digest(again)
    if d1 != d2:
        raise AssertionError(
            f"engine server run is not deterministic: {d1} != {d2}")
    doc = result.to_json(out_path)
    validate_run_result_json(doc)
    n_q = sum(len(m.server_ttfts) for m in result.metrics)
    if n_q == 0:
        raise AssertionError("engine server answered no queries")
    print(f"[serving-smoke] {len(result)} engine-served sessions, "
          f"{n_q} queries, digest {d1[:12]} reproduced -> {out_path}")

    # long-session eviction scenario: one session streams > 4x max_len
    # frame tokens; sink+recent eviction must keep it running with ZERO
    # rollovers, deterministically (digest compared across two runs)
    long_spec = base.with_(duration=8.0,
                           qa_kwargs=dict(start=1.0, period=2.0, count=3,
                                          answer_window=1.0),
                           engine_kwargs=dict(max_len=64, step_dt=0.004))
    r1, r2 = run_scenarios([long_spec]), run_scenarios([long_spec])
    if digest(r1) != digest(r2):
        raise AssertionError("eviction run is not deterministic")
    m = r1.metrics[0]
    if m.server_rollovers != 0 or m.server_evictions == 0:
        raise AssertionError(
            f"long session expected eviction-only overflow handling; got "
            f"{m.server_evictions} evictions, {m.server_rollovers} "
            "rollovers")
    print(f"[serving-smoke] long session: {m.server_evictions} evictions "
          f"({m.server_evicted_tokens} tokens), 0 rollovers, digest "
          "reproduced")
    for s, m in zip(result.specs, result.metrics):
        print(f"[serving-smoke]   {s.system}/{s.trace}: "
              f"ttft_p50={m.ttft_p50_ms:.1f}ms "
              f"ttft_p95={m.ttft_p95_ms:.1f}ms "
              f"queue_p95={m.queue_p95_ms:.1f}ms acc={m.accuracy:.2f}")
    return result


def churn_smoke(out_path: str = "/tmp/artic_churn_smoke.json") -> None:
    """Open-loop churn smoke: a sustained arrival stream through a fleet
    with fewer slots than arrivals, on the oracle AND engine server
    paths.  Each run goes TWICE and must reproduce its telemetry digest
    exactly — seeded arrival/lifetime processes plus per-lane bank
    resets at every slot revival make the whole open loop
    deterministic."""
    import json

    from repro.core.churn import (ChurnConfig,
                                  validate_churn_result_json)

    base = ScenarioSpec(scene="retail", frame_h=64, frame_w=64,
                        duration=6.0, qa="periodic",
                        qa_kwargs=dict(start=0.5, period=1.0,
                                       answer_window=0.7, count=5),
                        workload="churn",
                        churn_kwargs=dict(rate=1.0, slots=2,
                                          mean_lifetime=2.0, seed=7),
                        tag="churn-oracle")
    specs = [base,
             base.with_(duration=4.0, server="engine",
                        qa_kwargs=dict(start=0.5, period=1.0,
                                       answer_window=0.7, count=3),
                        churn_kwargs=dict(rate=1.5, slots=2,
                                          mean_lifetime=1.5, seed=3),
                        tag="churn-engine")]
    result = run_scenarios(specs)
    again = run_scenarios(specs)
    for r, r2 in zip(result.results, again.results):
        slots = ChurnConfig.from_spec(r.spec).slots
        if r.offered <= slots:
            raise AssertionError(
                f"{r.spec.tag}: churn smoke must offer more sessions "
                f"({r.offered}) than slots ({slots})")
        if r.served < 1:
            raise AssertionError(f"{r.spec.tag}: no session was served")
        d1, d2 = r.digest(), r2.digest()
        if d1 != d2:
            raise AssertionError(
                f"{r.spec.tag}: churn run is not deterministic: "
                f"{d1} != {d2}")
        s = r.summary()
        print(f"[churn-smoke]   {r.spec.tag}: offered={r.offered} "
              f"served={r.served} unserved={r.unserved} "
              f"rate={s['sessions_per_sec']:.2f}/s "
              f"adm_p95={s['admission_p95_ms']:.0f}ms "
              f"depth_peak={s['queue_depth_peak']:.0f} "
              f"digest {d1[:12]} reproduced")
    engine = result.results[1]
    if not any(rec.metrics.server_ttfts for rec in engine.records):
        raise AssertionError(
            "engine churn run produced no TTFT telemetry")
    doc = result.to_json(out_path)
    validate_churn_result_json(doc)
    with open(out_path) as f:
        validate_churn_result_json(json.load(f))  # survives the round trip
    print(f"[churn-smoke] {len(result)} open-loop scenarios -> {out_path} "
          "(schema OK)")


def _main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="/tmp/artic_scenario_smoke.json",
                    help="where the smoke grid's RunResult JSON lands")
    ap.add_argument("--devibench", action="store_true",
                    help="run the DeViBench degradation-grid smoke "
                         "instead of the RTC fleet smoke")
    ap.add_argument("--sharded", action="store_true",
                    help="run the fleet smoke device-sharded over all "
                         "visible devices (make_fleet_mesh)")
    ap.add_argument("--rollout", action="store_true",
                    help="run the whole-tick rollout parity smoke "
                         "(Fleet.run(rollout=K) vs the eager tick loop)")
    ap.add_argument("--serving", action="store_true",
                    help="run the engine-server smoke (Fleet(server="
                         "'engine') determinism + telemetry)")
    ap.add_argument("--churn", action="store_true",
                    help="run the open-loop churn smoke (arrivals > "
                         "slots on oracle + engine paths, digest-"
                         "reproducible)")
    args = ap.parse_args()
    if args.churn:
        churn_smoke(args.out)
    elif args.serving:
        serving_smoke(args.out)
    elif args.rollout:
        rollout_smoke()
    elif args.devibench:
        devibench_smoke(args.out)
    else:
        smoke(args.out, sharded=args.sharded)


if __name__ == "__main__":
    _main()

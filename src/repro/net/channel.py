"""Trace-driven uplink channel with a drop-tail queue (Mahimahi-style).

Frames are packetized (1500 B MTU), enqueued at send time and drained at
the trace bandwidth; queue capacity is 60 packets with drop-tail (§7.1).
Frame latency = last-surviving-packet departure - frame send time, which
matches the paper's "client encoder -> MLLM decoder" frame-latency metric.
Dropped packets shrink the frame's delivered bits (the receiver decodes
at a degraded effective rate) — that is how low-bandwidth accuracy damage
manifests in the end-to-end loop.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro.net.traces import Trace, TraceBank

MTU_BITS = 1500 * 8
QUEUE_PACKETS = 60

ACK_WINDOW = 20


def masked_mean_latency(lat: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Deterministic masked mean over the trailing (window) axis.

    The one ack-stat reduction whose float result depends on summation
    order: serial `Channel.ack_stats`, the vectorized
    `ChannelBank.ack_stats_arrays` and the on-device rollout
    (repro.core.rollout) all funnel their latency windows through THIS
    sequence of adds — a fixed chronological fori_loop of elementwise
    masked accumulations — so the three paths agree bit for bit.  Masked
    slots contribute an exact +0.0 and never perturb the accumulator,
    which makes the result invariant to where padding sits and to the
    batch shape (elementwise adds compile to the same scalar op
    sequence per lane at any N).  float64 in, float64 out; empty
    windows return the serial path's 1.0 fallback."""
    acc = jnp.zeros(lat.shape[:-1], lat.dtype)
    for j in range(lat.shape[-1]):
        acc = acc + jnp.where(mask[..., j], lat[..., j], 0.0)
    cnt = jnp.sum(mask, axis=-1)
    return jnp.where(cnt > 0, acc / cnt, 1.0)


@jax.jit
def _masked_mean_latency_jit(lat, mask):
    return masked_mean_latency(lat, mask)


def _avg_latency_host(lat: np.ndarray) -> np.ndarray:
    """Host entry: (N, window) float64 latencies (inf = undelivered) ->
    (N,) float64 mean over the finite entries (1.0 where none).  Traced
    under enable_x64 so the kernel really runs in float64 — the context
    only matters at trace time, later calls reuse the executable."""
    lat = np.asarray(lat, np.float64)
    with enable_x64():
        out = _masked_mean_latency_jit(lat, np.isfinite(lat))
    return np.asarray(out)


class FrameReport(NamedTuple):
    send_time: float
    latency: float          # seconds until last surviving packet delivered
    bits_sent: int
    bits_delivered: int
    dropped: bool           # any packet dropped
    queue_delay: float      # backlog delay seen on arrival (seconds)


@dataclasses.dataclass
class Channel:
    trace: Trace
    queue_packets: int = QUEUE_PACKETS

    def __post_init__(self):
        self.now = 0.0
        self._queue_bits = 0.0  # backlog (bits)
        self._queue_pkts = 0
        self.reports: List[FrameReport] = []

    # ------------------------------------------------------------------
    def _drain(self, until: float):
        """Advance time, servicing the backlog at the trace bandwidth."""
        t = self.now
        dt = self.trace.dt
        while t < until:
            step_end = (np.floor(t / dt + 1e-9) + 1) * dt
            if step_end <= t + 1e-12:  # float-boundary guard
                step_end = t + dt
            step_end = min(until, step_end)
            budget = self.trace.at(t) * (step_end - t)
            consumed = min(budget, self._queue_bits)
            self._queue_bits -= consumed
            t = step_end
        self._queue_pkts = int(np.ceil(self._queue_bits / MTU_BITS))
        self.now = until

    def _time_to_send(self, t: float, bits: float) -> float:
        """Seconds from t until `bits` of backlog fully depart."""
        dt = self.trace.dt
        tt, remaining = t, bits
        for _ in range(int(300.0 / dt)):
            bw = max(self.trace.at(tt), 1e3)
            step_end = (np.floor(tt / dt + 1e-9) + 1) * dt
            if step_end <= tt + 1e-12:  # float-boundary guard
                step_end = tt + dt
            budget = bw * (step_end - tt)
            if budget >= remaining:
                return tt + remaining / bw - t
            remaining -= budget
            tt = step_end
        return tt - t  # capped at 300 s

    def send_frame(self, t: float, bits: float) -> FrameReport:
        """Send a frame of `bits` at time t (sends must be time-ordered)."""
        t = max(t, self.now)
        self._drain(t)
        bw_now = max(self.trace.at(t), 1e3)
        queue_delay = self._queue_bits / bw_now

        n_pkts = max(int(np.ceil(bits / MTU_BITS)), 1)
        free = max(self.queue_packets - self._queue_pkts, 0)
        admitted_pkts = min(n_pkts, free)
        admitted_bits = min(bits, admitted_pkts * MTU_BITS)
        dropped = admitted_pkts < n_pkts

        backlog_incl = self._queue_bits + admitted_bits
        latency = (self._time_to_send(t, backlog_incl)
                   if admitted_pkts else float("inf"))
        self._queue_bits = backlog_incl
        self._queue_pkts += admitted_pkts

        rep = FrameReport(send_time=t, latency=latency,
                          bits_sent=int(bits),
                          bits_delivered=int(admitted_bits),
                          dropped=dropped, queue_delay=queue_delay)
        self.reports.append(rep)
        return rep

    # ------------------------------------------------------------------
    @property
    def queue_bits(self) -> float:
        return self._queue_bits

    def ack_stats(self, window: int = 20):
        """Receiver-side feedback for CC: recent delivery rate + delays.

        `app_limited`: the sender offered less than the link could carry
        (queue kept draining empty) — rate samples taken then must not
        LOWER the CC's bandwidth estimate (BBR's app-limited marking;
        essential once ReCapABR deliberately under-sends)."""
        recent = self.reports[-window:]
        if len(recent) < 2:
            return {"delivery_rate": 0.0, "avg_latency": 0.05,
                    "min_latency": 0.05, "loss": 0.0, "app_limited": 1.0}
        span = max(recent[-1].send_time - recent[0].send_time, 1e-6)
        bits = sum(r.bits_delivered for r in recent[:-1])
        finite = [r.latency for r in recent if np.isfinite(r.latency)]
        app_limited = float(np.mean([r.queue_delay < 0.02 for r in recent]))
        # avg latency via the shared deterministic kernel (see
        # `masked_mean_latency`): pad the chronological window to a fixed
        # shape so every call reuses one compiled executable
        lat_w = np.full((1, window), np.inf)
        lat_w[0, :len(recent)] = [r.latency for r in recent]
        return {
            "delivery_rate": bits / span,
            "avg_latency": float(_avg_latency_host(lat_w)[0]),
            "min_latency": float(np.min(finite)) if finite else 0.0,
            "loss": float(np.mean([r.dropped for r in recent])),
            "app_limited": app_limited,
        }


class BankReport(NamedTuple):
    """One tick's FrameReports for all N sessions, as (N,) arrays."""
    send_time: float
    latency: np.ndarray         # float64, inf where nothing was admitted
    bits_sent: np.ndarray       # int64
    bits_delivered: np.ndarray  # int64
    dropped: np.ndarray         # bool
    queue_delay: np.ndarray     # float64


class ChannelBank:
    """N drop-tail uplink queues advanced in lockstep with array ops.

    The fleet engine sends every session's frame at the same tick
    timestamps, so `now` and the trace-step boundaries are shared scalars
    and every per-session quantity (backlog, budget, latency) is a (N,)
    NumPy vector — no per-session Python Channel objects on the hot path.
    The arithmetic mirrors `Channel` operation for operation, so a bank of
    N queues is numerically identical to N serial channels (asserted by
    tests/test_fleet.py).

    `pad_to` sizes the bank past the live trace count with *dead
    sessions* (rows that repeat the first trace): the sharded fleet
    engine pads its session axis to a multiple of the device count, and
    keeping every per-session array — including the channel state — at
    the padded length means live and dead rows flow through one set of
    elementwise ops.  Dead rows never influence live rows (every
    per-session quantity is an independent vector lane); callers simply
    ignore rows >= `n_live`."""

    def __init__(self, traces: Sequence[Trace],
                 queue_packets: int = QUEUE_PACKETS,
                 pad_to: Optional[int] = None):
        traces = list(traces)
        self.n_live = len(traces)
        if pad_to is not None and pad_to > len(traces):
            traces = traces + [traces[0]] * (pad_to - len(traces))
        self.bank = TraceBank.stack(traces)
        self.n = self.bank.n
        self.queue_packets = queue_packets
        self.now = 0.0
        self._queue_bits = np.zeros(self.n)
        self._queue_pkts = np.zeros(self.n, np.int64)
        # per-tick history: rectangular because every session sends exactly
        # one frame per tick
        self._send_times: List[float] = []
        self._latency: List[np.ndarray] = []
        self._bits_sent: List[np.ndarray] = []
        self._bits_delivered: List[np.ndarray] = []
        self._dropped: List[np.ndarray] = []
        self._queue_delay: List[np.ndarray] = []

    # ------------------------------------------------------------------
    def _drain(self, until: float):
        """Advance shared time, servicing all backlogs at trace bandwidth."""
        t = self.now
        dt = self.bank.dt
        while t < until:
            step_end = (np.floor(t / dt + 1e-9) + 1) * dt
            if step_end <= t + 1e-12:  # float-boundary guard
                step_end = t + dt
            step_end = min(until, step_end)
            budget = self.bank.at(t) * (step_end - t)
            self._queue_bits = self._queue_bits - np.minimum(
                budget, self._queue_bits)
            t = step_end
        self._queue_pkts = np.ceil(self._queue_bits / MTU_BITS).astype(
            np.int64)
        self.now = until

    def _time_to_send(self, t: float, bits: np.ndarray) -> np.ndarray:
        """Seconds from t until each session's `bits` of backlog depart."""
        dt = self.bank.dt
        tt = t
        remaining = np.asarray(bits, np.float64).copy()
        out = np.empty(self.n)
        done = np.zeros(self.n, bool)
        for _ in range(int(300.0 / dt)):
            bw = np.maximum(self.bank.at(tt), 1e3)
            step_end = (np.floor(tt / dt + 1e-9) + 1) * dt
            if step_end <= tt + 1e-12:  # float-boundary guard
                step_end = tt + dt
            budget = bw * (step_end - tt)
            fin = ~done & (budget >= remaining)
            out[fin] = tt + remaining[fin] / bw[fin] - t
            done |= fin
            if done.all():
                return out
            remaining = np.where(done, remaining, remaining - budget)
            tt = step_end
        out[~done] = tt - t  # capped at 300 s
        return out

    def send_frames(self, t: float, bits: np.ndarray) -> BankReport:
        """Send one frame per session at shared time t (time-ordered)."""
        bits = np.asarray(bits, np.float64)
        t = max(t, self.now)
        self._drain(t)
        bw_now = np.maximum(self.bank.at(t), 1e3)
        queue_delay = self._queue_bits / bw_now

        n_pkts = np.maximum(np.ceil(bits / MTU_BITS).astype(np.int64), 1)
        free = np.maximum(self.queue_packets - self._queue_pkts, 0)
        admitted_pkts = np.minimum(n_pkts, free)
        admitted_bits = np.minimum(bits, admitted_pkts * MTU_BITS)
        dropped = admitted_pkts < n_pkts

        backlog_incl = self._queue_bits + admitted_bits
        latency = np.where(admitted_pkts > 0,
                           self._time_to_send(t, backlog_incl), np.inf)
        self._queue_bits = backlog_incl
        self._queue_pkts = self._queue_pkts + admitted_pkts

        rep = BankReport(send_time=t, latency=latency,
                         bits_sent=bits.astype(np.int64),
                         bits_delivered=admitted_bits.astype(np.int64),
                         dropped=dropped, queue_delay=queue_delay)
        self._send_times.append(t)
        self._latency.append(latency)
        self._bits_sent.append(rep.bits_sent)
        self._bits_delivered.append(rep.bits_delivered)
        self._dropped.append(dropped)
        self._queue_delay.append(queue_delay)
        return rep

    # ------------------------------------------------------------------
    @property
    def queue_bits(self) -> np.ndarray:
        return self._queue_bits

    @property
    def n_ticks(self) -> int:
        """Frames sent so far (the `since` index space of `reports_for`)."""
        return len(self._send_times)

    def ack_stats_arrays(self, window: int = 20) -> Dict[str, np.ndarray]:
        """CC feedback for all N sessions as (N,) arrays, computed with
        one set of array ops over the rolling (window, N) history —
        consumed directly by the vectorized CC banks (net.cc)."""
        if len(self._send_times) < 2:
            return {"delivery_rate": np.zeros(self.n),
                    "avg_latency": np.full(self.n, 0.05),
                    "min_latency": np.full(self.n, 0.05),
                    "loss": np.zeros(self.n),
                    "app_limited": np.ones(self.n)}
        st = self._send_times[-window:]
        lat = np.stack(self._latency[-window:])                 # (w, N)
        deliv = np.stack(self._bits_delivered[-window:])
        drop = np.stack(self._dropped[-window:])
        qd = np.stack(self._queue_delay[-window:])
        span = max(st[-1] - st[0], 1e-6)
        bits = deliv[:-1].sum(axis=0)
        finite = np.isfinite(lat)
        cnt = finite.sum(axis=0)
        # min / loss / app_limited are order-independent reductions, so
        # they vectorize exactly; the latency *mean* goes through the
        # shared deterministic kernel (chronological masked adds) that
        # the serial path and the on-device rollout also use, so all
        # three stay bit-identical.  Pad the window to a fixed shape so
        # one executable serves the whole run.
        lat_w = np.full((window, self.n), np.inf)
        lat_w[:lat.shape[0]] = lat
        avg_lat = _avg_latency_host(lat_w.T)
        min_lat = np.where(cnt > 0,
                           np.where(finite, lat, np.inf).min(axis=0), 0.0)
        return {"delivery_rate": bits / span,
                "avg_latency": avg_lat,
                "min_latency": min_lat,
                "loss": drop.mean(axis=0),
                "app_limited": (qd < 0.02).mean(axis=0)}

    def ack_stats(self, window: int = 20) -> List[Dict]:
        """Per-session CC feedback dicts (serial-compatible view of
        `ack_stats_arrays`)."""
        arr = self.ack_stats_arrays(window)
        return [{key: float(val[k]) for key, val in arr.items()}
                for k in range(self.n)]

    def reports_for(self, k: int, since: int = 0) -> List[FrameReport]:
        """Materialize session k's history as serial-style FrameReports.
        `since` skips ticks before the session's slot was (re)opened —
        churn tenants must not inherit the previous tenant's reports."""
        return [FrameReport(send_time=self._send_times[i],
                            latency=float(self._latency[i][k]),
                            bits_sent=int(self._bits_sent[i][k]),
                            bits_delivered=int(self._bits_delivered[i][k]),
                            dropped=bool(self._dropped[i][k]),
                            queue_delay=float(self._queue_delay[i][k]))
                for i in range(since, len(self._send_times))]

    def reset_row(self, k: int, trace: Optional[Trace] = None) -> None:
        """Recycle lane k for a new tenant (churn slot revival): zero the
        backlog, optionally swap in the tenant's trace, and blank the
        lane's trailing ACK window so the CC warmup never sees the
        previous tenant's traffic.  Rows older than the ACK window are
        left in place — `ack_stats_arrays` only reads the trailing
        window and `reports_for(k, since=...)` slices per tenant."""
        self._queue_bits[k] = 0.0
        self._queue_pkts[k] = 0
        if trace is not None:
            self.bank.set_row(k, trace)
        for rows, fill in ((self._latency, np.inf),
                           (self._bits_sent, 0),
                           (self._bits_delivered, 0),
                           (self._dropped, False),
                           (self._queue_delay, 0.0)):
            for row in rows[-ACK_WINDOW:]:
                row[k] = fill

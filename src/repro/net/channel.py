"""Trace-driven uplink channel with a drop-tail queue (Mahimahi-style).

Frames are packetized (1500 B MTU), enqueued at send time and drained at
the trace bandwidth; queue capacity is 60 packets with drop-tail (§7.1).
Frame latency = last-surviving-packet departure - frame send time, which
matches the paper's "client encoder -> MLLM decoder" frame-latency metric.
Dropped packets shrink the frame's delivered bits (the receiver decodes
at a degraded effective rate) — that is how low-bandwidth accuracy damage
manifests in the end-to-end loop.
"""
from __future__ import annotations

import dataclasses
from typing import List, NamedTuple

import numpy as np

from repro.net.traces import Trace

MTU_BITS = 1500 * 8
QUEUE_PACKETS = 60


class FrameReport(NamedTuple):
    send_time: float
    latency: float          # seconds until last surviving packet delivered
    bits_sent: int
    bits_delivered: int
    dropped: bool           # any packet dropped
    queue_delay: float      # backlog delay seen on arrival (seconds)


@dataclasses.dataclass
class Channel:
    trace: Trace
    queue_packets: int = QUEUE_PACKETS

    def __post_init__(self):
        self.now = 0.0
        self._queue_bits = 0.0  # backlog (bits)
        self._queue_pkts = 0
        self.reports: List[FrameReport] = []

    # ------------------------------------------------------------------
    def _drain(self, until: float):
        """Advance time, servicing the backlog at the trace bandwidth."""
        t = self.now
        dt = self.trace.dt
        while t < until:
            step_end = (np.floor(t / dt + 1e-9) + 1) * dt
            if step_end <= t + 1e-12:  # float-boundary guard
                step_end = t + dt
            step_end = min(until, step_end)
            budget = self.trace.at(t) * (step_end - t)
            consumed = min(budget, self._queue_bits)
            self._queue_bits -= consumed
            t = step_end
        self._queue_pkts = int(np.ceil(self._queue_bits / MTU_BITS))
        self.now = until

    def _time_to_send(self, t: float, bits: float) -> float:
        """Seconds from t until `bits` of backlog fully depart."""
        dt = self.trace.dt
        tt, remaining = t, bits
        for _ in range(int(300.0 / dt)):
            bw = max(self.trace.at(tt), 1e3)
            step_end = (np.floor(tt / dt + 1e-9) + 1) * dt
            if step_end <= tt + 1e-12:  # float-boundary guard
                step_end = tt + dt
            budget = bw * (step_end - tt)
            if budget >= remaining:
                return tt + remaining / bw - t
            remaining -= budget
            tt = step_end
        return tt - t  # capped at 300 s

    def send_frame(self, t: float, bits: float) -> FrameReport:
        """Send a frame of `bits` at time t (sends must be time-ordered)."""
        t = max(t, self.now)
        self._drain(t)
        bw_now = max(self.trace.at(t), 1e3)
        queue_delay = self._queue_bits / bw_now

        n_pkts = max(int(np.ceil(bits / MTU_BITS)), 1)
        free = max(self.queue_packets - self._queue_pkts, 0)
        admitted_pkts = min(n_pkts, free)
        admitted_bits = min(bits, admitted_pkts * MTU_BITS)
        dropped = admitted_pkts < n_pkts

        backlog_incl = self._queue_bits + admitted_bits
        latency = (self._time_to_send(t, backlog_incl)
                   if admitted_pkts else float("inf"))
        self._queue_bits = backlog_incl
        self._queue_pkts += admitted_pkts

        rep = FrameReport(send_time=t, latency=latency,
                          bits_sent=int(bits),
                          bits_delivered=int(admitted_bits),
                          dropped=dropped, queue_delay=queue_delay)
        self.reports.append(rep)
        return rep

    # ------------------------------------------------------------------
    @property
    def queue_bits(self) -> float:
        return self._queue_bits

    def ack_stats(self, window: int = 20):
        """Receiver-side feedback for CC: recent delivery rate + delays.

        `app_limited`: the sender offered less than the link could carry
        (queue kept draining empty) — rate samples taken then must not
        LOWER the CC's bandwidth estimate (BBR's app-limited marking;
        essential once ReCapABR deliberately under-sends)."""
        recent = self.reports[-window:]
        if len(recent) < 2:
            return {"delivery_rate": 0.0, "avg_latency": 0.05,
                    "min_latency": 0.05, "loss": 0.0, "app_limited": 1.0}
        span = max(recent[-1].send_time - recent[0].send_time, 1e-6)
        bits = sum(r.bits_delivered for r in recent[:-1])
        finite = [r.latency for r in recent if np.isfinite(r.latency)]
        app_limited = float(np.mean([r.queue_delay < 0.02 for r in recent]))
        return {
            "delivery_rate": bits / span,
            "avg_latency": float(np.mean(finite)) if finite else 1.0,
            "min_latency": float(np.min(finite)) if finite else 0.0,
            "loss": float(np.mean([r.dropped for r in recent])),
            "app_limited": app_limited,
        }

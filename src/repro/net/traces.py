"""5G uplink bandwidth traces: the measurement-study scenarios of §2.3.

Trace model calibrated to the paper's observations: static links saturate
~5 Mbps; mobility (walking/driving) switches among industry bitrate levels
at a configurable fluctuation frequency; the elevator scenario collapses
5 -> 1.23 Mbps within ~1.5 s (Fig. 2).  All traces are seeded arrays of
bandwidth (bits/s) sampled at `dt` seconds.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

# shared sampling period of every trace factory (seconds per tick);
# the scenario compiler's cohort key assumes this default
DEFAULT_TRACE_DT = 0.05

# Agora VideoEncoderConfiguration industry bitrate levels (Kbps) [23]
INDUSTRY_LEVELS_KBPS = [5000, 3000, 1710, 1130, 710, 400, 290]


@dataclasses.dataclass
class Trace:
    bw: np.ndarray    # bits/s per tick
    dt: float         # seconds per tick
    name: str = ""

    @property
    def duration(self) -> float:
        return len(self.bw) * self.dt

    def at(self, t: float) -> float:
        i = int(t / self.dt) % len(self.bw)
        return float(self.bw[i])

    def looped(self, duration: float) -> "Trace":
        n = int(np.ceil(duration / self.dt))
        reps = int(np.ceil(n / len(self.bw)))
        return Trace(np.tile(self.bw, reps)[:n], self.dt, self.name)


@dataclasses.dataclass
class TraceBank:
    """N traces stacked for vectorized lookup.

    All member traces must share `dt`; the per-trace bandwidth arrays are
    concatenated (they may have different lengths) and a shared-timestamp
    lookup becomes one fancy-indexing op returning a (N,) vector — the
    stacked-array substrate the fleet's ChannelBank advances against."""
    concat: np.ndarray     # all bw arrays back to back (bits/s)
    offsets: np.ndarray    # (N,) start index of each trace in `concat`
    lengths: np.ndarray    # (N,) length of each trace
    dt: float

    @classmethod
    def stack(cls, traces: List["Trace"]) -> "TraceBank":
        if not traces:
            raise ValueError("TraceBank needs at least one trace")
        dts = {t.dt for t in traces}
        if len(dts) != 1:
            raise ValueError(f"all traces must share dt, got {sorted(dts)}")
        lengths = np.asarray([len(t.bw) for t in traces], np.int64)
        offsets = np.concatenate([[0], np.cumsum(lengths[:-1])])
        concat = np.concatenate([np.asarray(t.bw, np.float64)
                                 for t in traces])
        return cls(concat=concat, offsets=offsets, lengths=lengths,
                   dt=traces[0].dt)

    @property
    def n(self) -> int:
        return len(self.lengths)

    def at(self, t: float) -> np.ndarray:
        """Bandwidth of every trace at shared time t -> (N,) bits/s."""
        k = int(t / self.dt)
        return self.concat[self.offsets + (k % self.lengths)]

    def set_row(self, k: int, trace: "Trace") -> None:
        """Replace trace k in place (slot revival under session churn).
        The replacement is tiled/truncated to the incumbent's length so
        the packed `concat` layout never moves."""
        if trace.dt != self.dt:
            raise ValueError(f"trace dt {trace.dt} != bank dt {self.dt}")
        L = int(self.lengths[k])
        bw = np.asarray(trace.bw, np.float64)
        if len(bw) != L:
            reps = -(-L // len(bw))
            bw = np.tile(bw, reps)[:L]
        self.concat[self.offsets[k]:self.offsets[k] + L] = bw


def static_trace(duration: float = 60.0, dt: float = DEFAULT_TRACE_DT,
                 mbps: float = 5.0, jitter: float = 0.03,
                 seed: int = 0) -> Trace:
    rng = np.random.default_rng(seed)
    n = int(duration / dt)
    bw = mbps * 1e6 * (1.0 + jitter * rng.standard_normal(n)).clip(0.5, 1.5)
    return Trace(bw, dt, "static")


def elevator_trace(duration: float = 60.0, dt: float = DEFAULT_TRACE_DT,
                   event_at: float = 26.25, drop_mbps: float = 1.23,
                   drop_len: float = 12.0, ramp: float = 1.5,
                   seed: int = 0) -> Trace:
    """§2.3: 5 Mbps collapses to 1.23 Mbps within 1.5 s entering the
    elevator (frame 525 at 20 fps = 26.25 s)."""
    t = static_trace(duration, dt, 5.0, seed=seed)
    n = len(t.bw)
    for i in range(n):
        ti = i * dt
        if event_at <= ti < event_at + drop_len:
            frac = min((ti - event_at) / ramp, 1.0)
            t.bw[i] = t.bw[i] * (1 - frac) + drop_mbps * 1e6 * frac
        elif event_at + drop_len <= ti < event_at + drop_len + ramp:
            frac = (ti - event_at - drop_len) / ramp
            t.bw[i] = drop_mbps * 1e6 * (1 - frac) + t.bw[i] * frac
    t.name = "elevator"
    return t


def fluctuating_trace(duration: float = 60.0, dt: float = DEFAULT_TRACE_DT,
                      switches_per_min: float = 4.0,
                      levels_kbps: Optional[List[float]] = None,
                      seed: int = 0) -> Trace:
    """§7.2: random switching among industry levels at a given frequency."""
    rng = np.random.default_rng(seed)
    levels = np.asarray(levels_kbps or INDUSTRY_LEVELS_KBPS, np.float64) * 1e3
    n = int(duration / dt)
    bw = np.empty(n)
    cur = levels[0]
    p_switch = switches_per_min / 60.0 * dt
    for i in range(n):
        if rng.random() < p_switch:
            cur = float(rng.choice(levels))
        bw[i] = cur * (1.0 + 0.02 * rng.standard_normal())
    return Trace(bw.clip(1e4, None), dt, f"fluct{switches_per_min}")


def mobility_trace(kind: str = "walking", duration: float = 120.0,
                   dt: float = DEFAULT_TRACE_DT, seed: int = 0) -> Trace:
    """Walking/driving 5G uplink (Ghoshal et al. [37] style): log-normal
    fading around a mobility-dependent mean with occasional outages."""
    rng = np.random.default_rng(seed)
    n = int(duration / dt)
    mean_mbps, vol, outage_p = {
        "walking": (3.5, 0.25, 0.002),
        "driving": (2.5, 0.45, 0.006),
    }[kind]
    # AR(1) log-bandwidth
    x = np.empty(n)
    x[0] = 0.0
    rho = 0.995
    for i in range(1, n):
        x[i] = rho * x[i - 1] + np.sqrt(1 - rho ** 2) * rng.standard_normal() * vol * 3
    bw = mean_mbps * 1e6 * np.exp(x - vol ** 2)
    # outages: short collapses to ~200 kbps
    i = 0
    while i < n:
        if rng.random() < outage_p:
            L = int(rng.uniform(0.5, 3.0) / dt)
            bw[i:i + L] = 2e5 * (1 + 0.2 * rng.standard_normal(min(L, n - i)))
            i += L
        i += 1
    return Trace(bw.clip(5e4, None), dt, kind)

"""Congestion control: GCC (delay-gradient) and BBR (bw-probing) baselines.

Faithful-in-spirit reimplementations of the two CC algorithms the paper
tests under (Carlucci et al. 2016; Cardwell et al. 2017), operating on the
per-frame ack feedback of repro.net.channel.  Both expose
``estimate(ack) -> B_hat`` — the bandwidth estimate ReCapABR caps (Eq. 2).

GCC: arrival-delay-gradient overuse detector with multiplicative increase
(~5%/update when underusing) and beta=0.85 decrease on overuse — this is
the adaptation lag that causes the Fig. 2 latency spike.

BBR: windowed-max delivery rate x pacing-gain cycle (probe up 1.25, drain
0.75, cruise 1.0 x6).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

# every CC estimate is clamped to this band (bits/s); the property tests
# in tests/test_net.py pin the banks to it under arbitrary ack streams
RATE_MIN = 5e4
RATE_MAX = 2e7


class CongestionControl:
    name = "base"

    def estimate(self, ack: Dict) -> float:  # bits/s
        raise NotImplementedError


@dataclasses.dataclass
class GCC(CongestionControl):
    init_rate: float = 1e6
    beta: float = 0.85
    eta: float = 1.05
    overuse_thresh: float = 0.010   # seconds of queuing delay growth
    name: str = "gcc"

    def __post_init__(self):
        self.rate = self.init_rate
        self._prev_delay = None
        self._state = "increase"
        self._capacity = self.init_rate  # believed link capacity

    def estimate(self, ack: Dict) -> float:
        delay = ack["avg_latency"] - ack["min_latency"]  # queuing component
        grad = 0.0 if self._prev_delay is None else delay - self._prev_delay
        self._prev_delay = delay

        if grad > self.overuse_thresh or ack["loss"] > 0.1 or delay > 0.3:
            self._state = "decrease"
        elif grad < -self.overuse_thresh / 2:
            self._state = "hold"
        else:
            self._state = "increase"

        measured = max(ack["delivery_rate"], 1e4)
        app_limited = ack.get("app_limited", 0.0) > 0.5
        if not app_limited:
            # only backlogged samples measure the link
            self._capacity = 0.7 * self._capacity + 0.3 * measured
        if self._state == "decrease":
            # an app-limited sample reflects the offered load, not the
            # link: never slash below the last believed capacity for it
            self.rate = (min(self.rate, 1.2 * self._capacity) if app_limited
                         else self.beta * measured)
        elif self._state == "increase":
            # probe up; when app-limited the measured rate is meaningless,
            # bound by believed capacity + probing margin instead
            cap = (2.0 * self._capacity + 1e5 if app_limited
                   else 1.5 * measured + 1e5)
            self.rate = min(self.rate * self.eta, cap)
        # hold: keep rate
        self.rate = float(np.clip(self.rate, RATE_MIN, RATE_MAX))
        return self.rate


@dataclasses.dataclass
class BBR(CongestionControl):
    init_rate: float = 1e6
    window: int = 10
    name: str = "bbr"
    GAIN_CYCLE = (1.25, 0.75, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0)

    def __post_init__(self):
        self._btlbw_samples = [self.init_rate]
        self._phase = 0

    def estimate(self, ack: Dict) -> float:
        measured = max(ack["delivery_rate"], 1e4)
        if ack.get("app_limited", 0.0) > 0.5:
            # BBR rate sampling: app-limited samples may only RAISE btlbw
            measured = max(measured, max(self._btlbw_samples))
        self._btlbw_samples.append(measured)
        self._btlbw_samples = self._btlbw_samples[-self.window:]
        btlbw = max(self._btlbw_samples)
        gain = self.GAIN_CYCLE[self._phase % len(self.GAIN_CYCLE)]
        self._phase += 1
        # back off hard on standing queues (ProbeRTT-ish behaviour)
        if ack["avg_latency"] - ack["min_latency"] > 0.25:
            gain = min(gain, 0.75)
        return float(np.clip(btlbw * gain, RATE_MIN, RATE_MAX))


def make_cc(kind: str, **kw) -> CongestionControl:
    return {"gcc": GCC, "bbr": BBR}[kind](**kw)


# --------------------------------------------------------------------------
# Vectorized banks: the same per-tick arithmetic as GCC / BBR, elementwise
# over (M,) session arrays — the fleet engine groups its sessions by CC
# kind and advances each group with one bank call per tick.  Results are
# identical to M serial objects (asserted via the fleet parity test).
# --------------------------------------------------------------------------
class GCCBank:
    def __init__(self, m: int, init_rate: float = 1e6, beta: float = 0.85,
                 eta: float = 1.05, overuse_thresh: float = 0.010):
        self.beta, self.eta, self.overuse_thresh = beta, eta, overuse_thresh
        self.init_rate = init_rate
        self.rate = np.full(m, init_rate)
        self._prev_delay = np.full(m, np.nan)   # nan == "no sample yet"
        self._capacity = np.full(m, init_rate)

    def reset_lane(self, i: int) -> None:
        """Forget lane i's state (churn slot revival): the new tenant
        starts from the same cold start a fresh bank would give it."""
        self.rate[i] = self.init_rate
        self._prev_delay[i] = np.nan
        self._capacity[i] = self.init_rate

    def estimate(self, ack: Dict) -> np.ndarray:
        delay = ack["avg_latency"] - ack["min_latency"]
        grad = np.where(np.isnan(self._prev_delay), 0.0,
                        delay - self._prev_delay)
        self._prev_delay = delay

        decrease = ((grad > self.overuse_thresh) | (ack["loss"] > 0.1)
                    | (delay > 0.3))
        hold = ~decrease & (grad < -self.overuse_thresh / 2)

        measured = np.maximum(ack["delivery_rate"], 1e4)
        app_limited = ack["app_limited"] > 0.5
        self._capacity = np.where(
            app_limited, self._capacity,
            0.7 * self._capacity + 0.3 * measured)
        dec_rate = np.where(app_limited,
                            np.minimum(self.rate, 1.2 * self._capacity),
                            self.beta * measured)
        inc_cap = np.where(app_limited, 2.0 * self._capacity + 1e5,
                           1.5 * measured + 1e5)
        inc_rate = np.minimum(self.rate * self.eta, inc_cap)
        rate = np.where(decrease, dec_rate,
                        np.where(hold, self.rate, inc_rate))
        self.rate = np.clip(rate, RATE_MIN, RATE_MAX)
        return self.rate


class BBRBank:
    GAIN_CYCLE = BBR.GAIN_CYCLE

    def __init__(self, m: int, init_rate: float = 1e6, window: int = 10):
        self.window = window
        self.init_rate = init_rate
        self._samples = np.full((window, m), -np.inf)
        self._samples[0] = init_rate
        self._count = 1
        self._phase = 0

    def reset_lane(self, i: int) -> None:
        """Forget lane i's bandwidth samples (churn slot revival).  The
        gain-cycle phase and sample counter are bank-global scalars by
        construction, so a revived lane rejoins the cycle mid-phase —
        only its btlbw window restarts cold."""
        self._samples[:, i] = -np.inf
        self._samples[(self._count - 1) % self.window, i] = self.init_rate

    def estimate(self, ack: Dict) -> np.ndarray:
        measured = np.maximum(ack["delivery_rate"], 1e4)
        btlbw_prev = self._samples.max(axis=0)
        measured = np.where(ack["app_limited"] > 0.5,
                            np.maximum(measured, btlbw_prev), measured)
        # ring append, keeping the last `window` samples
        self._samples[self._count % self.window] = measured
        self._count += 1
        btlbw = self._samples.max(axis=0)
        gain = self.GAIN_CYCLE[self._phase % len(self.GAIN_CYCLE)]
        self._phase += 1
        gain = np.where(ack["avg_latency"] - ack["min_latency"] > 0.25,
                        min(gain, 0.75), gain)
        return np.clip(btlbw * gain, RATE_MIN, RATE_MAX)


def make_cc_bank(kind: str, m: int):
    return {"gcc": GCCBank, "bbr": BBRBank}[kind](m)

"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from the JSON
artifacts in experiments/dryrun/."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List


def load_records(dirname: str) -> List[Dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def _gb(x):
    return "-" if x is None else f"{x / 2**30:.2f}"


def _fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def dryrun_table(recs: List[Dict]) -> str:
    lines = [
        "| arch | shape | mesh | chips | compile | args GB/dev | temp GB/dev "
        "| collectives (count) | coll GB/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        mem = r["memory_analysis"]
        coll = r["collectives"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['chips']} | "
            f"{r.get('compile_s', 0):.0f}s | {_gb(mem['argument_size_bytes'])} | "
            f"{_gb(mem['temp_size_bytes'])} | {coll['count']} | "
            f"{coll['total'] / 2**30:.3f} |")
    return "\n".join(lines)


def roofline_table(recs: List[Dict], mesh: str = "16x16") -> str:
    lines = [
        "| arch | shape | t_compute | t_memory | t_collective | bottleneck "
        "| MODEL/HLO flops | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["mesh"] != mesh:
            continue
        rf = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_s(rf['t_compute_s'])} | "
            f"{_fmt_s(rf['t_memory_s'])} | {_fmt_s(rf['t_collective_s'])} | "
            f"**{rf['bottleneck']}** | {rf['useful_flops_ratio']:.2f} | "
            f"{rf['roofline_fraction']:.3f} |")
    return "\n".join(lines)


def pick_hillclimb(recs: List[Dict]) -> Dict[str, Dict]:
    """Worst roofline fraction, most collective-bound, paper-representative."""
    single = [r for r in recs if r["mesh"] == "16x16"]
    worst = min(single, key=lambda r: r["roofline"]["roofline_fraction"])
    coll = max(single, key=lambda r: (r["roofline"]["t_collective_s"]
                                      / max(r["roofline"]["step_time_lb_s"], 1e-12)))
    paper = next((r for r in single
                  if r["arch"] == "qwen2-vl-72b" and r["shape"] == "decode_32k"),
                 single[0])
    return {"worst_fraction": worst, "most_collective_bound": coll,
            "paper_representative": paper}


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    recs = load_records(args.dir)
    print("## Dry-run (all cells)\n")
    print(dryrun_table(recs))
    print("\n## Roofline (single-pod)\n")
    print(roofline_table(recs))
    picks = pick_hillclimb(recs)
    print("\n## Hillclimb candidates\n")
    for k, r in picks.items():
        print(f"- **{k}**: {r['arch']} x {r['shape']} "
              f"(bottleneck {r['roofline']['bottleneck']}, "
              f"frac {r['roofline']['roofline_fraction']:.3f})")


if __name__ == "__main__":
    main()

"""Roofline-term derivation from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (TPU v5e constants):

    compute    = HLO_FLOPs_global / (chips * 197e12)
    memory     = HLO_bytes_global / (chips * 819e9)
    collective = collective_bytes_global / (chips * 50e9)

`cost_analysis()` yields per-device FLOPs/bytes of the SPMD module ->
multiply by chips for the global figures.  Collective bytes are not in
cost_analysis: we parse the post-SPMD HLO text and sum operand sizes of
all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute
(per-device, x chips for global — so the term reduces to
per_device_collective_bytes / 50 GB/s, i.e. every chip pushes its shard
through its ICI links).

MODEL_FLOPS uses the standard 6*N*D (train) / 2*N*D (inference) counting
with N = active parameter count; HLO/MODEL ratio flags remat and
redundant compute.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Iterable, Optional, Tuple

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
ICI_BW = 50e9            # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
    "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]+|pred)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes of every collective op in (post-SPMD) HLO text.

    Operand shapes print inline in HLO: `all-reduce(f32[8,128] %x)`; we sum
    every shape appearing in the operand list.  `*-start/-done` async pairs
    are counted once (on the -start op).  Returns bytes per collective kind
    (per-device).
    """
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*\S+\s+([\w\-]+)\(", ls)
        if not m:
            continue
        op = m.group(1)
        base = op.replace("-start", "")
        if base not in _COLLECTIVES or op.endswith("-done"):
            continue
        # operand segment: inside the call parens
        try:
            args = ls.split("(", 2)[2] if "= (" in ls.split(op)[0] else \
                ls[ls.index(op) + len(op):]
        except Exception:
            args = ls
        shapes = _SHAPE_RE.findall(args)
        nbytes = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        out[base] += nbytes
        out["count"] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_global: float
    hbm_bytes_global: float
    collective_bytes_per_device: float
    model_flops: float

    @property
    def t_compute(self) -> float:
        return self.flops_global / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes_global / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.collective_bytes_per_device / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Lower-bound step time: max of the three terms (perfect overlap)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / max(self.flops_global, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """MODEL_FLOPS-based MFU bound at the dominant-term step time."""
        return (self.model_flops / max(self.step_time, 1e-12)
                / (self.chips * PEAK_FLOPS))

    def row(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "flops_global": self.flops_global,
            "hbm_bytes_global": self.hbm_bytes_global,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "step_time_lb_s": self.step_time,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


# --------------------------------------------------------------------------
# Fleet rollout-step roofline (host CPU)
# --------------------------------------------------------------------------
# Single-core CPU envelope for the fleet benchmarks (the harness pins one
# core): ~2 FMA ports x 8 f32 lanes x 2 flops x ~3 GHz, and one core's
# share of memory bandwidth.  Coarse by design — the report's job is to
# say WHICH wall the compiled window step sits against and how far the
# measured wall time is from it, not to be a cycle model.
CPU_PEAK_FLOPS = 1.0e11   # flops/s, one core, f32 FMA
CPU_MEM_BW = 2.0e10       # bytes/s, one core

_HLO_OP_RE = re.compile(r"=\s*\S+\s+([\w\-]+)\(")


def _cost_dict(compiled) -> Dict[str, float]:
    """`compiled.cost_analysis()` result as one flat dict.

    JAX has returned a dict, a list-of-dicts (one per partition), and
    None for unsupported backends, depending on version — normalize all
    of them."""
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else None
    return dict(ca) if ca else {}


def hlo_op_histogram(hlo_text: str, top: int = 12) -> Dict[str, int]:
    """Instruction-kind counts of an HLO module text, largest first —
    the attribution trail for 'where did the step time go' (a wall of
    `while` means serial drain loops, `fusion` count tracks dispatch
    granularity, `custom-call` flags opaque kernels the cost model
    can't see into)."""
    counts: Dict[str, int] = {}
    for m in _HLO_OP_RE.finditer(hlo_text):
        op = m.group(1)
        counts[op] = counts.get(op, 0) + 1
    ranked = sorted(counts.items(), key=lambda kv: -kv[1])
    return dict(ranked[:top])


def fleet_step_report(lowered, compiled, *, n_sessions: int, window: int,
                      wall_time_s: Optional[float] = None,
                      host_replay_s: Optional[float] = None,
                      outfeed_bytes: Optional[float] = None,
                      peak_flops: float = CPU_PEAK_FLOPS,
                      mem_bw: float = CPU_MEM_BW) -> Dict:
    """Roofline report for one compiled rollout window step.

    Takes the `(lowered, compiled)` pair from `FleetRollout.aot()` and
    derives compute/memory lower bounds from XLA's own cost analysis,
    normalized per session-tick so sweeps across N and K compare
    directly.  `wall_time_s` (measured seconds per window dispatch, if
    available) turns the bounds into an attainment figure: how much of
    the remaining gap is NOT explained by the roofline — i.e. dispatch
    overhead, serial `while` drains, or cost-model-invisible
    custom-calls (see `hlo_ops`).

    `host_replay_s` (total host-side bookkeeping replay seconds) and
    `outfeed_bytes` (total scan-output bytes fetched per run) attribute
    the NON-device side of the rollout: the on-device server phase is
    justified exactly when these two columns collapse relative to the
    baseline mode, so benches record them per mode alongside the
    attainment figure."""
    cost = _cost_dict(compiled)
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    transcendentals = float(cost.get("transcendentals", 0.0))
    t_compute = flops / peak_flops
    t_memory = nbytes / mem_bw
    step_lb = max(t_compute, t_memory)
    ticks = max(n_sessions * window, 1)
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = lowered.as_text() if hasattr(lowered, "as_text") else ""
    report = {
        "n_sessions": n_sessions,
        "window": window,
        "flops": flops,
        "bytes_accessed": nbytes,
        "transcendentals": transcendentals,
        "arithmetic_intensity": flops / max(nbytes, 1.0),
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "bottleneck": "compute" if t_compute >= t_memory else "memory",
        "step_time_lb_s": step_lb,
        "per_session_tick_lb_us": step_lb / ticks * 1e6,
        "hlo_ops": hlo_op_histogram(hlo),
        "peak_flops": peak_flops,
        "mem_bw": mem_bw,
    }
    if wall_time_s is not None:
        report["wall_time_s"] = wall_time_s
        report["per_session_tick_wall_us"] = wall_time_s / ticks * 1e6
        report["roofline_attainment"] = step_lb / max(wall_time_s, 1e-12)
    if host_replay_s is not None:
        report["host_replay_s"] = host_replay_s
        report["host_replay_per_tick_us"] = host_replay_s / ticks * 1e6
    if outfeed_bytes is not None:
        report["outfeed_bytes"] = outfeed_bytes
        report["outfeed_bytes_per_tick"] = outfeed_bytes / ticks
    return report


def model_flops(cfg, shape_kind: str, tokens: int, n_params: int,
                n_active: Optional[int] = None) -> float:
    """6ND for train, 2ND for inference; N = active params for MoE."""
    n = n_active if n_active is not None else n_params
    factor = 6.0 if shape_kind == "train" else 2.0
    return factor * n * tokens


def active_params(cfg, n_params_total: int, n_params_experts: int) -> int:
    """MoE: total minus inactive expert weight share."""
    if cfg.moe is None:
        return n_params_total
    frac = cfg.moe.top_k / cfg.moe.num_experts
    return int(n_params_total - n_params_experts * (1.0 - frac))

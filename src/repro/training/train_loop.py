"""Train-step builder: loss -> grads (with microbatch accumulation and
optional gradient compression) -> optimizer update; plus the TrainState
pytree the checkpoint manager persists.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed import compression
from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.training import optimizer as opt_lib


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jnp.ndarray
    compress: Optional[compression.CompressionState]


@dataclasses.dataclass(frozen=True)
class TrainSettings:
    optimizer: str = "adamw"
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    weight_decay: float = 0.1
    max_grad_norm: float = 1.0
    moment_dtype: str = "float32"
    grad_accum: int = 1          # microbatches per step
    compress_grads: bool = False  # int8 error-feedback DP compression


def make_optimizer(s: TrainSettings):
    lr = opt_lib.warmup_cosine(s.peak_lr, s.warmup_steps, s.total_steps)
    if s.optimizer == "adamw":
        return opt_lib.AdamW(lr=lr, weight_decay=s.weight_decay,
                             max_grad_norm=s.max_grad_norm,
                             moment_dtype=s.moment_dtype)
    return opt_lib.Adafactor(lr=lr, max_grad_norm=s.max_grad_norm)


def init_state(key, cfg: ModelConfig, s: TrainSettings) -> TrainState:
    params = tfm.init(key, cfg)
    optimizer = make_optimizer(s)
    opt_state = optimizer.init(params)
    comp = compression.init_state(params) if s.compress_grads else None
    return TrainState(params=params, opt_state=opt_state,
                      step=jnp.zeros((), jnp.int32), compress=comp)


def make_train_step(cfg: ModelConfig, s: TrainSettings
                    ) -> Callable[[TrainState, Dict], Tuple[TrainState, Dict]]:
    """Returns train_step(state, batch) -> (state, metrics).

    With grad_accum > 1 the batch's leading axis is split into microbatches
    scanned sequentially (activation memory / accum tradeoff); gradients
    average across microbatches.
    """
    optimizer = make_optimizer(s)
    grad_fn = jax.value_and_grad(tfm.loss_fn, has_aux=True)

    def one_microbatch(params, mb):
        (loss, metrics), grads = grad_fn(params, mb, cfg)
        return loss, metrics, grads

    def train_step(state: TrainState, batch: Dict) -> Tuple[TrainState, Dict]:
        if s.grad_accum > 1:
            def split(path, x):
                # mrope position ids are (3, B, S): batch axis is 1
                axis = 1 if "mrope" in jax.tree_util.keystr(path) else 0
                b = x.shape[axis]
                shape = (x.shape[:axis] + (s.grad_accum, b // s.grad_accum)
                         + x.shape[axis + 1:])
                return jnp.moveaxis(x.reshape(shape), axis, 0)

            mbs = jax.tree_util.tree_map_with_path(split, batch)

            def accum(carry, mb):
                g_acc, loss_acc = carry
                loss, _, grads = one_microbatch(state.params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, grads)
                return (g_acc, loss_acc + loss), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              state.params)
            (grads, loss_sum), _ = jax.lax.scan(accum, (g0, jnp.float32(0)), mbs)
            grads = jax.tree.map(lambda g: g / s.grad_accum, grads)
            loss = loss_sum / s.grad_accum
            metrics = {"loss": loss}
        else:
            loss, metrics, grads = one_microbatch(state.params, batch)

        comp_state = state.compress
        if s.compress_grads:
            grads, comp_state, cm = compression.compress_grads(grads, comp_state)
            metrics.update(cm)

        params, opt_state, om = optimizer.update(grads, state.opt_state,
                                                 state.params)
        metrics.update(om)
        new_state = TrainState(params=params, opt_state=opt_state,
                               step=state.step + 1, compress=comp_state)
        return new_state, metrics

    return train_step

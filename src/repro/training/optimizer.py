"""Optimizers from scratch (no optax): AdamW and Adafactor, with global-norm
clipping and warmup+cosine schedules.

Optimizer state mirrors the parameter pytree, so whatever sharding the
params carry is inherited by the moments (ZeRO-3-equivalent under our 2-D
FSDPxTP layout).  `moment_dtype` lets big-dense configs keep Adam moments
in bf16 to fit HBM (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------
# Schedules
# --------------------------------------------------------------------------
def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
        t = (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1)
        t = jnp.clip(t, 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup_steps, warm, peak_lr * cos)

    return lr


def constant(lr_value: float) -> Callable:
    return lambda step: jnp.asarray(lr_value, jnp.float32)


# --------------------------------------------------------------------------
# Global-norm clipping
# --------------------------------------------------------------------------
def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


# --------------------------------------------------------------------------
# AdamW
# --------------------------------------------------------------------------
class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    max_grad_norm: Optional[float] = 1.0
    moment_dtype: str = "float32"

    def init(self, params) -> AdamWState:
        dt = jnp.dtype(self.moment_dtype)
        zeros = lambda p: jnp.zeros_like(p, dtype=dt)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          m=jax.tree.map(zeros, params),
                          v=jax.tree.map(zeros, params))

    def update(self, grads, state: AdamWState, params
               ) -> Tuple[Any, AdamWState, dict]:
        gnorm = jnp.asarray(0.0)
        if self.max_grad_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, self.max_grad_norm)
        step = state.step + 1
        lr = self.lr(step)
        b1, b2 = self.b1, self.b2
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        dt = jnp.dtype(self.moment_dtype)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
            mhat = m32 / bc1
            vhat = v32 / bc2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if p.ndim >= 2:  # decoupled decay on matrices only
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
            return new_p, m32.astype(dt), v32.astype(dt)

        out = jax.tree.map(upd, grads, state.m, state.v, params)
        new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
        return new_p, AdamWState(step, new_m, new_v), {
            "lr": lr, "grad_norm": gnorm}


# --------------------------------------------------------------------------
# Adafactor (factored second moment -> O(n+m) state for (n,m) weights)
# --------------------------------------------------------------------------
class AdafactorState(NamedTuple):
    step: jnp.ndarray
    vr: Any   # row second-moment (or full for <2D)
    vc: Any   # col second-moment (or None sentinel)


@dataclasses.dataclass(frozen=True)
class Adafactor:
    lr: Callable
    decay: float = 0.8
    eps: float = 1e-30
    clip_threshold: float = 1.0
    max_grad_norm: Optional[float] = None

    def init(self, params) -> AdafactorState:
        def vr(p):
            if p.ndim >= 2:
                return jnp.zeros(p.shape[:-1], jnp.float32)
            return jnp.zeros_like(p, dtype=jnp.float32)

        def vc(p):
            if p.ndim >= 2:
                return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            return jnp.zeros((1,), jnp.float32)

        return AdafactorState(step=jnp.zeros((), jnp.int32),
                              vr=jax.tree.map(vr, params),
                              vc=jax.tree.map(vc, params))

    def update(self, grads, state: AdafactorState, params):
        gnorm = jnp.asarray(0.0)
        if self.max_grad_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, self.max_grad_norm)
        step = state.step + 1
        lr = self.lr(step)
        beta = 1.0 - (step.astype(jnp.float32)) ** (-self.decay)

        def upd(g, vr, vc, p):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + self.eps
            if p.ndim >= 2:
                vr_n = beta * vr + (1 - beta) * jnp.mean(g2, axis=-1)
                vc_n = beta * vc + (1 - beta) * jnp.mean(g2, axis=-2)
                r = vr_n / jnp.maximum(
                    jnp.mean(vr_n, axis=-1, keepdims=True), self.eps)
                u = g32 / (jnp.sqrt(r)[..., None] * jnp.sqrt(vc_n)[..., None, :]
                           + self.eps)
            else:
                vr_n = beta * vr + (1 - beta) * g2
                vc_n = vc
                u = g32 / (jnp.sqrt(vr_n) + self.eps)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + self.eps)
            u = u / jnp.maximum(1.0, rms / self.clip_threshold)
            new_p = (p.astype(jnp.float32) - lr * u).astype(p.dtype)
            return new_p, vr_n, vc_n

        out = jax.tree.map(upd, grads, state.vr, state.vc, params)
        pick = lambda i: jax.tree.map(lambda t: t[i], out,
                                      is_leaf=lambda t: isinstance(t, tuple))
        return pick(0), AdafactorState(step, pick(1), pick(2)), {
            "lr": lr, "grad_norm": gnorm}


def make_optimizer(kind: str, lr_fn: Callable, **kw):
    if kind == "adamw":
        return AdamW(lr=lr_fn, **kw)
    if kind == "adafactor":
        return Adafactor(lr=lr_fn, **kw)
    raise ValueError(kind)

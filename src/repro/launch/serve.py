"""Serving launcher: continuous-batching engine over any registry arch.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b \
        --reduced --requests 8 --max-new 16

Random-weight serving demo on CPU (--reduced); on TPU, pair with the
checkpoint manager to load trained weights and set
``--attention pallas`` for the fused kernels.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import registry
from repro.models import transformer as tfm
from repro.models.config import reduced
from repro.serving.engine import Engine, Request
from repro.serving.sampler import SamplerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="artic-assistant",
                    choices=registry.list_archs(include_extra=True))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--step-dt", type=float, default=0.01,
                    help="simulated seconds charged per engine step "
                         "(the engine clock is simulated, not wall time)")
    ap.add_argument("--attention", default="reference",
                    choices=["reference", "pallas"])
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = registry.get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg, dtype="float32", param_dtype="float32")
    cfg = cfg.replace(attention_impl=args.attention)
    if cfg.family in ("ssm", "hybrid") or cfg.num_codebooks > 1:
        raise SystemExit(
            f"{cfg.name}: engine text-serving demo supports dense/moe "
            "backbones; ssm/hybrid/audio decode is exercised in tests")

    params = tfm.init(jax.random.PRNGKey(0), cfg)
    if args.ckpt_dir:
        from repro.checkpoint.manager import CheckpointManager
        params, _ = CheckpointManager(args.ckpt_dir).restore(
            jax.eval_shape(lambda: params))
    eng = Engine(cfg, params, max_batch=args.max_batch, max_len=args.max_len,
                 sampler=SamplerConfig(temperature=args.temperature),
                 step_dt=args.step_dt)

    rng = np.random.default_rng(0)
    t_wall = time.time()
    for i in range(args.requests):
        eng.submit(Request(
            uid=i, tokens=rng.integers(0, cfg.vocab, 16, dtype=np.int32),
            max_new_tokens=args.max_new), now=0.0)
    done = eng.run_until_drained()
    dt = time.time() - t_wall
    # latency/TTFT are simulated time (engine clock: step_dt per step);
    # throughput is wall time — the two axes are deliberately separate
    lat = [r.done_time - r.arrival for r in done if r.done_time is not None]
    ttft = [r.ttft for r in done if r.ttft is not None]
    st = eng.stats
    print(f"arch={cfg.name} served={len(done)} tokens={st.tokens_out} "
          f"ticks={st.steps} wall={dt:.1f}s "
          f"throughput={st.tokens_out / dt:.1f} tok/s")
    print(f"simulated: p50_latency={np.median(lat):.3f}s "
          f"ttft_p50={np.median(ttft):.3f}s "
          f"ttft_p95={np.percentile(ttft, 95):.3f}s "
          f"slot_util={st.slot_utilization:.2f} "
          f"kv_peak_util={st.kv_peak_utilization:.2f}")


if __name__ == "__main__":
    main()

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this entrypoint:
  1. builds the production mesh (single-pod 16x16 / multi-pod 2x16x16),
  2. resolves full sharding trees (params, optimizer state, batch, caches)
     through the logical-axis rules,
  3. jit-lowers the real entry point (train_step / prefill / decode_step)
     against ShapeDtypeStruct inputs — no allocation,
  4. compiles, then records memory_analysis(), cost_analysis() and the
     per-device collective bytes parsed from the post-SPMD HLO,
  5. writes one JSON per cell into experiments/dryrun/.

Run one cell:   python -m repro.launch.dryrun --arch qwen3-0.6b \
                    --shape train_4k --mesh single
Run the sweep:  python -m repro.launch.dryrun --all   (subprocess per cell
                for isolation; a failing cell doesn't kill the sweep)

NOTE the XLA_FLAGS assignment above MUST precede any jax import — jax
locks the device count on first init.  Only this entrypoint sees 512
host devices; tests and benchmarks see 1.
"""
import argparse
import json
import subprocess
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry, shapes as shape_lib
from repro.distributed import sharding as shlib
from repro.launch.mesh import make_production_mesh, use_mesh
from repro.models import transformer as tfm
from repro.roofline import analysis as roofline
from repro.training.optimizer import AdafactorState, AdamWState
from repro.training.train_loop import TrainSettings, TrainState, make_train_step

OUT_DIR = os.path.join(os.path.dirname(__file__), "../../../experiments/dryrun")

# Per-arch production training settings (memory-fit choices, DESIGN.md §4):
# big-dense archs use Adafactor + deep grad-accum + full remat + Megatron-
# style sequence-parallel residuals; small archs use AdamW.
TRAIN_SETTINGS: Dict[str, TrainSettings] = {
    "llama3-405b": TrainSettings(optimizer="adafactor", grad_accum=16),
    "mistral-large-123b": TrainSettings(optimizer="adafactor", grad_accum=8),
    "qwen2-vl-72b": TrainSettings(optimizer="adafactor", grad_accum=8),
    "dbrx-132b": TrainSettings(optimizer="adafactor", grad_accum=8),
    "qwen3-moe-30b-a3b": TrainSettings(optimizer="adamw", moment_dtype="bfloat16",
                                       grad_accum=4),
    "deepseek-7b": TrainSettings(optimizer="adamw", moment_dtype="bfloat16"),
    "recurrentgemma-9b": TrainSettings(optimizer="adamw", moment_dtype="bfloat16",
                                       grad_accum=4),
}
DEFAULT_SETTINGS = TrainSettings(optimizer="adamw")

# sequence-parallel residual sharding for the memory-pressed archs
SP_ARCHS = {"llama3-405b", "mistral-large-123b", "qwen2-vl-72b", "dbrx-132b"}


def sp_rules():
    r = dict(shlib.DEFAULT_RULES)
    r["act_seq"] = ("model",)
    return r


# --------------------------------------------------------------------------
# Sharding-tree construction
# --------------------------------------------------------------------------
def opt_state_axes(settings: TrainSettings, p_axes, p_shapes):
    if settings.optimizer == "adamw":
        return AdamWState(step=(), m=p_axes, v=p_axes)

    def vr_axes(a, s):
        return tuple(a[:-1]) if len(s.shape) >= 2 else tuple(a)

    def vc_axes(a, s):
        return tuple(a[:-2]) + tuple(a[-1:]) if len(s.shape) >= 2 else (None,)

    return AdafactorState(
        step=(),
        vr=jax.tree.map(vr_axes, p_axes, p_shapes, is_leaf=shlib.is_axes_leaf),
        vc=jax.tree.map(vc_axes, p_axes, p_shapes, is_leaf=shlib.is_axes_leaf),
    )


def batch_axes_of(batch_specs):
    def one(path, leaf):
        name = jax.tree_util.keystr(path)
        if "mrope" in name:
            return (None, "batch") + (None,) * (len(leaf.shape) - 2)
        return ("batch",) + (None,) * (len(leaf.shape) - 1)

    return jax.tree_util.tree_map_with_path(one, batch_specs)


def cache_axes_of(cfg, cache_specs, mesh):
    """Cache logical axes with the seq-dim fallback: if kv_heads doesn't
    divide the model axis, shard the KV sequence dim over `model` instead
    (flash-decoding style distributed softmax)."""
    base = tfm.cache_axes(cfg)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model_n = sizes.get("model", 1)

    def fix(axes, leaf):
        axes = tuple(axes)
        if ("kv_heads" in axes and cfg.n_kv_heads % model_n != 0
                and len(leaf.shape) == 5 and leaf.shape[2] % model_n == 0):
            lst = list(axes)
            lst[2] = "kv_seq"
            return tuple(lst)
        return axes

    return jax.tree.map(fix, base, cache_specs, is_leaf=shlib.is_axes_leaf)


def rules_for(arch: str, extra: Optional[Dict] = None):
    r = sp_rules() if arch in SP_ARCHS else dict(shlib.DEFAULT_RULES)
    r["kv_seq"] = ("model",)
    if extra:
        r.update(extra)
    return r


# --------------------------------------------------------------------------
# Cell lowering
# --------------------------------------------------------------------------
# --------------------------------------------------------------------------
# §Perf hillclimb variants: (rules_extra, cfg_transform, settings_transform)
# --------------------------------------------------------------------------
import dataclasses as _dc


def _v_serve_replicated(cfg, settings):
    """Decode: replicate the FSDP dims -> stationary weights, no per-token
    weight all-gather (classic TP-only serving layout)."""
    return ({"embed": (None,), "expert_embed": (None,)}, cfg, settings)


def _v_moe_gather(cfg, settings):
    """MoE: scatter/gather dispatch instead of one-hot einsums."""
    return ({}, cfg.replace(moe=_dc.replace(cfg.moe, moe_impl="gather")),
            settings)


def _v_accum2(cfg, settings):
    """Fewer grad-accum microbatches -> fewer FSDP weight re-gathers."""
    return ({}, cfg, _dc.replace(settings, grad_accum=2))


def _v_expert_replicated(cfg, settings):
    """Keep expert weights expert-sharded but FSDP-replicated (stationary)."""
    return ({"expert_embed": (None,)}, cfg, settings)


def _v_moe_gather_expert_repl(cfg, settings):
    rules, cfg, settings = _v_moe_gather(cfg, settings)
    rules.update({"expert_embed": (None,)})
    return rules, cfg, settings


def _v_moe_bf16_cap1(cfg, settings):
    """H1 combined: bf16 dispatch one-hots + capacity 1.0 + stationary
    expert weights — targets the dominant MoE dispatch collectives."""
    return ({"expert_embed": (None,)},
            cfg.replace(moe=_dc.replace(cfg.moe, dispatch_fp32=False,
                                        capacity_factor=1.0)),
            settings)


def _v_moe_full_opt(cfg, settings):
    """H1 iteration 4: bf16 dispatch + cap 1.0 + stationary experts +
    dots-saveable remat (combine the confirmed levers)."""
    return ({"expert_embed": (None,)},
            cfg.replace(remat_policy="dots",
                        moe=_dc.replace(cfg.moe, dispatch_fp32=False,
                                        capacity_factor=1.0)),
            settings)


def _v_cap1(cfg, settings):
    """Capacity factor 1.25 -> 1.0 (smaller dispatch buffers)."""
    return ({}, cfg.replace(moe=_dc.replace(cfg.moe, capacity_factor=1.0)),
            settings)


def _v_remat_dots(cfg, settings):
    """full remat -> dots-saveable (less recompute, more memory)."""
    return ({}, cfg.replace(remat_policy="dots"), settings)


def _v_expert_repl_accum2(cfg, settings):
    """H1 combined: stationary expert weights + half the microbatches."""
    return ({"expert_embed": (None,)}, cfg,
            _dc.replace(settings, grad_accum=2))


def _v_serve_repl_kvint8(cfg, settings):
    """Serving: stationary weights + int8-quantized KV cache."""
    return ({"embed": (None,), "expert_embed": (None,)},
            cfg.replace(kv_cache_dtype="int8"), settings)


VARIANTS = {
    "serve_replicated": _v_serve_replicated,
    "expert_repl_accum2": _v_expert_repl_accum2,
    "serve_repl_kvint8": _v_serve_repl_kvint8,
    "moe_gather": _v_moe_gather,
    "accum2": _v_accum2,
    "expert_replicated": _v_expert_replicated,
    "moe_gather_expert_repl": _v_moe_gather_expert_repl,
    "cap1": _v_cap1,
    "moe_bf16_cap1": _v_moe_bf16_cap1,
    "moe_full_opt": _v_moe_full_opt,
    "remat_dots": _v_remat_dots,
}


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               rules_extra: Optional[Dict] = None,
               cfg_override=None, settings_override=None,
               variant: Optional[str] = None):
    cfg = cfg_override or registry.get_config(arch)
    spec = shape_lib.SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    settings = settings_override or TRAIN_SETTINGS.get(arch, DEFAULT_SETTINGS)
    if variant is not None:
        v_rules, cfg, settings = VARIANTS[variant](cfg, settings)
        rules_extra = {**(rules_extra or {}), **v_rules}
    rules = rules_for(arch, rules_extra)

    specs = shape_lib.input_specs(cfg, shape_name)
    batch_specs = specs["batch"]
    b_sh = shlib.make_shardings(batch_axes_of(batch_specs), batch_specs,
                                mesh, rules)

    p_specs = jax.eval_shape(lambda: tfm.init(jax.random.PRNGKey(0), cfg))
    p_axes = tfm.axes(cfg)
    p_sh = shlib.make_shardings(p_axes, p_specs, mesh, rules)

    with shlib.rules_context(rules), use_mesh(mesh):
        if spec.kind == "train":
            from repro.training.train_loop import init_state
            train_step = make_train_step(cfg, settings)
            state_specs = jax.eval_shape(
                lambda: init_state(jax.random.PRNGKey(0), cfg, settings))
            s_axes = TrainState(
                params=p_axes,
                opt_state=opt_state_axes(settings, p_axes, p_specs),
                step=(), compress=None)
            s_sh = shlib.make_shardings(s_axes, state_specs, mesh, rules)
            lowered = jax.jit(
                train_step,
                in_shardings=(s_sh, b_sh),
                out_shardings=(s_sh, None),
            ).lower(state_specs, batch_specs)
        elif spec.kind == "prefill":
            def fn(params, batch):
                return tfm.prefill(params, batch, cfg)

            out_specs = jax.eval_shape(fn, p_specs, batch_specs)
            c_axes = cache_axes_of(cfg, out_specs[1], mesh)
            c_sh = shlib.make_shardings(c_axes, out_specs[1], mesh, rules)
            lowered = jax.jit(
                fn, in_shardings=(p_sh, b_sh),
                out_shardings=(None, c_sh),
            ).lower(p_specs, batch_specs)
        else:  # decode
            cache_specs = specs["cache"]
            c_axes = cache_axes_of(cfg, cache_specs, mesh)
            c_sh = shlib.make_shardings(c_axes, cache_specs, mesh, rules)

            def fn(params, cache, batch):
                return tfm.decode_step(params, cache, batch, cfg)

            lowered = jax.jit(
                fn, in_shardings=(p_sh, c_sh, b_sh),
                out_shardings=(None, c_sh),
            ).lower(p_specs, cache_specs, batch_specs)
    return lowered, mesh, cfg, settings


# --------------------------------------------------------------------------
# Scan-aware roofline probes
# --------------------------------------------------------------------------
# XLA's HLO cost analysis counts a while-loop body ONCE, not x trip-count,
# so the full-depth artifact underreports FLOPs/bytes/collectives of the
# scanned layer stack.  We therefore lower two shallow UNROLLED probes at
# depths (a, b) with grad_accum=1, fit v(L) = outer + L * per_layer, and
# extrapolate to the real depth.  memory_analysis comes from the full-depth
# compile (scan reuses buffers, so it is already correct there).
def probe_depths(cfg):
    if cfg.family == "hybrid":
        g = len(cfg.rglru.pattern)
        return g, 2 * g           # whole groups only; tail approximated
    return 2, 4


def cost_analysis_dict(compiled) -> Dict[str, float]:
    """Version-compat: `Compiled.cost_analysis()` returns a dict on new
    JAX but a one-element list of dicts on older releases."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def measure(lowered_compiled):
    compiled = lowered_compiled
    cost = cost_analysis_dict(compiled)
    coll = roofline.collective_bytes_from_hlo(compiled.as_text())
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)),
            float(coll["total"]))


def probe_corrected(arch, shape_name, multi_pod, rules_extra=None,
                    variant=None):
    cfg = registry.get_config(arch)
    settings0 = TRAIN_SETTINGS.get(arch, DEFAULT_SETTINGS)
    if variant is not None:
        v_rules, cfg, settings0 = VARIANTS[variant](cfg, settings0)
        rules_extra = {**(rules_extra or {}), **v_rules}
    a, b = probe_depths(cfg)
    vals = {}
    for depth in (a, b):
        pc = cfg.replace(n_layers=depth, scan_unroll=True)
        settings = dataclasses_replace_accum1(settings0)
        low, mesh, _, _ = lower_cell(arch, shape_name, multi_pod,
                                     rules_extra, cfg_override=pc,
                                     settings_override=settings)
        vals[depth] = measure(low.compile())
    per_layer = tuple((vb - va) / (b - a) for va, vb in zip(vals[a], vals[b]))
    outer = tuple(va - a * pl for va, pl in zip(vals[a], per_layer))
    L = cfg.n_layers
    corrected = tuple(o + L * pl for o, pl in zip(outer, per_layer))
    return {
        "probe_depths": [a, b],
        "per_layer": {"flops": per_layer[0], "bytes": per_layer[1],
                      "collective_bytes": per_layer[2]},
        "outer": {"flops": outer[0], "bytes": outer[1],
                  "collective_bytes": outer[2]},
        "corrected": {"flops": corrected[0], "bytes": corrected[1],
                      "collective_bytes": corrected[2]},
        "hybrid_tail_approx": cfg.family == "hybrid" and cfg.n_layers % len(
            cfg.rglru.pattern) != 0,
    }


def dataclasses_replace_accum1(settings):
    import dataclasses
    return dataclasses.replace(settings, grad_accum=1)


# --------------------------------------------------------------------------
# Metric collection
# --------------------------------------------------------------------------
def param_counts(cfg) -> Dict[str, int]:
    p_specs = jax.eval_shape(lambda: tfm.init(jax.random.PRNGKey(0), cfg))
    flat, _ = jax.tree_util.tree_flatten_with_path(p_specs)
    total = sum(int(np.prod(l.shape)) for _, l in flat)
    expert = sum(int(np.prod(l.shape)) for path, l in flat
                 if any(k in jax.tree_util.keystr(path)
                        for k in ("moe']['wi", "moe']['wg", "moe']['wo")))
    return {"total": total, "experts": expert}


def collect(lowered, compiled, mesh, cfg, shape_name: str,
            probe: Optional[Dict] = None) -> Dict[str, Any]:
    spec = shape_lib.SHAPES[shape_name]
    chips = int(np.prod(mesh.devices.shape))
    cost = cost_analysis_dict(compiled)
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    coll = roofline.collective_bytes_from_hlo(hlo)

    counts = param_counts(cfg)
    n_active = roofline.active_params(cfg, counts["total"], counts["experts"])
    if spec.kind == "train":
        tokens = spec.batch * spec.seq
    elif spec.kind == "prefill":
        tokens = spec.batch * spec.seq
    else:
        tokens = spec.batch  # one token per sequence
    mf = roofline.model_flops(cfg, spec.kind, tokens, counts["total"],
                              n_active)

    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    coll_dev = float(coll["total"])
    if probe is not None:  # scan-corrected per-device totals
        flops_dev = probe["corrected"]["flops"]
        bytes_dev = probe["corrected"]["bytes"]
        coll_dev = probe["corrected"]["collective_bytes"]
    terms = roofline.RooflineTerms(
        arch=cfg.name, shape=shape_name,
        mesh="x".join(map(str, mesh.devices.shape)),
        chips=chips,
        flops_global=flops_dev * chips,
        hbm_bytes_global=bytes_dev * chips,
        collective_bytes_per_device=coll_dev,
        model_flops=mf,
    )

    def _mem_attr(name):
        try:
            return int(getattr(mem, name))
        except Exception:
            return None

    return {
        "arch": cfg.name, "shape": shape_name,
        "mesh": "x".join(map(str, mesh.devices.shape)), "chips": chips,
        "params_total": counts["total"], "params_active": n_active,
        "cost_analysis": {k: float(v) for k, v in cost.items()
                          if isinstance(v, (int, float))},
        "memory_analysis": {
            "argument_size_bytes": _mem_attr("argument_size_in_bytes"),
            "output_size_bytes": _mem_attr("output_size_in_bytes"),
            "temp_size_bytes": _mem_attr("temp_size_in_bytes"),
            "generated_code_size_bytes": _mem_attr("generated_code_size_in_bytes"),
        },
        "collectives": coll,
        "probe": probe,
        "roofline": terms.row(),
    }


# --------------------------------------------------------------------------
# CLI
# --------------------------------------------------------------------------
def cells(include_multi: bool = True):
    for arch in registry.list_archs():
        cfg = registry.get_config(arch)
        for shape_name in shape_lib.SHAPES:
            ok, _ = shape_lib.supported(cfg, shape_name)
            if not ok:
                continue
            yield arch, shape_name, False
            if include_multi:
                yield arch, shape_name, True


def run_one(arch: str, shape_name: str, mesh_kind: str, out_path: str,
            rules_extra: Optional[Dict] = None, with_probe: bool = True,
            variant: Optional[str] = None) -> Dict:
    t0 = time.time()
    multi = mesh_kind == "multi"
    lowered, mesh, cfg, settings = lower_cell(arch, shape_name, multi,
                                              rules_extra, variant=variant)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    print(compiled.memory_analysis())
    print({k: v for k, v in cost_analysis_dict(compiled).items()
           if k in ("flops", "bytes accessed")})
    probe = None
    if with_probe:
        try:
            probe = probe_corrected(arch, shape_name, multi, rules_extra,
                                    variant)
        except Exception as e:  # record the artifact even if probes fail
            print(f"[probe-fail] {e}")
    rec = collect(lowered, compiled, mesh, cfg, shape_name, probe)
    rec["lower_s"] = t_lower
    rec["compile_s"] = t_compile
    rec["optimizer"] = settings.optimizer
    rec["grad_accum"] = settings.grad_accum
    rec["variant"] = variant
    if out_path:
        os.makedirs(os.path.dirname(out_path), exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--out-dir", default=os.path.abspath(OUT_DIR))
    ap.add_argument("--timeout", type=int, default=2700)
    ap.add_argument("--no-probe", action="store_true",
                    help="skip roofline probes (multi-pod compile proof only)")
    ap.add_argument("--variant", choices=sorted(VARIANTS),
                    help="§Perf hillclimb variant")
    args = ap.parse_args()

    if args.all:
        os.makedirs(args.out_dir, exist_ok=True)
        failures = []
        for arch, shape_name, multi in cells(not args.single_pod_only):
            mk = "multi" if multi else "single"
            out = os.path.join(args.out_dir, f"{arch}__{shape_name}__{mk}.json")
            if os.path.exists(out):
                print(f"[skip] {arch} {shape_name} {mk}")
                continue
            print(f"[cell] {arch} {shape_name} {mk}", flush=True)
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape_name, "--mesh", mk,
                   "--out-dir", args.out_dir]
            if mk == "multi":  # roofline table is single-pod only
                cmd.append("--no-probe")
            r = subprocess.run(
                cmd,
                timeout=args.timeout, capture_output=True, text=True,
                env={**os.environ, "PYTHONPATH": "src"})
            if r.returncode != 0:
                failures.append((arch, shape_name, mk))
                print(f"[FAIL] {arch} {shape_name} {mk}\n{r.stderr[-2000:]}")
        print(f"done; {len(failures)} failures: {failures}")
        sys.exit(1 if failures else 0)

    suffix = f"__{args.variant}" if args.variant else ""
    out = os.path.join(args.out_dir,
                       f"{args.arch}__{args.shape}__{args.mesh}{suffix}.json")
    rec = run_one(args.arch, args.shape, args.mesh, out,
                  with_probe=not args.no_probe, variant=args.variant)
    print(json.dumps(rec["roofline"], indent=1))


if __name__ == "__main__":
    main()

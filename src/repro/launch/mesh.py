"""Production mesh definitions.

Defined as functions (never module-level constants) so importing this
module touches no jax device state.  The single-pod mesh is a v5e pod
(16x16 = 256 chips); multi-pod adds a leading `pod` axis (2 pods = 512
chips) that carries only data-parallel traffic (DCN-friendly — see
DESIGN.md §4).
"""
from __future__ import annotations

import numpy as np

import jax


def use_mesh(mesh):
    """Version-compat mesh context: `jax.set_mesh` (new), falling back
    to `jax.sharding.use_mesh`, falling back to entering the Mesh itself
    (a context manager on every JAX we support).  Use as
    `with use_mesh(mesh): ...` wherever the current mesh must be set."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} — the "
            "dry-run entrypoint must set XLA_FLAGS="
            "--xla_force_host_platform_device_count=512 before importing jax")
    return jax.sharding.Mesh(
        np.asarray(devices[:n]).reshape(shape), axes)


def make_fleet_mesh(n_devices=None):
    """1-D `data` mesh over host devices for sharded fleet execution.

    The fleet engine shards every per-session array over this axis
    (repro.core.fleet.Fleet(mesh=...)); n_devices defaults to all
    visible devices.  On CPU, virtual devices come from
    XLA_FLAGS=--xla_force_host_platform_device_count=N set before jax
    is imported (the recipe tests/test_sharded_fleet.py uses)."""
    devices = jax.devices()
    n = len(devices) if n_devices is None else int(n_devices)
    if len(devices) < n:
        raise RuntimeError(f"fleet mesh needs {n} devices, have "
                           f"{len(devices)}")
    return jax.sharding.Mesh(np.asarray(devices[:n]), ("data",))


def make_debug_mesh(shape=(2, 2), axes=("data", "model")):
    """Tiny mesh for CPU tests (requires >=4 host devices)."""
    n = int(np.prod(shape))
    return jax.sharding.Mesh(
        np.asarray(jax.devices()[:n]).reshape(shape), axes)

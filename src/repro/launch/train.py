"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
        --steps 100 --batch 8 --seq 128 --reduced --ckpt-dir /tmp/ck

Wires together every substrate: config registry -> sharded init on the
available mesh -> deterministic data pipeline with prefetch -> jitted
train step (grad accum / compression per settings) -> checkpoint manager
with SIGTERM preemption flush and exact resume.  On a real TPU fleet the
same entrypoint runs under `jax.distributed.initialize()`; on CPU use
--reduced for a smoke-scale run.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import (CheckpointManager,
                                      register_preemption_handler)
from repro.configs import registry
from repro.data.pipeline import DataConfig, Prefetcher, TokenPipeline
from repro.models.config import reduced
from repro.training.train_loop import TrainSettings, init_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b",
                    choices=registry.list_archs(include_extra=True))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "adafactor"])
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = registry.get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg, dtype="float32", param_dtype="float32")
    settings = TrainSettings(
        optimizer=args.optimizer, peak_lr=args.lr,
        warmup_steps=max(args.steps // 10, 1), total_steps=args.steps,
        grad_accum=args.grad_accum, compress_grads=args.compress_grads)

    pipe = TokenPipeline(DataConfig(
        vocab=cfg.vocab, batch=args.batch, seq=args.seq,
        num_codebooks=cfg.num_codebooks,
        kind="vlm" if cfg.mrope_sections else "lm"))
    step_fn = jax.jit(make_train_step(cfg, settings), donate_argnums=0)

    start_step = 0
    state = init_state(jax.random.PRNGKey(0), cfg, settings)
    mgr = None
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir)
        if mgr.latest_step() is not None:
            state, extra = mgr.restore(jax.eval_shape(lambda: state))
            start_step = extra.get("data_step", mgr.latest_step())
            print(f"resumed from step {start_step}")
        cur = {"step": start_step}
        register_preemption_handler(
            lambda: mgr.save(cur["step"], state, extra=pipe.cursor(cur["step"])))

    n_params = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"arch={cfg.name} params={n_params / 1e6:.1f}M "
          f"devices={jax.device_count()} settings={settings}")

    pf = Prefetcher(pipe.iterate(start_step), depth=2,
                    put_fn=lambda b: jax.tree.map(jnp.asarray, b))
    t0 = time.time()
    for step in range(start_step, args.steps):
        state, metrics = step_fn(state, next(pf))
        if mgr:
            cur["step"] = step + 1
        if step % args.log_every == 0:
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics.get('grad_norm', 0)):.2f} "
                  f"lr {float(metrics['lr']):.2e}")
        if mgr and (step + 1) % args.ckpt_every == 0:
            mgr.save(step + 1, state, extra=pipe.cursor(step + 1))
    pf.stop()
    dt = time.time() - t0
    tok = (args.steps - start_step) * args.batch * args.seq
    print(f"done in {dt:.0f}s ({tok / max(dt, 1e-9):.0f} tok/s)")
    if mgr:
        mgr.save(args.steps, state, extra=pipe.cursor(args.steps))


if __name__ == "__main__":
    main()

"""Token samplers: greedy / temperature / top-k / top-p, plus logprob and
entropy telemetry (the Artic confidence head consumes these)."""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 0.0   # 0 => greedy
    top_k: Optional[int] = None
    top_p: Optional[float] = None


class SampleOut(NamedTuple):
    token: jnp.ndarray      # (B,) int32
    logprob: jnp.ndarray    # (B,) chosen-token logprob
    entropy: jnp.ndarray    # (B,) full-distribution entropy (nats)
    top1_prob: jnp.ndarray  # (B,) max prob


def sample(key, logits: jnp.ndarray, sc: SamplerConfig) -> SampleOut:
    """logits (B, V) -> sampled tokens + confidence telemetry."""
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    p = jnp.exp(logp)
    entropy = -jnp.sum(p * logp, axis=-1)
    top1 = jnp.max(p, axis=-1)

    if sc.temperature <= 0.0:
        tok = jnp.argmax(logits, axis=-1)
    else:
        z = logits / sc.temperature
        if sc.top_k is not None:
            kth = jnp.sort(z, axis=-1)[:, -sc.top_k][:, None]
            z = jnp.where(z < kth, -jnp.inf, z)
        if sc.top_p is not None:
            srt = jnp.sort(z, axis=-1)[:, ::-1]
            cdf = jnp.cumsum(jax.nn.softmax(srt, axis=-1), axis=-1)
            cut_idx = jnp.sum(cdf < sc.top_p, axis=-1)
            cutoff = jnp.take_along_axis(srt, cut_idx[:, None], axis=-1)
            z = jnp.where(z < cutoff, -jnp.inf, z)
        tok = jax.random.categorical(key, z, axis=-1)

    chosen = jnp.take_along_axis(logp, tok[:, None], axis=-1)[:, 0]
    return SampleOut(token=tok.astype(jnp.int32), logprob=chosen,
                     entropy=entropy, top1_prob=top1)

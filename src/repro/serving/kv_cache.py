"""Paged KV cache (vLLM-style, arXiv:2309.06180) adapted to JAX/TPU.

A global page pool per layer stack plus per-sequence block tables.  Pages
are (page_size, Hk, hd) tiles; the block table maps logical block index ->
physical page.  Allocation is host-side (the engine owns the allocator);
the device side is purely functional: `append_token` scatters new KV into
the right page, `gather_kv` materializes a sequence view for reference
attention (the Pallas flash_decode kernel consumes tables directly on TPU).

Paged caches beat contiguous per-slot caches at scale because memory is
allocated in O(page) quanta: fragmentation is bounded by page_size-1
tokens per sequence instead of (max_len - len) per slot.
"""
from __future__ import annotations

import dataclasses
from typing import List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class PagedState(NamedTuple):
    pages_k: jnp.ndarray   # (L, n_pages, page, Hk, hd)
    pages_v: jnp.ndarray   # (L, n_pages, page, Hk, hd)
    tables: jnp.ndarray    # (B, max_blocks) int32 physical page ids
    lengths: jnp.ndarray   # (B,) int32 tokens present per sequence


def init_paged(cfg, n_pages: int, page: int, batch: int, max_blocks: int
               ) -> PagedState:
    L, Hk, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim_
    dt = cfg.act_dtype
    return PagedState(
        pages_k=jnp.zeros((L, n_pages, page, Hk, hd), dt),
        pages_v=jnp.zeros((L, n_pages, page, Hk, hd), dt),
        tables=jnp.zeros((batch, max_blocks), jnp.int32),
        lengths=jnp.zeros((batch,), jnp.int32),
    )


def append_token(state: PagedState, k_new: jnp.ndarray, v_new: jnp.ndarray
                 ) -> PagedState:
    """Scatter one token per sequence: k_new/v_new (L, B, Hk, hd).

    The engine must have pre-assigned a page for position `lengths[b]`
    (tables[b, lengths[b] // page] is valid)."""
    L, n_pages, page, Hk, hd = state.pages_k.shape
    B = state.tables.shape[0]
    blk = state.lengths // page                       # (B,)
    off = state.lengths % page                        # (B,)
    phys = jnp.take_along_axis(state.tables, blk[:, None], axis=1)[:, 0]

    li = jnp.arange(L)[:, None]                       # (L, 1)
    bi = jnp.broadcast_to(phys[None, :], (L, B))
    oi = jnp.broadcast_to(off[None, :], (L, B))
    pages_k = state.pages_k.at[li, bi, oi].set(k_new)
    pages_v = state.pages_v.at[li, bi, oi].set(v_new)
    return state._replace(pages_k=pages_k, pages_v=pages_v,
                          lengths=state.lengths + 1)


def gather_kv(state: PagedState, layer: Optional[int] = None
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Materialize (L?, B, max_blocks*page, Hk, hd) contiguous views."""
    pk, pv = state.pages_k, state.pages_v
    if layer is not None:
        pk, pv = pk[layer], pv[layer]
        k = pk[state.tables]          # (B, max_blocks, page, Hk, hd)
        v = pv[state.tables]
        B, nb, pg, Hk, hd = k.shape
        return k.reshape(B, nb * pg, Hk, hd), v.reshape(B, nb * pg, Hk, hd)
    k = pk[:, state.tables]           # (L, B, max_blocks, page, Hk, hd)
    v = pv[:, state.tables]
    L, B, nb, pg, Hk, hd = k.shape
    return (k.reshape(L, B, nb * pg, Hk, hd),
            v.reshape(L, B, nb * pg, Hk, hd))


@dataclasses.dataclass
class PageAllocator:
    """Host-side free-list allocator for physical pages."""

    n_pages: int

    def __post_init__(self):
        self.free: List[int] = list(range(self.n_pages - 1, -1, -1))
        self.owned: dict = {}

    def alloc(self, seq_id: int, n: int = 1) -> List[int]:
        if len(self.free) < n:
            raise MemoryError(
                f"KV pool exhausted: need {n}, free {len(self.free)}")
        got = [self.free.pop() for _ in range(n)]
        self.owned.setdefault(seq_id, []).extend(got)
        return got

    def release(self, seq_id: int):
        for p in self.owned.pop(seq_id, []):
            self.free.append(p)

    @property
    def utilization(self) -> float:
        return 1.0 - len(self.free) / max(self.n_pages, 1)

"""Paged KV cache (vLLM-style, arXiv:2309.06180) adapted to JAX/TPU.

A global page pool per layer stack plus per-sequence block tables.  Pages
are (page_size, Hk, hd) tiles; the block table maps logical block index ->
physical page.  Allocation is host-side (the engine owns the allocator);
the device side is purely functional: `append_token` scatters new KV into
the right page, `gather_kv` materializes a sequence view for reference
attention (the Pallas flash_decode kernel consumes tables directly on TPU).

Paged caches beat contiguous per-slot caches at scale because memory is
allocated in O(page) quanta: fragmentation is bounded by page_size-1
tokens per sequence instead of (max_len - len) per slot.

This module also hosts the sink+recent *compaction* primitives behind
the engine's StreamingLLM-style context eviction (arXiv:2309.17453):
`sink_recent_indices` picks the surviving rows (attention sinks + the
recent window), `compact_slot_kv` gathers them to the front of one batch
slot of the contiguous cache and re-rotates the kept keys by their
position delta (the "KV shift" — rotate-half RoPE composes additively,
so shifting a cached key from position p to p-d is one exact extra
rotation by -d), and `PageAllocator.release_n` gives back the surplus
accounting pages a shrunk sequence no longer covers.
"""
from __future__ import annotations

import dataclasses
from typing import List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import rope


class PagedState(NamedTuple):
    pages_k: jnp.ndarray   # (L, n_pages, page, Hk, hd)
    pages_v: jnp.ndarray   # (L, n_pages, page, Hk, hd)
    tables: jnp.ndarray    # (B, max_blocks) int32 physical page ids
    lengths: jnp.ndarray   # (B,) int32 tokens present per sequence


def init_paged(cfg, n_pages: int, page: int, batch: int, max_blocks: int
               ) -> PagedState:
    L, Hk, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim_
    dt = cfg.act_dtype
    return PagedState(
        pages_k=jnp.zeros((L, n_pages, page, Hk, hd), dt),
        pages_v=jnp.zeros((L, n_pages, page, Hk, hd), dt),
        tables=jnp.zeros((batch, max_blocks), jnp.int32),
        lengths=jnp.zeros((batch,), jnp.int32),
    )


def append_token(state: PagedState, k_new: jnp.ndarray, v_new: jnp.ndarray
                 ) -> PagedState:
    """Scatter one token per sequence: k_new/v_new (L, B, Hk, hd).

    The engine must have pre-assigned a page for position `lengths[b]`
    (tables[b, lengths[b] // page] is valid)."""
    L, n_pages, page, Hk, hd = state.pages_k.shape
    B = state.tables.shape[0]
    blk = state.lengths // page                       # (B,)
    off = state.lengths % page                        # (B,)
    phys = jnp.take_along_axis(state.tables, blk[:, None], axis=1)[:, 0]

    li = jnp.arange(L)[:, None]                       # (L, 1)
    bi = jnp.broadcast_to(phys[None, :], (L, B))
    oi = jnp.broadcast_to(off[None, :], (L, B))
    pages_k = state.pages_k.at[li, bi, oi].set(k_new)
    pages_v = state.pages_v.at[li, bi, oi].set(v_new)
    return state._replace(pages_k=pages_k, pages_v=pages_v,
                          lengths=state.lengths + 1)


def gather_kv(state: PagedState, layer: Optional[int] = None
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Materialize (L?, B, max_blocks*page, Hk, hd) contiguous views."""
    pk, pv = state.pages_k, state.pages_v
    if layer is not None:
        pk, pv = pk[layer], pv[layer]
        k = pk[state.tables]          # (B, max_blocks, page, Hk, hd)
        v = pv[state.tables]
        B, nb, pg, Hk, hd = k.shape
        return k.reshape(B, nb * pg, Hk, hd), v.reshape(B, nb * pg, Hk, hd)
    k = pk[:, state.tables]           # (L, B, max_blocks, page, Hk, hd)
    v = pv[:, state.tables]
    L, B, nb, pg, Hk, hd = k.shape
    return (k.reshape(L, B, nb * pg, Hk, hd),
            v.reshape(L, B, nb * pg, Hk, hd))


@dataclasses.dataclass
class PageAllocator:
    """Host-side free-list allocator for physical pages."""

    n_pages: int

    def __post_init__(self):
        self.free: List[int] = list(range(self.n_pages - 1, -1, -1))
        self.owned: dict = {}

    def alloc(self, seq_id: int, n: int = 1) -> List[int]:
        if len(self.free) < n:
            raise MemoryError(
                f"KV pool exhausted: need {n}, free {len(self.free)}")
        got = [self.free.pop() for _ in range(n)]
        self.owned.setdefault(seq_id, []).extend(got)
        return got

    def release(self, seq_id: int):
        for p in self.owned.pop(seq_id, []):
            self.free.append(p)

    def release_n(self, seq_id: int, n: int) -> None:
        """Give back the last `n` pages of `seq_id` (LIFO, so a later
        re-grow reuses the same physical ids first) — the shrink half of
        the engine's `_kv_sync` after a context eviction."""
        owned = self.owned.get(seq_id, [])
        if n > len(owned):
            raise ValueError(
                f"seq {seq_id!r}: cannot release {n} pages, owns "
                f"{len(owned)}")
        for _ in range(n):
            self.free.append(owned.pop())
        if not owned:
            self.owned.pop(seq_id, None)

    @property
    def utilization(self) -> float:
        return 1.0 - len(self.free) / max(self.n_pages, 1)


# ==========================================================================
# Sink+recent eviction (StreamingLLM, arXiv:2309.17453)
# ==========================================================================
def sink_recent_indices(length: int, n_sink: int, n_recent: int
                        ) -> np.ndarray:
    """Row indices that survive a sink+recent eviction of a `length`-token
    context: the first `n_sink` positions (attention sinks) plus the last
    `n_recent` (the recent window), in order."""
    if n_sink < 0 or n_recent < 1:
        raise ValueError(
            f"need n_sink >= 0 and n_recent >= 1; got {n_sink}/{n_recent}")
    if n_sink + n_recent >= length:
        raise ValueError(
            f"sink+recent keeps {n_sink}+{n_recent} of {length} tokens — "
            "nothing to evict")
    return np.concatenate([
        np.arange(n_sink), np.arange(length - n_recent, length),
    ]).astype(np.int32)


def compact_slot_kv(cache: dict, slot: int, keep: np.ndarray, cfg
                    ) -> dict:
    """Gather the surviving rows of batch slot `slot` to the front of a
    contiguous (L, B, S, Hk, hd) KV cache, in place of positions
    0..len(keep).

    Kept keys are re-rotated by their position delta (new - old, <= 0):
    rotate-half RoPE rotations compose additively, so the result is
    bit-for-bit what a fresh prefill at the compacted positions would
    have written — relative attention distances inside the kept context
    stay exact (the StreamingLLM KV shift).  Holds for M-RoPE configs
    too because the engine feeds text-fallback positions (all three
    components equal), which degenerate to 1-D RoPE.

    Rows past len(keep) are left stale: the causal mask (prefill) and the
    per-slot length (decode) make them invisible, and the next prefill
    overwrites them.  Updates `cache["length"][slot]`; the caller owns
    the host-side length mirror and page accounting."""
    keep = np.asarray(keep, np.int32)
    n_keep = int(keep.shape[0])
    keep_j = jnp.asarray(keep)
    rows_k = cache["k"][:, slot][:, keep_j]      # (L, n_keep, Hk, hd)
    rows_v = cache["v"][:, slot][:, keep_j]
    delta = jnp.asarray(np.arange(n_keep, dtype=np.int32) - keep)
    cos, sin = rope.rope_angles(delta, cfg.head_dim_, cfg.rope_theta)
    # apply_rope wants (B, S, H, D) with (B, S, half) angles; the layer
    # axis stands in for batch
    rows_k = rope.apply_rope(rows_k, cos[None], sin[None])
    cache = dict(cache)
    cache["k"] = cache["k"].at[:, slot, :n_keep].set(rows_k)
    cache["v"] = cache["v"].at[:, slot, :n_keep].set(rows_v)
    cache["length"] = cache["length"].at[slot].set(n_keep)
    return cache

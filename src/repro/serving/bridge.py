"""Serving bridge: the continuous-batching engine as the fleet's cloud peer.

The fleet's default server is a glyph-decoding oracle — accuracy and
response latency are *looked up*.  This module closes the paper's loop:
`Fleet(server="engine")` routes the per-tick server phase through a real
`serving.Engine`, so the visual quality the channel actually delivered
is what a model conditions on, and response timing comes from the
engine's slot/queue discipline instead of a constant:

  delivered frame --frames_to_patches--> (P, d_model) embeddings
        --Engine.extend_session--> chunked prefill into the session slot
  QA commit --Engine.submit_query/drain_queries--> batched decode
        --> answer tokens scored by the SAME QA policy, plus TTFT /
            queueing-delay / confidence telemetry per query.

Determinism contract: the model is a seeded reduced-config backbone
(random weights, greedy sampling, float32 on CPU) and the engine clock
is simulated (`step_dt` per engine step), so two runs of the same
scenario are digest-identical.  Random weights answer at chance level —
the engine path measures *system* behavior (latency, queueing, context
growth, batching) end to end; the oracle stays the accuracy-calibrated
default and is untouched by this module.

Context growth: every delivered frame appends `patch_grid**2` tokens.
When the slot would overflow (`max_len`), the engine evicts middle
context StreamingLLM-style (sink+recent: the first `n_sink` tokens plus
the most recent window survive, RoPE positions re-rotated exactly), so
streaming sessions never hard-reset — evictions and evicted tokens are
counted in the telemetry.  Passing `eviction=False` opts back into the
legacy rollover (close + reopen, full context drop), kept for A/B
comparison (`bench_serving.py`'s eviction stage) and for ssm backbones,
whose constant-size state has no per-position KV to evict.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.configs import registry
from repro.models import transformer as tfm
from repro.models.config import reduced
from repro.serving.engine import Engine, Request
from repro.serving.sampler import SamplerConfig
from repro.video.scenes import GLYPH_BITS

_POOL = 8  # each patch is average-pooled to a POOL x POOL feature grid


def frames_to_patches(frames: np.ndarray, d_model: int,
                      patch_grid: int = 2, seed: int = 0) -> np.ndarray:
    """Deterministic patch embedder: (B, H, W) frames (or one (H, W)
    frame) -> (B, patch_grid**2, d_model) float32 embeddings.

    Each frame splits into a patch_grid x patch_grid grid; every patch is
    average-pooled to an 8x8 feature tile, zero-centered (frames live in
    [0, 1]) and projected by a FIXED seeded Gaussian matrix — pure NumPy,
    no learned state, bit-stable across runs and batch sizes.  The
    embeddings preserve exactly the degradation the channel inflicted:
    a re-quantized or downscaled frame produces different tokens than a
    clean one, which is the whole point of conditioning the model on
    *delivered* pixels."""
    frames = np.asarray(frames, np.float32)
    if frames.ndim == 2:
        frames = frames[None]
    if frames.ndim != 3:
        raise ValueError(f"frames must be (B, H, W) or (H, W); "
                         f"got shape {frames.shape}")
    B, H, W = frames.shape
    g = int(patch_grid)
    ph, pw = H // g, W // g
    if ph < _POOL or pw < _POOL:
        raise ValueError(
            f"frame {H}x{W} too small for patch_grid={g}: each patch "
            f"must be at least {_POOL}x{_POOL}")
    bh, bw = ph // _POOL, pw // _POOL
    # crop to pool-aligned patch tiles (top-left anchored, deterministic)
    x = frames[:, :g * bh * _POOL, :g * bw * _POOL]
    x = x.reshape(B, g, bh * _POOL, g, bw * _POOL)
    x = x.transpose(0, 1, 3, 2, 4).reshape(B, g * g, bh * _POOL, bw * _POOL)
    x = x.reshape(B, g * g, _POOL, bh, _POOL, bw).mean(axis=(3, 5))
    feats = x.reshape(B, g * g, _POOL * _POOL) - 0.5
    proj = _projection(d_model, seed)
    return (feats @ proj).astype(np.float32)


_PROJ_CACHE: Dict[Tuple[int, int], np.ndarray] = {}


def _projection(d_model: int, seed: int) -> np.ndarray:
    key = (d_model, seed)
    if key not in _PROJ_CACHE:
        rng = np.random.default_rng(seed)
        _PROJ_CACHE[key] = (rng.standard_normal((_POOL * _POOL, d_model))
                            / np.sqrt(_POOL * _POOL)).astype(np.float32)
    return _PROJ_CACHE[key]


@dataclasses.dataclass
class SessionTelemetry:
    """Per-session serving telemetry the bridge accumulates; lands in
    `SessionMetrics.server_ttfts` / `server_queue_delays` /
    `server_confidences` at finalize."""
    ttfts: List[float] = dataclasses.field(default_factory=list)
    queue_delays: List[float] = dataclasses.field(default_factory=list)
    confidences: List[float] = dataclasses.field(default_factory=list)
    extends: int = 0
    rollovers: int = 0
    evictions: int = 0
    evicted_tokens: int = 0

    def as_metrics_kwargs(self) -> Dict[str, object]:
        return dict(server_ttfts=list(self.ttfts),
                    server_queue_delays=list(self.queue_delays),
                    server_confidences=list(self.confidences),
                    server_evictions=self.evictions,
                    server_evicted_tokens=self.evicted_tokens,
                    server_rollovers=self.rollovers)


class EngineServerBridge:
    """Owns one `Engine` whose slots are the fleet's sessions.

    The fleet tick drives three entry points in order: `extend(k, ...)`
    for every session with frames delivered this tick, `submit(k, qa,
    t)` for every session whose question commits this tick, then one
    `drain(t)` that batch-decodes ALL open queries together — that last
    call is the continuous-batching payoff: one decode step per engine
    tick serves every querying session."""

    #: engine_cfg keys accepted by Fleet(engine_cfg=...) / ScenarioSpec
    KNOBS = ("arch", "reduced_model", "max_len", "step_dt", "patch_grid",
             "max_new", "query_len", "seed", "chunk_max", "temperature",
             "eviction", "n_sink", "evict_target")

    def __init__(self, n_sessions: int, *, arch: str = "qwen3-0.6b",
                 reduced_model: bool = True, max_len: int = 192,
                 step_dt: float = 0.004, patch_grid: int = 2,
                 max_new: int = 4, query_len: int = 3, seed: int = 0,
                 chunk_max: int = 32, temperature: float = 0.0,
                 eviction: Optional[bool] = None, n_sink: int = 4,
                 evict_target: Optional[int] = None):
        cfg = registry.get_config(arch)
        if reduced_model:
            cfg = reduced(cfg, dtype="float32", param_dtype="float32")
        if cfg.family == "hybrid" or cfg.kv_cache_dtype == "int8":
            raise NotImplementedError(
                f"{cfg.name}: the serving bridge needs prefill_extend "
                "(dense/moe/ssm, full-precision KV)")
        self.cfg = cfg
        self.patch_grid = int(patch_grid)
        self.max_new = int(max_new)
        self.query_len = int(query_len)
        self.seed = int(seed)
        # eviction=None -> auto: sink+recent wherever the backbone has a
        # per-position KV cache; ssm (constant-size state) keeps rollover
        if eviction is None:
            eviction = cfg.family in ("dense", "moe")
        self.eviction = bool(eviction)
        params = tfm.init(jax.random.PRNGKey(seed), cfg)
        self.engine = Engine(
            cfg, params, max_batch=n_sessions, max_len=max_len,
            sampler=SamplerConfig(temperature=temperature), seed=seed,
            step_dt=step_dt, chunk_max=chunk_max,
            eviction=("sink" if self.eviction else None),
            n_sink=n_sink, evict_target=evict_target)
        # headroom a query needs on top of the streamed context
        self._reserve = self.query_len + self.max_new
        self._scenes: Dict[int, object] = {}
        self._fps: Dict[int, float] = {}
        self.telemetry: Dict[int, SessionTelemetry] = {}
        self._pending: Dict[int, Tuple[object, Request]] = {}

    # -- session lifecycle ---------------------------------------------
    def open(self, k: int, scene, fps: float, now: float = 0.0,
             wait: bool = False) -> None:
        """Open fleet session k on the engine.  With `wait=True` (the
        churn admission path) a full engine waits for a slot instead of
        raising; the arrival-stamped admission delay joins the session's
        queueing-delay telemetry."""
        self.engine.open_session(k, now=now, wait=wait)
        self._scenes[k] = scene
        self._fps[k] = float(fps)
        self.telemetry[k] = SessionTelemetry()
        delay = self.engine.session_admission_delay(k)
        if delay > 0.0:
            self.telemetry[k].queue_delays.append(delay)

    def close(self, k: int) -> None:
        """Release fleet session k's engine slot (churn departure).
        Telemetry for the departed session survives until the slot is
        reopened; read it via `metrics_kwargs` before the next `open`."""
        if k in self._pending:
            raise RuntimeError(
                f"session {k}: close with an in-flight query — drain "
                "first (the departure path answers via answer_now)")
        # departure is a deliberate context drop: the unflushed final
        # answer token dies with the session it belonged to
        self.engine.close_session(k, discard=True)
        del self._scenes[k]
        del self._fps[k]

    def _ensure_capacity(self, k: int, n_new: int, now: float) -> None:
        """Legacy rollover (eviction=False only): close + reopen the
        slot when the next op would overflow `max_len`, dropping the
        whole context.  Under eviction (the default) this is a no-op —
        the engine compacts the context inside extend/submit instead."""
        if self.eviction:
            return
        if (self.engine.session_length(k) + n_new + self._reserve
                > self.engine.max_len):
            if k in self._pending:
                raise RuntimeError(
                    f"session {k}: rollover with an in-flight query "
                    "would drop its decode state — drain first")
            self.engine.close_session(k, discard=True)
            self.engine.open_session(k, now=now)
            tel = self.telemetry[k]
            tel.rollovers += 1
            # the reopen is arrival-stamped like every other open path:
            # a busy engine clock shows up as admission delay
            delay = self.engine.session_admission_delay(k)
            if delay > 0.0:
                tel.queue_delays.append(delay)

    def _sync_evictions(self, k: int) -> None:
        ev, toks = self.engine.session_eviction_stats(k)
        tel = self.telemetry[k]
        tel.evictions, tel.evicted_tokens = ev, toks

    # -- the per-tick server phase -------------------------------------
    def extend(self, k: int, frames: np.ndarray, now: float) -> None:
        """Prefill this tick's delivered frames ((B, H, W) or (H, W))
        into session k's context."""
        embeds = frames_to_patches(frames, self.cfg.d_model,
                                   self.patch_grid, self.seed)
        flat = embeds.reshape(-1, self.cfg.d_model)
        self._ensure_capacity(k, flat.shape[0], now)
        delay = self.engine.extend_session(k, flat, now=now)
        self._sync_evictions(k)
        tel = self.telemetry[k]
        tel.queue_delays.append(delay)
        tel.extends += 1

    def query_tokens(self, qa) -> np.ndarray:
        """Deterministic token encoding of a QASample (kind + object)."""
        V = self.cfg.vocab
        kind_id = 1 if qa.kind == "count_objects" else 0
        toks = [kind_id, 2 + (qa.obj_idx % (V - 2)),
                2 + (int(round(qa.t_ask * 10)) % (V - 2))]
        return np.asarray(toks[:self.query_len], np.int32)

    def submit(self, k: int, qa, now: float) -> None:
        toks = self.query_tokens(qa)
        self._ensure_capacity(k, len(toks), now)
        req = self.engine.submit_query(k, toks, now=now,
                                       max_new=self.max_new)
        self._sync_evictions(k)
        self._pending[k] = (qa, req)

    def drain(self, now: float) -> Dict[int, bool]:
        """Batch-decode all open queries; returns {k: correct} and
        records TTFT / queueing delay / confidence telemetry."""
        if not self._pending:
            return {}
        self.engine.drain_queries(now=now)
        results: Dict[int, bool] = {}
        for k, (qa, req) in sorted(self._pending.items()):
            tel = self.telemetry[k]
            if req.ttft is not None:
                # a request that never produced a token has no TTFT;
                # recording 0.0 here would drag the percentiles down
                tel.ttfts.append(req.ttft)
            tel.queue_delays.append(req.queue_delay)
            tel.confidences.append(req.confidence)
            results[k] = self._score(k, qa, req)
        self._pending.clear()
        return results

    def answer_now(self, k: int, qa, now: float) -> bool:
        """Submit + drain one question synchronously (the end-of-run QA
        flush in `session.finalize`)."""
        self.submit(k, qa, now)
        return self.drain(now)[k]

    # -- scoring: the same QA policy the oracle answers against --------
    def _score(self, k: int, qa, req: Request) -> bool:
        scene = self._scenes[k]
        frame_idx = int(round(qa.t_ask * self._fps[k]))
        if qa.kind == "count_objects":
            if not req.output:
                return False
            # first answer token folds to a count guess over the scene's
            # actual answer space [0, n_objects] — a fixed modulus would
            # make counts >= that modulus unreachable
            mod = len(scene.objects) + 1
            return (req.output[0] % mod) == len(scene.objects)
        epoch = scene.epoch(frame_idx)
        truth = scene.objects[qa.obj_idx].code_at(epoch)
        if len(req.output) < 2:
            return False
        code = ((req.output[0] * self.cfg.vocab + req.output[1])
                % (1 << GLYPH_BITS))
        return code == truth

    # -- introspection --------------------------------------------------
    @property
    def stats(self):
        return self.engine.stats

    def metrics_kwargs(self, k: int) -> Dict[str, List[float]]:
        return self.telemetry[k].as_metrics_kwargs()

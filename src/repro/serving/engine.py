"""Continuous-batching serving engine.

Slot-based continuous batching (Orca-style): a fixed device batch of B
decode slots; finished sequences free their slot immediately and queued
requests are admitted with a prefill that writes straight into the slot's
cache region.  One jitted decode step serves all active slots per tick
with per-slot lengths, so heterogeneous sequences never block each other.

The engine also exposes *streaming sessions* for the Artic video loop:
`open_session` pins a slot for a long-lived video context,
`extend_session` appends frame-patch embeddings to it (Sarathi-style
chunked prefill into the slot's cache region), and `submit_query` /
`drain_queries` decode a response over ALL querying sessions in one
batched decode loop, returning the confidence/logprob telemetry the
Artic feedback channel ships back to the client
(`repro.serving.bridge` wires this into the fleet tick).

Time is simulated when the caller passes `now` (the fleet clock): every
engine step — a prefill chunk or one batched decode — advances
`self.clock` by `step_dt`, so server-side queueing delay
(`max(clock, now) - now`) and TTFT (`first_token_time - arrival`) are
deterministic functions of the workload, not of the host's wall clock.
Without `now`, `step()` still self-advances the simulated clock, so
`run_until_drained` timings are reproducible too.

KV accounting rides a `kv_cache.PageAllocator` over a virtual page pool
sized to the contiguous cache (the device cache itself stays contiguous;
pages are the *accounting* quantum): slots allocate pages as their
lengths grow and release them on retirement, and `EngineStats` surfaces
current/peak pool utilization.

Streaming sessions that outgrow `max_len` are handled by the eviction
policy: with `eviction="sink"` (StreamingLLM, arXiv:2309.17453) an
overflowing extend/query first compacts the session context to the
first `n_sink` attention-sink tokens plus the most recent window
(`kv_cache.compact_slot_kv` gathers the survivors and re-rotates their
RoPE positions exactly), so long sessions never hard-reset; with
`eviction=None` (the default) overflow raises `SessionOverflowError`
and the caller decides (the bridge's legacy answer was close+reopen
rollover).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.serving import kv_cache
from repro.serving.kv_cache import PageAllocator
from repro.serving.sampler import SamplerConfig, sample


@dataclasses.dataclass
class Request:
    uid: int
    tokens: np.ndarray                   # prompt (S,) int32
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    arrival: float = 0.0
    # filled by the engine
    output: List[int] = dataclasses.field(default_factory=list)
    logprobs: List[float] = dataclasses.field(default_factory=list)
    entropies: List[float] = dataclasses.field(default_factory=list)
    first_token_time: Optional[float] = None
    done_time: Optional[float] = None
    queue_delay: float = 0.0             # arrival -> first engine service

    @property
    def ttft(self) -> Optional[float]:
        """Time to first token on the engine's (simulated) clock."""
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival

    @property
    def mean_logprob(self) -> float:
        return float(np.mean(self.logprobs)) if self.logprobs else 0.0

    @property
    def confidence(self) -> float:
        """exp(mean token logprob) — the telemetry the Artic feedback
        channel ships back as the server's answer confidence."""
        return float(np.exp(self.mean_logprob))


@dataclasses.dataclass
class EngineStats:
    steps: int = 0
    tokens_out: int = 0
    admitted: int = 0
    finished: int = 0
    # slot occupancy: busy slot-steps over total slot-steps
    slot_busy_steps: int = 0
    slot_total_steps: int = 0
    # KV page-pool accounting (PageAllocator over the contiguous cache)
    kv_pages_total: int = 0
    kv_pages_used: int = 0
    kv_pages_peak: int = 0
    # sink+recent context evictions across all streaming sessions
    evictions: int = 0
    tokens_evicted: int = 0

    @property
    def slot_utilization(self) -> float:
        return self.slot_busy_steps / max(self.slot_total_steps, 1)

    @property
    def kv_utilization(self) -> float:
        return self.kv_pages_used / max(self.kv_pages_total, 1)

    @property
    def kv_peak_utilization(self) -> float:
        return self.kv_pages_peak / max(self.kv_pages_total, 1)


class SessionOverflowError(RuntimeError):
    """A streaming session tried to grow past the slot's max_len."""


@dataclasses.dataclass
class _StreamSession:
    """Host-side record of one pinned streaming-session slot."""
    sid: int
    slot: int
    length: int = 0                  # tokens in the slot cache (host mirror)
    opened: float = 0.0
    admission_delay: float = 0.0     # sim seconds spent waiting for a slot
    extends: int = 0
    active: Optional[Request] = None  # in-flight query, if any
    pending_token: int = 0            # next token to feed the batched decode
    unflushed: Optional[int] = None   # final answer token awaiting its KV
    #   write (decode writes token i-1's KV while producing token i, so
    #   the last sampled token joins the cache with the NEXT prefill)
    evictions: int = 0                # sink+recent evictions this tenancy
    evicted_tokens: int = 0


def _chunk_pad(n: int, chunk_max: int) -> int:
    """Pad a chunk length to the next power of two (bounded by
    `chunk_max`) so the jitted extend retraces O(log) shapes, not one
    per frame geometry."""
    p = 1
    while p < n:
        p *= 2
    return min(p, max(chunk_max, n))


class Engine:
    def __init__(self, cfg: ModelConfig, params, max_batch: int = 4,
                 max_len: int = 512,
                 sampler: Optional[SamplerConfig] = None,
                 seed: int = 0, step_dt: float = 0.0,
                 kv_page: int = 16, chunk_max: int = 64,
                 eviction: Optional[str] = None, n_sink: int = 4,
                 evict_target: Optional[int] = None):
        self.cfg = cfg
        self.params = params
        self.B = max_batch
        self.max_len = max_len
        # streaming-session overflow policy: None (legacy) raises
        # SessionOverflowError; "sink" evicts middle context StreamingLLM-
        # style, keeping the first n_sink tokens plus the most recent
        # window, compacted down to evict_target tokens so successive
        # evictions are amortized rather than per-token
        if eviction not in (None, "sink"):
            raise ValueError(f"eviction must be None or 'sink'; "
                             f"got {eviction!r}")
        if eviction == "sink" and cfg.family not in ("dense", "moe"):
            raise NotImplementedError(
                f"{cfg.name}: sink+recent eviction needs a per-position "
                "KV cache (dense/moe); ssm state is constant-size and "
                "never overflows by construction")
        self.eviction = eviction
        self.n_sink = int(n_sink)
        self.evict_target = (max_len // 2 if evict_target is None
                             else int(evict_target))
        if eviction == "sink" and not (
                self.n_sink + 1 <= self.evict_target <= max_len):
            raise ValueError(
                f"evict_target={self.evict_target} must lie in "
                f"[n_sink+1={self.n_sink + 1}, max_len={max_len}]")
        # None -> a fresh default per engine (a dataclass default of
        # SamplerConfig() would be one shared instance across engines)
        self.sampler = SamplerConfig() if sampler is None else sampler
        self.cache = tfm.init_cache(cfg, max_batch, max_len)
        # per-slot lengths (vector mode)
        self.cache["length"] = jnp.zeros((max_batch,), jnp.int32)
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.queue: "collections.deque[Request]" = collections.deque()
        self.key = jax.random.PRNGKey(seed)
        self.stats = EngineStats()
        self._pending_tokens = [0] * max_batch
        # simulated clock: each engine step (prefill chunk or batched
        # decode) consumes step_dt of simulated server time
        self.clock = 0.0
        self.step_dt = float(step_dt)
        self.chunk_max = int(chunk_max)
        # KV page-pool accounting over the contiguous cache
        self.kv_page = int(kv_page)
        pages_per_slot = -(-max_len // self.kv_page)
        self.allocator = PageAllocator(max_batch * pages_per_slot)
        self.stats.kv_pages_total = self.allocator.n_pages
        # streaming sessions pin slots; _admit must not hand those out
        self._sessions: Dict[int, _StreamSession] = {}
        self._slot_sids: Dict[int, int] = {}

        self._decode = jax.jit(
            lambda p, c, b: tfm.decode_step(p, c, b, cfg))
        self._prefill_one = jax.jit(
            lambda p, b: tfm.prefill(p, b, cfg, max_len=max_len))
        self._extend_one = jax.jit(
            lambda p, c, b: tfm.prefill_extend(p, c, b, cfg))

    # -- simulated time ------------------------------------------------
    def _begin_service(self, now: Optional[float]) -> float:
        """Advance the clock to service an op submitted at `now`;
        returns the op's queueing delay (how long the engine was busy
        with earlier work)."""
        if now is None:
            return 0.0
        if now >= self.clock:
            self.clock = now
            return 0.0
        return self.clock - now

    def _spend_step(self) -> None:
        self.clock += self.step_dt
        self.stats.steps += 1
        self._count_busy()

    # -- KV page accounting --------------------------------------------
    def _kv_sync(self, seq_key, length: int) -> None:
        """Grow or shrink `seq_key`'s page allocation to cover `length`
        tokens (shrink happens after a sink+recent eviction compacts the
        slot)."""
        need = -(-max(length, 1) // self.kv_page)
        have = len(self.allocator.owned.get(seq_key, []))
        if need > have:
            self.allocator.alloc(seq_key, need - have)
        elif need < have:
            self.allocator.release_n(seq_key, have - need)
        self.stats.kv_pages_used = (self.allocator.n_pages
                                    - len(self.allocator.free))
        self.stats.kv_pages_peak = max(self.stats.kv_pages_peak,
                                       self.stats.kv_pages_used)

    def _kv_release(self, seq_key) -> None:
        self.allocator.release(seq_key)
        self.stats.kv_pages_used = (self.allocator.n_pages
                                    - len(self.allocator.free))

    # ------------------------------------------------------------------
    def submit(self, req: Request, now: Optional[float] = None):
        """Queue a request; `now` stamps its arrival on the simulated
        clock (the bridge passes fleet time here)."""
        if now is not None:
            req.arrival = now
        self.queue.append(req)

    def _write_slot(self, slot: int, cache_one, length: int):
        """Copy a single-sequence cache into batch slot `slot`."""

        def write(big, small):
            if big.ndim == 1 and big.shape[0] == self.B:  # lengths
                return big
            # small: (L, 1, ...) -> big (L, B, ...)
            return big.at[:, slot].set(small[:, 0])

        for k in self.cache:
            if k == "length":
                continue
            self.cache[k] = jax.tree.map(write, self.cache[k], cache_one[k])
        self.cache["length"] = self.cache["length"].at[slot].set(length)

    def _slot_cache(self, slot: int, length: int) -> Dict[str, Any]:
        """A single-sequence view of batch slot `slot` (scalar length,
        as `prefill_extend` requires)."""
        one = {}
        for k, v in self.cache.items():
            if k == "length":
                continue
            one[k] = jax.tree.map(lambda a: a[:, slot:slot + 1], v)
        one["length"] = jnp.asarray(length, jnp.int32)
        return one

    def _free_slot(self) -> Optional[int]:
        for slot in range(self.B):
            if self.slots[slot] is None and slot not in self._slot_sids:
                return slot
        return None

    def _admit(self, now: float) -> List[int]:
        newly: List[int] = []
        for slot in range(self.B):
            if (self.slots[slot] is not None or slot in self._slot_sids
                    or not self.queue or self.queue[0].arrival > now):
                # FIFO: a head that hasn't arrived yet blocks the queue
                # (no reordering around it)
                continue
            req = self.queue.popleft()
            req.queue_delay = max(now - req.arrival, 0.0)
            toks = jnp.asarray(req.tokens, jnp.int32)[None, :]
            logits, cache_one = self._prefill_one(self.params, {"tokens": toks})
            self._write_slot(slot, cache_one, int(req.tokens.shape[0]))
            self.slots[slot] = req
            self.stats.admitted += 1
            self._kv_sync(("req", req.uid), int(req.tokens.shape[0]))
            # sample the first token from the prefill logits
            self.key, sub = jax.random.split(self.key)
            out = sample(sub, logits[:, 0, :], self.sampler)
            self._record(req, out, 0, now)
            self._pending_tokens[slot] = int(out.token[0])
            newly.append(slot)
        return newly

    def _record(self, req: Request, out, i: int, now: float):
        tok = int(out.token[i])
        req.output.append(tok)
        req.logprobs.append(float(out.logprob[i]))
        req.entropies.append(float(out.entropy[i]))
        if req.first_token_time is None:
            req.first_token_time = now
        self.stats.tokens_out += 1

    def _retire(self, now: float) -> List[Request]:
        done = []
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            hit_eos = req.eos_id is not None and req.output and (
                req.output[-1] == req.eos_id)
            # full means the NEXT decode step has no cache row to write:
            # prompt + committed output fills max_len (the final sampled
            # token never needs a row, so lengths up to max_len - 1 can
            # still take one more step).  Derived from the request's own
            # budget, not the raw slot cache length, which on a session-
            # pinned slot would include unrelated streaming context.
            full = len(req.tokens) + len(req.output) - 1 >= self.max_len
            if len(req.output) >= req.max_new_tokens or hit_eos or full:
                req.done_time = now
                done.append(req)
                self.slots[slot] = None
                self.stats.finished += 1
                self._kv_release(("req", req.uid))
        return done

    def _count_busy(self) -> None:
        busy = sum(r is not None for r in self.slots) + len(self._sessions)
        self.stats.slot_busy_steps += busy
        self.stats.slot_total_steps += self.B

    def step(self, now: Optional[float] = None) -> List[Request]:
        """One engine tick: admit -> batched decode -> retire.

        `now` defaults to the engine's own simulated clock advanced by
        `step_dt` — not the host wall clock — so request timings are
        deterministic.  With an explicit `now`, service begins at
        max(clock, now) and the tick still consumes `step_dt`, matching
        `_spend_step` on the streaming path — so repeated `step(now=t)`
        calls are never free and queueing delay accumulates behind the
        advancing clock.  Returns requests finished this tick."""
        if now is None:
            now = self.clock + self.step_dt
            if (self.queue and all(r is None for r in self.slots)
                    and self.queue[0].arrival + self.step_dt > now):
                # discrete-event idle skip: nothing in flight, so sleep
                # until the next queued arrival instead of spinning ticks
                now = self.queue[0].arrival + self.step_dt
            self.clock = max(self.clock, now)
        else:
            self._begin_service(now)
            self.clock += self.step_dt
        now = self.clock
        newly = self._admit(now)
        self._count_busy()
        # Orca iteration semantics: an admission tick yields only the
        # prefill-sampled first token; decode starts on the next tick
        active = [s for s, r in enumerate(self.slots)
                  if r is not None and s not in newly]
        if active:
            toks = np.zeros((self.B, 1), np.int32)
            for s in active:
                toks[s, 0] = self._pending_tokens[s]
            lengths = self.cache["length"]
            logits, self.cache = self._decode(
                self.params, self.cache, {"tokens": jnp.asarray(toks)})
            # decode_step advances EVERY slot's length; restore idle
            # slots (free or pinned by a non-decoding session) so their
            # cache positions stay put
            mask = np.zeros(self.B, bool)
            mask[active] = True
            self.cache["length"] = jnp.where(
                jnp.asarray(mask), self.cache["length"], lengths)
            self.key, sub = jax.random.split(self.key)
            out = sample(sub, logits[:, 0, :], self.sampler)
            for s in active:
                self._record(self.slots[s], out, s, now)
                self._pending_tokens[s] = int(out.token[s])
                self._kv_sync(("req", self.slots[s].uid),
                              int(self.cache["length"][s]))
        self.stats.steps += 1
        return self._retire(now)

    def run_until_drained(self, max_steps: int = 10_000) -> List[Request]:
        finished = []
        for _ in range(max_steps):
            finished.extend(self.step())
            if not self.queue and all(r is None for r in self.slots):
                break
        return finished

    # ==================================================================
    # Streaming sessions (the Artic video loop)
    # ==================================================================
    def open_session(self, sid: int, now: Optional[float] = None,
                     wait: bool = False,
                     max_wait_steps: int = 100_000) -> int:
        """Pin a slot for a streaming video session; returns the slot.

        Unlike queued requests, a streaming context cannot be evicted
        and re-prefilled (its source frames are gone), so admission is
        slot-or-error by default: size `max_batch` to the expected
        session count.  With `wait=True` (the churn admission path) the
        engine instead runs plain-request ticks forward on the simulated
        clock until a retirement frees a slot; the time spent waiting is
        recorded as the session's `admission_delay` (read it back via
        `session_admission_delay`)."""
        if sid in self._sessions:
            raise ValueError(f"session {sid} already open")
        if self.cfg.family == "hybrid" or self.cfg.kv_cache_dtype == "int8":
            raise NotImplementedError(
                "streaming sessions need prefill_extend, which supports "
                "dense/moe/ssm backbones with full-precision KV caches")
        self._begin_service(now)
        slot = self._free_slot()
        if slot is None and wait:
            if len(self._sessions) >= self.B:
                raise RuntimeError(
                    f"no free slot for streaming session {sid}: all "
                    f"{self.B} slots pinned by other sessions, so waiting "
                    "cannot free one (raise max_batch)")
            # every slot not pinned by a session holds a plain request;
            # tick the engine until one retires (each tick costs step_dt
            # on the simulated clock, so the wait is a real, arrival-
            # stamped queueing delay rather than free spinning)
            for _ in range(max_wait_steps):
                self.step(now=self.clock)
                slot = self._free_slot()
                if slot is not None:
                    break
        if slot is None:
            raise RuntimeError(
                f"no free slot for streaming session {sid}: all "
                f"{self.B} slots busy (streaming sessions pin their "
                "slot; raise max_batch)")
        sess = _StreamSession(sid=sid, slot=slot, opened=self.clock)
        if now is not None:
            sess.admission_delay = max(self.clock - now, 0.0)
        self._sessions[sid] = sess
        self._slot_sids[slot] = sid
        self.cache["length"] = self.cache["length"].at[slot].set(0)
        self._kv_sync(("sid", sid), 0)
        return slot

    def close_session(self, sid: int, discard: bool = False) -> None:
        """Release session `sid`'s slot.

        Closing is destructive: the slot's context — including an
        in-flight query's decode state (`sess.active`) and the
        lazy-commit final answer token (`sess.unflushed`) — dies with
        it.  By default that is an error when such state exists, because
        a caller that closes mid-query (or mid-flush) and keeps serving
        would silently answer from a context missing committed tokens.
        Callers for whom the drop is the point — churn departures,
        explicit rollover — pass `discard=True`."""
        sess = self._sessions[sid]
        if not discard:
            if sess.active is not None:
                raise RuntimeError(
                    f"session {sid}: close_session with an in-flight "
                    "query would drop its decode state; drain first or "
                    "pass discard=True")
            if sess.unflushed is not None:
                raise RuntimeError(
                    f"session {sid}: close_session would drop the "
                    "unflushed final answer token; flush it with an "
                    "extend first or pass discard=True")
        self._sessions.pop(sid)
        del self._slot_sids[sess.slot]
        self._kv_release(("sid", sid))

    def session_length(self, sid: int) -> int:
        """Context length including a finished query's final answer
        token, which is committed to the KV cache lazily (on the next
        extend/query prefill)."""
        sess = self._sessions[sid]
        return sess.length + (sess.unflushed is not None)

    def session_admission_delay(self, sid: int) -> float:
        """Simulated seconds session `sid` waited for a free slot at
        `open_session` (nonzero only under `wait=True` contention or a
        busy clock)."""
        return self._sessions[sid].admission_delay

    def session_eviction_stats(self, sid: int) -> Tuple[int, int]:
        """(evictions, evicted_tokens) for session `sid`'s current
        tenancy — the bridge mirrors these into `SessionTelemetry`."""
        sess = self._sessions[sid]
        return sess.evictions, sess.evicted_tokens

    def _take_unflushed(self, sess: _StreamSession) -> Optional[np.ndarray]:
        """Pop the pending final answer token as a (1, D) embedding to
        prepend to the next prefill, materializing its KV row."""
        if sess.unflushed is None:
            return None
        tok = sess.unflushed
        sess.unflushed = None
        return np.asarray(
            tfm.layers.embed(self.params["embed"],
                             jnp.asarray([[tok]], jnp.int32),
                             self.cfg)[0], np.float32)

    def _check_capacity(self, sess: _StreamSession, n_new: int,
                        what: str) -> None:
        if sess.length + n_new > self.max_len:
            raise SessionOverflowError(
                f"session {sess.sid}: {what} of {n_new} tokens would "
                f"grow the context to {sess.length + n_new} > "
                f"max_len={self.max_len}")

    def _fit_or_evict(self, sess: _StreamSession, n_new: int,
                      what: str) -> None:
        """Make room for `n_new` tokens (which must include any
        unflushed answer token the caller is about to concatenate).

        With `eviction=None` this is exactly the legacy capacity check.
        With `eviction="sink"` an overflowing op first compacts the
        session to the sink+recent skeleton: keep the first `n_sink`
        tokens plus the most recent window, shrinking to
        min(evict_target, max_len - n_new) so the op then fits.  The
        compaction itself costs no simulated engine time — it is cache
        bookkeeping, not a forward pass.  An op too large to ever fit
        (n_new > max_len - n_sink - 1) still raises; so does eviction
        mid-query, which would shift cache positions under an active
        decode."""
        if sess.length + n_new <= self.max_len:
            return
        if self.eviction != "sink":
            self._check_capacity(sess, n_new, what)
            return
        if sess.active is not None:
            raise RuntimeError(
                f"session {sess.sid}: cannot evict context while a "
                "query is in flight (drain first)")
        allowed = min(self.evict_target, self.max_len - n_new)
        if allowed < self.n_sink + 1 or allowed >= sess.length:
            # either the op alone exceeds the post-eviction budget or
            # the context is already shorter than the target — evicting
            # cannot make this op fit
            raise SessionOverflowError(
                f"session {sess.sid}: {what} of {n_new} tokens cannot "
                f"fit even after sink+recent eviction (length "
                f"{sess.length}, n_sink {self.n_sink}, "
                f"max_len {self.max_len})")
        keep = kv_cache.sink_recent_indices(
            sess.length, self.n_sink, allowed - self.n_sink)
        self.cache = kv_cache.compact_slot_kv(
            self.cache, sess.slot, keep, self.cfg)
        evicted = sess.length - allowed
        sess.length = allowed
        self._kv_sync(("sid", sess.sid), allowed)
        sess.evictions += 1
        sess.evicted_tokens += evicted
        self.stats.evictions += 1
        self.stats.tokens_evicted += evicted

    def _extend_chunks(self, sess: _StreamSession, embeds: np.ndarray
                       ) -> jnp.ndarray:
        """Chunked prefill of (S, D) embeddings into the session slot.

        Chunks are padded to power-of-two lengths (bounded retrace set);
        the causal mask makes pad rows invisible to real positions and
        the host-side length mirror excludes them, so the next write
        overwrites their cache rows.  Returns the logits row of the last
        REAL position (1, V)."""
        S = embeds.shape[0]
        if S == 0:
            # returning None here would crash the caller's sample();
            # zero-length extends must be skipped (extend_session) or
            # rejected (submit_query) before reaching the chunk loop
            raise ValueError(
                f"session {sess.sid}: cannot prefill a zero-length extend")
        last = None
        done = 0
        while done < S:
            n = min(S - done, self.chunk_max)
            n_pad = _chunk_pad(n, self.chunk_max)
            chunk = np.zeros((1, n_pad, embeds.shape[1]), np.float32)
            chunk[0, :n] = embeds[done:done + n]
            cache_one = self._slot_cache(sess.slot, sess.length)
            logits, cache_one = self._extend_one(
                self.params, cache_one, {"embeds": jnp.asarray(chunk)})
            sess.length += n
            self._write_slot(sess.slot, cache_one, sess.length)
            last = logits[:, n - 1, :]
            done += n
            self._spend_step()
        self._kv_sync(("sid", sess.sid), sess.length)
        return last

    def extend_session(self, sid: int, patch_embeds: np.ndarray,
                       now: Optional[float] = None) -> float:
        """Append frame-patch embeddings (S, D) to the session context
        via chunked prefill; returns the op's queueing delay (simulated
        seconds the engine was busy before serving it)."""
        sess = self._sessions[sid]
        embeds = np.asarray(patch_embeds, np.float32)
        if embeds.ndim != 2 or embeds.shape[1] != self.cfg.d_model:
            raise ValueError(
                f"patch_embeds must be (S, d_model={self.cfg.d_model}); "
                f"got {embeds.shape}")
        if embeds.shape[0] == 0 and sess.unflushed is None:
            # nothing to prefill and no lazy answer token to flush
            return 0.0
        # capacity (and any eviction) resolves BEFORE the unflushed token
        # is popped, so an overflow raise never drops it — and an
        # eviction only compacts committed cache rows, so the host-side
        # token rides through untouched and flushes into the prefill
        self._fit_or_evict(
            sess, embeds.shape[0] + (sess.unflushed is not None), "extend")
        pre = self._take_unflushed(sess)
        if pre is not None:
            embeds = np.concatenate([pre, embeds], axis=0)
        delay = self._begin_service(now)
        self._extend_chunks(sess, embeds)
        sess.extends += 1
        return delay

    def submit_query(self, sid: int, query_tokens: np.ndarray,
                     now: Optional[float] = None, max_new: int = 8,
                     uid: Optional[int] = None,
                     eos_id: Optional[int] = None) -> Request:
        """Prefill a query into the session context and sample its first
        answer token; the remaining tokens decode in `drain_queries`
        batched across all querying sessions.

        The query tokens AND the answer tokens join the session context
        (interleaved chat a la VideoLLM-online), so capacity is checked
        for query + max_new."""
        sess = self._sessions[sid]
        if sess.active is not None:
            raise RuntimeError(f"session {sid} already has an open query")
        toks = np.asarray(query_tokens, np.int32).reshape(-1)
        if toks.shape[0] == 0:
            raise ValueError(
                f"session {sid}: a query needs at least one token")
        self._fit_or_evict(
            sess, toks.shape[0] + max_new + (sess.unflushed is not None),
            "query")
        req = Request(uid=(sid if uid is None else uid), tokens=toks,
                      max_new_tokens=max_new, eos_id=eos_id,
                      arrival=self.clock if now is None else now)
        req.queue_delay = self._begin_service(now)
        # chunked prefill of the query tokens through the embeds path
        embeds = np.asarray(
            tfm.layers.embed(self.params["embed"], jnp.asarray(toks)[None],
                             self.cfg)[0], np.float32)
        pre = self._take_unflushed(sess)
        if pre is not None:
            embeds = np.concatenate([pre, embeds], axis=0)
        last = self._extend_chunks(sess, embeds)
        self.key, sub = jax.random.split(self.key)
        out = sample(sub, last, self.sampler)
        self._record(req, out, 0, self.clock)
        sess.pending_token = int(out.token[0])
        sess.active = req
        self.stats.admitted += 1
        return req

    def drain_queries(self, now: Optional[float] = None,
                      max_steps: int = 10_000) -> Dict[int, Request]:
        """Decode every open session query to completion: ONE batched
        decode step per engine tick serves all querying sessions (plus
        nothing else — plain requests keep draining via `step`).

        Returns {sid: finished Request}."""
        self._begin_service(now)
        done: Dict[int, Request] = {}
        for _ in range(max_steps):
            live = [s for s in self._sessions.values()
                    if s.active is not None]
            if not live:
                break
            toks = np.zeros((self.B, 1), np.int32)
            mask = np.zeros(self.B, bool)
            for s in live:
                toks[s.slot, 0] = s.pending_token
                mask[s.slot] = True
            lengths = self.cache["length"]
            logits, self.cache = self._decode(
                self.params, self.cache, {"tokens": jnp.asarray(toks)})
            # answer tokens join the session context: only querying
            # slots keep the +1 length
            self.cache["length"] = jnp.where(
                jnp.asarray(mask), self.cache["length"], lengths)
            self._spend_step()
            self.key, sub = jax.random.split(self.key)
            out = sample(sub, logits[:, 0, :], self.sampler)
            for s in live:
                req = s.active
                self._record(req, out, s.slot, self.clock)
                s.pending_token = int(out.token[s.slot])
                s.length += 1
                hit_eos = (req.eos_id is not None
                           and req.output[-1] == req.eos_id)
                if len(req.output) >= req.max_new_tokens or hit_eos:
                    req.done_time = self.clock
                    s.active = None
                    s.unflushed = s.pending_token
                    done[s.sid] = req
                    self.stats.finished += 1
                self._kv_sync(("sid", s.sid), s.length)
        return done

"""Continuous-batching serving engine.

Slot-based continuous batching (Orca-style): a fixed device batch of B
decode slots; finished sequences free their slot immediately and queued
requests are admitted with a prefill that writes straight into the slot's
cache region.  One jitted decode step serves all active slots per tick
with per-slot lengths, so heterogeneous sequences never block each other.

The engine also exposes *streaming sessions* for the Artic video loop:
`extend_session` appends frame-patch embeddings to a session's context
(chunked prefill), `query_session` decodes a response and returns the
confidence/grounding telemetry the Artic feedback channel ships back to
the client.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.serving.sampler import SamplerConfig, sample


@dataclasses.dataclass
class Request:
    uid: int
    tokens: np.ndarray                   # prompt (S,) int32
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    arrival: float = 0.0
    # filled by the engine
    output: List[int] = dataclasses.field(default_factory=list)
    logprobs: List[float] = dataclasses.field(default_factory=list)
    entropies: List[float] = dataclasses.field(default_factory=list)
    first_token_time: Optional[float] = None
    done_time: Optional[float] = None


@dataclasses.dataclass
class EngineStats:
    steps: int = 0
    tokens_out: int = 0
    admitted: int = 0
    finished: int = 0


class Engine:
    def __init__(self, cfg: ModelConfig, params, max_batch: int = 4,
                 max_len: int = 512,
                 sampler: SamplerConfig = SamplerConfig(),
                 seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.B = max_batch
        self.max_len = max_len
        self.sampler = sampler
        self.cache = tfm.init_cache(cfg, max_batch, max_len)
        # per-slot lengths (vector mode)
        self.cache["length"] = jnp.zeros((max_batch,), jnp.int32)
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.queue: "collections.deque[Request]" = collections.deque()
        self.key = jax.random.PRNGKey(seed)
        self.stats = EngineStats()
        self._pending_tokens = [0] * max_batch

        self._decode = jax.jit(
            lambda p, c, b: tfm.decode_step(p, c, b, cfg))
        self._prefill_one = jax.jit(
            lambda p, b: tfm.prefill(p, b, cfg, max_len=max_len))

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _write_slot(self, slot: int, cache_one, length: int):
        """Copy a single-sequence cache into batch slot `slot`."""

        def write(big, small):
            if big.ndim == 1 and big.shape[0] == self.B:  # lengths
                return big
            # small: (L, 1, ...) -> big (L, B, ...)
            return big.at[:, slot].set(small[:, 0])

        for k in self.cache:
            if k == "length":
                continue
            self.cache[k] = jax.tree.map(write, self.cache[k], cache_one[k])
        self.cache["length"] = self.cache["length"].at[slot].set(length)

    def _admit(self, now: float):
        for slot in range(self.B):
            if self.slots[slot] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            toks = jnp.asarray(req.tokens, jnp.int32)[None, :]
            logits, cache_one = self._prefill_one(self.params, {"tokens": toks})
            self._write_slot(slot, cache_one, int(req.tokens.shape[0]))
            self.slots[slot] = req
            self.stats.admitted += 1
            # sample the first token from the prefill logits
            self.key, sub = jax.random.split(self.key)
            out = sample(sub, logits[:, 0, :], self.sampler)
            self._record(req, out, 0, now)
            self._pending_tokens[slot] = int(out.token[0])

    def _record(self, req: Request, out, i: int, now: float):
        tok = int(out.token[i])
        req.output.append(tok)
        req.logprobs.append(float(out.logprob[i]))
        req.entropies.append(float(out.entropy[i]))
        if req.first_token_time is None:
            req.first_token_time = now
        self.stats.tokens_out += 1

    def _retire(self, now: float) -> List[Request]:
        done = []
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            hit_eos = req.eos_id is not None and req.output and (
                req.output[-1] == req.eos_id)
            full = int(self.cache["length"][slot]) >= self.max_len - 1
            if len(req.output) >= req.max_new_tokens or hit_eos or full:
                req.done_time = now
                done.append(req)
                self.slots[slot] = None
                self.stats.finished += 1
        return done

    def step(self, now: Optional[float] = None) -> List[Request]:
        """One engine tick: admit -> batched decode -> retire.

        Returns requests finished this tick."""
        now = time.monotonic() if now is None else now
        self._admit(now)
        active = [s for s, r in enumerate(self.slots) if r is not None]
        if active:
            toks = np.zeros((self.B, 1), np.int32)
            for s in active:
                toks[s, 0] = self._pending_tokens[s]
            logits, self.cache = self._decode(
                self.params, self.cache, {"tokens": jnp.asarray(toks)})
            self.key, sub = jax.random.split(self.key)
            out = sample(sub, logits[:, 0, :], self.sampler)
            for s in active:
                self._record(self.slots[s], out, s, now)
                self._pending_tokens[s] = int(out.token[s])
        self.stats.steps += 1
        return self._retire(now)

    def run_until_drained(self, max_steps: int = 10_000) -> List[Request]:
        finished = []
        for _ in range(max_steps):
            finished.extend(self.step())
            if not self.queue and all(r is None for r in self.slots):
                break
        return finished

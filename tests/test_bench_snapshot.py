"""The committed BENCH_fleet.json / BENCH_kernels.json perf snapshots:
schema + gate logic.

The snapshots are committed artifacts (like tests/golden/*) — CI
re-measures and gates on them, so their structure must stay loadable and
the regression comparators must actually fire on a regressed ratio /
a dropped kernel row.
"""
import copy
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__)))))

from benchmarks.snapshot import (BENCH_SCHEMA, REGRESSION_TOL,  # noqa: E402
                                 KERNELS_SNAPSHOT_PATH, SNAPSHOT_PATH,
                                 check_kernels_coverage, check_regression,
                                 load_kernels_snapshot, load_snapshot,
                                 validate_kernels_snapshot,
                                 validate_snapshot)


@pytest.fixture(scope="module")
def committed():
    assert os.path.exists(SNAPSHOT_PATH), (
        "BENCH_fleet.json must be committed at the repo root "
        "(python -m benchmarks.bench_fleet --rollout writes it)")
    return load_snapshot()


@pytest.fixture(scope="module")
def committed_kernels():
    assert os.path.exists(KERNELS_SNAPSHOT_PATH), (
        "BENCH_kernels.json must be committed at the repo root "
        "(python -m benchmarks.bench_kernels --write writes it)")
    return load_kernels_snapshot()


def test_committed_snapshot_validates(committed):
    assert committed["schema"] == BENCH_SCHEMA
    ns = sorted({int(c["n"]) for c in committed["cells"]})
    assert ns == [8, 64, 256]
    # one cell per (n, mode); baseline and on-device cells at every N
    keys = {(int(c["n"]), c.get("mode", "baseline"))
            for c in committed["cells"]}
    assert len(keys) == len(committed["cells"])
    for n in ns:
        assert (n, "baseline") in keys
        assert (n, "on_device_server") in keys
    for c in committed["cells"]:
        assert c["rollout_sessions_per_sec"] > 0
        assert "roofline" in c and "bottleneck" in c["roofline"]
    # host-side attribution columns ride along with every measured cell
    for c in committed["cells"]:
        assert "host_replay_s" in c["roofline"]
        assert "outfeed_bytes" in c["roofline"]


def test_validator_rejects_corruption(committed):
    for mutate in (
        lambda d: d.update(schema="artic.bench.snapshot/v0"),
        lambda d: d.pop("cells"),
        lambda d: d["cells"][0].pop("median_ratio"),
        lambda d: d["cells"][0].update(rollout_sessions_per_sec=0.0),
        lambda d: d["cells"][0]["roofline"].pop("bottleneck"),
        lambda d: d["machine"].pop("jax"),
    ):
        doc = copy.deepcopy(committed)
        mutate(doc)
        with pytest.raises(ValueError):
            validate_snapshot(doc)


def test_regression_gate_fires_on_ratio_drop(committed):
    fresh = copy.deepcopy(committed)
    assert check_regression(committed, fresh) == []
    # a drop just inside the tolerance passes ...
    ok = copy.deepcopy(committed)
    ok["cells"][0]["median_ratio"] *= (1.0 - REGRESSION_TOL + 0.02)
    assert check_regression(committed, ok) == []
    # ... past it fails, naming the N that regressed
    bad = copy.deepcopy(committed)
    bad["cells"][0]["median_ratio"] *= (1.0 - REGRESSION_TOL - 0.05)
    failures = check_regression(committed, bad)
    assert len(failures) == 1
    assert f"N={bad['cells'][0]['n']}" in failures[0]


def test_gate_ignores_machine_dependent_absolutes(committed):
    """Absolutes (sessions/sec) may move arbitrarily across runners —
    only the same-process rollout/eager ratio is gated."""
    fresh = copy.deepcopy(committed)
    for c in fresh["cells"]:
        c["eager_sessions_per_sec"] *= 0.1
        c["rollout_sessions_per_sec"] *= 0.1
    assert check_regression(committed, fresh) == []


def test_gate_keys_cells_on_n_and_mode(committed):
    """A regressed baseline cell must not be masked by a healthy
    on-device cell at the same N (and pre-mode snapshots read as
    mode='baseline')."""
    bad = copy.deepcopy(committed)
    victim = next(c for c in bad["cells"]
                  if c.get("mode", "baseline") == "on_device_server")
    victim["median_ratio"] *= (1.0 - REGRESSION_TOL - 0.05)
    failures = check_regression(committed, bad)
    assert len(failures) == 1
    assert "mode=on_device_server" in failures[0]
    # old one-cell-per-N snapshots (no mode field) still gate fresh
    # baseline cells; fresh non-baseline modes are simply unmatched
    legacy = copy.deepcopy(committed)
    legacy["cells"] = [c for c in legacy["cells"]
                       if c.get("mode", "baseline") == "baseline"]
    for c in legacy["cells"]:
        c.pop("mode", None)
    assert check_regression(legacy, committed) == []
    worse = copy.deepcopy(committed)
    base_cell = next(c for c in worse["cells"]
                     if c.get("mode", "baseline") == "baseline")
    base_cell["median_ratio"] *= (1.0 - REGRESSION_TOL - 0.05)
    assert len(check_regression(legacy, worse)) == 1


def test_committed_kernels_snapshot_validates(committed_kernels):
    assert committed_kernels["schema"] == BENCH_SCHEMA
    assert committed_kernels["kind"] == "kernels"
    names = {r["name"] for r in committed_kernels["rows"]}
    # the tick megakernel rows must be part of the committed record
    assert any(n.startswith("kernel.tick_megakernel") for n in names)


def test_kernels_validator_rejects_corruption(committed_kernels):
    for mutate in (
        lambda d: d.update(kind="fleet"),
        lambda d: d.update(rows=[]),
        lambda d: d["rows"][0].pop("name"),
        lambda d: d["rows"][0].update(us_per_call=-1.0),
    ):
        doc = copy.deepcopy(committed_kernels)
        mutate(doc)
        with pytest.raises(ValueError):
            validate_kernels_snapshot(doc)


def test_kernels_gate_fires_on_missing_row(committed_kernels):
    class FakeRow:
        def __init__(self, name):
            self.name = name

    fresh = [FakeRow(r["name"]) for r in committed_kernels["rows"]]
    assert check_kernels_coverage(committed_kernels, fresh) == []
    failures = check_kernels_coverage(committed_kernels, fresh[1:])
    assert len(failures) == 1
    assert committed_kernels["rows"][0]["name"] in failures[0]

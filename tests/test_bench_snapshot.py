"""The committed BENCH_fleet.json perf snapshot: schema + gate logic.

The snapshot is a committed artifact (like tests/golden/*) — CI
re-measures and gates on it, so its structure must stay loadable and
the regression comparator must actually fire on a regressed ratio.
"""
import copy
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__)))))

from benchmarks.snapshot import (BENCH_SCHEMA, REGRESSION_TOL,  # noqa: E402
                                 SNAPSHOT_PATH, check_regression,
                                 load_snapshot, validate_snapshot)


@pytest.fixture(scope="module")
def committed():
    assert os.path.exists(SNAPSHOT_PATH), (
        "BENCH_fleet.json must be committed at the repo root "
        "(python -m benchmarks.bench_fleet --rollout writes it)")
    return load_snapshot()


def test_committed_snapshot_validates(committed):
    assert committed["schema"] == BENCH_SCHEMA
    ns = sorted(int(c["n"]) for c in committed["cells"])
    assert ns == [8, 64, 256]
    for c in committed["cells"]:
        assert c["rollout_sessions_per_sec"] > 0
        assert "roofline" in c and "bottleneck" in c["roofline"]


def test_validator_rejects_corruption(committed):
    for mutate in (
        lambda d: d.update(schema="artic.bench.snapshot/v0"),
        lambda d: d.pop("cells"),
        lambda d: d["cells"][0].pop("median_ratio"),
        lambda d: d["cells"][0].update(rollout_sessions_per_sec=0.0),
        lambda d: d["cells"][0]["roofline"].pop("bottleneck"),
        lambda d: d["machine"].pop("jax"),
    ):
        doc = copy.deepcopy(committed)
        mutate(doc)
        with pytest.raises(ValueError):
            validate_snapshot(doc)


def test_regression_gate_fires_on_ratio_drop(committed):
    fresh = copy.deepcopy(committed)
    assert check_regression(committed, fresh) == []
    # a drop just inside the tolerance passes ...
    ok = copy.deepcopy(committed)
    ok["cells"][0]["median_ratio"] *= (1.0 - REGRESSION_TOL + 0.02)
    assert check_regression(committed, ok) == []
    # ... past it fails, naming the N that regressed
    bad = copy.deepcopy(committed)
    bad["cells"][0]["median_ratio"] *= (1.0 - REGRESSION_TOL - 0.05)
    failures = check_regression(committed, bad)
    assert len(failures) == 1
    assert f"N={bad['cells'][0]['n']}" in failures[0]


def test_gate_ignores_machine_dependent_absolutes(committed):
    """Absolutes (sessions/sec) may move arbitrarily across runners —
    only the same-process rollout/eager ratio is gated."""
    fresh = copy.deepcopy(committed)
    for c in fresh["cells"]:
        c["eager_sessions_per_sec"] *= 0.1
        c["rollout_sessions_per_sec"] *= 0.1
    assert check_regression(committed, fresh) == []

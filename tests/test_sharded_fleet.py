"""Device-sharded fleet parity suite — the sharding acceptance contract.

A fleet sharded over 8 virtual CPU devices must be BIT-identical to the
single-device fleet, across system variants, padded (non-divisible) N,
the fused plan+encode path, and mixed cohort grids through
run_scenarios(mesh=...).  The heavy lifting happens in one subprocess
(tests/_sharded_fleet_child.py) because jax fixes the host device count
at import; the child asserts the sharded-vs-unsharded parity in-process
and reports digests, and this module additionally checks that the
child's *unsharded* run matches a run in THIS process — so the forced
multi-device environment itself provably doesn't shift numerics.

Quick (non-subprocess) tests cover the partition rules and the
degenerate single-device mesh; the subprocess cases are marked `slow`
(CI's quick lane runs -m "not slow"; the dedicated sharded-parity job
and the full tier-1 run include them).
"""
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

import _builders as B
from repro.core.fleet import Fleet, run_fleet
from repro.distributed.sharding import pad_sessions, session_partition
from repro.launch.mesh import make_fleet_mesh

DEVICES = 8
CASES = ("variants_n8", "padded_n12", "n64", "fused_n8",
         "rollout_n8", "rollout_pad_n12", "rollout_ondev_n8",
         "rollout_ondev_pad_n12", "mixed_grid")


# --------------------------------------------------------------------------
# Partition rules (pure, no devices needed)
# --------------------------------------------------------------------------
def test_pad_sessions_rounds_up_to_axis_multiple():
    assert pad_sessions(8, 8) == 8
    assert pad_sessions(12, 8) == 16
    assert pad_sessions(1, 8) == 8
    assert pad_sessions(64, 1) == 64
    with pytest.raises(ValueError):
        pad_sessions(0, 8)


def test_session_partition_prefers_data_axis():
    mesh = jax.sharding.Mesh(
        np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    # a 1-way data axis is no partition at all
    assert session_partition(mesh) == (None, 1)


def test_single_device_mesh_degenerates_to_unsharded(fleet_member,
                                                     metrics_equal):
    """make_fleet_mesh over one device: Fleet accepts it, runs the plain
    unsharded path (no padding), and matches the mesh-less fleet."""
    mesh = make_fleet_mesh(1)
    fl = Fleet([fleet_member(k, 2.0, hw=64) for k in range(2)], mesh=mesh)
    assert fl.mesh is None and fl.pad == 0 and fl.n_pad == fl.n
    base = run_fleet([fleet_member(k, 2.0, hw=64) for k in range(2)])
    for a, b in zip(base, fl.run()):
        metrics_equal(a, b)


# --------------------------------------------------------------------------
# The 8-virtual-device subprocess suite
# --------------------------------------------------------------------------
@pytest.fixture(scope="module")
def child_result(virtual_devices):
    child = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                         "_sharded_fleet_child.py")
    r = subprocess.run([sys.executable, child, str(DEVICES)],
                       capture_output=True, text=True, timeout=1500,
                       env=virtual_devices(DEVICES), cwd=B.ROOT)
    assert r.returncode == 0, (r.stderr[-4000:] or r.stdout[-4000:])
    lines = [ln for ln in r.stdout.splitlines() if ln.startswith("RESULT ")]
    assert lines, f"no RESULT line in child stdout:\n{r.stdout[-2000:]}"
    return json.loads(lines[-1][len("RESULT "):])


@pytest.mark.slow
def test_child_saw_forced_device_count(child_result):
    assert child_result["devices"] == DEVICES
    assert set(child_result["cases"]) == set(CASES)
    # the child proves the mesh engaged; pin the padding it reported
    assert child_result["cases"]["variants_n8"]["pad"] == 0
    assert child_result["cases"]["padded_n12"]["pad"] == 4
    assert child_result["cases"]["n64"]["pad"] == 0
    assert child_result["cases"]["rollout_pad_n12"]["pad"] == 4


@pytest.mark.slow
@pytest.mark.parametrize("case", CASES)
def test_sharded_bit_identical_to_single_device(child_result, case):
    info = child_result["cases"][case]
    assert info["equal"], f"{case}: {info['detail']}"


@pytest.mark.slow
def test_multi_device_process_matches_this_process(child_result):
    """The unsharded run inside the 8-device process is bit-identical to
    the same run in THIS process — forcing virtual devices does not
    shift numerics, so the in-child parity assertions carry over to this
    environment.  Must mirror the child's padded_n12 case exactly
    (n=12, duration=4.0, hw=64)."""
    local = run_fleet([B.hetero_fleet_session(k, 4.0, hw=64)
                       for k in range(12)])
    assert B.metrics_digest(local) == \
        child_result["cases"]["padded_n12"]["digest"]

"""Open-loop churn workload tests: seeded arrival/lifetime processes,
the admission layer over the fleet's dead-slot machinery, slot-revival
isolation, and end-to-end digest determinism on both server paths."""
import dataclasses
import json
import math

import numpy as np
import pytest

from repro.core.churn import (ChurnConfig, arrival_times, run_churn,
                              sample_lifetimes, validate_churn_result_json)
from repro.core.scenario import ScenarioSpec, build_session, run_scenarios


def _churn_spec(**over):
    kw = dict(scene="retail", frame_h=64, frame_w=64, duration=6.0,
              qa="periodic",
              qa_kwargs=dict(start=0.5, period=1.0, answer_window=0.7,
                             count=5),
              workload="churn",
              churn_kwargs=dict(rate=1.0, slots=2, mean_lifetime=2.0,
                                seed=7))
    kw.update(over)
    return ScenarioSpec(**kw)


# --------------------------------------------------------------------------
# Arrival / lifetime processes
# --------------------------------------------------------------------------
def test_arrival_processes_are_seeded_and_bounded():
    cfg = ChurnConfig(rate=2.0, seed=11)
    a1 = arrival_times(cfg, 30.0)
    a2 = arrival_times(cfg, 30.0)
    np.testing.assert_array_equal(a1, a2)
    assert len(a1) > 0
    assert np.all(np.diff(a1) > 0) and a1[0] > 0 and a1[-1] < 30.0
    # a different seed is a different process
    assert not np.array_equal(
        a1, arrival_times(ChurnConfig(rate=2.0, seed=12), 30.0))
    # rough rate sanity: 2/s over 30 s ~ 60 arrivals
    assert 30 <= len(a1) <= 100


def test_diurnal_arrivals_modulate_rate():
    cfg = ChurnConfig(arrival="diurnal", rate=4.0, period=20.0, depth=0.8,
                      seed=3, max_arrivals=512)
    a = arrival_times(cfg, 20.0)
    np.testing.assert_array_equal(a, arrival_times(cfg, 20.0))
    # intensity peaks in the first half-period (sin > 0) and troughs in
    # the second — the thinned process must reflect that asymmetry
    first, second = np.sum(a < 10.0), np.sum(a >= 10.0)
    assert first > second
    # depth=0 degenerates to homogeneous Poisson statistics
    flat = arrival_times(dataclasses.replace(cfg, depth=0.0), 20.0)
    assert len(flat) > 0


def test_lifetimes_seeded_and_floored():
    cfg = ChurnConfig(lifetime="exponential", mean_lifetime=2.0,
                      min_lifetime=1.0, seed=5)
    l1 = sample_lifetimes(cfg, 64)
    np.testing.assert_array_equal(l1, sample_lifetimes(cfg, 64))
    assert np.all(l1 >= 1.0)
    # lifetimes draw from their own stream: more arrivals extend, not
    # reshuffle, the prefix
    np.testing.assert_array_equal(l1, sample_lifetimes(cfg, 128)[:64])
    assert np.all(sample_lifetimes(
        dataclasses.replace(cfg, lifetime="fixed"), 8) == 2.0)
    uni = sample_lifetimes(dataclasses.replace(cfg, lifetime="uniform"), 64)
    assert np.all((uni >= 1.0) & (uni <= 3.0))


def test_churn_config_validation():
    with pytest.raises(ValueError, match="arrival"):
        ChurnConfig(arrival="bursty")
    with pytest.raises(ValueError, match="lifetime"):
        ChurnConfig(lifetime="pareto")
    with pytest.raises(ValueError, match="rate"):
        ChurnConfig(rate=0.0)
    with pytest.raises(ValueError, match="slots"):
        ChurnConfig(slots=0)
    with pytest.raises(ValueError, match="min_lifetime"):
        ChurnConfig(mean_lifetime=1.0, min_lifetime=2.0)
    with pytest.raises(ValueError, match="depth"):
        ChurnConfig(depth=1.5)


# --------------------------------------------------------------------------
# Spec plumbing
# --------------------------------------------------------------------------
def test_churn_spec_round_trip_and_validation():
    spec = _churn_spec()
    back = ScenarioSpec.from_dict(spec.to_dict())
    assert back == spec
    back2 = ScenarioSpec.from_dict(json.loads(json.dumps(spec.to_dict())))
    assert back2 == spec
    with pytest.raises(ValueError, match="workload"):
        ScenarioSpec(workload="openloop")
    with pytest.raises(ValueError, match="churn_kwargs"):
        ScenarioSpec(churn_kwargs=dict(rate=1.0))
    with pytest.raises(ValueError, match="run_churn needs"):
        run_churn(ScenarioSpec())


def test_churn_and_fixed_specs_cannot_mix():
    with pytest.raises(ValueError, match="cannot mix"):
        run_scenarios([_churn_spec(), ScenarioSpec(frame_h=64, frame_w=64)])


# --------------------------------------------------------------------------
# Slot-revival isolation: a slot's successive tenants never observe each
# other's state
# --------------------------------------------------------------------------
def test_slot_revival_is_isolated_from_previous_tenant():
    """Run B in a slot that previously hosted A (plus zombie ticks)
    vs. run B in a slot that was dead from tick 0: every per-lane bank
    reset at activate() must make B's telemetry bit-identical."""
    from repro.core.fleet import Fleet

    base = ScenarioSpec(scene="retail", frame_h=64, frame_w=64,
                        duration=6.0, cc_kind="gcc", qa="none")
    member_a = build_session(base.with_(scene_seed=1, trace_seed=1, seed=1),
                             None)
    dt = 1.0 / base.fps
    n = lambda s: int(round(s / dt))

    def drive(with_tenant_a: bool):
        fleet = Fleet([build_session(
            base.with_(scene_seed=1, trace_seed=1, seed=1), None)])
        if not with_tenant_a:
            fleet.deactivate(0, 0.0)
        for i in range(n(2.0)):                    # [0, 2): A live or dead
            t = i * dt
            if with_tenant_a and t >= 1.0 and fleet.alive[0]:
                fleet.deactivate(0, t)             # A departs at 1.0
            fleet.tick(t)
        member_b = build_session(
            base.with_(scene_seed=9, trace_seed=9, seed=9), None)
        fleet.activate(0, member_b, 2.0)
        for i in range(n(2.0), n(6.0)):            # [2, 6): B live
            fleet.tick(i * dt)
        return fleet.deactivate(0, 6.0)

    mb1, mb2 = drive(True), drive(False)
    assert mb1.latencies == mb2.latencies
    assert mb1.rates == mb2.rates
    assert mb1.confidences == mb2.confidences
    assert mb1.dropped_frames == mb2.dropped_frames
    assert mb1.zeco_engaged_frames == mb2.zeco_engaged_frames
    assert mb1.avg_bitrate == pytest.approx(mb2.avg_bitrate, rel=0, abs=0)


def test_activate_rejects_mismatched_member():
    from repro.core.fleet import Fleet

    base = ScenarioSpec(scene="retail", frame_h=64, frame_w=64,
                        duration=4.0, cc_kind="gcc", qa="none")
    fleet = Fleet([build_session(base, None)])
    with pytest.raises(ValueError, match="still live"):
        fleet.activate(0, build_session(base, None), 0.0)
    fleet.deactivate(0, 0.0)
    with pytest.raises(ValueError, match="already dead"):
        fleet.deactivate(0, 0.0)
    bad_cc = build_session(base.with_(cc_kind="bbr"), None)
    with pytest.raises(ValueError, match="cc_kind|membership"):
        fleet.activate(0, bad_cc, 0.0)


# --------------------------------------------------------------------------
# End-to-end: oracle and engine churn runs
# --------------------------------------------------------------------------
def test_oracle_churn_end_to_end_digest_identical():
    spec = _churn_spec()
    r1 = run_scenarios([spec]).results[0]
    r2 = run_scenarios([spec]).results[0]
    cfg = ChurnConfig.from_spec(spec)
    assert r1.offered > cfg.slots          # open-loop: arrivals exceed slots
    assert r1.served >= 1
    assert r1.offered == r1.served + r1.unserved
    assert r1.digest() == r2.digest()
    s = r1.summary()
    assert s["sessions_per_sec"] > 0
    assert s["queue_depth_peak"] >= 0
    assert math.isnan(s["ttft_p50_ms"])    # oracle: no engine telemetry
    assert all(rec.admitted >= rec.arrival for rec in r1.records)
    # every served session's QA fell inside its own tenancy
    for rec in r1.records:
        assert rec.departed <= spec.duration
        assert rec.metrics is not None


def test_churn_result_json_round_trip(tmp_path):
    res = run_scenarios([_churn_spec()])
    doc = res.to_json(str(tmp_path / "churn.json"))
    validate_churn_result_json(doc)
    with open(tmp_path / "churn.json") as f:
        validate_churn_result_json(json.load(f))
    with pytest.raises(ValueError, match="schema"):
        validate_churn_result_json({"schema": "bogus"})
    bad = json.loads(json.dumps(doc))
    bad["scenarios"][0]["summary"].pop("sessions_per_sec")
    with pytest.raises(ValueError, match="sessions_per_sec"):
        validate_churn_result_json(bad)


def test_engine_slot_revival_isolated_under_eviction():
    """Churn + eviction: a tenant whose predecessor in the same engine
    slot streamed far past max_len (forcing sink+recent compactions of
    that slot's cache) must behave bit-identically to running in a
    never-used slot — stale evicted rows and re-rotated keys from the
    previous tenancy are invisible to the fresh session."""
    from repro.core.fleet import Fleet

    base = ScenarioSpec(scene="retail", frame_h=64, frame_w=64,
                        duration=6.0, cc_kind="gcc", qa="periodic",
                        qa_kwargs=dict(start=0.5, period=1.0,
                                       answer_window=0.7, count=5),
                        server="engine",
                        engine_kwargs=dict(max_len=64, step_dt=0.004))
    dt = 1.0 / base.fps
    n = lambda s: int(round(s / dt))
    engine_cfg = dict(base.engine_kwargs)

    def drive(with_tenant_a: bool):
        fleet = Fleet([build_session(
            base.with_(scene_seed=1, trace_seed=1, seed=1), None)],
            server="engine", engine_cfg=engine_cfg)
        ma = None
        if not with_tenant_a:
            fleet.deactivate(0, 0.0)
        for i in range(n(3.0)):                    # [0, 3): A live or dead
            t = i * dt
            if with_tenant_a and t >= 2.5 and fleet.alive[0]:
                ma = fleet.deactivate(0, t)        # A departs at 2.5
            fleet.tick(t)
        member_b = build_session(
            base.with_(scene_seed=9, trace_seed=9, seed=9), None)
        fleet.activate(0, member_b, 3.0)
        for i in range(n(3.0), n(6.0)):            # [3, 6): B live
            fleet.tick(i * dt)
        return ma, fleet.deactivate(0, 6.0)

    ma, mb1 = drive(True)
    _, mb2 = drive(False)
    # tenant A really exercised the eviction path in the shared slot
    assert ma.server_evictions > 0 and ma.server_rollovers == 0
    assert mb1.qa_results == mb2.qa_results
    assert mb1.server_confidences == mb2.server_confidences
    assert mb1.server_ttfts == mb2.server_ttfts
    assert mb1.latencies == mb2.latencies
    assert (mb1.server_evictions, mb1.server_rollovers) == \
        (mb2.server_evictions, mb2.server_rollovers)


def test_engine_churn_end_to_end(tmp_path):
    spec = _churn_spec(
        duration=4.0, server="engine",
        qa_kwargs=dict(start=0.5, period=1.0, answer_window=0.7, count=3),
        churn_kwargs=dict(rate=1.5, slots=2, mean_lifetime=1.5, seed=3))
    res1, res2 = run_scenarios([spec]), run_scenarios([spec])
    r1 = res1.results[0]
    assert r1.offered > 2 and r1.served >= 1
    assert r1.digest() == res2.results[0].digest()
    # engine telemetry flows into the churn records: at least one served
    # session answered a query through the engine
    assert any(rec.metrics.n_qa > 0 for rec in r1.records)
    assert any(rec.metrics.server_ttfts for rec in r1.records)
    s = r1.summary()
    assert s["ttft_p50_ms"] > 0.0
    validate_churn_result_json(res2.to_json())

"""ZeCoStreamBank tests: Eq. 3/4 invariants, the batched jitted kernel
vs the NumPy reference, array-native feedback packets, the split
engage-decision/application fix, non-divisible patch-grid coverage, and
the exact N=4 parity of the bank against legacy per-session ZeCoStream
objects on identical feedback streams."""
import numpy as np
import pytest

from _builders import random_timed_boxes
from repro.core.grounding import TrajectoryPredictor
from repro.core.zecostream import (TimedBoxes, ZeCoStream, ZeCoStreamBank,
                                   boxes_to_array, importance_map, qp_map,
                                   reference_surface, surfaces_from_boxes)


def _surf(boxes, hw, **kw):
    arr, count = boxes_to_array(boxes)
    out = surfaces_from_boxes(arr[None], np.asarray([count], np.int32),
                              np.asarray([True]), frame_hw=hw, **kw)
    return np.asarray(out)[0]


# --------------------------------------------------------------------------
# Eq. 3 / Eq. 4 invariants on the batched kernel
# --------------------------------------------------------------------------
def test_eq3_rho_is_one_inside_box():
    rho = importance_map([(64, 64, 192, 192)], (256, 256), patch=64)
    assert rho[1, 1] == pytest.approx(1.0) and rho[2, 2] == pytest.approx(1.0)
    # kernel: blocks inside the box sit at the surface minimum (Qmin side)
    surf = _surf([(64, 64, 192, 192)], (256, 256))
    inside = surf[10, 10]
    assert inside == surf.min()


def test_eq3_monotone_decay_with_distance():
    surf = _surf([(0, 0, 32, 32)], (256, 256))
    # walking away from the box along the diagonal, QP never decreases
    diag = np.asarray([surf[i, i] for i in range(4, 32, 4)])
    assert np.all(np.diff(diag) >= 0)
    rho = importance_map([(0, 0, 32, 32)], (256, 256), patch=64)
    rdiag = np.asarray([rho[0, 0], rho[1, 1], rho[2, 2], rho[3, 3]])
    assert np.all(np.diff(rdiag) <= 0) and rho[0, 0] == pytest.approx(1.0)


def test_engaged_surface_is_zero_mean():
    rng = np.random.default_rng(0)
    for trial in range(3):
        y0, x0 = rng.uniform(0, 180, 2)
        surf = _surf([(y0, x0, y0 + 50, x0 + 50)], (256, 256))
        assert abs(float(surf.mean())) < 1e-4
        assert surf.std() > 0.1  # genuinely shaped, not uniform


def test_kernel_matches_numpy_reference():
    """Pin the jitted mask-over-boxes kernel to the pure-NumPy Eq. 3/4
    composition (importance_map -> qp_map -> upsample -> zero-mean)."""
    rng = np.random.default_rng(1)
    for hw, patch in [((256, 256), 64), ((128, 192), 32), ((64, 64), 16)]:
        boxes = []
        for _ in range(int(rng.integers(1, 5))):
            y0, x0 = rng.uniform(0, hw[0] - 40), rng.uniform(0, hw[1] - 40)
            boxes.append((y0, x0, y0 + rng.uniform(8, 40),
                          x0 + rng.uniform(8, 40)))
        want = reference_surface(boxes, hw, patch=patch)
        got = _surf(boxes, hw, patch=patch)
        np.testing.assert_allclose(got, want, atol=5e-5)


def test_kernel_box_padding_is_inert():
    """Extra padded box rows beyond `count` must not change the surface."""
    boxes = [(20.0, 20.0, 60.0, 60.0)]
    tight, count = boxes_to_array(boxes)
    padded, _ = boxes_to_array(boxes, capacity=16)
    padded[1:] = 777.0  # garbage in the padding rows
    a = surfaces_from_boxes(tight[None], np.asarray([count], np.int32),
                            np.asarray([True]), frame_hw=(256, 256))
    b = surfaces_from_boxes(padded[None], np.asarray([count], np.int32),
                            np.asarray([True]), frame_hw=(256, 256))
    assert np.array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------------
# Satellite: non-divisible patch grids are padded, not clipped
# --------------------------------------------------------------------------
def test_patch_grid_covers_nondivisible_frames():
    hw = (80, 96)  # 10 x 12 blocks; 64 does not divide either dimension
    rho = importance_map([(0, 0, 16, 16)], hw, patch=64)
    assert rho.shape == (2, 2)  # ceil grid, partial row/col kept
    surf = reference_surface([(0, 0, 16, 16)], hw, patch=64)
    assert surf.shape == (10, 12)  # every 8x8 block covered
    surf_k = _surf([(0, 0, 16, 16)], hw, patch=64)
    assert surf_k.shape == (10, 12)
    np.testing.assert_allclose(surf_k, surf, atol=5e-5)
    # trailing blocks carry the far-from-box penalty instead of vanishing
    assert surf[9, 11] > surf[0, 0]
    # and the legacy object path returns a full surface too
    z = ZeCoStream()
    z.on_feedback(TimedBoxes(times=np.asarray([0.0]),
                             boxes=[[(0, 0, 16, 16)]]))
    qp, engaged = z.qp_shape(0.0, hw, rate_bps=0.5e6)
    assert engaged and qp.shape == (10, 12)


def test_divisible_patch_grid_unchanged():
    rho = importance_map([(64, 64, 128, 128)], (256, 256), patch=64)
    assert rho.shape == (4, 4)
    assert reference_surface([(64, 64, 128, 128)], (256, 256)).shape == \
        (32, 32)


# --------------------------------------------------------------------------
# Satellite: engage decision split from its application
# --------------------------------------------------------------------------
def test_engage_decision_is_pure():
    z = ZeCoStream(trigger_bps=1.2e6, release_bps=1.6e6)
    assert z.engage_decision(1.0e6) and not z.active  # probe, no mutation
    assert z.engage_decision(1.0e6) and not z.active  # re-probe: no flap
    # decision uses the trigger threshold while inactive
    assert not z.engage_decision(1.4e6)
    z.active = True
    assert z.engage_decision(1.4e6)  # hysteresis band while active


def test_qp_shape_applies_decision_once_even_on_early_returns():
    z = ZeCoStream()
    # no feedback yet: early return, but the hysteresis state advances
    # exactly once (not engaged in the output)
    surf, engaged = z.qp_shape(0.0, (64, 64), rate_bps=1.0e6)
    assert not engaged and z.active
    assert np.all(surf == 0.0)
    # empty-boxes early return: same single application
    z.on_feedback(TimedBoxes(times=np.asarray([0.0]),
                             boxes=np.zeros((1, 0, 4), np.float32),
                             counts=np.zeros(1, np.int32)))
    surf, engaged = z.qp_shape(0.1, (64, 64), rate_bps=1.7e6)
    assert not engaged and not z.active  # released above release_bps


def test_bank_decide_engage_is_pure():
    bank = ZeCoStreamBank(3, (64, 64))
    rates = np.asarray([1.0e6, 1.4e6, 2.0e6])
    confs = np.full(3, 0.5)
    d1 = bank.decide_engage(rates, confs)
    d2 = bank.decide_engage(rates, confs)
    assert np.array_equal(d1, d2) and not bank.active.any()
    assert d1.tolist() == [True, False, False]
    bank.plan(0.0, rates, confs)  # application site
    assert bank.active.tolist() == [True, False, False]
    # hysteresis band now holds row 0 at 1.4e6
    assert bank.decide_engage(np.full(3, 1.4e6),
                              confs).tolist() == [True, False, False]


# --------------------------------------------------------------------------
# Array-native feedback packets
# --------------------------------------------------------------------------
def test_timedboxes_array_format():
    fb = TimedBoxes(times=[0.0, 1.0],
                    boxes=[[(1, 2, 3, 4)], [(5, 6, 7, 8), (1, 1, 2, 2)]])
    assert fb.boxes.shape == (2, 2, 4)
    assert fb.counts.tolist() == [1, 2]
    assert np.all(fb.boxes[0, 1] == 0)  # padding row
    arr, count = fb.at_arrays(1.2)
    assert count == 2
    assert np.array_equal(arr[0], np.asarray([5, 6, 7, 8], np.float32))
    assert fb.at(0.1) == [(1.0, 2.0, 3.0, 4.0)]


def test_trajectory_feedback_is_array_native():
    tp = TrajectoryPredictor()
    for i in range(4):
        t = i * 0.1
        tp.observe(t, [(10 + 20 * t, 10, 20 + 20 * t, 20),
                       (100, 100 + 10 * t, 120, 120 + 10 * t)])
    fb = tp.feedback(0.3, horizon=1.0, steps=5)
    assert fb.boxes.shape == (5, 2, 4)
    assert fb.counts.tolist() == [2] * 5
    for j, tr in enumerate(tp.tracks):
        want = np.asarray([tr.predict(float(tt)) for tt in fb.times],
                          np.float32)
        np.testing.assert_allclose(fb.boxes[:, j], want, rtol=1e-5,
                                   atol=1e-4)


def test_bank_capacity_grows_with_large_packets():
    bank = ZeCoStreamBank(2, (64, 64), box_capacity=2, time_capacity=2)
    big = TimedBoxes(times=np.linspace(0, 1.5, 6),
                     boxes=[[(i, i, i + 8, i + 8) for i in range(5)]] * 6)
    bank.on_feedback(1, big)
    assert bank.fb_boxes.shape[1] >= 6 and bank.fb_boxes.shape[2] >= 5
    boxes, counts = bank._select(0.0)
    assert counts.tolist() == [0, 5]
    # row 0 unaffected by the grow
    assert not bank.has_fb[0]


# --------------------------------------------------------------------------
# Tentpole acceptance: exact N=4 parity, bank vs legacy objects
# --------------------------------------------------------------------------
def test_bank_matches_legacy_objects_exact_n4():
    hw = (256, 256)
    n = 4
    rng = np.random.default_rng(7)
    legacy = [ZeCoStream() for _ in range(n)]
    bank = ZeCoStreamBank(n, hw)
    engaged_seen = 0
    for step in range(36):
        t = 0.1 * step
        if step % 3 == 0:  # a fresh feedback packet every 3 ticks
            for k in range(n):
                fb = random_timed_boxes(rng, t)
                legacy[k].on_feedback(fb)
                bank.on_feedback(k, fb)
        # rates sweep across trigger/release so hysteresis paths all fire
        rates = rng.uniform(0.5e6, 2.0e6, n)
        confs = rng.uniform(0.3, 1.0, n)
        surf_b, engaged_b = bank.plan(t, rates, confs)
        for k in range(n):
            s, e = legacy[k].qp_shape(t, hw, float(rates[k]),
                                      float(confs[k]))
            assert e == bool(engaged_b[k])
            assert np.array_equal(np.asarray(s), surf_b[k]), \
                f"surface mismatch at step {step}, session {k}"
            assert legacy[k].active == bool(bank.active[k])
        engaged_seen += int(engaged_b.sum())
    assert engaged_seen > 10  # context-aware sessions actually engaged
    # engaged-frame counters match what the legacy objects reported
    assert bank.engaged_total.sum() == engaged_seen

"""Artic core tests: ReCapABR (Eq. 1-2), ZeCoStream (Eq. 3-4),
grounding-then-prediction, confidence calibration, end-to-end session."""
from _hypothesis_compat import hypothesis, st  # noqa: hypothesis optional
import numpy as np
import pytest

from repro.core.confidence import PlattCalibrator, raw_score_from_telemetry
from repro.core.grounding import TrajectoryPredictor, detect_cards
from repro.core.recap_abr import CCOnlyABR, ReCapABR
from repro.core.session import QASample, SessionConfig, run_session
from repro.core.zecostream import (TimedBoxes, ZeCoStream, importance_map,
                                   qp_map)
from repro.net.traces import elevator_trace, fluctuating_trace, static_trace
from repro.video.scenes import make_scene


# --------------------------------------------------------------------------
# ReCapABR — Eq. 1 / Eq. 2 semantics
# --------------------------------------------------------------------------
def test_eq1_weight_signs_and_quadratic():
    abr = ReCapABR(tau=0.8, gamma=2.0)
    assert abr.weight(0.8) == pytest.approx(0.0)
    assert abr.weight(0.4) > 0           # struggling -> push rate up
    assert abr.weight(1.0) < 0           # saturated -> back off
    # gamma=2: quadratic scaling |delta|^2 with sign
    assert abr.weight(0.0) == pytest.approx(1.0)
    assert abr.weight(0.4) == pytest.approx(0.25)


def test_eq2_caps_at_bandwidth_on_congestion():
    abr = ReCapABR(init_rate=2e6)
    r = abr.update(confidence=0.2, bw_estimate=1e6)  # B_hat < R_t
    assert r == pytest.approx(1e6)


def test_eq2_holds_rate_when_saturated():
    """C_t > tau with ample bandwidth: rate must NOT chase the CC estimate."""
    abr = ReCapABR(init_rate=1e6)
    r = abr.update(confidence=0.95, bw_estimate=5e6)
    assert r < 1e6  # voluntarily decreases, reserving headroom
    base = CCOnlyABR(init_rate=1e6)
    assert base.update(0.95, 5e6) == pytest.approx(5e6)


def test_eq2_rises_toward_bandwidth_when_struggling():
    abr = ReCapABR(init_rate=5e5)
    r = abr.update(confidence=0.2, bw_estimate=4e6)
    assert 5e5 < r <= 4e6


@hypothesis.given(c=st.floats(0, 1), r=st.floats(2e5, 5e6),
                  b=st.floats(2e5, 8e6))
@hypothesis.settings(deadline=None, max_examples=60)
def test_property_eq2_never_exceeds_bandwidth(c, r, b):
    abr = ReCapABR(init_rate=r)
    out = abr.update(c, b)
    assert out <= max(b, abr.min_rate) + 1e-6


def test_equilibrium_at_tau():
    """C_t == tau is a fixed point of Eq. 2 (w_t = 0)."""
    abr = ReCapABR(init_rate=1e6)
    r = abr.update(confidence=0.8, bw_estimate=5e6)
    assert r == pytest.approx(1e6)


# --------------------------------------------------------------------------
# ZeCoStream — Eq. 3 / Eq. 4
# --------------------------------------------------------------------------
def test_eq3_importance_geometry():
    rho = importance_map([(64, 64, 128, 128)], (256, 256), patch=64, mu=0.5)
    # patch containing the box -> 1; far corner decays
    assert rho[1, 1] == pytest.approx(1.0)
    assert rho[3, 3] < rho[2, 2] < 1.0
    assert rho.min() >= 0.0 and rho.max() <= 1.0


def test_eq3_zero_beyond_half_diagonal():
    # box in one corner of a huge frame: opposite corner beyond mu*diag
    rho = importance_map([(0, 0, 8, 8)], (1024, 1024), patch=64, mu=0.25)
    assert rho[-1, -1] == 0.0


def test_eq4_qp_mapping_quadratic():
    rho = np.asarray([[1.0, 0.5, 0.0]])
    qp = qp_map(rho, 20, 51)
    assert qp[0, 0] == pytest.approx(20.0)       # inside box -> Qmin
    assert qp[0, 2] == pytest.approx(51.0)       # irrelevant -> Qmax
    assert qp[0, 1] == pytest.approx(20 + 31 * 0.25)  # quadratic midpoint


def test_trigger_hysteresis():
    z = ZeCoStream(trigger_bps=1.2e6, release_bps=1.6e6)
    assert not z.should_engage(2e6)
    assert z.should_engage(1.0e6)     # below trigger -> on
    assert z.should_engage(1.4e6)     # hysteresis: stays on below release
    assert not z.should_engage(1.7e6)  # above release -> off


def test_timedboxes_timestamp_matching():
    fb = TimedBoxes(times=np.asarray([1.0, 1.5, 2.0]),
                    boxes=[[(0, 0, 1, 1)], [(10, 10, 20, 20)], [(5, 5, 6, 6)]])
    assert fb.at(1.4) == [(10, 10, 20, 20)]


# --------------------------------------------------------------------------
# Grounding-then-prediction
# --------------------------------------------------------------------------
def test_trajectory_prediction_constant_velocity():
    tp = TrajectoryPredictor()
    for i in range(5):
        t = i * 0.1
        tp.observe(t, [(10 + 20 * t, 5 + 10 * t, 20 + 20 * t, 15 + 10 * t)])
    fb = tp.feedback(0.4, horizon=1.0, steps=3)
    pred = fb.at(1.4)  # 1 second into the future
    assert len(pred) == 1
    y0, x0, y1, x1 = pred[0]
    assert abs(y0 - (10 + 20 * 1.4)) < 2.0
    assert abs(x0 - (5 + 10 * 1.4)) < 1.5


def test_detect_cards_finds_glyph_cards():
    sc = make_scene("retail", False, seed=0, h=256, w=256)
    boxes = detect_cards(sc.render(0))
    assert len(boxes) >= 1
    # each detected box overlaps a true object card
    hits = 0
    for (y0, x0, y1, x1) in boxes:
        for obj in sc.objects:
            oy0, ox0, oy1, ox1 = obj.bbox(0)
            if not (y1 < oy0 - 8 or oy1 + 8 < y0 or x1 < ox0 - 8 or ox1 + 8 < x0):
                hits += 1
                break
    assert hits == len(boxes)


# --------------------------------------------------------------------------
# Confidence
# --------------------------------------------------------------------------
def test_platt_calibration_orders_scores():
    rng = np.random.default_rng(0)
    scores = rng.uniform(0, 1, 400)
    correct = (scores + 0.1 * rng.standard_normal(400)) > 0.5
    cal = PlattCalibrator().fit(scores, correct)
    assert cal(0.9) > 0.7 and cal(0.1) < 0.3


def test_telemetry_score_tracks_certainty():
    hi = raw_score_from_telemetry([0.95, 0.9], [0.2, 0.3], vocab=1000)
    lo = raw_score_from_telemetry([0.2, 0.3], [5.0, 5.5], vocab=1000)
    assert hi > 0.8 > 0.5 > lo


# --------------------------------------------------------------------------
# End-to-end session
# --------------------------------------------------------------------------
def _qa(scene, n=6, t0=10.0, dt=5.0):
    return [QASample(t_ask=t0 + i * dt, obj_idx=i % len(scene.objects))
            for i in range(n)]


def test_session_runs_and_reports():
    sc = make_scene("retail", True, seed=0)
    tr = static_trace(30.0, mbps=3.0)
    m = run_session(sc, _qa(sc, 4), tr,
                    SessionConfig(duration=30.0, use_recap=True, use_zeco=True))
    assert len(m.latencies) == 300
    assert 0.0 <= m.accuracy <= 1.0
    assert m.avg_latency_ms < 500


def test_recap_reserves_headroom_on_static_link():
    """With ample bandwidth + saturated confidence, ReCapABR's offered rate
    must sit well below the CC estimate (the Fig. 2 contrast)."""
    sc = make_scene("retail", False, seed=1)
    tr = static_trace(40.0, mbps=5.0)
    base = run_session(sc, [], tr, SessionConfig(
        duration=40.0, use_recap=False, use_zeco=False))
    recap = run_session(sc, [], tr, SessionConfig(
        duration=40.0, use_recap=True, use_zeco=False))
    # after convergence (last 10s), ReCapABR offered rate < baseline's
    assert np.mean(recap.rates[-100:]) < 0.75 * np.mean(base.rates[-100:])


def test_recap_cuts_latency_spike_on_elevator_drop():
    sc = make_scene("retail", False, seed=2)
    tr = elevator_trace(50.0)
    base = run_session(sc, [], tr, SessionConfig(
        duration=50.0, use_recap=False, use_zeco=False))
    recap = run_session(sc, [], tr, SessionConfig(
        duration=50.0, use_recap=True, use_zeco=False))
    # headroom absorbs the drop: lower average latency and fewer frames
    # lost to the drop-tail queue during the bandwidth collapse
    assert recap.avg_latency_ms < base.avg_latency_ms
    assert recap.dropped_frames <= base.dropped_frames


def test_zeco_helps_accuracy_under_low_bandwidth():
    sc = make_scene("retail", False, seed=3)
    tr = static_trace(40.0, mbps=0.35)  # starved uplink
    qa = _qa(sc, 6, t0=15.0, dt=4.0)
    plain = run_session(sc, qa, tr, SessionConfig(
        duration=40.0, use_recap=False, use_zeco=False, seed=1))
    zeco = run_session(sc, qa, tr, SessionConfig(
        duration=40.0, use_recap=False, use_zeco=True, seed=1))
    assert zeco.zeco_engaged_frames > 0
    assert zeco.accuracy >= plain.accuracy

"""Distribution-layer tests: logical-axis resolution, divisibility
fallback, rules contexts, sharded train/decode on a real (multi-device
host) mesh via subprocess, and dry-run cell smoke via subprocess."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.distributed import sharding as sh

SIZES = {"data": 16, "model": 16}


def test_resolve_divisibility_fallback():
    # 8 kv heads cannot shard over 16-way model -> replicated
    assert sh.resolve_axis("kv_heads", 8, SIZES) is None
    assert sh.resolve_axis("kv_heads", 32, SIZES) == "model"
    # embed prefers (pod,data) but falls back to data without a pod axis
    assert sh.resolve_axis("embed", 4096, SIZES) == "data"
    assert sh.resolve_axis("embed", 4096, {"pod": 2, **SIZES}) == ("pod", "data")


def test_pspec_no_duplicate_mesh_axes():
    spec = sh.logical_to_pspec(("heads", "mlp"), (32, 4096), SIZES)
    # both want `model`; only the first gets it
    assert spec[0] == "model" and (len(spec) < 2 or spec[1] is None)


def test_rules_context_override():
    with sh.rules_context({**sh.DEFAULT_RULES, "embed": (None,)}):
        assert sh.resolve_axis("embed", 4096, SIZES) is None
    assert sh.resolve_axis("embed", 4096, SIZES) == "data"


def test_use_mesh_shim_is_a_context_manager():
    """The version-compat shim must yield a usable context on the
    installed JAX regardless of which mesh API it exposes."""
    from repro.launch.mesh import use_mesh
    mesh = jax.sharding.Mesh(
        np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    with use_mesh(mesh):
        pass


def test_is_axes_leaf():
    from repro.training.train_loop import TrainState
    assert sh.is_axes_leaf(("embed", None))
    assert sh.is_axes_leaf(())
    assert not sh.is_axes_leaf(TrainState(params=None, opt_state=None,
                                          step=None, compress=None))
    assert not sh.is_axes_leaf(({"a": 1},))


SUBPROCESS_SHARDED = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import registry
    from repro.distributed import sharding as sh
    from repro.launch.mesh import make_debug_mesh, use_mesh
    from repro.models import transformer as tfm
    from repro.models.config import reduced
    from repro.training.train_loop import TrainSettings, init_state, make_train_step

    cfg = reduced(registry.get_config("qwen3-0.6b"), dtype="float32",
                  param_dtype="float32", vocab=64, d_model=64, n_heads=4,
                  n_kv_heads=2, head_dim=16, d_ff=128)
    mesh = make_debug_mesh((2, 2), ("data", "model"))
    s = TrainSettings(peak_lr=1e-3, warmup_steps=1, total_steps=10)
    with use_mesh(mesh):
        state = init_state(jax.random.PRNGKey(0), cfg, s)
        p_sh = sh.make_shardings(tfm.axes(cfg),
                                 jax.eval_shape(lambda: tfm.init(jax.random.PRNGKey(0), cfg)),
                                 mesh)
        params = jax.tree.map(lambda a, shd: jax.device_put(a, shd),
                              state.params, p_sh)
        state = state._replace(params=params)
        step = jax.jit(make_train_step(cfg, s))
        tok = jnp.zeros((4, 16), jnp.int32)
        state2, m = step(state, {"tokens": tok, "labels": tok})
        assert np.isfinite(float(m["loss"])), m
        # unsharded reference must agree
    state_ref = init_state(jax.random.PRNGKey(0), cfg, s)
    step_ref = jax.jit(make_train_step(cfg, s))
    state_ref2, m_ref = step_ref(state_ref, {"tokens": tok, "labels": tok})
    np.testing.assert_allclose(float(m["loss"]), float(m_ref["loss"]),
                               rtol=1e-4)
    print("SHARDED_OK", float(m["loss"]))
""")


def test_sharded_train_step_matches_unsharded():
    """4 host devices, (2,2) mesh: sharded step == single-device step."""
    r = subprocess.run([sys.executable, "-c", SUBPROCESS_SHARDED],
                       capture_output=True, text=True, timeout=300,
                       env={**os.environ, "PYTHONPATH": "src"})
    assert "SHARDED_OK" in r.stdout, r.stderr[-3000:]


@pytest.mark.slow
def test_dryrun_single_cell_subprocess():
    """Full dry-run path on the cheapest cell (proves the CLI contract)."""
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "mamba2-780m",
         "--shape", "long_500k", "--mesh", "single", "--no-probe",
         "--out-dir", "/tmp/dryrun_test"],
        capture_output=True, text=True, timeout=900,
        env={**os.environ, "PYTHONPATH": "src"})
    assert r.returncode == 0, r.stderr[-3000:]
    rec = json.load(open("/tmp/dryrun_test/mamba2-780m__long_500k__single.json"))
    assert rec["chips"] == 256
    assert rec["roofline"]["bottleneck"] in ("compute", "memory", "collective")

"""Channel, trace and congestion-control tests, including the net-layer
property tests (bit conservation through the drop-tail queues,
non-negative queueing delay, CC-bank rate bounds + serial parity) under
random trace seeds via the hypothesis compat shim."""
import numpy as np
import pytest

from _hypothesis_compat import HAVE_HYPOTHESIS, hypothesis, st
from repro.net import traces
from repro.net.cc import (BBR, GCC, RATE_MAX, RATE_MIN, BBRBank, GCCBank,
                          make_cc, make_cc_bank)
from repro.net.channel import MTU_BITS, Channel, ChannelBank


def test_static_trace_levels():
    t = traces.static_trace(10.0, mbps=5.0)
    assert 4.0e6 < np.mean(t.bw) < 6.0e6


def test_elevator_trace_drops():
    t = traces.elevator_trace(60.0)
    before = t.at(20.0)
    during = t.at(30.0)
    assert before > 4e6 and during < 1.6e6


def test_fluctuating_trace_switches():
    t = traces.fluctuating_trace(120.0, switches_per_min=6, seed=1)
    lv = np.unique(np.round(t.bw / 1e5))
    assert len(lv) > 3  # actually visits multiple levels


def test_channel_latency_low_when_underloaded():
    t = traces.static_trace(10.0, mbps=5.0, jitter=0.0)
    ch = Channel(t)
    rep = ch.send_frame(0.0, 1e5)  # 100 kbit over 5 Mbps -> 20 ms
    assert 0.01 < rep.latency < 0.05


def test_channel_queue_builds_under_overload():
    t = traces.static_trace(20.0, mbps=1.0, jitter=0.0)
    ch = Channel(t)
    lat = [ch.send_frame(i * 0.1, 3e5).latency for i in range(30)]
    finite = [l for l in lat if np.isfinite(l)]
    assert finite[-1] > finite[0]  # latency grows with backlog
    assert any(r.dropped for r in ch.reports)  # drop-tail eventually kicks in


def test_channel_droptail_caps_queue():
    t = traces.static_trace(5.0, mbps=0.5, jitter=0.0)
    ch = Channel(t)
    for i in range(20):
        ch.send_frame(i * 0.01, 1e6)
    assert ch._queue_pkts <= ch.queue_packets


def test_gcc_backs_off_on_delay_growth():
    cc = GCC(init_rate=2e6)
    r1 = cc.estimate({"delivery_rate": 2e6, "avg_latency": 0.05,
                      "min_latency": 0.04, "loss": 0.0})
    # sudden queue growth
    r2 = cc.estimate({"delivery_rate": 1e6, "avg_latency": 0.5,
                      "min_latency": 0.04, "loss": 0.0})
    assert r2 < r1


def test_gcc_probes_up_when_clear():
    cc = GCC(init_rate=1e6)
    r = 1e6
    for _ in range(20):
        r = cc.estimate({"delivery_rate": r, "avg_latency": 0.05,
                         "min_latency": 0.05, "loss": 0.0})
    assert r > 1.5e6  # multiplicative probe raised the rate


def test_bbr_tracks_bottleneck():
    cc = BBR(init_rate=5e5)
    for _ in range(12):
        est = cc.estimate({"delivery_rate": 2e6, "avg_latency": 0.06,
                           "min_latency": 0.05, "loss": 0.0})
    assert 1.4e6 < est < 2.6e6


# --------------------------------------------------------------------------
# Property tests (random trace seeds via the hypothesis compat shim)
# --------------------------------------------------------------------------
def _random_traces(seed: int, duration: float = 12.0):
    """A mixed-family trace bank keyed off one seed."""
    return [traces.static_trace(duration, mbps=0.3 + (seed % 5) * 0.4,
                                seed=seed),
            traces.fluctuating_trace(duration, switches_per_min=4 + seed % 8,
                                     seed=seed + 1),
            traces.mobility_trace(("walking", "driving")[seed % 2],
                                  duration, seed=seed + 2),
            traces.elevator_trace(duration)]


@hypothesis.given(seed=st.integers(min_value=0, max_value=10_000),
                  load=st.floats(min_value=0.2, max_value=3.0))
@hypothesis.settings(max_examples=12, deadline=None)
def test_channel_bank_conserves_bits(seed, load):
    """Drop-tail conservation per tick: what the sender offers either
    enters the queue (bits_delivered), or is dropped at the tail —
    nothing appears or vanishes.  Checked against the backlog directly:
    after every send, queue == drained queue + admitted bits, and the
    queue never exceeds its packet cap; queueing delay is never
    negative; `dropped` is set exactly when delivered < sent."""
    rng = np.random.default_rng(seed)
    bank = ChannelBank(_random_traces(seed))
    sent = np.zeros(bank.n)
    delivered = np.zeros(bank.n)
    serviced_total = np.zeros(bank.n)
    for i in range(60):
        t = i * 0.1
        q_before = bank.queue_bits.copy()
        bank._drain(t)             # what send_frames does first, observed
        q_mid = bank.queue_bits.copy()
        serviced = q_before - q_mid
        assert np.all(serviced >= -1e-9)      # draining only removes bits
        serviced_total += serviced
        bits = rng.uniform(2e3, load * 1e5, size=bank.n)
        rep = bank.send_frames(t, bits)
        # conservation: backlog grew by exactly the admitted bits (the
        # report truncates to whole bits; the un-dropped float amount is
        # the offered size, the dropped one a whole number of packets)
        admitted = np.where(rep.dropped, rep.bits_delivered, bits)
        np.testing.assert_allclose(bank.queue_bits, q_mid + admitted,
                                   atol=1e-6)
        assert np.all(rep.bits_delivered <= rep.bits_sent)
        assert np.array_equal(rep.dropped,
                              rep.bits_delivered < rep.bits_sent)
        assert np.all(rep.queue_delay >= 0.0)
        assert np.all(bank._queue_pkts <= bank.queue_packets)
        finite = np.isfinite(rep.latency)
        assert np.all(rep.latency[finite] >= 0.0)
        # latency is finite exactly when something was admitted: a
        # fully-dropped frame never gets one, an admitted frame always
        assert np.array_equal(finite, rep.bits_delivered > 0)
        sent += bits
        delivered += admitted
    dropped_bits = sent - delivered
    assert np.all(dropped_bits >= 0)
    # end-to-end: every admitted bit either departed or is still queued
    drained_total = delivered - bank.queue_bits
    assert np.all(drained_total >= -1e-6)
    # with enough idle time the queue drains completely, and the service
    # events (drain deltas, observed independently of the reports) must
    # then account for every report-admitted bit — the cross-ledger
    # conservation: nothing fabricated, nothing lost in the queues
    q_residual = bank.queue_bits.copy()
    bank._drain(bank.now + 300.0)
    np.testing.assert_allclose(bank.queue_bits, 0.0, atol=1e-6)
    serviced_total += q_residual - bank.queue_bits
    np.testing.assert_allclose(serviced_total, delivered, atol=1e-6)


@hypothesis.given(seed=st.integers(min_value=0, max_value=10_000))
@hypothesis.settings(max_examples=10, deadline=None)
def test_trace_bank_matches_member_traces(seed):
    """TraceBank.at is exactly the per-trace lookup at any timestamp."""
    trs = _random_traces(seed)
    bank = traces.TraceBank.stack(trs)
    rng = np.random.default_rng(seed)
    for t in rng.uniform(0.0, 30.0, size=16):
        got = bank.at(float(t))
        want = [tr.at(float(t)) for tr in trs]
        np.testing.assert_array_equal(got, want)


def _random_acks(rng, m):
    avg = rng.uniform(0.02, 0.6, m)
    return {"delivery_rate": rng.uniform(1e3, 1e7, m),
            "avg_latency": avg,
            "min_latency": avg * rng.uniform(0.3, 1.0, m),
            "loss": rng.uniform(0.0, 0.4, m),
            "app_limited": rng.choice([0.0, 1.0], m)}


@hypothesis.given(seed=st.integers(min_value=0, max_value=10_000),
                  kind=st.sampled_from(["gcc", "bbr"]))
@hypothesis.settings(max_examples=12, deadline=None)
def test_cc_bank_bounded_and_matches_serial(seed, kind):
    """Under arbitrary ack streams every bank estimate stays inside
    [RATE_MIN, RATE_MAX] and equals the serial GCC/BBR objects fed the
    same per-session ack dicts, element for element."""
    rng = np.random.default_rng(seed)
    m = 5
    bank = make_cc_bank(kind, m)
    assert isinstance(bank, {"gcc": GCCBank, "bbr": BBRBank}[kind])
    serial = [make_cc(kind) for _ in range(m)]
    for _ in range(25):
        ack = _random_acks(rng, m)
        got = bank.estimate(ack)
        assert np.all((got >= RATE_MIN) & (got <= RATE_MAX))
        want = [cc.estimate({key: float(val[k])
                             for key, val in ack.items()})
                for k, cc in enumerate(serial)]
        np.testing.assert_array_equal(got, want)

"""Channel, trace and congestion-control tests."""
import numpy as np
import pytest

from repro.net import traces
from repro.net.cc import BBR, GCC
from repro.net.channel import MTU_BITS, Channel


def test_static_trace_levels():
    t = traces.static_trace(10.0, mbps=5.0)
    assert 4.0e6 < np.mean(t.bw) < 6.0e6


def test_elevator_trace_drops():
    t = traces.elevator_trace(60.0)
    before = t.at(20.0)
    during = t.at(30.0)
    assert before > 4e6 and during < 1.6e6


def test_fluctuating_trace_switches():
    t = traces.fluctuating_trace(120.0, switches_per_min=6, seed=1)
    lv = np.unique(np.round(t.bw / 1e5))
    assert len(lv) > 3  # actually visits multiple levels


def test_channel_latency_low_when_underloaded():
    t = traces.static_trace(10.0, mbps=5.0, jitter=0.0)
    ch = Channel(t)
    rep = ch.send_frame(0.0, 1e5)  # 100 kbit over 5 Mbps -> 20 ms
    assert 0.01 < rep.latency < 0.05


def test_channel_queue_builds_under_overload():
    t = traces.static_trace(20.0, mbps=1.0, jitter=0.0)
    ch = Channel(t)
    lat = [ch.send_frame(i * 0.1, 3e5).latency for i in range(30)]
    finite = [l for l in lat if np.isfinite(l)]
    assert finite[-1] > finite[0]  # latency grows with backlog
    assert any(r.dropped for r in ch.reports)  # drop-tail eventually kicks in


def test_channel_droptail_caps_queue():
    t = traces.static_trace(5.0, mbps=0.5, jitter=0.0)
    ch = Channel(t)
    for i in range(20):
        ch.send_frame(i * 0.01, 1e6)
    assert ch._queue_pkts <= ch.queue_packets


def test_gcc_backs_off_on_delay_growth():
    cc = GCC(init_rate=2e6)
    r1 = cc.estimate({"delivery_rate": 2e6, "avg_latency": 0.05,
                      "min_latency": 0.04, "loss": 0.0})
    # sudden queue growth
    r2 = cc.estimate({"delivery_rate": 1e6, "avg_latency": 0.5,
                      "min_latency": 0.04, "loss": 0.0})
    assert r2 < r1


def test_gcc_probes_up_when_clear():
    cc = GCC(init_rate=1e6)
    r = 1e6
    for _ in range(20):
        r = cc.estimate({"delivery_rate": r, "avg_latency": 0.05,
                         "min_latency": 0.05, "loss": 0.0})
    assert r > 1.5e6  # multiplicative probe raised the rate


def test_bbr_tracks_bottleneck():
    cc = BBR(init_rate=5e5)
    for _ in range(12):
        est = cc.estimate({"delivery_rate": 2e6, "avg_latency": 0.06,
                           "min_latency": 0.05, "loss": 0.0})
    assert 1.4e6 < est < 2.6e6

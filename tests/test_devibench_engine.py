"""Vectorized DeViBench engine tests: exact parity against the pinned
serial pipeline, degradation-axis behavior, monotonicity properties,
the scenario-layer DeViBench RunResult (schema + golden saturation
snapshot), and the benchmark -> calibrator -> ReCap-ABR fitting loop."""
import dataclasses
import functools
import json
import os

import numpy as np
import pytest
from _hypothesis_compat import hypothesis, st  # noqa: hypothesis optional

from repro.api import (DegradationSpec, DeViBenchRunResult, ScenarioSpec,
                       fit_confidence_calibrator, preset, run_devibench,
                       run_scenarios, validate_devibench_json)
from repro.core.confidence import PlattCalibrator
from repro.core.recap_abr import (ReCapABR, fit_recap_params,
                                  saturation_point)
from repro.devibench import pipeline as dvb
from repro.devibench.engine import (bitrate_ladder, default_degradations,
                                    evaluate_records)

LADDER = [200.0, 400.0, 968.0, 1700.0, 4000.0]
GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "devibench_saturation.json")


@pytest.fixture(scope="module")
def bench():
    return dvb.generate(n_scenes_per_cat=1, questions_per_obj=2, seed=0,
                        n_frames=20)


@pytest.fixture(scope="module")
def bench_serial():
    return dvb.generate(n_scenes_per_cat=1, questions_per_obj=2, seed=0,
                        n_frames=20, engine="serial")


@pytest.fixture(scope="module")
def dvb_result() -> DeViBenchRunResult:
    base = preset("devibench")
    specs = [base.with_(degradation="bitrate",
                        degradation_kwargs=dict(kbps=k)) for k in LADDER]
    specs += [base.with_(degradation="requant",
                         degradation_kwargs=dict(kbps=4000.0, loss=0.5)),
              base.with_(degradation="drop",
                         degradation_kwargs=dict(kbps=4000.0,
                                                 stall_frames=5)),
              base.with_(degradation="downscale",
                         degradation_kwargs=dict(kbps=4000.0, scale=2))]
    return run_devibench(specs)


# --------------------------------------------------------------------------
# Exact parity with the pinned serial pipeline
# --------------------------------------------------------------------------
def test_generate_engines_bit_identical(bench, bench_serial):
    """The tentpole contract, construction side: the vectorized screen
    (steps 2+4+5 as one stacked grid) reproduces the serial per-record
    loop field for field — margins included, no tolerance."""
    for name in ("validation", "test"):
        ser, vec = getattr(bench_serial, name), getattr(bench, name)
        assert len(ser) == len(vec)
        for a, b in zip(ser, vec):
            assert dataclasses.asdict(a) == dataclasses.asdict(b)
    skip = ("build_time_s", "engine")
    assert {k: v for k, v in bench_serial.stats.items() if k not in skip} \
        == {k: v for k, v in bench.stats.items() if k not in skip}


def test_accuracy_grid_bit_identical_to_serial(bench, bench_serial):
    """The tentpole contract, evaluation side: the whole ladder as one
    stacked grid == the serial per-record `accuracy_at_bitrate` loop,
    aggregate accuracy bit for bit."""
    acc_serial = np.asarray([dvb.accuracy_at_bitrate(bench_serial, k)
                             for k in LADDER])
    acc_vec = dvb.accuracy_grid(bench, LADDER)
    np.testing.assert_array_equal(acc_serial, acc_vec)
    # the serial engine selector routes to the oracle
    np.testing.assert_array_equal(
        acc_serial, dvb.accuracy_grid(bench, LADDER, engine="serial"))


def test_grid_per_record_answers_bit_identical(bench):
    """Per-record answers AND margins of the vectorized grid match the
    serial `_encode_at` + `_answer` loop exactly (the fused
    DCT-sharing dispatch included)."""
    res = dvb.evaluate(bench, bitrate_ladder([400.0, 4000.0]))
    for j, kbps in enumerate((400.0, 4000.0)):
        for i, rec in enumerate(bench.test):
            sc = bench.scene(rec)
            rx = dvb._encode_at(sc.render(rec.t_frame), kbps)
            ans, margin = dvb._answer(sc, rec, rx)
            assert ans == res.answers[i, j]
            assert margin == res.margins[i, j]


def test_calibrator_engines_identical(bench, bench_serial):
    cal_s = dvb.fit_confidence_calibrator(bench_serial, engine="serial")
    cal_v = dvb.fit_confidence_calibrator(bench)
    assert cal_s.a == cal_v.a and cal_s.b == cal_v.b


def test_records_in_same_scene_get_distinct_degradations(bench):
    """Regression for the degraded-frame cache hazard: two records of
    the same (moving) scene at different frame times must hit distinct
    cache keys / grid rows, not alias one degraded frame."""
    pair = None
    for sc_id in range(len(bench.scenes)):
        recs = [r for r in bench.test + bench.validation
                if r.scene_id == sc_id and bench.scenes[sc_id].moving]
        ts = {r.t_frame for r in recs}
        if len(ts) >= 2:
            two = sorted(recs, key=lambda r: r.t_frame)
            pair = (two[0], two[-1])
            break
    if pair is None:  # synthesize a pair on a moving scene
        sc_id = next(i for i, sc in enumerate(bench.scenes) if sc.moving)
        base = dvb.QARecord(scene_id=sc_id, category="x", moving=True,
                            kind="read_code", t_frame=0, obj_idx=0,
                            answer=bench.scenes[sc_id].objects[0].code)
        pair = (base, dataclasses.replace(base, t_frame=15))
    r1, r2 = pair
    scene = bench.scenes[r1.scene_id]
    assert not np.array_equal(scene.render(r1.t_frame),
                              scene.render(r2.t_frame))
    # serial helper: explicit-argument cache keys stay distinct
    cache = {}
    f1 = dvb._degraded_frame(bench.scenes, cache, r1.scene_id,
                             r1.t_frame, 400.0, 10.0)
    f2 = dvb._degraded_frame(bench.scenes, cache, r2.scene_id,
                             r2.t_frame, 400.0, 10.0)
    assert len(cache) == 2 and not np.array_equal(f1, f2)
    # vectorized grid: each record is answered on ITS OWN degraded frame
    res = evaluate_records(bench.scenes, [r1, r2],
                           bitrate_ladder([400.0]))
    for i, r in enumerate((r1, r2)):
        ans, margin = dvb._answer(scene, r, np.asarray(
            dvb._encode_at(scene.render(r.t_frame), 400.0)))
        assert res.answers[i, 0] == ans
        assert res.margins[i, 0] == margin


# --------------------------------------------------------------------------
# Degradation axes
# --------------------------------------------------------------------------
def test_default_degradations_cover_all_kinds(bench):
    degr = default_degradations()
    assert {d.kind for d in degr} == {"none", "bitrate", "requant",
                                      "drop", "downscale"}
    res = dvb.evaluate(bench, degr, split="all")
    acc = res.accuracy()
    labels = [d.label for d in degr]
    # pristine and saturated-bitrate are the easy reference cells…
    assert acc[labels.index("pristine")] > 0.9
    assert acc[labels.index("bitrate@4000")] > 0.9
    # …every degraded cell is no better than pristine, and the starved
    # cap breaks the (degradation-sensitive by construction) samples
    assert acc[labels.index("bitrate@200")] < 0.3
    assert all(a <= acc[labels.index("pristine")] + 1e-12 for a in acc)


def test_requant_loss_ladder_monotone(bench):
    degr = [DegradationSpec(kind="requant", kbps=4000.0, loss=l)
            for l in (0.0, 0.3, 0.6, 0.9)]
    acc = dvb.evaluate(bench, degr, split="all").accuracy()
    assert all(a >= b - 1e-12 for a, b in zip(acc, acc[1:]))


def test_downscale_no_better_than_full_resolution(bench):
    degr = [DegradationSpec(kind="bitrate", kbps=4000.0),
            DegradationSpec(kind="downscale", kbps=4000.0, scale=2)]
    acc = dvb.evaluate(bench, degr, split="all").accuracy()
    assert acc[1] <= acc[0] + 1e-12


def test_degradation_spec_validation_and_round_trip():
    d = DegradationSpec(kind="drop", kbps=968.0, stall_frames=7)
    assert DegradationSpec.from_dict(
        json.loads(json.dumps(d.to_dict()))) == d
    assert d.label == "drop@968+7f"
    with pytest.raises(ValueError):
        DegradationSpec(kind="blur")
    with pytest.raises(ValueError):
        DegradationSpec(loss=1.5)
    with pytest.raises(ValueError):
        DegradationSpec(scale=0)
    with pytest.raises(ValueError):
        DegradationSpec(kbps=-1.0)


def test_engine_input_validation(bench):
    with pytest.raises(ValueError):
        evaluate_records(bench.scenes, bench.test, [])
    with pytest.raises(ValueError):
        evaluate_records(bench.scenes, [], bitrate_ladder([400.0]))
    with pytest.raises(ValueError):
        dvb.evaluate(bench, bitrate_ladder([400.0]), split="nope")
    with pytest.raises(ValueError):
        dvb.evaluate(bench, bitrate_ladder([400.0]), backend="cuda")
    with pytest.raises(ValueError):  # 256/3 breaks 8px blocking
        dvb.evaluate(bench, [DegradationSpec(kind="downscale", scale=3)])
    with pytest.raises(ValueError):
        dvb.generate(n_scenes_per_cat=1, n_frames=20, engine="gpu")


# --------------------------------------------------------------------------
# Pallas kernel backend (interpret mode off-TPU)
# --------------------------------------------------------------------------
def test_kernel_backend_matches_jnp(bench):
    """backend='kernel' reconstructs through the fused qp_codec Pallas
    kernel at the bisection-solved QP surfaces; at the saturated
    operating point it must agree with the jnp path to kernel
    tolerance."""
    recs = (bench.test + bench.validation)[:6]
    degr = bitrate_ladder([4000.0])
    jnp_res = evaluate_records(bench.scenes, recs, degr)
    krn_res = evaluate_records(bench.scenes, recs, degr,
                               backend="kernel")
    np.testing.assert_array_equal(jnp_res.codes, krn_res.codes)
    np.testing.assert_allclose(jnp_res.margins, krn_res.margins,
                               atol=1e-3)
    np.testing.assert_array_equal(jnp_res.answers, krn_res.answers)


def test_kernel_backend_rejects_requant(bench):
    with pytest.raises(ValueError):
        evaluate_records(bench.scenes, bench.test[:2],
                         [DegradationSpec(kind="requant", loss=0.5)],
                         backend="kernel")


# --------------------------------------------------------------------------
# Property tests (degradation monotonicity + fitting invariants)
# --------------------------------------------------------------------------
# note: @given tests must not take pytest fixtures (the no-hypothesis
# fallback shim wraps them as zero-arg), so the seed-pinned curves are
# cached by module-level helpers instead
@functools.lru_cache()
def _property_bench():
    return dvb.generate(n_scenes_per_cat=1, questions_per_obj=2, seed=0,
                        n_frames=20)


@functools.lru_cache()
def _bitrate_curve():
    ladder = (200.0, 290.0, 400.0, 710.0, 968.0, 1700.0, 3000.0, 4000.0)
    return np.asarray(dvb.accuracy_grid(_property_bench(), ladder))


@functools.lru_cache()
def _stall_curve():
    degr = [DegradationSpec(kind="drop", kbps=4000.0, stall_frames=s)
            for s in (0, 2, 5, 10, 15)]
    return dvb.evaluate(_property_bench(), degr, split="all").accuracy()


@hypothesis.given(i=st.integers(0, 6), j=st.integers(1, 7))
@hypothesis.settings(deadline=None, max_examples=20)
def test_property_accuracy_monotone_in_bitrate(i, j):
    """Tightening the bitrate cap never improves accuracy (checked on
    the seed-pinned curve, any rung pair)."""
    acc = _bitrate_curve()
    lo, hi = min(i, j), max(i, j)
    assert acc[lo] <= acc[hi] + 1e-12


@hypothesis.given(i=st.integers(1, 4))
@hypothesis.settings(deadline=None, max_examples=10)
def test_property_accuracy_under_stall_never_beats_fresh(i):
    """A rising drop/stall rate never beats the fresh-frame baseline."""
    acc = _stall_curve()
    assert acc[i] <= acc[0] + 1e-12


@hypothesis.given(score=st.floats(-5.0, 5.0), a=st.floats(-20.0, 20.0),
                  b=st.floats(-10.0, 10.0))
@hypothesis.settings(deadline=None, max_examples=50)
def test_property_calibrator_output_in_unit_interval(score, a, b):
    cal = PlattCalibrator(a=a, b=b)
    out = cal(score)
    assert 0.0 <= out <= 1.0
    np.testing.assert_allclose(cal.batch(np.asarray([score]))[0], out)


@hypothesis.given(conf=st.floats(0.0, 1.0), bw=st.floats(0.0, 5e6),
                  steps=st.integers(1, 8))
@hypothesis.settings(deadline=None, max_examples=50)
def test_property_recap_cap_never_below_min_rate(conf, bw, steps):
    abr = ReCapABR(min_rate=150e3)
    for _ in range(steps):
        rate = abr.update(conf, bw)
        assert rate >= 150e3


@hypothesis.given(seed=st.integers(0, 100), min_rate=st.floats(1e4, 5e5))
@hypothesis.settings(deadline=None, max_examples=25)
def test_property_fit_recap_respects_min_rate(seed, min_rate):
    rng = np.random.default_rng(seed)
    kbps = np.sort(rng.uniform(50, 5000, size=6))
    acc = np.sort(rng.uniform(0, 1, size=6))      # saturating curve
    conf = np.sort(rng.uniform(0, 1, size=6))
    fit = fit_recap_params(kbps, conf, accuracy=acc, min_rate=min_rate)
    assert fit["cap_bps"] >= min_rate
    assert 0.5 <= fit["tau"] <= 0.95
    assert 1.0 <= fit["gamma"] <= 4.0
    assert fit["knee_kbps"] in kbps


def test_saturation_point_reads_the_knee():
    kbps = [200.0, 400.0, 968.0, 1700.0, 4000.0]
    acc = [0.1, 0.5, 0.96, 0.99, 1.0]
    assert saturation_point(kbps, acc) == 968.0
    # order-insensitive
    assert saturation_point(kbps[::-1], acc[::-1]) == 968.0
    with pytest.raises(ValueError):
        saturation_point([], [])


# --------------------------------------------------------------------------
# Scenario-layer integration: run_devibench / DeViBenchRunResult
# --------------------------------------------------------------------------
def test_run_devibench_matches_direct_engine(bench, dvb_result):
    """run_devibench's cohort grid == evaluating the same benchmark
    directly (the preset's generation knobs match the module fixture)."""
    assert len(dvb_result) == 8 and len(dvb_result.cohorts) == 1
    np.testing.assert_array_equal(
        dvb_result.values("accuracy")[:len(LADDER)],
        dvb.accuracy_grid(bench, LADDER))
    kbps, acc = dvb_result.saturation_curve()
    np.testing.assert_array_equal(kbps, LADDER)


def test_run_scenarios_workload_dispatch(dvb_result):
    r = run_scenarios([preset("devibench")], workload="devibench")
    assert isinstance(r, DeViBenchRunResult) and len(r) == 1
    with pytest.raises(ValueError):
        run_scenarios([preset("devibench")], workload="quic")
    # a degraded spec on the RTC fleet path is an error, not a no-op
    with pytest.raises(ValueError):
        run_scenarios([ScenarioSpec(degradation="bitrate")])
    # and the devibench QA policy cannot leak into a fleet session
    with pytest.raises(ValueError):
        run_scenarios([ScenarioSpec(qa="devibench")])
    with pytest.raises(ValueError):
        run_devibench([ScenarioSpec()])  # qa != devibench


def test_spec_degradation_dimension_round_trips():
    s = preset("devibench").with_(degradation="requant",
                                  degradation_kwargs=dict(kbps=700.0,
                                                          loss=0.25))
    assert ScenarioSpec.from_dict(
        json.loads(json.dumps(s.to_dict()))) == s
    assert s.degradation_spec() == DegradationSpec(
        kind="requant", kbps=700.0, loss=0.25)
    with pytest.raises(ValueError):
        ScenarioSpec(degradation="blur")


def test_result_select_aggregate_and_arrays(dvb_result):
    arr = dvb_result.arrays()
    assert all(v.shape == (8,) for v in arr.values())
    assert np.all(arr["accuracy"] >= 0) and np.all(arr["accuracy"] <= 1)
    sub = dvb_result.select(degradation="bitrate")
    assert len(sub) == len(LADDER)
    # subset cohorts re-partition the kept indices
    assert sorted(i for c in sub.cohorts for i in c.indices) \
        == list(range(len(sub)))
    agg = dvb_result.aggregate(by=("degradation",))
    assert set(agg) == {("bitrate",), ("requant",), ("drop",),
                       ("downscale",)}


def test_devibench_json_schema_round_trip(dvb_result, tmp_path):
    path = tmp_path / "devibench.json"
    doc = dvb_result.to_json(str(path))
    validate_devibench_json(doc)
    validate_devibench_json(json.loads(path.read_text()))
    back = [ScenarioSpec.from_dict(rec["spec"])
            for rec in doc["scenarios"]]
    assert back == dvb_result.specs


def test_devibench_json_schema_rejects_corruption(dvb_result):
    doc = dvb_result.to_json()
    bad = json.loads(json.dumps(doc))
    bad["scenarios"][0]["metrics"].pop("accuracy")
    with pytest.raises(ValueError):
        validate_devibench_json(bad)
    bad2 = json.loads(json.dumps(doc))
    bad2["cohorts"][0]["sessions"] = bad2["cohorts"][0]["sessions"][:-1]
    with pytest.raises(ValueError):
        validate_devibench_json(bad2)
    bad3 = json.loads(json.dumps(doc))
    bad3["scenarios"][0]["degradation"].pop("label")
    with pytest.raises(ValueError):
        validate_devibench_json(bad3)
    with pytest.raises(ValueError):
        validate_devibench_json({"schema": "other"})


def test_devibench_csv(dvb_result):
    text = dvb_result.to_csv()
    lines = text.strip().splitlines()
    assert len(lines) == 1 + len(dvb_result)
    assert "degradation_label" in lines[0] and "accuracy" in lines[0]


# --------------------------------------------------------------------------
# The benchmark -> calibrator -> ReCap-ABR loop on stacked arrays
# --------------------------------------------------------------------------
def test_fit_confidence_calibrator_consumes_run_result(dvb_result):
    cal = fit_confidence_calibrator(dvb_result)
    assert 0.0 <= cal(0.05) <= 1.0 and 0.0 <= cal(0.95) <= 1.0
    assert cal(0.95) > cal(0.05)  # higher margin -> higher confidence


def test_fit_recap_closes_the_loop(dvb_result):
    fit = dvb_result.fit_recap()
    assert fit["cap_bps"] >= 150e3
    assert fit["knee_kbps"] in LADDER
    assert 0.5 <= fit["tau"] <= 0.95
    assert 1.0 <= fit["gamma"] <= 4.0
    assert 1 <= fit["settle_steps"] <= 48


# --------------------------------------------------------------------------
# Seed-pinned saturation-curve snapshot (golden file)
# --------------------------------------------------------------------------
def test_saturation_curve_matches_golden_snapshot(bench):
    with open(GOLDEN) as f:
        golden = json.load(f)
    assert golden["seed"] == 0 and golden["split"] == "all"
    acc = dvb.accuracy_grid(bench, golden["ladder_kbps"], split="all")
    # slack of one record per rung absorbs BLAS-level float drift across
    # platforms; the curve shape and knee must hold exactly
    n = len(bench.test) + len(bench.validation)
    assert n == golden["n_records"]
    np.testing.assert_allclose(acc, golden["accuracy"],
                               atol=1.5 / n + 1e-12)
    assert all(a <= b + 1e-12 for a, b in zip(acc, acc[1:]))
    assert saturation_point(golden["ladder_kbps"], acc) \
        == golden["knee_kbps"]

"""DeViBench pipeline tests: the 5-step construction, degradation
sensitivity of accepted samples, splits, and calibration."""
import numpy as np
import pytest

from repro.devibench import pipeline as dvb


@pytest.fixture(scope="module")
def bench():
    return dvb.generate(n_scenes_per_cat=1, questions_per_obj=2, seed=0,
                        n_frames=20)


def test_pipeline_yields_samples(bench):
    s = bench.stats
    assert s["n_generated"] > 50
    assert s["n_verified"] > 10
    # the paper's filter keeps a minority of generated QA (22.57% net)
    assert 0.02 < s["net_yield"] < 0.8
    assert s["n_validation"] + s["n_test"] == s["n_verified"]
    assert s["n_validation"] >= 1


def test_accepted_samples_are_degradation_sensitive(bench):
    for rec in bench.test + bench.validation:
        assert rec.correct_high and not rec.correct_low
        assert rec.verified


def test_sensitive_categories_dominate(bench):
    """Fine-detail categories should dominate accepted samples (paper:
    text-rich 81.86%), coarse 'lawn'/'sports' should contribute ~none."""
    cats = [r.category for r in bench.test + bench.validation]
    fine = sum(c in ("document", "retail", "office", "street") for c in cats)
    assert fine / len(cats) > 0.7


def test_accuracy_curve_saturates(bench):
    """Fig. 3: accuracy saturates with bitrate on DeViBench samples."""
    accs = {k: dvb.accuracy_at_bitrate(bench, k) for k in (200, 700, 1700, 4000)}
    assert accs[200] < 0.4          # accepted samples all fail @200 by design
    assert accs[4000] > 0.9
    assert accs[1700] >= accs[700] >= accs[200]


def test_calibrator_fits_margin_to_accuracy(bench):
    cal = dvb.fit_confidence_calibrator(bench)
    # margins near 1 -> confident, near 0 -> not
    assert cal(0.95) > 0.6
    assert cal(0.05) < 0.4
    assert cal(0.95) > cal(0.5) > cal(0.05)

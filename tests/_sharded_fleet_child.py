"""Subprocess body of tests/test_sharded_fleet.py.

Runs with XLA_FLAGS=--xla_force_host_platform_device_count=<D> set by
the parent BEFORE this interpreter starts (jax fixes the device count at
import, which is why the parity suite needs a subprocess at all).  For
each case it builds identical session sets twice, runs them sharded
(mesh over all visible devices) and unsharded in the SAME process,
asserts bit-exact SessionMetrics parity, and prints a JSON report —
including content digests of the unsharded runs so the parent can check
that the multi-device process didn't drift from a plain single-device
process either.

Usage:  python tests/_sharded_fleet_child.py <expected_device_count>
"""
from __future__ import annotations

import json
import sys
import traceback

import jax

import _builders as B
from repro.api import make_fleet_mesh, run_scenarios
from repro.core.fleet import Fleet, run_fleet
from repro.distributed.sharding import pad_sessions


def _compare(base, shard) -> str | None:
    """None if every session's metrics are bit-identical, else detail."""
    if len(base) != len(shard):
        return f"length mismatch {len(base)} != {len(shard)}"
    for k, (a, b) in enumerate(zip(base, shard)):
        try:
            B.assert_metrics_equal(a, b)
        except AssertionError:
            return (f"session {k} mismatch:\n"
                    + "".join(traceback.format_exc().splitlines(True)[-3:]))
    return None


def main() -> None:
    expect = int(sys.argv[1])
    n_dev = len(jax.devices())
    assert n_dev == expect, (
        f"child sees {n_dev} devices, expected {expect} — XLA_FLAGS not "
        "applied before jax import?")
    mesh = make_fleet_mesh()
    cases = {}

    def fleet_case(name, n, duration, fused=False, rollout=None,
                   on_device=False):
        def members():
            return [B.hetero_fleet_session(k, duration, hw=64)
                    for k in range(n)]
        base = run_fleet(members(), fused_plan=fused)
        fl = Fleet(members(), fused_plan=fused, mesh=mesh,
                   on_device_server=on_device)
        # parity of an unsharded-vs-unsharded run would be vacuous:
        # prove the mesh actually engaged and the padding is as expected
        assert fl.mesh is not None, f"{name}: mesh did not engage"
        assert fl.n_pad == pad_sessions(n, expect), (name, fl.n_pad)
        shard = fl.run(rollout=rollout)
        detail = _compare(base, shard)
        cases[name] = {"equal": detail is None, "detail": detail,
                       "n": n, "pad": fl.pad,
                       "digest": B.metrics_digest(base)}

    # system variants spread across members (artic / webrtc+zeco /
    # webrtc+recap / webrtc, gcc and bbr), N == device count
    fleet_case("variants_n8", n=8, duration=6.0)
    # N=12 does not divide 8 devices: pads to 16 with 4 dead sessions
    fleet_case("padded_n12", n=12, duration=4.0)
    # many sessions per device
    fleet_case("n64", n=64, duration=2.5)
    # fused plan+encode dispatch (surfaces computed in-graph)
    fleet_case("fused_n8", n=8, duration=4.0, fused=True)
    # whole-tick rollout (lax.scan windows) under shard_map, vs the
    # EAGER single-device fleet: one case per dispatch shape — even N
    # and padded N (12 pads to 16 on 8 devices, dead tail masked)
    fleet_case("rollout_n8", n=8, duration=4.0, fused=True, rollout=3)
    fleet_case("rollout_pad_n12", n=12, duration=3.0, rollout=3)
    # on-device server phase under shard_map: the scan emits stats-at-
    # send rows (sharded over the session axis) instead of decoded
    # frames, and the host replay must still be bit-exact — including
    # with a padded dead tail
    fleet_case("rollout_ondev_n8", n=8, duration=4.0, fused=True,
               rollout=3, on_device=True)
    fleet_case("rollout_ondev_pad_n12", n=12, duration=3.0, rollout=3,
               on_device=True)

    # mixed cohort grid through run_scenarios(mesh=...): two frame
    # sizes interleaved in input order, cohort sizes 3 and 5 (both pad
    # on 8 devices), results re-stacked into input positions
    specs = B.mixed_cohort_specs(duration=3.0, sizes=(64, 128),
                                 counts=(3, 5), interleave=True)
    base = run_scenarios(specs)
    shard = run_scenarios(specs, mesh=mesh)
    detail = _compare(base.metrics, shard.metrics)
    if detail is None and [s.tag for s in shard.specs] != \
            [s.tag for s in specs]:
        detail = "spec order not preserved"
    cases["mixed_grid"] = {"equal": detail is None, "detail": detail,
                           "n": len(specs),
                           "digest": B.metrics_digest(base.metrics)}

    print("RESULT " + json.dumps({"devices": n_dev, "cases": cases}))


if __name__ == "__main__":
    main()

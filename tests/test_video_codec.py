"""Codec simulator tests incl. hypothesis property tests on RD invariants."""
from _hypothesis_compat import hypothesis, st  # noqa: hypothesis optional
import jax.numpy as jnp
import numpy as np
import pytest

from repro.video import codec
from repro.video.scenes import decode_glyph, glyph_pattern, make_scene


def _frame(seed=0, h=64, w=64):
    rng = np.random.default_rng(seed)
    sc = make_scene("retail", False, seed, h=h, w=w)
    return sc.render(0)


def test_roundtrip_near_lossless_at_qmin():
    f = _frame()
    qp = np.full((8, 8), float(codec.QP_MIN), np.float32)
    rec, enc = codec.roundtrip(jnp.asarray(f), jnp.asarray(qp))
    assert float(codec.psnr(jnp.asarray(f), rec)) > 33.0


def test_rate_monotone_in_qp():
    f = jnp.asarray(_frame())
    bits = []
    for qp in (20, 28, 36, 44, 51):
        enc = codec.encode(f, jnp.full((8, 8), float(qp)))
        bits.append(float(enc.bits))
    assert all(a > b for a, b in zip(bits, bits[1:])), bits


def test_distortion_monotone_in_qp():
    f = jnp.asarray(_frame())
    psnrs = []
    for qp in (20, 32, 44):
        rec, _ = codec.roundtrip(f, jnp.full((8, 8), float(qp)))
        psnrs.append(float(codec.psnr(f, rec)))
    assert psnrs[0] > psnrs[1] > psnrs[2]


def test_rate_control_hits_target():
    f = _frame(h=128, w=128)
    for target in (3e4, 1e5, 4e5):
        qp, enc = codec.rate_control(
            jnp.asarray(f), jnp.zeros((16, 16), jnp.float32),
            jnp.float32(target))
        # within 25% (or pinned at the QP boundary when unreachable)
        at_bound = (float(qp.max()) >= codec.QP_MAX - 0.6 or
                    float(qp.min()) <= codec.QP_MIN + 0.6)
        assert at_bound or abs(float(enc.bits) - target) / target < 0.25


def test_per_block_qp_prioritizes_region():
    """Lower QP on a region must raise its fidelity vs elsewhere."""
    f = jnp.asarray(_frame(h=128, w=128))
    qp = np.full((16, 16), 48.0, np.float32)
    qp[4:10, 4:10] = 20.0
    rec, _ = codec.roundtrip(f, jnp.asarray(qp))
    err = np.abs(np.asarray(rec) - np.asarray(f))
    roi = err[32:80, 32:80].mean()
    rest = np.concatenate([err[:32].ravel(), err[80:].ravel()]).mean()
    assert roi < 0.5 * rest


@hypothesis.given(
    qp1=st.floats(min_value=20, max_value=50),
    dqp=st.floats(min_value=0.5, max_value=15),
    seed=st.integers(min_value=0, max_value=10),
)
@hypothesis.settings(deadline=None, max_examples=15)
def test_property_rate_decreases_with_qp(qp1, dqp, seed):
    f = jnp.asarray(_frame(seed))
    b1 = float(codec.encode(f, jnp.full((8, 8), qp1)).bits)
    b2 = float(codec.encode(f, jnp.full((8, 8), min(qp1 + dqp, 51.0))).bits)
    assert b2 <= b1 + 1e-3


@hypothesis.given(code=st.integers(min_value=0, max_value=(1 << 12) - 1),
                  cell=st.sampled_from([3, 4, 6, 8]))
@hypothesis.settings(deadline=None, max_examples=25)
def test_property_glyph_roundtrip_clean(code, cell):
    g = glyph_pattern(code, cell)
    got, margin = decode_glyph(g, cell)
    assert got == code
    assert margin > 0.9


def test_glyph_unreadable_when_blurred_flat():
    g = np.full((32, 32), 0.5, np.float32)
    _, margin = decode_glyph(g, 8)
    assert margin < 0.2


def test_glyph_degrades_with_bitrate():
    """Small glyphs must die at low bitrate but survive high bitrate."""
    sc = make_scene("document", False, seed=3, h=128, w=128)
    f = sc.render(0)
    # cells are jittered per object; test the finest glyph in the scene
    obj = min(sc.objects, key=lambda o: o.cell)
    y, x = obj.pos(0)
    y = int(np.clip(y, 0, sc.h - obj.size)); x = int(np.clip(x, 0, sc.w - obj.size))

    def read_at(bits):
        _, enc = codec.rate_control(jnp.asarray(f),
                                    np.zeros((16, 16), np.float32),
                                    jnp.float32(bits))
        rx = np.asarray(codec.decode(enc))
        code, margin = decode_glyph(rx[y:y + obj.size, x:x + obj.size], obj.cell)
        return code == obj.code and margin > 0.3

    assert read_at(4e5)      # 4000 kbps @10fps equivalent
    assert not read_at(6e3)  # starved

"""Pallas kernel validation (interpret mode) vs pure-jnp oracles:
shape/dtype sweeps + hypothesis property tests on kernel invariants."""
from _hypothesis_compat import hypothesis, st  # noqa: hypothesis optional
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.flash_decode import ops as fd_ops
from repro.kernels.flash_decode.ref import decode_ref
from repro.kernels.qp_codec.ops import (qp_codec_frame, tick_codec_frames,
                                        zeco_codec_frames)
from repro.kernels.qp_codec.qp_codec import qp_codec_blocks
from repro.kernels.qp_codec.ref import (qp_codec_ref, tick_codec_ref,
                                        zeco_codec_ref)
from repro.video import codec as codec_ref

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def _qkv(key, B, Sq, Sk, Hq, Hk, d, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (B, Sq, Hq, d), jnp.float32).astype(dtype)
    k = jax.random.normal(k2, (B, Sk, Hk, d), jnp.float32).astype(dtype)
    v = jax.random.normal(k3, (B, Sk, Hk, d), jnp.float32).astype(dtype)
    return q, k, v


def _bhsd(x):
    B, S, H, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(B * H, S, d)


# --------------------------------------------------------------------------
# flash_attention: shape/dtype sweep vs oracle
# --------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Sq,Sk,Hq,Hk,d,bq,bk,window",
    [
        (1, 64, 64, 4, 4, 32, 16, 16, None),     # MHA, even blocks
        (2, 48, 48, 4, 2, 32, 16, 16, None),     # GQA + ragged seq (pad)
        (1, 128, 128, 8, 2, 64, 32, 64, None),   # GQA 4:1
        (1, 96, 96, 2, 1, 32, 32, 32, 32),       # MQA + local window
        (2, 33, 33, 4, 4, 16, 16, 16, None),     # odd seq (pad both)
    ])
def test_flash_attention_matches_oracle(B, Sq, Sk, Hq, Hk, d, bq, bk,
                                        window, dtype):
    q, k, v = _qkv(jax.random.PRNGKey(0), B, Sq, Sk, Hq, Hk, d, dtype)
    got = fa_ops.flash_attention(q, k, v, causal=True, window=window,
                                 bq=bq, bk=bk, interpret=True)
    want = attention_ref(_bhsd(q), _bhsd(k), _bhsd(v), causal=True,
                         window=window)
    want = want.reshape(B, Hq, Sq, d).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


def test_flash_attention_q_offset():
    """Extension chunks: absolute-position causal masking."""
    q, k, v = _qkv(jax.random.PRNGKey(1), 1, 16, 48, 4, 4, 32, jnp.float32)
    got = fa_ops.flash_attention(q, k, v, causal=True, q_offset=32,
                                 bq=16, bk=16, interpret=True)
    want = attention_ref(_bhsd(q), _bhsd(k), _bhsd(v), causal=True,
                         q_offset=32)
    want = want.reshape(1, 4, 16, 32).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@hypothesis.given(
    seq=st.sampled_from([16, 40, 64]),
    heads=st.sampled_from([(4, 4), (4, 2), (8, 1)]),
    seed=st.integers(0, 50),
)
@hypothesis.settings(deadline=None, max_examples=10)
def test_property_flash_attention_rowsum(seq, heads, seed):
    """Softmax invariant: with v = ones, output must be exactly ones."""
    Hq, Hk = heads
    q, k, _ = _qkv(jax.random.PRNGKey(seed), 1, seq, seq, Hq, Hk, 16,
                   jnp.float32)
    v = jnp.ones((1, seq, Hk, 16), jnp.float32)
    got = fa_ops.flash_attention(q, k, v, causal=True, bq=16, bk=16,
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(got), 1.0, rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# flash_decode
# --------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Sk,Hq,Hk,d,bk",
    [
        (1, 128, 4, 4, 32, 64),
        (2, 100, 4, 2, 32, 32),   # ragged + GQA
        (4, 256, 8, 1, 64, 128),  # MQA
    ])
def test_flash_decode_matches_oracle(B, Sk, Hq, Hk, d, bk, dtype):
    key = jax.random.PRNGKey(0)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    q = jax.random.normal(k1, (B, 1, Hq, d), jnp.float32).astype(dtype)
    kc = jax.random.normal(k2, (B, Sk, Hk, d), jnp.float32).astype(dtype)
    vc = jax.random.normal(k3, (B, Sk, Hk, d), jnp.float32).astype(dtype)
    lengths = jax.random.randint(k4, (B,), 1, Sk + 1)
    got = fd_ops.flash_decode(q, kc, vc, lengths, bk=bk, interpret=True)
    want = decode_ref(
        q[:, 0].transpose(0, 1, 2).reshape(B * Hq, d),
        kc.transpose(0, 2, 1, 3).reshape(B * Hk, Sk, d),
        vc.transpose(0, 2, 1, 3).reshape(B * Hk, Sk, d),
        jnp.repeat(lengths, Hq))
    want = want.reshape(B, Hq, 1, d).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


def test_flash_decode_matches_model_decode_attention():
    """Cross-check against the model-layer reference decode attention."""
    from repro.models.attention import decode_attention
    from repro.models.config import ModelConfig
    cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=64,
                      n_heads=4, n_kv_heads=2, head_dim=16, d_ff=64,
                      vocab=32, dtype="float32", param_dtype="float32")
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (2, 1, 4, 16))
    kc = jax.random.normal(jax.random.PRNGKey(4), (2, 64, 2, 16))
    vc = jax.random.normal(jax.random.PRNGKey(5), (2, 64, 2, 16))
    lengths = jnp.asarray([10, 50])
    got = fd_ops.flash_decode(q, kc, vc, lengths, bk=32, interpret=True)
    want = decode_attention(q, kc, vc, lengths, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------------------
# qp_codec
# --------------------------------------------------------------------------
@pytest.mark.parametrize("N,bs", [(16, 8), (100, 32), (1024, 512)])
def test_qp_codec_matches_oracle(N, bs):
    key = jax.random.PRNGKey(0)
    blocks = jax.random.uniform(key, (N, 8, 8))
    qp = jax.random.uniform(jax.random.PRNGKey(1), (N,), minval=20,
                            maxval=51)
    rec, bits = qp_codec_blocks(blocks, qp, bs=bs, interpret=True)
    rec_ref_, bits_ref_ = qp_codec_ref(blocks, qp)
    np.testing.assert_allclose(np.asarray(rec), np.asarray(rec_ref_),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(bits), np.asarray(bits_ref_),
                               rtol=1e-5, atol=1e-5)


def test_qp_codec_frame_matches_video_codec():
    """The kernel path must agree with repro.video.codec end to end."""
    from repro.video.scenes import make_scene
    f = jnp.asarray(make_scene("retail", False, 0, h=64, w=64).render(0))
    qp = jnp.full((8, 8), 30.0)
    rec_k, bits_k = qp_codec_frame(f, qp, bs=16, interpret=True)
    rec_o = codec_ref.decode(codec_ref.encode(f, qp))
    bits_o = codec_ref.encode(f, qp).bits
    np.testing.assert_allclose(np.asarray(rec_k), np.asarray(rec_o),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(bits_k), float(bits_o), rtol=1e-5)


# --------------------------------------------------------------------------
# fused zeco codec: box arrays -> importance -> QP -> encode in one pass
# --------------------------------------------------------------------------
def _zeco_inputs(N=3, hw=128, seed=0):
    from repro.video.scenes import make_scene
    frames = np.stack([make_scene("retail", False, seed=s, h=hw, w=hw)
                       .render(0) for s in range(N)]).astype(np.float32)
    rng = np.random.default_rng(seed)
    boxes = rng.uniform(0, hw - 48, (N, 4, 4)).astype(np.float32)
    boxes[..., 2:] = boxes[..., :2] + rng.uniform(16, 40, (N, 4, 2))
    counts = np.asarray([2, 0, 4][:N], np.int32)
    engaged = np.asarray([True, False, True][:N])
    targets = np.asarray([6e4, 4e4, 1.2e5][:N], np.float32)
    return frames, boxes, counts, engaged, targets


def test_zeco_codec_frames_matches_oracle():
    frames, boxes, counts, engaged, targets = _zeco_inputs()
    rec_k, bits_k = zeco_codec_frames(frames, boxes, counts, engaged,
                                      targets, patch=32, interpret=True)
    rec_r, bits_r = zeco_codec_ref(frames, boxes, counts, engaged,
                                   targets, patch=32)
    np.testing.assert_allclose(np.asarray(rec_k), np.asarray(rec_r),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(bits_k), np.asarray(bits_r),
                               rtol=1e-5)


def test_zeco_codec_frames_matches_unfused_pipeline():
    """Fused kernel == surfaces_from_boxes -> rate_control_batch -> decode
    (the two-dispatch jnp path it replaces)."""
    from repro.core.zecostream import surfaces_from_boxes
    frames, boxes, counts, engaged, targets = _zeco_inputs(seed=3)
    hw = frames.shape[1:]
    rec_k, bits_k = zeco_codec_frames(frames, boxes, counts, engaged,
                                      targets, patch=32, interpret=True)
    surf = surfaces_from_boxes(boxes, counts, engaged, frame_hw=hw,
                               patch=32)
    _, enc = codec_ref.rate_control_batch(frames, np.asarray(surf),
                                          targets)
    rec_u = codec_ref.decode_batch(enc)
    np.testing.assert_allclose(np.asarray(bits_k), np.asarray(enc.bits),
                               rtol=1e-3)
    np.testing.assert_allclose(np.asarray(rec_k), np.asarray(rec_u),
                               atol=1e-3)


def test_zeco_codec_frames_nondefault_qp_bounds_match_unfused():
    """q_min/q_max parameterize Eq. 4 only; the offset search still clips
    at the codec's global QP range, exactly like codec.rate_control."""
    from repro.core.zecostream import surfaces_from_boxes
    frames, boxes, counts, engaged, targets = _zeco_inputs(seed=9)
    hw = frames.shape[1:]
    rec_k, bits_k = zeco_codec_frames(frames, boxes, counts, engaged,
                                      targets, patch=32, q_min=30.0,
                                      q_max=45.0, interpret=True)
    surf = surfaces_from_boxes(boxes, counts, engaged, frame_hw=hw,
                               patch=32, q_min=30.0, q_max=45.0)
    _, enc = codec_ref.rate_control_batch(frames, np.asarray(surf),
                                          targets)
    np.testing.assert_allclose(np.asarray(bits_k), np.asarray(enc.bits),
                               rtol=1e-3)
    np.testing.assert_allclose(np.asarray(rec_k),
                               np.asarray(codec_ref.decode_batch(enc)),
                               atol=1e-3)


def test_zeco_codec_frames_hits_rate_target():
    frames, boxes, counts, engaged, targets = _zeco_inputs(seed=5)
    _, bits = zeco_codec_frames(frames, boxes, counts, engaged, targets,
                                patch=32, interpret=True)
    bits = np.asarray(bits)
    # bisection lands at or below target within the usual probe slack
    assert np.all(bits <= targets * 1.15)


def test_zeco_codec_rejects_nondivisible_patch():
    frames, boxes, counts, engaged, targets = _zeco_inputs(N=1, hw=64)
    with pytest.raises(ValueError):
        zeco_codec_frames(frames, boxes, counts, engaged, targets,
                          patch=48, interpret=True)


# --------------------------------------------------------------------------
# tick megakernel: the rollout scan's whole per-tick client phase fused
# (surface -> strided-probe bisection -> quantize -> packetized rate),
# emitting codec products instead of a reconstruction
# --------------------------------------------------------------------------
def _assert_tick_products_equal(got, want):
    surf_k, enc_k = got
    surf_r, enc_r = want
    np.testing.assert_array_equal(np.asarray(surf_k), np.asarray(surf_r))
    np.testing.assert_array_equal(np.asarray(enc_k.coeffs),
                                  np.asarray(enc_r.coeffs))
    np.testing.assert_array_equal(np.asarray(enc_k.qp_blocks),
                                  np.asarray(enc_r.qp_blocks))
    np.testing.assert_array_equal(np.asarray(enc_k.bits_blocks),
                                  np.asarray(enc_r.bits_blocks))
    np.testing.assert_array_equal(np.asarray(enc_k.bits),
                                  np.asarray(enc_r.bits))


@pytest.mark.parametrize("hw,patch,stride", [
    (128, 64, 1),    # divisible grid, exact bisection
    (128, 64, 2),    # divisible grid, strided probe
    (96, 64, 2),     # partial trailing patches (one-hot upsample path)
    (104, 32, 3),    # non-divisible probe grid AND partial patches
])
def test_tick_megakernel_matches_oracle_bitwise(hw, patch, stride):
    """Interpret-mode kernel vs the op-for-op jitted jnp oracle: every
    product (surface, coeffs, qp, per-block and total bits) bitwise."""
    frames, boxes, counts, engaged, targets = _zeco_inputs(hw=hw)
    got = tick_codec_frames(frames, boxes, counts, engaged, targets,
                            frame_hw=(hw, hw), patch=patch,
                            probe_stride=stride, interpret=True)
    want = tick_codec_ref(frames, boxes, counts, engaged, targets,
                          frame_hw=(hw, hw), patch=patch,
                          probe_stride=stride)
    _assert_tick_products_equal(got, want)


def test_tick_megakernel_masks_dead_rows():
    """Disengaged / box-less sessions degenerate to a zero (uniform)
    surface and still match the oracle bitwise."""
    frames, boxes, counts, engaged, targets = _zeco_inputs()
    counts = np.zeros_like(counts)
    engaged = np.zeros_like(engaged)
    surf, enc = tick_codec_frames(frames, boxes, counts, engaged, targets,
                                  frame_hw=frames.shape[1:], patch=32,
                                  interpret=True)
    np.testing.assert_array_equal(np.asarray(surf),
                                  np.zeros_like(np.asarray(surf)))
    want = tick_codec_ref(frames, boxes, counts, engaged, targets,
                          frame_hw=frames.shape[1:], patch=32)
    _assert_tick_products_equal((surf, enc), want)


def test_tick_megakernel_fast_math_tier_vs_fused_jnp():
    """The documented tolerance tier: the megakernel is NOT bit-exact
    against the eager fused jnp plan+encode (different reduction shapes
    and fusion), but every product must agree to fast-math tolerance —
    and the bisection must land on the same QP offsets almost
    everywhere (a stray coefficient may flip at a round() boundary)."""
    from repro.core.zecostream import rate_control_batch_fused
    frames, boxes, counts, engaged, targets = _zeco_inputs(seed=7)
    hw = frames.shape[1:]
    surf_k, enc_k = tick_codec_frames(frames, boxes, counts, engaged,
                                      targets, frame_hw=hw, patch=32,
                                      probe_stride=2, interpret=True)
    surf_j, _, enc_j = rate_control_batch_fused(
        jnp.asarray(frames), jnp.asarray(boxes), jnp.asarray(counts),
        jnp.asarray(engaged), jnp.asarray(targets), frame_hw=hw,
        patch=32, probe_stride=2)
    np.testing.assert_allclose(np.asarray(surf_k), np.asarray(surf_j),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(enc_k.qp_blocks),
                               np.asarray(enc_j.qp_blocks),
                               rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(enc_k.bits),
                               np.asarray(enc_j.bits), rtol=1e-3)
    flipped = np.mean(np.asarray(enc_k.coeffs) != np.asarray(enc_j.coeffs))
    assert flipped < 1e-3


def test_tick_megakernel_hits_rate_target():
    frames, boxes, counts, engaged, targets = _zeco_inputs(seed=5)
    _, enc = tick_codec_frames(frames, boxes, counts, engaged, targets,
                               frame_hw=frames.shape[1:], patch=32,
                               interpret=True)
    assert np.all(np.asarray(enc.bits) <= targets * 1.15)


@hypothesis.given(qp_lo=st.floats(20, 35), dq=st.floats(3, 16),
                  seed=st.integers(0, 20))
@hypothesis.settings(deadline=None, max_examples=10)
def test_property_qp_codec_rate_monotone(qp_lo, dq, seed):
    blocks = jax.random.uniform(jax.random.PRNGKey(seed), (32, 8, 8))
    _, b1 = qp_codec_blocks(blocks, jnp.full((32,), qp_lo), bs=32,
                            interpret=True)
    _, b2 = qp_codec_blocks(blocks, jnp.full((32,), qp_lo + dq), bs=32,
                            interpret=True)
    assert float(b2.sum()) <= float(b1.sum()) + 1e-3

"""Import hypothesis if available, else a single-example no-op fallback.

Several test modules use hypothesis property tests.  The library is an
optional dev dependency (see requirements-dev.txt); when it is missing
the suite must still collect and run, so this shim provides `given` /
`settings` / `strategies` stand-ins that run each property test once on
a representative example instead of skipping the whole module at import
time.

Usage (in test modules):
    from _hypothesis_compat import HAVE_HYPOTHESIS, hypothesis, st
"""
from __future__ import annotations

try:
    import hypothesis
    import hypothesis.strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import functools
    import types
    import warnings

    HAVE_HYPOTHESIS = False
    warnings.warn(
        "hypothesis is not installed: property tests run a single "
        "representative example each (pip install -r requirements-dev.txt "
        "for full property coverage)", RuntimeWarning)

    class _Strategy:
        """Carries one representative example for the fallback run."""

        def __init__(self, example):
            self.example = example

    def _floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(0.5 * (float(min_value) + float(max_value)))

    def _integers(min_value=0, max_value=1, **_kw):
        return _Strategy(int((int(min_value) + int(max_value)) // 2))

    def _sampled_from(elements):
        elements = list(elements)
        return _Strategy(elements[len(elements) // 2])

    def _booleans():
        return _Strategy(True)

    st = types.SimpleNamespace(floats=_floats, integers=_integers,
                               sampled_from=_sampled_from,
                               booleans=_booleans)

    def _given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper():
                return fn(**{name: s.example
                             for name, s in strategies.items()})
            # pytest must see the zero-arg signature, not the wrapped
            # function's parameters (it would treat them as fixtures)
            del wrapper.__wrapped__
            return wrapper
        return deco

    def _settings(**_kw):
        def deco(fn):
            return fn
        return deco

    hypothesis = types.SimpleNamespace(given=_given, settings=_settings)

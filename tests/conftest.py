"""Shared fixtures for the suite: the heterogeneous session/fleet/
scenario builders (one definition in tests/_builders.py instead of the
copies that used to live in test_fleet.py / test_scenario.py /
test_zecostream_bank.py) plus the `virtual_devices(n)` subprocess-env
helper for multi-device tests, and the `slow` marker registration."""
import pytest

import _builders


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running test (CI's quick lane runs -m 'not slow'; "
        "the full tier-1 run includes them)")


@pytest.fixture(scope="session")
def fleet_member():
    """(k, duration=12.0, hw=None) -> heterogeneous FleetSession."""
    return _builders.hetero_fleet_session


@pytest.fixture(scope="session")
def scenario_specs():
    """(duration=8.0, n=4, base=None) -> heterogeneous ScenarioSpecs."""
    return _builders.hetero_scenario_specs


@pytest.fixture(scope="session")
def base_spec():
    """(duration=8.0) -> the periodic-QA base ScenarioSpec."""
    return _builders.base_scenario_spec


@pytest.fixture(scope="session")
def mixed_specs():
    """(duration, sizes, counts, interleave) -> multi-cohort specs."""
    return _builders.mixed_cohort_specs


@pytest.fixture(scope="session")
def virtual_devices():
    """(n) -> subprocess env with n virtual host CPU devices."""
    return _builders.virtual_devices


@pytest.fixture(scope="session")
def metrics_equal():
    """Bit-exact SessionMetrics equality assertion."""
    return _builders.assert_metrics_equal

"""Golden end-to-end snapshot: seed-pinned `artic` vs `webrtc` preset
metrics through the full fleet pipeline (render -> plan -> encode ->
channel -> decode -> ingest -> QA), stored as a schema-valid RunResult
export in tests/golden/e2e_presets.json.

Catches cross-PR numeric drift anywhere in the pipeline: the stored
specs re-run from the JSON itself and their aggregates must reproduce
the snapshot (counts exactly, float aggregates to tight tolerance —
allowing only for cross-platform float variation in the XLA-compiled
codec).  The export must also validate against the RunResult schema,
and corrupted copies must be rejected.

Regenerate (only when a PR *intends* to change the numbers):

    PYTHONPATH=src:tests python tests/test_e2e_golden.py --regen
"""
import json
import os

import pytest

from repro.api import (ScenarioSpec, run_scenarios,
                       validate_run_result_json)

GOLDEN = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                      "golden", "e2e_presets.json")

# metrics compared exactly (counts / booleans)
EXACT = ("n_qa", "dropped_frames", "zeco_engaged_frames")
# float aggregates compared to tolerance
CLOSE = ("accuracy", "avg_latency_ms", "p95_latency_ms", "avg_bitrate",
         "bandwidth_used")


def _golden_specs():
    """The seed-pinned workload: artic vs webrtc on one low, fluctuating
    uplink (the Fig. 13 operating point where ReCapABR slashes latency
    at equal accuracy and ZeCoStream engages), 128 px frames, periodic
    QA."""
    base = ScenarioSpec(
        duration=12.0, frame_h=128, frame_w=128, scene="retail",
        code_period_frames=40, trace="fluctuating", trace_seed=3, seed=3,
        scene_seed=3,
        trace_kwargs=dict(switches_per_min=8,
                          levels_kbps=[1130, 710, 400, 290]),
        qa="periodic",
        qa_kwargs=dict(start=3.0, period=2.0, count=4, answer_window=1.8))
    return [base.with_(system="artic", tag="artic"),
            base.with_(system="webrtc", tag="webrtc")]


@pytest.fixture(scope="module")
def golden():
    with open(GOLDEN) as f:
        return json.load(f)


def test_golden_file_is_schema_valid(golden):
    validate_run_result_json(golden)


def test_golden_schema_rejects_corruption(golden):
    bad = json.loads(json.dumps(golden))
    bad["scenarios"][0]["metrics"].pop("accuracy")
    with pytest.raises(ValueError):
        validate_run_result_json(bad)
    bad2 = json.loads(json.dumps(golden))
    bad2["schema"] = "artic.scenario.run_result/v0"
    with pytest.raises(ValueError):
        validate_run_result_json(bad2)


def test_pipeline_reproduces_golden_metrics(golden):
    """Re-run the STORED specs (round-tripped through the JSON) and pin
    every preset's aggregates to the snapshot."""
    specs = [ScenarioSpec.from_dict(rec["spec"])
             for rec in golden["scenarios"]]
    assert [s.tag for s in specs] == ["artic", "webrtc"]
    assert specs == _golden_specs(), \
        "golden specs drifted from _golden_specs(); regenerate the file"
    result = run_scenarios(specs)
    for rec, m in zip(golden["scenarios"], result.metrics):
        want = rec["metrics"]
        for f in EXACT:
            assert getattr(m, f) == want[f], (rec["spec"]["tag"], f)
        for f in CLOSE:
            assert getattr(m, f) == pytest.approx(want[f], rel=1e-4), \
                (rec["spec"]["tag"], f)
        assert [bool(b) for b in m.qa_results] == want["qa_results"]


def test_golden_separates_the_systems(golden):
    """The snapshot itself captures the paper's headline ordering on a
    starved link: artic sustains at least webrtc's accuracy at lower
    p95 latency, with ZeCoStream actually engaging."""
    by_tag = {rec["spec"]["tag"]: rec["metrics"]
              for rec in golden["scenarios"]}
    assert by_tag["artic"]["accuracy"] >= by_tag["webrtc"]["accuracy"]
    assert by_tag["artic"]["p95_latency_ms"] < \
        by_tag["webrtc"]["p95_latency_ms"]
    assert by_tag["artic"]["zeco_engaged_frames"] > 0
    assert by_tag["webrtc"]["zeco_engaged_frames"] == 0


def _regen() -> None:
    doc = run_scenarios(_golden_specs()).to_json(GOLDEN)
    validate_run_result_json(doc)
    print(f"wrote {GOLDEN}")
    for rec in doc["scenarios"]:
        print(rec["spec"]["tag"], {k: round(v, 3) if isinstance(v, float)
                                   else v
                                   for k, v in rec["metrics"].items()
                                   if k != "qa_results"})


if __name__ == "__main__":
    import sys

    if "--regen" in sys.argv:
        _regen()
    else:
        print(__doc__)

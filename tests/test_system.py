"""End-to-end behaviour tests for the paper's system.

These tie the full pipeline together: DeViBench build -> confidence
calibration -> trace-driven Artic session -> paper-claim directions
(headroom, latency, ZeCoStream accuracy, bandwidth reduction).
"""
import numpy as np
import pytest

from repro.core.session import QASample, SessionConfig, run_session
from repro.devibench import pipeline as dvb
from repro.net.traces import fluctuating_trace
from repro.video.scenes import make_scene


@pytest.fixture(scope="module")
def bench():
    return dvb.generate(n_scenes_per_cat=1, questions_per_obj=2, seed=0,
                        n_frames=20)


@pytest.fixture(scope="module")
def calibrator(bench):
    return dvb.fit_confidence_calibrator(bench)


def _episode(flags, seed, cal):
    sc = make_scene("retail", False, seed=seed, code_period_frames=40)
    tr = fluctuating_trace(40.0, switches_per_min=6, seed=seed)
    qa = [QASample(t_ask=4.5 + 4.0 * i, obj_idx=i % len(sc.objects),
                   answer_window=3.4) for i in range(8)]
    return run_session(sc, qa, tr, SessionConfig(
        duration=40.0, cc_kind="gcc", seed=seed, **flags), calibrator=cal)


def test_artic_end_to_end_beats_webrtc_on_qoe(calibrator):
    """The paper's headline direction: Artic must not lose accuracy while
    cutting latency and bandwidth use vs WebRTC (averaged over traces)."""
    acc_w, acc_a, lat_w, lat_a, bw_w, bw_a = [], [], [], [], [], []
    for seed in (0, 1, 2):
        w = _episode(dict(use_recap=False, use_zeco=False), seed, calibrator)
        a = _episode(dict(use_recap=True, use_zeco=True), seed, calibrator)
        acc_w.append(w.accuracy); acc_a.append(a.accuracy)
        lat_w.append(w.avg_latency_ms); lat_a.append(a.avg_latency_ms)
        bw_w.append(w.bandwidth_used); bw_a.append(a.bandwidth_used)
    assert np.mean(acc_a) >= np.mean(acc_w) - 0.05   # accuracy held
    # latency cut on every trace, large cut on average (on severely
    # starved links both systems ride the queue, shrinking the gap —
    # the paper's gains are likewise fluctuation-dependent, Fig. 9)
    assert all(a < w for a, w in zip(lat_a, lat_w))
    assert np.mean(lat_a) < 0.85 * np.mean(lat_w)
    assert np.mean(bw_a) < 0.8 * np.mean(bw_w)       # bandwidth headroom


def test_confidence_feedback_loop_closes(calibrator):
    """ReCapABR must settle near its tau-equilibrium: late-session
    confidence hovers around tau rather than saturating at 1."""
    sc = make_scene("retail", False, seed=5, code_period_frames=40)
    tr = fluctuating_trace(40.0, switches_per_min=2, seed=5)
    m = run_session(sc, [], tr, SessionConfig(
        duration=40.0, use_recap=True, use_zeco=False, tau=0.8),
        calibrator=calibrator)
    late_conf = np.mean(m.confidences[-150:])
    assert 0.45 < late_conf < 1.0
    # and the offered rate is bitrate-capped vs what webrtc would use
    assert np.mean(m.rates[-100:]) < 2.5e6


def test_devibench_drives_session_accuracy(bench, calibrator):
    """DeViBench validation split calibrates the confidence head used in
    sessions — the end-to-end dependency of §6.2."""
    assert calibrator(0.9) > calibrator(0.2)
    m = _episode(dict(use_recap=True, use_zeco=True), 7, calibrator)
    assert 0.0 <= m.accuracy <= 1.0
    assert m.n_qa == 8

"""Per-assigned-architecture smoke tests on reduced same-family configs.

Each arch: instantiate reduced config, run one forward + one train step
(grads) on CPU, assert output shapes and absence of NaNs; then one decode
step against a prefix cache.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry, shapes
from repro.models import transformer as tfm
from repro.models.config import reduced

ARCHS = registry.list_archs()
B, S = 2, 32


def _reduced(name):
    cfg = registry.get_config(name)
    over = {"dtype": "float32", "param_dtype": "float32"}
    if cfg.family == "hybrid":
        over["n_layers"] = 5  # 1 full (rec,rec,attn) group + 2 tail rec layers
    return reduced(cfg, **over)


def _batch(cfg, seq=S, labels=True):
    key = jax.random.PRNGKey(0)
    if cfg.mrope_sections is not None:
        b = {"embeds": jax.random.normal(key, (B, seq, cfg.d_model), jnp.float32) * 0.02,
             "mrope_positions": jnp.broadcast_to(
                 jnp.arange(seq, dtype=jnp.int32)[None, None, :], (3, B, seq)).copy()}
        if labels:
            b["labels"] = jax.random.randint(key, (B, seq), 0, cfg.vocab)
        return b
    if cfg.num_codebooks > 1:
        toks = jax.random.randint(key, (B, cfg.num_codebooks, seq), 0, cfg.vocab)
        return {"tokens": toks, "labels": toks} if labels else {"tokens": toks}
    toks = jax.random.randint(key, (B, seq), 0, cfg.vocab)
    return {"tokens": toks, "labels": toks} if labels else {"tokens": toks}


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    cfg = registry.get_config(arch)
    spec = {
        "qwen3-0.6b": (28, 1024, 16, 8, 3072, 151936),
        "llama3-405b": (126, 16384, 128, 8, 53248, 128256),
        "mistral-large-123b": (88, 12288, 96, 8, 28672, 32768),
        "deepseek-7b": (30, 4096, 32, 32, 11008, 102400),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
        "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
        "mamba2-780m": (48, 1536, 48, 48, 0, 50280),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab)
    assert got == spec
    if arch == "dbrx-132b":
        assert (cfg.moe.num_experts, cfg.moe.top_k) == (16, 4)
    if arch == "qwen3-moe-30b-a3b":
        assert (cfg.moe.num_experts, cfg.moe.top_k) == (128, 8)
    if arch == "mamba2-780m":
        assert cfg.ssm.d_state == 128
    if arch == "musicgen-medium":
        assert cfg.num_codebooks == 4
    if arch == "recurrentgemma-9b":
        assert cfg.local_window == 2048 and cfg.rglru is not None
    if arch == "qwen3-0.6b":
        assert cfg.qk_norm
    if arch == "qwen2-vl-72b":
        assert cfg.mrope_sections == (16, 24, 24)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = _reduced(arch)
    params = tfm.init(jax.random.PRNGKey(1), cfg)
    batch = _batch(cfg)
    logits, aux = tfm.forward(params, batch, cfg)
    if cfg.num_codebooks > 1:
        assert logits.shape == (B, cfg.num_codebooks, S, cfg.vocab)
    else:
        assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all(), "NaN/Inf in logits"

    (loss, _), grads = jax.value_and_grad(tfm.loss_fn, has_aux=True)(
        params, batch, cfg)
    assert np.isfinite(float(loss))
    assert all(np.isfinite(np.asarray(g)).all() for g in jax.tree.leaves(grads))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_prefill_decode(arch):
    cfg = _reduced(arch)
    params = tfm.init(jax.random.PRNGKey(1), cfg)
    batch = _batch(cfg, labels=False)
    logits, cache = tfm.prefill(params, batch, cfg,
                                max_len=S + 4 if cfg.family in ("dense", "moe") else None)
    assert np.isfinite(np.asarray(logits)).all()
    if cfg.num_codebooks > 1:
        step = {"tokens": jnp.zeros((B, cfg.num_codebooks, 1), jnp.int32)}
    elif cfg.mrope_sections is not None:
        step = {"tokens": jnp.zeros((B, 1), jnp.int32),
                "mrope_positions": jnp.full((3, B, 1), S, jnp.int32)}
    else:
        step = {"tokens": jnp.zeros((B, 1), jnp.int32)}
    logits2, cache2 = tfm.decode_step(params, cache, step, cfg)
    assert np.isfinite(np.asarray(logits2)).all()
    assert int(cache2["length"]) == S + 1


@pytest.mark.parametrize("shape_name", list(shapes.SHAPES))
@pytest.mark.parametrize("arch", ARCHS)
def test_input_specs_defined(arch, shape_name):
    cfg = registry.get_config(arch)
    ok, why = shapes.supported(cfg, shape_name)
    if not ok:
        assert shape_name == "long_500k" and why
        return
    specs = shapes.input_specs(cfg, shape_name)
    assert "batch" in specs
    for leaf in jax.tree.leaves(specs):
        assert isinstance(leaf, jax.ShapeDtypeStruct)

"""Shared test builders: heterogeneous fleet members / scenario specs,
metric-equality helpers, and the virtual-device subprocess environment.

One definition instead of the per-file copies that used to live in
test_fleet.py, test_scenario.py and test_zecostream_bank.py.  Kept as a
plain module (not conftest fixtures) because the sharded-parity suite's
subprocess child (tests/_sharded_fleet_child.py) imports it OUTSIDE
pytest; tests/conftest.py re-exposes the builders as fixtures.
"""
from __future__ import annotations

import hashlib
import json
import os

import numpy as np

from repro.core.fleet import FleetSession
from repro.core.scenario import ScenarioSpec
from repro.core.session import QASample, SessionConfig
from repro.core.zecostream import TimedBoxes
from repro.net.traces import (elevator_trace, fluctuating_trace,
                              mobility_trace, static_trace)
from repro.video.scenes import make_scene

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCENE_CYCLE = ("retail", "street", "office", "document")
TRACE_CYCLE = ("static", "fluctuating", "mobility.driving", "elevator")
SYSTEM_CYCLE = ("artic", "webrtc+zeco", "webrtc+recap", "webrtc")


# --------------------------------------------------------------------------
# Fleet-layer builder (FleetSession)
# --------------------------------------------------------------------------
def hetero_fleet_session(k: int, duration: float = 12.0,
                         hw: int | None = None) -> FleetSession:
    """Heterogeneous fleet member: scene category, motion, trace family,
    CC algorithm and system variant all cycle with k (k % 4 < 2 rows run
    ZeCoStream, so variants stay spread at any fleet size)."""
    kw = {} if hw is None else dict(h=hw, w=hw)
    sc = make_scene(SCENE_CYCLE[k % 4], k % 2 == 1, seed=k,
                    code_period_frames=40, **kw)
    tr = [static_trace(duration, mbps=0.5, seed=k),          # starved
          fluctuating_trace(duration, switches_per_min=6, seed=k),
          mobility_trace("driving", duration, seed=k),
          elevator_trace(duration)][k % 4]
    qa = [QASample(t_ask=4.0 + 3.0 * i, obj_idx=i % len(sc.objects),
                   answer_window=2.5) for i in range(2)]
    cfg = SessionConfig(duration=duration, cc_kind=["gcc", "bbr"][k % 2],
                        use_recap=k % 2 == 0, use_zeco=k % 4 < 2, seed=k)
    return FleetSession(sc, qa, tr, cfg)


# --------------------------------------------------------------------------
# Scenario-layer builders (ScenarioSpec)
# --------------------------------------------------------------------------
def base_scenario_spec(duration: float = 8.0) -> ScenarioSpec:
    return ScenarioSpec(duration=duration, code_period_frames=40,
                        qa="periodic",
                        qa_kwargs=dict(start=3.0, period=2.5, count=2,
                                       answer_window=2.0))


def hetero_scenario_specs(duration: float = 8.0, n: int = 4,
                          base: ScenarioSpec | None = None
                          ) -> list[ScenarioSpec]:
    """Heterogeneous but fleet-compatible specs: scene category, motion,
    trace family, CC and system variant all cycle across members."""
    base = base if base is not None else base_scenario_spec(duration)
    out = []
    for k in range(n):
        out.append(base.with_(
            scene=SCENE_CYCLE[k % 4],
            moving=k % 2 == 1, scene_seed=k, trace_seed=k, seed=k,
            trace=TRACE_CYCLE[k % 4],
            trace_kwargs=dict(mbps=0.5) if k % 4 == 0 else {},
            cc_kind=["gcc", "bbr"][k % 2],
            system=SYSTEM_CYCLE[k % 4]))
    return out


def mixed_cohort_specs(duration: float = 3.0, sizes=(64, 128),
                       counts=(3, 5), interleave: bool = True
                       ) -> list[ScenarioSpec]:
    """Specs spanning len(sizes) cohorts (one frame size each), tagged
    `c<cohort>-<member>`.  `interleave=True` round-robins the cohorts in
    the input order, so run_scenarios must re-stack per-cohort results
    back into input positions."""
    groups = []
    for ci, (hw, cnt) in enumerate(zip(sizes, counts)):
        group = hetero_scenario_specs(duration, n=cnt)
        groups.append([s.with_(frame_h=hw, frame_w=hw, tag=f"c{ci}-{k}")
                       for k, s in enumerate(group)])
    if not interleave:
        return [s for g in groups for s in g]
    out = []
    for i in range(max(len(g) for g in groups)):
        out.extend(g[i] for g in groups if i < len(g))
    return out


# --------------------------------------------------------------------------
# ZeCoStream feedback-packet builder
# --------------------------------------------------------------------------
def random_timed_boxes(rng: np.random.Generator, t: float,
                       steps: int = 6, horizon: float = 1.5,
                       max_boxes: int = 4) -> TimedBoxes:
    """A random grounding-then-prediction packet (the shape the fleet's
    TrajectoryPredictor emits), RNG-order-stable for seed pinning."""
    times = t + np.linspace(0.0, horizon, steps)
    rows = []
    for _ in times:
        nb = int(rng.integers(0, max_boxes))
        row = []
        for _ in range(nb):
            y0, x0 = rng.uniform(0, 200, 2)
            row.append((y0, x0, y0 + rng.uniform(10, 50),
                        x0 + rng.uniform(10, 50)))
        rows.append(row)
    return TimedBoxes(times=times, boxes=rows)


# --------------------------------------------------------------------------
# Metric equality (the fleet parity contract) + digests
# --------------------------------------------------------------------------
def assert_metrics_equal(a, b) -> None:
    """Bit-exact SessionMetrics equality — every list element equal, no
    tolerance (the fleet/scenario/sharding parity contract)."""
    assert a.accuracy == b.accuracy
    assert a.n_qa == b.n_qa and a.qa_results == b.qa_results
    assert a.latencies == b.latencies
    assert a.avg_bitrate == b.avg_bitrate
    assert a.bandwidth_used == b.bandwidth_used
    assert a.rates == b.rates
    assert a.confidences == b.confidences
    assert a.zeco_engaged_frames == b.zeco_engaged_frames
    assert a.dropped_frames == b.dropped_frames


def metrics_digest(metrics) -> str:
    """Order-sensitive content hash of a SessionMetrics list, floats as
    exact hex — equal digests mean bit-identical runs across processes."""
    def f(x):
        return float(x).hex()

    doc = [dict(latencies=[f(v) for v in m.latencies],
                rates=[f(v) for v in m.rates],
                confidences=[f(v) for v in m.confidences],
                accuracy=f(m.accuracy), n_qa=int(m.n_qa),
                qa_results=[bool(v) for v in m.qa_results],
                avg_bitrate=f(m.avg_bitrate),
                bandwidth_used=f(m.bandwidth_used),
                zeco_engaged_frames=int(m.zeco_engaged_frames),
                dropped_frames=int(m.dropped_frames))
           for m in metrics]
    return hashlib.sha256(
        json.dumps(doc, sort_keys=True).encode()).hexdigest()


# --------------------------------------------------------------------------
# Virtual-device subprocess environment
# --------------------------------------------------------------------------
def virtual_devices(n: int) -> dict:
    """Environment for a subprocess that sees `n` virtual host CPU
    devices: appends --xla_force_host_platform_device_count to XLA_FLAGS
    (must be set before jax imports, hence the subprocess) and puts the
    repo's src/ plus tests/ on PYTHONPATH."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={n}"
                        ).strip()
    extra = os.pathsep.join([os.path.join(ROOT, "src"),
                             os.path.join(ROOT, "tests")])
    env["PYTHONPATH"] = (extra + os.pathsep + env.get("PYTHONPATH", "")
                         ).rstrip(os.pathsep)
    return env

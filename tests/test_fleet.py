"""Fleet engine tests: serial<->batched parity, batched codec entry
points, vectorized channel bank, and batched ingestion helpers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _builders import assert_metrics_equal, hetero_fleet_session as _spec
from repro.core.fleet import Fleet, run_fleet
from repro.core.grounding import detect_cards, detect_cards_batch
from repro.core.session import run_session
from repro.kernels.qp_codec.ops import qp_codec_frame, qp_codec_frames
from repro.net.channel import Channel, ChannelBank
from repro.net.traces import (elevator_trace, fluctuating_trace,
                              static_trace)
from repro.video import codec
from repro.video.scenes import make_scene


# --------------------------------------------------------------------------
# Tentpole acceptance: fleet(N=4) reproduces serial run_session per session
# --------------------------------------------------------------------------
def test_fleet_n4_parity_with_serial():
    specs = [_spec(k) for k in range(4)]
    serial = [run_session(s.scene, s.qa_samples, s.trace, s.cfg)
              for s in specs]
    batched = run_fleet([_spec(k) for k in range(4)])
    for a, b in zip(serial, batched):
        assert_metrics_equal(a, b)


def test_fleet_fused_plan_matches_default():
    """The fused plan+encode dispatch (surfaces computed in-graph from the
    box arrays) reproduces the default bank-plan path exactly."""
    base = run_fleet([_spec(k, duration=8.0) for k in range(4)])
    fused = run_fleet([_spec(k, duration=8.0) for k in range(4)],
                      fused_plan=True)
    for a, b in zip(base, fused):
        assert a.accuracy == b.accuracy
        assert a.latencies == b.latencies
        assert a.avg_bitrate == b.avg_bitrate
        assert a.rates == b.rates
        assert a.confidences == b.confidences
        assert a.zeco_engaged_frames == b.zeco_engaged_frames


def test_profile_phase_times_sum_to_tick_loop_total():
    """`_mark` syncs each phase's device work before stamping it, so the
    per-phase times account for (nearly) all of the tick-loop wall time
    — async dispatch must not let one phase's work be billed to a later
    phase (or escape the accounting entirely)."""
    import time

    fl = Fleet([_spec(k, duration=3.0, hw=64) for k in range(4)],
               profile=True)
    cfg = fl.specs[0].cfg
    n_frames = int(cfg.duration * cfg.fps)
    t0 = time.perf_counter()
    for i in range(n_frames):
        fl.tick(i / cfg.fps)
    total = time.perf_counter() - t0
    assert fl.phase_times is not None
    assert all(v >= 0.0 for v in fl.phase_times.values())
    phase_sum = sum(fl.phase_times.values())
    # every phase ends in a sync, so the sum can only miss pure-python
    # glue between marks; allow 20% + scheduling noise
    assert phase_sum <= total + 1e-6
    assert phase_sum >= 0.8 * total - 0.05, (fl.phase_times, total)


def test_fleet_rejects_mismatched_members():
    a = _spec(0)
    b = _spec(1)
    b.cfg.duration = a.cfg.duration + 10.0
    with pytest.raises(ValueError):
        Fleet([a, b])
    c = _spec(1)
    c.cfg.rc_probe_stride = 4
    with pytest.raises(ValueError):
        Fleet([a, c])


# --------------------------------------------------------------------------
# Batched codec entry points
# --------------------------------------------------------------------------
def _frames(n=3, hw=64):
    return np.stack([make_scene("retail", False, seed=s, h=hw, w=hw)
                     .render(0) for s in range(n)]).astype(np.float32)


def test_rate_control_batch_equals_vmapped_rate_control():
    frames = _frames()
    shapes = np.zeros((3, 8, 8), np.float32)
    targets = np.asarray([2e4, 6e4, 1.5e5], np.float32)
    qb, eb = codec.rate_control_batch(frames, shapes, targets)
    qv, ev = jax.jit(jax.vmap(
        lambda f, q, t: codec.rate_control(f, q, t)))(frames, shapes,
                                                      targets)
    assert np.array_equal(np.asarray(qb), np.asarray(qv))
    assert np.array_equal(np.asarray(eb.coeffs), np.asarray(ev.coeffs))
    assert np.array_equal(np.asarray(eb.bits), np.asarray(ev.bits))


def test_rate_control_batch_matches_serial_per_sample():
    frames = _frames()
    shapes = np.zeros((3, 8, 8), np.float32)
    targets = np.asarray([2e4, 6e4, 1.5e5], np.float32)
    qb, eb = codec.rate_control_batch(frames, shapes, targets)
    for i in range(3):
        qs, es = codec.rate_control(jnp.asarray(frames[i]),
                                    jnp.asarray(shapes[i]),
                                    jnp.float32(targets[i]))
        assert np.array_equal(np.asarray(qb[i]), np.asarray(qs))
        assert float(eb.bits[i]) == float(es.bits)


def test_encode_decode_batch_match_single():
    frames = _frames()
    qp = np.full((3, 8, 8), 32.0, np.float32)
    eb = codec.encode_batch(frames, qp)
    rb = codec.decode_batch(eb)
    for i in range(3):
        es = codec.encode(jnp.asarray(frames[i]), jnp.asarray(qp[i]))
        assert np.array_equal(np.asarray(eb.coeffs[i]),
                              np.asarray(es.coeffs))
        assert np.array_equal(np.asarray(rb[i]),
                              np.asarray(codec.decode(es)))


def test_requantize_moves_bits_toward_delivered_budget():
    frame = _frames(1)[0]
    shape = np.zeros((8, 8), np.float32)
    _, enc = codec.rate_control(jnp.asarray(frame), jnp.asarray(shape),
                                jnp.float32(2e5))
    full_bits = float(enc.bits)
    target = 0.4 * full_bits
    enc2 = codec.requantize(enc.coeffs, enc.qp_blocks, jnp.asarray(shape),
                            jnp.float32(target))
    assert float(enc2.bits) < full_bits
    assert abs(float(enc2.bits) - target) / target < 0.3
    # and the requantized frame still decodes to a valid image
    rec = np.asarray(codec.decode(enc2))
    assert rec.shape == frame.shape and np.all((rec >= 0) & (rec <= 1))


def test_decode_delivered_batch_is_decode_when_nothing_dropped():
    frames = _frames()
    shapes = np.zeros((3, 8, 8), np.float32)
    targets = np.asarray([5e4, 5e4, 5e4], np.float32)
    _, enc = codec.rate_control_batch(frames, shapes, targets)
    none = np.zeros(3, bool)
    out = codec.decode_delivered_batch(enc, shapes, targets, none)
    assert np.array_equal(np.asarray(out), np.asarray(codec.decode_batch(enc)))


def test_qp_codec_frames_matches_per_frame_kernel():
    frames = _frames(3, hw=64)
    qp = np.stack([np.full((8, 8), q, np.float32) for q in (24, 32, 44)])
    rec_b, bits_b = qp_codec_frames(frames, qp, bs=16, interpret=True)
    for i in range(3):
        rec_s, bits_s = qp_codec_frame(frames[i], qp[i], bs=16,
                                       interpret=True)
        np.testing.assert_allclose(np.asarray(rec_b[i]), np.asarray(rec_s),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(float(bits_b[i]), float(bits_s),
                                   rtol=1e-6)


# --------------------------------------------------------------------------
# Vectorized channel bank
# --------------------------------------------------------------------------
def test_channel_bank_matches_serial_channels():
    rng = np.random.default_rng(0)
    traces = [static_trace(20.0, mbps=0.4, seed=1),
              fluctuating_trace(20.0, switches_per_min=8, seed=2),
              elevator_trace(20.0)]
    serial = [Channel(t) for t in traces]
    bank = ChannelBank(traces)
    for i in range(120):
        t = i * 0.1
        # interleave ack queries exactly as a session tick would
        acks_s = [ch.ack_stats() for ch in serial]
        acks_b = bank.ack_stats()
        for a, b in zip(acks_s, acks_b):
            assert a == b
        bits = rng.uniform(2e3, 6e4, size=3)
        reps = [ch.send_frame(t, float(bits[k]))
                for k, ch in enumerate(serial)]
        rep = bank.send_frames(t, bits)
        for k, r in enumerate(reps):
            assert r.latency == rep.latency[k]
            assert r.bits_sent == rep.bits_sent[k]
            assert r.bits_delivered == rep.bits_delivered[k]
            assert r.dropped == rep.dropped[k]
            assert r.queue_delay == rep.queue_delay[k]
    for k, ch in enumerate(serial):
        assert bank.reports_for(k) == ch.reports


# --------------------------------------------------------------------------
# Batched ingestion helpers
# --------------------------------------------------------------------------
def test_detect_cards_batch_matches_serial():
    frames = []
    for s in range(8):
        sc = make_scene(["retail", "lawn", "street", "document"][s % 4],
                        s % 2 == 1, seed=s, h=64, w=64)
        f = sc.render(s)
        rec, _ = codec.roundtrip(jnp.asarray(f),
                                 jnp.full((8, 8), 20.0 + 3 * s, jnp.float32))
        frames.append(np.asarray(rec))
    frames = np.stack(frames)
    assert detect_cards_batch(frames) == [detect_cards(f) for f in frames]


def test_detect_cards_core_matches_numpy():
    """Traceable grounding (the on-device rollout's server phase) ==
    the NumPy reference, boxes bit for bit."""
    from repro.core.grounding import detect_cards_core
    for s in range(6):
        sc = make_scene(["retail", "lawn", "street"][s % 3], s % 2 == 1,
                        seed=10 + s, h=64, w=64)
        f = sc.render(s).astype(np.float32)
        want = detect_cards(f)
        boxes, count, overflow = detect_cards_core(jnp.asarray(f))
        assert int(count) == len(want)
        assert not bool(overflow)
        got = [tuple(float(v) for v in np.asarray(boxes)[i])
               for i in range(int(count))]
        assert got == [tuple(float(v) for v in b) for b in want]


def test_glyph_stats_batch_compiles_x64_trace_once():
    """Regression: `glyph_stats_batch` used to re-enter `enable_x64()`
    (a global-config context manager) on EVERY call; the trace is now
    AOT-compiled once per (cell, padded batch) and steady-state calls
    must not touch the context manager at all."""
    from repro.core import ingest

    entered = []
    real = ingest.enable_x64

    class Counting:
        def __call__(self):
            entered.append(1)
            return real()

    ingest._COMPILED.clear()
    ingest.enable_x64 = Counting()
    try:
        rng = np.random.default_rng(0)
        patches = rng.random((3, 12, 12)).astype(np.float32)
        first = ingest.glyph_stats_batch(patches, 3)
        for _ in range(5):  # steady state: same padded shape
            again = ingest.glyph_stats_batch(patches, 3)
        assert len(entered) == 1  # one compile, zero re-entries
        np.testing.assert_array_equal(first[0], again[0])
        np.testing.assert_array_equal(first[1], again[1])
    finally:
        ingest.enable_x64 = real


def test_glyph_stats_batch_is_batch_size_invariant():
    """Per-record results must not depend on the batch they ride in (the
    fleet batches ingestion across sessions; the serial path calls per
    record) — including across the power-of-two padding boundary."""
    from repro.core import ingest
    rng = np.random.default_rng(7)
    patches = rng.random((5, 8, 8)).astype(np.float32)
    codes_all, margins_all = ingest.glyph_stats_batch(patches, 2)
    for i in range(5):
        c1, m1 = ingest.glyph_stats_batch(patches[i:i + 1], 2)
        assert int(c1[0]) == int(codes_all[i])
        assert float(m1[0]) == float(margins_all[i])

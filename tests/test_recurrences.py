"""Property tests for the two recurrent mixers against naive step-by-step
oracles: the chunked SSD algorithm and the RG-LRU associative scan must
match exact sequential recurrences for random shapes/chunk sizes."""
from _hypothesis_compat import hypothesis, st  # noqa: hypothesis optional
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.rglru import rglru_scan
from repro.models.ssd import ssd_scan


def naive_ssd(x, dt, A, B, C):
    """Exact sequential SSD recurrence (fp64-ish in fp32)."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    hpg = h // g
    Bf = np.repeat(np.asarray(B), hpg, axis=2)
    Cf = np.repeat(np.asarray(C), hpg, axis=2)
    xn, dtn, An = np.asarray(x), np.asarray(dt), np.asarray(A)
    state = np.zeros((b, h, p, n), np.float32)
    ys = np.zeros((b, s, h, p), np.float32)
    for t in range(s):
        decay = np.exp(dtn[:, t] * An[None, :])            # (b,h)
        dBx = np.einsum("bh,bhn,bhp->bhpn", dtn[:, t], Bf[:, t], xn[:, t])
        state = state * decay[..., None, None] + dBx
        ys[:, t] = np.einsum("bhpn,bhn->bhp", state, Cf[:, t])
    return ys, state


@hypothesis.given(
    seq=st.sampled_from([8, 16, 32]),
    chunk=st.sampled_from([4, 8, 16]),
    heads=st.sampled_from([(2, 1), (4, 2), (4, 4)]),
    seed=st.integers(0, 30),
)
@hypothesis.settings(deadline=None, max_examples=12)
def test_property_ssd_chunked_matches_sequential(seq, chunk, heads, seed):
    if seq % chunk:
        chunk = seq
    h, g = heads
    b, p, n = 2, 8, 4
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, seq, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, seq, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    B = jax.random.normal(ks[3], (b, seq, g, n)) * 0.5
    C = jax.random.normal(ks[4], (b, seq, g, n)) * 0.5
    y, final = ssd_scan(x, dt, A, B, C, chunk)
    y_ref, final_ref = naive_ssd(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(final), final_ref,
                               rtol=2e-4, atol=2e-4)


def test_ssd_chunk_boundary_state_passing():
    """Splitting a sequence into two ssd_scan calls with state threading
    must equal one full call (the prefill_extend contract)."""
    b, s, h, p, g, n = 1, 32, 2, 4, 1, 4
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    B = jax.random.normal(ks[3], (b, s, g, n)) * 0.5
    C = jax.random.normal(ks[4], (b, s, g, n)) * 0.5
    y_full, st_full = ssd_scan(x, dt, A, B, C, 8)
    y1, st1 = ssd_scan(x[:, :16], dt[:, :16], A, B[:, :16], C[:, :16], 8)
    y2, st2 = ssd_scan(x[:, 16:], dt[:, 16:], A, B[:, 16:], C[:, 16:], 8,
                       init_state=st1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], axis=1)),
                               np.asarray(y_full), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st2), np.asarray(st_full),
                               rtol=2e-4, atol=2e-4)


@hypothesis.given(seq=st.sampled_from([4, 16, 33]), seed=st.integers(0, 30),
                  with_init=st.booleans())
@hypothesis.settings(deadline=None, max_examples=12)
def test_property_rglru_scan_matches_sequential(seq, seed, with_init):
    b, w = 2, 8
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    a = jax.nn.sigmoid(jax.random.normal(k1, (b, seq, w)))  # decay in (0,1)
    bx = jax.random.normal(k2, (b, seq, w))
    h0 = jax.random.normal(k3, (b, w)) if with_init else None
    got = rglru_scan(a, bx, h0)
    h = np.zeros((b, w), np.float32) if h0 is None else np.asarray(h0)
    an, bn = np.asarray(a), np.asarray(bx)
    want = np.zeros((b, seq, w), np.float32)
    for t in range(seq):
        h = an[:, t] * h + bn[:, t]
        want[:, t] = h
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-5, atol=2e-5)

"""Whole-tick rollout parity suite — the PR's acceptance contract.

`Fleet.run(rollout=K)` compiles K-tick windows of the entire tick loop
into single `lax.scan` dispatches with all per-session state (channel
queues, CC/ABR lanes, ZeCoStream context, ack rings) resident in the
scan carry.  The contract is BIT-exactness: every metric list, channel
history row and client trajectory must equal the eager per-tick loop —
no tolerance — for every window size, fused or not, and for any way the
tick range is split into windows.  The sharded variant of the same
contract lives in tests/test_sharded_fleet.py (rollout_* cases).
"""
import functools

import jax
import numpy as np
import pytest

import _builders as B
from _hypothesis_compat import HAVE_HYPOTHESIS, hypothesis, st
from repro.core.fleet import Fleet, run_fleet
from repro.core.rollout import FleetRollout, max_window, slot_depth
from repro.core.session import finalize
from repro.net.cc import RATE_MAX, RATE_MIN

N, DUR, HW = 4, 3.0, 64


def _members(n=N, duration=DUR):
    return [B.hetero_fleet_session(k, duration, hw=HW) for k in range(n)]


@functools.lru_cache(maxsize=None)
def _eager_digest(n=N, duration=DUR, fused=True):
    # plain function, not a fixture: the hypothesis fallback shim calls
    # property tests with strategy examples only (no fixture injection)
    return B.metrics_digest(run_fleet(_members(n, duration),
                                      fused_plan=fused))


# --------------------------------------------------------------------------
# Window-size invariants
# --------------------------------------------------------------------------
def test_max_window_honours_turnaround_only():
    """The window clamp is the feedback TURNAROUND bound alone — the
    feedback period no longer caps it (multi-slot carries absorb several
    in-flight feedbacks per window); `slot_depth` sizes those carries."""
    specs = _members()
    cfg = specs[0].cfg
    dt = 1.0 / cfg.fps
    w = max_window(specs, cfg.fps)
    turnarounds = [s.cfg.inference_delay + s.cfg.downlink_delay
                   for s in specs]
    assert w == max(1, int(min(turnarounds) / dt + 1e-9))
    s = slot_depth(specs, cfg.fps, w)
    assert s >= 1
    # every spec's worst case number of feedbacks due inside one window
    # fits in the slots
    for sp in specs:
        assert s >= int(np.ceil(w * dt / sp.cfg.feedback_period - 1e-9))


def test_short_feedback_period_relaxes_window():
    """A feedback period SHORTER than the turnaround used to clamp the
    window to 1 tick; with depth-S slots the window stays at the
    turnaround bound and parity still holds for every split."""
    import dataclasses

    def members():
        ms = _members()
        return [dataclasses.replace(
            m, cfg=dataclasses.replace(m.cfg, feedback_period=0.15))
            for m in ms]

    specs = members()
    cfg = specs[0].cfg
    w = max_window(specs, cfg.fps)
    assert w > int(cfg.feedback_period * cfg.fps + 1e-9)  # old clamp beaten
    assert slot_depth(specs, cfg.fps, w) >= 2
    base = B.metrics_digest(run_fleet(members(), fused_plan=True))
    for window in (1, w):
        got = Fleet(members(), fused_plan=True).run(rollout=window)
        assert B.metrics_digest(got) == base


def test_rollout_clamps_oversized_window():
    fl = Fleet(_members(), fused_plan=True)
    ro = FleetRollout(fl, window=10 ** 6)
    assert ro.window == max_window(fl.specs, fl.specs[0].cfg.fps)


def test_rollout_rejects_partially_run_fleet():
    fl = Fleet(_members(), fused_plan=True)
    fl.tick(0.0)
    with pytest.raises(ValueError):
        FleetRollout(fl, 2)


# --------------------------------------------------------------------------
# Bit-exact parity with the eager tick loop
# --------------------------------------------------------------------------
@pytest.mark.parametrize("window", [1, 2, 3])
def test_rollout_bit_identical_to_eager(window):
    got = Fleet(_members(), fused_plan=True).run(rollout=window)
    assert B.metrics_digest(got) == _eager_digest()


def test_rollout_matches_nonfused_eager_fleet():
    """The rollout always plans in-graph (fused); the default bank-plan
    eager path must still match bit for bit (the two eager plan paths
    are themselves exact-equal, test_fleet.py)."""
    base = run_fleet(_members(), fused_plan=False)
    got = Fleet(_members(), fused_plan=False).run(rollout=3)
    for a, b in zip(base, got):
        B.assert_metrics_equal(a, b)


@pytest.mark.parametrize("window", [1, 3])
def test_on_device_server_bit_identical_to_eager(window):
    """`Fleet(on_device_server=True)`: glyph stats + card-grounding run
    inside the scan (stats-at-send) and the host only replays heap and
    metrics bookkeeping — still bit-exact against the eager loop."""
    got = Fleet(_members(), fused_plan=True,
                on_device_server=True).run(rollout=window)
    assert B.metrics_digest(got) == _eager_digest()


def test_on_device_server_shrinks_outfeed():
    """The on-device server phase replaces the (w, N, H, W) decoded-frame
    outfeed with per-session stats rows — orders of magnitude smaller."""
    fa = Fleet(_members(), fused_plan=True)
    fa.run(rollout=3)
    fb = Fleet(_members(), fused_plan=True, on_device_server=True)
    fb.run(rollout=3)
    assert fb._last_rollout._ys_nbytes < fa._last_rollout._ys_nbytes / 10


def test_megakernel_rollout_tolerance_tier():
    """`Fleet(megakernel=True)` is the documented fast-math tier: NOT
    bit-exact vs eager, but every metric stream must stay within
    fast-math tolerance and the QA outcomes must be identical."""
    base = run_fleet(_members(), fused_plan=True)
    got = Fleet(_members(), fused_plan=True, megakernel=True,
                on_device_server=True).run(rollout=3)
    for me, mm in zip(base, got):
        np.testing.assert_allclose(me.rates, mm.rates, rtol=1e-4)
        np.testing.assert_allclose(me.confidences, mm.confidences,
                                   rtol=1e-4, atol=1e-6)
        np.testing.assert_allclose(me.latencies, mm.latencies, rtol=1e-4)
        assert me.qa_results == mm.qa_results
        assert me.n_qa == mm.n_qa


def test_megakernel_rejects_mesh():
    from repro.launch.mesh import make_fleet_mesh
    with pytest.raises(NotImplementedError):
        Fleet(_members(), fused_plan=True, megakernel=True,
              mesh=make_fleet_mesh(1))


def test_rollout_syncs_bank_state_back():
    """After finish(), zeco/channel bank state equals the eager run's —
    post-run inspection must not see stale start-of-run arrays."""
    fa = Fleet(_members(), fused_plan=True)
    fa.run()
    fb = Fleet(_members(), fused_plan=True)
    fb.run(rollout=3)
    np.testing.assert_array_equal(fa.zeco.active, fb.zeco.active)
    np.testing.assert_array_equal(fa.zeco.engaged_total,
                                  fb.zeco.engaged_total)
    np.testing.assert_array_equal(fa.bank._queue_bits, fb.bank._queue_bits)
    np.testing.assert_array_equal(fa.bank._queue_pkts, fb.bank._queue_pkts)


# --------------------------------------------------------------------------
# Property: parity is invariant to how ticks are split into windows,
# and the resident carry stays inside its physical envelope throughout
# --------------------------------------------------------------------------
@hypothesis.settings(max_examples=3, deadline=None)
@hypothesis.given(seed=st.integers(min_value=0, max_value=31))
def test_carry_invariant_under_window_split_points(seed):
    rng = np.random.default_rng(seed)
    fl = Fleet(_members(), fused_plan=True)
    cfg = fl.specs[0].cfg
    n_frames = int(cfg.duration * cfg.fps)
    ro = FleetRollout(fl)
    i0 = 0
    while i0 < n_frames:
        w = min(int(rng.integers(1, ro.window + 1)), n_frames - i0)
        ro.run_window(i0, w)
        c = jax.device_get(ro.carry)
        # channel queues: non-negative bits, packet count within cap
        assert np.all(np.asarray(c["ch_qb"]) >= 0.0)
        qpk = np.asarray(c["ch_qpk"])
        assert np.all((qpk >= 0) & (qpk <= fl.bank.queue_packets))
        # CC lanes stay inside the rate envelope
        for key in ("gcc_rate", "abr_rate"):
            r = np.asarray(c[key])
            assert np.all((r >= RATE_MIN) & (r <= RATE_MAX)), key
        # hysteresis flags are strict booleans; only zeco-enabled
        # sessions may engage
        act = np.asarray(c["z_active"])
        assert act.dtype == np.bool_
        assert np.all(act[~np.asarray(fl.zeco.enabled, bool)] == False)  # noqa: E712
        i0 += w
    ro.finish()
    got = [finalize(s, fl.bank.reports_for(k))
           for k, s in enumerate(fl.states)]
    assert B.metrics_digest(got) == _eager_digest()

"""Whole-tick rollout parity suite — the PR's acceptance contract.

`Fleet.run(rollout=K)` compiles K-tick windows of the entire tick loop
into single `lax.scan` dispatches with all per-session state (channel
queues, CC/ABR lanes, ZeCoStream context, ack rings) resident in the
scan carry.  The contract is BIT-exactness: every metric list, channel
history row and client trajectory must equal the eager per-tick loop —
no tolerance — for every window size, fused or not, and for any way the
tick range is split into windows.  The sharded variant of the same
contract lives in tests/test_sharded_fleet.py (rollout_* cases).
"""
import functools

import jax
import numpy as np
import pytest

import _builders as B
from _hypothesis_compat import HAVE_HYPOTHESIS, hypothesis, st
from repro.core.fleet import Fleet, run_fleet
from repro.core.rollout import FleetRollout, max_window
from repro.core.session import finalize
from repro.net.cc import RATE_MAX, RATE_MIN

N, DUR, HW = 4, 3.0, 64


def _members(n=N, duration=DUR):
    return [B.hetero_fleet_session(k, duration, hw=HW) for k in range(n)]


@functools.lru_cache(maxsize=None)
def _eager_digest(n=N, duration=DUR, fused=True):
    # plain function, not a fixture: the hypothesis fallback shim calls
    # property tests with strategy examples only (no fixture injection)
    return B.metrics_digest(run_fleet(_members(n, duration),
                                      fused_plan=fused))


# --------------------------------------------------------------------------
# Window-size invariants
# --------------------------------------------------------------------------
def test_max_window_honours_turnaround_and_feedback_period():
    specs = _members()
    cfg = specs[0].cfg
    dt = 1.0 / cfg.fps
    w = max_window(specs, cfg.fps)
    for s in specs:
        turnaround = s.cfg.inference_delay + s.cfg.downlink_delay
        assert w <= int(turnaround / dt + 1e-9)
        assert w <= int(s.cfg.feedback_period / dt + 1e-9)
    assert w >= 1


def test_rollout_clamps_oversized_window():
    fl = Fleet(_members(), fused_plan=True)
    ro = FleetRollout(fl, window=10 ** 6)
    assert ro.window == max_window(fl.specs, fl.specs[0].cfg.fps)


def test_rollout_rejects_partially_run_fleet():
    fl = Fleet(_members(), fused_plan=True)
    fl.tick(0.0)
    with pytest.raises(ValueError):
        FleetRollout(fl, 2)


# --------------------------------------------------------------------------
# Bit-exact parity with the eager tick loop
# --------------------------------------------------------------------------
@pytest.mark.parametrize("window", [1, 2, 3])
def test_rollout_bit_identical_to_eager(window):
    got = Fleet(_members(), fused_plan=True).run(rollout=window)
    assert B.metrics_digest(got) == _eager_digest()


def test_rollout_matches_nonfused_eager_fleet():
    """The rollout always plans in-graph (fused); the default bank-plan
    eager path must still match bit for bit (the two eager plan paths
    are themselves exact-equal, test_fleet.py)."""
    base = run_fleet(_members(), fused_plan=False)
    got = Fleet(_members(), fused_plan=False).run(rollout=3)
    for a, b in zip(base, got):
        B.assert_metrics_equal(a, b)


def test_rollout_syncs_bank_state_back():
    """After finish(), zeco/channel bank state equals the eager run's —
    post-run inspection must not see stale start-of-run arrays."""
    fa = Fleet(_members(), fused_plan=True)
    fa.run()
    fb = Fleet(_members(), fused_plan=True)
    fb.run(rollout=3)
    np.testing.assert_array_equal(fa.zeco.active, fb.zeco.active)
    np.testing.assert_array_equal(fa.zeco.engaged_total,
                                  fb.zeco.engaged_total)
    np.testing.assert_array_equal(fa.bank._queue_bits, fb.bank._queue_bits)
    np.testing.assert_array_equal(fa.bank._queue_pkts, fb.bank._queue_pkts)


# --------------------------------------------------------------------------
# Property: parity is invariant to how ticks are split into windows,
# and the resident carry stays inside its physical envelope throughout
# --------------------------------------------------------------------------
@hypothesis.settings(max_examples=3, deadline=None)
@hypothesis.given(seed=st.integers(min_value=0, max_value=31))
def test_carry_invariant_under_window_split_points(seed):
    rng = np.random.default_rng(seed)
    fl = Fleet(_members(), fused_plan=True)
    cfg = fl.specs[0].cfg
    n_frames = int(cfg.duration * cfg.fps)
    ro = FleetRollout(fl)
    i0 = 0
    while i0 < n_frames:
        w = min(int(rng.integers(1, ro.window + 1)), n_frames - i0)
        ro.run_window(i0, w)
        c = jax.device_get(ro.carry)
        # channel queues: non-negative bits, packet count within cap
        assert np.all(np.asarray(c["ch_qb"]) >= 0.0)
        qpk = np.asarray(c["ch_qpk"])
        assert np.all((qpk >= 0) & (qpk <= fl.bank.queue_packets))
        # CC lanes stay inside the rate envelope
        for key in ("gcc_rate", "abr_rate"):
            r = np.asarray(c[key])
            assert np.all((r >= RATE_MIN) & (r <= RATE_MAX)), key
        # hysteresis flags are strict booleans; only zeco-enabled
        # sessions may engage
        act = np.asarray(c["z_active"])
        assert act.dtype == np.bool_
        assert np.all(act[~np.asarray(fl.zeco.enabled, bool)] == False)  # noqa: E712
        i0 += w
    ro.finish()
    got = [finalize(s, fl.bank.reports_for(k))
           for k, s in enumerate(fl.states)]
    assert B.metrics_digest(got) == _eager_digest()

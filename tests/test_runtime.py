"""Runtime-layer tests: optimizers, train loop, checkpointing (elastic +
atomic), data pipeline determinism, gradient compression, paged KV cache,
continuous-batching engine."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs import registry
from repro.data.pipeline import DataConfig, Prefetcher, TokenPipeline
from repro.distributed import compression
from repro.models import transformer as tfm
from repro.models.config import ModelConfig, reduced
from repro.serving import kv_cache
from repro.serving.engine import Engine, Request
from repro.serving.sampler import SamplerConfig, sample
from repro.training import optimizer as opt_lib
from repro.training.train_loop import TrainSettings, init_state, make_train_step

TINY = reduced(registry.get_config("qwen3-0.6b"),
               dtype="float32", param_dtype="float32", vocab=128)


def _pipeline(vocab=128, batch=4, seq=16, **kw):
    return TokenPipeline(DataConfig(vocab=vocab, batch=batch, seq=seq, **kw),
                         process_index=0, process_count=1)


# --------------------------------------------------------------------------
# Optimizers
# --------------------------------------------------------------------------
@pytest.mark.parametrize("kind", ["adamw", "adafactor"])
def test_optimizer_reduces_quadratic(kind):
    """Both optimizers should crush a convex toy loss."""
    w0 = {"w": jnp.array([3.0, -2.0, 1.5]), "b": jnp.array(4.0)}
    opt = opt_lib.make_optimizer(kind, opt_lib.constant(0.1))
    state = opt.init(w0)
    loss = lambda p: jnp.sum(p["w"] ** 2) + p["b"] ** 2
    p = w0
    for _ in range(200):
        g = jax.grad(loss)(p)
        p, state, _ = opt.update(g, state, p)
    assert float(loss(p)) < 1e-2


def test_train_step_descends_and_accum_matches():
    s1 = TrainSettings(peak_lr=1e-3, warmup_steps=2, total_steps=50)
    s2 = TrainSettings(peak_lr=1e-3, warmup_steps=2, total_steps=50, grad_accum=2)
    pipe = _pipeline()
    state1 = init_state(jax.random.PRNGKey(0), TINY, s1)
    state2 = init_state(jax.random.PRNGKey(0), TINY, s2)
    step1 = jax.jit(make_train_step(TINY, s1))
    step2 = jax.jit(make_train_step(TINY, s2))
    batch = jax.tree.map(jnp.asarray, pipe.batch_at(0))
    state1b, m1 = step1(state1, batch)
    state2b, m2 = step2(state2, batch)
    # accumulated grads over the same data give (nearly) the same update
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                     state1b.params, state2b.params)
    assert max(jax.tree.leaves(d)) < 5e-5

    # a few steps reduce loss
    losses = []
    state, step_fn = state1, step1
    for i in range(8):
        state, m = step_fn(state, jax.tree.map(jnp.asarray, pipe.batch_at(0)))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


# --------------------------------------------------------------------------
# Checkpointing
# --------------------------------------------------------------------------
def test_checkpoint_roundtrip_and_latest(tmp_path):
    s = TrainSettings()
    state = init_state(jax.random.PRNGKey(0), TINY, s)
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save(1, state, extra={"data_step": 7})
    mgr.save(5, state)
    mgr.save(9, state)
    assert mgr.all_steps() == [5, 9]  # keep=2 pruned step 1
    restored, extra = mgr.restore(jax.eval_shape(lambda: state), step=5)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert mgr.latest_step() == 9


def test_checkpoint_atomicity_partial_dir_ignored(tmp_path):
    s = TrainSettings()
    state = init_state(jax.random.PRNGKey(0), TINY, s)
    mgr = CheckpointManager(str(tmp_path), keep=5)
    mgr.save(3, state)
    # simulate a torn write: step dir without manifest
    os.makedirs(tmp_path / "step_00000010")
    (tmp_path / "step_00000010" / "arrays.npz").write_bytes(b"junk")
    assert mgr.all_steps() == [3]
    assert mgr.latest_step() == 3


def test_checkpoint_resume_is_bit_identical(tmp_path):
    """Train 4 steps; checkpoint at 2; resume and verify steps 3-4 match."""
    s = TrainSettings(peak_lr=1e-3, warmup_steps=1, total_steps=50)
    pipe = _pipeline()
    step_fn = jax.jit(make_train_step(TINY, s))
    mgr = CheckpointManager(str(tmp_path))

    state = init_state(jax.random.PRNGKey(0), TINY, s)
    for i in range(2):
        state, _ = step_fn(state, jax.tree.map(jnp.asarray, pipe.batch_at(i)))
    mgr.save(2, state, extra=pipe.cursor(2))
    for i in range(2, 4):
        state, _ = step_fn(state, jax.tree.map(jnp.asarray, pipe.batch_at(i)))
    ref = jax.tree.leaves(state.params)

    restored, extra = mgr.restore(jax.eval_shape(lambda: state))
    assert extra["data_step"] == 2
    state2 = restored
    for i in range(extra["data_step"], 4):
        state2, _ = step_fn(state2, jax.tree.map(jnp.asarray, pipe.batch_at(i)))
    for a, b in zip(ref, jax.tree.leaves(state2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------------
# Data pipeline
# --------------------------------------------------------------------------
def test_pipeline_deterministic_and_host_sharded():
    p0 = _pipeline()
    assert np.array_equal(p0.batch_at(5)["tokens"], p0.batch_at(5)["tokens"])
    p1 = TokenPipeline(DataConfig(vocab=128, batch=4, seq=16),
                       process_index=1, process_count=2)
    assert not np.array_equal(p0.batch_at(5)["tokens"], p1.batch_at(5)["tokens"])


def test_prefetcher_yields_in_order():
    pipe = _pipeline()
    pf = Prefetcher(pipe.iterate(0), depth=2)
    got = [next(pf) for _ in range(3)]
    for i, b in enumerate(got):
        np.testing.assert_array_equal(b["tokens"], pipe.batch_at(i)["tokens"])
    pf.stop()


# --------------------------------------------------------------------------
# Gradient compression
# --------------------------------------------------------------------------
def test_compression_error_feedback_converges():
    """SGD on a quadratic with int8 grads + error feedback still converges."""
    w = jnp.array([2.0, -3.0, 1.0, 0.5] * 8)
    target = jnp.linspace(-1, 1, 32)
    state = None
    for _ in range(300):
        g = 2 * (w - target)
        g_c, state, m = compression.compress_grads({"w": g}, state)
        w = w - 0.05 * g_c["w"]
    assert float(jnp.max(jnp.abs(w - target))) < 1e-2


def test_compression_unbiased_over_time():
    """Error feedback: accumulated residual stays bounded."""
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal(1000), jnp.float32)
    state = None
    for _ in range(50):
        _, state, m = compression.compress_grads({"g": g}, state)
    res = float(jnp.max(jnp.abs(state.error["g"])))
    scale = float(jnp.max(jnp.abs(g))) / 127
    assert res < 2 * scale  # residual bounded by ~1 quantization bin


# --------------------------------------------------------------------------
# Paged KV cache
# --------------------------------------------------------------------------
def test_paged_cache_matches_contiguous():
    cfg = TINY
    L, Hk, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim_
    B, page, max_blocks = 2, 4, 4
    st = kv_cache.init_paged(cfg, n_pages=B * max_blocks, page=page,
                             batch=B, max_blocks=max_blocks)
    alloc = kv_cache.PageAllocator(B * max_blocks)
    tables = np.zeros((B, max_blocks), np.int32)
    for b in range(B):
        tables[b] = alloc.alloc(b, max_blocks)
    st = st._replace(tables=jnp.asarray(tables))

    rng = np.random.default_rng(0)
    T = 10  # spans 3 pages
    ks = rng.standard_normal((T, L, B, Hk, hd)).astype(np.float32)
    vs = rng.standard_normal((T, L, B, Hk, hd)).astype(np.float32)
    for t in range(T):
        st = kv_cache.append_token(st, jnp.asarray(ks[t]), jnp.asarray(vs[t]))
    k_all, v_all = kv_cache.gather_kv(st)
    got = np.asarray(k_all)[:, :, :T]  # (L, B, T, Hk, hd)
    want = np.moveaxis(ks, 0, 2)
    np.testing.assert_allclose(got, want, rtol=1e-6)
    assert alloc.utilization == 1.0
    alloc.release(0)
    assert alloc.utilization == 0.5


# --------------------------------------------------------------------------
# Sampler + engine
# --------------------------------------------------------------------------
def test_sampler_greedy_and_topk():
    logits = jnp.asarray([[0.0, 5.0, 1.0, -2.0]])
    out = sample(jax.random.PRNGKey(0), logits, SamplerConfig())
    assert int(out.token[0]) == 1
    assert out.top1_prob[0] > 0.9
    out2 = sample(jax.random.PRNGKey(0), logits,
                  SamplerConfig(temperature=1.0, top_k=1))
    assert int(out2.token[0]) == 1


def test_engine_continuous_batching_serves_all():
    cfg = TINY
    params = tfm.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, max_batch=2, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, tokens=rng.integers(0, cfg.vocab, 8, dtype=np.int32),
                    max_new_tokens=5) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    finished = eng.run_until_drained()
    assert len(finished) == 5
    assert all(len(r.output) == 5 for r in finished)
    assert eng.stats.tokens_out == 25
    # continuous batching actually interleaved (5 reqs through 2 slots)
    assert eng.stats.admitted == 5


def test_engine_decode_matches_prefill_continuation():
    """Engine slot decode must equal monolithic prefill+decode for one seq."""
    cfg = TINY
    params = tfm.init(jax.random.PRNGKey(0), cfg)
    toks = np.arange(8, dtype=np.int32) % cfg.vocab
    eng = Engine(cfg, params, max_batch=2, max_len=64)
    eng.submit(Request(uid=0, tokens=toks, max_new_tokens=4))
    fin = eng.run_until_drained()
    # reference: greedy decode without batching
    logits, cache = tfm.prefill(params, {"tokens": jnp.asarray(toks)[None]},
                                cfg, max_len=64)
    out_ref = [int(jnp.argmax(logits[0, 0]))]
    for _ in range(3):
        logits, cache = tfm.decode_step(
            params, cache, {"tokens": jnp.asarray([[out_ref[-1]]])}, cfg)
        out_ref.append(int(jnp.argmax(logits[0, 0])))
    assert fin[0].output == out_ref

"""Serving-bridge tests: engine streaming sessions, slot lifecycle, KV
page accounting, the deterministic patch embedder, and the
Fleet(server="engine") end-to-end path."""
import math

import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.models import transformer as tfm
from repro.models.config import reduced
from repro.serving import kv_cache
from repro.serving.bridge import (EngineServerBridge, SessionTelemetry,
                                  frames_to_patches)
from repro.serving.engine import Engine, Request, SessionOverflowError
from repro.serving.sampler import SamplerConfig

TINY = reduced(registry.get_config("qwen3-0.6b"),
               dtype="float32", param_dtype="float32", vocab=128)


@pytest.fixture(scope="module")
def tiny_params():
    return tfm.init(jax.random.PRNGKey(0), TINY)


def _engine(tiny_params, **kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_len", 64)
    return Engine(TINY, tiny_params, **kw)


def _req(uid, n=4, max_new=3, **kw):
    rng = np.random.default_rng(uid)
    return Request(uid=uid,
                   tokens=rng.integers(0, TINY.vocab, n, dtype=np.int32),
                   max_new_tokens=max_new, **kw)


# --------------------------------------------------------------------------
# Satellite: shared-mutable-default + simulated-time fixes
# --------------------------------------------------------------------------
def test_engine_default_sampler_is_per_instance(tiny_params):
    a = _engine(tiny_params)
    b = _engine(tiny_params)
    assert a.sampler is not b.sampler
    assert a.sampler == SamplerConfig()


def test_engine_times_are_simulated_not_wall_clock(tiny_params):
    eng = _engine(tiny_params, step_dt=0.5)
    eng.submit(_req(0, max_new=2), now=3.0)
    done = eng.run_until_drained()
    assert len(done) == 1
    r = done[0]
    # arrival stamped from the caller's clock; service times are exact
    # multiples of step_dt past it — impossible under time.time()
    assert r.arrival == 3.0
    assert r.first_token_time == 3.5
    assert r.ttft == 0.5
    assert r.done_time == 4.0
    assert r.queue_delay == 0.5  # clock had self-advanced to 3.5 on tick 1


def test_engine_queue_delay_reflects_busy_clock(tiny_params):
    eng = _engine(tiny_params, step_dt=0.01, max_len=64)
    eng.open_session(7)
    d0 = eng.extend_session(7, np.zeros((8, TINY.d_model), np.float32),
                            now=0.0)
    # the engine clock is now past 0.0; a second op submitted at the
    # same fleet time queues behind the first
    d1 = eng.extend_session(7, np.zeros((8, TINY.d_model), np.float32),
                            now=0.0)
    assert d0 == 0.0
    assert d1 == pytest.approx(eng.step_dt)


def test_engine_step_explicit_now_advances_simulated_clock(tiny_params):
    """Regression: step(now=...) used to leave the clock where
    _begin_service put it — every externally-driven tick was free, so
    queue delays and TTFTs under a fleet driver were understated.  An
    explicit-now step must cost step_dt exactly like the self-advancing
    path."""
    eng = _engine(tiny_params, step_dt=0.5)
    eng.submit(_req(0, max_new=2), now=3.0)
    done = []
    while not done:
        done = eng.step(now=3.0)  # external driver stuck at t=3.0
    r = done[0]
    # identical timeline to the now=None path pinned above: the clock
    # self-advances past the stale driver time, never backwards
    assert r.arrival == 3.0
    assert r.first_token_time == 3.5
    assert r.ttft == 0.5
    assert r.done_time == 4.0
    assert eng.clock == r.done_time


def test_open_session_wait_mode(tiny_params):
    """With every slot busy serving plain requests, wait=True spins the
    engine until one frees, and the time spent waiting is stamped as
    the session's admission delay."""
    eng = _engine(tiny_params, step_dt=0.5)
    eng.submit(_req(0, max_new=2), now=0.0)
    eng.submit(_req(1, max_new=2), now=0.0)
    eng.step()  # both admitted: all 2 slots busy
    with pytest.raises(RuntimeError, match="no free slot"):
        eng.open_session(5)  # slot-or-error default unchanged
    slot = eng.open_session(5, now=0.0, wait=True)
    assert eng.slots[slot] is None and eng._slot_sids[slot] == 5
    assert eng.stats.finished == 2  # the wait drove both to completion
    delay = eng.session_admission_delay(5)
    assert delay > 0.0
    assert delay == pytest.approx(eng.clock)  # opened at now=0.0


def test_open_session_wait_all_pinned_fails_fast(tiny_params):
    """wait=True must not spin forever when every slot is pinned by
    another session — no amount of stepping frees one."""
    eng = _engine(tiny_params)
    eng.open_session(0)
    eng.open_session(1)
    with pytest.raises(RuntimeError, match="pinned"):
        eng.open_session(2, wait=True)


def test_extend_session_empty_embeds_is_noop(tiny_params):
    """Regression: a tick that delivered zero frames produced a
    zero-length extend, and _extend_chunks returned None instead of the
    updated KV state — the next sample() crashed.  Empty extends are
    now an explicit no-op (nothing buffered) and never reach prefill."""
    eng = _engine(tiny_params)
    eng.open_session(0)
    assert eng.extend_session(0, np.zeros((0, TINY.d_model),
                                          np.float32)) == 0.0
    assert eng.session_length(0) == 0
    # with context already buffered, an empty extend still flushes it
    eng.extend_session(0, np.ones((4, TINY.d_model), np.float32))
    eng.extend_session(0, np.zeros((0, TINY.d_model), np.float32))
    assert eng.session_length(0) == 4
    # a question, unlike a frame batch, can never be empty
    with pytest.raises(ValueError, match="at least one token"):
        eng.submit_query(0, np.asarray([], np.int32))


def test_score_count_modulus_tracks_scene_answer_space():
    """Regression: the count-question fold was hardcoded `% 9`, so any
    scene with >= 9 objects could never score a correct count.  The
    modulus must be the scene's actual answer space, [0, n_objects]."""
    from types import SimpleNamespace
    stub = SimpleNamespace(_scenes={0: SimpleNamespace(objects=[None] * 10)},
                           _fps={0: 10.0})
    qa = SimpleNamespace(kind="count_objects", t_ask=0.0, obj_idx=0)
    score = EngineServerBridge._score
    # correct count == n_objects == 10: unreachable under the old % 9
    assert score(stub, 0, qa, SimpleNamespace(output=[10]))
    assert score(stub, 0, qa, SimpleNamespace(output=[21]))  # 21 % 11 == 10
    assert not score(stub, 0, qa, SimpleNamespace(output=[9]))
    assert not score(stub, 0, qa, SimpleNamespace(output=[]))


def test_drain_skips_requests_without_ttft():
    """Regression: a drained request that never produced a token
    (ttft=None) used to record a 0.0 TTFT sentinel, dragging the
    percentiles toward zero.  It must be skipped entirely."""
    from types import SimpleNamespace
    tel = SessionTelemetry()
    qa = SimpleNamespace(kind="count_objects", t_ask=0.0, obj_idx=0)
    req = SimpleNamespace(ttft=None, queue_delay=0.25, confidence=0.5,
                          output=[])
    stub = SimpleNamespace(
        engine=SimpleNamespace(drain_queries=lambda now: None),
        telemetry={0: tel}, _pending={0: (qa, req)},
        _scenes={0: SimpleNamespace(objects=[None] * 3)}, _fps={0: 10.0})
    stub._score = lambda k, q, r: EngineServerBridge._score(stub, k, q, r)
    results = EngineServerBridge.drain(stub, now=1.0)
    assert results == {0: False}
    assert tel.ttfts == []                 # no 0.0 sentinel
    assert tel.queue_delays == [0.25]      # real telemetry still lands


def test_empty_serving_percentiles_export_nan():
    """Oracle sessions have no engine telemetry; their serving
    percentiles must export NaN, not a fake 0.0 measurement."""
    from repro.core.session import SessionMetrics
    m = SessionMetrics(latencies=[], accuracy=1.0, n_qa=0, avg_bitrate=0.0,
                       bandwidth_used=0.0, confidences=[], rates=[],
                       zeco_engaged_frames=0, qa_results=[])
    for name in ("ttft_p50_ms", "ttft_p95_ms", "ttft_p99_ms",
                 "queue_p50_ms", "queue_p95_ms", "queue_p99_ms"):
        assert math.isnan(getattr(m, name))
    # frame-latency percentiles keep their inf-when-empty convention
    assert math.isinf(m.p50_latency_ms) and math.isinf(m.p99_latency_ms)


# --------------------------------------------------------------------------
# Satellite: engine slot lifecycle
# --------------------------------------------------------------------------
def test_queue_admission_and_slot_reuse(tiny_params):
    """5 requests through 2 slots: all served, slots freed on finish and
    reused immediately, queue drains in order."""
    eng = _engine(tiny_params)
    for i in range(5):
        eng.submit(_req(i, max_new=3))
    done = eng.run_until_drained()
    assert sorted(r.uid for r in done) == [0, 1, 2, 3, 4]
    assert eng.stats.admitted == 5
    assert eng.stats.finished == 5
    assert all(len(r.output) == 3 for r in done)
    assert all(r is None for r in eng.slots)
    # later arrivals waited for a slot: queue delays are monotone in uid
    delays = [r.queue_delay for r in sorted(done, key=lambda r: r.uid)]
    assert delays[0] == 0.0 and delays[-1] >= delays[0]


def test_heterogeneous_lengths_do_not_block(tiny_params):
    """A short request sharing the batch with a long one finishes first
    and frees its slot while the long one keeps decoding."""
    eng = _engine(tiny_params)
    eng.submit(_req(0, n=3, max_new=2))
    eng.submit(_req(1, n=9, max_new=12))
    finished_at = {}
    for tick in range(30):
        for r in eng.step():
            finished_at[r.uid] = tick
        if len(finished_at) == 2:
            break
    assert finished_at[0] < finished_at[1]
    # the freed slot is immediately reusable mid-flight
    eng.submit(_req(2, max_new=2))
    done = eng.run_until_drained()
    assert {r.uid for r in done} == {2}


def test_session_pins_slot_against_admission(tiny_params):
    """A streaming session's slot must never be handed to queued
    requests; with 1 of 2 slots pinned, plain requests still drain
    through the remaining slot."""
    eng = _engine(tiny_params)
    slot = eng.open_session(42)
    eng.extend_session(42, np.ones((4, TINY.d_model), np.float32))
    before = eng.session_length(42)
    for i in range(3):
        eng.submit(_req(i, max_new=2))
    done = eng.run_until_drained()
    assert len(done) == 3
    assert all(r is None for r in eng.slots)
    assert eng._slot_sids[slot] == 42          # still pinned
    assert eng.session_length(42) == before    # context untouched
    # closing the session frees the slot for admission again
    eng.close_session(42)
    eng.submit(_req(9, max_new=2))
    eng.submit(_req(10, max_new=2))
    eng.step()
    assert sum(r is not None for r in eng.slots) == 2


def test_open_session_slot_or_error(tiny_params):
    eng = _engine(tiny_params)
    eng.open_session(0)
    eng.open_session(1)
    with pytest.raises(RuntimeError, match="no free slot"):
        eng.open_session(2)
    with pytest.raises(ValueError, match="already open"):
        eng.open_session(0)


def test_extend_session_overflow_raises(tiny_params):
    eng = _engine(tiny_params, max_len=32)
    eng.open_session(0)
    eng.extend_session(0, np.zeros((30, TINY.d_model), np.float32))
    with pytest.raises(SessionOverflowError):
        eng.extend_session(0, np.zeros((3, TINY.d_model), np.float32))
    # the failed op must not have grown the context
    assert eng.session_length(0) == 30
    # a query that would overflow (query + max_new) is refused too
    with pytest.raises(SessionOverflowError):
        eng.submit_query(0, np.asarray([1, 2], np.int32), max_new=4)


def test_extend_then_query_matches_monolithic_prefill(tiny_params):
    """Chunked extend + query prefill must reproduce one monolithic
    prefill over the same embedding sequence: the first sampled answer
    token (greedy) is identical."""
    rng = np.random.default_rng(0)
    emb_a = rng.standard_normal((5, TINY.d_model)).astype(np.float32)
    emb_b = rng.standard_normal((7, TINY.d_model)).astype(np.float32)
    toks = np.asarray([3, 1, 4], np.int32)

    eng = _engine(tiny_params, max_len=64, chunk_max=4)  # forces chunking
    eng.open_session(0)
    eng.extend_session(0, emb_a)
    eng.extend_session(0, emb_b)
    req = eng.submit_query(0, toks, max_new=1)
    eng.drain_queries()

    tok_emb = np.asarray(tfm.layers.embed(
        tiny_params["embed"], jax.numpy.asarray(toks)[None], TINY)[0])
    full = np.concatenate([emb_a, emb_b, tok_emb], axis=0)
    logits, _ = tfm.prefill(tiny_params, {"embeds": full[None]}, TINY,
                            max_len=64)
    want = int(np.argmax(np.asarray(logits[0, 0])))
    assert req.output[0] == want


def test_drain_queries_is_batched_across_sessions(tiny_params):
    """Two querying sessions decode together: the whole drain spends one
    engine step per answer token, not one per (session, token)."""
    eng = _engine(tiny_params, step_dt=1.0)
    for sid in (0, 1):
        eng.open_session(sid)
        eng.extend_session(sid, np.ones((4, TINY.d_model), np.float32) * sid)
    steps0 = eng.stats.steps
    for sid in (0, 1):
        eng.submit_query(sid, np.asarray([1, 2], np.int32), max_new=4)
    steps_prefill = eng.stats.steps - steps0
    done = eng.drain_queries()
    assert set(done) == {0, 1}
    # 3 more tokens after the prefill-sampled first -> 3 decode steps
    assert eng.stats.steps - steps0 - steps_prefill == 3
    assert all(len(r.output) == 4 for r in done.values())
    # answer tokens joined each session's context
    assert eng.session_length(0) == 4 + 2 + 4


# --------------------------------------------------------------------------
# Satellite: KV page accounting + kv_cache unit tests
# --------------------------------------------------------------------------
def test_page_allocator_round_trip():
    al = kv_cache.PageAllocator(4)
    got = al.alloc("a", 3)
    assert len(got) == 3 and len(set(got)) == 3
    assert al.utilization == 0.75
    with pytest.raises(MemoryError):
        al.alloc("b", 2)
    al.release("a")
    assert al.utilization == 0.0
    # released pages are reusable and release of unknown keys is a no-op
    assert len(al.alloc("c", 4)) == 4
    al.release("nope")
    assert al.utilization == 1.0


def test_init_paged_shapes_and_append_gather():
    st = kv_cache.init_paged(TINY, n_pages=8, page=4, batch=2, max_blocks=3)
    L, Hk, hd = TINY.n_layers, TINY.n_kv_heads, TINY.head_dim_
    assert st.pages_k.shape == (L, 8, 4, Hk, hd)
    assert st.tables.shape == (2, 3)
    assert int(st.lengths.sum()) == 0
    # give each sequence a distinct physical page and append two tokens
    st = st._replace(tables=st.tables.at[0, 0].set(5).at[1, 0].set(2))
    rng = np.random.default_rng(0)
    ks = rng.standard_normal((2, L, 2, Hk, hd)).astype(np.float32)
    vs = rng.standard_normal((2, L, 2, Hk, hd)).astype(np.float32)
    for t in range(2):
        st = kv_cache.append_token(st, ks[:, :, t].transpose(1, 0, 2, 3),
                                   vs[:, :, t].transpose(1, 0, 2, 3))
    assert list(np.asarray(st.lengths)) == [2, 2]
    k_all, v_all = kv_cache.gather_kv(st)
    assert k_all.shape == (L, 2, 3 * 4, Hk, hd)
    for b in range(2):
        np.testing.assert_allclose(np.asarray(k_all[:, b, :2]), ks[b],
                                   rtol=0, atol=0)
        np.testing.assert_allclose(np.asarray(v_all[:, b, :2]), vs[b],
                                   rtol=0, atol=0)
    # single-layer view agrees with the stacked gather
    k0, v0 = kv_cache.gather_kv(st, layer=0)
    np.testing.assert_array_equal(np.asarray(k0), np.asarray(k_all[0]))


def test_engine_stats_surface_kv_pages(tiny_params):
    eng = _engine(tiny_params, max_len=64, kv_page=16)
    assert eng.stats.kv_pages_total == 2 * (64 // 16)
    eng.submit(_req(0, n=20, max_new=2))
    eng.run_until_drained()
    # pages grew with the context, peaked, and were released on retire
    assert eng.stats.kv_pages_peak >= 2
    assert eng.stats.kv_pages_used == 0
    assert eng.stats.kv_utilization == 0.0
    assert 0.0 < eng.stats.kv_peak_utilization <= 1.0
    assert 0.0 < eng.stats.slot_utilization <= 1.0
    # session pages are held until close
    eng.open_session(0)
    eng.extend_session(0, np.zeros((17, TINY.d_model), np.float32))
    assert eng.stats.kv_pages_used == 2
    eng.close_session(0)
    assert eng.stats.kv_pages_used == 0


# --------------------------------------------------------------------------
# The patch embedder
# --------------------------------------------------------------------------
def test_frames_to_patches_shape_and_determinism():
    rng = np.random.default_rng(0)
    frames = rng.random((3, 64, 48)).astype(np.float32)
    a = frames_to_patches(frames, d_model=32, patch_grid=2, seed=1)
    b = frames_to_patches(frames.copy(), d_model=32, patch_grid=2, seed=1)
    assert a.shape == (3, 4, 32)
    np.testing.assert_array_equal(a, b)
    # a single (H, W) frame batches to B=1
    one = frames_to_patches(frames[0], d_model=32, patch_grid=2, seed=1)
    np.testing.assert_array_equal(one[0], a[0])


def test_frames_to_patches_sees_degradation():
    """The embedder must distinguish a clean frame from a degraded one —
    conditioning on delivered quality is the whole point."""
    rng = np.random.default_rng(0)
    clean = rng.random((64, 64)).astype(np.float32)
    degraded = np.round(clean * 4) / 4  # crude re-quantization
    a = frames_to_patches(clean, 32)
    b = frames_to_patches(degraded, 32)
    assert np.abs(a - b).max() > 0
    with pytest.raises(ValueError, match="too small"):
        frames_to_patches(np.zeros((8, 8)), 32, patch_grid=2)


# --------------------------------------------------------------------------
# Bridge behavior
# --------------------------------------------------------------------------
@pytest.fixture(scope="module")
def scene64():
    from repro.video.scenes import make_scene
    return make_scene("office", False, 0, h=64, w=64)


def _bridge(n=1, **kw):
    kw.setdefault("max_len", 96)
    kw.setdefault("step_dt", 0.004)
    return EngineServerBridge(n, **kw)


def test_bridge_rolls_context_over_at_capacity(scene64):
    # eviction=False opts back into the legacy close+reopen rollover
    br = _bridge(eviction=False)
    assert br.engine.eviction is None
    br.open(0, scene64, fps=10.0)
    for tick in range(40):  # 160 patch tokens vs max_len 96
        br.extend(0, scene64.render(tick % 8), tick * 0.1)
    tel = br.telemetry[0]
    assert tel.rollovers >= 1
    assert tel.evictions == 0
    assert br.engine.session_length(0) + 4 + br._reserve <= 96 + 4
    # a query still fits after heavy streaming
    class _QA:
        kind, obj_idx, t_ask = "read_code", 0, 1.0
    assert br.answer_now(0, _QA(), 5.0) in (True, False)
    assert len(tel.ttfts) == 1 and len(tel.confidences) == 1


def test_bridge_rollover_is_clock_stamped(scene64):
    """Regression: the rollover reopen used to call open_session with no
    `now=`, so the reopened session was clock-blind — no admission
    bookkeeping was stamped, unlike every other open path.  A rollover
    behind a busy engine clock must record the admission delay in the
    telemetry like `open` does."""
    br = _bridge(eviction=False, step_dt=0.05, max_len=32)
    br.open(0, scene64, fps=10.0)
    # 4 patch tokens/extend vs max_len 32 with reserve 7: rollover on
    # the 6th extend.  step_dt=0.05 per chunk keeps the engine clock
    # well ahead of the (stale) fleet tick time, so the reopen queues.
    for tick in range(7):
        br.extend(0, scene64.render(tick), now=0.0)
    tel = br.telemetry[0]
    assert tel.rollovers >= 1
    sess = br.engine._sessions[0]
    # the reopened session is stamped on the simulated clock: it waited
    # for the engine's earlier work, and the wait joined the telemetry
    assert sess.admission_delay > 0.0
    assert sess.admission_delay in tel.queue_delays


def test_bridge_rollover_with_inflight_query_raises(scene64):
    """Rollover (and `close`) while a query is in flight would silently
    drop its decode state; both must refuse instead."""
    br = _bridge(eviction=False, max_len=32)
    br.open(0, scene64, fps=10.0)
    class _QA:
        kind, obj_idx, t_ask = "read_code", 0, 0.1
    br.submit(0, _QA(), 0.1)
    with pytest.raises(RuntimeError, match="in-flight"):
        br.close(0)
    # force the next extend over capacity while the query is pending
    with pytest.raises(RuntimeError, match="in-flight"):
        for tick in range(6):
            br.extend(0, scene64.render(tick), 0.2 + tick * 0.1)
    # draining clears the way: the session rolls over / closes cleanly
    br.drain(0.5)
    for tick in range(6):
        br.extend(0, scene64.render(tick), 0.8 + tick * 0.1)
    assert br.telemetry[0].rollovers >= 1
    br.close(0)


def test_bridge_is_deterministic(scene64):
    def run_once():
        br = _bridge(n=2)
        for k in (0, 1):
            br.open(k, scene64, fps=10.0)
        for tick in range(4):
            for k in (0, 1):
                br.extend(k, scene64.render(tick), tick * 0.1)
        class _QA:
            kind, obj_idx, t_ask = "read_code", 0, 0.2
        for k in (0, 1):
            br.submit(k, _QA(), 0.5)
        res = br.drain(0.5)
        return res, {k: (tuple(t.ttfts), tuple(t.queue_delays),
                         tuple(t.confidences))
                     for k, t in br.telemetry.items()}

    r1, t1 = run_once()
    r2, t2 = run_once()
    assert r1 == r2 and t1 == t2


def test_bridge_rejects_unsupported_backbones():
    archs = registry.list_archs(include_extra=True)
    hybrid = [a for a in archs
              if registry.get_config(a).family == "hybrid"]
    if not hybrid:
        pytest.skip("no hybrid arch registered")
    with pytest.raises(NotImplementedError):
        EngineServerBridge(1, arch=hybrid[0])


# --------------------------------------------------------------------------
# Fleet / scenario integration
# --------------------------------------------------------------------------
def _fleet_members(n=2, duration=2.0):
    from _builders import hetero_fleet_session
    return [hetero_fleet_session(k, duration=duration, hw=64)
            for k in range(n)]


ENGINE_CFG = dict(max_len=128, step_dt=0.004)


def test_fleet_engine_server_end_to_end_deterministic():
    from _builders import metrics_digest
    from repro.core.fleet import Fleet

    def run_once():
        fl = Fleet(_fleet_members(), server="engine",
                   engine_cfg=dict(ENGINE_CFG))
        return fl, fl.run()

    fl, ms = run_once()
    _, ms2 = run_once()
    assert metrics_digest(ms) == metrics_digest(ms2)
    for m, m2 in zip(ms, ms2):
        assert m.server_ttfts == m2.server_ttfts
        assert m.server_queue_delays == m2.server_queue_delays
        assert m.server_confidences == m2.server_confidences
        # every answered question carries TTFT + confidence telemetry
        assert len(m.server_ttfts) == m.n_qa == len(m.qa_results)
        assert all(t > 0 for t in m.server_ttfts)
        assert m.ttft_p95_ms >= m.ttft_p50_ms > 0
    assert fl.bridge.stats.tokens_out > 0


def test_fleet_engine_mode_leaves_channel_dynamics_unchanged():
    """Engine mode swaps the ANSWER source, not the feedback loop: rate,
    latency and confidence series must match the oracle run exactly."""
    from repro.core.fleet import Fleet

    oracle = Fleet(_fleet_members()).run()
    engine = Fleet(_fleet_members(), server="engine",
                   engine_cfg=dict(ENGINE_CFG)).run()
    for mo, me in zip(oracle, engine):
        assert mo.latencies == me.latencies
        assert mo.rates == me.rates
        assert mo.confidences == me.confidences
        assert mo.avg_bitrate == me.avg_bitrate
        assert mo.n_qa == me.n_qa
        assert mo.server_ttfts == []  # oracle: no serving telemetry


def test_fleet_engine_gates():
    from repro.core.fleet import Fleet
    members = _fleet_members()
    with pytest.raises(ValueError, match="server must be"):
        Fleet(members, server="llm")
    with pytest.raises(NotImplementedError, match="megakernel"):
        Fleet(members, server="engine", megakernel=True)
    with pytest.raises(NotImplementedError):
        Fleet(members, server="engine", on_device_server=True)
    fl = Fleet(members, server="engine", engine_cfg=dict(ENGINE_CFG))
    with pytest.raises(NotImplementedError, match="rollout"):
        fl.run(rollout=3)


def test_scenario_spec_server_field_round_trip():
    from repro.core.scenario import ScenarioSpec, cohort_key

    spec = ScenarioSpec(server="engine",
                        engine_kwargs=dict(max_len=128, step_dt=0.004))
    back = ScenarioSpec.from_dict(spec.to_dict())
    assert back == spec
    # old exports (no server fields) still round-trip to the oracle
    d = spec.to_dict()
    del d["server"], d["engine_kwargs"]
    assert ScenarioSpec.from_dict(d).server == "oracle"
    with pytest.raises(ValueError, match="unknown server"):
        ScenarioSpec(server="llm")
    # server mode splits cohorts: oracle and engine specs never share a
    # fleet
    assert cohort_key(spec) != cohort_key(spec.with_(server="oracle"))


def test_run_scenarios_engine_cohort(tmp_path):
    from repro.core.scenario import (ScenarioSpec, run_scenarios,
                                     validate_run_result_json)

    base = ScenarioSpec(duration=2.0, frame_h=64, frame_w=64,
                        qa="periodic",
                        qa_kwargs=dict(start=0.5, period=0.7, count=2,
                                       answer_window=0.5))
    specs = [base.with_(tag="oracle"),
             base.with_(server="engine", engine_kwargs=ENGINE_CFG,
                        tag="engine")]
    r = run_scenarios(specs)
    assert len(r.cohorts) == 2
    doc = r.to_json(str(tmp_path / "r.json"))
    validate_run_result_json(doc)
    by_tag = {rec["spec"]["tag"]: rec["metrics"]
              for rec in doc["scenarios"]}
    # the oracle answers without an engine: no TTFT samples exist, and
    # the percentiles export as NaN (not a fake 0.0) — NaN round-trips
    # through json.dump/load in non-strict mode
    assert math.isnan(by_tag["oracle"]["ttft_p50_ms"])
    assert by_tag["engine"]["ttft_p50_ms"] > 0.0
    servers = {c["server"] for c in doc["cohorts"]}
    assert servers == {"oracle", "engine"}


# --------------------------------------------------------------------------
# Sink+recent eviction (StreamingLLM): kv_cache compaction, engine
# policy, bridge parity
# --------------------------------------------------------------------------
def test_sink_recent_indices():
    np.testing.assert_array_equal(
        kv_cache.sink_recent_indices(10, 2, 3), [0, 1, 7, 8, 9])
    np.testing.assert_array_equal(
        kv_cache.sink_recent_indices(5, 0, 2), [3, 4])
    with pytest.raises(ValueError, match="nothing to evict"):
        kv_cache.sink_recent_indices(5, 2, 3)
    with pytest.raises(ValueError, match="n_recent"):
        kv_cache.sink_recent_indices(5, 2, 0)


def test_page_allocator_release_n():
    al = kv_cache.PageAllocator(8)
    got = al.alloc("a", 5)
    al.release_n("a", 2)
    assert al.owned["a"] == got[:3]
    assert al.utilization == pytest.approx(3 / 8)
    with pytest.raises(ValueError, match="cannot release"):
        al.release_n("a", 4)
    al.release_n("a", 3)
    assert "a" not in al.owned and al.utilization == 0.0


def test_compact_slot_kv_gathers_and_rerotates():
    """Compaction must equal gathering the surviving rows and re-rotating
    each kept key from its old RoPE position to its new one: values move
    untouched, sink rows (delta 0) are bit-identical, other slots and
    the stale tail are untouched."""
    from repro.models import rope

    L, B, S, Hk, hd = TINY.n_layers, 2, 12, TINY.n_kv_heads, TINY.head_dim_
    rng = np.random.default_rng(0)
    k_raw = rng.standard_normal((L, B, S, Hk, hd)).astype(np.float32)
    v_raw = rng.standard_normal((L, B, S, Hk, hd)).astype(np.float32)
    pos = jax.numpy.arange(S)[None]  # (1, S) broadcasting over (L*B, ...)
    cos, sin = rope.rope_angles(pos, hd, TINY.rope_theta)
    k_cached = np.asarray(rope.apply_rope(
        jax.numpy.asarray(k_raw.reshape(L * B, S, Hk, hd)), cos, sin)
    ).reshape(L, B, S, Hk, hd)
    cache = {"k": jax.numpy.asarray(k_cached),
             "v": jax.numpy.asarray(v_raw),
             "length": jax.numpy.full((B,), S, jax.numpy.int32)}
    keep = kv_cache.sink_recent_indices(S, 2, 4)      # [0 1 8 9 10 11]
    out = kv_cache.compact_slot_kv(cache, 1, keep, TINY)
    n_keep = len(keep)
    # expected: the ORIGINAL (unrotated) rows rotated at their NEW pos
    new_pos = jax.numpy.arange(n_keep)[None]
    c2, s2 = rope.rope_angles(new_pos, hd, TINY.rope_theta)
    want_k = np.asarray(rope.apply_rope(
        jax.numpy.asarray(k_raw[:, 1][:, keep]), c2, s2))
    got_k = np.asarray(out["k"][:, 1, :n_keep])
    np.testing.assert_allclose(got_k, want_k, atol=1e-5, rtol=1e-5)
    # sink rows didn't move: delta-0 rotation is exact
    np.testing.assert_array_equal(np.asarray(out["k"][:, 1, :2]),
                                  k_cached[:, 1, :2])
    # values gather without rotation, bit-exact
    np.testing.assert_array_equal(np.asarray(out["v"][:, 1, :n_keep]),
                                  v_raw[:, 1][:, keep])
    # untouched: the other slot, the stale tail, and the length vector
    np.testing.assert_array_equal(np.asarray(out["k"][:, 0]),
                                  k_cached[:, 0])
    np.testing.assert_array_equal(np.asarray(out["k"][:, 1, n_keep:]),
                                  k_cached[:, 1, n_keep:])
    assert int(out["length"][1]) == n_keep and int(out["length"][0]) == S


def test_engine_evicts_instead_of_overflowing(tiny_params):
    """With eviction="sink" a session streams far past max_len: every
    overflow compacts to sink+recent, the length mirror / device length /
    page accounting agree, and counters tally the evicted tokens."""
    eng = _engine(tiny_params, max_len=32, eviction="sink", n_sink=4,
                  kv_page=4)
    eng.open_session(0)
    rng = np.random.default_rng(0)
    for _ in range(16):  # 128 tokens = 4x max_len
        eng.extend_session(
            0, rng.standard_normal((8, TINY.d_model)).astype(np.float32))
    assert eng.session_length(0) <= 32
    assert eng.stats.evictions > 0
    assert eng.stats.tokens_evicted >= 128 - 32
    sess = eng._sessions[0]
    assert int(eng.cache["length"][sess.slot]) == sess.length
    # page accounting shrank with the compactions: pages cover the
    # current length, not the high-water mark
    assert len(eng.allocator.owned[("sid", 0)]) == -(-sess.length // 4)
    assert eng.session_eviction_stats(0) == (sess.evictions,
                                             sess.evicted_tokens)
    # a query still fits and decodes after heavy eviction
    req = eng.submit_query(0, np.asarray([1, 2, 3], np.int32), max_new=4)
    eng.drain_queries()
    assert len(req.output) == 4


def test_engine_eviction_limits_and_guards(tiny_params):
    eng = _engine(tiny_params, max_len=32, eviction="sink", n_sink=4)
    eng.open_session(0)
    eng.extend_session(0, np.zeros((30, TINY.d_model), np.float32))
    # an op bigger than the post-eviction budget still overflows
    with pytest.raises(SessionOverflowError, match="even after"):
        eng.extend_session(0, np.zeros((30, TINY.d_model), np.float32))
    assert eng.session_length(0) == 30  # failed op didn't evict
    # eviction mid-query would shift cache positions under the decode
    eng2 = _engine(tiny_params, max_len=32, eviction="sink", n_sink=4)
    eng2.open_session(0)
    eng2.extend_session(0, np.zeros((24, TINY.d_model), np.float32))
    eng2.submit_query(0, np.asarray([1], np.int32), max_new=2)
    with pytest.raises(RuntimeError, match="in flight"):
        eng2.extend_session(0, np.zeros((8, TINY.d_model), np.float32))
    # knob validation
    with pytest.raises(ValueError, match="eviction"):
        _engine(tiny_params, eviction="lru")
    with pytest.raises(ValueError, match="evict_target"):
        _engine(tiny_params, max_len=32, eviction="sink", n_sink=8,
                evict_target=8)


def test_eviction_preserves_unflushed_token(tiny_params):
    """The lazy-commit final answer token must survive an eviction: it
    lives host-side until the next prefill, and eviction only compacts
    committed cache rows."""
    eng = _engine(tiny_params, max_len=32, eviction="sink", n_sink=4,
                  step_dt=0.0)
    eng.open_session(0)
    rng = np.random.default_rng(1)
    eng.extend_session(
        0, rng.standard_normal((8, TINY.d_model)).astype(np.float32))
    eng.submit_query(0, np.asarray([5, 6], np.int32), max_new=3)
    eng.drain_queries()
    sess = eng._sessions[0]
    assert sess.unflushed is not None
    assert eng.session_length(0) == sess.length + 1
    # this extend overflows (12 + 1 + 24 > 32): evict, then flush
    eng.extend_session(
        0, rng.standard_normal((24, TINY.d_model)).astype(np.float32))
    assert sess.unflushed is None
    assert sess.evictions == 1
    # post-eviction length = allowed target + unflushed + new embeds
    assert sess.length == min(16, 32 - 25) + 1 + 24


def test_close_session_guards_inflight_state(tiny_params):
    eng = _engine(tiny_params, max_len=64)
    eng.open_session(0)
    eng.extend_session(0, np.ones((4, TINY.d_model), np.float32))
    eng.submit_query(0, np.asarray([1, 2], np.int32), max_new=2)
    with pytest.raises(RuntimeError, match="in-flight"):
        eng.close_session(0)
    eng.drain_queries()
    # drained: the final answer token is still unflushed
    with pytest.raises(RuntimeError, match="unflushed"):
        eng.close_session(0)
    # flushing it (empty extend) makes the close clean...
    eng.extend_session(0, np.zeros((0, TINY.d_model), np.float32))
    eng.close_session(0)
    # ...and discard=True force-closes through either guard
    eng.open_session(1)
    eng.extend_session(1, np.ones((4, TINY.d_model), np.float32))
    eng.submit_query(1, np.asarray([1], np.int32), max_new=2)
    eng.close_session(1, discard=True)
    assert 1 not in eng._sessions


def test_retire_allows_decode_to_fill_max_len(tiny_params):
    """Regression: the full-slot check retired a request one token early
    (`>= max_len - 1`) and read the raw slot cache length.  A request
    whose prompt+output exactly fills max_len must get that last token:
    with prompt 8 and max_len 16, 9 output tokens fit (the final sampled
    token needs no cache row), not 8."""
    eng = _engine(tiny_params, max_batch=1, max_len=16)
    eng.submit(_req(0, n=8, max_new=100))
    done = eng.run_until_drained()
    assert len(done) == 1
    assert len(done[0].output) == 16 - 8 + 1
    # the cache row budget was exactly consumed, never exceeded
    assert int(eng.cache["length"][0]) == 16


def test_bridge_eviction_never_rolls_over(scene64):
    """Parity tier (a): a session streaming >= 4x max_len frame tokens
    under the default (eviction) bridge never rolls over, keeps
    answering, and is digest-reproducible across two runs."""
    def run_once():
        br = _bridge(max_len=64)
        assert br.eviction and br.engine.eviction == "sink"
        br.open(0, scene64, fps=10.0)
        for tick in range(64):  # 256 patch tokens = 4x max_len
            br.extend(0, scene64.render(tick % 8), tick * 0.1)
        class _QA:
            kind, obj_idx, t_ask = "read_code", 0, 3.0
        br.submit(0, _QA(), 6.5)
        req = br._pending[0][1]
        br.drain(6.5)
        tel = br.telemetry[0]
        return (tuple(req.output), tel.evictions, tel.evicted_tokens,
                tel.rollovers, tuple(tel.ttfts), tuple(tel.queue_delays),
                tuple(tel.confidences))

    r1, r2 = run_once(), run_once()
    assert r1 == r2
    _, evictions, evicted_tokens, rollovers, *_ = r1
    assert rollovers == 0
    assert evictions > 0
    assert evicted_tokens >= 256 - 64


def test_bridge_short_session_identical_with_or_without_eviction(scene64):
    """Parity tier (b): while no overflow occurs, the eviction engine
    path is bit-identical to the legacy (rollover-mode, i.e. pre-PR)
    path — eviction only engages at the capacity boundary."""
    def run_once(evict: bool):
        br = _bridge(max_len=96, eviction=evict)
        br.open(0, scene64, fps=10.0)
        for tick in range(6):  # 24 tokens: far from max_len
            br.extend(0, scene64.render(tick), tick * 0.1)
        class _QA:
            kind, obj_idx, t_ask = "read_code", 0, 0.3
        result = br.answer_now(0, _QA(), 0.7)
        tel = br.telemetry[0]
        return (result, tuple(tel.ttfts), tuple(tel.queue_delays),
                tuple(tel.confidences), br.engine.session_length(0))

    assert run_once(True) == run_once(False)


def test_serving_snapshot_schema():
    from benchmarks.snapshot import (check_serving_coverage,
                                     load_serving_snapshot,
                                     validate_serving_snapshot)

    doc = load_serving_snapshot()  # the committed BENCH_serving.json
    validate_serving_snapshot(doc)
    assert check_serving_coverage(doc, dict(doc["metrics"])) == []
    missing = check_serving_coverage(doc, {})
    # one entry per committed metric, plus the structural requirement
    # that the fresh bench produce the eviction.* stage at all
    assert len(missing) == len(doc["metrics"]) + 1
    assert any("eviction" in m for m in missing)
    # a fresh bench without the eviction stage fails even if the
    # committed document predates it
    no_evict = {k: v for k, v in doc["metrics"].items()
                if not k.startswith("eviction.")}
    legacy = dict(doc, metrics=no_evict)
    assert check_serving_coverage(legacy, no_evict) != []
    bad = dict(doc)
    bad["metrics"] = {}
    with pytest.raises(ValueError):
        validate_serving_snapshot(bad)

"""Scenario API tests: spec/grid/preset mechanics, cohort partitioning,
exact parity of run_scenarios with direct Fleet execution, mixed-shape
grids in one call, and the RunResult export schema."""
import dataclasses
import json

import numpy as np
import pytest

from _builders import (assert_metrics_equal as _assert_metrics_equal,
                       base_scenario_spec as _base,
                       hetero_scenario_specs as _hetero_specs,
                       mixed_cohort_specs)
from repro.api import (PRESETS, RunResult, ScenarioSpec, build_fleet,
                       build_session, cohort_key, compile_cohorts, grid,
                       preset, register_preset, run_scenarios,
                       validate_run_result_json)
from repro.core.fleet import Fleet
from repro.core.session import run_session


# --------------------------------------------------------------------------
# Spec mechanics
# --------------------------------------------------------------------------
def test_spec_is_frozen_and_hashable():
    s = ScenarioSpec(trace_kwargs=dict(mbps=0.4),
                     qa_kwargs=dict(count=3))
    assert hash(s) == hash(ScenarioSpec(trace_kwargs=dict(mbps=0.4),
                                        qa_kwargs=dict(count=3)))
    with pytest.raises(dataclasses.FrozenInstanceError):
        s.system = "webrtc"


def test_spec_rejects_unknown_system():
    with pytest.raises(ValueError):
        ScenarioSpec(system="quic")


def test_spec_rejects_nested_dict_kwargs():
    # freeze/thaw is one level deep; nesting would round-trip corrupted
    with pytest.raises(ValueError):
        ScenarioSpec(trace_kwargs=dict(opts=dict(a=1)))


def test_spec_dict_round_trip():
    s = _hetero_specs()[1]
    assert ScenarioSpec.from_dict(s.to_dict()) == s
    # survives JSON too (lists/tuples normalize to tuples on the way in)
    assert ScenarioSpec.from_dict(json.loads(json.dumps(s.to_dict()))) == s


def test_with_and_flags():
    s = ScenarioSpec(system="webrtc").with_(system="artic", cc_kind="bbr")
    assert s.flags == dict(use_recap=True, use_zeco=True)
    assert s.session_config().cc_kind == "bbr"


def test_grid_order_and_scalars():
    specs = grid(ScenarioSpec(), system=["webrtc", "artic"],
                 cc_kind=["gcc", "bbr"], trace_seed=7)
    assert len(specs) == 4
    # first axis varies slowest; scalar axes broadcast
    assert [(s.system, s.cc_kind) for s in specs] == [
        ("webrtc", "gcc"), ("webrtc", "bbr"),
        ("artic", "gcc"), ("artic", "bbr")]
    assert all(s.trace_seed == 7 for s in specs)


def test_preset_registry():
    assert preset("fleet-thumb").frame_hw == (64, 64)
    with pytest.raises(KeyError):
        preset("nope")
    with pytest.raises(ValueError):
        register_preset("artic", ScenarioSpec())
    register_preset("_test_tmp", ScenarioSpec(tag="x"))
    assert preset("_test_tmp").tag == "x"
    del PRESETS["_test_tmp"]


# --------------------------------------------------------------------------
# Cohort partitioning
# --------------------------------------------------------------------------
def test_mixed_grid_compiles_to_expected_cohorts():
    """Two frame sizes x two fps -> four cohorts, grouped by
    compatibility key and ordered by first occurrence."""
    specs = grid(_base(duration=4.0), frame_h=[64, 128], fps=[10.0, 5.0],
                 system=["webrtc", "artic"])
    assert len(specs) == 8
    cohorts = compile_cohorts(specs)
    assert len(cohorts) == 4
    assert [c.indices for c in cohorts] == [
        (0, 1), (2, 3), (4, 5), (6, 7)]
    for c in cohorts:
        keys = {cohort_key(specs[i]) for i in c.indices}
        assert keys == {c.key}
    # partition covers every index exactly once
    all_idx = sorted(i for c in cohorts for i in c.indices)
    assert all_idx == list(range(len(specs)))


def test_build_fleet_rejects_mixed_cohorts():
    specs = grid(_base(4.0), frame_h=[64, 128])
    with pytest.raises(ValueError):
        build_fleet(specs)


# --------------------------------------------------------------------------
# Exact parity with the lower layer
# --------------------------------------------------------------------------
def test_run_scenarios_matches_direct_fleet_bit_for_bit():
    """The tentpole contract: run_scenarios over one cohort reproduces a
    hand-built Fleet over the same materialized sessions, metric for
    metric (every list element equal, no tolerance)."""
    specs = _hetero_specs()
    direct = Fleet([build_session(s) for s in specs]).run()
    result = run_scenarios(specs)
    assert len(result) == 4 and len(result.cohorts) == 1
    for a, b in zip(direct, result.metrics):
        _assert_metrics_equal(a, b)


def test_mixed_shape_grid_runs_in_one_call_and_matches_per_cohort_fleets():
    """A grid mixing frame sizes and fps runs in a single run_scenarios
    call; each cohort's results are identical to running that cohort as
    its own Fleet."""
    specs = grid(_base(duration=4.0), frame_h=[64, 128], fps=[10.0, 5.0],
                 scene_seed=[0, 1])
    specs = [s.with_(frame_w=s.frame_h, trace_seed=s.scene_seed,
                     seed=s.scene_seed) for s in specs]
    result = run_scenarios(specs)        # one call, four cohorts
    assert len(result.cohorts) == 4
    for cohort in result.cohorts:
        own = Fleet([build_session(specs[i]) for i in cohort.indices]).run()
        for i, m in zip(cohort.indices, own):
            _assert_metrics_equal(m, result.metrics[i])


def test_single_spec_matches_serial_run_session():
    """N=1 cohort == serial run_session (the fleet parity, reachable
    straight from a spec)."""
    spec = _base(6.0).with_(trace="fluctuating", trace_seed=3, seed=3)
    s = build_session(spec)
    serial = run_session(s.scene, s.qa_samples, s.trace, s.cfg)
    result = run_scenarios(spec)
    _assert_metrics_equal(serial, result.metrics[0])


def test_preset_name_accepted_directly():
    r = run_scenarios(["webrtc"], fused_plan=False)
    assert len(r) == 1 and r.specs[0].system == "webrtc"


def test_run_result_rows_map_back_to_specs_after_repartitioning():
    """Regression for cohort ordering: with cohorts INTERLEAVED in the
    input (A B A B ...), run_scenarios partitions them apart, runs each
    as one fleet, and must re-stack results into input positions.  Every
    row is pinned to its originating spec by the tag/permutation
    round-trip: the same multiset of specs run in cohort-grouped order
    yields identical metrics per TAG, and the JSON export maps each row
    back to its spec and cohort."""
    inter = mixed_cohort_specs(duration=3.0, sizes=(64, 128),
                               counts=(3, 2), interleave=True)
    grouped = mixed_cohort_specs(duration=3.0, sizes=(64, 128),
                                 counts=(3, 2), interleave=False)
    assert inter != grouped  # genuinely permuted input
    assert sorted(s.tag for s in inter) == sorted(s.tag for s in grouped)
    r_inter = run_scenarios(inter)
    r_grouped = run_scenarios(grouped)
    # rows come back in input order, attached to their input spec
    assert r_inter.specs == inter and r_grouped.specs == grouped
    by_tag = {s.tag: m for s, m in zip(r_grouped.specs, r_grouped.metrics)}
    for s, m in zip(r_inter.specs, r_inter.metrics):
        _assert_metrics_equal(m, by_tag[s.tag])
    # the export's cohort table round-trips the mapping
    doc = r_inter.to_json()
    validate_run_result_json(doc)
    for i, rec in enumerate(doc["scenarios"]):
        assert ScenarioSpec.from_dict(rec["spec"]) == inter[i]
        assert i in doc["cohorts"][rec["cohort"]]["sessions"]
    # cohorts really did split the interleaved input apart
    assert len(doc["cohorts"]) == 2
    assert doc["cohorts"][0]["sessions"] == [0, 2, 4]
    assert doc["cohorts"][1]["sessions"] == [1, 3]


# --------------------------------------------------------------------------
# RunResult: arrays, selection, aggregation, export
# --------------------------------------------------------------------------
@pytest.fixture(scope="module")
def small_result() -> RunResult:
    specs = grid(_base(4.0), system=["webrtc", "artic"],
                 trace_seed=[0, 1])
    return run_scenarios(specs)


def test_result_arrays_and_order(small_result):
    arr = small_result.arrays()
    assert set(arr) >= {"accuracy", "avg_latency_ms", "bandwidth_used"}
    assert all(v.shape == (4,) for v in arr.values())
    np.testing.assert_array_equal(
        arr["accuracy"],
        [m.accuracy for m in small_result.metrics])


def test_result_select_and_aggregate(small_result):
    artic = small_result.select(system="artic")
    assert len(artic) == 2
    assert all(s.system == "artic" for s in artic.specs)
    agg = small_result.aggregate(by=("system",), fields=("accuracy",))
    assert set(agg) == {("webrtc",), ("artic",)}
    assert agg[("artic",)]["accuracy"] == pytest.approx(
        float(np.mean(artic.values("accuracy"))))


def test_result_json_schema_round_trip(small_result, tmp_path):
    path = tmp_path / "run.json"
    doc = small_result.to_json(str(path))
    validate_run_result_json(doc)
    validate_run_result_json(json.loads(path.read_text()))
    # specs survive the export
    back = [ScenarioSpec.from_dict(rec["spec"]) for rec in doc["scenarios"]]
    assert back == small_result.specs


def test_result_json_schema_rejects_corruption(small_result):
    doc = small_result.to_json()
    bad = json.loads(json.dumps(doc))
    bad["scenarios"][0]["metrics"].pop("accuracy")
    with pytest.raises(ValueError):
        validate_run_result_json(bad)
    bad2 = json.loads(json.dumps(doc))
    bad2["cohorts"][0]["sessions"] = bad2["cohorts"][0]["sessions"][:-1]
    with pytest.raises(ValueError):
        validate_run_result_json(bad2)
    with pytest.raises(ValueError):
        validate_run_result_json({"schema": "other"})


def test_result_csv(small_result):
    text = small_result.to_csv()
    lines = text.strip().splitlines()
    assert len(lines) == 1 + len(small_result)
    assert lines[0].startswith("system,")
    assert "accuracy" in lines[0]


def test_profile_exposes_per_cohort_phase_times():
    specs = grid(_base(3.0).with_(qa="none", qa_kwargs={}),
                 frame_h=[64, 128])
    specs = [s.with_(frame_w=s.frame_h) for s in specs]
    r = run_scenarios(specs, profile=True)
    assert r.phase_times is not None and len(r.phase_times) == 2
    assert all(set(pt) >= {"client", "plan", "encode", "channel"}
               for pt in r.phase_times)

"""Family-level smoke tests on hand-rolled tiny configs.

(Per-assigned-architecture smoke tests live in test_arch_smoke.py; these
exercise each family's forward / loss / prefill / decode consistency.)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import transformer as tfm
from repro.models.config import ModelConfig, MoEConfig, RGLRUConfig, SSMConfig

BATCH, SEQ = 2, 32


def tiny(family, **kw):
    base = dict(
        name=f"tiny-{family}", family=family, n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab=256,
        attn_block_q=8, attn_block_kv=8, blocked_threshold=1 << 30,
        dtype="float32", param_dtype="float32",
    )
    base.update(kw)
    return ModelConfig(**base).validate()


CFGS = {
    "dense": tiny("dense", qk_norm=True),
    # capacity_factor=2.0 makes routing drop-free at S=32/E=4/k=2 so that
    # batched (forward) and incremental (decode) routing agree exactly;
    # with drops they legitimately differ (GShard capacity semantics).
    "moe": tiny("moe", moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=64,
                                     capacity_factor=2.0)),
    "ssm": tiny("ssm", n_heads=4, ssm=SSMConfig(d_state=16, headdim=16, chunk=8)),
    "hybrid": tiny("hybrid", n_layers=3, n_kv_heads=1, local_window=16,
                   rglru=RGLRUConfig(lru_width=64)),
}


@pytest.fixture(params=list(CFGS))
def cfg(request):
    return CFGS[request.param]


def _batch(cfg, seq=SEQ):
    key = jax.random.PRNGKey(0)
    tokens = jax.random.randint(key, (BATCH, seq), 0, cfg.vocab)
    return {"tokens": tokens, "labels": tokens}


def test_forward_shapes_and_finite(cfg):
    params = tfm.init(jax.random.PRNGKey(1), cfg)
    logits, aux = tfm.forward(params, _batch(cfg), cfg)
    assert logits.shape == (BATCH, SEQ, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    assert np.isfinite(float(aux))


def test_loss_and_grads_finite(cfg):
    params = tfm.init(jax.random.PRNGKey(1), cfg)
    (loss, metrics), grads = jax.value_and_grad(
        tfm.loss_fn, has_aux=True)(params, _batch(cfg), cfg)
    assert np.isfinite(float(loss))
    leaves = jax.tree.leaves(grads)
    assert leaves and all(np.isfinite(np.asarray(g)).all() for g in leaves)


def test_prefill_matches_forward(cfg):
    params = tfm.init(jax.random.PRNGKey(1), cfg)
    batch = _batch(cfg)
    logits_full, _ = tfm.forward(params, batch, cfg)
    logits_last, cache = tfm.prefill(params, batch, cfg)
    np.testing.assert_allclose(
        np.asarray(logits_last[:, 0]), np.asarray(logits_full[:, -1]),
        rtol=2e-4, atol=2e-4)
    assert int(cache["length"]) == SEQ


def test_decode_matches_forward(cfg):
    """Teacher-forced decode must reproduce the parallel forward logits."""
    params = tfm.init(jax.random.PRNGKey(1), cfg)
    batch = _batch(cfg)
    logits_full, _ = tfm.forward(params, batch, cfg)

    prompt = {"tokens": batch["tokens"][:, : SEQ // 2]}
    logits_last, cache = tfm.prefill(params, prompt, cfg,
                                     max_len=SEQ if cfg.family != "ssm" else None)
    step_fn = jax.jit(lambda p, c, b: tfm.decode_step(p, c, b, cfg))
    outs = [np.asarray(logits_last[:, 0])]
    for t in range(SEQ // 2, SEQ - 1):
        logits, cache = step_fn(params, cache, {"tokens": batch["tokens"][:, t:t + 1]})
        outs.append(np.asarray(logits[:, 0]))
    got = np.stack(outs, axis=1)  # (B, SEQ/2, V) predictions at SEQ/2-1 .. SEQ-2
    want = np.asarray(logits_full[:, SEQ // 2 - 1: SEQ - 1])
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_blocked_path_equals_full(cfg):
    if cfg.family == "ssm":
        pytest.skip("no attention")
    params = tfm.init(jax.random.PRNGKey(1), cfg)
    batch = _batch(cfg)
    full, _ = tfm.forward(params, batch, cfg)
    blocked, _ = tfm.forward(params, batch, cfg.replace(blocked_threshold=8))
    np.testing.assert_allclose(np.asarray(blocked), np.asarray(full),
                               rtol=2e-4, atol=2e-4)


def test_moe_gather_matches_gshard():
    """The scatter/gather dispatch must agree exactly with the GShard
    one-hot formulation (same arrival-order capacity semantics)."""
    import dataclasses
    from repro.models import moe as moe_lib
    cfg = CFGS["moe"]
    params = tfm.init(jax.random.PRNGKey(1), cfg)
    layer0 = jax.tree.map(lambda p: p[0], params["layers"])
    x = jax.random.normal(jax.random.PRNGKey(2), (BATCH, SEQ, cfg.d_model))
    y1, aux1 = moe_lib.moe_apply_gshard(layer0["moe"], x, cfg)
    cfg_g = cfg.replace(moe=dataclasses.replace(cfg.moe, moe_impl="gather"))
    y2, aux2 = moe_lib.moe_apply_gather(layer0["moe"], x, cfg_g)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(float(aux1), float(aux2), rtol=1e-5)


def test_moe_gather_with_drops_matches_gshard():
    """Equivalence must hold when capacity drops occur too."""
    import dataclasses
    base = CFGS["moe"]
    tight = dataclasses.replace(base.moe, capacity_factor=0.5)
    from repro.models import moe as moe_lib
    cfg = base.replace(moe=tight)
    params = tfm.init(jax.random.PRNGKey(1), cfg)
    layer0 = jax.tree.map(lambda p: p[0], params["layers"])
    x = jax.random.normal(jax.random.PRNGKey(3), (BATCH, SEQ, cfg.d_model))
    y1, _ = moe_lib.moe_apply_gshard(layer0["moe"], x, cfg)
    y2, _ = moe_lib.moe_apply_gather(layer0["moe"], x, cfg)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-5, atol=2e-5)


def test_int8_kv_cache_close_to_fp():
    """int8 KV (per-token-per-head scales) must track the fp cache decode
    closely (serving memory optimization, DESIGN.md §Perf)."""
    cfg = CFGS["dense"]
    params = tfm.init(jax.random.PRNGKey(1), cfg)
    batch = _batch(cfg)
    prompt = {"tokens": batch["tokens"][:, :16]}
    logits_fp, cache_fp = tfm.prefill(params, prompt, cfg, max_len=24)
    cfg8 = cfg.replace(kv_cache_dtype="int8")
    logits_q, cache_q = tfm.prefill(params, prompt, cfg8, max_len=24)
    np.testing.assert_allclose(np.asarray(logits_q), np.asarray(logits_fp),
                               rtol=0.1, atol=0.15)
    step = {"tokens": batch["tokens"][:, 16:17]}
    out_fp, _ = tfm.decode_step(params, cache_fp, step, cfg)
    out_q, _ = tfm.decode_step(params, cache_q, step, cfg8)
    # logit agreement within quantization noise; top-1 must match mostly
    top_fp = np.argmax(np.asarray(out_fp[:, 0]), -1)
    top_q = np.argmax(np.asarray(out_q[:, 0]), -1)
    assert (top_fp == top_q).mean() >= 0.5
    np.testing.assert_allclose(np.asarray(out_q), np.asarray(out_fp),
                               rtol=0.2, atol=0.3)

"""Capacity-knee sweep: open-loop churn at increasing offered load.

Sweeps the Poisson arrival rate over multiples of a base rate against a
fixed slot count (the oracle server, so the sweep measures the
admission/fleet layer, not model decode speed).  With `slots` concurrent
sessions of mean lifetime L, theoretical capacity is slots/L
sessions/sec; below it, served tracks offered, and past it the admission
queue grows and served throughput flattens — the knee.  The sweep
reports per-point steady-state metrics plus the detected knee, and rides
in BENCH_serving.json as the `load.*` stage (coverage-gated like every
other serving metric: absolutes move with the runner, key coverage must
not).

    PYTHONPATH=src python -m benchmarks.bench_load
"""
from __future__ import annotations

import time
from typing import Dict, Sequence

MULTIPLIERS: Sequence[float] = (0.5, 1.0, 2.0)
BASE_RATE = 1.0        # arrivals/sec at x1
SLOTS = 2
MEAN_LIFETIME = 2.0    # -> capacity ~ SLOTS / MEAN_LIFETIME = 1.0 /s
DURATION = 12.0   # long enough that end-of-run truncation does not
#   read as saturation at the under-loaded sweep points
SERVED_FRACTION_KNEE = 0.9   # knee: served drops below 90% of offered


def bench_load(multipliers: Sequence[float] = MULTIPLIERS,
               base_rate: float = BASE_RATE, slots: int = SLOTS,
               duration: float = DURATION) -> Dict[str, float]:
    """Run the churn sweep; flat `load.*` metrics for the snapshot."""
    from repro.core.churn import run_churn
    from repro.core.scenario import ScenarioSpec

    t0 = time.perf_counter()
    metrics: Dict[str, float] = {}
    knee_offered = float("nan")
    peak_served = 0.0
    for m in multipliers:
        spec = ScenarioSpec(
            scene="retail", frame_h=64, frame_w=64, duration=duration,
            qa="none", workload="churn",
            churn_kwargs=dict(rate=base_rate * m, slots=slots,
                              mean_lifetime=MEAN_LIFETIME, seed=17),
            tag=f"load-x{m:g}")
        s = run_churn(spec).summary()
        key = f"load.x{m:g}"
        metrics[f"{key}.offered_per_sec"] = s["offered_per_sec"]
        metrics[f"{key}.served_per_sec"] = s["sessions_per_sec"]
        metrics[f"{key}.admission_p95_ms"] = s["admission_p95_ms"]
        metrics[f"{key}.queue_depth_peak"] = s["queue_depth_peak"]
        peak_served = max(peak_served, s["sessions_per_sec"])
        saturated = (s["offered_per_sec"] > 0
                     and s["sessions_per_sec"]
                     < SERVED_FRACTION_KNEE * s["offered_per_sec"])
        if saturated and knee_offered != knee_offered:  # first saturated pt
            knee_offered = s["offered_per_sec"]
    if knee_offered != knee_offered:  # never saturated: knee beyond sweep
        knee_offered = metrics[f"load.x{multipliers[-1]:g}.offered_per_sec"]
    metrics["load.peak_sessions_per_sec"] = peak_served
    metrics["load.knee_offered_per_sec"] = knee_offered
    metrics["load.wall_s"] = time.perf_counter() - t0
    return metrics


def _main() -> None:
    metrics = bench_load()
    for k in sorted(metrics):
        print(f"  {k:36s} {metrics[k]:.3f}")


if __name__ == "__main__":
    _main()

"""Fig. 2 — measurement study: CC-driven bitrate under the elevator trace.

Reproduces the paper's observation chain: static link saturates; the CC
keeps probing bitrate up; the elevator drop at t=26.25s collapses
bandwidth 5 -> 1.23 Mbps within 1.5 s; the CC adaptation lag causes a
latency spike (paper: 1,389 ms).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, timed
from repro.api import preset, run_scenarios


def run(quick: bool = True):
    spec = preset("webrtc").with_(duration=50.0, trace="elevator")
    result, us = timed(run_scenarios, spec)
    m = result.metrics[0]

    lat = np.asarray([l for l in m.latencies if np.isfinite(l)]) * 1e3
    fps = spec.fps
    pre = lat[: int(25 * fps)]
    spike_win = lat[int(26 * fps): int(33 * fps)]
    spike = float(spike_win.max()) if len(spike_win) else float("nan")
    rows = [
        Row("fig2.baseline_latency_pre_drop_ms", us,
            f"median={np.median(pre):.0f}ms"),
        Row("fig2.latency_spike_after_drop_ms", us, f"peak={spike:.0f}ms"),
        Row("fig2.spike_ratio", us,
            f"{spike / max(np.median(pre), 1e-9):.1f}x"),
    ]
    print(f"[fig2] pre-drop median {np.median(pre):.0f} ms, "
          f"post-drop peak {spike:.0f} ms "
          f"(paper observes 1389 ms spikes from CC lag)")
    return rows

"""Fleet engine throughput — batched multi-session simulation.

Compares sessions/sec of the vectorized fleet engine (repro.core.fleet:
one batched codec dispatch + one ChannelBank advance per tick) against
the serial per-frame `run_session` loop at N in {1, 8, 32, 128}, on a
thumbnail-tier workload (64x64 frames) where the serial loop is
dispatch-bound.  Also reports the per-tick batched encode time of the
jnp rate-controlled path and of the fused Pallas qp_codec kernel.

Members are declared via the "fleet-thumb" scenario preset; the serial
cells materialize the same specs through `build_session` and the fleet
cells compile them through `build_fleet`, so both sides run literally
identical sessions (same scenes, traces, configs, rc probe stride),
interleaved and median-aggregated so background load on shared machines
does not bias either side.

`python -m benchmarks.bench_fleet --devices` runs the device-count
sweep: sessions/sec of the mesh-sharded fleet at N in {8, 64, 256} x
devices in {1, 2, 4, 8}, each cell in its own subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=<D> set before jax
imports (virtual CPU devices — on real accelerators drop the flag and
the sweep uses the physical device counts).
"""
from __future__ import annotations

import gc
import time

import numpy as np

from benchmarks.common import Row
from repro.api import build_fleet, build_session, preset, run_session
from repro.kernels.qp_codec.ops import qp_codec_frames
from repro.video import codec

NS = (1, 8, 32, 128)
HW = 64
TARGET_N, TARGET_X = 32, 5.0

SWEEP_NS = (8, 64, 256)
SWEEP_DEVICES = (1, 2, 4, 8)


def _spec(k: int, duration: float):
    return preset("fleet-thumb").with_(duration=duration, moving=k % 2 == 1,
                                       scene_seed=k, trace_seed=k, seed=k)


def _serial_once(duration: float, seed: int) -> float:
    s = build_session(_spec(seed, duration))
    t0 = time.perf_counter()
    run_session(s.scene, s.qa_samples, s.trace, s.cfg)
    return time.perf_counter() - t0


def _fleet_once(duration: float, n: int) -> float:
    fl = build_fleet([_spec(k, duration) for k in range(n)])
    t0 = time.perf_counter()
    fl.run()
    return time.perf_counter() - t0


def _encode_tick_us(n: int, reps: int = 10) -> float:
    """Per-tick batched rate-controlled encode (one fleet dispatch)."""
    frames = np.stack([build_session(_spec(k, 1.0)).scene.render(0)
                       for k in range(n)]).astype(np.float32)
    qps = np.zeros((n, HW // 8, HW // 8), np.float32)
    tgt = np.full((n,), 5e4, np.float32)
    codec.rate_control_batch(frames, qps, tgt,
                             probe_stride=2)[1].bits.block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = codec.rate_control_batch(frames, qps, tgt, probe_stride=2)
    out[1].bits.block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6


def _pallas_tick_us(n: int, reps: int = 5) -> float:
    """Per-tick fused Pallas encode+decode over the whole fleet batch."""
    frames = np.stack([build_session(_spec(k, 1.0)).scene.render(0)
                       for k in range(n)]).astype(np.float32)
    qps = np.full((n, HW // 8, HW // 8), 30.0, np.float32)
    qp_codec_frames(frames, qps)[1].block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = qp_codec_frames(frames, qps)
    out[1].block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6


def run(quick: bool = True):
    duration = 10.0 if quick else 30.0
    reps = 5 if quick else 7
    rows = []

    # warm every compile shape before timing anything
    _serial_once(duration, 0)
    for n in NS:
        _fleet_once(duration, n)

    # Interleaved serial / fleet(N=32) pairs.  The speedup is the median
    # of per-pair ratios: adjacent-in-time pairs see the same background
    # load on shared machines, so the ratio is far more stable than the
    # two independent medians.
    t_serial, ratios, t_target = [], [], []
    for r in range(reps):
        ts = float(np.mean([_serial_once(duration, 1),
                            _serial_once(duration, 2)]))
        tf = _fleet_once(duration, TARGET_N)
        t_serial.append(ts)
        t_target.append(tf)
        ratios.append(TARGET_N * ts / tf)
    serial_sps = 1.0 / float(np.median(t_serial))
    rows.append(Row("fleet.serial_loop", float(np.median(t_serial)) * 1e6,
                    f"sessions_per_sec={serial_sps:.2f}"))

    for n in NS:
        if n == TARGET_N:
            tf = float(np.median(t_target))
            speedup = float(np.median(ratios))
        else:
            tf = min(_fleet_once(duration, n) for _ in range(2))
            speedup = (n / tf) / serial_sps
        sps = n / tf
        rows.append(Row(f"fleet.batch.N{n}", tf * 1e6,
                        f"sessions_per_sec={sps:.2f},speedup={speedup:.2f}x"))
        if n == TARGET_N:
            status = "OK" if speedup >= TARGET_X else "BELOW"
            print(f"[fleet] N={n}: fleet {sps:.2f} sessions/s vs serial "
                  f"{serial_sps:.2f} -> {speedup:.2f}x median "
                  f"(target >={TARGET_X:.0f}x: {status})")
        else:
            print(f"[fleet] N={n}: {sps:.2f} sessions/s "
                  f"({speedup:.2f}x serial)")

    for n in NS:
        rows.append(Row(f"fleet.encode_tick.N{n}", _encode_tick_us(n),
                        "batched rate_control per tick"))
    for n in (8, 32):
        rows.append(Row(f"fleet.pallas_tick.N{n}", _pallas_tick_us(n),
                        "fused pallas qp_codec per tick"))
    return rows


# --------------------------------------------------------------------------
# Whole-tick rollout sweep (lax.scan windows) + committed snapshot
# --------------------------------------------------------------------------
ROLLOUT_NS = (8, 64, 256)


def _eager_once(duration: float, n: int) -> float:
    fl = build_fleet([_spec(k, duration) for k in range(n)],
                     fused_plan=True)
    gc.collect()   # don't bill this run for the previous run's garbage
    t0 = time.perf_counter()
    fl.run()
    return time.perf_counter() - t0


# rollout execution modes benchmarked as separate snapshot cells;
# "megakernel" runs interpret-mode Pallas on CPU (a validation cell —
# only meaningful as a perf mode on real TPU hardware), so the sweep
# times it at the smallest N only
ROLLOUT_MODES = ("baseline", "on_device_server", "megakernel")
_MODE_KW = {"baseline": {},
            "on_device_server": {"on_device_server": True},
            "megakernel": {"megakernel": True, "on_device_server": True}}


def _rollout_once(duration: float, n: int, window: int,
                  mode: str = "baseline"):
    fl = build_fleet([_spec(k, duration) for k in range(n)],
                     fused_plan=True, **_MODE_KW[mode])
    gc.collect()   # don't bill this run for the previous run's garbage
    t0 = time.perf_counter()
    fl.run(rollout=window)
    return time.perf_counter() - t0, fl


def _rollout_roofline(duration: float, n: int, window: int,
                      wall_per_window: float, mode: str = "baseline",
                      timed_fleet=None):
    """Compile (without running) one window step and derive the roofline
    attribution for it; `wall_per_window` is the measured seconds per
    dispatched window (host replay included — the gap the report
    attributes covers the whole driver, not just the XLA executable).
    `timed_fleet` is the fleet object of a measured run: its rollout's
    phase timers and outfeed byte counter become the host-side
    attribution columns."""
    from repro.core.rollout import FleetRollout
    from repro.roofline.analysis import fleet_step_report

    fl = build_fleet([_spec(k, duration) for k in range(n)],
                     fused_plan=True, **_MODE_KW[mode])
    ro = FleetRollout(fl, window)
    lowered, compiled = ro.aot()
    extra = {}
    timed_ro = getattr(timed_fleet, "_last_rollout", None)
    if timed_ro is not None:
        extra = {"host_replay_s": timed_ro.t_replay,
                 "outfeed_bytes": float(timed_ro._ys_nbytes)}
    return fleet_step_report(lowered, compiled, n_sessions=n,
                             window=ro.window,
                             wall_time_s=wall_per_window, **extra)


def run_rollout(quick: bool = True, write: bool = True):
    """Eager vs rollout sessions/sec at N in ROLLOUT_NS, interleaved and
    median-of-ratios aggregated, each cell rooflined; returns (and by
    default writes) the BENCH_fleet.json snapshot document."""
    from benchmarks.snapshot import (BENCH_SCHEMA, PINNED_EAGER_BASELINE,
                                     SNAPSHOT_PATH, env_knobs,
                                     machine_info, save_snapshot)

    duration = 5.0 if quick else 15.0
    window = 3
    cells = []
    print(f"[fleet --rollout] eager vs rollout={window} "
          f"(duration={duration:.0f}s, fused plan, medians of adjacent "
          f"eager/rollout pairs; modes: {', '.join(ROLLOUT_MODES)})")
    for n in ROLLOUT_NS:
        reps = 3
        # the megakernel cell is interpret-mode Pallas on CPU —
        # validation only, timed at the smallest N to bound the sweep
        modes = [m for m in ROLLOUT_MODES
                 if m != "megakernel" or n == min(ROLLOUT_NS)]
        _eager_once(duration, n)        # warm every compile shape
        for m in modes:
            _rollout_once(duration, n, window, m)
        n_frames = int(duration * _spec(0, duration).fps)
        n_windows = -(-n_frames // window)
        for m in modes:
            # each mode gets its own ADJACENT eager/rollout pairs: the
            # ratio of a pair is taken between back-to-back runs, so
            # slowly-varying machine noise cancels inside the pair
            # instead of drifting between one shared eager measurement
            # and a rollout run several fleets later (the big modes
            # churn ~100s of MB of outfeed, which is exactly the kind
            # of allocator state that made split pairs noisy)
            t_e, t_r, ratios = [], [], []
            fleet_m = None
            for _ in range(reps):
                te_i = _eager_once(duration, n)
                tr_i, fleet_m = _rollout_once(duration, n, window, m)
                t_e.append(te_i)
                t_r.append(tr_i)
                ratios.append(te_i / tr_i)
            te = float(np.median(t_e))
            tr = float(np.median(t_r))
            ratio = float(np.median(ratios))
            roof = _rollout_roofline(duration, n, window, tr / n_windows,
                                     m, fleet_m)
            cells.append({
                "n": n, "mode": m, "window": window,
                "duration_s": duration,
                "eager_sessions_per_sec": n / te,
                "rollout_sessions_per_sec": n / tr,
                "median_ratio": ratio,
                "roofline": roof,
            })
            host = (f", host replay {roof['host_replay_s']:.2f}s"
                    if "host_replay_s" in roof else "")
            print(f"[fleet --rollout] N={n} {m}: eager {n / te:.2f} -> "
                  f"rollout {n / tr:.2f} sessions/s ({ratio:.2f}x), "
                  f"roofline LB {roof['per_session_tick_lb_us']:.1f} "
                  f"us/session-tick vs "
                  f"{roof['per_session_tick_wall_us']:.1f} measured "
                  f"({roof['bottleneck']}-bound, attainment "
                  f"{roof['roofline_attainment']:.1%}{host})")
    headline = {c["n"]: c for c in cells if c["mode"] == "on_device_server"}
    doc = {
        "schema": BENCH_SCHEMA,
        "kind": "fleet",
        "machine": machine_info(),
        "env": env_knobs(),
        "baseline": {"name": "pr5-eager-fleet-thumb",
                     "sessions_per_sec": PINNED_EAGER_BASELINE},
        "cells": cells,
        "summary": {
            "window": window,
            "headline_mode": "on_device_server",
            "vs_pinned_eager": {
                str(n): (c["rollout_sessions_per_sec"]
                         / PINNED_EAGER_BASELINE[str(n)])
                for n, c in headline.items()
                if str(n) in PINNED_EAGER_BASELINE},
            "notes": "ratios are same-process medians of ADJACENT "
                     "eager/rollout pairs (each mode paired with its "
                     "own eager runs, gc.collect before every timed "
                     "run), one cell per (n, mode); "
                     "absolutes move with the runner, ratios gate CI "
                     "(benchmarks.snapshot); the megakernel cell is "
                     "interpret-mode Pallas on CPU (validation, not a "
                     "perf claim)",
        },
    }
    if write:
        save_snapshot(doc)
        print(f"[fleet --rollout] snapshot -> {SNAPSHOT_PATH}")
    return doc


# --------------------------------------------------------------------------
# Device-count sweep (sharded fleet)
# --------------------------------------------------------------------------
def _sweep_cell(n: int, devices: int, duration: float) -> float:
    """One (N, devices) cell, run inside the forced-device subprocess:
    seconds per sharded fleet run (post-warmup)."""
    import jax

    from repro.launch.mesh import make_fleet_mesh

    assert len(jax.devices()) >= devices, (
        f"need {devices} devices, have {len(jax.devices())} — XLA_FLAGS "
        "must force the device count before jax imports")
    mesh = make_fleet_mesh(devices) if devices > 1 else None

    def once() -> float:
        fl = build_fleet([_spec(k, duration) for k in range(n)], mesh=mesh)
        t0 = time.perf_counter()
        fl.run()
        return time.perf_counter() - t0

    once()  # compile warmup
    return min(once() for _ in range(2))


def _child_main(argv) -> None:
    """`--_child N D DURATION`: print one sweep cell as JSON on stdout."""
    import json

    n, devices, duration = int(argv[0]), int(argv[1]), float(argv[2])
    dt = _sweep_cell(n, devices, duration)
    print(json.dumps({"n": n, "devices": devices, "seconds": dt,
                      "sessions_per_sec": n / dt}))


def run_devices(quick: bool = True, ns=SWEEP_NS, devices=SWEEP_DEVICES):
    """Spawn one subprocess per (N, devices) cell with the forced host
    device count, collect sessions/sec, and print the sweep table."""
    import json
    import os
    import subprocess
    import sys

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    duration = 5.0 if quick else 15.0
    rows = []
    grid = {}
    for d in devices:
        for n in ns:
            env = dict(os.environ)
            env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                                + f" --xla_force_host_platform_device_count={d}").strip()
            env["PYTHONPATH"] = (os.path.join(root, "src") + os.pathsep
                                 + env.get("PYTHONPATH", "")).rstrip(os.pathsep)
            r = subprocess.run(
                [sys.executable, "-m", "benchmarks.bench_fleet",
                 "--_child", str(n), str(d), str(duration)],
                capture_output=True, text=True, timeout=1800, env=env,
                cwd=root)
            if r.returncode != 0:
                raise RuntimeError(
                    f"sweep cell N={n} D={d} failed:\n{r.stderr[-2000:]}")
            cell = json.loads(r.stdout.strip().splitlines()[-1])
            grid[(n, d)] = cell["sessions_per_sec"]
            rows.append(Row(f"fleet.sharded.N{n}.D{d}",
                            cell["seconds"] * 1e6,
                            f"sessions_per_sec={cell['sessions_per_sec']:.2f}"))
    print(f"\n[fleet --devices] sessions/sec "
          f"(duration={duration:.0f}s, virtual CPU devices)")
    header = "  N \\ D " + "".join(f"{d:>10}" for d in devices)
    print(header)
    for n in ns:
        line = f"  {n:<6}" + "".join(f"{grid[(n, d)]:>10.2f}"
                                     for d in devices)
        print(line)
    return rows


def _main() -> None:
    import argparse

    from benchmarks.common import QUICK

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--devices", action="store_true",
                    help="run the sharded device-count sweep "
                         "(subprocesses with forced host device counts)")
    ap.add_argument("--rollout", action="store_true",
                    help="run the eager-vs-rollout sweep with roofline "
                         "attribution and write BENCH_fleet.json")
    ap.add_argument("--_child", nargs=3, metavar=("N", "D", "DURATION"),
                    help=argparse.SUPPRESS)
    args = ap.parse_args()
    if args._child:
        _child_main(args._child)
        return
    if args.rollout:
        run_rollout(QUICK)
        return
    rows = run_devices(QUICK) if args.devices else run(QUICK)
    print("\nname,us_per_call,derived")
    for r in rows:
        print(r.csv())


if __name__ == "__main__":
    _main()

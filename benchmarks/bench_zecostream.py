"""Fig. 11 — ZeCoStream accuracy vs bitrate: context-aware QP allocation
vs context-agnostic standard encoding at the industry bitrate ladder.

Also derives the two headline numbers: accuracy preserved at ~290 Kbps
(paper: 0.39 -> 0.60) and the bitrate needed for 0.9 accuracy (paper:
3171 -> 908 Kbps).

Fleet-scale additions: per-tick phase breakdown (plan / encode / channel
/ decode / server) of the vectorized fleet engine at N in {1, 8, 32},
and the plan-phase speedup of the ZeCoStreamBank's single jitted
dispatch over the old per-session plan loop.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Row, shared_benchmark, timed
from repro.api import grid, run_scenarios
from repro.core.zecostream import (TimedBoxes, ZeCoStream, ZeCoStreamBank,
                                   reference_surface)
from repro.devibench.pipeline import accuracy_at_bitrate

LADDER = [200, 290, 400, 710, 968, 1700]
FLEET_SIZES = [1, 8, 32]


def _zeco_shape(sc, rec):
    """Oracle-grounded QP surface (boxes around the queried object —
    what the MLLM feedback converges to)."""
    obj = sc.objects[rec.obj_idx]
    return reference_surface([obj.bbox(rec.t_frame)], (sc.h, sc.w),
                             patch=64)


# --------------------------------------------------------------------------
# Fleet plan-phase instrumentation
# --------------------------------------------------------------------------
def _fleet_specs(n: int, duration: float):
    """Context-aware members on starved uplinks, so ZeCoStream engages."""
    return [p.with_(
        scene=["retail", "street", "office", "document"][k % 4],
        moving=k % 2 == 1, scene_seed=k, trace_seed=k, seed=k,
        trace_kwargs=dict(mbps=0.35 + 0.05 * (k % 4)),
        system=["artic", "webrtc+zeco"][k % 2],
        cc_kind=["gcc", "bbr"][k % 2])
        for k, p in enumerate(grid("zeco-starved", duration=duration,
                                   seed=list(range(n))))]


def _engaged_state(n: int, hw=(256, 256)):
    """Identical engaged feedback state in N legacy objects + one bank."""
    rng = np.random.default_rng(0)
    legacy = [ZeCoStream() for _ in range(n)]
    bank = ZeCoStreamBank(n, hw)
    for k in range(n):
        times = np.linspace(0.0, 1.5, 6)
        rows = []
        for _ in times:
            row = []
            for _ in range(3):
                y0, x0 = rng.uniform(0, 200, 2)
                row.append((y0, x0, y0 + 40, x0 + 40))
            rows.append(row)
        fb = TimedBoxes(times=times, boxes=rows)
        legacy[k].on_feedback(fb)
        bank.on_feedback(k, fb)
    rates = np.full(n, 0.6e6)   # below trigger -> engaged
    confs = np.full(n, 0.4)
    return legacy, bank, rates, confs


def _pre_bank_plan_step(z, t, hw):
    """The pre-bank per-session plan step Fleet.tick ran via build_plan:
    trigger/hysteresis, client-side timestamp matching into a Python box
    list, then the NumPy Eq. 3/4 composition."""
    if not z.engage_decision(0.6e6, 0.4):
        return None
    boxes = z.last_feedback.at(t)
    if not boxes:
        return None
    return reference_surface(boxes, hw, patch=z.patch, mu=z.mu)


def _plan_speedup_rows(quick: bool):
    """Time the bank's single fleet-wide dispatch against the two
    per-session plan loops it replaced: the faithful pre-bank NumPy step
    (`_pre_bank_plan_step`) and the ZeCoStream-object loop (per-session
    qp_shape calls, now kernel-backed).  Each rep interleaves all three
    so load swings on the shared box hit them alike; speedups are
    medians of per-rep ratios (the bench_fleet technique)."""
    rows = []
    hw = (256, 256)
    reps = 30 if quick else 150
    for n in FLEET_SIZES:
        legacy, bank, rates, confs = _engaged_state(n, hw)
        # warmup: compile the surface kernel for both batch shapes
        [z.qp_shape(0.1, hw, float(rates[k]), float(confs[k]))
         for k, z in enumerate(legacy)]
        bank.plan(0.1, rates, confs)

        ts = {"numpy": [], "loop": [], "bank": []}
        for r in range(reps):
            t = 0.1 * r
            t0 = time.perf_counter()
            for z in legacy:
                _pre_bank_plan_step(z, t, hw)
            t1 = time.perf_counter()
            for k, z in enumerate(legacy):
                z.qp_shape(t, hw, float(rates[k]), float(confs[k]))
            t2 = time.perf_counter()
            bank.plan(t, rates, confs)
            t3 = time.perf_counter()
            ts["numpy"].append(t1 - t0)
            ts["loop"].append(t2 - t1)
            ts["bank"].append(t3 - t2)
        med = {k: 1e6 * float(np.median(v)) for k, v in ts.items()}
        x_np = float(np.median(np.asarray(ts["numpy"])
                               / np.asarray(ts["bank"])))
        x_loop = float(np.median(np.asarray(ts["loop"])
                                 / np.asarray(ts["bank"])))
        rows.append(Row(
            f"zeco.plan_speedup@N={n}", med["bank"],
            f"numpy={med['numpy']:.0f}us,loop={med['loop']:.0f}us,"
            f"bank={med['bank']:.0f}us,xnumpy{x_np:.1f},xloop{x_loop:.1f}"))
        print(f"[zeco] plan N={n}: numpy loop {med['numpy']:.0f}us / "
              f"object loop {med['loop']:.0f}us vs bank "
              f"{med['bank']:.0f}us ({x_np:.1f}x / {x_loop:.1f}x)")
    return rows


def _fleet_breakdown_rows(quick: bool):
    """Per-tick wall-clock of each fleet phase at N in {1, 8, 32}."""
    rows = []
    duration = 4.0 if quick else 12.0
    for n in FLEET_SIZES:
        specs = _fleet_specs(n, duration)
        result = run_scenarios(specs, profile=True)
        [pt] = result.phase_times
        ticks = int(duration * specs[0].fps)
        per_tick = {k: 1e6 * v / ticks for k, v in pt.items()}
        rows.append(Row(
            f"fleet.tick_breakdown@N={n}", sum(per_tick.values()),
            ",".join(f"{k}={per_tick[k]:.0f}us"
                     for k in ("client", "render", "plan", "encode",
                               "channel", "decode", "server"))))
        print(f"[fleet] N={n} per-tick: "
              + " ".join(f"{k}={per_tick[k]:.0f}us" for k in per_tick))
    return rows


def run(quick: bool = True):
    bench = shared_benchmark(quick)
    ladder = [200, 290, 400, 968] if quick else LADDER
    rows, base_acc, zeco_acc = [], {}, {}
    for kbps in ladder:
        b, us1 = timed(accuracy_at_bitrate, bench, float(kbps))
        z, us2 = timed(accuracy_at_bitrate, bench, float(kbps),
                       qp_shape_fn=_zeco_shape)
        base_acc[kbps], zeco_acc[kbps] = b, z
        rows.append(Row(f"fig11.accuracy@{kbps}kbps", us1 + us2,
                        f"standard={b:.2f},zecostream={z:.2f}"))

    k290 = 290 if 290 in base_acc else min(base_acc)
    rows.append(Row("fig11.low_bitrate_gain", 0.0,
                    f"@{k290}kbps {base_acc[k290]:.2f}->{zeco_acc[k290]:.2f}"))

    def bitrate_for(accs, target=0.9):
        for k in sorted(accs):
            if accs[k] >= target:
                return k
        return float("inf")

    rows.append(Row("fig11.bitrate_for_0.9_acc", 0.0,
                    f"standard={bitrate_for(base_acc)},"
                    f"zeco={bitrate_for(zeco_acc)}kbps"))
    print(f"[fig11] standard={base_acc} zeco={zeco_acc} "
          "(paper: 0.39->0.60 @290kbps; 0.9 acc at 3171 vs 908 kbps)")

    rows += _plan_speedup_rows(quick)
    rows += _fleet_breakdown_rows(quick)
    return rows

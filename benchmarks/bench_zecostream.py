"""Fig. 11 — ZeCoStream accuracy vs bitrate: context-aware QP allocation
vs context-agnostic standard encoding at the industry bitrate ladder.

Also derives the two headline numbers: accuracy preserved at ~290 Kbps
(paper: 0.39 -> 0.60) and the bitrate needed for 0.9 accuracy (paper:
3171 -> 908 Kbps).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, shared_benchmark, timed
from repro.core.zecostream import importance_map, qp_map
from repro.devibench.pipeline import accuracy_at_bitrate

LADDER = [200, 290, 400, 710, 968, 1700]


def _zeco_shape(sc, rec):
    """Oracle-grounded QP surface (boxes around the queried object —
    what the MLLM feedback converges to)."""
    obj = sc.objects[rec.obj_idx]
    rho = importance_map([obj.bbox(rec.t_frame)], (sc.h, sc.w), patch=64)
    qp = qp_map(rho)
    rep = 64 // 8
    qp_blocks = np.repeat(np.repeat(qp, rep, axis=0), rep, axis=1)
    qp_blocks = qp_blocks[: sc.h // 8, : sc.w // 8]
    return (qp_blocks - qp_blocks.mean()).astype(np.float32)


def run(quick: bool = True):
    bench = shared_benchmark(quick)
    ladder = [200, 290, 400, 968] if quick else LADDER
    rows, base_acc, zeco_acc = [], {}, {}
    for kbps in ladder:
        b, us1 = timed(accuracy_at_bitrate, bench, float(kbps))
        z, us2 = timed(accuracy_at_bitrate, bench, float(kbps),
                       qp_shape_fn=_zeco_shape)
        base_acc[kbps], zeco_acc[kbps] = b, z
        rows.append(Row(f"fig11.accuracy@{kbps}kbps", us1 + us2,
                        f"standard={b:.2f},zecostream={z:.2f}"))

    k290 = 290 if 290 in base_acc else min(base_acc)
    rows.append(Row("fig11.low_bitrate_gain", 0.0,
                    f"@{k290}kbps {base_acc[k290]:.2f}->{zeco_acc[k290]:.2f}"))

    def bitrate_for(accs, target=0.9):
        for k in sorted(accs):
            if accs[k] >= target:
                return k
        return float("inf")

    rows.append(Row("fig11.bitrate_for_0.9_acc", 0.0,
                    f"standard={bitrate_for(base_acc)},"
                    f"zeco={bitrate_for(zeco_acc)}kbps"))
    print(f"[fig11] standard={base_acc} zeco={zeco_acc} "
          "(paper: 0.39->0.60 @290kbps; 0.9 acc at 3171 vs 908 kbps)")
    return rows

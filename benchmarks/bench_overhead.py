"""Fig. 14/15 — overhead: uplink bandwidth usage reduction and the
monetary-cost model of running Artic's feedback loop."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, shared_calibrator, timed
from repro.api import grid, run_scenarios

# $/min cost model from the paper §7.5
COST_MLLM_API = 0.303
COST_RTC_API = 0.01
COST_ZECO = 0.071       # grounding feedback tokens
COST_RECAP = 0.0137     # confidence feedback tokens


def run(quick: bool = True):
    cal = shared_calibrator(quick)
    duration = 40.0 if quick else 90.0
    seeds = [0] if quick else [0, 1, 2]
    rows = []
    specs = [s.with_(scene_seed=s.seed, trace_seed=s.seed)
             for s in grid("artic", cc_kind=["gcc", "bbr"],
                           system=["webrtc", "artic"], seed=seeds,
                           duration=duration,
                           trace_kwargs=dict(switches_per_min=2))]
    result, us_tot = timed(run_scenarios, specs, calibrator=cal)
    # both cc kinds run inside the one timed call, so per-cc wall time
    # is not individually measurable (same convention as fig13)
    rows.append(Row("fig14.fleet_run", us_tot, f"sessions={len(specs)}"))
    usage = {}
    for cc in ("gcc", "bbr"):
        sub = result.select(cc_kind=cc)
        u = {name: float(np.mean(sub.select(system=name)
                                 .values("bandwidth_used")))
             for name in ("webrtc", "artic")}
        usage[cc] = u
        red = 100 * (1 - u["artic"] / max(u["webrtc"], 1.0))
        rows.append(Row(f"fig14.bandwidth.{cc}", 0.0,
                        f"webrtc={u['webrtc'] / 1e6:.2f}Mbps,"
                        f"artic={u['artic'] / 1e6:.2f}Mbps,"
                        f"reduction={red:.1f}%,time=see:fig14.fleet_run"))
        print(f"[fig14/{cc}] uplink usage {u['webrtc'] / 1e6:.2f} -> "
              f"{u['artic'] / 1e6:.2f} Mbps ({red:.1f}% reduction; "
              "paper: 46.84%/69.77% for GCC/BBR)")

    base_cost = COST_MLLM_API + COST_RTC_API
    artic_cost = base_cost + COST_ZECO + COST_RECAP
    rise = 100 * (artic_cost / base_cost - 1)
    rows.append(Row("fig15.monetary_cost", 0.0,
                    f"baseline=${base_cost:.4f}/min,"
                    f"artic=${artic_cost:.4f}/min,rise={rise:.2f}%"))
    print(f"[fig15] ${base_cost:.4f} -> ${artic_cost:.4f}/min "
          f"(+{rise:.2f}%; paper: +27.13%)")
    return rows

"""Fig. 14/15 — overhead: uplink bandwidth usage reduction and the
monetary-cost model of running Artic's feedback loop."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, shared_calibrator, timed
from repro.core.session import SessionConfig, run_session
from repro.net.traces import fluctuating_trace
from repro.video.scenes import make_scene

# $/min cost model from the paper §7.5
COST_MLLM_API = 0.303
COST_RTC_API = 0.01
COST_ZECO = 0.071       # grounding feedback tokens
COST_RECAP = 0.0137     # confidence feedback tokens


def run(quick: bool = True):
    cal = shared_calibrator(quick)
    duration = 40.0 if quick else 90.0
    rows = []
    usage = {}
    for cc in ("gcc", "bbr"):
        u = {}
        for name, flags in (("webrtc", dict(use_recap=False, use_zeco=False)),
                            ("artic", dict(use_recap=True, use_zeco=True))):
            vals, us_tot = [], 0.0
            for seed in ([0] if quick else [0, 1, 2]):
                sc = make_scene("retail", False, seed=seed)
                tr = fluctuating_trace(duration, switches_per_min=2,
                                       seed=seed)
                m, us = timed(run_session, sc, [], tr, SessionConfig(
                    duration=duration, cc_kind=cc, **flags), cal)
                vals.append(m.bandwidth_used)
                us_tot += us
            u[name] = float(np.mean(vals))
        usage[cc] = u
        red = 100 * (1 - u["artic"] / max(u["webrtc"], 1.0))
        rows.append(Row(f"fig14.bandwidth.{cc}", us_tot,
                        f"webrtc={u['webrtc'] / 1e6:.2f}Mbps,"
                        f"artic={u['artic'] / 1e6:.2f}Mbps,"
                        f"reduction={red:.1f}%"))
        print(f"[fig14/{cc}] uplink usage {u['webrtc'] / 1e6:.2f} -> "
              f"{u['artic'] / 1e6:.2f} Mbps ({red:.1f}% reduction; "
              "paper: 46.84%/69.77% for GCC/BBR)")

    base_cost = COST_MLLM_API + COST_RTC_API
    artic_cost = base_cost + COST_ZECO + COST_RECAP
    rise = 100 * (artic_cost / base_cost - 1)
    rows.append(Row("fig15.monetary_cost", 0.0,
                    f"baseline=${base_cost:.4f}/min,"
                    f"artic=${artic_cost:.4f}/min,rise={rise:.2f}%"))
    print(f"[fig15] ${base_cost:.4f} -> ${artic_cost:.4f}/min "
          f"(+{rise:.2f}%; paper: +27.13%)")
    return rows
